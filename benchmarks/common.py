"""Shared benchmark utilities: dataset, timing, CSV emission."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.preprocess import preprocess_batch
from repro.data.digits import make_digits

_CACHE: dict = {}

# Every emit() call also lands here so run.py --json can serialize the
# whole sweep (name -> us_per_call + parsed derived k=v metrics).
RECORDS: list[dict] = []


def digits_dataset(n_train=2000, n_test=1000, seed=1):
    """Preprocessed (deskew + soft-threshold) procedural digit split."""
    key = (n_train, n_test, seed)
    if key not in _CACHE:
        tr_img, tr_lab = make_digits(n_train, seed=seed)
        te_img, te_lab = make_digits(n_test, seed=seed + 1)
        tr = np.asarray(preprocess_batch(
            jnp.asarray(tr_img.reshape(-1, 28, 28)), 0.1)).reshape(-1, 784)
        te = np.asarray(preprocess_batch(
            jnp.asarray(te_img.reshape(-1, 28, 28)), 0.1)).reshape(-1, 784)
        _CACHE[key] = (tr, tr_lab, te, te_lab)
    return _CACHE[key]


def time_fn(fn, *args, reps=10, warmup=2):
    """Median wall time (us) of a jitted callable."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def emit(name: str, us_per_call: float | None, derived: str) -> None:
    """Print one CSV row and record it for --json.

    ``us_per_call=None`` marks an analytic-only row (derived metrics
    with nothing timed): the timing field is left empty and the record
    carries ``analytic: true`` instead of a bogus 0.0 that the perf
    gate or history plots could mistake for a measurement.
    """
    if us_per_call is None:
        print(f"{name},,{derived};analytic=true")
        rec: dict = {"name": name, "analytic": True}
    else:
        print(f"{name},{us_per_call:.2f},{derived}")
        rec = {"name": name, "us_per_call": float(us_per_call)}
    for part in derived.split(";"):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        v = v.rstrip("x")
        try:
            rec[k] = float(v)
        except ValueError:
            rec[k] = v
    RECORDS.append(rec)
