"""Paper Figure 4: power comparison Wenquxing 22A vs ODIN.

Paper: 5.055 W vs 25.949 W on the same Alveo U250 (5.13x).  This
container cannot measure FPGA watts; we run the event-driven energy
model (repro.core.energy) on REAL spike statistics from the trained
network — fused-pipeline machine vs decoupled-accelerator machine — and
report modeled energy + the ratio.  Constants documented in energy.py.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import digits_dataset, emit
from repro.configs.wenquxing_snn import WENQUXING_22A
from repro.core import network
from repro.core.bitpack import unpack
from repro.core.encoder import poisson_encode_batch
from repro.core.energy import EnergyConstants, count_events, energy
from repro.core.trainer import train

PAPER_RATIO = 25.949 / 5.055  # 5.13x


def run() -> dict:
    tr, tr_lab, te, te_lab = digits_dataset(n_train=1000, n_test=200)
    cfg = dataclasses.replace(WENQUXING_22A, n_neurons=40)
    model = train(cfg, tr, tr_lab)
    st = poisson_encode_batch(jax.random.key(7), jnp.asarray(te),
                              cfg.n_steps)
    # real spike statistics over the test presentations
    in_spikes = int(unpack(st.reshape(-1, st.shape[-1]), 784).sum())
    counts = np.asarray(network.infer_batch(model.weights, st, cfg.lif()))
    post = int(counts.sum())
    n_samples = st.shape[0]
    k = EnergyConstants()

    results = {}
    for machine in ("fused", "decoupled"):
        ev = count_events(cfg.n_neurons, cfg.n_inputs,
                          cfg.n_steps * n_samples, in_spikes, post,
                          machine)
        e = energy(ev, k, machine)
        results[machine] = e
        emit(f"fig4/{machine}", e["time_s"] * 1e6,
             f"modeled_E={e['total_J']:.3e}J;avg_P={e['avg_power_W']:.3f}W")
    ratio = results["decoupled"]["total_J"] / results["fused"]["total_J"]
    emit("fig4/ratio-decoupled-over-fused", 0.0,
         f"modeled={ratio:.2f}x;paper={PAPER_RATIO:.2f}x")
    return {"ratio": ratio}


if __name__ == "__main__":
    run()
