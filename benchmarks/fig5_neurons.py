"""Paper Figure 5: classification accuracy vs output-layer size.

Paper (MNIST): 10 -> 80.94%, 20 -> 86.91%, 40 -> 91.91%.  The claim
being validated is the monotone CA growth from active learning, on the
offline digit set.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from benchmarks.common import digits_dataset, emit
from repro.configs.wenquxing_snn import WENQUXING_22A
from repro.core.encoder import poisson_encode_batch
from repro.core.trainer import accuracy, train

PAPER = {10: 0.8094, 20: 0.8691, 40: 0.9191}


def run() -> dict:
    tr, tr_lab, te, te_lab = digits_dataset()
    st = poisson_encode_batch(jax.random.key(99), jnp.asarray(te),
                              WENQUXING_22A.n_steps)
    out = {}
    for n in (10, 20, 40):
        cfg = dataclasses.replace(WENQUXING_22A, n_neurons=n)
        t0 = time.time()
        model = train(cfg, tr, tr_lab)
        acc = accuracy(model, st, jnp.asarray(te_lab))
        emit(f"fig5/neurons-{n}", (time.time() - t0) * 1e6,
             f"CA={acc:.4f} paper={PAPER[n]:.4f}")
        out[n] = acc
    mono = out[10] <= out[20] <= out[40]
    emit("fig5/monotone-trend", 0.0, f"monotone={mono}")
    return out


if __name__ == "__main__":
    run()
