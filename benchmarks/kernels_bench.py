"""RV-SNN granularity claim: fused SNNU step vs unfused SPU->NU->SU,
and the time axis on top: the window kernel vs T per-step launches.

The paper's coarse-grained instruction avoids pipeline stalls; the TPU
analogue is HBM round-trips between kernel launches.  We report (a)
wall time per call across population sizes (relative only — CPU
emulation of the ref/XLA paths, plus one small interpret-mode row that
exercises the actual Pallas kernel body), and (b) the structural metric
that transfers to TPU: analytic minimum HBM bytes per call.

Four levels of scale-out, each vs its sequential baseline:
  * fused step vs unfused SPU->NU->SU chain (one cycle, 3 launches);
  * fused window vs T fused-step launches (the whole presentation
    window, weights/LFSR resident in VMEM — weight traffic drops ~T×);
  * batched training grid vs B sequential window launches (one launch
    trains B independent streams);
  * neuron-sharded window ops vs single-core (per-device weight
    traffic drops D× on a D-device mesh; run.py forces an 8-device
    host mesh so the shard_map path really executes here).
Plus chunked spike streaming: the VMEM spike slab shrinks T/T_chunk×
while staying bit-exact, which is what lets T grow unbounded.
"""

from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

_REPO = Path(__file__).resolve().parents[1]

from benchmarks.common import emit, time_fn
from repro.core import lfsr
from repro.kernels import ops

KW = dict(threshold=192, leak=16, w_exp=128, gain=4, ltp_prob=16)


def _operands(n, w, seed=0):
    rng = np.random.default_rng(seed)
    weights = jnp.asarray(rng.integers(0, 2**32, (n, w), dtype=np.uint32))
    pre = jnp.asarray(rng.integers(0, 2**32, (w,), dtype=np.uint32))
    v = jnp.zeros((n,), jnp.int32)
    teach = jnp.zeros((n,), jnp.int32)
    st = lfsr.seed(1, n * w).reshape(n, w)
    return weights, pre, v, st, teach


def run() -> dict:
    out = {}
    for n, w in ((256, 32), (1024, 64), (4096, 256)):
        n_syn = w * 32
        weights, pre, v, st, teach = _operands(n, w)

        fused = jax.jit(lambda *a: ops.fused_snn_step(
            *a, n_syn=n_syn, **KW))

        # the unfused path is THREE separate kernel launches (the
        # fine-grained instruction sequence): each round-trips HBM
        spu = jax.jit(lambda p, wt: ops.spike_process(p, wt))
        nu = jax.jit(lambda vv, cc: ops.lif_step(
            vv, cc, KW["threshold"], KW["leak"]))
        su = jax.jit(lambda wt, p, f, s: ops.stdp_update(
            wt, p, f, s, w_exp=KW["w_exp"], gain=KW["gain"],
            n_syn=n_syn, ltp_prob=KW["ltp_prob"]))

        def unfused_chain(weights, pre, v, st, teach):
            counts = spu(pre, weights)
            v2, fired = nu(v, counts + teach)
            w2, s2 = su(weights, pre, fired, st)
            return w2, v2, fired, s2

        t_f = time_fn(fused, weights, pre, v, st, teach, reps=5)
        t_u = time_fn(unfused_chain, weights, pre, v, st, teach, reps=5)

        # analytic minimum HBM traffic per step (bytes):
        #   fused:   W r+w, LFSR r+w, spikes r          (one VMEM pass)
        #   unfused: W r(SPU)+r+w(SU), LFSR r+w, spikes r(SPU)+r(SU),
        #            counts w+r, V r+w, fired w+r       (3 launches)
        wb = n * w * 4
        sb = w * 4
        nb = n * 4
        b_f = 2 * wb + 2 * wb + sb            # W rw + LFSR rw + spikes
        b_u = 3 * wb + 2 * wb + 2 * sb + 2 * nb + 2 * nb + 2 * n
        emit(f"kernels/fused-{n}x{n_syn}", t_f,
             f"min_hbm_bytes={b_f}")
        emit(f"kernels/unfused-{n}x{n_syn}", t_u,
             f"min_hbm_bytes={b_u};bytes_ratio={b_u/b_f:.2f}x;"
             f"time_ratio={t_u/max(t_f,1e-9):.2f}x")
        out[(n, n_syn)] = {"bytes_ratio": b_u / b_f,
                           "time_ratio": t_u / max(t_f, 1e-9)}

    # --- time axis: window kernel vs T per-step fused launches ----------
    rng = np.random.default_rng(7)
    for n, w, t_steps in ((256, 32, 72), (1024, 64, 32), (1024, 64, 128)):
        n_syn = w * 32
        weights, _, v, st, teach = _operands(n, w)
        spk = jnp.asarray(
            rng.integers(0, 2**32, (t_steps, w), dtype=np.uint32))

        window = jax.jit(lambda *a: ops.fused_snn_window(
            *a, n_syn=n_syn, **KW))

        # the per-step path is T SEPARATE launches (one dispatch per
        # cycle, state round-tripping host-visible buffers between
        # them) — jitting a scan over the steps would fuse them into
        # the very program the window op builds, measuring nothing
        step = jax.jit(lambda *a: ops.fused_snn_step(
            *a, n_syn=n_syn, **KW))

        def step_chain(weights, spk, v, st, teach):
            for t in range(spk.shape[0]):
                weights, v, f, st = step(weights, spk[t], v, st, teach)
            return weights, v, st

        t_w = time_fn(window, weights, spk, v, st, teach, reps=5)
        t_s = time_fn(step_chain, weights, spk, v, st, teach, reps=5)

        # analytic minimum HBM traffic per window (bytes):
        #   per-step: every launch round-trips weights + LFSR and reads
        #             its spike row           -> T * (4*wb + sb)
        #   window:   weights + LFSR cross HBM once, the T spike rows
        #             stream in, the raster + v stream out
        wb = n * w * 4
        sb = w * 4
        nb = n * 4
        b_steps = t_steps * (4 * wb + sb)
        b_win = 4 * wb + t_steps * sb + t_steps * n + 2 * nb
        emit(f"kernels/window-{n}x{n_syn}xT{t_steps}", t_w,
             f"min_hbm_bytes={b_win};bytes_ratio={b_steps/b_win:.2f}x;"
             f"time_ratio={t_s/max(t_w,1e-9):.2f}x")
        out[(n, n_syn, t_steps)] = {"bytes_ratio": b_steps / b_win,
                                    "time_ratio": t_s / max(t_w, 1e-9)}

    # --- batch axis: batched training grid vs B sequential windows ------
    for n, w, t_steps, b in ((16, 25, 72, 8), (128, 32, 32, 8)):
        n_syn = w * 32
        rngb = np.random.default_rng(11)
        wts = jnp.asarray(
            rngb.integers(0, 2**32, (b, n, w), dtype=np.uint32))
        spk = jnp.asarray(
            rngb.integers(0, 2**32, (b, t_steps, w), dtype=np.uint32))
        v = jnp.zeros((b, n), jnp.int32)
        teach = jnp.zeros((b, n), jnp.int32)
        st = jnp.stack([lfsr.seed(1 + i, n * w).reshape(n, w)
                        for i in range(b)])

        batched = jax.jit(lambda *a: ops.train_window_batch(
            *a, n_syn=n_syn, **KW))
        window = jax.jit(lambda *a: ops.fused_snn_window(
            *a, n_syn=n_syn, **KW))

        # the sequential baseline is B SEPARATE window launches — one
        # per training stream, exactly what the pre-batch trainer did
        # per active-learning block / epoch replica
        def seq_chain(wts, spk, v, st, teach):
            outs = []
            for i in range(b):
                outs.append(window(wts[i], spk[i], v[i], st[i],
                                   teach[i]))
            return outs

        t_b = time_fn(batched, wts, spk, v, st, teach, reps=5)
        t_q = time_fn(seq_chain, wts, spk, v, st, teach, reps=5)
        emit(f"kernels/train-batch-{n}x{n_syn}xT{t_steps}xB{b}", t_b,
             f"launches=1_vs_{b};"
             f"time_ratio={t_q/max(t_b,1e-9):.2f}x")
        out[("train_batch", n, n_syn, t_steps, b)] = {
            "time_ratio": t_q / max(t_b, 1e-9)}

    # --- neuron axis: sharded window ops vs single-core -----------------
    # Runs in a subprocess: the forced multi-device CPU mesh would split
    # this process's thread pool and skew every other wall-clock row.
    ndev = 8
    n, w, t_steps, b = 1024, 64, 32, 8
    n_syn = w * 32
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={ndev}"
                        ).strip()
    env["PYTHONPATH"] = (str(_REPO / "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    try:
        proc = subprocess.run(
            [sys.executable, "-m", "repro.distributed.snn_mesh",
             "--bench", "--devices", str(ndev), "--neurons", str(n),
             "--words", str(w), "--steps", str(t_steps),
             "--batch", str(b)],
            env=env, capture_output=True, text=True, timeout=600)
    except subprocess.TimeoutExpired as e:
        proc = subprocess.CompletedProcess(e.cmd, -1, stdout="",
                                           stderr="timeout after 600s")
    row = next((ln for ln in proc.stdout.splitlines()
                if ln.startswith("BENCH ")), None)
    if proc.returncode == 0 and row is not None:
        kv = dict(p.split("=", 1) for p in row.split()[1:])
        t_1, t_d = float(kv["t_single_us"]), float(kv["t_shard_us"])
        # analytic per-device weight traffic: each device reads only its
        # n/D rows once per launch — the capacity metric that lets
        # populations scale past one core's VMEM
        wb = n * w * 4
        emit(f"kernels/window-shard-{n}x{n_syn}xD{ndev}", t_d,
             f"per_device_weight_bytes={wb // ndev};"
             f"bytes_ratio={ndev:.2f}x;"
             f"time_ratio={t_1/max(t_d,1e-9):.2f}x")
        out[("shard", n, n_syn, ndev)] = {
            "bytes_ratio": float(ndev),
            "time_ratio": t_1 / max(t_d, 1e-9)}
    else:
        print(f"# window-shard row skipped "
              f"(rc={proc.returncode}): {proc.stderr.strip()[:200]}")

    # --- on-core encode: intensity stream vs pre-packed spike windows ---
    # The serving input shrinks from the T*w*4-byte packed window to the
    # n_in uint8 intensities it was generated from (bytes_ratio = T/8 —
    # the encode-fused kernel draws each cycle's spikes in VMEM).  Wall
    # clock compares end-to-end from intensities: host counter-encode +
    # pre-packed launch vs the single encode-fused launch (both XLA-ref
    # on CPU; the structural metric that transfers to TPU is the bytes).
    from repro.core.encoder import encode_from_counter_batch

    b = 8
    for n, w, t_steps in ((1024, 64, 32), (1024, 64, 128)):
        n_in = w * 32
        rng_e = np.random.default_rng(13)
        weights = jnp.asarray(
            rng_e.integers(0, 2**32, (n, w), dtype=np.uint32))
        inten = jnp.asarray(
            rng_e.integers(0, 256, (b, n_in), dtype=np.uint8))
        seeds = jnp.arange(1, b + 1, dtype=jnp.int32)

        pre = jax.jit(lambda wt, x, s, t=t_steps: ops.infer_window_batch(
            wt, encode_from_counter_batch(s, x, t),
            threshold=KW["threshold"], leak=KW["leak"]))
        enc = jax.jit(
            lambda wt, x, s, t=t_steps: ops.infer_window_batch_encode(
                wt, x, s, n_steps=t, threshold=KW["threshold"],
                leak=KW["leak"]))

        t_pre = time_fn(pre, weights, inten, seeds, reps=5)
        t_enc = time_fn(enc, weights, inten, seeds, reps=5)
        in_pre = t_steps * w * 4           # packed window bytes/sample
        in_enc = n_in                      # uint8 intensity bytes/sample
        emit(f"kernels/encode-{n}x{n_in}xT{t_steps}", t_enc,
             f"input_bytes={in_enc};bytes_ratio={in_pre/in_enc:.2f}x;"
             f"time_ratio={t_pre/max(t_enc,1e-9):.2f}x")
        out[("encode", n, n_in, t_steps)] = {
            "bytes_ratio": in_pre / in_enc,
            "time_ratio": t_pre / max(t_enc, 1e-9)}

    # --- intensity-resident training: dataset bytes vs host pre-encode --
    # The trainer's ingestion claim: with encode="kernel" the dataset
    # stays n_in uint8 bytes/sample instead of the T*w*4-byte pre-packed
    # window.  The ratio here is analytic (a function of the row's
    # shape, >= 8x at T=128; the assert only pins the shape choice) —
    # the guarantee that trainer.train really never materializes the
    # N×T×w tensor is tests/test_train_ingest.py's monkeypatch test.
    # Wall clock compares end-to-end from intensities: host
    # counter-encode + pre-packed batched training vs the single
    # encode-fused training launch.
    from repro.core.encoder import encode_from_counter_batch as _efc

    b = 32
    for n, w, t_steps in ((256, 25, 128),):
        n_in = w * 32
        n_syn = n_in
        rng_t = np.random.default_rng(17)
        wts = jnp.asarray(
            rng_t.integers(0, 2**32, (b, n, w), dtype=np.uint32))
        inten = jnp.asarray(
            rng_t.integers(0, 256, (b, n_in), dtype=np.uint8))
        seeds = jnp.arange(1, b + 1, dtype=jnp.int32)
        v = jnp.zeros((b, n), jnp.int32)
        teach = jnp.zeros((b, n), jnp.int32)
        st = jnp.stack([lfsr.seed(1 + i, n * w).reshape(n, w)
                        for i in range(b)])

        pre = jax.jit(lambda wt, x, s, vv, lf, tc, t=t_steps:
                      ops.train_window_batch(
                          wt, _efc(s, x, t), vv, lf, tc, n_syn=n_syn,
                          **KW))
        enc = jax.jit(lambda wt, x, s, vv, lf, tc, t=t_steps:
                      ops.train_window_batch_encode(
                          wt, x, s, vv, lf, tc, n_steps=t, n_syn=n_syn,
                          **KW))

        t_pre = time_fn(pre, wts, inten, seeds, v, st, teach, reps=5)
        t_enc = time_fn(enc, wts, inten, seeds, v, st, teach, reps=5)
        ds_pre = t_steps * w * 4           # pre-packed window bytes/sample
        ds_int = n_in                      # uint8 intensity bytes/sample
        assert ds_pre / ds_int >= 8.0, (
            f"dataset-bytes reduction collapsed: {ds_pre}/{ds_int}")
        emit(f"kernels/train-intensity-{n}x{n_in}xT{t_steps}xB{b}",
             t_enc,
             f"dataset_bytes={ds_int};bytes_ratio={ds_pre/ds_int:.2f}x;"
             f"time_ratio={t_pre/max(t_enc,1e-9):.2f}x")
        out[("train-intensity", n, n_in, t_steps, b)] = {
            "bytes_ratio": ds_pre / ds_int,
            "time_ratio": t_pre / max(t_enc, 1e-9)}

    # --- 2-D (data × neuron) mesh: the batched training grid sharded
    # over BOTH axes vs the 1-D neuron mesh (same 8 devices).  Runs in a
    # subprocess for the same thread-pool reason as the shard row.
    d2, n2 = 2, 4
    n, w, t_steps, b = 1024, 64, 32, 32
    n_syn = w * 32
    try:
        proc = subprocess.run(
            [sys.executable, "-m", "repro.distributed.snn_mesh",
             "--bench", "--mesh-shape", f"{d2},{n2}",
             "--neurons", str(n), "--words", str(w),
             "--steps", str(t_steps), "--batch", str(b)],
            env=env, capture_output=True, text=True, timeout=600)
    except subprocess.TimeoutExpired as e:
        proc = subprocess.CompletedProcess(e.cmd, -1, stdout="",
                                           stderr="timeout after 600s")
    row = next((ln for ln in proc.stdout.splitlines()
                if ln.startswith("BENCH2D ")), None)
    if proc.returncode == 0 and row is not None:
        kv = dict(p.split("=", 1) for p in row.split()[1:])
        t_1d, t_2d = float(kv["t_1d_us"]), float(kv["t_2d_us"])
        # structural per-device metrics: a (d, n) grid gives each device
        # b/d streams × 1/n of every regfile — weight traffic drops
        # d*n x vs single-device, and d x vs the 1-D neuron mesh that
        # replicates all b streams' windows everywhere
        emit(f"kernels/train-2d-{n}x{n_syn}xT{t_steps}xB{b}", t_2d,
             f"mesh={d2}x{n2};streams_per_device={b // d2};"
             f"bytes_ratio={float(d2):.2f}x;"
             f"time_ratio={t_1d/max(t_2d,1e-9):.2f}x")
        out[("train-2d", n, n_syn, t_steps, b)] = {
            "bytes_ratio": float(d2),
            "time_ratio": t_1d / max(t_2d, 1e-9)}
    else:
        print(f"# train-2d row skipped "
              f"(rc={proc.returncode}): {proc.stderr.strip()[:200]}")

    # analytic streaming extreme: at T=2048 the pre-packed input stream
    # is 256x the intensity bytes (and the encode kernel's VMEM holds no
    # spike slab at all) — analytic-only, nothing is timed
    n_in = 64 * 32
    emit(f"kernels/encode-stream-1024x{n_in}xT2048", None,
         f"input_bytes={n_in};"
         f"bytes_ratio={2048 * 64 * 4 / n_in:.2f}x")
    out[("encode-stream", 1024, n_in, 2048)] = {
        "bytes_ratio": 2048 * 64 * 4 / n_in}

    # --- chunked spike streaming: bounded VMEM at unbounded T -----------
    # (analytic: the streamed slab is the only T-dependent VMEM term)
    for n, w, t_steps, tc in ((1024, 64, 2048, 64),):
        slab_full = t_steps * w * 4
        slab_chunk = tc * w * 4
        emit(f"kernels/window-chunk-{n}x{w * 32}xT{t_steps}c{tc}", None,
             f"vmem_spike_bytes={slab_chunk};"
             f"vmem_ratio={slab_full/slab_chunk:.2f}x")
        out[("chunk", n, t_steps, tc)] = {
            "vmem_ratio": slab_full / slab_chunk}

    # one small interpret-mode row: the real Pallas window-kernel body
    # (Python-interpreted, so absolute time is meaningless; it documents
    # that the kernel itself runs and how it scales vs the oracle),
    # exercised in chunked form (T=8 in two 4-cycle slabs)
    n, w, t_steps = 16, 4, 8
    weights, _, v, st, teach = _operands(n, w, seed=3)
    spk = jnp.asarray(rng.integers(0, 2**32, (t_steps, w), dtype=np.uint32))
    t_i = time_fn(
        lambda *a: ops.fused_snn_window(*a, n_syn=w * 32, backend="interp",
                                        t_chunk=4, **KW),
        weights, spk, v, st, teach, reps=3, warmup=1)
    emit(f"kernels/window-interp-{n}x{w * 32}xT{t_steps}c4", t_i,
         "backend=interp")

    # ...and the encode-fused serving kernel body (interpret mode,
    # chunked, ragged lengths) — documents the in-VMEM draw itself runs
    inten_i = jnp.asarray(rng.integers(0, 256, (2, w * 32),
                                       dtype=np.uint8))
    t_ie = time_fn(
        lambda *a: ops.infer_window_batch_encode(
            *a, n_steps=t_steps, threshold=KW["threshold"],
            leak=KW["leak"], t_total=jnp.asarray([t_steps, t_steps - 3]),
            t_chunk=4, backend="interp"),
        weights, inten_i, jnp.asarray([1, 2], jnp.int32),
        reps=3, warmup=1)
    emit(f"kernels/encode-interp-{n}x{w * 32}xT{t_steps}c4", t_ie,
         "backend=interp")

    # --- serving latency: queue-wait + service percentiles --------------
    # End-to-end request latency through the dynamic-window-batching
    # SNNServingEngine (intensity requests, ragged T's — the same path
    # ``serve --bench`` reports).  One throwaway pass warms every
    # window-length bucket's compile cache, then the latency lists are
    # cleared so the measured pass sees steady-state serving only.  The
    # percentiles land in BENCH_kernels.json as the committed baseline;
    # run.py --gate fails when a percentile grows past
    # GATE_LATENCY_RATIO x its baseline above an absolute floor — the
    # increase direction, unlike the kernel speedup ratios which gate
    # on drops.
    from repro.engine import SNNEnginePlan
    from repro.serving import SNNRequest, SNNServingEngine

    n_req, n, w, t_steps = 32, 64, 8, 16
    rng_l = np.random.default_rng(21)
    s_weights = np.asarray(
        rng_l.integers(0, 2**32, (n, w), dtype=np.uint32))
    s_inten = rng_l.integers(0, 256, (n_req, w * 32), dtype=np.uint8)
    plan_l = SNNEnginePlan(threshold=192, leak=16, n_syn=w * 32,
                           encode="kernel", cycle_backend="window",
                           max_batch=8, t_chunk=8)

    def _latency_reqs(base):
        return [SNNRequest(rid=base + i, intensities=s_inten[i],
                           n_steps=t_steps - 4 * (i % 3))
                for i in range(n_req)]

    s_eng = SNNServingEngine(s_weights, plan_l)
    s_eng.run(_latency_reqs(0))            # warm all T-bucket compiles
    s_eng.queue_wait_hist.reset()
    s_eng.service_hist.reset()
    s_eng.run(_latency_reqs(n_req))        # measured steady-state pass
    s_st = s_eng.stats()
    lat_keys = ("queue_wait_ms_p50", "queue_wait_ms_p99",
                "service_ms_p50", "service_ms_p99")
    emit(f"serve/latency-{n}x{w * 32}xT{t_steps}r{n_req}", None,
         ";".join(f"{k}={s_st[k]:.3f}" for k in lat_keys))
    out[("serve-latency", n, w * 32, t_steps, n_req)] = {
        k: s_st[k] for k in lat_keys}

    # ...and the same pass with the crash-consistency journal enabled
    # (fsync'd WAL + periodic snapshots): documents the durability
    # overhead and gates it with the same increase-direction latency
    # rule, so journaling can never silently blow the serving budget
    import shutil
    import tempfile

    jdir = tempfile.mkdtemp(prefix="bench-journal-")
    try:
        j_eng = SNNServingEngine(s_weights, plan_l, journal_dir=jdir,
                                 snapshot_every=4)
        j_eng.run(_latency_reqs(0))        # warm all T-bucket compiles
        j_eng.queue_wait_hist.reset()
        j_eng.service_hist.reset()
        j_eng.run(_latency_reqs(n_req))    # measured steady-state pass
        j_st = j_eng.stats()
        j_eng.close()
    finally:
        shutil.rmtree(jdir, ignore_errors=True)
    emit(f"serve/latency-journal-{n}x{w * 32}xT{t_steps}r{n_req}", None,
         ";".join(f"{k}={j_st[k]:.3f}" for k in lat_keys)
         + f";journal_syncs={j_st['journal_syncs']}"
         + f";journal_snapshots={j_st['journal_snapshots']}")
    out[("serve-latency-journal", n, w * 32, t_steps, n_req)] = {
        k: j_st[k] for k in lat_keys}
    return out


if __name__ == "__main__":
    run()
