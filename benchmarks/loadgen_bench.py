"""Open-loop load-generation rows: throughput vs latency under the
committed replayable trace.

Three gated ``loadgen/*`` rows, all driven by
:func:`repro.loadgen.runner.run_rows` (open-loop injection by intended
arrival timestamp, so every latency is coordinated-omission-correct):

* ``loadgen/virtual-<trace>`` — deterministic replay of the committed
  compact trace (``benchmarks/traces/smoke_50k.json``) on the virtual
  clock.  Every derived metric (per-status totals, SLO attainment,
  e2e percentiles) is bit-identical across hosts, so these gate
  tightly: the latency percentiles via the increase-direction latency
  gate and ``slo_attainment`` via the absolute-drop gate in run.py.
* ``loadgen/wall-…`` — the same engine shape on the paced wall clock
  at a moderate offered rate: real kernel time on the virtual arrival
  axis.  Latency here is measured, so only the wide latency-ratio
  gate applies.
* ``loadgen/sweep-…`` — bisected maximum sustainable offered rate
  (virtual clock, deterministic) whose run keeps SLO attainment above
  the floor; ``sustainable_rps`` gates on the drop direction like the
  structural speedup ratios.
* ``loadgen/overload-1x…`` / ``loadgen/overload-5x…`` — the committed
  priority-mixed overload trace (``traces/overload_50k.json``) replayed
  at its recorded rate and time-compressed to 5x, with the adaptive
  overload controller attached and a seeded service-time-inflation
  storm on both runs.  Deterministic on the virtual clock, so they
  gate hard: ``goodput_rps`` on relative collapse,
  ``high_slo_attainment`` on absolute drop, and the module itself
  asserts the robustness contract (every request terminal, 5x goodput
  retains >= ``OVERLOAD_RETENTION`` of the 1x anchor, high-priority
  attainment >= ``OVERLOAD_HIGH_FLOOR`` under 5x) so a metastable
  collapse fails CI even before the baseline comparison.
"""

from __future__ import annotations

import dataclasses
import os
import time

from benchmarks.common import emit

TRACE = os.path.join(os.path.dirname(__file__), "traces",
                     "smoke_50k.json")
OVERLOAD_TRACE = os.path.join(os.path.dirname(__file__), "traces",
                              "overload_50k.json")
SLO_MS = 50.0
SWEEP_FLOOR = 0.95
OVERLOAD_SCALE = 5.0           # the storm runs the trace at 5x
OVERLOAD_RETENTION = 0.8       # 5x goodput vs the 1x anchor
OVERLOAD_HIGH_FLOOR = 0.95     # high-priority SLO attainment under 5x
# seeded service-time-inflation storm, armed on BOTH overload runs so
# the 1x anchor is an honest (capacity-sagged) baseline
OVERLOAD_FAULTS = dict(p_slowdown=0.02, slowdown_factor=3.0,
                       slowdown_steps=6, seed=5)


def _engine(workload, clock, *, overload=None, injector=None):
    import numpy as np

    from repro.core.stdp import init_weights
    from repro.engine.plan import SNNEnginePlan
    from repro.serving.snn import SNNServingEngine, SNNServingPolicy

    plan = SNNEnginePlan(threshold=192, leak=16,
                         n_syn=workload.n_inputs, encode="kernel",
                         cycle_backend="window", max_batch=32,
                         t_chunk=8)
    weights = init_weights(64, workload.words, density_seed=0)
    del np  # weights helper owns the arrays
    policy = SNNServingPolicy(max_queue=4096, deadline_ms=200.0)
    return SNNServingEngine(weights, plan, policy=policy, clock=clock,
                            on_launch=injector, overload=overload)


def _report_metrics(rep, *, gate_slo: bool) -> dict:
    # only deterministic (virtual-clock) rows publish the gated
    # ``slo_attainment`` key; the measured wall row reports the same
    # value under a key the absolute-drop gate ignores, so host noise
    # can never fail CI
    return {
        "offered_rps": rep.offered_rps,
        "achieved_rps": rep.achieved_rps,
        ("slo_attainment" if gate_slo else "slo_measured"):
            rep.slo_attainment,
        "e2e_ms_p50": rep.e2e_ms_p50,
        "e2e_ms_p99": rep.e2e_ms_p99,
        "e2e_ms_p999": rep.e2e_ms_p999,
        "queue_wait_ms_p99": rep.queue_wait_ms_p99,
        "served": rep.per_status.get("SERVED", 0),
        "expired": rep.per_status.get("EXPIRED", 0),
        "rejected": rep.per_status.get("REJECTED", 0),
    }


def _emit_report(name: str, rep, wall_us: float | None, *,
                 gate_slo: bool = True) -> dict:
    metrics = _report_metrics(rep, gate_slo=gate_slo)
    emit(name, wall_us,
         ";".join(f"{k}={v}" for k, v in metrics.items()))
    return metrics


def run() -> dict:
    from repro.loadgen import (ArrivalSpec, WorkloadSpec, generate_rows,
                               read_trace)
    from repro.loadgen.runner import (ServiceModel, make_clock,
                                      rate_sweep, run_rows)

    out: dict = {}

    # --- deterministic virtual replay of the committed trace --------
    header, rows = read_trace(TRACE)
    workload = WorkloadSpec.from_dict(header["workload"])
    t0 = time.perf_counter()
    eng = _engine(workload, make_clock("virtual"))
    rep = run_rows(eng, workload, rows, slo_ms=SLO_MS)
    wall_us = (time.perf_counter() - t0) * 1e6
    tag = (f"virtual-{header['n_requests'] // 1000}k"
           f"@{header['arrivals']['rate_rps']:.0f}")
    out[tag] = _emit_report(f"loadgen/{tag}", rep, wall_us)

    # --- measured wall-clock run (same shape, moderate rate) --------
    arrivals = ArrivalSpec(process="poisson", rate_rps=2000.0,
                           n_requests=4000, seed=42)
    wall_rows = generate_rows(arrivals, workload)
    # warm every T-bucket's compile on a throwaway engine (the XLA
    # compile cache is global, keyed on shapes) so the measured run
    # sees steady-state kernels from its first arrival
    warm_eng = _engine(workload, make_clock("wall"))
    warm_eng.run([_warm(workload, r) for r in wall_rows[:64]])
    eng = _engine(workload, make_clock("wall"))
    t0 = time.perf_counter()
    rep = run_rows(eng, workload, wall_rows, slo_ms=SLO_MS)
    wall_us = (time.perf_counter() - t0) * 1e6
    out["wall-4k@2000"] = _emit_report("loadgen/wall-4k@2000", rep,
                                       wall_us, gate_slo=False)

    # --- max sustainable rate (virtual, deterministic bisection) ----
    sweep_arr = ArrivalSpec(process="poisson", rate_rps=1000.0,
                            n_requests=5000, seed=42)

    def run_at(rate):
        asp = dataclasses.replace(sweep_arr, rate_rps=rate)
        eng = _engine(workload, make_clock(
            "virtual", ServiceModel()))
        return run_rows(eng, workload, generate_rows(asp, workload),
                        slo_ms=SLO_MS)

    rate, srep = rate_sweep(run_at, 1000.0, 64000.0,
                            slo_floor=SWEEP_FLOOR, iters=6)
    emit("loadgen/sweep-5k",  None,
         f"sustainable_rps={round(rate, 1)}"
         f";slo_floor={SWEEP_FLOOR}"
         f";slo_attainment={srep.slo_attainment}"
         f";e2e_ms_p99={srep.e2e_ms_p99}")
    out["sweep-5k"] = {"sustainable_rps": rate,
                       "slo_attainment": srep.slo_attainment}

    # --- overload storm: controller at 1x and 5x (virtual) ----------
    out.update(_overload_rows())
    return out


def _overload_run(workload, rows, base_rps: float):
    from repro.loadgen.runner import make_clock, run_rows
    from repro.serving.faults import FaultInjector, FaultSpec
    from repro.serving.overload import storm_policy

    eng = _engine(workload, make_clock("virtual"),
                  overload=storm_policy(base_rps),
                  injector=FaultInjector(FaultSpec(**OVERLOAD_FAULTS)))
    rep = run_rows(eng, workload, rows, slo_ms=SLO_MS)
    return rep, eng


def _overload_metrics(rep, eng) -> dict:
    st = eng.stats()
    return {
        "offered_rps": rep.offered_rps,
        "goodput_rps": rep.goodput_rps,
        "slo_attainment": rep.slo_attainment,
        "high_slo_attainment":
            rep.slo_attainment_by_priority.get("1", 0.0),
        "non_terminal": rep.non_terminal,
        "e2e_ms_p99": rep.e2e_ms_p99,
        "served": rep.per_status.get("SERVED", 0),
        "shed_admission": st["shed_admission"],
        "shed_low_priority": st["shed_low_priority"],
        "shed_codel": st["shed_codel"],
        "admit_rate_rps": st["admit_rate_rps"],
    }


def _overload_rows() -> dict:
    from repro.loadgen import WorkloadSpec, read_trace, scale_rows

    header, rows = read_trace(OVERLOAD_TRACE)
    workload = WorkloadSpec.from_dict(header["workload"])
    base_rps = float(header["arrivals"]["rate_rps"])
    kreq = header["n_requests"] // 1000

    out: dict = {}
    reps = {}
    for factor, tag in ((1.0, "1x"), (OVERLOAD_SCALE,
                                      f"{OVERLOAD_SCALE:.0f}x")):
        r = rows if factor == 1.0 else scale_rows(rows, factor)
        t0 = time.perf_counter()
        rep, eng = _overload_run(workload, r, base_rps)
        wall_us = (time.perf_counter() - t0) * 1e6
        name = f"overload-{tag}-{kreq}k@{base_rps * factor:.0f}"
        metrics = _overload_metrics(rep, eng)
        emit(f"loadgen/{name}", wall_us,
             ";".join(f"{k}={v}" for k, v in metrics.items()))
        out[name] = metrics
        reps[tag] = rep

    # the robustness contract, asserted in-module so a metastable
    # collapse fails CI even on the first run (no baseline needed)
    rep1, rep5 = reps["1x"], reps[f"{OVERLOAD_SCALE:.0f}x"]
    assert rep1.non_terminal == 0 and rep5.non_terminal == 0, \
        f"overload runs leaked non-terminal requests: " \
        f"1x={rep1.non_terminal} 5x={rep5.non_terminal}"
    retention = rep5.goodput_rps / rep1.goodput_rps \
        if rep1.goodput_rps else 0.0
    assert retention >= OVERLOAD_RETENTION, \
        f"5x goodput {rep5.goodput_rps} retains only " \
        f"{retention:.3f} of 1x {rep1.goodput_rps} " \
        f"(floor {OVERLOAD_RETENTION})"
    high = rep5.slo_attainment_by_priority.get("1", 0.0)
    assert high >= OVERLOAD_HIGH_FLOOR, \
        f"high-priority SLO attainment {high} under 5x overload " \
        f"(floor {OVERLOAD_HIGH_FLOOR})"
    return out


def _warm(workload, row):
    req = workload.materialize(dict(row))
    req.rid += 1_000_000       # keep warmup rids off the measured ones
    return req


if __name__ == "__main__":
    run()
