"""Render the per-commit benchmark trajectory to SVG.

``run.py --json --history`` archives one immutable
``bench_history/<sha>.json`` per commit; this module turns that
directory into a small-multiples SVG — one sparkline panel per
benchmark row, ``us_per_call`` panels in one section, the
structural ``bytes_ratio`` panels in another, and (when ``loadgen/*``
rows are present) throughput-vs-latency sections for the open-loop
load harness: sustainable/achieved requests-per-second, SLO
attainment, and coordinated-omission-correct end-to-end p99 — so the
perf trajectory across PRs is readable at a glance instead of by
diffing JSON.  CI
writes the SVG next to the history artifacts and uploads the
directory.

Commits are ordered by ``git rev-list --first-parent`` where the
checkout is available (history files are named by short sha), falling
back to file mtime.  Analytic-only rows (``analytic: true``, no timing
field) appear only in the ratio section — a 0.0 never plots.

Stdlib only (CI runs this with no plotting deps)::

    python benchmarks/plot_history.py [--history bench_history]
                                      [--out bench_history/history.svg]
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from html import escape

# Single-series panels: one accent per metric section (categorical
# slots of the validated default palette), neutral ink for text.
_C_TIME = "#2a78d6"
_C_RATIO = "#eb6834"
_C_RPS = "#13866f"
_C_SLO = "#7856c1"
_INK = "#0b0b0b"
_INK_MUTED = "#52514e"
_GRID = "#e4e3e0"
_SURFACE = "#fcfcfb"

_PANEL_W, _PANEL_H = 240, 96
_PLOT_H = 44
_COLS = 3
_PAD = 16


def load_history(history_dir: str) -> list[tuple[str, dict]]:
    """[(sha, rows)] ordered oldest -> newest."""
    shas = [f[:-5] for f in os.listdir(history_dir)
            if f.endswith(".json")]
    if not shas:
        return []
    order = {}
    try:
        log = subprocess.run(
            ["git", "rev-list", "--first-parent", "--reverse", "HEAD"],
            capture_output=True, text=True, timeout=30).stdout.split()
        for i, full in enumerate(log):
            for s in shas:
                if full.startswith(s):
                    order[s] = i
    except (OSError, subprocess.SubprocessError):
        pass

    def key(s: str):
        if s in order:
            return (0, order[s])
        return (1, os.path.getmtime(os.path.join(history_dir,
                                                 f"{s}.json")))

    out = []
    for s in sorted(shas, key=key):
        try:
            with open(os.path.join(history_dir, f"{s}.json")) as fh:
                out.append((s, json.load(fh)))
        except (OSError, json.JSONDecodeError):
            continue
    return out


def _series(history, metric: str) -> dict[str, list]:
    """row name -> per-commit values (None where absent)."""
    names = sorted({n for _, rows in history for n in rows
                    if isinstance(rows[n].get(metric), (int, float))})
    return {n: [rows.get(n, {}).get(metric) for _, rows in history]
            for n in names}


def _fmt(v: float) -> str:
    if v >= 1e6:
        return f"{v / 1e6:.1f}M"
    if v >= 1e4:
        return f"{v / 1e3:.0f}k"
    if v >= 100:
        return f"{v:.0f}"
    return f"{v:.2f}".rstrip("0").rstrip(".")


def _panel(x0: float, y0: float, name: str, vals: list, color: str,
           unit: str) -> list[str]:
    """One sparkline panel at (x0, y0); gaps where a commit lacks the
    row."""
    pts = [(i, v) for i, v in enumerate(vals) if v is not None]
    lo = min(v for _, v in pts)
    hi = max(v for _, v in pts)
    span = (hi - lo) or max(abs(hi), 1e-9)
    px0, px1 = x0 + 4, x0 + _PANEL_W - 44
    py0, py1 = y0 + 22, y0 + 22 + _PLOT_H
    nx = max(len(vals) - 1, 1)

    def xy(i, v):
        return (px0 + (px1 - px0) * i / nx,
                py1 - (py1 - py0) * (v - lo) / span)

    title = name[len("kernels/"):] if name.startswith("kernels/") else name
    out = [f'<text x="{x0 + 4}" y="{y0 + 13}" class="t">'
           f'{escape(title)}</text>',
           f'<line x1="{px0}" y1="{py1}" x2="{px1}" y2="{py1}" '
           f'class="g"/>']
    # polyline segments between consecutive commits that both have data
    seg: list[str] = []
    prev_i = None
    for i, v in pts:
        if prev_i is not None and i == prev_i + 1:
            seg.append("{:.1f},{:.1f}".format(*xy(i, v)))
        else:
            if len(seg) > 1:
                out.append(f'<polyline points="{" ".join(seg)}" '
                           f'class="s" stroke="{color}"/>')
            seg = ["{:.1f},{:.1f}".format(*xy(i, v))]
        prev_i = i
    if len(seg) > 1:
        out.append(f'<polyline points="{" ".join(seg)}" class="s" '
                   f'stroke="{color}"/>')
    lx, ly = xy(*pts[-1])
    out.append(f'<circle cx="{lx:.1f}" cy="{ly:.1f}" r="2.5" '
               f'fill="{color}"/>')
    out.append(f'<text x="{px1 + 6}" y="{ly + 4:.1f}" class="v">'
               f'{_fmt(pts[-1][1])}{unit}</text>')
    if hi > lo:
        out.append(f'<text x="{px0}" y="{py1 + 12}" class="m">'
                   f'{_fmt(lo)}–{_fmt(hi)}{unit}</text>')
    return out


def _section(parts: list[str], series: dict[str, list], y: float,
             heading: str, color: str, unit: str) -> float:
    if not series:
        return y
    parts.append(f'<text x="{_PAD}" y="{y + 14}" class="h">'
                 f'{escape(heading)}</text>')
    y += 24
    for k, (name, vals) in enumerate(series.items()):
        x0 = _PAD + (k % _COLS) * (_PANEL_W + _PAD)
        y0 = y + (k // _COLS) * (_PANEL_H + 4)
        parts.extend(_panel(x0, y0, name, vals, color, unit))
    rows = (len(series) + _COLS - 1) // _COLS
    return y + rows * (_PANEL_H + 4) + 12


def render_svg(history: list[tuple[str, dict]]) -> str:
    times = _series(history, "us_per_call")
    ratios = _series(history, "bytes_ratio")
    # loadgen throughput-vs-latency: achieved + bisected-sustainable
    # rates in one section, SLO attainment and open-loop e2e p99 in
    # their own (only loadgen rows carry these metrics)
    rps = _series(history, "achieved_rps")
    rps.update({f"{n} (max sustainable)": vals for n, vals in
                _series(history, "sustainable_rps").items()})
    slo = _series(history, "slo_attainment")
    e2e = {n: vals for n, vals in
           _series(history, "e2e_ms_p99").items()
           if n.startswith("loadgen/")}
    width = _PAD + _COLS * (_PANEL_W + _PAD)
    parts: list[str] = []
    y = float(_PAD)
    parts.append(f'<text x="{_PAD}" y="{y + 14}" class="hh">Benchmark '
                 f'trajectory — {len(history)} commits '
                 f'({escape(history[0][0])} → {escape(history[-1][0])})'
                 f'</text>')
    y += 28
    y = _section(parts, times, y, "us_per_call (wall clock per call)",
                 _C_TIME, "")
    y = _section(parts, ratios, y,
                 "bytes_ratio (structural, sequential ÷ fused path)",
                 _C_RATIO, "×")
    y = _section(parts, rps, y,
                 "load harness throughput (requests/s, open-loop)",
                 _C_RPS, "")
    y = _section(parts, slo, y,
                 "SLO attainment (fraction of offered requests)",
                 _C_SLO, "")
    y = _section(parts, e2e, y,
                 "open-loop e2e p99 (ms from intended arrival)",
                 _C_SLO, "ms")
    height = int(y) + _PAD
    head = (
        f'<svg xmlns="http://www.w3.org/2000/svg" width="{width}" '
        f'height="{height}" viewBox="0 0 {width} {height}" '
        f'font-family="system-ui, sans-serif">'
        f'<style>'
        f'.hh{{font-size:13px;font-weight:600;fill:{_INK}}}'
        f'.h{{font-size:12px;font-weight:600;fill:{_INK}}}'
        f'.t{{font-size:10px;fill:{_INK_MUTED}}}'
        f'.v{{font-size:10px;fill:{_INK}}}'
        f'.m{{font-size:9px;fill:{_INK_MUTED}}}'
        f'.s{{fill:none;stroke-width:2;stroke-linejoin:round}}'
        f'.g{{stroke:{_GRID};stroke-width:1}}'
        f'</style>'
        f'<rect width="{width}" height="{height}" fill="{_SURFACE}"/>')
    return head + "".join(parts) + "</svg>"


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--history", default="bench_history",
                    help="directory of per-commit <sha>.json artifacts")
    ap.add_argument("--out", default=None,
                    help="output SVG path (default: "
                         "<history>/history.svg)")
    args = ap.parse_args(argv)
    out = args.out or os.path.join(args.history, "history.svg")
    if not os.path.isdir(args.history):
        print(f"# no history directory at {args.history}; nothing to "
              f"plot")
        return 0
    history = load_history(args.history)
    if not history:
        print(f"# no history artifacts in {args.history}; nothing to "
              f"plot")
        return 0
    svg = render_svg(history)
    with open(out, "w") as fh:
        fh.write(svg)
    n_rows = len({n for _, rows in history for n in rows})
    print(f"# wrote {out}: {len(history)} commits x {n_rows} rows")
    return 0


if __name__ == "__main__":
    sys.exit(main())
