"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks.common.emit).

  table1_accuracy   Table 1  MNIST-recognition comparison row
  fig5_neurons      Fig. 5   CA vs {10,20,40} output neurons
  wexp_sweep        §3.3     w_exp {128,256,512} dead-neuron sweep
  fig4_energy       Fig. 4   modeled power, fused vs decoupled
  table2_resources  Table 2  state-footprint analogue of LUT/FF/BRAM
  kernels_bench     §2.2     fused SNNU vs unfused chain, window vs steps

Usage::

  python benchmarks/run.py [module] [--json[=PATH]]

``--json`` additionally writes every emitted row as machine-readable
JSON (name -> us_per_call + parsed derived metrics such as bytes_ratio
and time_ratio) so the perf trajectory is tracked across PRs.  PATH
defaults to ``BENCH_kernels.json``; the ``=`` form keeps the module
filter unambiguous (``run.py --json kernels_bench`` filters, it does
not name the output file).
"""

from __future__ import annotations

import json
import sys
import time

# NOTE: the sharded-window benchmark row needs a multi-device mesh;
# kernels_bench runs it in a subprocess with
# --xla_force_host_platform_device_count set there, NOT here — forcing
# the flag in this process would split the CPU thread pool eight ways
# and skew every other wall-clock row.


def main(argv: list[str] | None = None) -> None:
    from benchmarks import (common, fig4_energy, fig5_neurons,
                            kernels_bench, table1_accuracy,
                            table2_resources, wexp_sweep)

    args = list(sys.argv[1:] if argv is None else argv)
    json_path = None
    for a in list(args):
        if a == "--json":
            json_path = "BENCH_kernels.json"
            args.remove(a)
        elif a.startswith("--json="):
            json_path = a.split("=", 1)[1] or "BENCH_kernels.json"
            args.remove(a)

    mods = [("table1_accuracy", table1_accuracy),
            ("fig5_neurons", fig5_neurons),
            ("wexp_sweep", wexp_sweep),
            ("fig4_energy", fig4_energy),
            ("table2_resources", table2_resources),
            ("kernels_bench", kernels_bench)]
    only = args[0] if args else None
    print("name,us_per_call,derived")
    for name, mod in mods:
        if only and only != name:
            continue
        t0 = time.time()
        mod.run()
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)

    if json_path is not None:
        rows = {rec["name"]: {k: v for k, v in rec.items() if k != "name"}
                for rec in common.RECORDS}
        with open(json_path, "w") as fh:
            json.dump(rows, fh, indent=2, sort_keys=True)
        print(f"# wrote {len(rows)} rows to {json_path}", flush=True)


if __name__ == "__main__":
    main()
