"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks.common.emit).

  table1_accuracy   Table 1  MNIST-recognition comparison row
  fig5_neurons      Fig. 5   CA vs {10,20,40} output neurons
  wexp_sweep        §3.3     w_exp {128,256,512} dead-neuron sweep
  fig4_energy       Fig. 4   modeled power, fused vs decoupled
  table2_resources  Table 2  state-footprint analogue of LUT/FF/BRAM
  kernels_bench     §2.2     fused SNNU vs unfused SPU/NU/SU chain
"""

from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (fig4_energy, fig5_neurons, kernels_bench,
                            table1_accuracy, table2_resources, wexp_sweep)

    mods = [("table1_accuracy", table1_accuracy),
            ("fig5_neurons", fig5_neurons),
            ("wexp_sweep", wexp_sweep),
            ("fig4_energy", fig4_energy),
            ("table2_resources", table2_resources),
            ("kernels_bench", kernels_bench)]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for name, mod in mods:
        if only and only != name:
            continue
        t0 = time.time()
        mod.run()
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
