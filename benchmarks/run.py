"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks.common.emit).

  table1_accuracy   Table 1  MNIST-recognition comparison row
  fig5_neurons      Fig. 5   CA vs {10,20,40} output neurons
  wexp_sweep        §3.3     w_exp {128,256,512} dead-neuron sweep
  fig4_energy       Fig. 4   modeled power, fused vs decoupled
  table2_resources  Table 2  state-footprint analogue of LUT/FF/BRAM
  kernels_bench     §2.2     fused SNNU vs unfused chain, window vs steps
  loadgen_bench     serving  open-loop throughput vs latency, rate sweep

Usage::

  python benchmarks/run.py [module ...] [--json[=PATH]] [--gate]

Any number of module names filters the run (none = all modules).

``--json`` additionally writes every emitted row as machine-readable
JSON (name -> us_per_call + parsed derived metrics such as bytes_ratio
and time_ratio) so the perf trajectory is tracked across PRs.  PATH
defaults to ``BENCH_kernels.json``; the ``=`` form keeps the module
filter unambiguous (``run.py --json kernels_bench`` filters, it does
not name the output file).

``--history[=DIR]`` additionally archives the JSON rows as
``DIR/<git-sha>.json`` (DIR defaults to ``bench_history``), one
immutable artifact per commit — CI uploads the directory, so the perf
trajectory across PRs is reconstructable from artifacts instead of a
single moving baseline.

``--gate`` turns the run into a CI perf gate: before overwriting PATH,
the committed rows there become the baseline, and any shared row whose
``time_ratio`` or ``bytes_ratio`` drops by more than ``GATE_THRESHOLD``
(25%) fails the run with exit code 1.  The ratios are relative
(sequential baseline vs fused/batched/sharded path, measured in the
same process), so they gate the *structural* speedups rather than raw
host wall-clock; because single-run wall clock still swings several-x
on CI hosts, ``time_ratio`` only fails when a clearly-structural
baseline row (>= ``GATE_TIME_BASE_MIN``) collapses below
``GATE_TIME_FLOOR`` — the speedup is gone, not merely noisy.  Serving
latency percentiles (the ``serve/latency-*`` rows' ``*_ms_p50`` /
``*_ms_p99`` metrics) gate the increase direction instead: they fail
only past ``GATE_LATENCY_RATIO`` x baseline above an absolute
``GATE_LATENCY_FLOOR_MS``.  The ``loadgen/*`` rows add two more rules:
``slo_attainment`` and ``high_slo_attainment`` (fractions in [0, 1])
fail on an *absolute* drop of more than ``GATE_SLO_DROP``, and
``sustainable_rps`` / ``goodput_rps`` (the bisected max sustainable
offered rate and the overload rows' SLO-meeting serve rate, both
deterministic on the virtual clock) fail like the structural ratios
when they collapse by more than ``GATE_THRESHOLD``.  ``--gate``
without ``--json``, or without a loadable committed baseline, is a
configuration error (exit 2), never a silent pass.  Without ``--gate``,
regressions are printed as warnings only.
"""

from __future__ import annotations

import json
import sys
import time

GATE_THRESHOLD = 0.25          # fail on >25% drop of a gated ratio
GATE_TIME_BASE_MIN = 4.0       # only clearly-structural rows time-gate
GATE_TIME_FLOOR = 1.25         # ...and only when the speedup is gone
_GATED_METRICS = ("time_ratio", "bytes_ratio")

# serving latency gates in the INCREASE direction: a percentile fails
# only when it grows past GATE_LATENCY_RATIO x its committed baseline
# AND lands above GATE_LATENCY_FLOOR_MS.  Steady-state percentiles on
# this path measure ~1-2 ms warm; CI wall clock swings several-x, so
# the 8x ratio + 10 ms absolute floor pass any noisy-but-healthy run
# while a structural regression (per-batch recompile, a blocking refresh
# in the serving step) lands orders of magnitude past both.
GATE_LATENCY_RATIO = 8.0
GATE_LATENCY_FLOOR_MS = 10.0
_GATED_LATENCY_SUFFIXES = ("_ms_p50", "_ms_p99", "_ms_p999")

# loadgen rows: SLO attainment is a fraction of offered requests, so it
# gates on an absolute drop (0.98 -> 0.90 is a real regression even
# though the relative change is small); sustainable_rps comes from a
# deterministic virtual-clock bisection, so the structural-drop
# threshold applies as-is.
GATE_SLO_DROP = 0.05


def archive_history(rows: dict, history_dir: str) -> str:
    """Write rows to ``history_dir/<git-sha>.json``; returns the path.

    The sha comes from ``git rev-parse --short HEAD`` (falls back to
    ``nogit`` outside a checkout) — one artifact per commit, never
    overwritten by later runs of the same tree state.
    """
    import os
    import subprocess

    try:
        sha = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=30).stdout.strip()
    except (OSError, subprocess.SubprocessError):
        sha = ""
    os.makedirs(history_dir, exist_ok=True)
    path = os.path.join(history_dir, f"{sha or 'nogit'}.json")
    with open(path, "w") as fh:
        json.dump(rows, fh, indent=2, sort_keys=True)
    return path


def load_baseline(path: str) -> dict | None:
    try:
        with open(path) as fh:
            return json.load(fh)
    except (FileNotFoundError, json.JSONDecodeError):
        return None


def check_regressions(baseline: dict, rows: dict,
                      threshold: float = GATE_THRESHOLD) -> list[str]:
    """Rows whose gated ratios regressed past the threshold.

    Only rows AND metrics present on both sides are compared — new
    rows, removed rows and rows without ratios (e.g. interp timing)
    never gate.  ``bytes_ratio`` is analytic and always gates on the
    relative threshold.  ``time_ratio`` is single-run CPU wall clock
    and swings several-x between runs of identical code (the committed
    baseline's own history shows 1.1 <-> 1.55 and 2.3 <-> 12.5 swings),
    so it fails only when BOTH hold: the baseline row was a clearly
    structural speedup (>= GATE_TIME_BASE_MIN) and the new ratio fell
    below GATE_TIME_FLOOR — i.e. the batched/fused path degraded to
    ~sequential speed, not merely a noisy-but-still-fast run.

    Serving-latency percentiles (``*_ms_p50``/``*_ms_p99``/
    ``*_ms_p999`` metrics on the ``serve/latency-*`` and ``loadgen/*``
    rows) gate the opposite direction: bigger is worse.  They fail
    only when the new value exceeds BOTH ``GATE_LATENCY_RATIO`` x the
    baseline and the absolute ``GATE_LATENCY_FLOOR_MS`` — so
    host-speed noise on a ~1-2 ms percentile never gates, but a
    serving step that started recompiling or blocking does.

    ``slo_attainment`` and ``high_slo_attainment`` (the high-priority
    class on the overload rows) fail on an absolute drop past
    ``GATE_SLO_DROP``; ``sustainable_rps`` and ``goodput_rps`` (the
    SLO-meeting serve rate under the overload storm) fail on a
    relative collapse past ``threshold``; all are deterministic on the
    virtual clock, so none needs a noise allowance beyond the
    thresholds themselves.
    """
    msgs = []
    for name in sorted(set(baseline) & set(rows)):
        old, new = baseline[name], rows[name]
        ov, nv = old.get("slo_attainment"), new.get("slo_attainment")
        if (isinstance(ov, (int, float)) and isinstance(nv, (int, float))
                and nv < ov - GATE_SLO_DROP):
            msgs.append(
                f"{name}: slo_attainment {ov:.4f} -> {nv:.4f} "
                f"(gate is an absolute -{GATE_SLO_DROP})")
        ov, nv = old.get("high_slo_attainment"), \
            new.get("high_slo_attainment")
        if (isinstance(ov, (int, float)) and isinstance(nv, (int, float))
                and nv < ov - GATE_SLO_DROP):
            msgs.append(
                f"{name}: high_slo_attainment {ov:.4f} -> {nv:.4f} "
                f"(gate is an absolute -{GATE_SLO_DROP})")
        for rate_key in ("sustainable_rps", "goodput_rps"):
            ov, nv = old.get(rate_key), new.get(rate_key)
            if (isinstance(ov, (int, float))
                    and isinstance(nv, (int, float))
                    and ov > 0 and nv < ov * (1.0 - threshold)):
                msgs.append(
                    f"{name}: {rate_key} {ov:.0f} -> {nv:.0f} "
                    f"({(nv / ov - 1.0) * 100:+.0f}%, gate is "
                    f"-{threshold * 100:.0f}%)")
        for metric in _GATED_METRICS:
            ov, nv = old.get(metric), new.get(metric)
            if not (isinstance(ov, (int, float))
                    and isinstance(nv, (int, float))):
                continue
            if metric == "time_ratio" and (
                    ov < GATE_TIME_BASE_MIN or nv >= GATE_TIME_FLOOR):
                continue
            if ov > 0 and nv < ov * (1.0 - threshold):
                msgs.append(
                    f"{name}: {metric} {ov:.2f} -> {nv:.2f} "
                    f"({(nv / ov - 1.0) * 100:+.0f}%, gate is "
                    f"-{threshold * 100:.0f}%)")
        for metric in sorted(set(old) & set(new)):
            if not metric.endswith(_GATED_LATENCY_SUFFIXES):
                continue
            ov, nv = old[metric], new[metric]
            if not (isinstance(ov, (int, float))
                    and isinstance(nv, (int, float))):
                continue
            if (nv >= GATE_LATENCY_FLOOR_MS
                    and nv > max(ov, 1e-6) * GATE_LATENCY_RATIO):
                msgs.append(
                    f"{name}: {metric} {ov:.3f}ms -> {nv:.3f}ms "
                    f"(latency gate is {GATE_LATENCY_RATIO:.0f}x above "
                    f"{GATE_LATENCY_FLOOR_MS:.0f}ms)")
    return msgs

# NOTE: the sharded-window benchmark row needs a multi-device mesh;
# kernels_bench runs it in a subprocess with
# --xla_force_host_platform_device_count set there, NOT here — forcing
# the flag in this process would split the CPU thread pool eight ways
# and skew every other wall-clock row.


def main(argv: list[str] | None = None) -> None:
    from benchmarks import (common, fig4_energy, fig5_neurons,
                            kernels_bench, loadgen_bench,
                            table1_accuracy, table2_resources,
                            wexp_sweep)

    args = list(sys.argv[1:] if argv is None else argv)
    json_path = None
    gate = False
    history_dir = None
    for a in list(args):
        if a == "--json":
            json_path = "BENCH_kernels.json"
            args.remove(a)
        elif a.startswith("--json="):
            json_path = a.split("=", 1)[1] or "BENCH_kernels.json"
            args.remove(a)
        elif a == "--gate":
            gate = True
            args.remove(a)
        elif a == "--history":
            history_dir = "bench_history"
            args.remove(a)
        elif a.startswith("--history="):
            history_dir = a.split("=", 1)[1] or "bench_history"
            args.remove(a)

    if history_dir is not None and json_path is None:
        print("# --history requires --json (nothing to archive)",
              flush=True)
        sys.exit(2)
    if gate and json_path is None:
        print("# --gate requires --json (nothing to compare)",
              flush=True)
        sys.exit(2)

    mods = [("table1_accuracy", table1_accuracy),
            ("fig5_neurons", fig5_neurons),
            ("wexp_sweep", wexp_sweep),
            ("fig4_energy", fig4_energy),
            ("table2_resources", table2_resources),
            ("kernels_bench", kernels_bench),
            ("loadgen_bench", loadgen_bench)]
    only = set(args)
    unknown = only - {name for name, _ in mods}
    if unknown:
        print(f"# unknown module(s): {', '.join(sorted(unknown))}",
              flush=True)
        sys.exit(2)
    print("name,us_per_call,derived")
    for name, mod in mods:
        if only and name not in only:
            continue
        t0 = time.time()
        mod.run()
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)

    if json_path is not None:
        rows = {rec["name"]: {k: v for k, v in rec.items() if k != "name"}
                for rec in common.RECORDS}
        baseline = load_baseline(json_path)
        with open(json_path, "w") as fh:
            json.dump(rows, fh, indent=2, sort_keys=True)
        print(f"# wrote {len(rows)} rows to {json_path}", flush=True)
        if history_dir is not None:
            hist = archive_history(rows, history_dir)
            print(f"# archived history artifact {hist}", flush=True)
        if baseline is None:
            if gate:
                print(f"# perf gate FAILED: no committed baseline at "
                      f"{json_path} (missing or unparseable)",
                      flush=True)
                sys.exit(2)
        else:
            msgs = check_regressions(baseline, rows)
            for m in msgs:
                print(f"# PERF REGRESSION {m}", flush=True)
            if msgs and gate:
                print(f"# perf gate FAILED ({len(msgs)} regressed rows)",
                      flush=True)
                sys.exit(1)
            if not msgs:
                print(f"# perf gate OK ({len(set(baseline) & set(rows))} "
                      f"rows within {GATE_THRESHOLD * 100:.0f}%)",
                      flush=True)


if __name__ == "__main__":
    main()
