"""Paper Table 1: MNIST recognition comparison.

Reproduces the "this work" row (784-40, 1-bit synapses, binary
stochastic STDP, rate-Poisson encoding) on the offline procedural digit
set, alongside the paper's reported numbers for context.  The oracle
ceiling row quantifies the dataset substitution (see EXPERIMENTS.md).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import digits_dataset, emit
from repro.configs.wenquxing_snn import WENQUXING_22A
from repro.core.encoder import poisson_encode_batch
from repro.core.trainer import accuracy, train

PAPER_ROWS = [
    ("Neftci2014-784-500-40-8bit", 0.916),
    ("ODIN-784-10-3bit", 0.850),
    ("Yousefzadeh2018-784-6400-1bit", 0.957),
    ("Wenquxing22A-paper-784-40-1bit", 0.9191),
]


def oracle_ceiling(tr, tr_lab, te, te_lab, k=128) -> float:
    protos = np.zeros((10, 784), bool)
    for c in range(10):
        mean = tr[tr_lab == c].mean(0)
        protos[c, np.argsort(mean)[-k:]] = True
    scores = te @ protos.T.astype(np.float32)
    return float((scores.argmax(1) == te_lab).mean())


def run() -> dict:
    tr, tr_lab, te, te_lab = digits_dataset()
    cfg = WENQUXING_22A  # 784-40, 1-bit, the paper's best setting
    t0 = time.time()
    model = train(cfg, tr, tr_lab)
    train_s = time.time() - t0
    st = poisson_encode_batch(jax.random.key(99), jnp.asarray(te),
                              cfg.n_steps)
    acc = accuracy(model, st, jnp.asarray(te_lab))
    ceiling = oracle_ceiling(tr, tr_lab, te, te_lab)

    for name, ca in PAPER_ROWS:
        emit(f"table1/{name}", 0.0, f"CA={ca:.4f} (reported,MNIST)")
    emit("table1/this-work-784-40-1bit", train_s * 1e6,
         f"CA={acc:.4f} (procedural digits)")
    emit("table1/oracle-binary-prototype-K128", 0.0,
         f"CA={ceiling:.4f} (dataset ceiling)")
    return {"accuracy": acc, "ceiling": ceiling}


if __name__ == "__main__":
    run()
