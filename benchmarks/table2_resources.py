"""Paper Table 2: hardware utilization (LUT/FF/BRAM) analogue.

FPGA synthesis is out of reach here; the architectural quantity behind
those numbers is the state the SNN datapath must hold and the logic
ops per cycle.  We report the storage footprint of the Wenquxing SNNU
configuration vs an ODIN-style 256-neuron crossbar for the same task,
plus the paper's reported utilization for context.
"""

from __future__ import annotations

from benchmarks.common import emit
from repro.configs.wenquxing_snn import WENQUXING_22A
from repro.core.energy import footprint

PAPER = {
    "ODIN": {"LUT": 63411, "FF": 75362, "BRAM": 82.5},
    "Wenquxing22A": {"LUT": 56487, "FF": 69702, "BRAM": 73.0},
}


def run() -> dict:
    cfg = WENQUXING_22A
    ours = footprint(cfg.n_neurons, cfg.n_inputs)
    # ODIN: fixed 256-neuron, 64k-synapse crossbar with 3-bit weights +
    # per-neuron state RAM (its architecture, independent of the task)
    odin = {
        "synapse_bytes": 256 * 256 * 3 // 8 * 8,  # 64k synapses x 3 bit
        "membrane_bytes": 256 * 13,               # ODIN neuron state
        "lfsr_bytes": 4,
        "spike_reg_bytes": 256 // 8,
    }
    for name, fp in (("this-work", ours), ("odin-crossbar", odin)):
        total = sum(fp.values())
        emit(f"table2/{name}", 0.0,
             f"state_bytes={total};" +
             ";".join(f"{k}={v}" for k, v in fp.items()))
    for name, row in PAPER.items():
        emit(f"table2/paper-{name}", 0.0,
             ";".join(f"{k}={v}" for k, v in row.items()))
    return {"ours_bytes": sum(ours.values()),
            "odin_bytes": sum(odin.values())}


if __name__ == "__main__":
    run()
