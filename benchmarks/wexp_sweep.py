"""Paper §3.3: the w_exp meta-parameter sweep {128, 256, 512}.

Validates the dead-neuron claim: w_exp controls the LTD probability and
thereby the number of effective synapses; the wrong setting leaves
neurons dead (never winning for their class) and costs accuracy.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import digits_dataset, emit
from repro.configs.wenquxing_snn import WENQUXING_22A
from repro.core import network
from repro.core.bitpack import unpack
from repro.core.encoder import poisson_encode_batch
from repro.core.trainer import train


def run() -> dict:
    tr, tr_lab, te, te_lab = digits_dataset()
    st = poisson_encode_batch(jax.random.key(99), jnp.asarray(te),
                              WENQUXING_22A.n_steps)
    out = {}
    for wexp in (128, 256, 512):
        cfg = dataclasses.replace(WENQUXING_22A, w_exp=wexp, n_neurons=40)
        t0 = time.time()
        model = train(cfg, tr, tr_lab)
        counts = np.asarray(network.infer_batch(model.weights, st,
                                                cfg.lif()))
        pred = np.asarray(model.neuron_class)[counts.argmax(1)]
        acc = float((pred == te_lab).mean())
        # dead neuron = never the argmax winner on the test set
        winners = set(counts.argmax(1).tolist())
        dead = cfg.n_neurons - len(winners)
        on_bits = unpack(model.weights, 784).sum(axis=1)
        emit(f"wexp/{wexp}", (time.time() - t0) * 1e6,
             f"CA={acc:.4f};dead={dead};mean_on={float(np.mean(np.asarray(on_bits))):.0f}")
        out[wexp] = {"acc": acc, "dead": dead}
    return out


if __name__ == "__main__":
    run()
