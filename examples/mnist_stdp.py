"""End-to-end driver for the paper's own experiment (Table 1 / Fig. 5).

Full pipeline: procedural digits (offline MNIST substitute) -> deskew +
soft threshold -> Poisson rate encoding -> supervised binary-stochastic-
STDP training with active learning -> test-set classification.

Run:  PYTHONPATH=src python examples/mnist_stdp.py \
          [--neurons 40] [--wexp 128] [--train 2000] [--test 1000] \
          [--cycle-backend window|step] [--kernel-backend ref|interp|tpu] \
          [--train-mode active|parallel] [--window-chunk T_CHUNK] \
          [--encode host|kernel] [--mesh-shape D,N]

The backend/batching flags become one frozen ``SNNEnginePlan``
(``--cycle-backend window`` is the time-resident window kernel,
``--train-mode parallel`` the batched training grid, ``--window-chunk``
the bounded-VMEM chunked spike streaming, ``--encode kernel`` the
intensity-resident ingestion where the dataset stays uint8 and spikes
are drawn in VMEM, ``--mesh-shape D,N`` the 2-D data × neuron
placement — needs D*N devices, e.g.
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for 2,4), and
test-set classification runs the plan's ``SNNEngine.infer`` verb
directly — the same engine the trainer and the serving path dispatch
through.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.wenquxing_snn import WENQUXING_22A
from repro.core.encoder import (poisson_encode_batch,
                                quantize_intensities, sample_seeds)
from repro.core.preprocess import preprocess_batch
from repro.core.trainer import train
from repro.data.digits import make_digits
from repro.engine import SNNEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--neurons", type=int, default=40,
                    choices=[10, 20, 30, 40])
    ap.add_argument("--wexp", type=int, default=128)
    ap.add_argument("--train", type=int, default=2000)
    ap.add_argument("--test", type=int, default=1000)
    ap.add_argument("--epochs", type=int, default=2)
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--cycle-backend", default="window",
                    choices=["window", "step"],
                    help="window = time-resident fused kernel, "
                         "step = per-cycle scan")
    ap.add_argument("--kernel-backend", default="ref",
                    choices=["ref", "interp", "tpu"],
                    help="window-kernel implementation (interp runs the "
                         "Pallas body in Python — slow, validation only)")
    ap.add_argument("--train-mode", default="active",
                    choices=["active", "parallel"],
                    help="active = sequential error-driven blocks, "
                         "parallel = all blocks in one batched grid")
    ap.add_argument("--window-chunk", type=int, default=None,
                    help="stream the spike window through VMEM in "
                         "chunks of this many cycles (kernel backends)")
    ap.add_argument("--encode", default="host",
                    choices=["host", "kernel"],
                    help="host = pre-encode the dataset into a spike "
                         "tensor (JAX PRNG), kernel = keep uint8 "
                         "intensities and draw spikes in VMEM from "
                         "counter-hash seeds")
    ap.add_argument("--mesh-shape", default=None, metavar="D,N",
                    help="shard every engine launch over a 2-D "
                         "(data × neuron) mesh; needs D*N devices")
    args = ap.parse_args()
    mesh_shape = (tuple(int(p) for p in args.mesh_shape.split(","))
                  if args.mesh_shape else None)

    print("rendering + preprocessing digits ...")
    imgs, labels = make_digits(args.train, seed=args.seed)
    timgs, tlabels = make_digits(args.test, seed=args.seed + 1)
    pp = lambda x: np.asarray(preprocess_batch(  # noqa: E731
        jnp.asarray(x.reshape(-1, 28, 28)), 0.1)).reshape(-1, 784)
    tr, te = pp(imgs), pp(timgs)

    cfg = dataclasses.replace(WENQUXING_22A, n_neurons=args.neurons,
                              w_exp=args.wexp, epochs=args.epochs,
                              cycle_backend=args.cycle_backend,
                              kernel_backend=args.kernel_backend,
                              train_mode=args.train_mode,
                              window_chunk=args.window_chunk,
                              encode=args.encode,
                              mesh_shape=mesh_shape)
    print(f"training 784-{args.neurons} (w_exp={args.wexp}, "
          f"{args.epochs} epochs, {args.train} samples, "
          f"{args.train_mode}/{args.cycle_backend}/"
          f"{args.kernel_backend}/{args.encode}"
          + (f"/mesh{mesh_shape}" if mesh_shape else "") + ") ...")
    t0 = time.time()
    model = train(cfg, tr, labels)
    print(f"  trained in {time.time() - t0:.1f}s")

    # classification = the engine's infer verb on the config's plan
    eng = SNNEngine(cfg.plan())
    if args.encode == "kernel":
        # test set stays intensity-resident too: uint8 rows + counter
        # seeds disjoint from the training chain
        counts = eng.infer(
            model.weights,
            intensities=quantize_intensities(jnp.asarray(te)),
            seeds=sample_seeds(0x7E57, len(te)), n_steps=cfg.n_steps)
    else:
        st = poisson_encode_batch(jax.random.key(99), jnp.asarray(te),
                                  cfg.n_steps)
        counts = eng.infer(model.weights, st)
    pred = model.neuron_class[jnp.argmax(counts, axis=-1)]
    acc = float(jnp.mean((pred == jnp.asarray(tlabels))
                         .astype(jnp.float32)))
    print(f"test accuracy: {acc:.4f}  "
          f"(paper, real MNIST @40: 0.9191; chance: 0.10)")

    from repro.core.bitpack import unpack
    on = np.asarray(unpack(model.weights, 784).sum(axis=1))
    print(f"effective synapses per neuron: mean={on.mean():.0f} "
          f"(w_exp budget = {args.wexp})")


if __name__ == "__main__":
    main()
