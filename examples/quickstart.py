"""Quickstart: the paper's SNN computing in 30 lines.

Trains the Wenquxing 22A network (784-10, 1-bit synapses, binary
stochastic STDP) on procedural digits and classifies a test batch, then
shows the RV-SNN fused kernel agreeing bit-exactly with the ISA-level
reference.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.wenquxing_snn import WENQUXING_22A
from repro.core.encoder import poisson_encode_batch
from repro.core.preprocess import preprocess_batch
from repro.core.trainer import accuracy, train
from repro.data.digits import make_digits
from repro.kernels import ops, ref
from repro.core import lfsr


def main() -> None:
    # --- train the paper's SNN on (offline substitute for) MNIST ------
    imgs, labels = make_digits(800, seed=1)
    timgs, tlabels = make_digits(200, seed=2)
    pp = lambda x: np.asarray(preprocess_batch(  # noqa: E731
        jnp.asarray(x.reshape(-1, 28, 28)), 0.1)).reshape(-1, 784)
    cfg = dataclasses.replace(WENQUXING_22A, n_neurons=10, epochs=1)
    model = train(cfg, pp(imgs), labels)
    st = poisson_encode_batch(jax.random.key(0), jnp.asarray(pp(timgs)),
                              cfg.n_steps)
    print(f"784-10 SNN accuracy: {accuracy(model, st, jnp.asarray(tlabels)):.3f}"
          f"  (chance = 0.10)")

    # --- one fused RV-SNN step: Pallas kernel == ISA reference --------
    n, w = 40, 25
    rng = np.random.default_rng(0)
    weights = jnp.asarray(rng.integers(0, 2**32, (n, w), dtype=np.uint32))
    pre = jnp.asarray(rng.integers(0, 2**32, (w,), dtype=np.uint32))
    v = jnp.zeros((n,), jnp.int32)
    teach = jnp.zeros((n,), jnp.int32)
    st0 = lfsr.seed(1, n * w).reshape(n, w)
    kw = dict(threshold=192, leak=16, w_exp=128, gain=4, n_syn=784,
              ltp_prob=16)
    got = ops.fused_snn_step(weights, pre, v, st0, teach,
                             backend="interp", **kw)
    want = ref.fused_snn_step_ref(weights, pre, v, st0, teach, **kw)
    ok = all(np.array_equal(np.asarray(a), np.asarray(b))
             for a, b in zip(got, want))
    print(f"fused Pallas SNNU step bit-exact vs reference: {ok}")


if __name__ == "__main__":
    main()
