"""Serve a small LM with continuous batching.

Exercises: prefill/decode split, per-slot cache lengths, slot reuse,
greedy + temperature sampling — the serving half of the framework.

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch starcoder2-3b]
      (the arch is instantiated at its REDUCED smoke size on CPU)
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models.transformer import Model
from repro.serving import Request, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    print(f"serving {cfg.name} ({cfg.n_params()/1e6:.1f}M params, "
          f"{args.slots} slots)")
    model = Model(cfg, dtype=jnp.float32, attn_chunk=16)
    params = model.init_params(jax.random.key(0))
    eng = ServingEngine(model, params, n_slots=args.slots, max_len=128,
                        temperature=args.temperature)

    rng = jax.random.key(42)
    reqs = []
    for i in range(args.requests):
        rng, k = jax.random.split(rng)
        plen = int(jax.random.randint(k, (), 3, 12))
        prompt = [int(t) for t in
                  jax.random.randint(k, (plen,), 1, cfg.vocab_size)]
        reqs.append(Request(rid=i, prompt=prompt,
                            max_new_tokens=args.max_new))

    t0 = time.time()
    eng.run(reqs, max_steps=2000)
    dt = time.time() - t0
    done = sum(r.done for r in reqs)
    print(f"completed {done}/{len(reqs)} requests in {dt:.1f}s "
          f"({eng.tokens_out} tokens, {eng.tokens_out/dt:.1f} tok/s, "
          f"{eng.steps} engine steps)")
    for r in reqs[:3]:
        print(f"  req {r.rid}: prompt={r.prompt[:6]}... "
              f"output={r.output}")


if __name__ == "__main__":
    main()
