"""Train a ~100M-parameter LM with the full production stack.

Exercises: config-driven model zoo, AdamW (optionally bf16 states +
stochastic rounding), sharded data loader, fault-tolerant TrainLoop
(checkpoint/restart + straggler watchdog), cosine schedule.

The default preset is a 110M dense decoder (12L x 768, GQA 12/4,
vocab 32k).  A few hundred steps on CPU takes a while — use --steps to
taste; --preset tiny runs in seconds for CI.

Run:  PYTHONPATH=src python examples/train_lm.py --steps 20 --preset tiny
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.data import ShardedLoader, SyntheticTokens
from repro.launch.train import make_train_step
from repro.models.transformer import Model
from repro.optim import AdamW, AdamWConfig, cosine_schedule
from repro.runtime import TrainLoop, TrainLoopConfig

PRESETS = {
    "100m": ArchConfig(
        name="lm-100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=4, d_ff=3072, vocab_size=32768,
        head_dim=64, max_seq_len=2048, source="example"),
    "tiny": ArchConfig(
        name="lm-tiny", family="dense", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=2, d_ff=256, vocab_size=512,
        head_dim=32, max_seq_len=512, source="example"),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--stochastic-rounding", action="store_true",
                    help="bf16 params + stochastic rounding (paper C3)")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    n_params = cfg.n_params()
    print(f"{cfg.name}: {n_params/1e6:.1f}M params")

    dtype = jnp.bfloat16 if args.stochastic_rounding else jnp.float32
    model = Model(cfg, dtype=dtype, loss_chunk=min(256, args.seq),
                  attn_chunk=min(512, args.seq))
    opt = AdamW(AdamWConfig(
        lr=cosine_schedule(args.lr, warmup_steps=10,
                           total_steps=args.steps),
        state_dtype=jnp.bfloat16 if args.stochastic_rounding
        else jnp.float32,
        stochastic_rounding=args.stochastic_rounding))

    params = model.init_params(jax.random.key(0))
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(model, opt))

    source = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=args.seq,
                             batch_size=args.batch, seed=0)
    loader = ShardedLoader(source.batch, prefetch=2)

    def batch_fn(step):
        b = loader.get(step)
        return {k: jnp.asarray(v) for k, v in b.items()}

    loop = TrainLoop(
        step_fn,
        TrainLoopConfig(total_steps=args.steps,
                        checkpoint_every=max(10, args.steps // 5)),
        args.ckpt_dir, batch_fn=batch_fn)
    (params, opt_state) = loop.run((params, opt_state))

    first = loop.metrics_log[0]["loss"] if loop.metrics_log else float("nan")
    last = loop.metrics_log[-1]["loss"] if loop.metrics_log else float("nan")
    print(f"loss: {first:.3f} -> {last:.3f} over "
          f"{len(loop.metrics_log)} steps "
          f"(stragglers: {len(loop.straggler_events)})")


if __name__ == "__main__":
    main()
