"""repro.checkpoint — sharded, async, elastic checkpointing."""

from repro.checkpoint.checkpointer import CheckpointManager

__all__ = ["CheckpointManager"]
