"""Checkpoint manager: async, atomic, keep-k, restore-to-any-mesh.

Design for 1000+ nodes (documented behavior at each scale):

* **Atomicity** — writes land in ``step_N.tmp/`` and are renamed to
  ``step_N/`` only after fsync; a crash mid-write never corrupts the
  latest checkpoint.  Restore picks the newest *complete* step.
* **Async** — ``save`` snapshots device arrays to host then hands the
  file I/O to a background thread; the train loop blocks only on the
  previous save (single-buffer back-pressure).
* **Sharded layout** — every leaf is saved as one ``.npy`` per process
  (``leaf_name.proc{K}.npy``) holding that process's addressable shards;
  on a single-process run this degenerates to one file per leaf.
* **Elastic restore** — ``restore`` takes the *target* sharding tree;
  leaves are re-laid-out with ``jax.device_put`` regardless of the mesh
  they were saved under (pod count up/down, TP width change), which is
  the mechanism behind elastic scaling in repro.runtime.
* **keep-k rotation** — old steps are deleted after a successful save.
"""

from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path)
        flat[key] = leaf
    return flat


class CheckpointManager:
    def __init__(self, directory: str | Path, *, keep: int = 3,
                 async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._pending: threading.Thread | None = None

    # --- save ------------------------------------------------------------

    def save(self, step: int, tree) -> None:
        """Snapshot ``tree`` (any pytree of arrays) at ``step``."""
        self.wait()  # back-pressure: at most one in-flight save
        host = {k: np.asarray(jax.device_get(v))
                for k, v in _flatten(tree).items()}
        treedef = jax.tree.structure(tree)

        def write():
            tmp = self.dir / f"step_{step}.tmp"
            final = self.dir / f"step_{step}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            manifest = {"step": step, "leaves": {}}
            for key, arr in host.items():
                fname = key.replace("/", "__") + ".proc0.npy"
                np.save(tmp / fname, arr)
                manifest["leaves"][key] = {
                    "file": fname, "shape": list(arr.shape),
                    "dtype": str(arr.dtype)}
            manifest["treedef"] = str(treedef)
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)
            self._rotate()

        if self.async_save:
            self._pending = threading.Thread(target=write, daemon=True)
            self._pending.start()
        else:
            write()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def purge_tmp(self) -> list[str]:
        """Remove ``step_N.tmp/`` droppings left by writers that died
        mid-save (a crash before the atomic rename).  Restore already
        ignores them; purging on recovery keeps the directory from
        accumulating torn state.  Returns the purged directory names.
        Call only when no save is in flight (e.g. at restore time)."""
        self.wait()
        purged = []
        for p in self.dir.glob("step_*.tmp"):
            if p.is_dir():
                shutil.rmtree(p, ignore_errors=True)
                purged.append(p.name)
        return purged

    def _rotate(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # --- restore ---------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            try:
                out.append(int(p.name.split("_")[1]))
            except ValueError:
                continue
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int | None, like_tree, sharding_tree=None):
        """Load ``step`` (or latest).  ``like_tree`` provides structure/
        dtypes; ``sharding_tree`` (optional) re-lays-out every leaf onto
        the CURRENT mesh — the elastic-scaling path."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self.dir / f"step_{step}"
        manifest = json.loads((d / "manifest.json").read_text())
        flat_like = _flatten(like_tree)
        flat_sh = (_flatten(sharding_tree)
                   if sharding_tree is not None else {})
        out = {}
        for key, like in flat_like.items():
            info = manifest["leaves"][key]
            arr = np.load(d / info["file"])
            if flat_sh:
                arr = jax.device_put(arr, flat_sh[key])
            else:
                arr = jax.device_put(arr)
            out[key] = arr
        # rebuild the tree in like_tree's structure
        leaves_in_order = [out[k] for k in flat_like]
        return jax.tree.unflatten(jax.tree.structure(like_tree),
                                  leaves_in_order), step
