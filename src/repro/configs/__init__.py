"""repro.configs — assigned architecture configs + the paper's own SNN.

Every module registers its config(s) on import; ``get_config(name)``
and ``list_configs()`` are the public API.
"""

from repro.configs.base import (ArchConfig, LayerKind, get_config,
                                layer_kinds, list_configs, reduced,
                                register, scan_grouping)

# Register all assigned architectures (import side effects).
from repro.configs import (command_r_35b, gemma3_1b, grok1_314b,  # noqa: F401
                           internvl2_26b, jamba_1_5_large_398b,
                           llama3_405b, mixtral_8x22b, rwkv6_7b,
                           starcoder2_3b, whisper_small)
from repro.configs.shapes import SHAPES, ShapeSpec, applicable_shapes  # noqa: F401
from repro.configs.wenquxing_snn import WENQUXING_22A  # noqa: F401

__all__ = ["ArchConfig", "LayerKind", "get_config", "layer_kinds",
           "list_configs", "reduced", "register", "scan_grouping",
           "SHAPES", "ShapeSpec", "applicable_shapes", "WENQUXING_22A"]
