"""ArchConfig — one dataclass drives every assigned architecture.

Layer pattern encoding (see ``layer_kinds``):
  mixer:       "attn" everywhere, "rwkv" (attn-free), or "hybrid"
               (1 attention layer per ``attn_period``, mamba elsewhere)
  swa_period:  k > 0 -> every k-th layer is GLOBAL attention, the others
               use ``window`` sliding-window attention (gemma3 5:1).
               k == 0 and window set -> ALL layers windowed (mixtral).
  moe_period:  k > 0 -> every k-th layer's FFN is MoE (mixtral/grok: 1 =
               every layer; jamba: 2).  0 -> dense FFN everywhere.
  encoder_layers > 0 -> encoder-decoder (whisper).
  frontend:    modality stub — ``input_specs`` provides precomputed
               frame/patch embeddings of length ``frontend_len``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Literal

Mixer = Literal["attn", "rwkv", "hybrid"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense|moe|ssm|hybrid|audio|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    rope_theta: float = 10000.0
    use_rope: bool = True
    # attention pattern
    window: int | None = None
    swa_period: int = 0
    # MoE
    n_experts: int = 0
    top_k: int = 2
    moe_period: int = 0
    capacity_factor: float = 1.25
    # hybrid / attn-free
    mixer: Mixer = "attn"
    attn_period: int = 0            # hybrid: 1 attn layer per k
    d_state: int = 16               # mamba
    rwkv_head_size: int = 64
    # encoder-decoder / frontends
    encoder_layers: int = 0
    frontend: str | None = None     # None|audio|vision
    frontend_len: int = 0
    # misc
    norm: str = "rms"               # rms|ln
    act: str = "swiglu"             # swiglu|gelu
    use_bias: bool = False
    tie_embeddings: bool = False
    max_seq_len: int = 131072
    source: str = ""                # provenance tag

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        return (self.vocab_size + 127) // 128 * 128

    @property
    def is_enc_dec(self) -> bool:
        return self.encoder_layers > 0

    def n_params(self) -> int:
        """Total parameter count (all experts; embeddings included)."""
        return _count_params(self, active_only=False)

    def n_params_active(self) -> int:
        """Active params per token (top-k experts only) — for 6ND."""
        return _count_params(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class LayerKind:
    mixer: str            # attn_full | attn_window | mamba | rwkv
    ffn: str              # mlp | moe | none
    cross_attn: bool = False


def layer_kinds(cfg: ArchConfig, n_layers: int | None = None,
                decoder: bool = True) -> list[LayerKind]:
    """The per-layer pattern for the (decoder) stack."""
    n = cfg.n_layers if n_layers is None else n_layers
    kinds = []
    for i in range(n):
        if cfg.mixer == "rwkv":
            mixer = "rwkv"
        elif cfg.mixer == "hybrid":
            mixer = ("attn_full" if i % cfg.attn_period ==
                     cfg.attn_period // 2 else "mamba")
        else:
            if cfg.swa_period > 0:
                mixer = ("attn_full" if (i + 1) % cfg.swa_period == 0
                         else "attn_window")
            elif cfg.window is not None:
                mixer = "attn_window"
            else:
                mixer = "attn_full"
        if cfg.moe_period > 0 and (i % cfg.moe_period ==
                                   cfg.moe_period - 1):
            ffn = "moe"
        else:
            ffn = "mlp"
        kinds.append(LayerKind(mixer, ffn,
                               cross_attn=decoder and cfg.is_enc_dec))
    return kinds


def scan_grouping(kinds: list[LayerKind]) -> tuple[int, int, int]:
    """(period, n_scanned_superblocks, n_remainder_layers).

    Finds the smallest repeating pattern period so the layer stack can be
    lax.scan'ed over stacked params (compile-time ~ O(period), not O(L)).
    """
    n = len(kinds)
    for p in range(1, n + 1):
        if all(kinds[i] == kinds[i % p] for i in range(n)):
            return p, n // p, n % p
    return n, 1, 0


def _count_params(cfg: ArchConfig, active_only: bool) -> int:
    d, ff, v = cfg.d_model, cfg.d_ff, cfg.vocab_size
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    total = v * d  # embedding
    if not cfg.tie_embeddings:
        total += d * v
    kinds = layer_kinds(cfg)
    if cfg.is_enc_dec:
        kinds = kinds + layer_kinds(cfg, cfg.encoder_layers, decoder=False)
    for kd in kinds:
        if kd.mixer.startswith("attn"):
            total += d * (hq + 2 * hkv) * hd + hq * hd * d
        elif kd.mixer == "mamba":
            di = 2 * d
            r = max(1, d // 16)
            total += d * 2 * di + 5 * di \
                + di * (r + 2 * cfg.d_state) + r * di + di * d \
                + 2 * di * cfg.d_state
        elif kd.mixer == "rwkv":
            total += 5 * d * d + 2 * d * 64
        if kd.cross_attn:
            total += d * (hq + 2 * hkv) * hd + hq * hd * d
        if kd.ffn == "moe":
            e = cfg.top_k if active_only else cfg.n_experts
            total += d * cfg.n_experts  # router
            total += e * 3 * d * ff
        elif kd.ffn == "mlp":
            total += (3 if cfg.act == "swiglu" else 2) * d * ff
    return total


# --- registry ----------------------------------------------------------------

_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    import repro.configs  # noqa: F401  (ensures registration ran)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list[str]:
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests.

    Preserves the structural pattern (SWA period, MoE period, hybrid
    ratio, enc-dec) while shrinking width/depth/vocab.
    """
    period = 1
    if cfg.swa_period:
        period = cfg.swa_period
    if cfg.attn_period:
        period = cfg.attn_period
    if cfg.moe_period:
        period = max(period, cfg.moe_period)
    n_layers = max(2, period)
    kv = max(1, min(cfg.n_kv_heads, 2))
    heads = max(2, (4 // kv) * kv)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=128,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        window=min(cfg.window, 16) if cfg.window else None,
        encoder_layers=2 if cfg.is_enc_dec else 0,
        frontend_len=8 if cfg.frontend else 0,
        rwkv_head_size=32,
        max_seq_len=256,
    )
