"""gemma3-1b [dense] — 5:1 local:global attention, 128k-class context.

26L, d_model=1152, 4H (GQA kv=1), d_ff=6912, vocab=262144, head_dim=256,
sliding window 512 on local layers, every 6th layer global, tied
embeddings.  [hf:google/gemma-3-1b-pt; unverified]
"""

from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    d_ff=6912,
    vocab_size=262144,
    head_dim=256,
    rope_theta=1e6,
    window=512,
    swa_period=6,
    tie_embeddings=True,
    max_seq_len=1 << 19,
    source="hf:google/gemma-3-1b-pt; unverified",
))
