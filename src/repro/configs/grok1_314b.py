"""grok-1-314b [moe] — 8 experts top-2.

64L, d_model=6144, 48H (GQA kv=8), d_ff=32768, vocab=131072, MoE 8e
top-2.  [hf:xai-org/grok-1; unverified]  Full attention.
"""

from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    head_dim=128,
    n_experts=8,
    top_k=2,
    moe_period=1,
    max_seq_len=32768,
    source="hf:xai-org/grok-1; unverified",
))
