"""internvl2-26b [vlm] — InternViT frontend (stub) + InternLM2 backbone.

48L, d_model=6144, 48H (GQA kv=8), d_ff=16384, vocab=92553.
[arXiv:2404.16821; hf]  The ViT is a STUB: ``input_specs`` provides
precomputed patch embeddings [B, 256, d_model] prepended to the text.
"""

from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    head_dim=128,
    rope_theta=1e6,
    frontend="vision",
    frontend_len=256,
    max_seq_len=32768,
    source="arXiv:2404.16821; hf",
))
