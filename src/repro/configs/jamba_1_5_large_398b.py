"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7, MoE 16e top-2.

72L, d_model=8192, 64H (GQA kv=8), d_ff=24576, vocab=65536; one
attention layer per 8 (rest Mamba), MoE every 2nd layer.
[arXiv:2403.19887; hf]  O(1) state on Mamba layers -> runs long_500k.
"""

from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    head_dim=128,
    use_rope=False,            # jamba: no positional encoding
    mixer="hybrid",
    attn_period=8,
    d_state=16,
    n_experts=16,
    top_k=2,
    moe_period=2,
    max_seq_len=1 << 19,
    source="arXiv:2403.19887; hf",
))
