"""mixtral-8x22b [moe] — 8 experts top-2, SWA.

56L, d_model=6144, 48H (GQA kv=8), d_ff=16384, vocab=32768, MoE 8e top-2.
[arXiv:2401.04088; hf]  Sliding window 4096 on all layers (Mistral-style).
"""

from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    head_dim=128,
    rope_theta=1e6,
    window=4096,
    n_experts=8,
    top_k=2,
    moe_period=1,
    max_seq_len=65536,
    source="arXiv:2401.04088; hf",
))
