"""rwkv6-7b [ssm] — Finch, attention-free, data-dependent decay.

32L, d_model=4096 (attn-free), d_ff=14336, vocab=65536.
[arXiv:2404.05892; hf]  State is O(1) in T -> runs long_500k.
"""

from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,           # d_model / rwkv_head_size
    n_kv_heads=64,
    d_ff=14336,
    vocab_size=65536,
    mixer="rwkv",
    rwkv_head_size=64,
    use_rope=False,
    max_seq_len=1 << 20,
    source="arXiv:2404.05892; hf",
))
