"""The four assigned input-shape presets + per-arch applicability.

LM transformer shapes are seq_len x global_batch.  ``decode_*`` /
``long_*`` lower ``serve_step`` (one new token against a KV cache of
seq_len), not ``train_step``.  ``long_500k`` needs sub-quadratic
attention: skipped for pure full-attention archs (recorded with reasons),
run for SSM / hybrid / SWA archs.
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# Archs whose long-context state stays sub-quadratic: SSM (rwkv6),
# hybrid (jamba: O(1) Mamba state + 9 attn layers), SWA-bounded
# (gemma3 5:1 local:global, mixtral all-window).
_LONG_OK = {"rwkv6-7b", "jamba-1.5-large-398b", "gemma3-1b",
            "mixtral-8x22b"}

LONG_SKIP_REASONS: dict[str, str] = {
    "whisper-small": "enc-dec full attention; architecture capped at "
                     "1500 frames / short decoder — no 500k mode",
    "grok-1-314b": "pure full attention (no SWA/SSM path)",
    "starcoder2-3b": "pure full attention",
    "command-r-35b": "pure full attention",
    "llama3-405b": "pure full attention",
    "internvl2-26b": "pure full attention",
}


def applicable_shapes(cfg: ArchConfig) -> list[ShapeSpec]:
    """Shape cells that run for this arch (others recorded as skips)."""
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and cfg.name not in _LONG_OK:
            continue
        out.append(s)
    return out
