"""starcoder2-3b [dense] — GQA, RoPE.

30L, d_model=3072, 24H (GQA kv=2), d_ff=12288, vocab=49152.
[arXiv:2402.19173; hf]
"""

from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="starcoder2-3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    d_ff=12288,
    vocab_size=49152,
    head_dim=128,
    rope_theta=1e5,
    use_bias=True,
    max_seq_len=16384,
    source="arXiv:2402.19173; hf",
))
