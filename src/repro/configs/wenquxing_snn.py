"""The paper's own configuration: Wenquxing 22A MNIST SNN (784-{10,20,40}).

This is the config the reproduction experiments (benchmarks/, examples/)
run; it mirrors Table 1's "this work" row: 784 inputs, 1-bit synapses,
binary stochastic STDP, rate-Poisson encoding, {10, 20, 40} LIF neurons.
"""

from __future__ import annotations

import dataclasses

from repro.core.trainer import SNNTrainConfig

WENQUXING_22A = SNNTrainConfig(
    n_inputs=784,
    n_classes=10,
    n_neurons=40,      # paper's best CA (91.91% on MNIST) at 40
    n_steps=72,
    threshold=192,
    leak=16,
    w_exp=128,         # paper sweeps {128, 256, 512}
    gain=4,
    ltp_prob=16,
    ltp_prob_active=1023,
    teach_pos=64,
    teach_neg=-1024,
    epochs=2,
)

VARIANTS = {
    n: dataclasses.replace(WENQUXING_22A, n_neurons=n)
    for n in (10, 20, 40)
}

# Intensity-resident ingestion: the dataset stays uint8[N, 784] and the
# window kernels draw each cycle's spikes in VMEM from per-sample
# counter-hash seeds — no N×T×w spike tensor (T*w*4 -> n_in
# bytes/sample, ~T/8x).
WENQUXING_22A_INTENSITY = dataclasses.replace(
    WENQUXING_22A, encode="kernel", encode_seed=0x22A)

# Cluster-scale training sweep: all blocks train concurrently as one
# batched grid per presented sample, sharded over a 2-D (data × neuron)
# mesh — block streams over "data", neuron rows over "neurons".  Any
# (data, neurons) factorization is bit-exact with the local run; (2, 4)
# matches the 8-device host mesh CI forces.
WENQUXING_22A_MESH2D = dataclasses.replace(
    WENQUXING_22A_INTENSITY, train_mode="parallel", mesh_shape=(2, 4))
