"""whisper-small [audio] — enc-dec, conv frontend stub.

12L (enc) + 12L (dec), d_model=768, 12H (GQA kv=12 -> MHA), d_ff=3072,
vocab=51865.  [arXiv:2212.04356; unverified]

The audio frontend (2x conv + GELU over 80-mel spectrograms) is a STUB:
``input_specs`` provides precomputed frame embeddings [B, 1500, 768].
Whisper uses learned positional embeddings and LayerNorm (not RoPE/RMS).
"""

from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,
    encoder_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    head_dim=64,
    use_rope=False,
    norm="ln",
    act="gelu",
    use_bias=True,
    frontend="audio",
    frontend_len=1500,
    max_seq_len=32768,
    source="arXiv:2212.04356; unverified",
))
