"""repro.core — Wenquxing 22A's contribution as a composable JAX module.

Layers (bottom-up):
  lfsr / bitpack          bit-exact PRNG + 1-bit synapse packing
  lif / stdp              streamlined LIF (C2), binary stochastic STDP (C3)
  rvsnn                   RV-SNN V1.0 instruction semantics (C1)
  encoder / preprocess    Poisson rate coding, deskew + soft threshold
  network / trainer       scan-based execution, supervised STDP + active
                          learning (C4)
  energy                  event-driven energy/footprint model (Fig.4/Tab.2)
"""

from repro.core.bitpack import n_words, pack, popcount, tail_mask, unpack
from repro.core.encoder import (encode_from_counter,
                                encode_from_counter_batch, poisson_encode,
                                poisson_encode_batch, quantize_intensities,
                                spike_rate)
from repro.core.lif import LIFParams, lif_params, lif_reset, lif_step
from repro.core.network import SNNOutput, infer_batch, run_sample, train_stream
from repro.core.preprocess import deskew, preprocess, preprocess_batch, soft_threshold
from repro.core.rvsnn import SnnRegFile, snn_ls, snn_nu, snn_regfile, snn_sp, snn_step, snn_su
from repro.core.stdp import STDPParams, init_weights, ltd_prob_from_wexp, stdp_params, stdp_update
from repro.core.trainer import SNNModel, SNNTrainConfig, accuracy, classify, train

__all__ = [k for k in dir() if not k.startswith("_")]
