"""Bit-packing for 1-bit synapses and spike vectors.

Wenquxing 22A stores one synaptic row per neuron as 1-bit weights; the
SPU ANDs the incoming spike vector against the row and counts survivors.
On TPU we pack 32 synapses (or spikes) per ``uint32`` word so the whole
row update is a handful of VPU lane ops.

Convention: bit ``j`` of word ``w`` corresponds to flat index
``w * 32 + j`` (little-endian within the word).  Tail bits past ``n`` are
kept at 0 by every op in this module.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

WORD_BITS = 32


def n_words(n_bits: int) -> int:
    """Words needed for ``n_bits`` packed bits."""
    return (n_bits + WORD_BITS - 1) // WORD_BITS


def pack(bits: jnp.ndarray) -> jnp.ndarray:
    """Pack a {0,1} array (..., n) -> uint32 (..., n_words(n))."""
    n = bits.shape[-1]
    pad = n_words(n) * WORD_BITS - n
    if pad:
        bits = jnp.concatenate(
            [bits, jnp.zeros(bits.shape[:-1] + (pad,), bits.dtype)], axis=-1)
    b = bits.astype(jnp.uint32).reshape(bits.shape[:-1] + (-1, WORD_BITS))
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    return jnp.sum(jnp.left_shift(b, shifts), axis=-1, dtype=jnp.uint32)


def unpack(words: jnp.ndarray, n: int) -> jnp.ndarray:
    """Unpack uint32 (..., w) -> {0,1} int32 (..., n)."""
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    bits = jnp.bitwise_and(
        jnp.right_shift(words[..., :, None], shifts), jnp.uint32(1))
    flat = bits.reshape(words.shape[:-1] + (-1,))
    return flat[..., :n].astype(jnp.int32)


def tail_mask(n: int) -> jnp.ndarray:
    """uint32[n_words(n)] with ones only in valid bit positions."""
    w = n_words(n)
    idx = np.arange(w * WORD_BITS).reshape(w, WORD_BITS)
    valid = (idx < n).astype(np.uint64)
    vals = (valid << np.arange(WORD_BITS, dtype=np.uint64)).sum(axis=1)
    return jnp.asarray(vals.astype(np.uint32))


def popcount(words: jnp.ndarray, axis=-1) -> jnp.ndarray:
    """Total set bits along ``axis`` (int32)."""
    import jax.lax as lax
    return jnp.sum(lax.population_count(words).astype(jnp.int32), axis=axis)
