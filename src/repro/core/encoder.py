"""Rate-based Poisson spike encoder (paper §3.1).

"To generate the spike, we set a firing probability of a time cycle:
P = x, where x needs to be normalized to [0,1]" — i.e. each pixel fires
as an independent Bernoulli(intensity) per time cycle.  The encoder
outputs *packed* uint32 spike words (the SPU's native operand).

Randomness note (DESIGN.md §7): the paper's encoder runs on-core; its RNG
is unspecified, so we use JAX's counter-based PRNG here (statistical
fidelity), reserving the bit-exact LFSR for the LTD path where the paper
specifies it.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.bitpack import pack


def poisson_encode(key: jax.Array, intensities: jnp.ndarray,
                   n_steps: int) -> jnp.ndarray:
    """Encode normalized intensities [n] -> packed spikes uint32[T, w].

    intensities: float32 in [0, 1] (pixel value / 255 after preprocessing).
    """
    n = intensities.shape[-1]
    u = jax.random.uniform(key, (n_steps, n))
    bits = (u < intensities[None, :]).astype(jnp.uint32)
    return pack(bits)


def poisson_encode_batch(key: jax.Array, batch: jnp.ndarray,
                         n_steps: int) -> jnp.ndarray:
    """[B, n] intensities -> uint32[B, T, w] packed spike trains."""
    keys = jax.random.split(key, batch.shape[0])
    return jax.vmap(lambda k, x: poisson_encode(k, x, n_steps))(keys, batch)


def spike_rate(packed: jnp.ndarray, n: int) -> jnp.ndarray:
    """Mean firing rate per input across time.  packed: uint32[T, w]."""
    from repro.core.bitpack import unpack
    return jnp.mean(unpack(packed, n).astype(jnp.float32), axis=0)
