"""Rate-based Poisson spike encoder (paper §3.1).

"To generate the spike, we set a firing probability of a time cycle:
P = x, where x needs to be normalized to [0,1]" — i.e. each pixel fires
as an independent Bernoulli(intensity) per time cycle.  The encoder
outputs *packed* uint32 spike words (the SPU's native operand).

Two encoders live here:

``poisson_encode``
    JAX counter-based PRNG (statistical fidelity; DESIGN.md §7).  Used
    by the training pipeline, where the exact bit stream is not part of
    the architecture contract.

``encode_from_counter``
    The deterministic host oracle of the **in-kernel encode path**: the
    same stateless ``lfsr.counter_hash`` draw the window kernels run in
    VMEM, so a kernel launch that generates its own spikes from uint8
    intensities is bit-exact with this function.  A spike at (cycle t,
    input i) fires iff ``counter_hash(seed, t, i) & 0xFF < intensity``,
    i.e. P = intensity / 256 — intensity 0 is silent by construction
    (the property batch-padding in serving relies on).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import lfsr
from repro.core.bitpack import pack, popcount, unpack


def poisson_encode(key: jax.Array, intensities: jnp.ndarray,
                   n_steps: int) -> jnp.ndarray:
    """Encode normalized intensities [n] -> packed spikes uint32[T, w].

    intensities: float32 in [0, 1] (pixel value / 255 after preprocessing).
    """
    n = intensities.shape[-1]
    u = jax.random.uniform(key, (n_steps, n))
    bits = (u < intensities[None, :]).astype(jnp.uint32)
    return pack(bits)


def poisson_encode_batch(key: jax.Array, batch: jnp.ndarray,
                         n_steps: int) -> jnp.ndarray:
    """[B, n] intensities -> uint32[B, T, w] packed spike trains."""
    keys = jax.random.split(key, batch.shape[0])
    return jax.vmap(lambda k, x: poisson_encode(k, x, n_steps))(keys, batch)


def quantize_intensities(x: jnp.ndarray) -> jnp.ndarray:
    """Normalized [0, 1] intensities -> the uint8 operand of the counter
    encoder (P = round(x * 255) / 256 per cycle)."""
    return jnp.clip(jnp.round(jnp.asarray(x, jnp.float32) * 255.0),
                    0, 255).astype(jnp.uint8)


def encode_from_counter(seed, intensities: jnp.ndarray, n_steps: int,
                        *, t0: int = 0) -> jnp.ndarray:
    """Deterministic counter encode: uint8[n] -> packed uint32[T, w].

    Bit-exact host oracle of the kernels' in-VMEM draw (same
    ``lfsr.counter_hash``, same low-8-bit compare).  ``t0`` offsets the
    cycle counter, so any slice of a window can be regenerated in
    isolation — e.g. just the final cycle for the spike register.
    """
    inten = jnp.asarray(intensities)
    n = inten.shape[-1]
    idx = jnp.arange(n, dtype=jnp.uint32)
    cyc = jnp.arange(t0, t0 + n_steps, dtype=jnp.uint32)
    h = lfsr.counter_hash(jnp.asarray(seed, jnp.uint32),
                          cyc[:, None], idx[None, :])
    bits = (jnp.bitwise_and(h, jnp.uint32(0xFF))
            < inten.astype(jnp.uint32)).astype(jnp.uint32)
    return pack(bits)


def sample_seeds(base, n: int, epoch: int = 0) -> jnp.ndarray:
    """Per-sample counter seeds i32[n] derived from ``(base, epoch)``.

    One :func:`lfsr.counter_hash` draw per sample index (cycle axis =
    sample, lane axis = epoch), so consecutive samples get decorrelated
    seed values rather than consecutive integers, and every ``epoch``
    gets fresh Poisson draws for the same samples at zero memory cost —
    the train-while-serving refresh path re-presents the dataset with
    new stochastic windows each refresh epoch.  ``epoch=0`` is
    bit-exact with the historical single-epoch derivation.
    Device-independent and stateless — any shard, chunk or epoch
    regenerates sample i's seed (and therefore its whole spike window)
    from (base, epoch, i) alone, which is what keeps every (data,
    neurons) mesh factorization bit-exact.  The int32 cast is a
    wrapping bit-cast; the encode path reads the seeds back as uint32.
    """
    return sample_seeds_at(base, jnp.arange(n, dtype=jnp.uint32), epoch)


def sample_seeds_at(base, idx, epoch: int = 0) -> jnp.ndarray:
    """Seeds for explicit sample indices ``idx`` (i32/u32[...]) —
    ``sample_seeds(base, n, epoch)[idx]`` without materializing the
    full range, so error-subset re-presentations and refresh slices
    keep each sample's original (base, epoch, index) derivation."""
    return lfsr.counter_hash(jnp.asarray(base, jnp.uint32),
                             jnp.asarray(idx, jnp.uint32),
                             jnp.asarray(epoch, jnp.uint32)
                             ).astype(jnp.int32)


def encode_from_counter_batch(seeds, intensities: jnp.ndarray,
                              n_steps: int) -> jnp.ndarray:
    """Per-sample-seeded counter encode: uint8[B, n] -> uint32[B, T, w].

    ``seeds`` is an i32/u32[B] vector or a scalar broadcast to every
    sample (all-identical seeds produce all-identical windows).
    """
    b = intensities.shape[0]
    sd = jnp.broadcast_to(jnp.asarray(seeds, jnp.uint32), (b,))
    return jax.vmap(
        lambda s, x: encode_from_counter(s, x, n_steps))(sd, intensities)


def encode_windows_host(seeds, intensities: jnp.ndarray, n_steps: int,
                        words: int, t_total=None) -> jnp.ndarray:
    """Host-side counter encode shaped for the window kernels:
    uint8[B, n_in] -> u32[B, T, words].

    The ground truth of the in-kernel encode path: windows from
    :func:`encode_from_counter_batch`, zero-padded on the word axis to
    the kernels' ``words`` width and — when ``t_total`` (i32[B]) is
    given — zero-masked past each sample's true window length,
    mirroring the serving kernel's SMEM mask (count-identical for any
    threshold >= 1: a zero row adds no input counts and the membrane
    only leaks).
    """
    wins = encode_from_counter_batch(seeds, intensities, n_steps)
    pad = words - wins.shape[-1]
    if pad:
        wins = jnp.pad(wins, ((0, 0), (0, 0), (0, pad)))
    if t_total is not None:
        mask = (jnp.arange(n_steps)[None, :, None]
                < jnp.asarray(t_total, jnp.int32)[:, None, None])
        wins = jnp.where(mask, wins, jnp.uint32(0))
    return wins


def spike_rate(packed: jnp.ndarray, n: int) -> jnp.ndarray:
    """Population firing rate per time cycle.  packed: uint32[T, w].

    Returns float32[T]: the fraction of the ``n`` inputs spiking at each
    cycle, computed as a per-time-slice popcount over the packed words —
    the raster is never unpacked (tail bits past ``n`` are zero by the
    packing convention, so they never count).
    """
    return popcount(packed, axis=-1).astype(jnp.float32) / n


def spike_rate_per_input(packed: jnp.ndarray, n: int) -> jnp.ndarray:
    """Mean firing rate per input across time: float32[n]."""
    return jnp.mean(unpack(packed, n).astype(jnp.float32), axis=0)
