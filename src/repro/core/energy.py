"""Event-driven energy/footprint model (paper Fig. 4 / Table 2 analogue).

The paper measures FPGA wall-power of Wenquxing 22A (5.055 W) vs ODIN
driven by the same NutShell control core (25.949 W) — a 5.13x gap it
attributes to the decoupled CPU<->accelerator control/data flow.  We
cannot measure watts in this container, so this module implements the
standard event-driven accounting (the same kind the 12.7 pJ/SOP ODIN
figure comes from) for two machine models:

* ``fused``     — Wenquxing-style: the SNNU lives in the pipeline; per
  cycle each neuron row is streamed once past the SPU/NU/SU, weights are
  written back only on post-spikes, no event queue, no bus transfers.
* ``decoupled`` — ODIN-style accelerator behind a bus: per *input spike
  event* an AER packet crosses the bus, the full synapse column is read,
  all neuron states are read+written, and the controller core polls.

Constants are explicit and documented; results are **modeled energy**,
clearly labeled as such everywhere they are reported.

Model validity: the fused machine streams every synapse row every cycle
while the decoupled machine is event-driven, so the fused advantage
holds for input activity >= ~5% per cycle (Poisson-encoded MNIST runs
at 15-20%); at near-zero activity the event-driven accelerator's
idle-cycle skipping wins (property-tested crossover,
tests/test_property.py).
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class EnergyConstants:
    """Per-event energies (J) and static power (W).

    e_sop:    energy per synaptic operation (AND+count lane) — ODIN's
              measured 12.7 pJ/SOP [Frenkel 2019] is the reference point.
    e_sram:   per-byte SRAM access.
    e_bus:    per-byte bus/AER transfer (decoupled model only).
    e_nu:     per neuron-state update.
    p_static: static/idle power of the compute fabric.
    """
    e_sop: float = 12.7e-12
    e_sram: float = 5.0e-12
    e_bus: float = 40.0e-12
    e_nu: float = 20.0e-12
    p_static_fused: float = 0.35      # SNNU shares the CPU pipeline
    p_static_decoupled: float = 1.75  # separate accelerator + bus + poll
    cycle_s: float = 1.0 / 100e6      # 100 MHz FPGA clock


@dataclass
class EventCounts:
    """Raw activity counters for one presentation window."""
    cycles: int = 0
    input_spikes: int = 0      # total pre-synaptic spike events
    sops: int = 0              # synaptic AND+count lane ops
    neuron_updates: int = 0
    post_spikes: int = 0       # STDP row-update events
    weight_bytes: int = 0      # synapse memory traffic
    state_bytes: int = 0       # membrane/LFSR traffic
    bus_bytes: int = 0         # decoupled only


def count_events(n_neurons: int, n_inputs: int, n_steps: int,
                 input_spike_total: int, post_spike_total: int,
                 machine: str) -> EventCounts:
    """Analytic event counts for one sample presentation.

    input_spike_total: sum over cycles of active inputs (from the raster).
    post_spike_total:  sum over cycles of fired neurons.
    """
    words = (n_inputs + 31) // 32
    row_bytes = words * 4
    c = EventCounts(cycles=n_steps, input_spikes=input_spike_total,
                    post_spikes=post_spike_total)
    if machine == "fused":
        # One streaming pass per cycle: every row read once; written back
        # only on post spikes.  Neuron state lives in registers (no SRAM).
        c.sops = input_spike_total * n_neurons
        c.neuron_updates = n_steps * n_neurons
        c.weight_bytes = n_steps * n_neurons * row_bytes \
            + post_spike_total * row_bytes
        c.state_bytes = 0
        c.bus_bytes = 0
    elif machine == "decoupled":
        # Per input-spike event: AER packet (4B each way), synapse column
        # read (n_neurons bits), all neuron states read+written (4B each),
        # plus weight write-back traffic on post spikes and per-cycle
        # controller polling (8B MMIO).
        col_bytes = (n_neurons + 7) // 8
        c.sops = input_spike_total * n_neurons
        c.neuron_updates = input_spike_total * n_neurons
        c.weight_bytes = input_spike_total * col_bytes \
            + post_spike_total * row_bytes * 2
        c.state_bytes = input_spike_total * n_neurons * 8
        c.bus_bytes = input_spike_total * 8 + n_steps * 8
    else:
        raise ValueError(f"unknown machine model {machine!r}")
    return c


def energy(c: EventCounts, k: EnergyConstants, machine: str) -> dict:
    """Modeled energy breakdown (J) and average power (W) for the window."""
    t = c.cycles * k.cycle_s
    dyn = (c.sops * k.e_sop
           + c.neuron_updates * k.e_nu
           + (c.weight_bytes + c.state_bytes) * k.e_sram
           + c.bus_bytes * k.e_bus)
    p_static = k.p_static_fused if machine == "fused" else k.p_static_decoupled
    stat = p_static * t
    return {
        "dynamic_J": dyn,
        "static_J": stat,
        "total_J": dyn + stat,
        "avg_power_W": (dyn + stat) / t if t else 0.0,
        "time_s": t,
    }


def footprint(n_neurons: int, n_inputs: int) -> dict:
    """Table-2 analogue: storage footprint of the SNN state (bytes).

    FPGA LUT/FF/BRAM cannot be synthesized here; the architectural
    quantity that drives them is the state the SNNU must hold.
    """
    words = (n_inputs + 31) // 32
    return {
        "synapse_bytes": n_neurons * words * 4,
        "membrane_bytes": n_neurons * 4,
        "lfsr_bytes": n_neurons * words * 4,
        "spike_reg_bytes": words * 4,
    }
