"""16-bit Fibonacci LFSR, bit-exact with the Wenquxing 22A hardware PRNG.

The paper's LTD unit draws a random 10-bit number ``x`` from a 16-bit
LFSR each LTD decision and clears the synapse iff
``x <= ltd_probability``.  Hardware has a single LFSR; a data-parallel TPU
wants one independent stream per neuron lane, so every function here is
vectorized over a ``uint32`` array of per-lane 16-bit states (stored in
uint32 because TPUs have no native u16 ALU lanes; the high 16 bits are
kept zero).

Taps: x^16 + x^14 + x^13 + x^11 + 1 (the classic maximal-length 16-bit
polynomial, period 65535).  State 0 is absorbing and therefore forbidden;
seeding guards against it.
"""

from __future__ import annotations

import jax.numpy as jnp

# Feedback taps as right-shift amounts in the Fibonacci form
# (tap t of the polynomial reads register bit 16 - t).
_TAP_SHIFTS = (0, 2, 3, 5)  # taps 16, 14, 13, 11

LFSR_PERIOD = (1 << 16) - 1

# 32-bit golden-ratio constant; 0x9E37 (used by :func:`seed`) is its
# 16-bit truncation.  PHI32 and the odd mix constants below define the
# stateless counter draw shared by the host encoder oracle and the
# in-kernel encode path — both must use EXACTLY these constants.
PHI32 = 0x9E3779B9
_WEYL_IDX = 0x85EBCA6B     # odd, decorrelates the lane axis from time
_MIX1 = 0x7FEB352D         # xorshift-multiply finalizer ("lowbias32")
_MIX2 = 0x846CA68B


def seed(base: int, n: int) -> jnp.ndarray:
    """Produce ``n`` distinct nonzero 16-bit LFSR states from ``base``.

    Uses a Weyl sequence on the odd constant 0x9E37 (golden-ratio hash
    truncated to 16 bits) so lanes are decorrelated, then maps 0 -> 0xACE1
    (the traditional LFSR example seed) to avoid the absorbing state.
    """
    idx = jnp.arange(n, dtype=jnp.uint32)
    s = (jnp.uint32(base & 0xFFFF) + idx * jnp.uint32(0x9E37)) & jnp.uint32(0xFFFF)
    return jnp.where(s == 0, jnp.uint32(0xACE1), s)


def step(state: jnp.ndarray) -> jnp.ndarray:
    """Advance every lane one LFSR step.  state: uint32[..., n] -> same."""
    fb = jnp.zeros_like(state)
    for sh in _TAP_SHIFTS:
        fb = jnp.bitwise_xor(fb, jnp.right_shift(state, jnp.uint32(sh)))
    fb = jnp.bitwise_and(fb, jnp.uint32(1))
    return jnp.bitwise_and(
        jnp.bitwise_or(jnp.right_shift(state, jnp.uint32(1)),
                       jnp.left_shift(fb, jnp.uint32(15))),
        jnp.uint32(0xFFFF),
    )


def counter_hash(seed, cycle, idx) -> jnp.ndarray:
    """Stateless counter-based uint32 draw for (cycle, lane) pairs.

    A Weyl sequence over two axes — ``cycle`` steps by the golden-ratio
    constant :data:`PHI32`, ``idx`` by another odd constant — finalized
    with an xorshift-multiply mix, all in wrapping uint32 arithmetic.
    No carried PRNG state: any (seed, cycle, idx) triple can be drawn in
    isolation, so chunked and sharded kernel launches regenerate
    identical values without cross-launch or cross-shard broadcast.

    All three arguments broadcast; the result has their broadcast shape.
    The encode path consumes the low 8 bits (a spike fires iff
    ``hash & 0xFF < intensity``), so P(fire) = intensity / 256.
    """
    h = (jnp.asarray(seed, jnp.uint32)
         + jnp.asarray(cycle, jnp.uint32) * jnp.uint32(PHI32)
         + jnp.asarray(idx, jnp.uint32) * jnp.uint32(_WEYL_IDX))
    h = jnp.bitwise_xor(h, jnp.right_shift(h, jnp.uint32(16)))
    h = h * jnp.uint32(_MIX1)
    h = jnp.bitwise_xor(h, jnp.right_shift(h, jnp.uint32(15)))
    h = h * jnp.uint32(_MIX2)
    return jnp.bitwise_xor(h, jnp.right_shift(h, jnp.uint32(16)))


def draw10(state: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One LTD draw per lane: advance the LFSR, return (new_state, x).

    ``x`` is the low 10 bits of the new state, in [0, 1023], matching the
    paper's "random 10-bit number x ... compare with the LTD probability".
    """
    new = step(state)
    return new, jnp.bitwise_and(new, jnp.uint32(0x3FF))
