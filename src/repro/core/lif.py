"""Streamlined Leaky Integrate-and-Fire (LIF) — paper contribution C2.

The paper streamlines the standard LIF ODE into an integer datapath that
fits a single execution-stage cycle:

    V' = V + count            # integrate this cycle's valid-spike count
    fire = V' >= threshold
    V  <- 0           if fire            # hard reset
    V  <- max(V' - leak, 0)  otherwise   # single-subtraction leak, floor 0

``count`` is the SPU popcount output (non-negative).  All state is int32.
A teacher current (supervised learning, §3.1) is simply added to
``count`` before the update — the hardware injects it on the same adder.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class LIFParams(NamedTuple):
    threshold: jnp.ndarray  # int32 scalar or [n]
    leak: jnp.ndarray       # int32 scalar or [n]


def lif_params(threshold: int, leak: int) -> LIFParams:
    return LIFParams(jnp.int32(threshold), jnp.int32(leak))


def lif_step(v: jnp.ndarray, count: jnp.ndarray, p: LIFParams
             ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One streamlined-LIF cycle.

    v: int32[n] membrane potentials; count: int32[n] valid-spike counts
    (may include teacher current, possibly negative for inhibition).
    Returns (v_next int32[n], fired bool[n]).
    """
    v_int = v + count
    fired = v_int >= p.threshold
    v_next = jnp.where(
        fired,
        jnp.int32(0),
        jnp.maximum(v_int - p.leak, jnp.int32(0)),
    )
    return v_next, fired


def lif_reset(n: int) -> jnp.ndarray:
    """Fresh membrane state (the paper resets V between samples)."""
    return jnp.zeros((n,), jnp.int32)
