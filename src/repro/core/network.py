"""SNN network execution over the presentation window (paper §3.1 network).

The paper's network is a single fully-connected layer of LIF neurons fed
by Poisson-encoded input spikes; training is online (weights change every
cycle), inference counts output spikes over the presentation window.

Two execution strategies:

``cycle_backend="window"`` (default)
    One ``ops.fused_snn_window`` launch covers the whole T-cycle window:
    weights, membrane and LFSR state stay resident in VMEM while the
    (tiny) per-cycle spike words stream past — the TPU analogue of the
    paper's claim that the coarse-grained ``snn.step`` instruction keeps
    the SPU→NU→SU dataflow in-pipeline.  Requires concrete (non-traced)
    LIF/STDP parameters, since they lower as kernel literals.

``cycle_backend="step"``
    The original ``lax.scan`` of per-cycle ``snn_step`` calls.  Also the
    automatic fallback when parameters arrive as tracers (e.g. a caller
    jits this module with LIFParams as a runtime argument).

``kernel_backend`` selects the kernel implementation for the window path
("ref" = XLA scan oracle, "interp" = Pallas interpret, "tpu" = compiled).
``window_chunk`` streams the spike window through VMEM in fixed-size
slabs (kernel backends only; bit-exact with the unchunked launch), so T
is unbounded at bounded VMEM.

Batched training (``train_stream_batch``): B independent streams — one
batched :class:`SnnRegFile` (leading stream axis on every leaf) — train
in ONE kernel launch per presented sample via ``ops.train_window_batch``
instead of B sequential ``train_stream`` scans.  Stream b is bit-exact
with a sequential ``train_stream`` run from regfile b.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.lif import LIFParams
from repro.core.rvsnn import SnnRegFile, snn_regfile, snn_step
from repro.core.stdp import STDPParams
from repro.kernels import ops


class SNNOutput(NamedTuple):
    regfile: SnnRegFile
    spike_counts: jnp.ndarray  # int32[n] output spikes over the window
    fired: jnp.ndarray         # bool[T, n] raster


def _check_backend(cycle_backend: str) -> None:
    if cycle_backend not in ("window", "step"):
        raise ValueError(
            f"cycle_backend must be 'window' or 'step', got "
            f"{cycle_backend!r}")


def _static_int(x) -> int | None:
    """Concretize a parameter to a Python int, or None if traced."""
    try:
        return int(x)
    except (TypeError, jax.errors.ConcretizationTypeError):
        return None


def _window_params(lif: LIFParams, stdp: STDPParams | None):
    """Static kernel literals for the window path, or None if traced."""
    th, lk = _static_int(lif.threshold), _static_int(lif.leak)
    if th is None or lk is None:
        return None
    if stdp is None:
        # SU idle: the STDP literals are unused when train=False.
        return dict(threshold=th, leak=lk, w_exp=0, gain=0, n_syn=1,
                    ltp_prob=0, train=False)
    su = tuple(_static_int(x) for x in
               (stdp.w_exp, stdp.gain, stdp.n_syn, stdp.ltp_prob))
    if any(x is None for x in su):
        return None
    return dict(threshold=th, leak=lk, w_exp=su[0], gain=su[1],
                n_syn=su[2], ltp_prob=su[3], train=True)


def run_sample(
    rf: SnnRegFile,
    spike_train: jnp.ndarray,   # uint32[T, w] packed input spikes
    lif: LIFParams,
    stdp: STDPParams | None = None,
    teach: jnp.ndarray | None = None,
    *,
    cycle_backend: str = "window",
    kernel_backend: str = "ref",
    window_chunk: int | None = None,
) -> SNNOutput:
    """Present one sample for T cycles.  stdp=None -> inference."""
    _check_backend(cycle_backend)
    params = (_window_params(lif, stdp)
              if cycle_backend == "window" else None)
    if params is not None:
        teach_arr = (jnp.zeros_like(rf.v) if teach is None
                     else teach.astype(jnp.int32))
        w2, v2, fired, lf2 = ops.fused_snn_window(
            rf.weights, spike_train, rf.v, rf.lfsr, teach_arr,
            backend=kernel_backend, t_chunk=window_chunk, **params)
        rf_out = rf._replace(
            weights=w2, v=v2, lfsr=lf2,
            spike=spike_train[-1].astype(jnp.uint32))
        counts = jnp.sum(fired.astype(jnp.int32), axis=0)
        return SNNOutput(rf_out, counts, fired)

    def body(carry: SnnRegFile, words: jnp.ndarray):
        carry, fired = snn_step(carry, words, lif, stdp, teach)
        return carry, fired

    rf_out, fired = jax.lax.scan(body, rf, spike_train)
    counts = jnp.sum(fired.astype(jnp.int32), axis=0)
    return SNNOutput(rf_out, counts, fired)


def reset_between_samples(rf: SnnRegFile) -> SnnRegFile:
    """Clear membrane + spike registers, keep weights and LFSR (paper
    resets neuron state between digit presentations)."""
    return rf._replace(
        v=jnp.zeros_like(rf.v),
        spike=jnp.zeros_like(rf.spike),
    )


def infer_batch(
    weights: jnp.ndarray,       # uint32[n, w]
    spike_trains: jnp.ndarray,  # uint32[B, T, w]
    lif: LIFParams,
    *,
    cycle_backend: str = "window",
    kernel_backend: str = "ref",
    window_chunk: int | None = None,
) -> jnp.ndarray:
    """Spike counts int32[B, n] for a batch (weights frozen).

    The window path serves all B samples from ONE kernel launch with a
    batch grid dimension (weights fetched once per neuron block, reused
    across the batch) — the serving-throughput path.  The step path
    vmaps B independent per-cycle scans.
    """
    _check_backend(cycle_backend)
    params = (_window_params(lif, None)
              if cycle_backend == "window" else None)
    if params is not None:
        return ops.infer_window_batch(weights, spike_trains,
                                      threshold=params["threshold"],
                                      leak=params["leak"],
                                      t_chunk=window_chunk,
                                      backend=kernel_backend)
    rf0 = snn_regfile(weights)

    def one(train):
        return run_sample(reset_between_samples(rf0), train, lif,
                          cycle_backend="step").spike_counts

    return jax.vmap(one)(spike_trains)


def train_stream(
    rf: SnnRegFile,
    spike_trains: jnp.ndarray,  # uint32[N, T, w] pre-encoded samples
    teach: jnp.ndarray,         # int32[N, n] per-sample teacher currents
    lif: LIFParams,
    stdp: STDPParams,
    *,
    cycle_backend: str = "window",
    kernel_backend: str = "ref",
    window_chunk: int | None = None,
) -> tuple[SnnRegFile, jnp.ndarray]:
    """Online STDP over a stream of samples (sequential, as in hardware).

    Returns (rf', spike_counts int32[N, n]).
    """

    def body(carry: SnnRegFile, inp):
        train, tch = inp
        carry = reset_between_samples(carry)
        out = run_sample(carry, train, lif, stdp, tch,
                         cycle_backend=cycle_backend,
                         kernel_backend=kernel_backend,
                         window_chunk=window_chunk)
        return out.regfile, out.spike_counts

    return jax.lax.scan(body, rf, (spike_trains, teach))


def train_stream_batch(
    rfs: SnnRegFile,            # batched regfile (leading stream axis B)
    spike_trains: jnp.ndarray,  # uint32[B, N, T, w] per-stream samples
    teach: jnp.ndarray,         # int32[B, N, n] per-stream teachers
    lif: LIFParams,
    stdp: STDPParams,
    *,
    cycle_backend: str = "window",
    kernel_backend: str = "ref",
    window_chunk: int | None = None,
) -> tuple[SnnRegFile, jnp.ndarray]:
    """Online STDP over B independent streams, batched per launch.

    Each presented sample is ONE ``ops.train_window_batch`` launch
    covering all B streams (per-stream weights/v/LFSR regfiles), instead
    of B sequential :func:`train_stream` scans — the batched training
    grid.  Stream b is bit-exact (incl. its LFSR sequence) with
    ``train_stream(rf_b, spike_trains[b], teach[b], ...)``.

    LIF/STDP params are shared across streams (they lower as kernel
    literals).  Falls back to a vmap of per-cycle scans when params
    arrive traced or ``cycle_backend="step"``.

    Returns (rfs', spike_counts int32[B, N, n]).
    """
    _check_backend(cycle_backend)
    params = (_window_params(lif, stdp)
              if cycle_backend == "window" else None)
    # scan over the sample axis: [B, N, ...] -> [N, B, ...]
    trains_t = jnp.swapaxes(spike_trains, 0, 1)
    teach_t = jnp.swapaxes(teach, 0, 1)

    if params is not None:
        params = {k: v for k, v in params.items() if k != "train"}

        def body(carry: SnnRegFile, inp):
            trains, tch = inp
            w2, v2, fired, lf2 = ops.train_window_batch(
                carry.weights, trains, jnp.zeros_like(carry.v),
                carry.lfsr, tch.astype(jnp.int32),
                backend=kernel_backend, t_chunk=window_chunk, **params)
            carry = carry._replace(
                weights=w2, v=v2, lfsr=lf2,
                spike=trains[:, -1].astype(jnp.uint32))
            return carry, jnp.sum(fired.astype(jnp.int32), axis=1)

        rfs_out, counts = jax.lax.scan(body, rfs, (trains_t, teach_t))
        return rfs_out, jnp.swapaxes(counts, 0, 1)

    def body(carry: SnnRegFile, inp):
        trains, tch = inp

        def one(rf_b, train_b, tch_b):
            out = run_sample(reset_between_samples(rf_b), train_b, lif,
                             stdp, tch_b, cycle_backend="step")
            return out.regfile, out.spike_counts

        return jax.vmap(one)(carry, trains, tch)

    rfs_out, counts = jax.lax.scan(body, rfs, (trains_t, teach_t))
    return rfs_out, jnp.swapaxes(counts, 0, 1)
