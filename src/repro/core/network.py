"""SNN network execution over the presentation window (paper §3.1 network).

The paper's network is a single fully-connected layer of LIF neurons fed
by Poisson-encoded input spikes; training is online (weights change every
cycle), inference counts output spikes over the presentation window.

Two execution strategies:

``cycle_backend="window"`` (default)
    One ``ops.fused_snn_window`` launch covers the whole T-cycle window:
    weights, membrane and LFSR state stay resident in VMEM while the
    (tiny) per-cycle spike words stream past — the TPU analogue of the
    paper's claim that the coarse-grained ``snn.step`` instruction keeps
    the SPU→NU→SU dataflow in-pipeline.  Requires concrete (non-traced)
    LIF/STDP parameters, since they lower as kernel literals.

``cycle_backend="step"``
    The original ``lax.scan`` of per-cycle ``snn_step`` calls.  Also the
    automatic fallback when parameters arrive as tracers (e.g. a caller
    jits this module with LIFParams as a runtime argument).

``kernel_backend`` selects the kernel implementation for the window path
("ref" = XLA scan oracle, "interp" = Pallas interpret, "tpu" = compiled).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.lif import LIFParams
from repro.core.rvsnn import SnnRegFile, snn_regfile, snn_step
from repro.core.stdp import STDPParams
from repro.kernels import ops


class SNNOutput(NamedTuple):
    regfile: SnnRegFile
    spike_counts: jnp.ndarray  # int32[n] output spikes over the window
    fired: jnp.ndarray         # bool[T, n] raster


def _check_backend(cycle_backend: str) -> None:
    if cycle_backend not in ("window", "step"):
        raise ValueError(
            f"cycle_backend must be 'window' or 'step', got "
            f"{cycle_backend!r}")


def _static_int(x) -> int | None:
    """Concretize a parameter to a Python int, or None if traced."""
    try:
        return int(x)
    except (TypeError, jax.errors.ConcretizationTypeError):
        return None


def _window_params(lif: LIFParams, stdp: STDPParams | None):
    """Static kernel literals for the window path, or None if traced."""
    th, lk = _static_int(lif.threshold), _static_int(lif.leak)
    if th is None or lk is None:
        return None
    if stdp is None:
        # SU idle: the STDP literals are unused when train=False.
        return dict(threshold=th, leak=lk, w_exp=0, gain=0, n_syn=1,
                    ltp_prob=0, train=False)
    su = tuple(_static_int(x) for x in
               (stdp.w_exp, stdp.gain, stdp.n_syn, stdp.ltp_prob))
    if any(x is None for x in su):
        return None
    return dict(threshold=th, leak=lk, w_exp=su[0], gain=su[1],
                n_syn=su[2], ltp_prob=su[3], train=True)


def run_sample(
    rf: SnnRegFile,
    spike_train: jnp.ndarray,   # uint32[T, w] packed input spikes
    lif: LIFParams,
    stdp: STDPParams | None = None,
    teach: jnp.ndarray | None = None,
    *,
    cycle_backend: str = "window",
    kernel_backend: str = "ref",
) -> SNNOutput:
    """Present one sample for T cycles.  stdp=None -> inference."""
    _check_backend(cycle_backend)
    params = (_window_params(lif, stdp)
              if cycle_backend == "window" else None)
    if params is not None:
        teach_arr = (jnp.zeros_like(rf.v) if teach is None
                     else teach.astype(jnp.int32))
        w2, v2, fired, lf2 = ops.fused_snn_window(
            rf.weights, spike_train, rf.v, rf.lfsr, teach_arr,
            backend=kernel_backend, **params)
        rf_out = rf._replace(
            weights=w2, v=v2, lfsr=lf2,
            spike=spike_train[-1].astype(jnp.uint32))
        counts = jnp.sum(fired.astype(jnp.int32), axis=0)
        return SNNOutput(rf_out, counts, fired)

    def body(carry: SnnRegFile, words: jnp.ndarray):
        carry, fired = snn_step(carry, words, lif, stdp, teach)
        return carry, fired

    rf_out, fired = jax.lax.scan(body, rf, spike_train)
    counts = jnp.sum(fired.astype(jnp.int32), axis=0)
    return SNNOutput(rf_out, counts, fired)


def reset_between_samples(rf: SnnRegFile) -> SnnRegFile:
    """Clear membrane + spike registers, keep weights and LFSR (paper
    resets neuron state between digit presentations)."""
    return rf._replace(
        v=jnp.zeros_like(rf.v),
        spike=jnp.zeros_like(rf.spike),
    )


def infer_batch(
    weights: jnp.ndarray,       # uint32[n, w]
    spike_trains: jnp.ndarray,  # uint32[B, T, w]
    lif: LIFParams,
    *,
    cycle_backend: str = "window",
    kernel_backend: str = "ref",
) -> jnp.ndarray:
    """Spike counts int32[B, n] for a batch (weights frozen).

    The window path serves all B samples from ONE kernel launch with a
    batch grid dimension (weights fetched once per neuron block, reused
    across the batch) — the serving-throughput path.  The step path
    vmaps B independent per-cycle scans.
    """
    _check_backend(cycle_backend)
    params = (_window_params(lif, None)
              if cycle_backend == "window" else None)
    if params is not None:
        return ops.infer_window_batch(weights, spike_trains,
                                      threshold=params["threshold"],
                                      leak=params["leak"],
                                      backend=kernel_backend)
    rf0 = snn_regfile(weights)

    def one(train):
        return run_sample(reset_between_samples(rf0), train, lif,
                          cycle_backend="step").spike_counts

    return jax.vmap(one)(spike_trains)


def train_stream(
    rf: SnnRegFile,
    spike_trains: jnp.ndarray,  # uint32[N, T, w] pre-encoded samples
    teach: jnp.ndarray,         # int32[N, n] per-sample teacher currents
    lif: LIFParams,
    stdp: STDPParams,
    *,
    cycle_backend: str = "window",
    kernel_backend: str = "ref",
) -> tuple[SnnRegFile, jnp.ndarray]:
    """Online STDP over a stream of samples (sequential, as in hardware).

    Returns (rf', spike_counts int32[N, n]).
    """

    def body(carry: SnnRegFile, inp):
        train, tch = inp
        carry = reset_between_samples(carry)
        out = run_sample(carry, train, lif, stdp, tch,
                         cycle_backend=cycle_backend,
                         kernel_backend=kernel_backend)
        return out.regfile, out.spike_counts

    return jax.lax.scan(body, rf, (spike_trains, teach))
