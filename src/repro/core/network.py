"""SNN network execution over the presentation window (paper §3.1).

.. deprecated::
    This module is a thin compatibility shim over the unified engine in
    :mod:`repro.engine` — build an
    :class:`~repro.engine.SNNEnginePlan` and speak the engine's three
    verbs (``infer`` / ``train`` / ``train_batch``) instead of threading
    ``cycle_backend``/``kernel_backend``/``window_chunk`` kwargs through
    these functions.  The wrappers stay byte-identical with the
    pre-engine implementations (see ``repro.engine`` for the migration
    table), so existing callers keep working unchanged.

The only logic that still lives here is the traced-parameter fallback:
engine plans hold concrete Python ints, so when a caller jits one of
these wrappers with ``LIFParams``/``STDPParams`` as runtime arguments
(tracers), the window path cannot lower them as kernel literals and the
wrapper drops to the original per-cycle ``lax.scan`` of ``snn_step``
calls.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.lif import LIFParams
from repro.core.rvsnn import SnnRegFile, snn_regfile, snn_step
from repro.core.stdp import STDPParams
from repro.engine import SNNEngine, SNNEnginePlan, SNNOutput
from repro.engine import engine as _engine
from repro.engine import reset_between_samples  # noqa: F401  (re-export)

__all__ = ["SNNOutput", "run_sample", "reset_between_samples",
           "infer_batch", "train_stream", "train_stream_batch"]


def _check_backend(cycle_backend: str) -> None:
    if cycle_backend not in ("window", "step"):
        raise ValueError(
            f"cycle_backend must be 'window' or 'step', got "
            f"{cycle_backend!r}")


def _static_int(x) -> int | None:
    """Concretize a parameter to a Python int, or None if traced."""
    try:
        return int(x)
    except (TypeError, jax.errors.ConcretizationTypeError):
        return None


def _make_plan(lif: LIFParams, stdp: STDPParams | None,
               kernel_backend: str, window_chunk: int | None
               ) -> SNNEnginePlan | None:
    """An engine plan from (possibly traced) params, or None if traced."""
    th, lk = _static_int(lif.threshold), _static_int(lif.leak)
    if th is None or lk is None:
        return None
    if stdp is None:
        return SNNEnginePlan(threshold=th, leak=lk, w_exp=None,
                             kernel_backend=kernel_backend,
                             t_chunk=window_chunk)
    su = tuple(_static_int(x) for x in
               (stdp.w_exp, stdp.gain, stdp.n_syn, stdp.ltp_prob))
    if any(x is None for x in su):
        return None
    return SNNEnginePlan(threshold=th, leak=lk, w_exp=su[0], gain=su[1],
                         n_syn=su[2], ltp_prob=su[3],
                         kernel_backend=kernel_backend,
                         t_chunk=window_chunk)


def run_sample(
    rf: SnnRegFile,
    spike_train: jnp.ndarray,   # uint32[T, w] packed input spikes
    lif: LIFParams,
    stdp: STDPParams | None = None,
    teach: jnp.ndarray | None = None,
    *,
    cycle_backend: str = "window",
    kernel_backend: str = "ref",
    window_chunk: int | None = None,
) -> SNNOutput:
    """Present one sample for T cycles.  stdp=None -> inference."""
    _check_backend(cycle_backend)
    plan = (_make_plan(lif, stdp, kernel_backend, window_chunk)
            if cycle_backend == "window" else None)
    if plan is not None:
        return SNNEngine(plan).train(rf, spike_train, teach)

    def body(carry: SnnRegFile, words: jnp.ndarray):
        carry, fired = snn_step(carry, words, lif, stdp, teach)
        return carry, fired

    rf_out, fired = jax.lax.scan(body, rf, spike_train)
    counts = jnp.sum(fired.astype(jnp.int32), axis=0)
    return SNNOutput(rf_out, counts, fired)


def infer_batch(
    weights: jnp.ndarray,       # uint32[n, w]
    spike_trains: jnp.ndarray,  # uint32[B, T, w]
    lif: LIFParams,
    *,
    cycle_backend: str = "window",
    kernel_backend: str = "ref",
    window_chunk: int | None = None,
) -> jnp.ndarray:
    """Spike counts int32[B, n] for a batch (weights frozen).

    Shim over :meth:`SNNEngine.infer`: the window path serves all B
    samples from ONE kernel launch; the step path (and the traced-lif
    fallback) vmaps B per-cycle scans.
    """
    _check_backend(cycle_backend)
    plan = (_make_plan(lif, None, kernel_backend, window_chunk)
            if cycle_backend == "window" else None)
    if plan is not None:
        return SNNEngine(plan).infer(weights, spike_trains)
    rf0 = snn_regfile(weights)

    def one(train):
        return run_sample(reset_between_samples(rf0), train, lif,
                          cycle_backend="step").spike_counts

    return jax.vmap(one)(spike_trains)


def train_stream(
    rf: SnnRegFile,
    spike_trains: jnp.ndarray,  # uint32[N, T, w] pre-encoded samples
    teach: jnp.ndarray,         # int32[N, n] per-sample teacher currents
    lif: LIFParams,
    stdp: STDPParams,
    *,
    cycle_backend: str = "window",
    kernel_backend: str = "ref",
    window_chunk: int | None = None,
) -> tuple[SnnRegFile, jnp.ndarray]:
    """Online STDP over a stream of samples (sequential, as in hardware).

    Shim over :func:`repro.engine.train_stream`.  Returns
    (rf', spike_counts int32[N, n]).
    """
    _check_backend(cycle_backend)
    plan = (_make_plan(lif, stdp, kernel_backend, window_chunk)
            if cycle_backend == "window" else None)
    if plan is not None:
        return _engine.train_stream(SNNEngine(plan), rf, spike_trains,
                                    teach)

    def body(carry: SnnRegFile, inp):
        train, tch = inp
        carry = reset_between_samples(carry)
        out = run_sample(carry, train, lif, stdp, tch,
                         cycle_backend=cycle_backend,
                         kernel_backend=kernel_backend,
                         window_chunk=window_chunk)
        return out.regfile, out.spike_counts

    return jax.lax.scan(body, rf, (spike_trains, teach))


def train_stream_batch(
    rfs: SnnRegFile,            # batched regfile (leading stream axis B)
    spike_trains: jnp.ndarray,  # uint32[B, N, T, w] per-stream samples
    teach: jnp.ndarray,         # int32[B, N, n] per-stream teachers
    lif: LIFParams,
    stdp: STDPParams,
    *,
    cycle_backend: str = "window",
    kernel_backend: str = "ref",
    window_chunk: int | None = None,
) -> tuple[SnnRegFile, jnp.ndarray]:
    """Online STDP over B independent streams, batched per launch.

    Shim over :func:`repro.engine.train_stream_batch` (one
    ``train_window_batch`` launch per presented sample).  Stream b is
    bit-exact (incl. its LFSR sequence) with
    ``train_stream(rf_b, spike_trains[b], teach[b], ...)``.  Falls back
    to a vmap of per-cycle scans when params arrive traced or
    ``cycle_backend="step"``.

    Returns (rfs', spike_counts int32[B, N, n]).
    """
    _check_backend(cycle_backend)
    plan = (_make_plan(lif, stdp, kernel_backend, window_chunk)
            if cycle_backend == "window" else None)
    if plan is not None:
        return _engine.train_stream_batch(SNNEngine(plan), rfs,
                                          spike_trains, teach)

    # scan over the sample axis: [B, N, ...] -> [N, B, ...]
    trains_t = jnp.swapaxes(spike_trains, 0, 1)
    teach_t = jnp.swapaxes(teach, 0, 1)

    def body(carry: SnnRegFile, inp):
        trains, tch = inp

        def one(rf_b, train_b, tch_b):
            out = run_sample(reset_between_samples(rf_b), train_b, lif,
                             stdp, tch_b, cycle_backend="step")
            return out.regfile, out.spike_counts

        return jax.vmap(one)(carry, trains, tch)

    rfs_out, counts = jax.lax.scan(body, rfs, (trains_t, teach_t))
    return rfs_out, jnp.swapaxes(counts, 0, 1)
