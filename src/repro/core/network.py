"""SNN network execution: lax.scan over time cycles (paper §3.1 network).

The paper's network is a single fully-connected layer of LIF neurons fed
by Poisson-encoded input spikes; training is online (weights change every
cycle), inference counts output spikes over the presentation window.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.lif import LIFParams
from repro.core.rvsnn import SnnRegFile, snn_regfile, snn_step
from repro.core.stdp import STDPParams


class SNNOutput(NamedTuple):
    regfile: SnnRegFile
    spike_counts: jnp.ndarray  # int32[n] output spikes over the window
    fired: jnp.ndarray         # bool[T, n] raster


def run_sample(
    rf: SnnRegFile,
    spike_train: jnp.ndarray,   # uint32[T, w] packed input spikes
    lif: LIFParams,
    stdp: STDPParams | None = None,
    teach: jnp.ndarray | None = None,
) -> SNNOutput:
    """Present one sample for T cycles.  stdp=None -> inference."""

    def body(carry: SnnRegFile, words: jnp.ndarray):
        carry, fired = snn_step(carry, words, lif, stdp, teach)
        return carry, fired

    rf_out, fired = jax.lax.scan(body, rf, spike_train)
    counts = jnp.sum(fired.astype(jnp.int32), axis=0)
    return SNNOutput(rf_out, counts, fired)


def reset_between_samples(rf: SnnRegFile) -> SnnRegFile:
    """Clear membrane + spike registers, keep weights and LFSR (paper
    resets neuron state between digit presentations)."""
    return rf._replace(
        v=jnp.zeros_like(rf.v),
        spike=jnp.zeros_like(rf.spike),
    )


def infer_batch(
    weights: jnp.ndarray,       # uint32[n, w]
    spike_trains: jnp.ndarray,  # uint32[B, T, w]
    lif: LIFParams,
) -> jnp.ndarray:
    """Spike counts int32[B, n] for a batch (weights frozen, vmapped)."""
    rf0 = snn_regfile(weights)

    def one(train):
        return run_sample(reset_between_samples(rf0), train, lif).spike_counts

    return jax.vmap(one)(spike_trains)


def train_stream(
    rf: SnnRegFile,
    spike_trains: jnp.ndarray,  # uint32[N, T, w] pre-encoded samples
    teach: jnp.ndarray,         # int32[N, n] per-sample teacher currents
    lif: LIFParams,
    stdp: STDPParams,
) -> tuple[SnnRegFile, jnp.ndarray]:
    """Online STDP over a stream of samples (sequential, as in hardware).

    Returns (rf', spike_counts int32[N, n]).
    """

    def body(carry: SnnRegFile, inp):
        train, tch = inp
        carry = reset_between_samples(carry)
        out = run_sample(carry, train, lif, stdp, tch)
        return out.regfile, out.spike_counts

    return jax.lax.scan(body, rf, (spike_trains, teach))
