"""MNIST-style preprocessing from the paper §3.1: deskew + soft threshold.

Both are "common practices for small networks" (paper's words) and are
executed on-processor in Wenquxing 22A; here they are pure-jnp image ops
applied before Poisson encoding.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _image_moments(img: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Centroid and second-order row/col covariance of a 2-D image."""
    h, w = img.shape
    total = jnp.sum(img) + 1e-6
    ys = jnp.arange(h, dtype=jnp.float32)[:, None]
    xs = jnp.arange(w, dtype=jnp.float32)[None, :]
    cy = jnp.sum(ys * img) / total
    cx = jnp.sum(xs * img) / total
    # mixed moment / row variance -> shear coefficient
    mu_yy = jnp.sum((ys - cy) ** 2 * img) / total
    mu_xy = jnp.sum((ys - cy) * (xs - cx) * img) / total
    return cy, cx, mu_xy / (mu_yy + 1e-6)


def deskew(img: jnp.ndarray) -> jnp.ndarray:
    """Shear the image so its principal vertical axis is upright.

    Classic MNIST deskew: estimate the shear ``alpha`` from image moments
    and resample ``x' = x + alpha * (y - cy)`` with bilinear interpolation.
    img: float32[h, w] in [0, 1].
    """
    h, w = img.shape
    cy, cx, alpha = _image_moments(img)
    ys = jnp.arange(h, dtype=jnp.float32)[:, None]
    xs = jnp.arange(w, dtype=jnp.float32)[None, :]
    src_x = xs + alpha * (ys - cy)
    x0 = jnp.floor(src_x)
    frac = src_x - x0
    x0i = jnp.clip(x0.astype(jnp.int32), 0, w - 1)
    x1i = jnp.clip(x0i + 1, 0, w - 1)
    rows = jnp.broadcast_to(jnp.arange(h)[:, None], (h, w))
    left = img[rows, x0i]
    right = img[rows, x1i]
    out = left * (1.0 - frac) + right * frac
    inb = (src_x >= 0) & (src_x <= w - 1)
    return jnp.where(inb, out, 0.0)


def soft_threshold(img: jnp.ndarray, thresh: float = 0.1) -> jnp.ndarray:
    """Soft-threshold shrinkage: max(x - t, 0) rescaled back to [0, 1]."""
    out = jnp.maximum(img - thresh, 0.0)
    return out / (1.0 - thresh)


def preprocess(img: jnp.ndarray, thresh: float = 0.1) -> jnp.ndarray:
    """Full paper pipeline: deskew then soft threshold.  [h,w] -> [h,w]."""
    return soft_threshold(deskew(img), thresh)


preprocess_batch = jax.vmap(preprocess, in_axes=(0, None))
