"""RV-SNN V1.0 — the paper's SNN instruction set, reified as JAX ops.

Wenquxing 22A extends NutShell's execution stage with an SNN unit (SNNU)
containing the Spike Process Unit (SPU), Neuron Unit (NU) and Synapse
Unit (SU = LTP + LTD), plus an *SNN special register file* next to the
GPRs.  The paper stresses **high computational granularity**: one
instruction performs a whole neuron-row's worth of work so the in-order
pipeline is not stalled by long µop sequences.

This module is the "toolchain" layer: each instruction is a pure JAX
function over an :class:`SnnRegFile`, with the same operand granularity
the hardware has.  The Pallas kernels in ``repro.kernels`` are the TPU
microarchitecture of the same instructions (see DESIGN.md §2); everything
here is the architectural (ISA-level) reference.

Instruction summary (names follow the unit that executes them; the
public paper does not print the exact mnemonics, so these are
reconstructed from §2.2 and flagged as such in DESIGN.md §7):

=============  ====  =====================================================
mnemonic       unit  semantics
=============  ====  =====================================================
``snn.ls``     SPU   load a packed spike vector into the spike register
``snn.sp``     SPU   AND spike reg with a synapse row block, popcount ->
                     valid-spike counts
``snn.nu``     NU    streamlined-LIF update of membrane registers
``snn.su``     SU    single-pass LTP+LTD synapse row update (uses the
                     LFSR register)
``snn.step``   SNNU  fused sp+nu+su for a whole population — the
                     coarse-granularity instruction the paper's speedup
                     comes from
=============  ====  =====================================================
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core import lfsr as _lfsr
from repro.core.bitpack import popcount
from repro.core.lif import LIFParams, lif_step
from repro.core.stdp import STDPParams, stdp_update


class SnnRegFile(NamedTuple):
    """The SNN special register file (paper Fig. 2).

    spike:   uint32[w]      packed input spike vector (spike register)
    v:       int32[n]       membrane potentials (neuron registers)
    lfsr:    uint32[n, w]   PRNG lanes (LFSR register, vectorized)
    weights: uint32[n, w]   packed 1-bit synapse rows (synapse memory —
                            architecturally a register-addressed SRAM)
    """
    spike: jnp.ndarray
    v: jnp.ndarray
    lfsr: jnp.ndarray
    weights: jnp.ndarray


def snn_regfile(weights: jnp.ndarray, seed: int = 0x22A) -> SnnRegFile:
    n, w = weights.shape
    return SnnRegFile(
        spike=jnp.zeros((w,), jnp.uint32),
        v=jnp.zeros((n,), jnp.int32),
        lfsr=_lfsr.seed(seed, n * w).reshape(n, w),
        weights=weights,
    )


def snn_regfile_batch(weights: jnp.ndarray, seeds) -> SnnRegFile:
    """B independent register files as one batched SnnRegFile.

    weights: uint32[B, n, w]; seeds: B per-stream LFSR base seeds.
    Every leaf gains a leading stream axis; stream b is exactly
    ``snn_regfile(weights[b], seeds[b])``, so batched execution can be
    checked bit-exactly against B sequential regfiles.
    """
    b, n, w = weights.shape
    if len(seeds) != b:
        raise ValueError(f"need {b} seeds, got {len(seeds)}")
    return SnnRegFile(
        spike=jnp.zeros((b, w), jnp.uint32),
        v=jnp.zeros((b, n), jnp.int32),
        lfsr=jnp.stack([_lfsr.seed(int(s), n * w).reshape(n, w)
                        for s in seeds]),
        weights=weights,
    )


# --- SPU ------------------------------------------------------------------

def snn_ls(rf: SnnRegFile, spike_words: jnp.ndarray) -> SnnRegFile:
    """``snn.ls`` — latch a packed spike vector into the spike register."""
    return rf._replace(spike=spike_words.astype(jnp.uint32))


def snn_sp(rf: SnnRegFile) -> jnp.ndarray:
    """``snn.sp`` — valid-spike counts: popcount(spike & weights) per row."""
    return popcount(jnp.bitwise_and(rf.spike[None, :], rf.weights))


# --- NU -------------------------------------------------------------------

def snn_nu(rf: SnnRegFile, counts: jnp.ndarray, p: LIFParams
           ) -> tuple[SnnRegFile, jnp.ndarray]:
    """``snn.nu`` — streamlined-LIF membrane update; returns fired mask."""
    v_next, fired = lif_step(rf.v, counts, p)
    return rf._replace(v=v_next), fired


# --- SU -------------------------------------------------------------------

def snn_su(rf: SnnRegFile, fired: jnp.ndarray, p: STDPParams) -> SnnRegFile:
    """``snn.su`` — binary stochastic STDP row update on post-spikes."""
    w_out, lf_out = stdp_update(rf.weights, rf.spike, fired, rf.lfsr, p)
    return rf._replace(weights=w_out, lfsr=lf_out)


# --- fused SNNU step --------------------------------------------------------

def snn_step(
    rf: SnnRegFile,
    spike_words: jnp.ndarray,
    lif: LIFParams,
    stdp: STDPParams | None,
    teach: jnp.ndarray | None = None,
) -> tuple[SnnRegFile, jnp.ndarray]:
    """``snn.step`` — one fused SNNU cycle for the whole population.

    spike_words: uint32[w] this cycle's packed input spikes.
    teach:       optional int32[n] supervised teacher current added on the
                 NU adder (positive drives the labeled neuron, negative
                 inhibits the rest).
    stdp:        None => inference only (SU idle).
    Returns (rf', fired bool[n]).
    """
    rf = snn_ls(rf, spike_words)
    counts = snn_sp(rf)
    if teach is not None:
        counts = counts + teach
    rf, fired = snn_nu(rf, counts, lif)
    if stdp is not None:
        rf = snn_su(rf, fired, stdp)
    return rf, fired
