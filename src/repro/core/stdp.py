"""Binary stochastic STDP — paper contribution C3.

Semantics reconstructed from §2.2 of the paper (SU = LTP unit + LTD
unit), geared to "single cycle updating of synaptic weights":

On a post-synaptic spike of neuron ``i`` (and only then):

* **LTP** (deterministic): every synapse whose pre-synaptic input spiked
  this cycle is set to 1 — ``w[i] |= pre_spikes``.
* **LTD** (stochastic): a 10-bit draw ``x`` from a 16-bit LFSR is
  compared against ``ltd_prob``; if ``x <= ltd_prob`` the non-coincident
  synapses are cleared — ``w[i] &= pre_spikes`` for the words whose draw
  passed.

Granularity assumption (recorded in DESIGN.md §7): hardware holds one
LFSR; updating a 784-synapse row in one cycle cannot draw 784 independent
numbers, so the depress decision is made **per 32-synapse word**, one
LFSR lane per (neuron, word).  This preserves the paper's dynamics — the
expected fraction of non-coincident synapses cleared per post-spike is
``p_ltd`` — while mapping 1:1 onto packed uint32 lanes.

``w_exp`` (paper §3.3, values {128, 256, 512}) "affects the number of
effective synapses that ultimately remain by changing the LTD
probability".  We implement that statement directly as a homeostatic
rule: the LTD probability of a row grows with the excess of its ON-count
over the ``w_exp`` budget (the SPU already produces row popcounts, so
this costs the hardware one subtract+clamp):

    p_ltd(row) = clamp((popcount(row) - w_exp) * gain * 1024 / n_syn,
                       0, 1023) / 1024

At equilibrium each row keeps ~``w_exp`` synapses — the ones most
frequently coincident with the neuron's post-spikes — which also
equalizes rows for the output argmax competition.  Higher ``w_exp`` =>
lower LTD pressure => more synapses survive, exactly the paper's knob.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

import jax.lax as lax

from repro.core import lfsr as _lfsr


class STDPParams(NamedTuple):
    w_exp: jnp.ndarray     # int32: effective-synapse budget {128,256,512}
    gain: jnp.ndarray      # int32: homeostatic gain (LTD slope)
    n_syn: jnp.ndarray     # int32: synapses per row (for normalization)
    ltp_prob: jnp.ndarray  # uint32: 10-bit stochastic-LTP probability


def stdp_params(n_syn: int, w_exp: int, gain: int = 4,
                ltp_prob: int = 1023) -> STDPParams:
    """ltp_prob < 1023 slows acquisition (stochastic LTP a la Yousefzadeh
    2018 [13], the paper's 1-bit STDP reference): a potentiation event
    only fires with probability (ltp_prob+1)/1024, so the learned row is
    a long-horizon average over samples instead of a copy of the most
    recent one."""
    return STDPParams(jnp.int32(w_exp), jnp.int32(gain), jnp.int32(n_syn),
                      jnp.uint32(ltp_prob))


def ltd_prob(row_popcount: jnp.ndarray, p: STDPParams) -> jnp.ndarray:
    """Homeostatic 10-bit LTD probability per row.  int32[n] -> uint32[n]."""
    excess = (row_popcount - p.w_exp) * p.gain * 1024 // p.n_syn
    return jnp.clip(excess, 0, 1023).astype(jnp.uint32)


def ltd_prob_from_wexp(n_syn: int, w_exp: int, popcount: int | None = None,
                       gain: int = 4) -> int:
    """Scalar helper (tests/benchmarks): LTD prob for a given ON-count."""
    pc = n_syn if popcount is None else popcount
    return int(min(1023, max(0, (pc - w_exp) * gain * 1024 // n_syn)))


def stdp_update(
    weights: jnp.ndarray,      # uint32[n, w] packed 1-bit synapses
    pre_spikes: jnp.ndarray,   # uint32[w] packed spike vector (this cycle)
    post_fired: jnp.ndarray,   # bool[n]
    lfsr_state: jnp.ndarray,   # uint32[n, w] per-lane LFSR states
    p: STDPParams,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Single-pass LTP+LTD row update.  Returns (weights', lfsr_state').

    The LFSR advances only for rows whose neuron fired, matching hardware
    (the SU is clocked per post-spike event).
    """
    fired_u = post_fired[:, None]  # [n, 1] broadcast over words
    # Two LFSR draws per update event: one for LTP, one for LTD (the
    # hardware clocks the LFSR twice per SU op; see DESIGN.md §7).
    s1, x_ltp = _lfsr.draw10(lfsr_state)
    s2, x_ltd = _lfsr.draw10(s1)
    lfsr_out = jnp.where(fired_u, s2, lfsr_state)

    potentiate = x_ltp <= p.ltp_prob  # bool[n, w]
    ltp = jnp.where(potentiate,
                    jnp.bitwise_or(weights, pre_spikes[None, :]), weights)
    pc = jnp.sum(lax.population_count(ltp).astype(jnp.int32), axis=-1)
    prob = ltd_prob(pc, p)  # uint32[n]
    depress = x_ltd <= prob[:, None]  # bool[n, w], one decision per word
    ltd = jnp.where(depress, jnp.bitwise_and(ltp, pre_spikes[None, :]), ltp)
    w_out = jnp.where(fired_u, ltd, weights)
    return w_out, lfsr_out


def init_weights(n_neurons: int, n_words: int, density_seed: int = 0,
                 dense: bool = True) -> jnp.ndarray:
    """Initial synaptic matrix.  The paper starts from all-ON rows (LTP
    only ever sets bits; learning proceeds by stochastic pruning), which
    ``dense=True`` reproduces; ``dense=False`` gives a ~50% random init
    for ablations."""
    if dense:
        return jnp.full((n_neurons, n_words), 0xFFFFFFFF, jnp.uint32)
    s = _lfsr.seed(density_seed ^ 0xBEEF, n_neurons * n_words)
    s = _lfsr.step(_lfsr.step(s))
    lo = jnp.bitwise_and(s, jnp.uint32(0xFFFF))
    hi = jnp.left_shift(_lfsr.step(s) & jnp.uint32(0xFFFF), jnp.uint32(16))
    return jnp.bitwise_or(hi, lo).reshape(n_neurons, n_words)
