"""Supervised STDP trainer + "Active learning" (paper §3.1).

10-neuron network: one neuron per digit class; a teacher current drives
the labeled neuron while the others are held at low activity (inhibited).

>10-neuron networks ("Active learning"): train 10 neurons, evaluate on
the training set, collect the misclassified samples, then train a fresh
block of 10 neurons *on the error samples only*, supervised by their
labels; repeat until the target population size.  Classification is by
the class of the maximally-firing neuron across all blocks.

``train_mode="parallel"`` instead trains ALL blocks concurrently on the
full training set — one ``engine.train_batch`` launch per presented
sample covers every block (per-block weights/v/LFSR regfiles,
decorrelated by per-block LFSR seeds) — trading the active-learning
curriculum for a B-way batched training grid.  ``ltp_prob`` rides along
as a per-stream SMEM scalar operand, so block 0 trains at the base
``ltp_prob`` while blocks >= 1 keep the faster ``ltp_prob_active``
schedule, exactly as in active mode.

Ingestion is intensity-resident when ``encode="kernel"``: the dataset
is quantized ONCE to uint8[N, n_inputs] and stays that way — per-sample
seeds come from the counter hash (:func:`encoder.sample_seeds`) and
every presentation draws its spike window inside the window kernel, so
the N×T×w spike tensor never exists (n_inputs bytes/sample instead of
T*w*4 — T/8×, 16× at T=128).  ``encode="host"`` (the default) keeps
the legacy statistical pre-encode (``poisson_encode_batch`` with the
JAX PRNG) as the fallback path.

Placement: ``mesh_shape=(data, neurons)`` shards every engine launch
over a 2-D mesh — the block-stream/batch axis over "data", neuron rows
over "neurons" — making ``train_mode="parallel"`` a data-parallel sweep
whose weights never leave their devices.  Any factorization is
bit-exact with the unsharded run.

Execution (kernel path, backend, chunking, placement) is owned by the
unified engine: ``SNNTrainConfig.plan()`` builds the
:class:`~repro.engine.SNNEnginePlan` and everything below drives
:class:`~repro.engine.SNNEngine` verbs.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bitpack import n_words
from repro.core.encoder import (poisson_encode_batch,
                                quantize_intensities, sample_seeds,
                                sample_seeds_at)
from repro.core.lif import LIFParams, lif_params
from repro.core.rvsnn import snn_regfile, snn_regfile_batch
from repro.core.stdp import STDPParams, init_weights, stdp_params
from repro.engine import SNNEngine, plan_from_config
from repro.engine import engine as _engine


@dataclass(frozen=True)
class SNNTrainConfig:
    n_inputs: int = 784
    n_classes: int = 10
    n_neurons: int = 40          # total population (multiple of n_classes)
    n_steps: int = 72            # presentation window T (cycles/sample)
    threshold: int = 192         # streamlined-LIF firing threshold
    leak: int = 16               # per-cycle leak
    w_exp: int = 128             # paper meta-parameter {128, 256, 512}
    gain: int = 4                # homeostatic LTD slope
    ltp_prob: int = 16           # 10-bit stochastic-LTP prob (base block)
    ltp_prob_active: int = 1023  # faster LTP for active-learning blocks
                                 # (few, hard samples -> specialize)
    teach_pos: int = 64          # teacher current into the labeled neuron
    teach_neg: int = -1024       # inhibition into the others
    epochs: int = 2
    seed: int = 0x22A
    cycle_backend: str = "window"   # "window" (time-resident) | "step"
    kernel_backend: str = "ref"     # "ref" | "interp" | "tpu"
    train_mode: str = "active"      # "active" (sequential blocks on the
                                    # error set) | "parallel" (batched
                                    # training grid, all blocks at once)
    window_chunk: int | None = None  # VMEM spike-slab size (None = T)
    encode: str = "host"             # dataset ingestion: "host" keeps
                                     # the legacy JAX-PRNG pre-encode;
                                     # "kernel" holds uint8 intensities
                                     # and draws spikes in VMEM
    encode_seed: int = 0             # counter base for the in-kernel draw
    mesh_shape: tuple | None = None  # (data, neurons) 2-D placement of
                                     # every engine launch (None = local)

    @property
    def n_blocks(self) -> int:
        assert self.n_neurons % self.n_classes == 0
        return self.n_neurons // self.n_classes

    @property
    def words(self) -> int:
        return n_words(self.n_inputs)

    def lif(self) -> LIFParams:
        return lif_params(self.threshold, self.leak)

    def stdp(self, block_idx: int = 0) -> STDPParams:
        lp = self.ltp_prob if block_idx == 0 else self.ltp_prob_active
        return stdp_params(self.n_inputs, self.w_exp, self.gain, lp)

    def plan(self, block_idx: int = 0, mesh=None):
        """The engine execution plan this config describes."""
        return plan_from_config(self, block_idx, mesh)


@dataclass
class SNNModel:
    """Trained population: packed weights + per-neuron class labels."""
    weights: jnp.ndarray           # uint32[n_neurons, w]
    neuron_class: jnp.ndarray      # int32[n_neurons]
    cfg: SNNTrainConfig = field(repr=False, default=None)


def _teacher(labels: jnp.ndarray, cfg: SNNTrainConfig) -> jnp.ndarray:
    """int32[N, n_classes] teacher currents for a 10-neuron block."""
    onehot = jax.nn.one_hot(labels, cfg.n_classes, dtype=jnp.int32)
    return onehot * cfg.teach_pos + (1 - onehot) * cfg.teach_neg


def _regfile_seed(key: jax.Array) -> int:
    """Fold a PRNG key into a nonzero 16-bit LFSR base seed."""
    return int(jax.random.randint(key, (), 1, 1 << 16))


def _train_block(cfg: SNNTrainConfig, key: jax.Array,
                 labels: jnp.ndarray, block_idx: int, *,
                 spike_trains: jnp.ndarray | None = None,
                 intensities: jnp.ndarray | None = None,
                 sample_idx: jnp.ndarray | None = None) -> jnp.ndarray:
    """Train one 10-neuron block online over (possibly repeated) samples.

    The sample stream is EITHER pre-encoded ``spike_trains``
    uint32[N, T, w] (``encode="host"``) OR uint8 ``intensities``
    [N, n_inputs] with their original dataset indices ``sample_idx``
    i32[N] — the intensity-resident path, where each presentation's
    window is drawn from the counter hash at use.  Counter seeds are
    epoch-keyed (``sample_seeds_at(encode_seed, idx, epoch)``), so each
    epoch re-presents the same samples with fresh Poisson draws at zero
    memory cost; epoch 0 is bit-exact with the historical derivation.
    ``key`` seeds the block's LFSR lanes (stochastic-STDP randomness),
    so per-block randomness is keyed; the default ``train()`` key chain
    is derived from ``cfg.seed``, keeping default-seed runs
    reproducible.
    """
    w0 = init_weights(cfg.n_classes, cfg.words, dense=True)
    rf = snn_regfile(w0, seed=_regfile_seed(key))
    teach = _teacher(labels, cfg)
    # The plan's params are plain ints closed over via the engine, so
    # they stay concrete at trace time and lower as kernel literals.
    eng = SNNEngine(cfg.plan(block_idx))
    if intensities is not None:
        step = jax.jit(functools.partial(_engine.train_stream, eng,
                                         n_steps=cfg.n_steps))
        for epoch in range(cfg.epochs):
            rf, _ = step(rf, teach=teach, intensities=intensities,
                         seeds=sample_seeds_at(cfg.encode_seed,
                                               sample_idx, epoch))
        return rf.weights
    step = jax.jit(functools.partial(_engine.train_stream, eng))
    for _ in range(cfg.epochs):
        rf, _ = step(rf, spike_trains, teach)
    return rf.weights


def _train_blocks_parallel(cfg: SNNTrainConfig, key: jax.Array,
                           labels: jnp.ndarray, *,
                           spike_trains: jnp.ndarray | None = None,
                           intensities: jnp.ndarray | None = None,
                           sample_idx: jnp.ndarray | None = None
                           ) -> jnp.ndarray:
    """Train all blocks concurrently on the full set (batched grid).

    Every presented sample is one ``engine.train_batch`` launch covering
    the B = n_blocks per-block regfiles; blocks differ by their keyed
    LFSR seeds AND their LTP schedule — ``ltp_prob`` is a per-stream
    SMEM scalar operand, so block 0 trains at the base ``ltp_prob`` and
    blocks >= 1 at ``ltp_prob_active``, matching active mode's
    ``cfg.stdp(block_idx)`` schedule.  With ``cfg.mesh_shape`` the
    launch shards block streams over the "data" axis and neuron rows
    over "neurons" — the 2-D data-parallel training sweep.  The sample
    stream is pre-encoded windows OR uint8 intensities + their dataset
    indices ``sample_idx`` (shared across blocks, exactly as the
    broadcast spike trains were); counter seeds are epoch-keyed, so
    every epoch draws fresh windows.  Returns packed weights
    uint32[n_neurons, words].
    """
    b = cfg.n_blocks
    w0 = jnp.broadcast_to(
        init_weights(cfg.n_classes, cfg.words, dense=True),
        (b, cfg.n_classes, cfg.words))
    # blocks differ ONLY by these seeds, and lfsr.seed folds its base to
    # 16 bits — draw without replacement so no two blocks can collide
    # into bit-identical training runs
    lfsr_seeds = [int(s) + 1
                  for s in jax.random.choice(key, (1 << 16) - 1, (b,),
                                             replace=False)]
    rfs = snn_regfile_batch(w0, lfsr_seeds)
    teach = _teacher(labels, cfg)
    teach_b = jnp.broadcast_to(teach, (b,) + teach.shape)
    lp = jnp.asarray([cfg.ltp_prob if i == 0 else cfg.ltp_prob_active
                      for i in range(b)], jnp.int32)
    eng = SNNEngine(cfg.plan(0))
    if intensities is not None:
        inten_b = jnp.broadcast_to(intensities,
                                   (b,) + intensities.shape)
        step = jax.jit(functools.partial(_engine.train_stream_batch,
                                         eng, ltp_prob=lp,
                                         n_steps=cfg.n_steps))
        for epoch in range(cfg.epochs):
            rfs, _ = step(rfs, teach=teach_b, intensities=inten_b,
                          seeds=sample_seeds_at(cfg.encode_seed,
                                                sample_idx, epoch))
        return rfs.weights.reshape(b * cfg.n_classes, cfg.words)
    trains_b = jnp.broadcast_to(spike_trains, (b,) + spike_trains.shape)
    step = jax.jit(functools.partial(_engine.train_stream_batch, eng,
                                     ltp_prob=lp))
    for _ in range(cfg.epochs):
        rfs, _ = step(rfs, trains_b, teach_b)
    return rfs.weights.reshape(b * cfg.n_classes, cfg.words)


def classify(model: SNNModel, spike_trains: jnp.ndarray | None = None,
             *, intensities: jnp.ndarray | None = None,
             seeds=None) -> jnp.ndarray:
    """Predicted class int32[B]: class of the maximally-firing neuron.

    Takes pre-encoded ``spike_trains`` uint32[B, T, w] or uint8
    ``intensities`` [B, n_inputs] (+ per-sample ``seeds``), presented
    over ``cfg.n_steps`` cycles through the plan's encode path.
    """
    eng = SNNEngine(model.cfg.plan())
    if intensities is not None:
        counts = eng.infer(model.weights, intensities=intensities,
                           seeds=seeds, n_steps=model.cfg.n_steps)
    else:
        counts = eng.infer(model.weights, spike_trains)
    best = jnp.argmax(counts, axis=-1)
    return model.neuron_class[best]


def accuracy(model: SNNModel, spike_trains: jnp.ndarray | None = None,
             labels: jnp.ndarray | None = None, *,
             intensities: jnp.ndarray | None = None,
             seeds=None) -> float:
    pred = classify(model, spike_trains, intensities=intensities,
                    seeds=seeds)
    return float(jnp.mean((pred == labels).astype(jnp.float32)))


def train(cfg: SNNTrainConfig, images: np.ndarray, labels: np.ndarray,
          key: jax.Array | None = None) -> SNNModel:
    """Full active-learning training.

    images: float32[N, n_inputs] normalized (already preprocessed);
    labels: int[N].

    Dataset residency follows ``cfg.encode``: "host" pre-encodes the
    whole set into a uint32[N, T, w] spike tensor with the statistical
    JAX PRNG (the legacy fallback); "kernel" quantizes ONCE to
    uint8[N, n_inputs] + per-sample counter-hash seeds and every
    presentation draws its window inside the kernels — the N×T×w
    tensor is never materialized.  Kernel-path seeds are epoch-keyed
    (``sample_seeds(base, n, epoch)``): each training epoch re-presents
    the samples with fresh Poisson draws at zero memory cost, and epoch
    0 stays bit-exact with the historical seeds.
    """
    if cfg.train_mode not in ("active", "parallel"):
        raise ValueError(f"train_mode must be 'active' or 'parallel', "
                         f"got {cfg.train_mode!r}")
    if key is None:
        key = jax.random.key(cfg.seed)
    key, ek = jax.random.split(key)
    labels_j = jnp.asarray(labels, jnp.int32)

    if cfg.encode == "kernel":
        spike_trains = None
        intensities = quantize_intensities(
            jnp.asarray(images, jnp.float32))
        seeds = sample_seeds(cfg.encode_seed, intensities.shape[0])
        sample_idx = jnp.arange(intensities.shape[0], dtype=jnp.int32)
    else:
        spike_trains = poisson_encode_batch(
            ek, jnp.asarray(images, jnp.float32), cfg.n_steps)
        intensities = seeds = sample_idx = None

    if cfg.train_mode == "parallel":
        key, bk = jax.random.split(key)
        weights = _train_blocks_parallel(
            cfg, bk, labels_j, spike_trains=spike_trains,
            intensities=intensities, sample_idx=sample_idx)
        classes = jnp.tile(jnp.arange(cfg.n_classes, dtype=jnp.int32),
                           cfg.n_blocks)
        return SNNModel(weights, classes, cfg)

    blocks: list[jnp.ndarray] = []
    classes: list[jnp.ndarray] = []
    cur = (spike_trains, intensities, sample_idx, labels_j)
    for b in range(cfg.n_blocks):
        cur_trains, cur_inten, cur_idx, cur_labels = cur
        key, bk = jax.random.split(key)
        blocks.append(_train_block(
            cfg, bk, cur_labels, b, spike_trains=cur_trains,
            intensities=cur_inten, sample_idx=cur_idx))
        classes.append(jnp.arange(cfg.n_classes, dtype=jnp.int32))
        if b + 1 == cfg.n_blocks:
            break
        # Active learning: next block trains on this ensemble's errors.
        model = SNNModel(jnp.concatenate(blocks, axis=0),
                         jnp.concatenate(classes), cfg)
        if intensities is not None:
            pred = classify(model, intensities=intensities, seeds=seeds)
        else:
            pred = classify(model, spike_trains)
        err = np.asarray(pred != labels_j)
        if not err.any():
            break
        idx = np.where(err)[0]
        # error samples keep their ORIGINAL dataset indices: the same
        # (seed, epoch, intensity) derivation on every re-presentation
        if intensities is not None:
            cur = (None, intensities[idx], sample_idx[idx],
                   labels_j[idx])
        else:
            cur = (spike_trains[idx], None, None, labels_j[idx])
    return SNNModel(jnp.concatenate(blocks, axis=0),
                    jnp.concatenate(classes), cfg)
