"""repro.data — data pipelines.

digits:    procedural 28x28 digit dataset (offline MNIST substitute)
synthetic: token streams for LM training/serving
loader:    sharded, step-indexed host loader with prefetch + resume
"""

from repro.data.digits import make_digits
from repro.data.loader import ShardedLoader
from repro.data.synthetic import SyntheticTokens

__all__ = ["make_digits", "ShardedLoader", "SyntheticTokens"]
