"""Procedural 28x28 digit dataset — offline substitute for MNIST.

The container has no network access, so the paper's MNIST experiments
run on a procedurally rendered digit set with the same format (28x28
grayscale in [0, 255] -> normalized, labels 0-9).  Each class is drawn
from its own hand-designed stroke path (curved polylines approximating
handwritten digit shapes, NOT a shared seven-segment grid — shared
segments would make classes nested subsets, which no count-based
classifier can separate), anti-aliased, with per-sample random affine
jitter (translation, rotation, shear, scale), stroke-width variation and
pixel noise.

EXPERIMENTS.md reports accuracy on this set with an explicit caveat that
it is not MNIST; the preprocessing/encoding/training path is identical.
"""

from __future__ import annotations

import numpy as np

_H = _W = 28

# Per-class stroke paths: list of polylines, points in a unit box,
# y grows downward.  Curves are approximated by short chords.


def _ellipse(cx, cy, rx, ry, n=14, t0=0.0, t1=2 * np.pi):
    ts = np.linspace(t0, t1, n)
    return [(cx + rx * np.sin(t), cy - ry * np.cos(t)) for t in ts]


_DIGIT_PATHS: dict[int, list[list[tuple[float, float]]]] = {
    0: [_ellipse(0.50, 0.50, 0.26, 0.34)],
    1: [[(0.34, 0.28), (0.54, 0.12), (0.54, 0.88)]],
    2: [[(0.27, 0.32), (0.33, 0.16), (0.55, 0.11), (0.72, 0.22),
         (0.72, 0.38), (0.50, 0.58), (0.28, 0.78), (0.26, 0.87),
         (0.76, 0.87)]],
    3: [[(0.28, 0.20), (0.48, 0.11), (0.68, 0.21), (0.66, 0.38),
         (0.48, 0.47), (0.68, 0.56), (0.72, 0.74), (0.52, 0.88),
         (0.28, 0.80)]],
    4: [[(0.62, 0.12), (0.24, 0.62), (0.80, 0.62)],
        [(0.62, 0.12), (0.62, 0.88)]],
    5: [[(0.72, 0.12), (0.32, 0.12), (0.29, 0.45), (0.55, 0.40),
         (0.73, 0.55), (0.70, 0.76), (0.50, 0.88), (0.28, 0.80)]],
    6: [[(0.64, 0.12), (0.44, 0.26), (0.32, 0.50), (0.32, 0.72),
         (0.48, 0.87), (0.66, 0.78), (0.68, 0.60), (0.52, 0.50),
         (0.34, 0.58)]],
    7: [[(0.24, 0.13), (0.76, 0.13), (0.46, 0.88)]],
    8: [_ellipse(0.50, 0.29, 0.20, 0.17),
        _ellipse(0.50, 0.68, 0.24, 0.21)],
    9: [_ellipse(0.52, 0.30, 0.19, 0.18),
        [(0.71, 0.30), (0.69, 0.55), (0.62, 0.88)]],
}


def _render(digit: int, rng: np.random.Generator) -> np.ndarray:
    """Render one jittered digit as float32[28, 28] in [0, 1]."""
    scale = rng.uniform(0.78, 1.02)
    theta = rng.uniform(-0.16, 0.16)
    shear = rng.uniform(-0.14, 0.14)
    tx, ty = rng.uniform(-1.8, 1.8, size=2)
    width = rng.uniform(0.9, 1.6)

    c, s = np.cos(theta), np.sin(theta)
    A = np.array([[c, -s], [s, c]]) @ np.array([[1.0, shear], [0.0, 1.0]])

    ys, xs = np.mgrid[0:_H, 0:_W].astype(np.float32)
    img = np.zeros((_H, _W), np.float32)
    for path in _DIGIT_PATHS[digit]:
        pts = [A @ (np.array([px - 0.5, py - 0.5]) * scale * 22.0)
               + (14 + tx, 14 + ty) for px, py in path]
        for p0, p1 in zip(pts[:-1], pts[1:]):
            d = p1 - p0
            L2 = max(float(d @ d), 1e-6)
            t = ((xs - p0[0]) * d[0] + (ys - p0[1]) * d[1]) / L2
            t = np.clip(t, 0.0, 1.0)
            px_ = p0[0] + t * d[0]
            py_ = p0[1] + t * d[1]
            dist = np.sqrt((xs - px_) ** 2 + (ys - py_) ** 2)
            img = np.maximum(img, np.clip(width + 0.5 - dist, 0.0, 1.0))

    img += rng.normal(0.0, 0.04, img.shape).astype(np.float32)
    return np.clip(img, 0.0, 1.0)


def make_digits(n: int, seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """n samples -> (images float32[n, 784] in [0,1], labels int32[n])."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, size=n).astype(np.int32)
    imgs = np.stack([_render(int(d), rng) for d in labels])
    return imgs.reshape(n, _H * _W), labels
