"""Sharded host loader with background prefetch and exact resume.

At 1000+ nodes the data pipeline must be (a) shardable by host without
coordination, (b) restartable to an exact step, (c) overlapped with
compute.  This loader achieves all three with a stateless design: the
underlying source maps ``step -> global batch`` deterministically; each
host slices its shard by ``host_id``; a small thread pool prefetches the
next ``prefetch`` steps while the current one trains.
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator

import numpy as np


class ShardedLoader:
    def __init__(
        self,
        batch_fn: Callable[[int], dict[str, np.ndarray]],
        *,
        host_id: int = 0,
        num_hosts: int = 1,
        prefetch: int = 2,
    ) -> None:
        self._batch_fn = batch_fn
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.prefetch = max(0, prefetch)

    def _shard(self, batch: dict[str, np.ndarray]) -> dict[str, np.ndarray]:
        out = {}
        for k, v in batch.items():
            b = v.shape[0]
            assert b % self.num_hosts == 0, (
                f"global batch {b} not divisible by {self.num_hosts} hosts")
            per = b // self.num_hosts
            out[k] = v[self.host_id * per:(self.host_id + 1) * per]
        return out

    def get(self, step: int) -> dict[str, np.ndarray]:
        """This host's shard of the global batch for ``step``."""
        return self._shard(self._batch_fn(step))

    def iterate(self, start_step: int, end_step: int | None = None
                ) -> Iterator[tuple[int, dict[str, np.ndarray]]]:
        """Prefetching iterator from ``start_step`` (exact resume point)."""
        if self.prefetch == 0:
            step = start_step
            while end_step is None or step < end_step:
                yield step, self.get(step)
                step += 1
            return

        q: queue.Queue = queue.Queue(maxsize=self.prefetch)
        stop = threading.Event()

        def producer() -> None:
            step = start_step
            while not stop.is_set() and (end_step is None or step < end_step):
                try:
                    q.put((step, self.get(step)), timeout=0.1)
                except queue.Full:
                    continue
                step += 1
            q.put(None)

        th = threading.Thread(target=producer, daemon=True)
        th.start()
        try:
            while True:
                item = q.get()
                if item is None:
                    return
                yield item
        finally:
            stop.set()
