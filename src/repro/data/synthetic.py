"""Synthetic token streams for LM training/serving.

Deterministic, step-indexed generation: batch ``i`` is a pure function of
``(seed, i)`` so the pipeline is stateless and resumes exactly after a
restart (fault-tolerance requirement — no data-iterator checkpoint is
needed, just the step counter).

The stream is a mixture of a Zipfian unigram draw and short Markov
repeats, which gives the loss curve enough structure for the ~100M-model
example to visibly learn (pure uniform noise would pin loss at ln(V)).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class SyntheticTokens:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    zipf_a: float = 1.3       # Zipf exponent of the unigram mixture
    repeat_p: float = 0.35    # probability of copying token[t - period]
    period: int = 16

    def batch(self, step: int) -> dict[str, np.ndarray]:
        """Batch for ``step`` -> {tokens, labels} int32[B, T]."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        b, t, v = self.batch_size, self.seq_len, self.vocab_size
        # Zipf over a capped support for speed; modulo-fold into vocab.
        base = rng.zipf(self.zipf_a, size=(b, t)).astype(np.int64)
        toks = (base - 1) % v
        # Inject periodic repeats (learnable structure).
        rep = rng.random((b, t)) < self.repeat_p
        rep[:, : self.period] = False
        idx = np.arange(t)
        src = np.clip(idx - self.period, 0, t - 1)
        toks = np.where(rep, toks[:, src], toks)
        toks = toks.astype(np.int32)
        labels = np.concatenate(
            [toks[:, 1:], np.zeros((b, 1), np.int32)], axis=1)
        return {"tokens": toks, "labels": labels}
