"""repro.distributed — sharding rules, collectives, pipeline schedule."""

from repro.distributed.sharding import (constrain, current_mesh,
                                        logical_spec, named_sharding,
                                        use_mesh)

__all__ = ["constrain", "current_mesh", "logical_spec", "named_sharding",
           "use_mesh"]
