"""Pipeline parallelism: GPipe-style microbatch schedule over shard_map.

An optional parallelism axis for the deepest architectures (llama3's
126 layers): the layer stack is split into S stages laid out on a mesh
axis; microbatches flow stage-to-stage with
``jax.lax.ppermute`` (the TPU-native point-to-point collective), giving
the classic (S - 1 + M) step schedule with bubble fraction
(S-1)/(S-1+M).

This module is deliberately self-contained (stage_fn is any pure
function) so it composes with the transformer stack: pass the
super-block apply as ``stage_fn`` and stage-stacked params.  Used by
tests/test_pipeline.py and available to launch/train.py as a config
switch; the dry-run's default recipe keeps FSDP+TP (DESIGN.md §5) —
pipeline becomes profitable on real hardware when TP collectives
saturate ICI, which the §Roofline table identifies per arch.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_schedule(stage_fn, n_stages: int, n_micro: int,
                      axis_name: str = "stage"):
    """Build a pipelined forward usable under shard_map.

    stage_fn(stage_params, x) -> y : one stage's compute.
    Returns fn(stage_params, micro_x) -> micro_y where, PER DEVICE
    (inside shard_map over ``axis_name``):
      stage_params: this stage's params;
      micro_x: [M, ...] all microbatches (only stage 0's input is real);
      micro_y: [M, ...] outputs (only the LAST stage's are real).

    The schedule runs T = M + S - 1 ticks; at tick t, stage s computes
    microbatch (t - s) if 0 <= t - s < M.  Data moves s -> s+1 with a
    single ppermute per tick.
    """

    def run(stage_params, micro_x):
        s = jax.lax.axis_index(axis_name)
        m = micro_x.shape[0]
        ticks = m + n_stages - 1
        # carries become device-varying inside the scan; mark them so
        buf = jax.lax.pcast(jnp.zeros_like(micro_x[0]), (axis_name,),
                            to="varying")          # inflight activation
        out = jax.lax.pcast(jnp.zeros_like(micro_x), (axis_name,),
                            to="varying")

        def tick(carry, t):
            buf, out = carry
            # stage 0 ingests microbatch t; others use the ppermuted buf
            inject = jnp.where(t < m, t, m - 1)
            x_in = jnp.where(s == 0, micro_x[inject], buf)
            active = (t - s >= 0) & (t - s < m)
            y = stage_fn(stage_params, x_in)
            y = jnp.where(active, y, buf)
            # last stage writes its finished microbatch (masked write —
            # lax.cond branches would disagree on shard_map vma types)
            widx = jnp.clip(t - s, 0, m - 1)
            write = active & (s == n_stages - 1)
            out = out.at[widx].set(jnp.where(write, y, out[widx]))
            # shift activations one stage forward
            buf = jax.lax.ppermute(
                y, axis_name,
                perm=[(i, i + 1) for i in range(n_stages - 1)])
            return (buf, out), None

        (buf, out), _ = jax.lax.scan(tick, (buf, out),
                                     jnp.arange(ticks))
        return out

    return run


def pipelined_apply(mesh: Mesh, stage_fn, stage_params, micro_x,
                    axis_name: str = "stage"):
    """Convenience wrapper: shard_map the schedule over ``axis_name``.

    stage_params: leading axis = n_stages (one slice per stage).
    micro_x: [M, ...] microbatches, replicated across stages.
    Returns [M, ...] outputs from the last stage (replicated).
    """
    n_stages = mesh.shape[axis_name]
    run = pipeline_schedule(stage_fn, n_stages, micro_x.shape[0],
                            axis_name)

    def wrapped(sp, mx):
        out = run(jax.tree.map(lambda a: a[0], sp), mx)
        # broadcast the last stage's result to all stages (masked psum)
        s = jax.lax.axis_index(axis_name)
        last = jax.lax.psum(
            jnp.where(s == n_stages - 1, out, jnp.zeros_like(out)),
            axis_name)
        return last

    return jax.shard_map(
        wrapped, mesh=mesh,
        in_specs=(P(axis_name), P()),
        out_specs=P(),
    )(stage_params, micro_x)
