"""Logical-axis sharding: models annotate tensors with logical names;
a rules table maps them to mesh axes (or None = replicated).

Models call ``constrain(x, "batch", "seq", "embed")`` at layer
boundaries; outside a ``use_mesh`` context this is the identity, inside
it becomes ``with_sharding_constraint`` — so the same model code runs
single-device (tests), and SPMD (dry-run / production) without edits.

Rules are plain dicts so the dry-run can swap entire strategies (e.g.
heads-TP vs sequence-parallel attention) per architecture x shape; see
DEFAULT_RULES / SEQPAR_RULES below and repro.launch.dryrun.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

# Logical axis -> mesh axis (str | tuple | None).
DEFAULT_RULES: dict = {
    # activations
    "batch": ("pod", "data"),
    "seq": None,              # input token sequence axis
    "res_seq": "model",       # residual-stream seq axis (Megatron-SP:
                              # layer-scan carries shrink 16x; XLA turns
                              # the TP all-reduces into RS+AG pairs)
    "mix_seq": None,          # seq axis of matmul INPUTS: gathered for
                              # heads-TP (so dW psums span data only and
                              # per-layer grad buffers stay 1/TP-sized),
                              # model-sharded for seq-parallel archs
    "embed": None,
    "heads": "model",
    "kv_heads": None,
    "head_dim": None,
    "qkv": "model",           # fused qkv output dim (heads packed)
    "ffn": "model",
    "experts": None,
    "vocab": "model",         # logits vocab axis
    "kv_seq": "model",        # decode KV-cache sequence axis
    "frames": None,
    # parameters (FSDP-style: second axis over data where large)
    "p_vocab": ("pod", "data"),
    "p_embed": "model",
    "p_in": ("pod", "data"),  # contracting dim of weight matrices
    "p_out": "model",         # output dim (heads/ffn packed)
    "p_experts": None,
    "layers": None,           # stacked-layer leading axis
    # SNN window engine (repro.distributed.snn_mesh): the neuron axis
    # shards across the "neuron" mesh axis — rows are independent (LFSR
    # lanes are per-neuron, so shards carry no cross-device PRNG state);
    # the packed synapse-word axis stays replicated with its row.  The
    # sample/stream batch axis of the batched window ops shards across
    # the "data" mesh axis of a 2-D (data × neuron) mesh — streams are
    # independent too (per-stream regfiles, per-sample counter-hash
    # seeds), and on a 1-D neuron mesh the rule resolves to replicated,
    # so the same specs drive both placements.
    "neurons": "neuron",
    "syn_words": None,
    "data": "data",
}

# Sequence-parallel attention variant: for archs whose head counts do not
# divide the model axis (gemma3 4H, whisper 12H, starcoder2 24H).
SEQPAR_RULES_OVERRIDES: dict = {
    "heads": None,
    "qkv": None,
    "seq": "model",
    "res_seq": "model",
    "mix_seq": "model",
    "p_out": "model",  # weights still shard on the packed output dim
}


def use_rules(base: dict | None = None, **overrides) -> dict:
    r = dict(DEFAULT_RULES if base is None else base)
    r.update(overrides)
    return r


@contextmanager
def use_mesh(mesh: Mesh, rules: dict | None = None):
    prev = getattr(_state, "ctx", None)
    _state.ctx = (mesh, dict(DEFAULT_RULES if rules is None else rules))
    try:
        yield
    finally:
        _state.ctx = prev


def current_mesh() -> tuple[Mesh, dict] | None:
    return getattr(_state, "ctx", None)


def _resolve(rules: dict, mesh: Mesh, names: tuple) -> P:
    axes = []
    used: set = set()
    for nm in names:
        ax = rules.get(nm) if nm is not None else None
        if ax is None:
            axes.append(None)
            continue
        cand = ax if isinstance(ax, tuple) else (ax,)
        # keep only axes present in this mesh and not already used
        cand = tuple(a for a in cand if a in mesh.shape and a not in used)
        used.update(cand)
        axes.append(cand if len(cand) > 1 else (cand[0] if cand else None))
    return P(*axes)


def logical_spec(names: tuple, rules: dict | None = None,
                 mesh: Mesh | None = None) -> P:
    ctx = current_mesh()
    if mesh is None or rules is None:
        if ctx is None:
            raise RuntimeError("no active mesh; use use_mesh(...)")
        mesh = mesh or ctx[0]
        rules = rules or ctx[1]
    return _resolve(rules, mesh, names)


def named_sharding(mesh: Mesh, rules: dict, names: tuple) -> NamedSharding:
    return NamedSharding(mesh, _resolve(rules, mesh, names))


def _divisible(mesh: Mesh, spec: P, shape: tuple) -> bool:
    for dim, ax in zip(shape, spec):
        if ax is None:
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        if dim % n != 0:
            return False
    return True


def constrain(x: jax.Array, *names):
    """Annotate ``x`` with logical axis names (identity w/o a mesh)."""
    ctx = current_mesh()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = _resolve(rules, mesh, names)
    if not _divisible(mesh, spec, x.shape):
        # drop non-divisible axes rather than failing mid-model; the
        # dry-run surfaces the resulting (replicated) memory cost.
        spec = P(*[
            ax if ax is not None and _divisible(
                mesh, P(*[None] * i + [ax] + [None] * (x.ndim - i - 1)),
                x.shape) else None
            for i, ax in enumerate(spec)])
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec))
