"""Mesh sharding of the SNN window engine: 1-D (neuron) and 2-D
(data × neuron) placements.

These are the low-level shard_map wrappers behind the engine's plan
placement: build an ``SNNEnginePlan(mesh=...)`` (or declaratively,
``mesh_shape=(data, neurons)``) and ``repro.engine.SNNEngine``
dispatches its verbs here — that is the public API.  The functions
remain callable directly (the ``--check``/``--bench`` CLI and older
call sites use them), with unchanged signatures and bit-identical
outputs.

The window kernels grid over neuron blocks independently — every neuron
row owns its weights, membrane and LFSR lanes, and the (small) packed
spike window is shared read-only.  That makes the n axis trivially
spatial: ``shard_map`` the window ops over the "neuron" mesh axis and
each device runs the SAME kernels on its n/D-row shard, with no
collectives and no cross-device PRNG state.  Populations then scale
past one core's VMEM by adding devices.

The batched ops add a second independent axis: streams/samples.  Each
stream owns its regfile (batched training) or its window/intensity row
(batched serving), and the encode-fused kernels draw spikes from
per-sample *counter-hash* seeds — stateless, so any device regenerates
any (seed, cycle, input) bit identically.  ``snn_mesh2d(data,
neurons)`` therefore factorizes the device grid over BOTH axes::

                 neuron axis (populations) ->
               +----------------+----------------+
      data     |  dev(0,0)      |  dev(0,1)      |   samples 0..B/2
      axis     |  rows 0..n/2   |  rows n/2..n   |
    (samples)  +----------------+----------------+
        |      |  dev(1,0)      |  dev(1,1)      |   samples B/2..B
        v      |  rows 0..n/2   |  rows n/2..n   |
               +----------------+----------------+

Device (i, j) trains/serves its sample rows × its neuron rows; no
collectives, no cross-shard PRNG state, and any (data, neurons)
factorization — (2,4), (4,2), (8,1), … — is bit-exact with the 1-D and
unsharded paths.  The same wrappers serve every placement: batch axes
carry the "data" logical name, which resolves to the "data" mesh axis
when present and to replicated on a 1-D neuron mesh.

Specs come from the logical-axis machinery in
:mod:`repro.distributed.sharding`: state matrices are ("neurons",
"syn_words") — with a leading "data" axis when batched — per-neuron
vectors ("neurons",), per-sample scalars ("data",), spike windows and
intensities ("data", …) with the word axis replicated.

Entry point (runs on a forced-multi-device CPU mesh in containers
without TPUs)::

    python -m repro.distributed.snn_mesh --check            # 8 devices
    python -m repro.distributed.snn_mesh --check \
        --mesh-shape 2,4 --mesh-shape 4,2 --mesh-shape 8,1  # 2-D grids
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        python -m repro.distributed.snn_mesh --check --devices 4

``--check`` asserts sharded == single-device outputs bit-exactly for
every wrapper (pre-packed and encode-fused, infer and train) on each
requested mesh.
"""

from __future__ import annotations

import functools
import os
import sys

if __name__ == "__main__":  # before any jax backend initialization
    _flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_force_host_platform_device_count=8").strip()

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh

from repro.distributed.sharding import logical_spec, use_rules
from repro.kernels import ops

_AXIS = "neuron"
_DATA_AXIS = "data"


def snn_mesh(n_devices: int | None = None) -> Mesh:
    """1-D neuron mesh over (the first n of) the available devices."""
    devs = jax.devices()
    if n_devices is not None:
        if n_devices > len(devs):
            raise ValueError(f"asked for {n_devices} devices, "
                             f"have {len(devs)}")
        devs = devs[:n_devices]
    import numpy as np
    return Mesh(np.asarray(devs), (_AXIS,))


def snn_mesh2d(data: int, neurons: int) -> Mesh:
    """2-D (data × neuron) mesh over the first data*neurons devices.

    Sample/stream batch axes shard over ``data``, neuron rows over
    ``neurons``; ``snn_mesh2d(1, d)`` and ``snn_mesh(d)`` produce
    bit-identical results through every wrapper below.
    """
    if data < 1 or neurons < 1:
        raise ValueError(f"mesh extents must be >= 1, got "
                         f"({data}, {neurons})")
    devs = jax.devices()
    need = data * neurons
    if need > len(devs):
        raise ValueError(f"asked for a {data}x{neurons} mesh "
                         f"({need} devices), have {len(devs)}")
    import numpy as np
    return Mesh(np.asarray(devs[:need]).reshape(data, neurons),
                (_DATA_AXIS, _AXIS))


def _dims(mesh: Mesh) -> tuple[int, int]:
    """(data, neuron) extents; data is 1 on a 1-D neuron mesh."""
    return mesh.shape.get(_DATA_AXIS, 1), mesh.shape[_AXIS]


def _specs(mesh: Mesh, *names_tuples):
    rules = use_rules()
    return tuple(logical_spec(names, rules, mesh) for names in names_tuples)


def _pad_rows(x: jnp.ndarray, mult: int, fill=0, axis: int = 0
              ) -> jnp.ndarray:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


def sharded_infer_window_batch(weights, spike_trains, *, threshold: int,
                               leak: int, t_chunk: int | None = None,
                               backend: str = "ref",
                               mesh: Mesh | None = None) -> jnp.ndarray:
    """:func:`ops.infer_window_batch` over an SNN mesh.

    weights u32[n, w] shard on n; spike_trains u32[B, T, w] shard on B
    over the "data" axis (replicated on a 1-D neuron mesh); counts
    i32[B, n] come back sharded on both and are reassembled.  Bit-exact
    with the single-device op for any mesh factorization.
    """
    mesh = snn_mesh() if mesh is None else mesh
    dd, nd = _dims(mesh)
    n = weights.shape[0]
    b = spike_trains.shape[0]
    wp = _pad_rows(weights, nd)
    tp = _pad_rows(spike_trains, dd)
    row, trains, out = _specs(mesh, ("neurons", "syn_words"),
                              ("data", None, "syn_words"),
                              ("data", "neurons"))
    fn = shard_map(
        functools.partial(ops.infer_window_batch, threshold=threshold,
                          leak=leak, t_chunk=t_chunk, backend=backend),
        mesh=mesh, in_specs=(row, trains), out_specs=out, check_rep=False)
    return fn(wp, tp)[:b, :n]


def sharded_fused_snn_window(weights, spike_train, v, lfsr_state, teach, *,
                             threshold: int, leak: int, w_exp: int,
                             gain: int, n_syn: int, ltp_prob: int = 1023,
                             train: bool = True,
                             t_chunk: int | None = None,
                             backend: str = "ref",
                             mesh: Mesh | None = None):
    """:func:`ops.fused_snn_window` over an SNN mesh.

    weights/lfsr u32[n, w], v/teach i32[n] shard on n; the spike window
    replicates (incl. over the "data" axis of a 2-D mesh — one sample
    has no batch axis to split); the fired raster bool[T, n] comes back
    n-sharded.  Each shard's LFSR lanes travel with its rows, so
    training stays bit-exact with the single-device op (incl. the LFSR
    sequence).  Returns (weights', v', fired bool[T, n], lfsr').
    """
    mesh = snn_mesh() if mesh is None else mesh
    _, nd = _dims(mesh)
    n = weights.shape[0]
    wp = _pad_rows(weights, nd)
    vp = _pad_rows(v, nd)
    tp = _pad_rows(teach, nd)
    sp = _pad_rows(lfsr_state, nd, fill=1)
    row, vec, rep2, ras = _specs(
        mesh, ("neurons", "syn_words"), ("neurons",),
        (None, "syn_words"), (None, "neurons"))
    fn = shard_map(
        functools.partial(ops.fused_snn_window, threshold=threshold,
                          leak=leak, w_exp=w_exp, gain=gain, n_syn=n_syn,
                          ltp_prob=ltp_prob, train=train, t_chunk=t_chunk,
                          backend=backend),
        mesh=mesh, in_specs=(row, rep2, vec, row, vec),
        out_specs=(row, vec, ras, row), check_rep=False)
    w2, v2, fired, s2 = fn(wp, spike_train, vp, sp, tp)
    return w2[:n], v2[:n], fired[:, :n], s2[:n]


def sharded_train_window_batch(weights, spike_trains, v, lfsr_state,
                               teach, *, threshold: int, leak: int,
                               w_exp: int, gain: int, n_syn: int,
                               ltp_prob=1023, t_chunk: int | None = None,
                               backend: str = "ref",
                               mesh: Mesh | None = None):
    """:func:`ops.train_window_batch` over an SNN mesh.

    weights/lfsr u32[B, n, w], v/teach i32[B, n] shard on n AND on the
    stream axis over "data" (every stream's rows travel with their LFSR
    lanes); the spike windows u32[B, T, w] and the per-stream
    ``ltp_prob`` (int or i32[B]) shard on "data" only.  On a 2-D
    (data × neuron) mesh device (i, j) trains its B/dd streams × its
    n/nd rows; bit-exact with the single-device op for any
    factorization.  Returns (weights', v', fired bool[B, T, n], lfsr').
    """
    mesh = snn_mesh() if mesh is None else mesh
    dd, nd = _dims(mesh)
    b, n, _ = weights.shape
    wp = _pad_rows(_pad_rows(weights, nd, axis=1), dd)
    vp = _pad_rows(_pad_rows(v, nd, axis=1), dd)
    tp = _pad_rows(_pad_rows(teach, nd, axis=1), dd)
    sp = _pad_rows(_pad_rows(lfsr_state, nd, fill=1, axis=1), dd, fill=1)
    kp = _pad_rows(spike_trains, dd)
    lp = _pad_rows(
        jnp.broadcast_to(jnp.asarray(ltp_prob, jnp.int32), (b,)), dd)
    row3, vecb, trains, per, ras3 = _specs(
        mesh, ("data", "neurons", "syn_words"), ("data", "neurons"),
        ("data", None, "syn_words"), ("data",), ("data", None, "neurons"))

    def call(w, s, vv, st, tc, lp_):
        return ops.train_window_batch(
            w, s, vv, st, tc, threshold=threshold, leak=leak,
            w_exp=w_exp, gain=gain, n_syn=n_syn, ltp_prob=lp_,
            t_chunk=t_chunk, backend=backend)

    fn = shard_map(call, mesh=mesh,
                   in_specs=(row3, trains, vecb, row3, vecb, per),
                   out_specs=(row3, vecb, ras3, row3), check_rep=False)
    w2, v2, fired, s2 = fn(wp, kp, vp, sp, tp, lp)
    return w2[:b, :n], v2[:b, :n], fired[:b, :, :n], s2[:b, :n]


def sharded_infer_window_batch_encode(weights, intensities, seeds, *,
                                      n_steps: int, threshold: int,
                                      leak: int, t_total=None,
                                      t_chunk: int | None = None,
                                      backend: str = "ref",
                                      mesh: Mesh | None = None
                                      ) -> jnp.ndarray:
    """:func:`ops.infer_window_batch_encode` over an SNN mesh.

    weights shard on n; intensities u8[B, n_in], per-sample seeds and
    the optional ``t_total`` shard on "data" — the counter draw is
    stateless, so every neuron shard regenerates the SAME spikes from
    its sample rows' (seed, cycle) keys with no cross-shard broadcast.
    Bit-exact with the single-device op for any factorization.
    """
    mesh = snn_mesh() if mesh is None else mesh
    dd, nd = _dims(mesh)
    n = weights.shape[0]
    b = intensities.shape[0]
    wp = _pad_rows(weights, nd)
    xp = _pad_rows(intensities, dd)
    sd = _pad_rows(
        jnp.broadcast_to(jnp.asarray(seeds, jnp.int32), (b,)), dd)
    tt = (jnp.full((b,), n_steps, jnp.int32) if t_total is None
          else jnp.asarray(t_total, jnp.int32))
    tt = _pad_rows(tt, dd, fill=n_steps)
    row, inten, per, out = _specs(mesh, ("neurons", "syn_words"),
                                  ("data", None), ("data",),
                                  ("data", "neurons"))

    def call(w, x, s, t):
        return ops.infer_window_batch_encode(
            w, x, s, n_steps=n_steps, threshold=threshold, leak=leak,
            t_total=t, t_chunk=t_chunk, backend=backend)

    fn = shard_map(call, mesh=mesh, in_specs=(row, inten, per, per),
                   out_specs=out, check_rep=False)
    return fn(wp, xp, sd, tt)[:b, :n]


def sharded_fused_snn_window_encode(weights, intensities, seed, v,
                                    lfsr_state, teach, *, n_steps: int,
                                    threshold: int, leak: int, w_exp: int,
                                    gain: int, n_syn: int,
                                    ltp_prob: int = 1023,
                                    train: bool = True,
                                    t_chunk: int | None = None,
                                    backend: str = "ref",
                                    mesh: Mesh | None = None):
    """:func:`ops.fused_snn_window_encode` over an SNN mesh.

    State shards on n as in :func:`sharded_fused_snn_window`; the uint8
    intensities replicate (n_in bytes instead of a T*w*4-byte window,
    incl. over the "data" axis — one sample has no batch axis) and the
    scalar counter seed closes over the call.  Bit-exact with the
    single-device op, incl. each shard's LFSR sequence.
    """
    mesh = snn_mesh() if mesh is None else mesh
    _, nd = _dims(mesh)
    n = weights.shape[0]
    wp = _pad_rows(weights, nd)
    vp = _pad_rows(v, nd)
    tp = _pad_rows(teach, nd)
    sp = _pad_rows(lfsr_state, nd, fill=1)
    row, vec, rep1, ras = _specs(
        mesh, ("neurons", "syn_words"), ("neurons",), (None,),
        (None, "neurons"))

    def call(w, x, vv, st, tc):
        return ops.fused_snn_window_encode(
            w, x, seed, vv, st, tc, n_steps=n_steps, threshold=threshold,
            leak=leak, w_exp=w_exp, gain=gain, n_syn=n_syn,
            ltp_prob=ltp_prob, train=train, t_chunk=t_chunk,
            backend=backend)

    fn = shard_map(call, mesh=mesh, in_specs=(row, rep1, vec, row, vec),
                   out_specs=(row, vec, ras, row), check_rep=False)
    w2, v2, fired, s2 = fn(wp, intensities, vp, sp, tp)
    return w2[:n], v2[:n], fired[:, :n], s2[:n]


def sharded_train_window_batch_encode(weights, intensities, seeds, v,
                                      lfsr_state, teach, *, n_steps: int,
                                      threshold: int, leak: int,
                                      w_exp: int, gain: int, n_syn: int,
                                      ltp_prob=1023,
                                      t_chunk: int | None = None,
                                      backend: str = "ref",
                                      mesh: Mesh | None = None):
    """:func:`ops.train_window_batch_encode` over an SNN mesh.

    Per-stream state shards on n and on "data"; intensities u8[B, n_in],
    per-sample seeds and ``ltp_prob`` shard on "data" only — each
    stream's n_in intensity bytes land exactly on the devices training
    that stream, so the 2-D mesh is the end-to-end intensity-resident
    placement: no spike window in HBM anywhere, no replicated dataset.
    Bit-exact with the single-device op for any factorization.
    """
    mesh = snn_mesh() if mesh is None else mesh
    dd, nd = _dims(mesh)
    b, n, _ = weights.shape
    wp = _pad_rows(_pad_rows(weights, nd, axis=1), dd)
    vp = _pad_rows(_pad_rows(v, nd, axis=1), dd)
    tp = _pad_rows(_pad_rows(teach, nd, axis=1), dd)
    sp = _pad_rows(_pad_rows(lfsr_state, nd, fill=1, axis=1), dd, fill=1)
    xp = _pad_rows(intensities, dd)
    lp = _pad_rows(
        jnp.broadcast_to(jnp.asarray(ltp_prob, jnp.int32), (b,)), dd)
    sd = _pad_rows(
        jnp.broadcast_to(jnp.asarray(seeds, jnp.int32), (b,)), dd)
    row3, vecb, inten, per, ras3 = _specs(
        mesh, ("data", "neurons", "syn_words"), ("data", "neurons"),
        ("data", None), ("data",), ("data", None, "neurons"))

    def call(w, x, s, vv, st, tc, lp_):
        return ops.train_window_batch_encode(
            w, x, s, vv, st, tc, n_steps=n_steps, threshold=threshold,
            leak=leak, w_exp=w_exp, gain=gain, n_syn=n_syn, ltp_prob=lp_,
            t_chunk=t_chunk, backend=backend)

    fn = shard_map(call, mesh=mesh,
                   in_specs=(row3, inten, per, vecb, row3, vecb, per),
                   out_specs=(row3, vecb, ras3, row3), check_rep=False)
    w2, v2, fired, s2 = fn(wp, xp, sd, vp, sp, tp, lp)
    return w2[:b, :n], v2[:b, :n], fired[:b, :, :n], s2[:b, :n]


def _parse_mesh_shapes(shapes) -> list[tuple[int, int]]:
    out = []
    for s in shapes or []:
        parts = s.split(",")
        if len(parts) != 2:
            raise SystemExit(f"--mesh-shape wants D,N — got {s!r}")
        out.append((int(parts[0]), int(parts[1])))
    return out


def _meshes(args) -> list[Mesh]:
    shapes = _parse_mesh_shapes(args.mesh_shape)
    if shapes:
        return [snn_mesh2d(d, n) for d, n in shapes]
    return [snn_mesh(args.devices)]


def _mesh_label(mesh: Mesh) -> str:
    dd, nd = _dims(mesh)
    if _DATA_AXIS in mesh.shape:
        return f"{dd}x{nd} mesh"
    return f"{nd} devices"


def _check(args) -> int:
    import numpy as np

    rng = np.random.default_rng(0x22A)
    n, w, t, b = args.neurons, args.words, args.steps, args.batch
    weights = jnp.asarray(rng.integers(0, 2**32, (n, w), dtype=np.uint32))
    trains = jnp.asarray(
        rng.integers(0, 2**32, (b, t, w), dtype=np.uint32))
    v = jnp.zeros((n,), jnp.int32)
    teach = jnp.asarray(rng.integers(-50, 50, (n,), dtype=np.int32))
    from repro.core import lfsr
    st = lfsr.seed(7, n * w).reshape(n, w)
    kw = dict(threshold=60, leak=4, w_exp=64, gain=4, n_syn=w * 32,
              ltp_prob=200)
    inten = jnp.asarray(rng.integers(0, 256, (b, w * 32), dtype=np.uint8))
    seeds = jnp.arange(1, b + 1, dtype=jnp.int32)
    tt = jnp.asarray([t - (i % 3) for i in range(b)], jnp.int32)
    wts_b = jnp.asarray(
        rng.integers(0, 2**32, (b, n, w), dtype=np.uint32))
    vb = jnp.zeros((b, n), jnp.int32)
    tb = jnp.asarray(rng.integers(-50, 50, (b, n), dtype=np.int32))
    stb = jnp.stack([lfsr.seed(3 + i, n * w).reshape(n, w)
                     for i in range(b)])

    for mesh in _meshes(args):
        label = _mesh_label(mesh)

        got = sharded_infer_window_batch(
            weights, trains, threshold=60, leak=4, backend=args.backend,
            mesh=mesh)
        want = ops.infer_window_batch(weights, trains, threshold=60,
                                      leak=4, backend=args.backend)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        print(f"infer_window_batch: sharded({label}) == single-device "
              f"[B={b}, n={n}]")

        for train in (True, False):
            got = sharded_fused_snn_window(
                weights, trains[0], v, st, teach, train=train,
                backend=args.backend, mesh=mesh, **kw)
            want = ops.fused_snn_window(weights, trains[0], v, st, teach,
                                        train=train,
                                        backend=args.backend, **kw)
            for g, r in zip(got, want):
                np.testing.assert_array_equal(np.asarray(g),
                                              np.asarray(r))
            print(f"fused_snn_window(train={train}): sharded({label}) "
                  f"== single-device [n={n}, T={t}]")

        got = sharded_train_window_batch(
            wts_b, trains, vb, stb, tb, backend=args.backend, mesh=mesh,
            **kw)
        want = ops.train_window_batch(wts_b, trains, vb, stb, tb,
                                      backend=args.backend, **kw)
        for g, r in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(r))
        print(f"train_window_batch: sharded({label}) == single-device "
              f"[B={b}]")

        # encode-fused paths: every shard regenerates the same spikes
        # from its samples' seeds (stateless counter draw)
        got = sharded_infer_window_batch_encode(
            weights, inten, seeds, n_steps=t, threshold=60, leak=4,
            t_total=tt, backend=args.backend, mesh=mesh)
        want = ops.infer_window_batch_encode(
            weights, inten, seeds, n_steps=t, threshold=60, leak=4,
            t_total=tt, backend=args.backend)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        print(f"infer_window_batch_encode: sharded({label}) == "
              f"single-device [B={b}, ragged T]")

        for train in (True, False):
            got = sharded_fused_snn_window_encode(
                weights, inten[0], 7, v, st, teach, n_steps=t,
                train=train, backend=args.backend, mesh=mesh, **kw)
            want = ops.fused_snn_window_encode(
                weights, inten[0], 7, v, st, teach, n_steps=t,
                train=train, backend=args.backend, **kw)
            for g, r in zip(got, want):
                np.testing.assert_array_equal(np.asarray(g),
                                              np.asarray(r))
            print(f"fused_snn_window_encode(train={train}): "
                  f"sharded({label}) == single-device")

        got = sharded_train_window_batch_encode(
            wts_b, inten, seeds, vb, stb, tb, n_steps=t,
            backend=args.backend, mesh=mesh, **kw)
        want = ops.train_window_batch_encode(
            wts_b, inten, seeds, vb, stb, tb, n_steps=t,
            backend=args.backend, **kw)
        for g, r in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(r))
        print(f"train_window_batch_encode: sharded({label}) == "
              f"single-device [B={b}]")
    print("OK")
    return 0


def _bench(args) -> int:
    """Time sharded vs single-device serving; print one parseable line.

    Meant to run in a fresh process (benchmarks/kernels_bench.py spawns
    it with --xla_force_host_platform_device_count) so the forced
    multi-device CPU mesh cannot skew the parent's timings.  With
    ``--mesh-shape D,N`` it instead times the batched TRAINING grid on
    the 2-D mesh vs the 1-D neuron mesh of the same device count
    (``BENCH2D`` line).
    """
    import time as _time

    import numpy as np

    rng = np.random.default_rng(5)
    n, w, t, b = args.neurons, args.words, args.steps, args.batch

    def med_us(fn, *operands):
        for _ in range(2):
            jax.block_until_ready(fn(*operands))
        ts = []
        for _ in range(5):
            t0 = _time.perf_counter()
            jax.block_until_ready(fn(*operands))
            ts.append(_time.perf_counter() - t0)
        return float(np.median(ts) * 1e6)

    shapes = _parse_mesh_shapes(args.mesh_shape)
    if shapes:
        from repro.core import lfsr
        wts = jnp.asarray(
            rng.integers(0, 2**32, (b, n, w), dtype=np.uint32))
        spk = jnp.asarray(
            rng.integers(0, 2**32, (b, t, w), dtype=np.uint32))
        vb = jnp.zeros((b, n), jnp.int32)
        tb = jnp.zeros((b, n), jnp.int32)
        stb = jnp.stack([lfsr.seed(1 + i, n * w).reshape(n, w)
                         for i in range(b)])
        kw = dict(threshold=192, leak=16, w_exp=128, gain=4,
                  n_syn=w * 32, ltp_prob=16, backend=args.backend)
        for dd, nd in shapes:
            f1 = jax.jit(functools.partial(sharded_train_window_batch,
                                           mesh=snn_mesh(dd * nd), **kw))
            f2 = jax.jit(functools.partial(sharded_train_window_batch,
                                           mesh=snn_mesh2d(dd, nd),
                                           **kw))
            t_1, t_2 = (med_us(f, wts, spk, vb, stb, tb)
                        for f in (f1, f2))
            print(f"BENCH2D shape={dd}x{nd} b={b} n={n} words={w} "
                  f"t_1d_us={t_1:.2f} t_2d_us={t_2:.2f}")
        return 0

    mesh = snn_mesh(args.devices)
    d = mesh.shape[_AXIS]
    weights = jnp.asarray(rng.integers(0, 2**32, (n, w), dtype=np.uint32))
    trains = jnp.asarray(
        rng.integers(0, 2**32, (b, t, w), dtype=np.uint32))
    single = jax.jit(functools.partial(
        ops.infer_window_batch, threshold=192, leak=16,
        backend=args.backend))
    # jit once so repeated calls hit the compile cache — timing a fresh
    # shard_map build per call would measure tracing, not execution
    shard = jax.jit(functools.partial(
        sharded_infer_window_batch, threshold=192, leak=16,
        backend=args.backend, mesh=mesh))

    t_1, t_d = med_us(single, weights, trains), med_us(shard, weights,
                                                       trains)
    print(f"BENCH devices={d} n={n} words={w} t_single_us={t_1:.2f} "
          f"t_shard_us={t_d:.2f}")
    return 0


def main(argv: list[str] | None = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--devices", type=int, default=None,
                    help="1-D mesh size (default: all devices)")
    ap.add_argument("--mesh-shape", action="append", default=None,
                    metavar="D,N",
                    help="2-D (data × neuron) factorization; repeatable "
                         "— each D,N grid is checked in turn")
    ap.add_argument("--neurons", type=int, default=264)
    ap.add_argument("--words", type=int, default=25)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--backend", default="ref",
                    choices=["ref", "interp", "tpu"])
    ap.add_argument("--check", action="store_true",
                    help="assert sharded == unsharded and exit")
    ap.add_argument("--bench", action="store_true",
                    help="time sharded vs single-device and exit")
    args = ap.parse_args(argv)
    print(f"devices: {jax.device_count()} "
          f"({jax.devices()[0].platform})")
    if args.bench:
        return _bench(args)
    return _check(args)


if __name__ == "__main__":
    sys.exit(main())
