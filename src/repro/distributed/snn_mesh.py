"""Neuron-axis mesh sharding of the SNN window engine.

These are the low-level shard_map wrappers behind the engine's plan
placement: build an ``SNNEnginePlan(mesh=...)`` and
``repro.engine.SNNEngine`` dispatches its verbs here — that is the
public API.  The functions remain callable directly (the ``--check``/
``--bench`` CLI and older call sites use them), with unchanged
signatures and bit-identical outputs.

The window kernels grid over neuron blocks independently — every neuron
row owns its weights, membrane and LFSR lanes, and the (small) packed
spike window is shared read-only.  That makes the n axis trivially
spatial: ``shard_map`` the window ops over a 1-D "neuron" mesh and each
device runs the SAME kernels on its n/D-row shard, with no collectives
and no cross-device PRNG state.  Populations then scale past one core's
VMEM by adding devices.

Specs come from the logical-axis machinery in
:mod:`repro.distributed.sharding`: state matrices are ("neurons",
"syn_words"), per-neuron vectors ("neurons",), spike windows replicated.

Entry point (runs on a forced-multi-device CPU mesh in containers
without TPUs)::

    python -m repro.distributed.snn_mesh --check            # 8 devices
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        python -m repro.distributed.snn_mesh --check --devices 4

``--check`` asserts sharded == single-device outputs bit-exactly for
both ``infer_window_batch`` and ``fused_snn_window`` (train and infer).
"""

from __future__ import annotations

import functools
import os
import sys

if __name__ == "__main__":  # before any jax backend initialization
    _flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_force_host_platform_device_count=8").strip()

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh

from repro.distributed.sharding import logical_spec, use_rules
from repro.kernels import ops

_AXIS = "neuron"


def snn_mesh(n_devices: int | None = None) -> Mesh:
    """1-D mesh over (the first n of) the available devices."""
    devs = jax.devices()
    if n_devices is not None:
        if n_devices > len(devs):
            raise ValueError(f"asked for {n_devices} devices, "
                             f"have {len(devs)}")
        devs = devs[:n_devices]
    import numpy as np
    return Mesh(np.asarray(devs), (_AXIS,))


def _specs(mesh: Mesh, *names_tuples):
    rules = use_rules()
    return tuple(logical_spec(names, rules, mesh) for names in names_tuples)


def _pad_rows(x: jnp.ndarray, mult: int, fill=0, axis: int = 0
              ) -> jnp.ndarray:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


def sharded_infer_window_batch(weights, spike_trains, *, threshold: int,
                               leak: int, t_chunk: int | None = None,
                               backend: str = "ref",
                               mesh: Mesh | None = None) -> jnp.ndarray:
    """:func:`ops.infer_window_batch` over a neuron-sharded mesh.

    weights u32[n, w] shard on n; spike_trains u32[B, T, w] replicate;
    counts i32[B, n] come back n-sharded and are reassembled.  Bit-exact
    with the single-device op.
    """
    mesh = snn_mesh() if mesh is None else mesh
    d = mesh.shape[_AXIS]
    n = weights.shape[0]
    wp = _pad_rows(weights, d)
    row, rep3, out = _specs(mesh, ("neurons", "syn_words"),
                            (None, None, "syn_words"), (None, "neurons"))
    fn = shard_map(
        functools.partial(ops.infer_window_batch, threshold=threshold,
                          leak=leak, t_chunk=t_chunk, backend=backend),
        mesh=mesh, in_specs=(row, rep3), out_specs=out, check_rep=False)
    return fn(wp, spike_trains)[:, :n]


def sharded_fused_snn_window(weights, spike_train, v, lfsr_state, teach, *,
                             threshold: int, leak: int, w_exp: int,
                             gain: int, n_syn: int, ltp_prob: int = 1023,
                             train: bool = True,
                             t_chunk: int | None = None,
                             backend: str = "ref",
                             mesh: Mesh | None = None):
    """:func:`ops.fused_snn_window` over a neuron-sharded mesh.

    weights/lfsr u32[n, w], v/teach i32[n] shard on n; the spike window
    replicates; the fired raster bool[T, n] comes back n-sharded.  Each
    shard's LFSR lanes travel with its rows, so training stays bit-exact
    with the single-device op (incl. the LFSR sequence).
    Returns (weights', v', fired bool[T, n], lfsr').
    """
    mesh = snn_mesh() if mesh is None else mesh
    d = mesh.shape[_AXIS]
    n = weights.shape[0]
    wp = _pad_rows(weights, d)
    vp = _pad_rows(v, d)
    tp = _pad_rows(teach, d)
    sp = _pad_rows(lfsr_state, d, fill=1)
    row, vec, rep2, ras = _specs(
        mesh, ("neurons", "syn_words"), ("neurons",),
        (None, "syn_words"), (None, "neurons"))
    fn = shard_map(
        functools.partial(ops.fused_snn_window, threshold=threshold,
                          leak=leak, w_exp=w_exp, gain=gain, n_syn=n_syn,
                          ltp_prob=ltp_prob, train=train, t_chunk=t_chunk,
                          backend=backend),
        mesh=mesh, in_specs=(row, rep2, vec, row, vec),
        out_specs=(row, vec, ras, row), check_rep=False)
    w2, v2, fired, s2 = fn(wp, spike_train, vp, sp, tp)
    return w2[:n], v2[:n], fired[:, :n], s2[:n]


def sharded_train_window_batch(weights, spike_trains, v, lfsr_state,
                               teach, *, threshold: int, leak: int,
                               w_exp: int, gain: int, n_syn: int,
                               ltp_prob=1023, t_chunk: int | None = None,
                               backend: str = "ref",
                               mesh: Mesh | None = None):
    """:func:`ops.train_window_batch` over a neuron-sharded mesh.

    weights/lfsr u32[B, n, w], v/teach i32[B, n] shard on n (every
    stream's rows travel with their LFSR lanes); the spike windows
    u32[B, T, w] and the per-stream ``ltp_prob`` (int or i32[B])
    replicate.  Bit-exact with the single-device op.
    Returns (weights', v', fired bool[B, T, n], lfsr').
    """
    mesh = snn_mesh() if mesh is None else mesh
    d = mesh.shape[_AXIS]
    b, n, _ = weights.shape
    wp = _pad_rows(weights, d, axis=1)
    vp = _pad_rows(v, d, axis=1)
    tp = _pad_rows(teach, d, axis=1)
    sp = _pad_rows(lfsr_state, d, fill=1, axis=1)
    lp = jnp.broadcast_to(jnp.asarray(ltp_prob, jnp.int32), (b,))
    row3, vecb, rep3, rep1, ras3 = _specs(
        mesh, (None, "neurons", "syn_words"), (None, "neurons"),
        (None, None, "syn_words"), (None,), (None, None, "neurons"))

    def call(w, s, vv, st, tc, lp_):
        return ops.train_window_batch(
            w, s, vv, st, tc, threshold=threshold, leak=leak,
            w_exp=w_exp, gain=gain, n_syn=n_syn, ltp_prob=lp_,
            t_chunk=t_chunk, backend=backend)

    fn = shard_map(call, mesh=mesh,
                   in_specs=(row3, rep3, vecb, row3, vecb, rep1),
                   out_specs=(row3, vecb, ras3, row3), check_rep=False)
    w2, v2, fired, s2 = fn(wp, spike_trains, vp, sp, tp, lp)
    return w2[:, :n], v2[:, :n], fired[:, :, :n], s2[:, :n]


def sharded_infer_window_batch_encode(weights, intensities, seeds, *,
                                      n_steps: int, threshold: int,
                                      leak: int, t_total=None,
                                      t_chunk: int | None = None,
                                      backend: str = "ref",
                                      mesh: Mesh | None = None
                                      ) -> jnp.ndarray:
    """:func:`ops.infer_window_batch_encode` over a neuron-sharded mesh.

    weights shard on n; intensities u8[B, n_in], seeds and the optional
    per-sample ``t_total`` replicate — the counter draw is stateless, so
    every shard regenerates the SAME spikes from the same (seed, cycle)
    keys with no cross-shard broadcast.  Bit-exact with the
    single-device op.
    """
    mesh = snn_mesh() if mesh is None else mesh
    d = mesh.shape[_AXIS]
    n = weights.shape[0]
    b = intensities.shape[0]
    wp = _pad_rows(weights, d)
    sd = jnp.broadcast_to(jnp.asarray(seeds, jnp.int32), (b,))
    tt = (jnp.full((b,), n_steps, jnp.int32) if t_total is None
          else jnp.asarray(t_total, jnp.int32))
    row, rep2, rep1, out = _specs(mesh, ("neurons", "syn_words"),
                                  (None, None), (None,), (None, "neurons"))

    def call(w, x, s, t):
        return ops.infer_window_batch_encode(
            w, x, s, n_steps=n_steps, threshold=threshold, leak=leak,
            t_total=t, t_chunk=t_chunk, backend=backend)

    fn = shard_map(call, mesh=mesh, in_specs=(row, rep2, rep1, rep1),
                   out_specs=out, check_rep=False)
    return fn(wp, intensities, sd, tt)[:, :n]


def sharded_fused_snn_window_encode(weights, intensities, seed, v,
                                    lfsr_state, teach, *, n_steps: int,
                                    threshold: int, leak: int, w_exp: int,
                                    gain: int, n_syn: int,
                                    ltp_prob: int = 1023,
                                    train: bool = True,
                                    t_chunk: int | None = None,
                                    backend: str = "ref",
                                    mesh: Mesh | None = None):
    """:func:`ops.fused_snn_window_encode` over a neuron-sharded mesh.

    State shards on n as in :func:`sharded_fused_snn_window`; the uint8
    intensities replicate (n_in bytes instead of a T*w*4-byte window)
    and the scalar counter seed closes over the call.  Bit-exact with
    the single-device op, incl. each shard's LFSR sequence.
    """
    mesh = snn_mesh() if mesh is None else mesh
    d = mesh.shape[_AXIS]
    n = weights.shape[0]
    wp = _pad_rows(weights, d)
    vp = _pad_rows(v, d)
    tp = _pad_rows(teach, d)
    sp = _pad_rows(lfsr_state, d, fill=1)
    row, vec, rep1, ras = _specs(
        mesh, ("neurons", "syn_words"), ("neurons",), (None,),
        (None, "neurons"))

    def call(w, x, vv, st, tc):
        return ops.fused_snn_window_encode(
            w, x, seed, vv, st, tc, n_steps=n_steps, threshold=threshold,
            leak=leak, w_exp=w_exp, gain=gain, n_syn=n_syn,
            ltp_prob=ltp_prob, train=train, t_chunk=t_chunk,
            backend=backend)

    fn = shard_map(call, mesh=mesh, in_specs=(row, rep1, vec, row, vec),
                   out_specs=(row, vec, ras, row), check_rep=False)
    w2, v2, fired, s2 = fn(wp, intensities, vp, sp, tp)
    return w2[:n], v2[:n], fired[:, :n], s2[:n]


def sharded_train_window_batch_encode(weights, intensities, seeds, v,
                                      lfsr_state, teach, *, n_steps: int,
                                      threshold: int, leak: int,
                                      w_exp: int, gain: int, n_syn: int,
                                      ltp_prob=1023,
                                      t_chunk: int | None = None,
                                      backend: str = "ref",
                                      mesh: Mesh | None = None):
    """:func:`ops.train_window_batch_encode` over a neuron-sharded mesh.

    Per-stream state shards on n; intensities u8[B, n_in], seeds and
    ``ltp_prob`` replicate.  Bit-exact with the single-device op.
    """
    mesh = snn_mesh() if mesh is None else mesh
    d = mesh.shape[_AXIS]
    b, n, _ = weights.shape
    wp = _pad_rows(weights, d, axis=1)
    vp = _pad_rows(v, d, axis=1)
    tp = _pad_rows(teach, d, axis=1)
    sp = _pad_rows(lfsr_state, d, fill=1, axis=1)
    lp = jnp.broadcast_to(jnp.asarray(ltp_prob, jnp.int32), (b,))
    sd = jnp.broadcast_to(jnp.asarray(seeds, jnp.int32), (b,))
    row3, vecb, rep2, rep1, ras3 = _specs(
        mesh, (None, "neurons", "syn_words"), (None, "neurons"),
        (None, None), (None,), (None, None, "neurons"))

    def call(w, x, s, vv, st, tc, lp_):
        return ops.train_window_batch_encode(
            w, x, s, vv, st, tc, n_steps=n_steps, threshold=threshold,
            leak=leak, w_exp=w_exp, gain=gain, n_syn=n_syn, ltp_prob=lp_,
            t_chunk=t_chunk, backend=backend)

    fn = shard_map(call, mesh=mesh,
                   in_specs=(row3, rep2, rep1, vecb, row3, vecb, rep1),
                   out_specs=(row3, vecb, ras3, row3), check_rep=False)
    w2, v2, fired, s2 = fn(wp, intensities, sd, vp, sp, tp, lp)
    return w2[:, :n], v2[:, :n], fired[:, :, :n], s2[:, :n]


def _check(args) -> int:
    import numpy as np

    mesh = snn_mesh(args.devices)
    d = mesh.shape[_AXIS]
    rng = np.random.default_rng(0x22A)
    n, w, t, b = args.neurons, args.words, args.steps, args.batch
    weights = jnp.asarray(rng.integers(0, 2**32, (n, w), dtype=np.uint32))
    trains = jnp.asarray(
        rng.integers(0, 2**32, (b, t, w), dtype=np.uint32))
    v = jnp.zeros((n,), jnp.int32)
    teach = jnp.asarray(rng.integers(-50, 50, (n,), dtype=np.int32))
    from repro.core import lfsr
    st = lfsr.seed(7, n * w).reshape(n, w)
    kw = dict(threshold=60, leak=4, w_exp=64, gain=4, n_syn=w * 32,
              ltp_prob=200)

    got = sharded_infer_window_batch(
        weights, trains, threshold=60, leak=4, backend=args.backend,
        mesh=mesh)
    want = ops.infer_window_batch(weights, trains, threshold=60, leak=4,
                                  backend=args.backend)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    print(f"infer_window_batch: sharded({d} devices) == single-device "
          f"[B={b}, n={n}]")

    for train in (True, False):
        got = sharded_fused_snn_window(
            weights, trains[0], v, st, teach, train=train,
            backend=args.backend, mesh=mesh, **kw)
        want = ops.fused_snn_window(weights, trains[0], v, st, teach,
                                    train=train, backend=args.backend,
                                    **kw)
        for g, r in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(r))
        print(f"fused_snn_window(train={train}): sharded == "
              f"single-device [n={n}, T={t}]")

    # encode-fused paths: every shard regenerates the same spikes from
    # the replicated intensities (stateless counter draw)
    inten = jnp.asarray(rng.integers(0, 256, (b, w * 32), dtype=np.uint8))
    seeds = jnp.arange(1, b + 1, dtype=jnp.int32)
    tt = jnp.asarray([t - (i % 3) for i in range(b)], jnp.int32)
    got = sharded_infer_window_batch_encode(
        weights, inten, seeds, n_steps=t, threshold=60, leak=4,
        t_total=tt, backend=args.backend, mesh=mesh)
    want = ops.infer_window_batch_encode(
        weights, inten, seeds, n_steps=t, threshold=60, leak=4,
        t_total=tt, backend=args.backend)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    print(f"infer_window_batch_encode: sharded({d} devices) == "
          f"single-device [B={b}, ragged T]")

    for train in (True, False):
        got = sharded_fused_snn_window_encode(
            weights, inten[0], 7, v, st, teach, n_steps=t, train=train,
            backend=args.backend, mesh=mesh, **kw)
        want = ops.fused_snn_window_encode(
            weights, inten[0], 7, v, st, teach, n_steps=t, train=train,
            backend=args.backend, **kw)
        for g, r in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(r))
        print(f"fused_snn_window_encode(train={train}): sharded == "
              f"single-device")

    wts_b = jnp.asarray(
        rng.integers(0, 2**32, (b, n, w), dtype=np.uint32))
    vb = jnp.zeros((b, n), jnp.int32)
    tb = jnp.asarray(rng.integers(-50, 50, (b, n), dtype=np.int32))
    stb = jnp.stack([lfsr.seed(3 + i, n * w).reshape(n, w)
                     for i in range(b)])
    got = sharded_train_window_batch_encode(
        wts_b, inten, seeds, vb, stb, tb, n_steps=t,
        backend=args.backend, mesh=mesh, **kw)
    want = ops.train_window_batch_encode(
        wts_b, inten, seeds, vb, stb, tb, n_steps=t,
        backend=args.backend, **kw)
    for g, r in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(r))
    print("train_window_batch_encode: sharded == single-device "
          f"[B={b}]")
    print("OK")
    return 0


def _bench(args) -> int:
    """Time sharded vs single-device serving; print one parseable line.

    Meant to run in a fresh process (benchmarks/kernels_bench.py spawns
    it with --xla_force_host_platform_device_count) so the forced
    multi-device CPU mesh cannot skew the parent's timings.
    """
    import time as _time

    import numpy as np

    mesh = snn_mesh(args.devices)
    d = mesh.shape[_AXIS]
    rng = np.random.default_rng(5)
    n, w, t, b = args.neurons, args.words, args.steps, args.batch
    weights = jnp.asarray(rng.integers(0, 2**32, (n, w), dtype=np.uint32))
    trains = jnp.asarray(
        rng.integers(0, 2**32, (b, t, w), dtype=np.uint32))
    single = jax.jit(functools.partial(
        ops.infer_window_batch, threshold=192, leak=16,
        backend=args.backend))
    # jit once so repeated calls hit the compile cache — timing a fresh
    # shard_map build per call would measure tracing, not execution
    shard = jax.jit(functools.partial(
        sharded_infer_window_batch, threshold=192, leak=16,
        backend=args.backend, mesh=mesh))

    def med_us(fn):
        for _ in range(2):
            jax.block_until_ready(fn(weights, trains))
        ts = []
        for _ in range(5):
            t0 = _time.perf_counter()
            jax.block_until_ready(fn(weights, trains))
            ts.append(_time.perf_counter() - t0)
        return float(np.median(ts) * 1e6)

    t_1, t_d = med_us(single), med_us(shard)
    print(f"BENCH devices={d} n={n} words={w} t_single_us={t_1:.2f} "
          f"t_shard_us={t_d:.2f}")
    return 0


def main(argv: list[str] | None = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--devices", type=int, default=None,
                    help="mesh size (default: all devices)")
    ap.add_argument("--neurons", type=int, default=264)
    ap.add_argument("--words", type=int, default=25)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--backend", default="ref",
                    choices=["ref", "interp", "tpu"])
    ap.add_argument("--check", action="store_true",
                    help="assert sharded == unsharded and exit")
    ap.add_argument("--bench", action="store_true",
                    help="time sharded vs single-device and exit")
    args = ap.parse_args(argv)
    print(f"devices: {jax.device_count()} "
          f"({jax.devices()[0].platform})")
    if args.bench:
        return _bench(args)
    return _check(args)


if __name__ == "__main__":
    sys.exit(main())
