"""Logical-axis spec trees for params and caches.

Maps every parameter / cache leaf to a tuple of logical axis names
(resolved against a rules table by repro.distributed.sharding).  Driven
by leaf *path names*, so it stays in sync with the model's param
structure without the model having to carry annotations.
"""

from __future__ import annotations

import jax

# last-path-key -> logical names (unstacked form)
_PARAM_TABLE: dict[str, tuple] = {
    "embed": ("p_vocab", "p_embed"),
    "lm_head": ("p_in", "vocab"),
    "pos_embed": (None, "p_embed"),
    "enc_pos": (None, "p_embed"),
    # attention
    "wqkv": ("p_in", "p_out"),
    "bqkv": (None,),
    "bo": (None,),
    # shared output-projection name (attn wo [H*D, d], mlp wo [ff, d],
    # rwkv wo [d, d], moe wo [E, ff, d] — all contract a model-sharded dim
    "wo": ("p_out", "p_in"),
    # mlp / moe
    "wi": ("p_in", "p_out"),
    "wg": ("p_in", "p_out"),
    "bi": (None,),
    "router": ("p_in", None),
    # mamba
    "in_proj": ("p_in", "p_out"),
    "conv_w": (None, "p_out"),
    "conv_b": ("p_out",),
    "x_proj": ("p_out", None),
    "dt_proj": (None, "p_out"),
    "dt_bias": ("p_out",),
    "A_log": ("p_out", None),
    "D": ("p_out",),
    "out_proj": ("p_out", "p_in"),
    # rwkv
    "mu": (None, None),
    "wr": ("p_in", "p_out"),
    "wk": ("p_in", "p_out"),
    "wv": ("p_in", "p_out"),
    "wd1": ("p_in", None),
    "wd2": (None, "p_out"),
    "decay_base": ("p_out",),
    "bonus": (None, None),
    "ln_scale": ("p_out",),
    # norms
    "scale": (None,),
    "bias": (None,),
}

_CACHE_TABLE: dict[str, tuple] = {
    "k": ("batch", "kv_heads", "kv_seq", "head_dim"),
    "v": ("batch", "kv_heads", "kv_seq", "head_dim"),
    "conv": ("batch", None, "ffn"),
    "ssm": ("batch", "ffn", None),
    "shift": ("batch", None),
    "state": ("batch", "heads", None, None),
    "enc_out": ("batch", None, None),
}


def _leaf_path_keys(path) -> list[str]:
    keys = []
    for p in path:
        if hasattr(p, "key"):
            keys.append(str(p.key))
        elif hasattr(p, "idx"):
            keys.append(str(p.idx))
        elif hasattr(p, "name"):
            keys.append(str(p.name))
    return keys


def _spec_for(path, leaf, table, stack_marker="scan"):
    keys = _leaf_path_keys(path)
    last = keys[-1]
    base = table.get(last)
    if base is None:
        # MoE experts: 3-D wi/wg/wo handled via ndim below; unknown ->
        # replicate (safe default)
        base = (None,) * leaf.ndim
        return base
    spec = tuple(base)
    # MoE expert tensors gain a leading experts axis
    extra = leaf.ndim - len(spec)
    if stack_marker in keys:
        extra -= 1  # stacked-layer leading axis
    if extra > 0:
        spec = ("p_experts",) * extra + spec
    if stack_marker in keys:
        spec = ("layers",) + spec
    if len(spec) != leaf.ndim:  # fallback: replicate
        spec = (None,) * leaf.ndim
    return spec


def param_logical_tree(params):
    """Pytree of logical-name tuples matching ``params``."""
    return jax.tree_util.tree_map_with_path(
        lambda p, l: _spec_for(p, l, _PARAM_TABLE), params)


def cache_logical_tree(cache):
    def spec(path, leaf):
        keys = _leaf_path_keys(path)
        last = keys[-1]
        base = _CACHE_TABLE.get(last, (None,) * leaf.ndim)
        spec = tuple(base)
        if "scan" in keys and len(spec) == leaf.ndim - 1:
            spec = ("layers",) + spec
        if len(spec) != leaf.ndim:
            spec = (None,) * leaf.ndim
        return spec

    return jax.tree_util.tree_map_with_path(spec, cache)


def to_shardings(mesh, rules, logical_tree, shape_tree=None):
    """Logical tree -> NamedSharding tree.

    ``shape_tree`` (ShapeDtypeStructs, optional) enables per-leaf
    divisibility checks: a mesh axis that does not divide the dim is
    dropped (e.g. whisper's 1500-frame cross-attention cache vs
    kv_seq->model=16) instead of failing in pjit.
    """
    from repro.distributed.sharding import _divisible, named_sharding

    def build(names, leaf=None):
        sh = named_sharding(mesh, rules, tuple(names))
        if leaf is None or _divisible(mesh, sh.spec, leaf.shape):
            return sh
        from jax.sharding import NamedSharding, PartitionSpec as P
        fixed = []
        for i, ax in enumerate(sh.spec):
            if ax is None:
                fixed.append(None)
                continue
            probe = P(*([None] * i + [ax] + [None] * (leaf.ndim - i - 1)))
            fixed.append(ax if _divisible(mesh, probe, leaf.shape)
                         else None)
        return NamedSharding(mesh, P(*fixed))

    is_leaf = lambda x: isinstance(x, tuple)  # noqa: E731
    if shape_tree is None:
        return jax.tree.map(build, logical_tree, is_leaf=is_leaf)
    flat_l, treedef = jax.tree.flatten(logical_tree, is_leaf=is_leaf)
    flat_s = treedef.flatten_up_to(shape_tree)
    return jax.tree.unflatten(
        treedef, [build(n, s) for n, s in zip(flat_l, flat_s)])
