"""repro.engine — the unified SNN execution-plan API.

One :class:`SNNEnginePlan` (frozen) + one :class:`SNNEngine` (three
verbs) replace the ~10 scattered SNN entrypoints that each re-accepted
``threshold``/``leak``/``ltp_prob``/``backend``/``t_chunk``/``mesh``
kwargs.  The engine owns kernel-path dispatch (``ref``/``interp``/
``tpu`` × ``step``/``window``) and neuron-mesh placement; consumers
(``repro.core.network``, ``repro.core.trainer``,
``repro.serving.snn``) are thin shims over it.

Migration table (old call -> plan verb)
---------------------------------------

===========================================================  ==========================================================
old call                                                     engine equivalent
===========================================================  ==========================================================
``network.run_sample(rf, win, lif, stdp, teach, **kw)``      ``SNNEngine(plan).train(rf, win, teach)``
``network.run_sample(rf, win, lif, None, **kw)``             ``SNNEngine(replace(plan, w_exp=None)).train(rf, win)``
``network.infer_batch(w, wins, lif, **kw)``                  ``SNNEngine(plan).infer(w, wins)``
``network.train_stream(rf, wins, teach, lif, stdp, **kw)``   ``engine.train_stream(SNNEngine(plan), rf, wins, teach)``
``network.train_stream_batch(rfs, wins, teach, ...)``        ``engine.train_stream_batch(SNNEngine(plan), rfs, ...)``
``snn_mesh.sharded_infer_window_batch(..., mesh=m)``         ``SNNEngine(replace(plan, mesh=m)).infer(w, wins)``
``snn_mesh.sharded_fused_snn_window(..., mesh=m)``           ``SNNEngine(replace(plan, mesh=m)).train(rf, win)``
``snn_mesh.sharded_train_window_batch(..., mesh=m2d)``       ``SNNEngine(replace(plan, mesh_shape=(d, n))).train_batch``
``trainer kwargs (cycle_backend/kernel_backend/...)``        ``SNNEnginePlan`` fields / ``plan_from_config(cfg)``
===========================================================  ==========================================================

where ``plan = SNNEnginePlan(threshold=..., leak=..., w_exp=...,
gain=..., n_syn=..., ltp_prob=..., cycle_backend=...,
kernel_backend=..., t_chunk=...)`` is built once (or via
:func:`plan_from_config` from an ``SNNTrainConfig``), and ``replace`` is
``dataclasses.replace``.  Placement is an explicit ``mesh`` or the
declarative ``mesh_shape=(data, neurons)`` — the 2-D grid shards batch
axes over "data" and regfiles over "neurons"; the verbs dispatch 1-D
vs 2-D automatically and every factorization is bit-exact with the
unsharded path.  The legacy entrypoints remain as deprecation wrappers
with byte-identical outputs.
"""

from repro.engine.engine import (SNNEngine, SNNOutput, refresh_weights,
                                 reset_between_samples, train_stream,
                                 train_stream_batch)
from repro.engine.plan import SNNEnginePlan, plan_from_config

__all__ = ["SNNEngine", "SNNEnginePlan", "SNNOutput", "plan_from_config",
           "refresh_weights", "reset_between_samples", "train_stream",
           "train_stream_batch"]
