"""The unified SNN engine: three verbs over one execution plan.

:class:`SNNEngine` is the single object that owns kernel-path and
placement decisions for the SNN stack.  Callers build one
:class:`~repro.engine.plan.SNNEnginePlan` and then speak three verbs:

``infer(weights, windows)``
    Spike counts i32[B, n] for B presentation windows, weights frozen,
    membrane reset per sample — the serving path.  One
    ``infer_window_batch`` launch (sharded over the plan's neuron mesh
    when present), or a vmap of per-cycle scans on the step path.

``train(rf, window, teach)``
    Present one window to one register file with online STDP (SU idle
    for inference-only plans).  One ``fused_snn_window`` launch, or a
    per-cycle ``snn_step`` scan on the step path.

``train_batch(rfs, windows, teach)``
    B independent training streams in ONE launch (the batched training
    grid), with optional per-stream ``ltp_prob`` — the SMEM scalar
    operand keeps each stream's active-learning schedule.

The module-level :func:`train_stream` / :func:`train_stream_batch`
helpers compose the verbs over a sample stream (reset between samples,
scan over the sample axis) — they are what ``repro.core.network`` and
``repro.core.trainer`` now shim to.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.encoder import encode_from_counter, encode_windows_host
from repro.core.rvsnn import (SnnRegFile, snn_regfile, snn_regfile_batch,
                              snn_step)
from repro.core.stdp import STDPParams
from repro.engine.plan import SNNEnginePlan
from repro.kernels import ops


class SNNOutput(NamedTuple):
    """One presented window: updated regfile + spike statistics."""
    regfile: SnnRegFile
    spike_counts: jnp.ndarray  # int32[n] output spikes over the window
    fired: jnp.ndarray         # bool[T, n] raster


def reset_between_samples(rf: SnnRegFile) -> SnnRegFile:
    """Clear membrane + spike registers, keep weights and LFSR (paper
    resets neuron state between digit presentations)."""
    return rf._replace(
        v=jnp.zeros_like(rf.v),
        spike=jnp.zeros_like(rf.spike),
    )


def _teach_arr(teach, v) -> jnp.ndarray:
    return (jnp.zeros_like(v) if teach is None
            else teach.astype(jnp.int32))


def _last_cycle_spikes(seeds, intensities, n_steps: int, words: int
                       ) -> jnp.ndarray:
    """Packed words of the window's final cycle (the spike register
    after a presentation), regenerated in isolation from the counter."""
    sd = jnp.asarray(seeds, jnp.uint32)
    if intensities.ndim == 1:
        rows = encode_from_counter(sd, intensities, 1, t0=n_steps - 1)[0]
    else:
        rows = jax.vmap(
            lambda s, x: encode_from_counter(s, x, 1, t0=n_steps - 1)[0]
        )(jnp.broadcast_to(sd, intensities.shape[:1]), intensities)
    pad = words - rows.shape[-1]
    if pad:
        widths = [(0, 0)] * (rows.ndim - 1) + [(0, pad)]
        rows = jnp.pad(rows, widths)
    return rows


def _one_of(windows, intensities, n_steps, what: str) -> None:
    if (windows is None) == (intensities is None):
        raise ValueError(f"{what}: pass exactly one of the packed "
                         "window(s) or intensities")
    if intensities is not None and n_steps is None:
        raise ValueError(f"{what}: n_steps is required with intensities")


class SNNEngine:
    """Dispatches the three verbs according to one frozen plan."""

    def __init__(self, plan: SNNEnginePlan):
        self.plan = plan

    def __repr__(self) -> str:
        return f"SNNEngine({self.plan!r})"

    # --- encoding --------------------------------------------------------

    def _seeds(self, seeds, b: int) -> jnp.ndarray:
        """Per-sample counter seeds (default: plan seed + sample index)."""
        if seeds is None:
            return self.plan.encode_seed + jnp.arange(b, dtype=jnp.int32)
        return jnp.broadcast_to(jnp.asarray(seeds, jnp.int32), (b,))

    # --- infer -----------------------------------------------------------

    def infer(self, weights: jnp.ndarray,
              windows: jnp.ndarray | None = None, *,
              intensities: jnp.ndarray | None = None, seeds=None,
              n_steps: int | None = None, t_total=None) -> jnp.ndarray:
        """Spike counts int32[B, n] for B presentation windows.

        Pass EITHER pre-packed ``windows`` uint32[B, T, w] OR uint8
        ``intensities`` [B, n_in] with ``n_steps`` (and optional
        per-sample ``seeds`` i32[B] / true lengths ``t_total`` i32[B]).
        The intensity form encodes deterministically from the counter —
        in VMEM when the plan says ``encode="kernel"`` (the window never
        exists in HBM), on the host otherwise — with identical counts
        either way.
        """
        p = self.plan
        mesh = p.placement()
        if intensities is not None or windows is None:
            _one_of(windows, intensities, n_steps, "infer")
            seeds = self._seeds(seeds, intensities.shape[0])
            if p.encode == "kernel":
                if mesh is not None:
                    from repro.distributed import snn_mesh
                    return snn_mesh.sharded_infer_window_batch_encode(
                        weights, intensities, seeds, n_steps=n_steps,
                        threshold=p.threshold, leak=p.leak,
                        t_total=t_total, t_chunk=p.t_chunk,
                        backend=p.kernel_backend, mesh=mesh)
                return ops.infer_window_batch_encode(
                    weights, intensities, seeds, n_steps=n_steps,
                    threshold=p.threshold, leak=p.leak, t_total=t_total,
                    t_chunk=p.t_chunk, backend=p.kernel_backend)
            windows = encode_windows_host(seeds, intensities, n_steps,
                                    weights.shape[1], t_total)
        if p.cycle_backend == "window":
            if mesh is not None:
                from repro.distributed import snn_mesh
                return snn_mesh.sharded_infer_window_batch(
                    weights, windows, threshold=p.threshold, leak=p.leak,
                    t_chunk=p.t_chunk, backend=p.kernel_backend,
                    mesh=mesh)
            return ops.infer_window_batch(
                weights, windows, threshold=p.threshold, leak=p.leak,
                t_chunk=p.t_chunk, backend=p.kernel_backend)

        lif = p.lif()
        rf0 = snn_regfile(weights)

        def one(window):
            def body(carry, words):
                carry, fired = snn_step(carry, words, lif, None)
                return carry, fired

            _, fired = jax.lax.scan(body, rf0, window)
            return jnp.sum(fired.astype(jnp.int32), axis=0)

        return jax.vmap(one)(windows)

    # --- train -----------------------------------------------------------

    def train(self, rf: SnnRegFile, window: jnp.ndarray | None = None,
              teach: jnp.ndarray | None = None, *,
              intensities: jnp.ndarray | None = None, seed=None,
              n_steps: int | None = None) -> SNNOutput:
        """Present one window to one regfile.

        Pass EITHER a packed uint32[T, w] ``window`` OR uint8
        ``intensities`` [n_in] with ``n_steps`` (+ optional counter
        ``seed``; default: the plan's).  Online STDP when the plan
        learns (``w_exp`` set); SU idle otherwise.  Returns
        :class:`SNNOutput`.
        """
        p = self.plan
        mesh = p.placement()
        if intensities is not None or window is None:
            _one_of(window, intensities, n_steps, "train")
            seed = p.encode_seed if seed is None else seed
            if p.encode == "kernel":
                teach_arr = _teach_arr(teach, rf.v)
                kwargs = p.window_kwargs()
                if mesh is not None:
                    from repro.distributed import snn_mesh
                    w2, v2, fired, lf2 = \
                        snn_mesh.sharded_fused_snn_window_encode(
                            rf.weights, intensities, seed, rf.v, rf.lfsr,
                            teach_arr, n_steps=n_steps,
                            t_chunk=p.t_chunk,
                            backend=p.kernel_backend, mesh=mesh,
                            **kwargs)
                else:
                    w2, v2, fired, lf2 = ops.fused_snn_window_encode(
                        rf.weights, intensities, seed, rf.v, rf.lfsr,
                        teach_arr, n_steps=n_steps, t_chunk=p.t_chunk,
                        backend=p.kernel_backend, **kwargs)
                rf_out = rf._replace(
                    weights=w2, v=v2, lfsr=lf2,
                    spike=_last_cycle_spikes(seed, intensities, n_steps,
                                             rf.weights.shape[1]))
                counts = jnp.sum(fired.astype(jnp.int32), axis=0)
                return SNNOutput(rf_out, counts, fired)
            window = encode_windows_host(seed, intensities[None], n_steps,
                                   rf.weights.shape[1])[0]
        if p.cycle_backend == "window":
            teach_arr = _teach_arr(teach, rf.v)
            kwargs = p.window_kwargs()
            if mesh is not None:
                from repro.distributed import snn_mesh
                w2, v2, fired, lf2 = snn_mesh.sharded_fused_snn_window(
                    rf.weights, window, rf.v, rf.lfsr, teach_arr,
                    t_chunk=p.t_chunk, backend=p.kernel_backend,
                    mesh=mesh, **kwargs)
            else:
                w2, v2, fired, lf2 = ops.fused_snn_window(
                    rf.weights, window, rf.v, rf.lfsr, teach_arr,
                    t_chunk=p.t_chunk, backend=p.kernel_backend,
                    **kwargs)
            rf_out = rf._replace(
                weights=w2, v=v2, lfsr=lf2,
                spike=window[-1].astype(jnp.uint32))
            counts = jnp.sum(fired.astype(jnp.int32), axis=0)
            return SNNOutput(rf_out, counts, fired)

        lif, stdp = p.lif(), p.stdp()

        def body(carry: SnnRegFile, words: jnp.ndarray):
            carry, fired = snn_step(carry, words, lif, stdp, teach)
            return carry, fired

        rf_out, fired = jax.lax.scan(body, rf, window)
        counts = jnp.sum(fired.astype(jnp.int32), axis=0)
        return SNNOutput(rf_out, counts, fired)

    # --- train_batch -----------------------------------------------------

    def train_batch(self, rfs: SnnRegFile,
                    windows: jnp.ndarray | None = None,
                    teach: jnp.ndarray | None = None, *, ltp_prob=None,
                    intensities: jnp.ndarray | None = None, seeds=None,
                    n_steps: int | None = None
                    ) -> tuple[SnnRegFile, jnp.ndarray, jnp.ndarray]:
        """B independent streams, one launch: batched regfile (leading
        stream axis), windows uint32[B, T, w] OR intensities uint8
        [B, n_in] + ``n_steps`` (+ per-stream counter ``seeds`` i32[B]),
        teach i32[B, n].

        ``ltp_prob`` overrides the plan's shared value with a per-stream
        i32[B] vector (active-learning schedules per block).  Returns
        (rfs', spike_counts i32[B, n], fired bool[B, T, n]); stream b is
        bit-exact with a :meth:`train` call on regfile b.
        """
        p = self.plan
        if not p.learn:
            raise ValueError("train_batch needs a learning plan "
                             "(w_exp is None)")
        lp = p.ltp_prob if ltp_prob is None else ltp_prob
        teach = _teach_arr(teach, rfs.v)
        mesh = p.placement()
        if intensities is not None or windows is None:
            _one_of(windows, intensities, n_steps, "train_batch")
            seeds = self._seeds(seeds, intensities.shape[0])
            if p.encode == "kernel":
                kwargs = {k: v for k, v in p.window_kwargs().items()
                          if k not in ("train", "ltp_prob")}
                if mesh is not None:
                    from repro.distributed import snn_mesh
                    w2, v2, fired, lf2 = \
                        snn_mesh.sharded_train_window_batch_encode(
                            rfs.weights, intensities, seeds, rfs.v,
                            rfs.lfsr, teach.astype(jnp.int32),
                            ltp_prob=lp, n_steps=n_steps,
                            t_chunk=p.t_chunk,
                            backend=p.kernel_backend, mesh=mesh,
                            **kwargs)
                else:
                    w2, v2, fired, lf2 = ops.train_window_batch_encode(
                        rfs.weights, intensities, seeds, rfs.v,
                        rfs.lfsr, teach.astype(jnp.int32), ltp_prob=lp,
                        n_steps=n_steps, t_chunk=p.t_chunk,
                        backend=p.kernel_backend, **kwargs)
                rfs_out = rfs._replace(
                    weights=w2, v=v2, lfsr=lf2,
                    spike=_last_cycle_spikes(seeds, intensities, n_steps,
                                             rfs.weights.shape[2]))
                counts = jnp.sum(fired.astype(jnp.int32), axis=1)
                return rfs_out, counts, fired
            windows = encode_windows_host(seeds, intensities, n_steps,
                                    rfs.weights.shape[2])
        if p.cycle_backend == "window":
            kwargs = {k: v for k, v in p.window_kwargs().items()
                      if k not in ("train", "ltp_prob")}
            if mesh is not None:
                from repro.distributed import snn_mesh
                w2, v2, fired, lf2 = snn_mesh.sharded_train_window_batch(
                    rfs.weights, windows, rfs.v, rfs.lfsr,
                    teach.astype(jnp.int32), ltp_prob=lp,
                    t_chunk=p.t_chunk, backend=p.kernel_backend,
                    mesh=mesh, **kwargs)
            else:
                w2, v2, fired, lf2 = ops.train_window_batch(
                    rfs.weights, windows, rfs.v, rfs.lfsr,
                    teach.astype(jnp.int32), ltp_prob=lp,
                    t_chunk=p.t_chunk, backend=p.kernel_backend,
                    **kwargs)
            rfs_out = rfs._replace(
                weights=w2, v=v2, lfsr=lf2,
                spike=windows[:, -1].astype(jnp.uint32))
            counts = jnp.sum(fired.astype(jnp.int32), axis=1)
            return rfs_out, counts, fired

        b = rfs.v.shape[0]
        lif = p.lif()
        lp_arr = jnp.broadcast_to(jnp.asarray(lp, jnp.int32), (b,))

        def one(rf_b, window_b, teach_b, lp_b):
            stdp = STDPParams(jnp.int32(p.w_exp), jnp.int32(p.gain),
                              jnp.int32(p.n_syn), jnp.uint32(lp_b))

            def body(carry, words):
                carry, fired = snn_step(carry, words, lif, stdp, teach_b)
                return carry, fired

            return jax.lax.scan(body, rf_b, window_b)

        rfs_out, fired = jax.vmap(one)(rfs, windows, teach, lp_arr)
        counts = jnp.sum(fired.astype(jnp.int32), axis=1)
        return rfs_out, counts, fired


# --- stream drivers (compose the verbs over the sample axis) ---------------

def train_stream(engine: SNNEngine, rf: SnnRegFile,
                 spike_trains: jnp.ndarray | None = None,
                 teach: jnp.ndarray | None = None, *,
                 intensities: jnp.ndarray | None = None, seeds=None,
                 n_steps: int | None = None
                 ) -> tuple[SnnRegFile, jnp.ndarray]:
    """Online STDP over a stream of samples (sequential, as in hardware).

    Pass EITHER pre-packed ``spike_trains`` uint32[N, T, w] OR uint8
    ``intensities`` [N, n_in] with ``n_steps`` and per-sample counter
    ``seeds`` i32[N] (default: the engine's seed chain) — the
    intensity-resident form never materializes the N×T×w spike tensor;
    each presentation draws its window from the counter hash inside the
    kernel (``encode="kernel"``) or per-sample on the host.  teach
    i32[N, n].  Neuron state resets between presentations; weights and
    LFSR persist.  Returns (rf', spike_counts i32[N, n]).
    """
    _one_of(spike_trains, intensities, n_steps, "train_stream")
    if intensities is not None:
        seeds = engine._seeds(seeds, intensities.shape[0])

        def body(carry: SnnRegFile, inp):
            x, s, tch = inp
            out = engine.train(reset_between_samples(carry), teach=tch,
                               intensities=x, seed=s, n_steps=n_steps)
            return out.regfile, out.spike_counts

        return jax.lax.scan(body, rf, (intensities, seeds, teach))

    def body(carry: SnnRegFile, inp):
        window, tch = inp
        out = engine.train(reset_between_samples(carry), window, tch)
        return out.regfile, out.spike_counts

    return jax.lax.scan(body, rf, (spike_trains, teach))


def train_stream_batch(engine: SNNEngine, rfs: SnnRegFile,
                       spike_trains: jnp.ndarray | None = None,
                       teach: jnp.ndarray | None = None, *,
                       ltp_prob=None,
                       intensities: jnp.ndarray | None = None,
                       seeds=None, n_steps: int | None = None
                       ) -> tuple[SnnRegFile, jnp.ndarray]:
    """B independent sample streams, one :meth:`SNNEngine.train_batch`
    launch per presented sample.

    Pass EITHER ``spike_trains`` uint32[B, N, T, w] OR uint8
    ``intensities`` [B, N, n_in] with ``n_steps`` and per-sample
    ``seeds`` i32[N] (shared by every stream, as broadcast spike trains
    would be) or i32[B, N]; teach i32[B, N, n].  ``ltp_prob``
    optionally carries the per-stream i32[B] schedule through every
    launch.  Returns (rfs', spike_counts i32[B, N, n]).
    """
    _one_of(spike_trains, intensities, n_steps, "train_stream_batch")
    teach_t = jnp.swapaxes(teach, 0, 1)
    if intensities is not None:
        b, n_samples = intensities.shape[:2]
        seeds = (engine._seeds(None, n_samples) if seeds is None
                 else jnp.asarray(seeds, jnp.int32))
        seeds = jnp.broadcast_to(seeds, (b, n_samples))
        inten_t = jnp.swapaxes(intensities, 0, 1)
        seeds_t = jnp.swapaxes(seeds, 0, 1)

        def body(carry: SnnRegFile, inp):
            x, s, tch = inp
            carry = carry._replace(v=jnp.zeros_like(carry.v))
            rfs2, counts, _ = engine.train_batch(
                carry, teach=tch, ltp_prob=ltp_prob, intensities=x,
                seeds=s, n_steps=n_steps)
            return rfs2, counts

        rfs_out, counts = jax.lax.scan(body, rfs,
                                       (inten_t, seeds_t, teach_t))
        return rfs_out, jnp.swapaxes(counts, 0, 1)

    trains_t = jnp.swapaxes(spike_trains, 0, 1)

    def body(carry: SnnRegFile, inp):
        windows, tch = inp
        carry = carry._replace(v=jnp.zeros_like(carry.v))
        rfs2, counts, _ = engine.train_batch(carry, windows, tch,
                                             ltp_prob=ltp_prob)
        return rfs2, counts

    rfs_out, counts = jax.lax.scan(body, rfs, (trains_t, teach_t))
    return rfs_out, jnp.swapaxes(counts, 0, 1)


def refresh_weights(engine: SNNEngine, weights: jnp.ndarray, *,
                    labels: jnp.ndarray, n_classes: int,
                    teach_pos: int = 64, teach_neg: int = -1024,
                    intensities: jnp.ndarray | None = None, seeds=None,
                    n_steps: int | None = None,
                    spike_trains: jnp.ndarray | None = None,
                    lfsr_seeds=None, ltp_prob=None) -> jnp.ndarray:
    """One online-STDP refresh pass over a PACKED population bank — the
    train-while-serving verb.

    ``weights`` is a serving-shaped uint32[n, w] bank whose n =
    blocks × ``n_classes`` rows follow the block layout the trainer
    emits (neuron i's class is ``i % n_classes``).  The bank is
    reshaped into per-block regfiles and every labeled sample is one
    data-parallel :meth:`SNNEngine.train_batch` launch across all
    blocks — on the plan's mesh placement when one is present — then
    reshaped back, so a serving engine can periodically push live
    traffic (or a replay buffer) through the SU and obtain a refreshed
    *candidate* bank without ever mutating the serving copy.

    Samples are uint8 ``intensities`` [N, n_in] + counter ``seeds``
    i32[N] with ``n_steps`` (the intensity-resident form; pass
    epoch-keyed seeds for fresh draws per refresh) OR pre-packed
    ``spike_trains`` uint32[N, T, w].  ``teach_pos``/``teach_neg``
    build the supervision currents from ``labels`` exactly as the
    trainer does; ``lfsr_seeds`` (one per block, default a fixed
    decorrelated chain) key the stochastic-STDP lanes; ``ltp_prob``
    optionally carries a per-block i32[B] schedule.  Returns the
    refreshed bank uint32[n, w]; the input bank is never modified.
    """
    if not engine.plan.learn:
        raise ValueError("refresh_weights needs a learning plan "
                         "(w_exp is None)")
    n, w = int(weights.shape[0]), int(weights.shape[1])
    if n % n_classes:
        raise ValueError(f"weight bank rows ({n}) must be a multiple "
                         f"of n_classes ({n_classes})")
    b = n // n_classes
    w_b = jnp.asarray(weights, jnp.uint32).reshape(b, n_classes, w)
    if lfsr_seeds is None:
        # fixed decorrelated per-block chain (0x9E37 Weyl step, as
        # lfsr.seed uses internally); refresh determinism comes from
        # the caller's epoch-keyed sample seeds, not the LFSR bases
        lfsr_seeds = [(0x22A + 0x9E37 * i) & 0xFFFF or 0xACE1
                      for i in range(b)]
    rfs = snn_regfile_batch(w_b, lfsr_seeds)
    onehot = jax.nn.one_hot(jnp.asarray(labels, jnp.int32), n_classes,
                            dtype=jnp.int32)
    teach = onehot * teach_pos + (1 - onehot) * teach_neg
    teach_b = jnp.broadcast_to(teach, (b,) + teach.shape)
    if intensities is not None:
        inten_b = jnp.broadcast_to(intensities,
                                   (b,) + intensities.shape)
        rfs, _ = train_stream_batch(engine, rfs, teach=teach_b,
                                    ltp_prob=ltp_prob,
                                    intensities=inten_b, seeds=seeds,
                                    n_steps=n_steps)
    else:
        trains_b = jnp.broadcast_to(spike_trains,
                                    (b,) + spike_trains.shape)
        rfs, _ = train_stream_batch(engine, rfs, trains_b, teach_b,
                                    ltp_prob=ltp_prob)
    return rfs.weights.reshape(n, w)
