"""The frozen execution plan of the unified SNN engine.

An :class:`SNNEnginePlan` owns every decision that used to be threaded
through call sites as kwargs (``threshold``/``leak``/``ltp_prob``/
``backend``/``t_chunk``/``mesh`` across ``ops.py``, ``network.py``,
``trainer.py`` and ``snn_mesh.py``): LIF/STDP parameters, the kernel
backend, the cycle path, VMEM chunking, serving batch size and the
optional neuron-mesh placement.  Plans are frozen dataclasses of plain
Python scalars (plus an optional :class:`jax.sharding.Mesh`), so the
parameters stay concrete at trace time and lower as window-kernel
literals — the engine never hits the traced-parameter fallback the
legacy ``network.run_sample`` path needed.
"""

from __future__ import annotations

import dataclasses

from jax.sharding import Mesh

from repro.core.lif import LIFParams, lif_params
from repro.core.stdp import STDPParams, stdp_params

_CYCLE_BACKENDS = ("window", "step")
_KERNEL_BACKENDS = ("ref", "interp", "tpu")
_ENCODE_BACKENDS = ("host", "kernel")


@dataclasses.dataclass(frozen=True)
class SNNEnginePlan:
    """Everything the engine needs to place and dispatch SNN work.

    ``w_exp=None`` marks an inference-only plan (SU idle): ``train``
    presents windows without learning, exactly the legacy
    ``run_sample(stdp=None)`` semantics.  Placement is either an
    explicit ``mesh`` (any 1-D neuron or 2-D data × neuron Mesh) or the
    declarative ``mesh_shape=(data, neurons)``, which builds the 2-D
    host mesh on first use — both shard_map the window ops (window path
    only — the step path is a plain XLA scan).  Batch axes shard over
    "data", weights/v/LFSR regfiles over "neurons"; per-stream
    counter-hash seeds are device-independent, so every ``(data,
    neurons)`` factorization is bit-exact with the 1-D and unsharded
    paths.
    """
    # --- LIF / STDP parameters (lower as kernel literals) ---------------
    threshold: int = 192
    leak: int = 16
    w_exp: int | None = 128     # None => SU idle (inference-only plan)
    gain: int = 4
    n_syn: int = 784
    ltp_prob: int = 16
    # --- dispatch -------------------------------------------------------
    cycle_backend: str = "window"    # "window" | "step"
    kernel_backend: str = "ref"      # "ref" | "interp" | "tpu"
    t_chunk: int | None = None       # VMEM spike-slab cycles (None = T)
    # --- encoding --------------------------------------------------------
    # Where intensity-driven verbs run the Poisson encode: "host" builds
    # the packed window with encoder.encode_from_counter and feeds the
    # pre-packed kernels; "kernel" fuses the same (bit-exact) counter
    # draw into the window kernels, so spike windows never exist in HBM.
    encode: str = "host"             # "host" | "kernel"
    encode_seed: int = 0             # base counter seed for the draw
    # --- serving / placement -------------------------------------------
    max_batch: int = 8               # serving admission cap per launch
    mesh: Mesh | None = None         # explicit mesh (None = local)
    mesh_shape: tuple | None = None  # declarative (data, neurons) grid;
                                     # built via snn_mesh2d on first use

    def __post_init__(self):
        if self.cycle_backend not in _CYCLE_BACKENDS:
            raise ValueError(f"cycle_backend must be one of "
                             f"{_CYCLE_BACKENDS}, got "
                             f"{self.cycle_backend!r}")
        if self.kernel_backend not in _KERNEL_BACKENDS:
            raise ValueError(f"kernel_backend must be one of "
                             f"{_KERNEL_BACKENDS}, got "
                             f"{self.kernel_backend!r}")
        if self.encode not in _ENCODE_BACKENDS:
            raise ValueError(f"encode must be one of {_ENCODE_BACKENDS}, "
                             f"got {self.encode!r}")
        if self.encode == "kernel" and self.cycle_backend != "window":
            raise ValueError("in-kernel encode requires the window "
                             "path; use cycle_backend='window'")
        if self.t_chunk is not None and self.t_chunk < 1:
            raise ValueError(f"t_chunk must be >= 1, got {self.t_chunk}")
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got "
                             f"{self.max_batch}")
        if self.mesh_shape is not None:
            shape = tuple(self.mesh_shape)
            if (len(shape) != 2
                    or not all(isinstance(x, int) and x >= 1
                               for x in shape)):
                raise ValueError(f"mesh_shape must be a (data, neurons) "
                                 f"pair of ints >= 1, got "
                                 f"{self.mesh_shape!r}")
            object.__setattr__(self, "mesh_shape", shape)
            if self.mesh is not None:
                raise ValueError("pass either an explicit mesh or a "
                                 "mesh_shape, not both")
        if ((self.mesh is not None or self.mesh_shape is not None)
                and self.cycle_backend != "window"):
            raise ValueError("mesh placement applies to the window "
                             "path; use cycle_backend='window'")

    # --- derived views ---------------------------------------------------

    def placement(self) -> Mesh | None:
        """The resolved mesh the verbs dispatch over: the explicit
        ``mesh`` when given, else the ``mesh_shape`` grid built over the
        host's devices (Mesh equality is structural, so rebuilding per
        call never re-traces), else None (local execution)."""
        if self.mesh is not None:
            return self.mesh
        if self.mesh_shape is None:
            return None
        from repro.distributed.snn_mesh import snn_mesh2d
        return snn_mesh2d(*self.mesh_shape)

    @property
    def learn(self) -> bool:
        """Whether the train verb runs the SU (STDP) at all."""
        return self.w_exp is not None

    def lif(self) -> LIFParams:
        return lif_params(self.threshold, self.leak)

    def stdp(self) -> STDPParams | None:
        if not self.learn:
            return None
        return stdp_params(self.n_syn, self.w_exp, self.gain,
                           self.ltp_prob)

    def window_kwargs(self) -> dict:
        """Static literals for the window kernels (ops.fused_snn_window
        signature); inference-only plans hand the SU zeroed literals +
        train=False, matching the legacy ``_window_params`` encoding."""
        if not self.learn:
            return dict(threshold=self.threshold, leak=self.leak,
                        w_exp=0, gain=0, n_syn=1, ltp_prob=0,
                        train=False)
        return dict(threshold=self.threshold, leak=self.leak,
                    w_exp=self.w_exp, gain=self.gain, n_syn=self.n_syn,
                    ltp_prob=self.ltp_prob, train=True)


def plan_from_config(cfg, block_idx: int = 0,
                     mesh: Mesh | None = None) -> SNNEnginePlan:
    """Build a plan from an ``SNNTrainConfig``-shaped object.

    ``block_idx`` selects the active-learning LTP schedule exactly as
    ``SNNTrainConfig.stdp`` does (block 0 trains at ``ltp_prob``, later
    error-driven blocks at ``ltp_prob_active``).  An explicit ``mesh``
    overrides the config's declarative ``mesh_shape``.
    """
    lp = cfg.ltp_prob if block_idx == 0 else cfg.ltp_prob_active
    shape = getattr(cfg, "mesh_shape", None)
    return SNNEnginePlan(
        threshold=cfg.threshold, leak=cfg.leak, w_exp=cfg.w_exp,
        gain=cfg.gain, n_syn=cfg.n_inputs, ltp_prob=lp,
        cycle_backend=cfg.cycle_backend,
        kernel_backend=cfg.kernel_backend,
        t_chunk=cfg.window_chunk,
        encode=getattr(cfg, "encode", "host"),
        encode_seed=getattr(cfg, "encode_seed", 0), mesh=mesh,
        mesh_shape=None if mesh is not None else shape)
