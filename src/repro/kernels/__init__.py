"""repro.kernels — Pallas TPU kernels for compute hot-spots.

snn_kernels:      SPU / NU / SU / fused SNNU (the paper's RV-SNN ops)
flash_attention:  FlashAttention-2 style prefill kernel (LM substrate)
ops:              jit'd wrappers + backend dispatch (ref / interp / tpu)
ref:              pure-jnp oracles for all of the above
"""

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention

__all__ = ["ops", "ref", "flash_attention"]
