"""FlashAttention-2-style Pallas TPU kernel (prefill + decode).

The LM substrate's perf-critical hot spot.  Online-softmax accumulation
in VMEM scratch; supports causal masking, sliding windows (gemma3 /
mixtral SWA) and GQA (the kv head index is derived from the q head index
in the BlockSpec index maps, so kv blocks are fetched once per group).

Block sizes default to MXU-friendly (128, 128) tiles; the f32
accumulators live in VMEM scratch across the kv-block grid dimension
(TPU grids iterate the last axis innermost & sequentially).

The XLA fallback used by the multi-pod dry-run (chunked scan with
identical math) lives in repro.models.layers.attention; this kernel is
the single-chip deployment path, validated in interpret mode against
ref.attention_ref.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _flash_kernel(block_q: int, block_k: int, seq_k: int, causal: bool,
                  window: int | None, scale: float,
                  q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref):
    qi = pl.program_id(2)
    kj = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # positions: queries are the last (num_q_blocks*block_q) tokens of the
    # seq_k-long stream (prefill: equal; decode handled by the jnp path).
    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0) + (seq_k - pl.num_programs(2) * block_q)
    k_pos = kj * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    def _compute():
        q = q_ref[...].astype(jnp.float32) * scale   # (BQ, D)
        k = k_ref[...].astype(jnp.float32)           # (BK, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)      # (BQ, BK)
        mask = jnp.ones(s.shape, jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[...]                           # (BQ, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        v = v_ref[...].astype(jnp.float32)            # (BK, D)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = m_new

    if causal or window is not None:
        # Skip fully-masked kv blocks (block-level sparsity).
        needed = jnp.bool_(True)
        if causal:
            needed &= (kj * block_k) <= (q_pos[-1, 0])
        if window is not None:
            needed &= (kj + 1) * block_k - 1 > (q_pos[0, 0] - window)
        pl.when(needed)(_compute)
    else:
        _compute()

    @pl.when(kj == nk - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> zeros
        o_ref[...] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: int | None = None, scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: [B, Hq, Tq, D]; k, v: [B, Hkv, Tk, D] -> [B, Hq, Tq, D].

    Tq/Tk must be multiples of the block sizes (pad upstream);
    Hq % Hkv == 0 (GQA group = Hq // Hkv).
    """
    b, hq, tq, d = q.shape
    _, hkv, tk, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    block_q = min(block_q, tq)
    block_k = min(block_k, tk)
    scale = scale if scale is not None else 1.0 / (d ** 0.5)

    grid = (b, hq, tq // block_q, tk // block_k)
    kern = functools.partial(_flash_kernel, block_q, block_k, tk, causal,
                             window, scale)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, block_q, d),
                         lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((None, None, block_k, d),
                         lambda b_, h, i, j, g=group: (b_, h // g, j, 0)),
            pl.BlockSpec((None, None, block_k, d),
                         lambda b_, h, i, j, g=group: (b_, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, block_q, d),
                               lambda b_, h, i, j: (b_, h, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
