"""jit'd public wrappers around the Pallas kernels.

Handles TPU-alignment padding (word axis -> multiple of 128 lanes,
neuron axis -> multiple of the block size) and backend dispatch:

  backend="ref"     pure-jnp oracle (XLA; used inside scans and dry-runs)
  backend="interp"  Pallas interpret mode (CPU container: kernel body
                    executed in Python — correctness validation)
  backend="tpu"     compiled pl.pallas_call (the deployment target)

The SNN training loop (repro.core.network) uses the ref path by default
because it is scanned over time on CPU here; on a real TPU deployment the
fused kernel replaces the per-cycle body 1:1 (same signature).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels import snn_kernels as _k

_LANES = 128


def _pad_to(x: jnp.ndarray, axis: int, mult: int, fill=0) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


def _block_n(n_padded: int) -> int:
    return min(128, n_padded)


def _prep(weights, pre, block_w_mult=_LANES):
    n, w = weights.shape
    bn = _block_n(max(8, n))
    wp = _pad_to(_pad_to(weights, 1, block_w_mult), 0, max(bn, 8))
    pp = _pad_to(pre, 0, block_w_mult)
    return wp, pp, bn


@functools.partial(jax.jit, static_argnames=("backend",))
def spike_process(spikes, weights, *, backend: str = "ref"):
    """SPU: counts i32[n] = popcount(spikes & weights[i]) per row."""
    if backend == "ref":
        return _ref.spike_process_ref(spikes, weights)
    n, _ = weights.shape
    wp, pp, bn = _prep(weights, spikes)
    out = _k.spike_process(pp, wp, block_n=max(bn, 8),
                           block_w=min(wp.shape[1], 512),
                           interpret=(backend == "interp"))
    return out[:n]


@functools.partial(jax.jit, static_argnames=("threshold", "leak", "backend"))
def lif_step(v, count, threshold: int, leak: int, *, backend: str = "ref"):
    if backend == "ref":
        return _ref.lif_step_ref(v, count, threshold, leak)
    n = v.shape[0]
    bn = _block_n(max(8, n))
    vp = _pad_to(v, 0, bn)
    cp = _pad_to(count, 0, bn)
    v2, f = _k.lif_step(vp, cp, threshold, leak, block_n=bn,
                        interpret=(backend == "interp"))
    return v2[:n], f[:n]


@functools.partial(jax.jit, static_argnames=(
    "w_exp", "gain", "n_syn", "ltp_prob", "backend"))
def stdp_update(weights, pre_spikes, post_fired, lfsr_state, *,
                w_exp: int, gain: int, n_syn: int, ltp_prob: int = 1023,
                backend: str = "ref"):
    if backend == "ref":
        return _ref.stdp_update_ref(weights, pre_spikes, post_fired,
                                    lfsr_state, w_exp, gain, n_syn, ltp_prob)
    n, w = weights.shape
    wp, pp, bn = _prep(weights, pre_spikes)
    fp = _pad_to(post_fired, 0, max(bn, 8))
    # padded LFSR lanes must be nonzero (absorbing state), value is unused
    sp = _pad_to(_pad_to(lfsr_state, 1, _LANES, fill=1), 0, max(bn, 8),
                 fill=1)
    w2, s2 = _k.stdp_update(wp, pp, fp, sp, w_exp=w_exp, gain=gain,
                            n_syn=n_syn, ltp_prob=ltp_prob,
                            block_n=max(bn, 8),
                            interpret=(backend == "interp"))
    return w2[:n, :w], s2[:n, :w]


@functools.partial(jax.jit, static_argnames=(
    "threshold", "leak", "w_exp", "gain", "n_syn", "ltp_prob", "train",
    "backend"))
def fused_snn_step(weights, pre_spikes, v, lfsr_state, teach, *,
                   threshold: int, leak: int, w_exp: int, gain: int,
                   n_syn: int, ltp_prob: int = 1023, train: bool = True,
                   backend: str = "ref"):
    """The paper's coarse-granularity ``snn.step`` as one fused kernel."""
    if backend == "ref":
        return _ref.fused_snn_step_ref(
            weights, pre_spikes, v, lfsr_state, teach, threshold, leak,
            w_exp, gain, n_syn, ltp_prob)
    n, w = weights.shape
    wp, pp, bn = _prep(weights, pre_spikes)
    bn = max(bn, 8)
    vp = _pad_to(v, 0, bn)
    tp = _pad_to(teach, 0, bn)
    sp = _pad_to(_pad_to(lfsr_state, 1, _LANES, fill=1), 0, bn, fill=1)
    w2, v2, f, s2 = _k.fused_snn_step(
        wp, pp, vp, sp, tp, threshold=threshold, leak=leak, w_exp=w_exp,
        gain=gain, n_syn=n_syn, ltp_prob=ltp_prob, train=train,
        block_n=bn, interpret=(backend == "interp"))
    return w2[:n, :w], v2[:n], f[:n], s2[:n, :w]
