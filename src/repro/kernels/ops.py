"""jit'd public wrappers around the Pallas kernels.

Handles TPU-alignment padding (word axis -> multiple of 128 lanes,
neuron axis -> multiple of the block size) and backend dispatch:

  backend="ref"     pure-jnp oracle (XLA; used inside scans and dry-runs)
  backend="interp"  Pallas interpret mode (CPU container: kernel body
                    executed in Python — correctness validation)
  backend="tpu"     compiled pl.pallas_call (the deployment target)

The SNN training loop (repro.core.network) calls the *window* ops
(``fused_snn_window`` / ``infer_window_batch``): one launch covers the
whole T-cycle presentation window with weights/LFSR resident in VMEM,
instead of T per-cycle launches that round-trip state through HBM.  The
ref path of those ops is the same scan-of-steps XLA program the old
per-cycle path produced, so CPU behavior is unchanged; on TPU the
``backend="tpu"`` window kernel is the deployment target.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels import snn_kernels as _k

_LANES = 128


def _pad_to(x: jnp.ndarray, axis: int, mult: int, fill=0) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


def _block_n(n_padded: int) -> int:
    return min(128, n_padded)


def _pad_state(x: jnp.ndarray, bn: int, fill=0) -> jnp.ndarray:
    """Pad an [n, w] state matrix to lane/block alignment.

    LFSR states must use fill=1: padded lanes have to be nonzero (0 is
    the PRNG's absorbing state); the value itself is never read back.
    """
    return _pad_to(_pad_to(x, 1, _LANES, fill=fill), 0, bn, fill=fill)


def _pad_window(spike_train: jnp.ndarray, t_chunk: int | None
                ) -> tuple[jnp.ndarray, int]:
    """Zero-pad the time axis (axis -2) to a t_chunk multiple.

    Returns (padded train, effective chunk).  Padded cycles are masked
    inside the kernels via the ``t_total`` literal, so chunked and
    unchunked launches are bit-exact.
    """
    t_steps = spike_train.shape[-2]
    tc = t_steps if t_chunk is None else max(1, min(t_chunk, t_steps))
    return _pad_to(spike_train, spike_train.ndim - 2, tc), tc


def _prep(weights, pre, block_w_mult=_LANES):
    n, w = weights.shape
    bn = _block_n(max(8, n))
    wp = _pad_to(_pad_to(weights, 1, block_w_mult), 0, max(bn, 8))
    pp = _pad_to(pre, 0, block_w_mult)
    return wp, pp, bn


@functools.partial(jax.jit, static_argnames=("backend",))
def spike_process(spikes, weights, *, backend: str = "ref"):
    """SPU: counts i32[n] = popcount(spikes & weights[i]) per row."""
    if backend == "ref":
        return _ref.spike_process_ref(spikes, weights)
    n, _ = weights.shape
    wp, pp, bn = _prep(weights, spikes)
    out = _k.spike_process(pp, wp, block_n=max(bn, 8),
                           block_w=min(wp.shape[1], 512),
                           interpret=(backend == "interp"))
    return out[:n]


@functools.partial(jax.jit, static_argnames=("threshold", "leak", "backend"))
def lif_step(v, count, threshold: int, leak: int, *, backend: str = "ref"):
    if backend == "ref":
        return _ref.lif_step_ref(v, count, threshold, leak)
    n = v.shape[0]
    bn = _block_n(max(8, n))
    vp = _pad_to(v, 0, bn)
    cp = _pad_to(count, 0, bn)
    v2, f = _k.lif_step(vp, cp, threshold, leak, block_n=bn,
                        interpret=(backend == "interp"))
    return v2[:n], f[:n]


@functools.partial(jax.jit, static_argnames=(
    "w_exp", "gain", "n_syn", "ltp_prob", "backend"))
def stdp_update(weights, pre_spikes, post_fired, lfsr_state, *,
                w_exp: int, gain: int, n_syn: int, ltp_prob: int = 1023,
                backend: str = "ref"):
    if backend == "ref":
        return _ref.stdp_update_ref(weights, pre_spikes, post_fired,
                                    lfsr_state, w_exp, gain, n_syn, ltp_prob)
    n, w = weights.shape
    wp, pp, bn = _prep(weights, pre_spikes)
    fp = _pad_to(post_fired, 0, max(bn, 8))
    sp = _pad_state(lfsr_state, max(bn, 8), fill=1)
    w2, s2 = _k.stdp_update(wp, pp, fp, sp, w_exp=w_exp, gain=gain,
                            n_syn=n_syn, ltp_prob=ltp_prob,
                            block_n=max(bn, 8),
                            interpret=(backend == "interp"))
    return w2[:n, :w], s2[:n, :w]


@functools.partial(jax.jit, static_argnames=(
    "threshold", "leak", "w_exp", "gain", "n_syn", "ltp_prob", "train",
    "backend"))
def fused_snn_step(weights, pre_spikes, v, lfsr_state, teach, *,
                   threshold: int, leak: int, w_exp: int, gain: int,
                   n_syn: int, ltp_prob: int = 1023, train: bool = True,
                   backend: str = "ref"):
    """The paper's coarse-granularity ``snn.step`` as one fused kernel."""
    if backend == "ref":
        return _ref.fused_snn_step_ref(
            weights, pre_spikes, v, lfsr_state, teach, threshold, leak,
            w_exp, gain, n_syn, ltp_prob, train)
    n, w = weights.shape
    wp, pp, bn = _prep(weights, pre_spikes)
    bn = max(bn, 8)
    vp = _pad_to(v, 0, bn)
    tp = _pad_to(teach, 0, bn)
    sp = _pad_state(lfsr_state, bn, fill=1)
    w2, v2, f, s2 = _k.fused_snn_step(
        wp, pp, vp, sp, tp, threshold=threshold, leak=leak, w_exp=w_exp,
        gain=gain, n_syn=n_syn, ltp_prob=ltp_prob, train=train,
        block_n=bn, interpret=(backend == "interp"))
    return w2[:n, :w], v2[:n], f[:n], s2[:n, :w]


@functools.partial(jax.jit, static_argnames=(
    "threshold", "leak", "w_exp", "gain", "n_syn", "ltp_prob", "train",
    "t_chunk", "backend"))
def fused_snn_window(weights, spike_train, v, lfsr_state, teach, *,
                     threshold: int, leak: int, w_exp: int, gain: int,
                     n_syn: int, ltp_prob: int = 1023, train: bool = True,
                     t_chunk: int | None = None, backend: str = "ref"):
    """T ``snn.step`` cycles with weights/v/LFSR resident in VMEM.

    spike_train: uint32[T, w].  Bit-exact with T sequential
    :func:`fused_snn_step` calls (including the LFSR sequence).
    ``t_chunk`` streams the window through VMEM in t_chunk-cycle slabs
    (ragged tails are zero-padded and masked) — same results, bounded
    VMEM for arbitrarily long windows.
    Returns (weights', v', fired bool[T, n], lfsr').
    """
    if backend == "ref":
        return _ref.fused_snn_window_ref(
            weights, spike_train, v, lfsr_state, teach, threshold, leak,
            w_exp, gain, n_syn, ltp_prob, train)
    n, w = weights.shape
    t_steps = spike_train.shape[0]
    bn = max(_block_n(max(8, n)), 8)
    wp = _pad_state(weights, bn)
    stp, tc = _pad_window(_pad_to(spike_train, 1, _LANES), t_chunk)
    vp = _pad_to(v, 0, bn)
    tp = _pad_to(teach, 0, bn)
    sp = _pad_state(lfsr_state, bn, fill=1)
    w2, v2, f, s2 = _k.fused_snn_window(
        wp, stp, vp, sp, tp, threshold=threshold, leak=leak, w_exp=w_exp,
        gain=gain, n_syn=n_syn, ltp_prob=ltp_prob, train=train,
        block_n=bn, t_chunk=tc, t_total=t_steps,
        interpret=(backend == "interp"))
    return w2[:n, :w], v2[:n], f[:t_steps, :n], s2[:n, :w]


@functools.partial(jax.jit, static_argnames=(
    "threshold", "leak", "w_exp", "gain", "n_syn", "t_chunk", "backend"))
def train_window_batch(weights, spike_trains, v, lfsr_state, teach, *,
                       threshold: int, leak: int, w_exp: int, gain: int,
                       n_syn: int, ltp_prob=1023,
                       t_chunk: int | None = None, backend: str = "ref"):
    """Batched training grid: B independent streams per launch.

    weights/lfsr u32[B, n, w], spike_trains u32[B, T, w], v i32[B, n],
    teach i32[B, n] — per-stream regfiles, one grid ordered
    (neuron-block major, batch, time-chunk minor).  ``ltp_prob`` is a
    shared int or a per-stream i32[B] vector (an SMEM scalar operand of
    the kernel, so each stream can keep its own active-learning
    schedule).  Bit-exact with B sequential :func:`fused_snn_window`
    runs, including each stream's LFSR sequence.
    Returns (weights', v', fired bool[B, T, n], lfsr').
    """
    if backend == "ref":
        return _ref.train_window_batch_ref(
            weights, spike_trains, v, lfsr_state, teach, threshold, leak,
            w_exp, gain, n_syn, ltp_prob)
    b, n, w = weights.shape
    t_steps = spike_trains.shape[1]
    bn = max(_block_n(max(8, n)), 8)
    wp = _pad_to(_pad_to(weights, 2, _LANES), 1, bn)
    stp, tc = _pad_window(_pad_to(spike_trains, 2, _LANES), t_chunk)
    vp = _pad_to(v, 1, bn)
    tp = _pad_to(teach, 1, bn)
    sp = _pad_to(_pad_to(lfsr_state, 2, _LANES, fill=1), 1, bn, fill=1)
    w2, v2, f, s2 = _k.train_window_batch(
        wp, stp, vp, sp, tp, threshold=threshold, leak=leak, w_exp=w_exp,
        gain=gain, n_syn=n_syn, ltp_prob=ltp_prob, block_n=bn,
        t_chunk=tc, t_total=t_steps, interpret=(backend == "interp"))
    return (w2[:, :n, :w], v2[:, :n], f[:, :t_steps, :n], s2[:, :n, :w])


def _intensity_words(intensities: jnp.ndarray, words: int) -> jnp.ndarray:
    """uint8[..., n_in] -> uint32[..., 8, words] intensity words.

    The encode kernels' operand layout: byte ``b`` of word ``[k, wi]``
    is the intensity of input ``wi*32 + 4k + b`` (4 intensities per
    uint32 lane — the whole operand is n_in bytes, the T/8x input-stream
    saving the encode path exists for).  ``words`` is the (already
    lane-padded) spike-word width; padding intensities are zero, so
    padded inputs never fire.
    """
    x = jnp.asarray(intensities, jnp.uint32)
    pad = words * 32 - x.shape[-1]
    if pad < 0:
        raise ValueError(f"{x.shape[-1]} intensities exceed the "
                         f"{words}-word spike width ({words * 32} inputs)")
    if pad:
        widths = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
        x = jnp.pad(x, widths)
    x = x.reshape(x.shape[:-1] + (words, 8, 4))
    w = (x[..., 0]
         | jnp.left_shift(x[..., 1], jnp.uint32(8))
         | jnp.left_shift(x[..., 2], jnp.uint32(16))
         | jnp.left_shift(x[..., 3], jnp.uint32(24)))
    return jnp.swapaxes(w, -1, -2)


@functools.partial(jax.jit, static_argnames=(
    "n_steps", "threshold", "leak", "w_exp", "gain", "n_syn", "ltp_prob",
    "train", "t_chunk", "backend"))
def fused_snn_window_encode(weights, intensities, seed, v, lfsr_state,
                            teach, *, n_steps: int, threshold: int,
                            leak: int, w_exp: int, gain: int, n_syn: int,
                            ltp_prob: int = 1023, train: bool = True,
                            t_chunk: int | None = None,
                            backend: str = "ref"):
    """:func:`fused_snn_window` with the Poisson encode fused in-kernel.

    intensities: uint8[n_in] (n_in <= w*32), seed: counter base (int or
    i32 scalar).  The spike window never exists in HBM — each cycle's
    packed row is drawn in VMEM from ``lfsr.counter_hash`` — and the
    result is bit-exact with host-encoding
    ``encoder.encode_from_counter(seed, intensities, n_steps)`` and
    running the pre-packed window op, for every backend and chunking.
    Returns (weights', v', fired bool[T, n], lfsr').
    """
    if backend == "ref":
        return _ref.fused_snn_window_encode_ref(
            weights, intensities, seed, v, lfsr_state, teach, n_steps,
            threshold, leak, w_exp, gain, n_syn, ltp_prob, train)
    n, w = weights.shape
    bn = max(_block_n(max(8, n)), 8)
    wp = _pad_state(weights, bn)
    iw = _intensity_words(intensities, wp.shape[1])
    vp = _pad_to(v, 0, bn)
    tp = _pad_to(teach, 0, bn)
    sp = _pad_state(lfsr_state, bn, fill=1)
    w2, v2, f, s2 = _k.fused_snn_window_encode(
        wp, iw, jnp.asarray(seed, jnp.int32), vp, sp, tp,
        n_steps=n_steps, threshold=threshold, leak=leak, w_exp=w_exp,
        gain=gain, n_syn=n_syn, ltp_prob=ltp_prob, train=train,
        block_n=bn, t_chunk=t_chunk, interpret=(backend == "interp"))
    return w2[:n, :w], v2[:n], f[:n_steps, :n], s2[:n, :w]


@functools.partial(jax.jit, static_argnames=(
    "n_steps", "threshold", "leak", "w_exp", "gain", "n_syn", "t_chunk",
    "backend"))
def train_window_batch_encode(weights, intensities, seeds, v, lfsr_state,
                              teach, *, n_steps: int, threshold: int,
                              leak: int, w_exp: int, gain: int,
                              n_syn: int, ltp_prob=1023,
                              t_chunk: int | None = None,
                              backend: str = "ref"):
    """:func:`train_window_batch` with in-kernel encode.

    intensities uint8[B, n_in], seeds int | i32[B] (per-stream counter
    bases, an SMEM scalar operand like ``ltp_prob``).  Bit-exact with
    host-encoding each stream and running the pre-packed batch op.
    Returns (weights', v', fired bool[B, T, n], lfsr').
    """
    if backend == "ref":
        return _ref.train_window_batch_encode_ref(
            weights, intensities, seeds, v, lfsr_state, teach, n_steps,
            threshold, leak, w_exp, gain, n_syn, ltp_prob)
    b, n, w = weights.shape
    bn = max(_block_n(max(8, n)), 8)
    wp = _pad_to(_pad_to(weights, 2, _LANES), 1, bn)
    iw = _intensity_words(intensities, wp.shape[2])
    vp = _pad_to(v, 1, bn)
    tp = _pad_to(teach, 1, bn)
    sp = _pad_to(_pad_to(lfsr_state, 2, _LANES, fill=1), 1, bn, fill=1)
    w2, v2, f, s2 = _k.train_window_batch_encode(
        wp, iw, seeds, vp, sp, tp, n_steps=n_steps, threshold=threshold,
        leak=leak, w_exp=w_exp, gain=gain, n_syn=n_syn,
        ltp_prob=ltp_prob, block_n=bn, t_chunk=t_chunk,
        interpret=(backend == "interp"))
    return (w2[:, :n, :w], v2[:, :n], f[:, :n_steps, :n], s2[:, :n, :w])


@functools.partial(jax.jit, static_argnames=("n_steps", "threshold",
                                             "leak", "t_chunk", "backend"))
def infer_window_batch_encode(weights, intensities, seeds, *,
                              n_steps: int, threshold: int, leak: int,
                              t_total=None, t_chunk: int | None = None,
                              backend: str = "ref"):
    """Intensity-resident serving: :func:`infer_window_batch` with
    in-kernel encode and per-sample window lengths.

    intensities uint8[B, n_in], seeds int | i32[B].  ``t_total``
    (i32[B], optional) is each sample's true window length — a traced
    SMEM operand, NOT a static — so ragged serving batches share one
    compiled launch per (B, n_steps) bucket.  Returns counts i32[B, n];
    bit-exact in counts with host-encode + zero-mask + pre-packed serve
    (requires threshold >= 1, which serving enforces).
    """
    if backend == "ref":
        return _ref.infer_window_batch_encode_ref(
            weights, intensities, seeds, n_steps, threshold, leak,
            t_total)
    n, _ = weights.shape
    b = intensities.shape[0]
    bn = max(_block_n(max(8, n)), 8)
    wp = _pad_state(weights, bn)
    iw = _intensity_words(intensities, wp.shape[1])
    tt = (jnp.full((b,), n_steps, jnp.int32) if t_total is None
          else jnp.asarray(t_total, jnp.int32))
    counts = _k.infer_window_batch_encode(
        wp, iw, seeds, tt, n_steps=n_steps, threshold=threshold,
        leak=leak, block_n=bn, t_chunk=t_chunk,
        interpret=(backend == "interp"))
    return counts[:, :n]


@functools.partial(jax.jit, static_argnames=("threshold", "leak", "t_chunk",
                                             "backend"))
def infer_window_batch(weights, spike_trains, *, threshold: int, leak: int,
                       t_chunk: int | None = None, backend: str = "ref"):
    """Serving path: spike counts int32[B, n] for B windows per launch.

    spike_trains: uint32[B, T, w]; weights frozen, membrane reset per
    sample (``reset_between_samples`` semantics).  ``t_chunk`` bounds
    the VMEM spike slab as in :func:`fused_snn_window`.
    """
    if backend == "ref":
        return _ref.infer_window_batch_ref(weights, spike_trains,
                                           threshold, leak)
    n, _ = weights.shape
    t_steps = spike_trains.shape[1]
    bn = max(_block_n(max(8, n)), 8)
    wp = _pad_state(weights, bn)
    stp, tc = _pad_window(_pad_to(spike_trains, 2, _LANES), t_chunk)
    counts = _k.infer_window_batch(
        wp, stp, threshold=threshold, leak=leak, block_n=bn,
        t_chunk=tc, t_total=t_steps, interpret=(backend == "interp"))
    return counts[:, :n]
