"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic ground truth: kernel tests sweep shapes/dtypes and
assert bit-exact (integer kernels) or allclose (attention) agreement.
The SNN oracles delegate to ``repro.core`` — the core module IS the
architectural reference (ISA-level semantics); the kernels are the TPU
microarchitecture.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import lfsr as _lfsr
from repro.core.bitpack import popcount
from repro.core.lif import LIFParams, lif_step as _lif_step
from repro.core.stdp import STDPParams, stdp_update as _stdp_update


def spike_process_ref(spikes: jnp.ndarray, weights: jnp.ndarray
                      ) -> jnp.ndarray:
    """SPU: valid-spike counts.  spikes u32[w], weights u32[n, w] -> i32[n]."""
    return popcount(jnp.bitwise_and(spikes[None, :], weights))


def lif_step_ref(v: jnp.ndarray, count: jnp.ndarray, threshold: int,
                 leak: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """NU: streamlined LIF.  v,count i32[n] -> (v' i32[n], fired bool[n])."""
    return _lif_step(v, count, LIFParams(jnp.int32(threshold),
                                         jnp.int32(leak)))


def stdp_update_ref(weights, pre_spikes, post_fired, lfsr_state,
                    w_exp: int, gain: int, n_syn: int, ltp_prob: int):
    """SU: binary stochastic STDP row update (see repro.core.stdp)."""
    p = STDPParams(jnp.int32(w_exp), jnp.int32(gain), jnp.int32(n_syn),
                   jnp.uint32(ltp_prob))
    return _stdp_update(weights, pre_spikes, post_fired, lfsr_state, p)


def fused_snn_step_ref(weights, pre_spikes, v, lfsr_state, teach,
                       threshold: int, leak: int, w_exp: int, gain: int,
                       n_syn: int, ltp_prob: int, train: bool = True):
    """SNNU: one fused spike->neuron->synapse cycle.

    Returns (weights', v', fired, lfsr').  ``teach`` may be None;
    ``train=False`` leaves the SU idle (weights/LFSR pass through).
    """
    counts = spike_process_ref(pre_spikes, weights)
    if teach is not None:
        counts = counts + teach
    v2, fired = lif_step_ref(v, counts, threshold, leak)
    if not train:
        return weights, v2, fired, lfsr_state
    w2, lf2 = stdp_update_ref(weights, pre_spikes, fired, lfsr_state,
                              w_exp, gain, n_syn, ltp_prob)
    return w2, v2, fired, lf2


def fused_snn_window_ref(weights, spike_train, v, lfsr_state, teach,
                         threshold: int, leak: int, w_exp: int, gain: int,
                         n_syn: int, ltp_prob: int, train: bool = True):
    """T sequential fused SNNU cycles (the window kernel's ground truth).

    spike_train: uint32[T, w].  Returns (weights', v', fired bool[T, n],
    lfsr') — bit-exact (incl. the LFSR sequence) with T sequential
    :func:`fused_snn_step_ref` calls.
    """

    def body(carry, pre):
        w, vv, st = carry
        w2, v2, fired, st2 = fused_snn_step_ref(
            w, pre, vv, st, teach, threshold, leak, w_exp, gain,
            n_syn, ltp_prob, train)
        return (w2, v2, st2), fired

    (w2, v2, st2), fired = jax.lax.scan(
        body, (weights, v, lfsr_state), spike_train)
    return w2, v2, fired, st2


def train_window_batch_ref(weights, spike_trains, v, lfsr_state, teach,
                           threshold: int, leak: int, w_exp: int,
                           gain: int, n_syn: int, ltp_prob):
    """B independent training streams (the batched train kernel's oracle).

    weights/lfsr u32[B, n, w], spike_trains u32[B, T, w], v i32[B, n],
    teach i32[B, n]; ltp_prob is a shared int or a per-stream i32[B]
    vector (mirroring the kernel's SMEM scalar operand).  Each stream is
    exactly one :func:`fused_snn_window_ref` run — bit-exact (incl. each
    stream's LFSR sequence) with B sequential single-stream windows.
    Returns (weights', v', fired bool[B, T, n], lfsr').
    """
    b = weights.shape[0]
    lp = jnp.broadcast_to(jnp.asarray(ltp_prob, jnp.int32), (b,))

    def one(w, s, vv, st, tc, lp_b):
        return fused_snn_window_ref(w, s, vv, st, tc, threshold, leak,
                                    w_exp, gain, n_syn, lp_b, True)

    return jax.vmap(one)(weights, spike_trains, v, lfsr_state, teach, lp)


def _host_windows(seeds, intensities, n_steps: int, words: int,
                  t_total=None) -> jnp.ndarray:
    """Host counter encode shaped for the kernels (the encode oracles'
    ground truth); see :func:`repro.core.encoder.encode_windows_host`."""
    from repro.core.encoder import encode_windows_host

    return encode_windows_host(seeds, intensities, n_steps, words,
                               t_total)


def fused_snn_window_encode_ref(weights, intensities, seed, v, lfsr_state,
                                teach, n_steps: int, threshold: int,
                                leak: int, w_exp: int, gain: int,
                                n_syn: int, ltp_prob: int,
                                train: bool = True):
    """Encode-fused window oracle: host-encode, then the window oracle."""
    win = _host_windows(seed, intensities[None], n_steps,
                        weights.shape[1])[0]
    return fused_snn_window_ref(weights, win, v, lfsr_state, teach,
                                threshold, leak, w_exp, gain, n_syn,
                                ltp_prob, train)


def train_window_batch_encode_ref(weights, intensities, seeds, v,
                                  lfsr_state, teach, n_steps: int,
                                  threshold: int, leak: int, w_exp: int,
                                  gain: int, n_syn: int, ltp_prob):
    """Encode-fused batched training oracle."""
    wins = _host_windows(seeds, intensities, n_steps, weights.shape[2])
    return train_window_batch_ref(weights, wins, v, lfsr_state, teach,
                                  threshold, leak, w_exp, gain, n_syn,
                                  ltp_prob)


def infer_window_batch_encode_ref(weights, intensities, seeds,
                                  n_steps: int, threshold: int,
                                  leak: int, t_total=None):
    """Encode-fused serving oracle (ragged lengths via ``t_total``).

    Count-equality with the kernel's SMEM masking holds for any
    ``threshold >= 1``: a zero-masked cycle adds no input counts and the
    membrane only leaks, so it cannot fire (the kernel freezes v instead
    of leaking it, but v is discarded here).
    """
    wins = _host_windows(seeds, intensities, n_steps, weights.shape[1],
                         t_total)
    return infer_window_batch_ref(weights, wins, threshold, leak)


def infer_window_batch_ref(weights, spike_trains, threshold: int,
                           leak: int):
    """Serving oracle: spike counts int32[B, n], weights frozen, v reset."""
    n = weights.shape[0]

    def one(train):
        def body(vv, pre):
            counts = spike_process_ref(pre, weights)
            v2, fired = lif_step_ref(vv, counts, threshold, leak)
            return v2, fired

        _, fired = jax.lax.scan(body, jnp.zeros((n,), jnp.int32), train)
        return jnp.sum(fired.astype(jnp.int32), axis=0)

    return jax.vmap(one)(spike_trains)


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True, window: int | None = None,
                  scale: float | None = None) -> jnp.ndarray:
    """Dense reference attention.

    q: [B, Hq, Tq, D]; k, v: [B, Hkv, Tk, D] (GQA: Hq % Hkv == 0).
    window: sliding-window size (keys within [i - window + 1, i]).
    """
    b, hq, tq, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qg = qf.reshape(b, hkv, group, tq, d)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kf)
    # offset: queries are the LAST tq positions of the tk-long stream
    tk = kf.shape[2]
    qpos = jnp.arange(tq)[:, None] + (tk - tq)
    kpos = jnp.arange(tk)[None, :]
    mask = jnp.ones((tq, tk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
    return o.reshape(b, hq, tq, d).astype(q.dtype)
