"""Pallas TPU kernels for the RV-SNN datapath (SPU / NU / SU / fused SNNU).

Layout conventions
------------------
All packed operands are padded on the word axis to a multiple of 128
(the TPU lane width) by ``ops.py``; tail words are zero, which every op
here preserves (AND/popcount ignore zero words; STDP's LTP or-in of a
zero pre-word is a no-op and LTD can only clear).  The neuron axis is
blocked by ``BN`` (multiple of 8, the sublane width).

Time axis (window kernels): **state is VMEM-resident, time is
streamed**.  ``fused_snn_window`` loads the weight block, LFSR block and
membrane block once, then a ``fori_loop`` over the T presentation cycles
reads one (small) packed spike row per cycle and stores one fired row
into the raster — weights/LFSR cross HBM once per *window*, not once per
*cycle*.  The batch kernels order the grid (neuron-block major, batch
minor) so a shared weight block (inference) stays resident across all B
samples of a serving batch, and B independent training streams share one
launch.

Chunked spike streaming: every window kernel takes a ``t_chunk`` grid
dimension (innermost, so per-(block, stream) state carries across
chunks via revisited output blocks).  VMEM then holds ``T_chunk x W``
spike words instead of ``T x W`` — unbounded T at bounded VMEM.  Chunk
boundaries are bit-exact with the unchunked kernel: membrane/weight/
LFSR state is read back from the (still-resident) output block, and a
``t_total`` literal masks the zero-padded ragged tail so padded cycles
advance no state.

In-kernel encode (the ``*_encode`` kernels): the paper's on-core
Poisson encoder (§3.1, P = x per cycle) fused into the window kernels.
Instead of streaming a pre-packed ``uint32[T, W]`` spike window from
HBM, the kernel takes one uint8 intensity per input (packed 4-per-word
as ``uint32[8, W]``) plus a counter seed and draws each cycle's packed
spike row in VMEM via the stateless ``counter_hash`` (keyed on the
absolute cycle — no carried PRNG state, so chunked and sharded launches
regenerate identical spikes).  Input-stream HBM traffic per sample
drops ``T*W*4 -> 32*W`` bytes (= n_in): ~T/8x — 4x at T=32, 16x at
T=128, 256x at T=2048 — and the serving variant reads the per-sample
window length from SMEM, so one launch serves a ragged batch.

VMEM budget (per grid step, BN=128, padded words W<=2048):
  fused step:    in + out blocks of weights and LFSR
                 ~ 4 * BN * W * 4B = 4 MiB at the 64k-synapse extreme.
  train window:  the same 4 MiB of state blocks, plus the streamed
                 spike chunk T_chunk * W * 4B (256 KiB at T_chunk=32,
                 W=2048) and the bool raster chunk T_chunk * BN (4 KiB)
                 — ~4.3 MiB, *independent of T*; the unchunked launch
                 (T_chunk = T) adds T * W * 4B, which caps T near 3k
                 at W=2048 on a ~16 MiB v5e core.
  infer window:  one weight block (2 MiB) + spike chunk + v/count rows
                 — ~2.3 MiB per grid step at T_chunk=32.
  train encode:  the 4 MiB of state blocks + intensity words 8 * W * 4B
                 (64 KiB at W=2048) + the raster chunk — no spike slab
                 at ALL, so VMEM is independent of both T and T_chunk.
  infer encode:  one weight block + intensity words + v/count rows
                 — ~2.07 MiB; the T-dependent VMEM term vanishes.

The fused kernels are the TPU microarchitecture of the paper's
coarse-granularity ``snn.step`` instruction: one pass through VMEM does
spike-process + LIF + STDP, where the unfused path round-trips HBM
between the three stages — and the window kernels extend the same
argument across the time axis and the batch/stream axis
(benchmarks/kernels_bench.py measures all three levels).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# --- in-kernel LFSR (bit-exact with repro.core.lfsr) -------------------------

def _lfsr_step(state):
    fb = state
    for sh in (2, 3, 5):
        fb = jnp.bitwise_xor(fb, jnp.right_shift(state, jnp.uint32(sh)))
    fb = jnp.bitwise_and(fb, jnp.uint32(1))
    return jnp.bitwise_and(
        jnp.bitwise_or(jnp.right_shift(state, jnp.uint32(1)),
                       jnp.left_shift(fb, jnp.uint32(15))),
        jnp.uint32(0xFFFF))


def _popcount_rows(words):
    """uint32[bn, w] -> int32[bn] total set bits per row."""
    return jnp.sum(jax.lax.population_count(words).astype(jnp.int32),
                   axis=-1)


# --- in-kernel Poisson encode (bit-exact with encoder.encode_from_counter) ---

def _counter_hash(seed, cycle, idx):
    """Stateless counter draw; mirror of repro.core.lfsr.counter_hash."""
    h = (seed + cycle * jnp.uint32(0x9E3779B9)
         + idx * jnp.uint32(0x85EBCA6B))
    h = jnp.bitwise_xor(h, jnp.right_shift(h, jnp.uint32(16)))
    h = h * jnp.uint32(0x7FEB352D)
    h = jnp.bitwise_xor(h, jnp.right_shift(h, jnp.uint32(15)))
    h = h * jnp.uint32(0x846CA68B)
    return jnp.bitwise_xor(h, jnp.right_shift(h, jnp.uint32(16)))


def _encode_cycle(seed, cycle, iw):
    """Generate one cycle's packed spike row in VMEM.

    iw: uint32[8, W] intensity words — byte ``b`` of ``iw[k, wi]`` is the
    uint8 intensity of input ``wi*32 + 4k + b`` (ops.py packs this
    layout; 1 byte of HBM traffic per input instead of T/8 bytes of
    pre-packed spikes).  Returns uint32[1, W]: bit ``j`` of word ``wi``
    fires iff ``counter_hash(seed, cycle, wi*32+j) & 0xFF < intensity``
    — bit-exact with the host oracle, and intensity 0 (incl. all
    padding) never fires.
    """
    w = iw.shape[-1]
    base_idx = jax.lax.broadcasted_iota(jnp.uint32, (1, w),
                                        1) * jnp.uint32(32)
    out = jnp.zeros((1, w), jnp.uint32)
    for k in range(8):          # static: 8 intensity words x 4 bytes
        word = iw[k][None, :]
        for b in range(4):
            j = 4 * k + b
            inten = jnp.bitwise_and(
                jnp.right_shift(word, jnp.uint32(8 * b)),
                jnp.uint32(0xFF))
            h = _counter_hash(seed, cycle, base_idx + jnp.uint32(j))
            bit = (jnp.bitwise_and(h, jnp.uint32(0xFF))
                   < inten).astype(jnp.uint32)
            out = jnp.bitwise_or(out, jnp.left_shift(bit, jnp.uint32(j)))
    return out


# --- SPU: spike process -------------------------------------------------------

def _spike_process_kernel(s_ref, w_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    s = s_ref[...]          # (1, BW)
    w = w_ref[...]          # (BN, BW)
    o_ref[...] += _popcount_rows(jnp.bitwise_and(s, w))


def spike_process(spikes, weights, *, block_n=128, block_w=512,
                  interpret=False):
    """SPU kernel.  spikes u32[w], weights u32[n, w] -> counts i32[n].

    Requires n % block_n == 0 and w % block_w == 0 (ops.py pads).
    """
    n, w = weights.shape
    grid = (n // block_n, w // block_w)
    return pl.pallas_call(
        _spike_process_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_w), lambda i, j: (0, j)),
            pl.BlockSpec((block_n, block_w), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i, j: (i,)),
        interpret=interpret,
    )(spikes[None, :], weights)


# --- NU: streamlined LIF ------------------------------------------------------

def _lif_kernel(threshold: int, leak: int, v_ref, c_ref, v_out_ref, f_ref):
    # threshold/leak are Python ints -> lowered as literals.
    v = v_ref[...] + c_ref[...]
    fired = v >= threshold
    v_out_ref[...] = jnp.where(
        fired, jnp.int32(0), jnp.maximum(v - leak, jnp.int32(0)))
    f_ref[...] = fired


def lif_step(v, count, threshold: int, leak: int, *, block_n=128,
             interpret=False):
    """NU kernel.  v, count i32[n] -> (v' i32[n], fired bool[n])."""
    n = v.shape[0]
    kern = functools.partial(_lif_kernel, int(threshold), int(leak))
    return pl.pallas_call(
        kern,
        out_shape=(jax.ShapeDtypeStruct((n,), jnp.int32),
                   jax.ShapeDtypeStruct((n,), jnp.bool_)),
        grid=(n // block_n,),
        in_specs=[pl.BlockSpec((block_n,), lambda i: (i,)),
                  pl.BlockSpec((block_n,), lambda i: (i,))],
        out_specs=(pl.BlockSpec((block_n,), lambda i: (i,)),
                   pl.BlockSpec((block_n,), lambda i: (i,))),
        interpret=interpret,
    )(v, count)


# --- SU: binary stochastic STDP ----------------------------------------------

def _stdp_body(w, pre, fired, st, *, w_exp, gain, n_syn, ltp_prob):
    """Shared LTP+LTD dataflow (uint32 blocks).  Returns (w', st')."""
    fired_u = fired[:, None]
    s1 = _lfsr_step(st)
    x_ltp = jnp.bitwise_and(s1, jnp.uint32(0x3FF))
    s2 = _lfsr_step(s1)
    x_ltd = jnp.bitwise_and(s2, jnp.uint32(0x3FF))
    st_out = jnp.where(fired_u, s2, st)

    potentiate = x_ltp <= jnp.uint32(ltp_prob)
    ltp = jnp.where(potentiate, jnp.bitwise_or(w, pre), w)
    pc = _popcount_rows(ltp)
    excess = (pc - jnp.int32(w_exp)) * jnp.int32(gain) * 1024 \
        // jnp.int32(n_syn)
    prob = jnp.clip(excess, 0, 1023).astype(jnp.uint32)
    depress = x_ltd <= prob[:, None]
    ltd = jnp.where(depress, jnp.bitwise_and(ltp, pre), ltp)
    w_out = jnp.where(fired_u, ltd, w)
    return w_out, st_out


def _stdp_kernel(w_exp, gain, n_syn, ltp_prob,
                 w_ref, pre_ref, f_ref, st_ref, wo_ref, sto_ref):
    w_out, st_out = _stdp_body(
        w_ref[...], pre_ref[...], f_ref[...], st_ref[...],
        w_exp=w_exp, gain=gain, n_syn=n_syn, ltp_prob=ltp_prob)
    wo_ref[...] = w_out
    sto_ref[...] = st_out


def stdp_update(weights, pre_spikes, post_fired, lfsr_state, *,
                w_exp: int, gain: int, n_syn: int, ltp_prob: int,
                block_n=128, interpret=False):
    """SU kernel.  Whole word axis in-block (row popcount is global).

    weights/lfsr u32[n, w], pre u32[w], fired bool[n]
    -> (weights' u32[n, w], lfsr' u32[n, w]).
    """
    n, w = weights.shape
    kern = functools.partial(_stdp_kernel, w_exp, gain, n_syn, ltp_prob)
    return pl.pallas_call(
        kern,
        out_shape=(jax.ShapeDtypeStruct((n, w), jnp.uint32),
                   jax.ShapeDtypeStruct((n, w), jnp.uint32)),
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, w), lambda i: (i, 0)),
            pl.BlockSpec((1, w), lambda i: (0, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n, w), lambda i: (i, 0)),
        ],
        out_specs=(pl.BlockSpec((block_n, w), lambda i: (i, 0)),
                   pl.BlockSpec((block_n, w), lambda i: (i, 0))),
        interpret=interpret,
    )(weights, pre_spikes[None, :], post_fired, lfsr_state)


# --- fused SNNU step (the paper's coarse-granularity instruction) -------------

def _fused_kernel(threshold, leak, w_exp, gain, n_syn, ltp_prob, train,
                  w_ref, pre_ref, v_ref, st_ref, t_ref,
                  wo_ref, vo_ref, f_ref, sto_ref):
    w = w_ref[...]
    pre = pre_ref[...]
    counts = _popcount_rows(jnp.bitwise_and(pre, w)) + t_ref[...]
    v = v_ref[...] + counts
    fired = v >= threshold
    vo_ref[...] = jnp.where(
        fired, jnp.int32(0), jnp.maximum(v - leak, jnp.int32(0)))
    f_ref[...] = fired
    if train:
        w_out, st_out = _stdp_body(
            w, pre, fired, st_ref[...],
            w_exp=w_exp, gain=gain, n_syn=n_syn, ltp_prob=ltp_prob)
    else:
        w_out, st_out = w, st_ref[...]
    wo_ref[...] = w_out
    sto_ref[...] = st_out


def fused_snn_step(weights, pre_spikes, v, lfsr_state, teach, *,
                   threshold: int, leak: int, w_exp: int, gain: int,
                   n_syn: int, ltp_prob: int, train: bool = True,
                   block_n=128, interpret=False):
    """One fused SNNU cycle: SPU + NU + SU in a single VMEM pass.

    Returns (weights', v', fired, lfsr').
    """
    n, w = weights.shape
    kern = functools.partial(_fused_kernel, int(threshold), int(leak),
                             w_exp, gain, n_syn, ltp_prob, train)
    return pl.pallas_call(
        kern,
        out_shape=(jax.ShapeDtypeStruct((n, w), jnp.uint32),
                   jax.ShapeDtypeStruct((n,), jnp.int32),
                   jax.ShapeDtypeStruct((n,), jnp.bool_),
                   jax.ShapeDtypeStruct((n, w), jnp.uint32)),
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, w), lambda i: (i, 0)),
            pl.BlockSpec((1, w), lambda i: (0, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n, w), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=(pl.BlockSpec((block_n, w), lambda i: (i, 0)),
                   pl.BlockSpec((block_n,), lambda i: (i,)),
                   pl.BlockSpec((block_n,), lambda i: (i,)),
                   pl.BlockSpec((block_n, w), lambda i: (i, 0))),
        interpret=interpret,
    )(weights, pre_spikes[None, :], v, lfsr_state, teach)


# --- batched + chunked training window (B streams x T cycles per launch) -----

def _train_window_kernel(threshold, leak, w_exp, gain, n_syn,
                         t_chunk, t_total,
                         lp_ref, w_ref, s_ref, v_ref, st_ref, t_ref,
                         wo_ref, vo_ref, f_ref, sto_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        wo_ref[...] = w_ref[...]
        vo_ref[...] = v_ref[...]
        sto_ref[...] = st_ref[...]

    # per-stream LTP probability: an SMEM scalar operand rather than a
    # kernel literal, so the B streams of one launch can run different
    # active-learning schedules (ltp_prob vs ltp_prob_active)
    ltp_prob = lp_ref[0, 0]
    teach = t_ref[...][0]
    base = k * t_chunk
    masked = t_total % t_chunk != 0   # zero-padded ragged tail present

    def cycle(t, carry):
        w, v, st = carry
        pre = pl.load(s_ref, (pl.dslice(0, 1), pl.dslice(t, 1),
                              slice(None)))[0]         # (1, W)
        counts = _popcount_rows(jnp.bitwise_and(pre, w)) + teach
        v_int = v + counts
        fired = v_int >= threshold
        v_next = jnp.where(
            fired, jnp.int32(0), jnp.maximum(v_int - leak, jnp.int32(0)))
        if masked:
            active = base + t < t_total
            fired = jnp.logical_and(fired, active)
            v_next = jnp.where(active, v_next, v)
        pl.store(f_ref, (pl.dslice(0, 1), pl.dslice(t, 1), slice(None)),
                 fired[None, None, :])
        # masked `fired` also gates STDP: _stdp_body only commits w/LFSR
        # for fired rows, so padded cycles advance no state.
        w, st = _stdp_body(w, pre, fired, st, w_exp=w_exp, gain=gain,
                           n_syn=n_syn, ltp_prob=ltp_prob)
        return w, v_next, st

    w, v, st = jax.lax.fori_loop(
        0, t_chunk, cycle,
        (wo_ref[...][0], vo_ref[...][0], sto_ref[...][0]))
    wo_ref[...] = w[None]
    vo_ref[...] = v[None]
    sto_ref[...] = st[None]


def train_window_batch(weights, spike_trains, v, lfsr_state, teach, *,
                       threshold: int, leak: int, w_exp: int, gain: int,
                       n_syn: int, ltp_prob, block_n=128,
                       t_chunk: int | None = None,
                       t_total: int | None = None, interpret=False):
    """B independent training streams, T fused SNNU cycles each.

    weights/lfsr u32[B, n, w], spike_trains u32[B, T, w], v i32[B, n],
    teach i32[B, n].  Grid is (neuron blocks, batch, time chunks) —
    neuron-block major, batch next, chunk minor, so each stream's state
    block stays VMEM-resident across all its chunks (the chunk axis
    revisits the same output block; state is carried by reading it
    back).  Per stream this is bit-exact with :func:`fused_snn_window`
    (including the LFSR sequence).

    ``ltp_prob`` is an int shared by every stream or an i32[B] vector —
    it enters the kernel as an SMEM scalar operand (one (1, 1) block per
    batch grid step), NOT a lowering literal, so parallel-mode training
    keeps per-block active-learning schedules in a single launch.

    ``t_chunk`` bounds the spike words in VMEM to t_chunk * w per grid
    step (default: the whole window).  ``t_total`` masks the cycles
    beyond the true window length when T was zero-padded up to a chunk
    multiple; padded cycles store fired=False and advance no state.

    Returns (weights', v', fired bool[B, T, n], lfsr').
    """
    b, n, w = weights.shape
    t_steps = spike_trains.shape[1]
    tc = t_steps if t_chunk is None else min(t_chunk, t_steps)
    if t_steps % tc != 0:
        raise ValueError(f"T={t_steps} not a multiple of t_chunk={tc}; "
                         "pad the window (ops.py does)")
    tt = t_steps if t_total is None else t_total
    lp = jnp.asarray(ltp_prob, jnp.int32)
    if lp.ndim == 0:
        lp = jnp.broadcast_to(lp, (b,))
    if lp.shape != (b,):
        raise ValueError(f"ltp_prob must be a scalar or shape ({b},), "
                         f"got {lp.shape}")
    kern = functools.partial(_train_window_kernel, int(threshold),
                             int(leak), w_exp, gain, n_syn, tc, tt)
    return pl.pallas_call(
        kern,
        out_shape=(jax.ShapeDtypeStruct((b, n, w), jnp.uint32),
                   jax.ShapeDtypeStruct((b, n), jnp.int32),
                   jax.ShapeDtypeStruct((b, t_steps, n), jnp.bool_),
                   jax.ShapeDtypeStruct((b, n, w), jnp.uint32)),
        grid=(n // block_n, b, t_steps // tc),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, k: (j, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, block_n, w), lambda i, j, k: (j, i, 0)),
            pl.BlockSpec((1, tc, w), lambda i, j, k: (j, k, 0)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (j, i)),
            pl.BlockSpec((1, block_n, w), lambda i, j, k: (j, i, 0)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (j, i)),
        ],
        out_specs=(
            pl.BlockSpec((1, block_n, w), lambda i, j, k: (j, i, 0)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (j, i)),
            pl.BlockSpec((1, tc, block_n), lambda i, j, k: (j, k, i)),
            pl.BlockSpec((1, block_n, w), lambda i, j, k: (j, i, 0)),
        ),
        interpret=interpret,
    )(lp[:, None], weights, spike_trains, v, lfsr_state, teach)


# --- time-resident fused window (T cycles per launch) -------------------------

def _window_infer_kernel(threshold, leak, t_chunk, t_total,
                         w_ref, s_ref, v_ref, t_ref, vo_ref, f_ref):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        vo_ref[...] = v_ref[...]

    w = w_ref[...]
    teach = t_ref[...]
    base = k * t_chunk
    masked = t_total % t_chunk != 0

    def cycle(t, v):
        pre = pl.load(s_ref, (pl.dslice(t, 1), slice(None)))   # (1, W)
        v_int = v + _popcount_rows(jnp.bitwise_and(pre, w)) + teach
        fired = v_int >= threshold
        v_next = jnp.where(
            fired, jnp.int32(0), jnp.maximum(v_int - leak, jnp.int32(0)))
        if masked:
            active = base + t < t_total
            fired = jnp.logical_and(fired, active)
            v_next = jnp.where(active, v_next, v)
        pl.store(f_ref, (pl.dslice(t, 1), slice(None)), fired[None, :])
        return v_next

    vo_ref[...] = jax.lax.fori_loop(0, t_chunk, cycle, vo_ref[...])


def fused_snn_window(weights, spike_train, v, lfsr_state, teach, *,
                     threshold: int, leak: int, w_exp: int, gain: int,
                     n_syn: int, ltp_prob: int, train: bool = True,
                     block_n=128, t_chunk: int | None = None,
                     t_total: int | None = None, interpret=False):
    """T fused SNNU cycles with VMEM-resident state (one stream).

    spike_train: uint32[T, w] — the presentation window, streamed one
    row per inner-loop cycle while weights/v/LFSR stay resident; with
    ``t_chunk`` set, VMEM holds one t_chunk-row slab of the window at a
    time (see :func:`train_window_batch` for the carry/masking scheme).
    Per cycle this is bit-exact with :func:`fused_snn_step` (the LFSR
    advances through the identical sequence).

    ``train=True`` is the B=1 case of :func:`train_window_batch`.
    ``train=False`` (SU idle) dispatches to a read-only variant whose
    launch declares no weight/LFSR outputs — those arrays cross HBM
    once inbound and the originals are passed through — so the
    inference window pays none of the state write-back traffic.

    Returns (weights', v', fired bool[T, n], lfsr').
    """
    n, w = weights.shape
    t_steps = spike_train.shape[0]
    tc = t_steps if t_chunk is None else min(t_chunk, t_steps)
    if t_steps % tc != 0:
        raise ValueError(f"T={t_steps} not a multiple of t_chunk={tc}; "
                         "pad the window (ops.py does)")
    tt = t_steps if t_total is None else t_total
    if not train:
        v2, fired = pl.pallas_call(
            functools.partial(_window_infer_kernel, int(threshold),
                              int(leak), tc, tt),
            out_shape=(jax.ShapeDtypeStruct((n,), jnp.int32),
                       jax.ShapeDtypeStruct((t_steps, n), jnp.bool_)),
            grid=(n // block_n, t_steps // tc),
            in_specs=[
                pl.BlockSpec((block_n, w), lambda i, k: (i, 0)),
                pl.BlockSpec((tc, w), lambda i, k: (k, 0)),
                pl.BlockSpec((block_n,), lambda i, k: (i,)),
                pl.BlockSpec((block_n,), lambda i, k: (i,)),
            ],
            out_specs=(pl.BlockSpec((block_n,), lambda i, k: (i,)),
                       pl.BlockSpec((tc, block_n), lambda i, k: (k, i))),
            interpret=interpret,
        )(weights, spike_train, v, teach)
        return weights, v2, fired, lfsr_state
    w2, v2, fired, s2 = train_window_batch(
        weights[None], spike_train[None], v[None], lfsr_state[None],
        teach[None], threshold=threshold, leak=leak, w_exp=w_exp,
        gain=gain, n_syn=n_syn, ltp_prob=ltp_prob, block_n=block_n,
        t_chunk=tc, t_total=tt, interpret=interpret)
    return w2[0], v2[0], fired[0], s2[0]


# --- batched inference window (serving path) ----------------------------------

def _infer_window_kernel(threshold, leak, t_chunk, t_total,
                         w_ref, s_ref, o_ref, vo_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        vo_ref[...] = jnp.zeros_like(vo_ref)

    w = w_ref[...]
    base = k * t_chunk
    masked = t_total % t_chunk != 0

    def cycle(t, carry):
        v, acc = carry
        pre = pl.load(s_ref, (pl.dslice(0, 1), pl.dslice(t, 1),
                              slice(None)))[0]        # (1, W)
        v_int = v + _popcount_rows(jnp.bitwise_and(pre, w))
        fired = v_int >= threshold
        v_next = jnp.where(
            fired, jnp.int32(0), jnp.maximum(v_int - leak, jnp.int32(0)))
        if masked:
            active = base + t < t_total
            fired = jnp.logical_and(fired, active)
            v_next = jnp.where(active, v_next, v)
        return v_next, acc + fired.astype(jnp.int32)

    v, acc = jax.lax.fori_loop(
        0, t_chunk, cycle, (vo_ref[...][0], o_ref[...][0]))
    o_ref[...] = acc[None, :]
    vo_ref[...] = v[None, :]


def infer_window_batch(weights, spike_trains, *, threshold: int,
                       leak: int, block_n=128, t_chunk: int | None = None,
                       t_total: int | None = None, interpret=False):
    """Serving kernel: B frozen-weight windows per launch.

    spike_trains: uint32[B, T, w].  Grid is (neuron blocks, batch, time
    chunks) with batch/chunk minor, so each weight block is fetched once
    and reused for all B samples and all chunks.  Membrane state starts
    from reset (v=0), matching ``reset_between_samples`` semantics, and
    carries across chunks through a revisited v output block (discarded
    by the caller).

    Returns spike counts int32[B, n] over the window.
    """
    n, w = weights.shape
    b, t_steps, _ = spike_trains.shape
    tc = t_steps if t_chunk is None else min(t_chunk, t_steps)
    if t_steps % tc != 0:
        raise ValueError(f"T={t_steps} not a multiple of t_chunk={tc}; "
                         "pad the window (ops.py does)")
    tt = t_steps if t_total is None else t_total
    kern = functools.partial(_infer_window_kernel, int(threshold),
                             int(leak), tc, tt)
    counts, _ = pl.pallas_call(
        kern,
        out_shape=(jax.ShapeDtypeStruct((b, n), jnp.int32),
                   jax.ShapeDtypeStruct((b, n), jnp.int32)),
        grid=(n // block_n, b, t_steps // tc),
        in_specs=[
            pl.BlockSpec((block_n, w), lambda i, j, k: (i, 0)),
            pl.BlockSpec((1, tc, w), lambda i, j, k: (j, k, 0)),
        ],
        out_specs=(pl.BlockSpec((1, block_n), lambda i, j, k: (j, i)),
                   pl.BlockSpec((1, block_n), lambda i, j, k: (j, i))),
        interpret=interpret,
    )(weights, spike_trains)
    return counts


# --- encode-fused windows: spikes generated in VMEM, never read from HBM -----

def _t_grid(n_steps: int, t_chunk: int | None) -> tuple[int, int]:
    """(effective chunk, padded cycle count) for an encode-path launch."""
    tc = n_steps if t_chunk is None else max(1, min(t_chunk, n_steps))
    return tc, -(-n_steps // tc) * tc


def _window_infer_enc_kernel(threshold, leak, t_chunk, t_total,
                             seed_ref, w_ref, iw_ref, v_ref, t_ref,
                             vo_ref, f_ref):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        vo_ref[...] = v_ref[...]

    w = w_ref[...]
    iw = iw_ref[...]
    teach = t_ref[...]
    seed = seed_ref[0, 0].astype(jnp.uint32)
    base = k * t_chunk
    masked = t_total % t_chunk != 0

    def cycle(t, v):
        pre = _encode_cycle(seed, (base + t).astype(jnp.uint32), iw)
        v_int = v + _popcount_rows(jnp.bitwise_and(pre, w)) + teach
        fired = v_int >= threshold
        v_next = jnp.where(
            fired, jnp.int32(0), jnp.maximum(v_int - leak, jnp.int32(0)))
        if masked:
            active = base + t < t_total
            fired = jnp.logical_and(fired, active)
            v_next = jnp.where(active, v_next, v)
        pl.store(f_ref, (pl.dslice(t, 1), slice(None)), fired[None, :])
        return v_next

    vo_ref[...] = jax.lax.fori_loop(0, t_chunk, cycle, vo_ref[...])


def _infer_window_enc_kernel(threshold, leak, t_chunk,
                             seed_ref, tt_ref, w_ref, iw_ref,
                             o_ref, vo_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        vo_ref[...] = jnp.zeros_like(vo_ref)

    w = w_ref[...]
    iw = iw_ref[...][0]
    seed = seed_ref[0, 0].astype(jnp.uint32)
    # per-SAMPLE window length from SMEM (not a literal): one launch
    # serves a ragged batch, masking each stream past its own t_total
    tt = tt_ref[0, 0]
    base = k * t_chunk

    def cycle(t, carry):
        v, acc = carry
        pre = _encode_cycle(seed, (base + t).astype(jnp.uint32), iw)
        v_int = v + _popcount_rows(jnp.bitwise_and(pre, w))
        fired = v_int >= threshold
        v_next = jnp.where(
            fired, jnp.int32(0), jnp.maximum(v_int - leak, jnp.int32(0)))
        active = base + t < tt
        fired = jnp.logical_and(fired, active)
        v_next = jnp.where(active, v_next, v)
        return v_next, acc + fired.astype(jnp.int32)

    v, acc = jax.lax.fori_loop(
        0, t_chunk, cycle, (vo_ref[...][0], o_ref[...][0]))
    o_ref[...] = acc[None, :]
    vo_ref[...] = v[None, :]


def _train_window_enc_kernel(threshold, leak, w_exp, gain, n_syn,
                             t_chunk, t_total,
                             lp_ref, seed_ref, w_ref, iw_ref, v_ref,
                             st_ref, t_ref,
                             wo_ref, vo_ref, f_ref, sto_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        wo_ref[...] = w_ref[...]
        vo_ref[...] = v_ref[...]
        sto_ref[...] = st_ref[...]

    ltp_prob = lp_ref[0, 0]
    seed = seed_ref[0, 0].astype(jnp.uint32)
    iw = iw_ref[...][0]
    teach = t_ref[...][0]
    base = k * t_chunk
    masked = t_total % t_chunk != 0

    def cycle(t, carry):
        w, v, st = carry
        pre = _encode_cycle(seed, (base + t).astype(jnp.uint32), iw)
        counts = _popcount_rows(jnp.bitwise_and(pre, w)) + teach
        v_int = v + counts
        fired = v_int >= threshold
        v_next = jnp.where(
            fired, jnp.int32(0), jnp.maximum(v_int - leak, jnp.int32(0)))
        if masked:
            active = base + t < t_total
            fired = jnp.logical_and(fired, active)
            v_next = jnp.where(active, v_next, v)
        pl.store(f_ref, (pl.dslice(0, 1), pl.dslice(t, 1), slice(None)),
                 fired[None, None, :])
        # padded cycles: masked `fired` gates STDP (see train kernel)
        w, st = _stdp_body(w, pre, fired, st, w_exp=w_exp, gain=gain,
                           n_syn=n_syn, ltp_prob=ltp_prob)
        return w, v_next, st

    w, v, st = jax.lax.fori_loop(
        0, t_chunk, cycle,
        (wo_ref[...][0], vo_ref[...][0], sto_ref[...][0]))
    wo_ref[...] = w[None]
    vo_ref[...] = v[None]
    sto_ref[...] = st[None]


def train_window_batch_encode(weights, intens_words, seeds, v, lfsr_state,
                              teach, *, n_steps: int, threshold: int,
                              leak: int, w_exp: int, gain: int,
                              n_syn: int, ltp_prob, block_n=128,
                              t_chunk: int | None = None, interpret=False):
    """B training streams whose spike windows are generated in VMEM.

    Same grid/carry scheme as :func:`train_window_batch`, but the spike
    slab operand is replaced by intensity words u32[B, 8, w] (byte
    layout of :func:`_encode_cycle`) plus per-stream counter seeds
    i32[B] — each cycle's packed row is drawn on the fly, so the input
    stream shrinks from ``T*w*4`` to ``n_in`` bytes per stream and the
    draw is identical across chunkings (the hash is keyed on the
    absolute cycle).  Bit-exact with :func:`train_window_batch` fed the
    ``encoder.encode_from_counter`` host windows.

    Returns (weights', v', fired bool[B, T_pad, n], lfsr') with T_pad =
    n_steps rounded up to the chunk (callers slice to n_steps).
    """
    b, n, w = weights.shape
    tc, t_pad = _t_grid(n_steps, t_chunk)
    lp = jnp.asarray(ltp_prob, jnp.int32)
    if lp.ndim == 0:
        lp = jnp.broadcast_to(lp, (b,))
    sd = jnp.broadcast_to(jnp.asarray(seeds, jnp.int32), (b,))
    kern = functools.partial(_train_window_enc_kernel, int(threshold),
                             int(leak), w_exp, gain, n_syn, tc,
                             int(n_steps))
    return pl.pallas_call(
        kern,
        out_shape=(jax.ShapeDtypeStruct((b, n, w), jnp.uint32),
                   jax.ShapeDtypeStruct((b, n), jnp.int32),
                   jax.ShapeDtypeStruct((b, t_pad, n), jnp.bool_),
                   jax.ShapeDtypeStruct((b, n, w), jnp.uint32)),
        grid=(n // block_n, b, t_pad // tc),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, k: (j, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda i, j, k: (j, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, block_n, w), lambda i, j, k: (j, i, 0)),
            pl.BlockSpec((1, 8, w), lambda i, j, k: (j, 0, 0)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (j, i)),
            pl.BlockSpec((1, block_n, w), lambda i, j, k: (j, i, 0)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (j, i)),
        ],
        out_specs=(
            pl.BlockSpec((1, block_n, w), lambda i, j, k: (j, i, 0)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (j, i)),
            pl.BlockSpec((1, tc, block_n), lambda i, j, k: (j, k, i)),
            pl.BlockSpec((1, block_n, w), lambda i, j, k: (j, i, 0)),
        ),
        interpret=interpret,
    )(lp[:, None], sd[:, None], weights, intens_words, v, lfsr_state,
      teach)


def fused_snn_window_encode(weights, intens_words, seed, v, lfsr_state,
                            teach, *, n_steps: int, threshold: int,
                            leak: int, w_exp: int, gain: int, n_syn: int,
                            ltp_prob: int, train: bool = True,
                            block_n=128, t_chunk: int | None = None,
                            interpret=False):
    """One stream, T cycles, spikes generated in VMEM (B=1 of the
    batched encode grid; ``train=False`` uses a read-only variant as in
    :func:`fused_snn_window`).

    intens_words u32[8, w], seed i32 scalar.  Returns
    (weights', v', fired bool[T_pad, n], lfsr').
    """
    n, w = weights.shape
    tc, t_pad = _t_grid(n_steps, t_chunk)
    if not train:
        sd = jnp.reshape(jnp.asarray(seed, jnp.int32), (1, 1))
        v2, fired = pl.pallas_call(
            functools.partial(_window_infer_enc_kernel, int(threshold),
                              int(leak), tc, int(n_steps)),
            out_shape=(jax.ShapeDtypeStruct((n,), jnp.int32),
                       jax.ShapeDtypeStruct((t_pad, n), jnp.bool_)),
            grid=(n // block_n, t_pad // tc),
            in_specs=[
                pl.BlockSpec((1, 1), lambda i, k: (0, 0),
                             memory_space=pltpu.SMEM),
                pl.BlockSpec((block_n, w), lambda i, k: (i, 0)),
                pl.BlockSpec((8, w), lambda i, k: (0, 0)),
                pl.BlockSpec((block_n,), lambda i, k: (i,)),
                pl.BlockSpec((block_n,), lambda i, k: (i,)),
            ],
            out_specs=(pl.BlockSpec((block_n,), lambda i, k: (i,)),
                       pl.BlockSpec((tc, block_n), lambda i, k: (k, i))),
            interpret=interpret,
        )(sd, weights, intens_words, v, teach)
        return weights, v2, fired, lfsr_state
    w2, v2, fired, s2 = train_window_batch_encode(
        weights[None], intens_words[None], jnp.asarray(seed, jnp.int32),
        v[None], lfsr_state[None], teach[None], n_steps=n_steps,
        threshold=threshold, leak=leak, w_exp=w_exp, gain=gain,
        n_syn=n_syn, ltp_prob=ltp_prob, block_n=block_n, t_chunk=tc,
        interpret=interpret)
    return w2[0], v2[0], fired[0], s2[0]


def infer_window_batch_encode(weights, intens_words, seeds, t_totals, *,
                              n_steps: int, threshold: int, leak: int,
                              block_n=128, t_chunk: int | None = None,
                              interpret=False):
    """Serving kernel, intensity-resident: B windows generated in VMEM.

    intens_words u32[B, 8, w], seeds i32[B], t_totals i32[B] — the
    per-sample window length is an SMEM scalar (NOT a literal), so one
    launch serves a ragged batch: stream j's cycles at or past
    ``t_totals[j]`` store no spikes and advance no state.  Zero-intensity
    batch padding is silent by construction.  Bit-exact with
    :func:`infer_window_batch` fed host-encoded (and zero-masked)
    windows.  Returns spike counts int32[B, n].
    """
    n, w = weights.shape
    b = intens_words.shape[0]
    tc, t_pad = _t_grid(n_steps, t_chunk)
    sd = jnp.broadcast_to(jnp.asarray(seeds, jnp.int32), (b,))
    tt = jnp.broadcast_to(jnp.asarray(t_totals, jnp.int32), (b,))
    kern = functools.partial(_infer_window_enc_kernel, int(threshold),
                             int(leak), tc)
    counts, _ = pl.pallas_call(
        kern,
        out_shape=(jax.ShapeDtypeStruct((b, n), jnp.int32),
                   jax.ShapeDtypeStruct((b, n), jnp.int32)),
        grid=(n // block_n, b, t_pad // tc),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, k: (j, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda i, j, k: (j, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((block_n, w), lambda i, j, k: (i, 0)),
            pl.BlockSpec((1, 8, w), lambda i, j, k: (j, 0, 0)),
        ],
        out_specs=(pl.BlockSpec((1, block_n), lambda i, j, k: (j, i)),
                   pl.BlockSpec((1, block_n), lambda i, j, k: (j, i))),
        interpret=interpret,
    )(sd[:, None], tt[:, None], weights, intens_words)
    return counts
