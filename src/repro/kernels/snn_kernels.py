"""Pallas TPU kernels for the RV-SNN datapath (SPU / NU / SU / fused SNNU).

Layout conventions
------------------
All packed operands are padded on the word axis to a multiple of 128
(the TPU lane width) by ``ops.py``; tail words are zero, which every op
here preserves (AND/popcount ignore zero words; STDP's LTP or-in of a
zero pre-word is a no-op and LTD can only clear).  The neuron axis is
blocked by ``BN`` (multiple of 8, the sublane width).

VMEM budget (per grid step, BN=128, padded words W<=2048):
  fused step: weights + lfsr + outputs ~ 4 * BN * W * 4B = 4 MiB at the
  64k-synapse extreme, comfortably under the ~16 MiB v5e VMEM.

The fused kernel is the TPU microarchitecture of the paper's
coarse-granularity ``snn.step`` instruction: one pass through VMEM does
spike-process + LIF + STDP, where the unfused path round-trips HBM
between the three stages (benchmarked in benchmarks/kernels_bench.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# --- in-kernel LFSR (bit-exact with repro.core.lfsr) -------------------------

def _lfsr_step(state):
    fb = state
    for sh in (2, 3, 5):
        fb = jnp.bitwise_xor(fb, jnp.right_shift(state, jnp.uint32(sh)))
    fb = jnp.bitwise_and(fb, jnp.uint32(1))
    return jnp.bitwise_and(
        jnp.bitwise_or(jnp.right_shift(state, jnp.uint32(1)),
                       jnp.left_shift(fb, jnp.uint32(15))),
        jnp.uint32(0xFFFF))


def _popcount_rows(words):
    """uint32[bn, w] -> int32[bn] total set bits per row."""
    return jnp.sum(jax.lax.population_count(words).astype(jnp.int32),
                   axis=-1)


# --- SPU: spike process -------------------------------------------------------

def _spike_process_kernel(s_ref, w_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    s = s_ref[...]          # (1, BW)
    w = w_ref[...]          # (BN, BW)
    o_ref[...] += _popcount_rows(jnp.bitwise_and(s, w))


def spike_process(spikes, weights, *, block_n=128, block_w=512,
                  interpret=False):
    """SPU kernel.  spikes u32[w], weights u32[n, w] -> counts i32[n].

    Requires n % block_n == 0 and w % block_w == 0 (ops.py pads).
    """
    n, w = weights.shape
    grid = (n // block_n, w // block_w)
    return pl.pallas_call(
        _spike_process_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_w), lambda i, j: (0, j)),
            pl.BlockSpec((block_n, block_w), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i, j: (i,)),
        interpret=interpret,
    )(spikes[None, :], weights)


# --- NU: streamlined LIF ------------------------------------------------------

def _lif_kernel(threshold: int, leak: int, v_ref, c_ref, v_out_ref, f_ref):
    # threshold/leak are Python ints -> lowered as literals.
    v = v_ref[...] + c_ref[...]
    fired = v >= threshold
    v_out_ref[...] = jnp.where(
        fired, jnp.int32(0), jnp.maximum(v - leak, jnp.int32(0)))
    f_ref[...] = fired


def lif_step(v, count, threshold: int, leak: int, *, block_n=128,
             interpret=False):
    """NU kernel.  v, count i32[n] -> (v' i32[n], fired bool[n])."""
    n = v.shape[0]
    kern = functools.partial(_lif_kernel, int(threshold), int(leak))
    return pl.pallas_call(
        kern,
        out_shape=(jax.ShapeDtypeStruct((n,), jnp.int32),
                   jax.ShapeDtypeStruct((n,), jnp.bool_)),
        grid=(n // block_n,),
        in_specs=[pl.BlockSpec((block_n,), lambda i: (i,)),
                  pl.BlockSpec((block_n,), lambda i: (i,))],
        out_specs=(pl.BlockSpec((block_n,), lambda i: (i,)),
                   pl.BlockSpec((block_n,), lambda i: (i,))),
        interpret=interpret,
    )(v, count)


# --- SU: binary stochastic STDP ----------------------------------------------

def _stdp_body(w, pre, fired, st, *, w_exp, gain, n_syn, ltp_prob):
    """Shared LTP+LTD dataflow (uint32 blocks).  Returns (w', st')."""
    fired_u = fired[:, None]
    s1 = _lfsr_step(st)
    x_ltp = jnp.bitwise_and(s1, jnp.uint32(0x3FF))
    s2 = _lfsr_step(s1)
    x_ltd = jnp.bitwise_and(s2, jnp.uint32(0x3FF))
    st_out = jnp.where(fired_u, s2, st)

    potentiate = x_ltp <= jnp.uint32(ltp_prob)
    ltp = jnp.where(potentiate, jnp.bitwise_or(w, pre), w)
    pc = _popcount_rows(ltp)
    excess = (pc - jnp.int32(w_exp)) * jnp.int32(gain) * 1024 \
        // jnp.int32(n_syn)
    prob = jnp.clip(excess, 0, 1023).astype(jnp.uint32)
    depress = x_ltd <= prob[:, None]
    ltd = jnp.where(depress, jnp.bitwise_and(ltp, pre), ltp)
    w_out = jnp.where(fired_u, ltd, w)
    return w_out, st_out


def _stdp_kernel(w_exp, gain, n_syn, ltp_prob,
                 w_ref, pre_ref, f_ref, st_ref, wo_ref, sto_ref):
    w_out, st_out = _stdp_body(
        w_ref[...], pre_ref[...], f_ref[...], st_ref[...],
        w_exp=w_exp, gain=gain, n_syn=n_syn, ltp_prob=ltp_prob)
    wo_ref[...] = w_out
    sto_ref[...] = st_out


def stdp_update(weights, pre_spikes, post_fired, lfsr_state, *,
                w_exp: int, gain: int, n_syn: int, ltp_prob: int,
                block_n=128, interpret=False):
    """SU kernel.  Whole word axis in-block (row popcount is global).

    weights/lfsr u32[n, w], pre u32[w], fired bool[n]
    -> (weights' u32[n, w], lfsr' u32[n, w]).
    """
    n, w = weights.shape
    kern = functools.partial(_stdp_kernel, w_exp, gain, n_syn, ltp_prob)
    return pl.pallas_call(
        kern,
        out_shape=(jax.ShapeDtypeStruct((n, w), jnp.uint32),
                   jax.ShapeDtypeStruct((n, w), jnp.uint32)),
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, w), lambda i: (i, 0)),
            pl.BlockSpec((1, w), lambda i: (0, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n, w), lambda i: (i, 0)),
        ],
        out_specs=(pl.BlockSpec((block_n, w), lambda i: (i, 0)),
                   pl.BlockSpec((block_n, w), lambda i: (i, 0))),
        interpret=interpret,
    )(weights, pre_spikes[None, :], post_fired, lfsr_state)


# --- fused SNNU step (the paper's coarse-granularity instruction) -------------

def _fused_kernel(threshold, leak, w_exp, gain, n_syn, ltp_prob, train,
                  w_ref, pre_ref, v_ref, st_ref, t_ref,
                  wo_ref, vo_ref, f_ref, sto_ref):
    w = w_ref[...]
    pre = pre_ref[...]
    counts = _popcount_rows(jnp.bitwise_and(pre, w)) + t_ref[...]
    v = v_ref[...] + counts
    fired = v >= threshold
    vo_ref[...] = jnp.where(
        fired, jnp.int32(0), jnp.maximum(v - leak, jnp.int32(0)))
    f_ref[...] = fired
    if train:
        w_out, st_out = _stdp_body(
            w, pre, fired, st_ref[...],
            w_exp=w_exp, gain=gain, n_syn=n_syn, ltp_prob=ltp_prob)
    else:
        w_out, st_out = w, st_ref[...]
    wo_ref[...] = w_out
    sto_ref[...] = st_out


def fused_snn_step(weights, pre_spikes, v, lfsr_state, teach, *,
                   threshold: int, leak: int, w_exp: int, gain: int,
                   n_syn: int, ltp_prob: int, train: bool = True,
                   block_n=128, interpret=False):
    """One fused SNNU cycle: SPU + NU + SU in a single VMEM pass.

    Returns (weights', v', fired, lfsr').
    """
    n, w = weights.shape
    kern = functools.partial(_fused_kernel, int(threshold), int(leak),
                             w_exp, gain, n_syn, ltp_prob, train)
    return pl.pallas_call(
        kern,
        out_shape=(jax.ShapeDtypeStruct((n, w), jnp.uint32),
                   jax.ShapeDtypeStruct((n,), jnp.int32),
                   jax.ShapeDtypeStruct((n,), jnp.bool_),
                   jax.ShapeDtypeStruct((n, w), jnp.uint32)),
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, w), lambda i: (i, 0)),
            pl.BlockSpec((1, w), lambda i: (0, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n, w), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=(pl.BlockSpec((block_n, w), lambda i: (i, 0)),
                   pl.BlockSpec((block_n,), lambda i: (i,)),
                   pl.BlockSpec((block_n,), lambda i: (i,)),
                   pl.BlockSpec((block_n, w), lambda i: (i, 0))),
        interpret=interpret,
    )(weights, pre_spikes[None, :], v, lfsr_state, teach)
