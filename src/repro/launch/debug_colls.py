"""Debug tool: top collective / largest-tensor contributors in a cell's
compiled HLO.  Usage:
  python -m repro.launch.debug_colls --arch gemma3-1b --shape train_4k
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse  # noqa: E402
import re  # noqa: E402

from repro.configs import SHAPES, get_config  # noqa: E402
from repro.launch import dryrun as dr  # noqa: E402
from repro.launch.hlo_cost import (HloCostModel, _parse_op, _shape_info,  # noqa: E402
                                   _TRIP_RE, _BODY_RE, _CALLS_RE)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--top", type=int, default=15)
    args = ap.parse_args()

    shape = SHAPES[args.shape]
    import jax
    from repro.distributed import sharding as shd
    from repro.distributed.specs import (cache_logical_tree,
                                         param_logical_tree, to_shardings)
    from repro.launch import inputs as inp
    from repro.launch.mesh import make_production_mesh
    import jax.numpy as jnp

    mesh = make_production_mesh(multi_pod=args.multipod)
    model = dr.build_model(args.arch)
    rules = dr.rules_for(args.arch, shape, mesh)
    from repro.launch.train import make_train_step
    from repro.launch.serve import make_prefill_step, make_serve_step
    cfg = model.cfg
    with shd.use_mesh(mesh, rules):
        params_shape = jax.eval_shape(
            lambda: model.init_params(jax.random.key(0)))
        p_sh = to_shardings(mesh, rules, param_logical_tree(params_shape), params_shape)
        none_sh = jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec())
        if shape.kind == "train":
            opt = dr.make_optimizer(args.arch)
            opt_shape = jax.eval_shape(opt.init, params_shape)
            o_sh = {"m": p_sh, "v": p_sh, "step": none_sh}
            b_sh = to_shardings(mesh, rules,
                                inp.input_logical(cfg, shape))
            step = make_train_step(model, opt,
                                   accum_steps=dr.ACCUM.get(args.arch, 1))
            lowered = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh,
                                                  none_sh),
                              donate_argnums=(0, 1)).lower(
                params_shape, opt_shape, inp.input_specs(cfg, shape),
                inp.rng_spec())
        elif shape.kind == "prefill":
            b_sh = to_shardings(mesh, rules,
                                inp.input_logical(cfg, shape))
            step = make_prefill_step(model, max_len=shape.seq_len)
            lowered = jax.jit(step, in_shardings=(p_sh, b_sh)).lower(
                params_shape, inp.input_specs(cfg, shape))
        else:
            cache_shape = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch,
                                         shape.seq_len))
            c_sh = to_shardings(mesh, rules,
                                cache_logical_tree(cache_shape),
                                cache_shape)
            tok_spec, tok_log = inp.decode_token_specs(cfg, shape)
            t_sh = to_shardings(mesh, rules, {"t": tok_log})["t"]
            step = make_serve_step(model)
            lowered = jax.jit(step, in_shardings=(p_sh, t_sh, c_sh,
                                                  none_sh),
                              donate_argnums=(2,)).lower(
                params_shape, tok_spec, cache_shape,
                jax.ShapeDtypeStruct((), jnp.int32))
        compiled = lowered.compile()
    txt = compiled.as_text()

    # per-collective-op totals with trip multipliers
    cm = HloCostModel(txt)
    trips: dict[str, float] = {cm.entry: 1.0}
    # propagate trip counts through while/call/fusion references
    order = [cm.entry]
    seen = {cm.entry}
    while order:
        name = order.pop(0)
        mult = trips.get(name, 1.0)
        for line in cm.computations.get(name, []):
            p = _parse_op(line)
            if not p:
                continue
            _, _, opcode, _, attrs = p
            t = 1.0
            mt = _TRIP_RE.search(attrs)
            if opcode == "while" and mt:
                t = float(mt.group(1))
            for rx in (_BODY_RE, _CALLS_RE):
                mm = rx.search(attrs)
                if mm:
                    child = mm.group(1)
                    trips[child] = max(trips.get(child, 0), mult * t)
                    if child not in seen:
                        seen.add(child)
                        order.append(child)
    rows = []
    for name, lines in cm.computations.items():
        mult = trips.get(name, 1.0)
        for line in lines:
            p = _parse_op(line)
            if not p:
                continue
            nm, out_type, opcode, _, attrs = p
            base = opcode.replace("-start", "")
            if base in ("all-gather", "all-reduce", "reduce-scatter",
                        "all-to-all", "collective-permute") \
                    and not opcode.endswith("-done"):
                b, _ = _shape_info(out_type)
                meta = re.search(r'op_name="([^"]*)"', attrs)
                rows.append((b * mult, b, mult, base,
                             (meta.group(1) if meta else nm)[:110]))
    rows.sort(reverse=True)
    print("\nTop collectives (total_bytes x trips):")
    for tot, b, mult, kind, opname in rows[:args.top]:
        print(f"  {tot/1e9:8.2f} GB  ({b/1e6:8.1f} MB x {mult:4.0f})  "
              f"{kind:<18s} {opname}")


if __name__ == "__main__":
    main()
