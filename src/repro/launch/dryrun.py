import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. picks the sharding rules for the arch (heads-TP vs sequence-
     parallel; batch rules degrade gracefully when B < shards),
  3. jits the train / prefill / serve step with NamedShardings derived
     from the logical spec trees and lowers it against ShapeDtypeStruct
     inputs (no allocation),
  4. compiles, records memory_analysis / cost_analysis / collective
     bytes (launch/roofline.py), and appends to the results JSON.

Usage:
  python -m repro.launch.dryrun --arch gemma3-1b --shape train_4k
  python -m repro.launch.dryrun --all [--mesh both|pod|multipod]
  python -m repro.launch.dryrun --all --out experiments/dryrun.json
"""  # noqa: E402

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, applicable_shapes, get_config
from repro.configs.shapes import LONG_SKIP_REASONS, ShapeSpec
from repro.distributed import sharding as shd
from repro.distributed.specs import (cache_logical_tree, param_logical_tree,
                                     to_shardings)
from repro.launch import inputs as inp
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import analyze, estimate_tpu_peak, model_flops
from repro.launch.serve import make_prefill_step, make_serve_step
from repro.launch.train import make_train_step
from repro.models.transformer import Model
from repro.optim.adamw import AdamW, AdamWConfig

ARCHS = [
    "whisper-small", "mixtral-8x22b", "grok-1-314b", "rwkv6-7b",
    "starcoder2-3b", "command-r-35b", "gemma3-1b", "llama3-405b",
    "jamba-1.5-large-398b", "internvl2-26b",
]

# Archs whose head counts don't divide model=16 -> sequence-parallel attn.
SEQPAR = {"gemma3-1b", "whisper-small", "starcoder2-3b"}

# Microbatch accumulation for the train shape (keeps activations in HBM).
ACCUM = {
    "llama3-405b": 8, "jamba-1.5-large-398b": 8, "grok-1-314b": 4,
    "command-r-35b": 4, "mixtral-8x22b": 4, "internvl2-26b": 4,
    "rwkv6-7b": 2, "starcoder2-3b": 1, "gemma3-1b": 1,
    "whisper-small": 1,
}

# >=100B-class archs train with bf16 states + stochastic rounding
# (8 bytes/param total; see repro.optim.adamw).
BF16_STATE = {"llama3-405b", "jamba-1.5-large-398b", "grok-1-314b",
              "mixtral-8x22b"}


def rules_for(arch: str, shape: ShapeSpec, mesh) -> dict:
    overrides = {}
    if arch in SEQPAR:
        overrides.update(shd.SEQPAR_RULES_OVERRIDES)
    n_batch_shards = 1
    for ax in ("pod", "data"):
        n_batch_shards *= mesh.shape.get(ax, 1)
    if shape.global_batch % n_batch_shards != 0:
        overrides["batch"] = ("data",) if shape.global_batch % \
            mesh.shape.get("data", 1) == 0 else None
    return shd.use_rules(**overrides)


# §Perf hillclimb variants: model-construction overrides, selected with
# --variant; results are keyed "<cell>#<variant>" so baselines persist.
VARIANTS: dict[str, dict] = {
    "rwkv-chunk32": {"rwkv_chunk": 32},
    "rwkv-chunk64": {"rwkv_chunk": 64},
    "rwkv-chunk128": {"rwkv_chunk": 128},
}

# train-step accumulation overrides per variant (hillclimb B)
VARIANT_ACCUM: dict[str, int] = {
    "accum16": 16,
    "accum32": 32,
}
for _v in VARIANT_ACCUM:
    VARIANTS.setdefault(_v, {})


def build_model(arch: str, variant: str | None = None) -> Model:
    cfg = get_config(arch)
    kw = dict(VARIANTS.get(variant, {}))
    return Model(cfg, dtype=jnp.bfloat16, remat=True, **kw)


def make_optimizer(arch: str) -> AdamW:
    if arch in BF16_STATE:
        return AdamW(AdamWConfig(state_dtype=jnp.bfloat16,
                                 stochastic_rounding=True))
    return AdamW(AdamWConfig(state_dtype=jnp.float32))


def lower_cell(arch: str, shape: ShapeSpec, multi_pod: bool,
               *, variant: str | None = None,
               compile_only_summary: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = 1
    for v in mesh.shape.values():
        chips *= v
    model = build_model(arch, variant)
    cfg = model.cfg
    rules = rules_for(arch, shape, mesh)

    t0 = time.perf_counter()
    with shd.use_mesh(mesh, rules):
        params_shape = jax.eval_shape(
            lambda: model.init_params(jax.random.key(0)))
        p_log = param_logical_tree(params_shape)
        p_sh = to_shardings(mesh, rules, p_log, params_shape)
        batch_shape = inp.input_specs(cfg, shape)
        b_log = inp.input_logical(cfg, shape)
        b_sh = to_shardings(mesh, rules, b_log)
        none_sh = jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec())

        accum = VARIANT_ACCUM.get(variant or "", ACCUM.get(arch, 1))
        if shape.kind == "train":
            opt = make_optimizer(arch)
            opt_shape = jax.eval_shape(opt.init, params_shape)
            o_sh = {"m": p_sh, "v": p_sh, "step": none_sh}
            step = make_train_step(model, opt, accum_steps=accum)
            fn = jax.jit(step,
                         in_shardings=(p_sh, o_sh, b_sh, none_sh),
                         donate_argnums=(0, 1))
            lowered = fn.lower(params_shape, opt_shape, batch_shape,
                               inp.rng_spec())
        elif shape.kind == "prefill":
            step = make_prefill_step(model, max_len=shape.seq_len)
            fn = jax.jit(step, in_shardings=(p_sh, b_sh))
            lowered = fn.lower(params_shape, batch_shape)
        else:  # decode
            cache_shape = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch,
                                         shape.seq_len))
            c_log = cache_logical_tree(cache_shape)
            c_sh = to_shardings(mesh, rules, c_log, cache_shape)
            tok_spec, tok_log = inp.decode_token_specs(cfg, shape)
            t_sh = to_shardings(mesh, rules, {"t": tok_log})["t"]
            step = make_serve_step(model)
            fn = jax.jit(step,
                         in_shardings=(p_sh, t_sh, c_sh, none_sh),
                         donate_argnums=(2,))
            lowered = fn.lower(params_shape, tok_spec, cache_shape,
                               jax.ShapeDtypeStruct((), jnp.int32))
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    rl = analyze(compiled, chips)
    mf = model_flops(cfg, shape)
    bytes_per_dev = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                     + mem.output_size_in_bytes
                     - mem.alias_size_in_bytes)
    est_peak = estimate_tpu_peak(
        cfg, shape, chips, mesh.shape.get("model", 1),
        accum if shape.kind == "train" else 1,
        mem.argument_size_in_bytes)
    result = {
        "arch": arch, "shape": shape.name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "arg_bytes": mem.argument_size_in_bytes,
        "temp_bytes": mem.temp_size_in_bytes,
        "out_bytes": mem.output_size_in_bytes,
        "alias_bytes": mem.alias_size_in_bytes,
        "peak_bytes_per_device": bytes_per_dev,
        "est_tpu_peak_bytes": est_peak,
        "fits_16GB_cpu_temp": bool(bytes_per_dev < 16e9),
        "fits_16GB": bool(est_peak < 16e9),
        "roofline": rl.summary(),
        "model_flops_total": mf,
        "model_flops_per_chip": mf / chips,
        "useful_flops_frac": (mf / chips) / max(rl.flops, 1.0),
    }
    return result


def load_results(path: Path) -> dict:
    if path.exists():
        return json.loads(path.read_text())
    return {}


def save_results(path: Path, results: dict) -> None:
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(results, indent=1, sort_keys=True))


def cell_key(arch, shape_name, multi_pod):
    return f"{arch}|{shape_name}|{'2x16x16' if multi_pod else '16x16'}"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="both",
                    choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default=None, choices=sorted(VARIANTS))
    ap.add_argument("--out", default="experiments/dryrun_results.json")
    args = ap.parse_args()

    out = Path(args.out)
    results = load_results(out)

    cells = []
    archs = ARCHS if (args.all or not args.arch) else [args.arch]
    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]
    for arch in archs:
        cfg = get_config(arch)
        shapes = ([SHAPES[args.shape]] if args.shape
                  else applicable_shapes(cfg))
        for s in shapes:
            for mp in meshes:
                cells.append((arch, s, mp))
    # record skips
    for arch in archs:
        if arch in LONG_SKIP_REASONS and (not args.shape
                                          or args.shape == "long_500k"):
            for mp in meshes:
                results[cell_key(arch, "long_500k", mp)] = {
                    "arch": arch, "shape": "long_500k",
                    "mesh": "2x16x16" if mp else "16x16",
                    "status": "skipped",
                    "reason": LONG_SKIP_REASONS[arch],
                }

    for arch, s, mp in cells:
        key = cell_key(arch, s.name, mp)
        if args.variant:
            key = f"{key}#{args.variant}"
        if not args.force and results.get(key, {}).get("status") == "ok":
            print(f"[skip cached] {key}", flush=True)
            continue
        print(f"[cell] {key} ...", flush=True)
        try:
            res = lower_cell(arch, s, mp, variant=args.variant)
            print(f"  -> {res['status']} compile={res['compile_s']}s "
                  f"peak={res['peak_bytes_per_device']/1e9:.2f}GB "
                  f"dominant={res['roofline']['dominant']}", flush=True)
        except Exception as e:  # noqa: BLE001
            res = {"arch": arch, "shape": s.name,
                   "mesh": "2x16x16" if mp else "16x16",
                   "status": "error", "error": str(e)[:2000],
                   "trace": traceback.format_exc()[-4000:]}
            print(f"  -> ERROR {str(e)[:300]}", flush=True)
        results[key] = res
        save_results(out, results)

    n_ok = sum(1 for r in results.values() if r.get("status") == "ok")
    print(f"done: {n_ok} ok / {len(results)} recorded")


if __name__ == "__main__":
    main()
