import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dry-run + §Perf hillclimb C for the paper's own workload.

Production-scale Wenquxing 22A deployment: a 4096-neuron active-learning
ensemble (102 x the paper's 40-neuron network) classifying a 4096-sample
batch (72 Poisson cycles each), plus an online-STDP training stream —
sharded population x batch over the 16x16 / 2x16x16 production meshes
(neurons -> model, batch -> data; every neuron row is independent, so
population parallelism is exact).

Two variants quantify the paper's central design choice on TPU:

  packed   (this work): 1-bit synapses in uint32 lanes, AND+popcount
  unpacked (naive port): 0/1 weights as int8, counts via dense matmul

Usage:  python -m repro.launch.dryrun_snn [--mesh pod|multipod|both]
"""  # noqa: E402

import argparse  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs.wenquxing_snn import WENQUXING_22A  # noqa: E402
from repro.core.bitpack import n_words  # noqa: E402
from repro.core.lif import LIFParams  # noqa: E402
from repro.core.stdp import STDPParams  # noqa: E402
from repro.launch.dryrun import load_results, save_results  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.roofline import analyze  # noqa: E402

N_NEURONS = 4096
N_INPUTS = 784
BATCH = 4096
T = WENQUXING_22A.n_steps
STREAM = 8  # online-training samples per lowered step

LIF = LIFParams(jnp.int32(WENQUXING_22A.threshold),
                jnp.int32(WENQUXING_22A.leak))
STDP = STDPParams(jnp.int32(WENQUXING_22A.w_exp),
                  jnp.int32(WENQUXING_22A.gain), jnp.int32(N_INPUTS),
                  jnp.uint32(WENQUXING_22A.ltp_prob))


# --- packed (paper-faithful) ----------------------------------------------------

def infer_packed(weights, spike_trains):
    """weights u32[N, W]; spike_trains u32[B, T, W] -> counts i32[B, N]."""
    from repro.core.network import infer_batch
    return infer_batch(weights, spike_trains, LIF)


def train_packed(weights, lfsr_state, spike_trains, teach):
    """Online STDP over a sample stream (sequential, as in hardware).

    spike_trains u32[S, T, W]; teach i32[S, N]."""
    from repro.core.rvsnn import SnnRegFile
    from repro.core.network import train_stream
    rf = SnnRegFile(spike=jnp.zeros((weights.shape[1],), jnp.uint32),
                    v=jnp.zeros((weights.shape[0],), jnp.int32),
                    lfsr=lfsr_state, weights=weights)
    rf2, counts = train_stream(rf, spike_trains, teach, LIF, STDP)
    return rf2.weights, rf2.lfsr, counts


# --- unpacked (naive port baseline) ---------------------------------------------

def infer_unpacked(weights8, spikes8):
    """weights8 i8[N, 784]; spikes8 i8[B, T, 784] -> counts i32[B, N].

    The dynamics are identical; the synaptic AND+count becomes a dense
    int matmul — what a direct JAX port without the paper's 1-bit
    bit-packing would do."""
    def sample(train):
        def cycle(v, spk):
            counts = jnp.einsum("i,ni->n", spk.astype(jnp.int32),
                                weights8.astype(jnp.int32))
            v2 = v + counts
            fired = v2 >= LIF.threshold
            v3 = jnp.where(fired, 0, jnp.maximum(v2 - LIF.leak, 0))
            return v3, fired
        _, fired = jax.lax.scan(
            cycle, jnp.zeros((weights8.shape[0],), jnp.int32), train)
        return fired.astype(jnp.int32).sum(0)
    return jax.vmap(sample)(spikes8)


def lower_snn(kind: str, multi_pod: bool, packed: bool) -> dict:
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = 1
    for v in mesh.shape.values():
        chips *= v
    dp = ("pod", "data") if multi_pod else ("data",)
    W = n_words(N_INPUTS)

    t0 = time.perf_counter()
    if kind == "infer":
        if packed:
            w_s = jax.ShapeDtypeStruct((N_NEURONS, W), jnp.uint32)
            s_s = jax.ShapeDtypeStruct((BATCH, T, W), jnp.uint32)
            fn = infer_packed
        else:
            w_s = jax.ShapeDtypeStruct((N_NEURONS, N_INPUTS), jnp.int8)
            s_s = jax.ShapeDtypeStruct((BATCH, T, N_INPUTS), jnp.int8)
            fn = infer_unpacked
        w_sh = NamedSharding(mesh, P("model", None))
        s_sh = NamedSharding(mesh, P(dp, None, None))
        lowered = jax.jit(fn, in_shardings=(w_sh, s_sh)).lower(w_s, s_s)
    else:  # train (packed only — the 1-bit LTP/LTD has no unpacked twin)
        w_s = jax.ShapeDtypeStruct((N_NEURONS, W), jnp.uint32)
        l_s = jax.ShapeDtypeStruct((N_NEURONS, W), jnp.uint32)
        s_s = jax.ShapeDtypeStruct((STREAM, T, W), jnp.uint32)
        t_s = jax.ShapeDtypeStruct((STREAM, N_NEURONS), jnp.int32)
        row = NamedSharding(mesh, P("model", None))
        rep = NamedSharding(mesh, P())
        tch = NamedSharding(mesh, P(None, "model"))
        lowered = jax.jit(
            train_packed, in_shardings=(row, row, rep, tch),
            donate_argnums=(0, 1)).lower(w_s, l_s, s_s, t_s)
    compiled = lowered.compile()
    dt = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    rl = analyze(compiled, chips)
    peak = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
            + mem.output_size_in_bytes - mem.alias_size_in_bytes)
    return {
        "arch": "wenquxing-22a-x102", "shape": f"snn_{kind}",
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "status": "ok", "compile_s": round(dt, 1),
        "variant": "packed" if packed else "unpacked",
        "peak_bytes_per_device": peak,
        "fits_16GB": bool(peak < 16e9),
        "roofline": rl.summary(),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="both",
                    choices=["pod", "multipod", "both"])
    ap.add_argument("--out", default="experiments/dryrun_results.json")
    args = ap.parse_args()
    from pathlib import Path
    out = Path(args.out)
    results = load_results(out)
    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]
    for mp in meshes:
        for kind in ("infer", "train"):
            for packed in ((True, False) if kind == "infer" else (True,)):
                key = (f"wenquxing-22a-x102|snn_{kind}|"
                       f"{'2x16x16' if mp else '16x16'}"
                       f"{'' if packed else '#unpacked'}")
                print(f"[cell] {key}", flush=True)
                res = lower_snn(kind, mp, packed)
                rl = res["roofline"]
                print(f"  -> t_c={rl['t_compute_s']:.4f} "
                      f"t_m={rl['t_memory_s']:.4f} "
                      f"t_coll={rl['t_collective_s']:.4f} "
                      f"dom={rl['dominant']} "
                      f"peak={res['peak_bytes_per_device']/1e9:.2f}GB",
                      flush=True)
                results[key] = res
                save_results(out, results)


if __name__ == "__main__":
    main()
