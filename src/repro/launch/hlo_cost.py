"""Trip-count-aware HLO cost analysis.

``compiled.cost_analysis()`` counts while-loop bodies ONCE, which
under-reports every lax.scan in this codebase (layer stacks, microbatch
accumulation, kv-chunked attention, loss chunks, SSM time scans) — the
probe in EXPERIMENTS.md §Roofline shows an 8-iteration scan reporting 1x
its flops.  This module re-derives roofline inputs by walking the
compiled HLO text:

* dot flops       = 2 * prod(result_dims) * prod(lhs_contracting_dims)
* elementwise     = 1 flop / result element
* while           = trip_count x (body + cond)   [backend_config
                    known_trip_count; static lax.scan always has it]
* fusion          = internal flops; HBM bytes counted at the fusion
                    boundary only (operands + result)
* conditional     = max over branches
* collectives     = wire bytes per device with ring-cost multipliers:
                    all-gather/reduce-scatter ~ bytes, all-reduce ~ 2x,
                    all-to-all ~ bytes, collective-permute ~ bytes

Approximations (documented in EXPERIMENTS.md): intra-fusion reuse is
perfect, inter-op HBM caching is ignored, transcendentals count 1 flop.
CPU-backend fusion boundaries differ from TPU's; numbers are
order-correct roofline inputs, not cycle-accurate predictions.
"""

from __future__ import annotations

import dataclasses
import json
import re

_DTYPE_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "f64": 8, "s32": 4,
                "u32": 4, "s8": 1, "u8": 1, "pred": 1, "s64": 8,
                "u64": 8, "s16": 2, "u16": 2, "c64": 8, "c128": 16,
                "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
                "token": 0, "opaque": 0}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\](?:\{[^}]*\})?")

_OP_HEAD_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")


def _parse_op(line: str):
    """-> (name, type, opcode, operands, attrs) or None.

    Operand list is extracted with balanced-paren scanning because
    metadata attrs contain nested parens (e.g. op_name="jit(f)/...").
    """
    m = _OP_HEAD_RE.match(line)
    if not m:
        return None
    start = m.end()  # index just past the opening paren
    depth = 1
    i = start
    while i < len(line) and depth:
        ch = line[i]
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
        i += 1
    operands = line[start:i - 1]
    attrs = line[i:]
    return m.group(1), m.group(2), m.group(3), operands, attrs

# computation headers end with "{" and contain "->"; param lists may
# nest parens (tuple types) so only the leading name is parsed.
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")

_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')

_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_ZERO_COST = {"parameter", "constant", "tuple", "get-tuple-element",
              "bitcast", "after-all", "token", "partition-id",
              "replica-id", "opt-barrier", "domain"}

_COLLECTIVES = {"all-gather": 1.0, "all-reduce": 2.0,
                "reduce-scatter": 1.0, "all-to-all": 1.0,
                "collective-permute": 1.0}


def _shape_info(type_str: str) -> tuple[int, list[list[int]]]:
    """bytes, list of dim-lists for a (possibly tuple) HLO type."""
    total = 0
    shapes = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        dl = [int(d) for d in dims.split(",") if d] if dims else []
        n = 1
        for d in dl:
            n *= d
        total += n * _DTYPE_BYTES[dt]
        shapes.append(dl)
    return total, shapes


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v * mult


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.computations = self._split(hlo_text)
        self.entry = self._find_entry(hlo_text)
        self._memo: dict[str, Cost] = {}

    @staticmethod
    def _split(text: str) -> dict[str, list[str]]:
        comps: dict[str, list[str]] = {}
        cur = None
        for line in text.splitlines():
            stripped = line.rstrip()
            if (stripped.endswith("{") and "->" in stripped
                    and not line.startswith(" ")):
                m = _COMP_RE.match(line)
                if m:
                    cur = m.group(1)
                    comps[cur] = []
                    continue
            if cur is not None:
                if line.strip() == "}":
                    cur = None
                    continue
                comps[cur].append(line)
        return comps

    @staticmethod
    def _find_entry(text: str) -> str:
        for line in text.splitlines():
            if line.startswith("ENTRY"):
                m = re.match(r"ENTRY\s+%?([\w\.\-]+)", line)
                if m:
                    return m.group(1)
        raise ValueError("no ENTRY computation found")

    def cost(self) -> Cost:
        return self._cost_of(self.entry, top=True)

    def _cost_of(self, name: str, top: bool) -> Cost:
        key = f"{name}|{top}"
        if key in self._memo:
            return self._memo[key]
        total = Cost()
        symtab: dict[str, str] = {}
        for line in self.computations.get(name, []):
            m = _parse_op(line)
            if not m:
                continue
            out_name, out_type, opcode, operands, attrs = m
            symtab[out_name] = out_type
            total.add(self._op_cost(out_type, opcode, operands, attrs,
                                    symtab, top))
        self._memo[key] = total
        return total

    def _fusion_operand_bytes(self, callee: str, operand_names: list,
                              symtab: dict) -> float:
        """Effective HBM read bytes of a fusion's operands.

        A parameter consumed only by dynamic-slice/gather/slice inside
        the fusion reads just the slice, not the whole buffer (the
        lax.scan xs pattern) — counting the full operand would inflate
        loop-body traffic by the trip count.
        """
        lines = self.computations.get(callee, [])
        # param idx -> param ssa name
        params: dict[int, str] = {}
        for line in lines:
            p = _parse_op(line)
            if p and p[2] == "parameter":
                try:
                    params[int(p[3])] = p[0]
                except ValueError:
                    pass
        total = 0.0
        for idx, nm in enumerate(operand_names):
            t = symtab.get(nm)
            if not t:
                continue
            full, _ = _shape_info(t)
            pname = params.get(idx)
            if pname is None:
                total += full
                continue
            slice_bytes = 0.0
            sliced_only = True
            used = False
            pat = re.compile(r"%?" + re.escape(pname) + r"\b")
            for line in lines:
                p = _parse_op(line)
                if not p or p[0] == pname:
                    continue
                if pat.search(p[3]):
                    used = True
                    if p[2] in ("dynamic-slice", "gather", "slice"):
                        b, _ = _shape_info(p[1])
                        slice_bytes += b
                    else:
                        sliced_only = False
                        break
            if used and sliced_only:
                total += slice_bytes
            elif used:
                total += full
        return total

    def _operand_names(self, operands: str) -> list[str]:
        names = []
        depth = 0
        cur = ""
        for ch in operands:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
            elif ch == "," and depth == 0:
                names.append(cur.strip())
                cur = ""
                continue
            cur += ch
        if cur.strip():
            names.append(cur.strip())
        return [n.lstrip("%") for n in names]

    def _op_cost(self, out_type: str, opcode: str, operands: str,
                 attrs: str, symtab: dict, top: bool) -> Cost:
        c = Cost()
        if opcode in _ZERO_COST:
            return c
        out_bytes, out_shapes = _shape_info(out_type)
        out_elems = 0
        for dl in out_shapes:
            n = 1
            for d in dl:
                n *= d
            out_elems += n

        opnd_bytes = 0
        for nm in self._operand_names(operands):
            t = symtab.get(nm)
            if t:
                b, _ = _shape_info(t)
                opnd_bytes += b

        base = opcode.replace("-start", "").replace("-done", "")
        if base in _COLLECTIVES:
            if opcode.endswith("-done"):
                return c
            wire = out_bytes * _COLLECTIVES[base]
            c.coll[base] = c.coll.get(base, 0.0) + wire
            c.bytes += out_bytes + opnd_bytes
            return c

        if opcode == "while":
            trip = 1.0
            mt = _TRIP_RE.search(attrs)
            if mt:
                trip = float(mt.group(1))
            mb = _BODY_RE.search(attrs)
            mc = _COND_RE.search(attrs)
            if mb:
                c.add(self._cost_of(mb.group(1), top=True), trip)
            if mc:
                c.add(self._cost_of(mc.group(1), top=True), trip)
            return c

        if opcode == "conditional":
            mbr = _BRANCHES_RE.search(attrs)
            if mbr:
                branches = [b.strip().lstrip("%")
                            for b in mbr.group(1).split(",")]
                costs = [self._cost_of(b, top=True) for b in branches]
                if costs:
                    best = max(costs, key=lambda x: x.flops + x.bytes)
                    c.add(best)
            return c

        if opcode == "fusion":
            mcalls = _CALLS_RE.search(attrs)
            eff_opnd = opnd_bytes
            if mcalls:
                callee = mcalls.group(1)
                inner = self._cost_of(callee, top=False)
                c.flops += inner.flops
                for k, v in inner.coll.items():
                    c.coll[k] = c.coll.get(k, 0.0) + v
                eff_opnd = self._fusion_operand_bytes(
                    callee, self._operand_names(operands), symtab)
            c.bytes += out_bytes + eff_opnd
            return c

        if opcode in ("call", "async-start", "async-done",
                      "async-update"):
            mcalls = _CALLS_RE.search(attrs)
            if mcalls and not opcode.endswith(("-done", "-update")):
                c.add(self._cost_of(mcalls.group(1), top=True))
            return c

        if opcode == "dot":
            k = 1.0
            mct = _CONTRACT_RE.search(attrs)
            lhs_name = self._operand_names(operands)[0] \
                if operands else None
            lhs_type = symtab.get(lhs_name or "", "")
            _, lhs_shapes = _shape_info(lhs_type)
            if mct and lhs_shapes:
                dims = [int(d) for d in mct.group(1).split(",") if d]
                for d in dims:
                    if d < len(lhs_shapes[0]):
                        k *= lhs_shapes[0][d]
            c.flops += 2.0 * out_elems * k
            if top:
                c.bytes += out_bytes + opnd_bytes
            return c

        if opcode in ("dynamic-slice", "gather", "slice"):
            # reads only the slice, not the sliced buffer
            if top:
                c.bytes += 2.0 * out_bytes
            return c

        if opcode in ("dynamic-update-slice", "scatter"):
            # in-place region write: read update + write region
            upd_idx = 1 if opcode == "dynamic-update-slice" else 2
            names = self._operand_names(operands)
            upd_bytes = 0
            if len(names) > upd_idx:
                t = symtab.get(names[upd_idx])
                if t:
                    upd_bytes, _ = _shape_info(t)
            if top:
                c.bytes += 2.0 * (upd_bytes or out_bytes)
            return c

        if opcode in ("convolution",):
            # rare here; approximate via result * window (unknown) -> 2x
            c.flops += 2.0 * out_elems
            if top:
                c.bytes += out_bytes + opnd_bytes
            return c

        # everything else: 1 flop per output element
        c.flops += float(out_elems)
        if top:
            c.bytes += out_bytes + opnd_bytes
        return c


def analyze_hlo(hlo_text: str) -> dict:
    model = HloCostModel(hlo_text)
    cost = model.cost()
    return {
        "flops": cost.flops,
        "bytes": cost.bytes,
        "collectives": dict(cost.coll),
        "collective_bytes": float(sum(cost.coll.values())),
    }
