"""ShapeDtypeStruct input stand-ins for every (arch x shape) cell.

No device allocation — the dry-run lowers/compiles against these.
Also exposes the logical-axis trees for batch/cache inputs so the
dry-run can build NamedShardings.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.configs.shapes import ShapeSpec


def _sd(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """Batch ShapeDtypeStructs for a train/prefill cell."""
    b, t = shape.global_batch, shape.seq_len
    batch = {"tokens": _sd((b, t), jnp.int32)}
    if shape.kind == "train":
        batch["labels"] = _sd((b, t), jnp.int32)
    if cfg.is_enc_dec:
        batch["frames"] = _sd((b, cfg.frontend_len, cfg.d_model),
                              jnp.float32)
    if cfg.frontend == "vision":
        batch["patches"] = _sd((b, cfg.frontend_len, cfg.d_model),
                               jnp.float32)
    return batch


def input_logical(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """Logical axis names matching input_specs."""
    batch = {"tokens": ("batch", "seq")}
    if shape.kind == "train":
        batch["labels"] = ("batch", "seq")
    if cfg.is_enc_dec:
        batch["frames"] = ("batch", None, None)
    if cfg.frontend == "vision":
        batch["patches"] = ("batch", None, None)
    return batch


def decode_token_specs(cfg: ArchConfig, shape: ShapeSpec):
    b = shape.global_batch
    return (_sd((b, 1), jnp.int32), ("batch", None))


def rng_spec():
    return jax.eval_shape(lambda: jax.random.key(0))
