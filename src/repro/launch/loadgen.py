"""Open-loop load-generator CLI for the SNN serving engine.

Three verbs over one driver (:func:`repro.loadgen.runner.run_rows`):

* **record** — sample a request stream from seeded arrival + workload
  specs and write it as a replayable trace (``--record PATH``;
  ``--compact`` commits a 50k-request stream as a few hundred bytes,
  pinned by its SHA-256 stream digest).
* **replay** — load a trace (``--trace PATH``) or generate the stream
  in memory, drive the engine open-loop, and report offered vs
  achieved rate, per-status totals, SLO attainment, and
  coordinated-omission-correct latency percentiles.  ``--check`` runs
  the stream twice and exits nonzero unless the per-status totals and
  histogram buckets are bit-identical — the CI replay invariant.
* **sweep** — bisect the maximum offered rate whose run still clears
  ``--slo-floor`` attainment (``--sweep LO HI``).

``--mode virtual`` (default) is fully deterministic: the engine reads
a virtual clock whose serving steps cost a modeled
``base + per_slot*B + per_cycle*T`` ms, so runs are bit-identical on
any host.  ``--mode wall`` measures real kernel time on the same
virtual arrival axis (idle gaps skipped, never slept).

``--overload`` attaches the adaptive overload controller
(:func:`repro.serving.overload.storm_policy` scaled to the stream's
recorded 1x rate), ``--scale F`` time-compresses a recorded trace to
``F``x its offered rate, and ``--slowdown-p/-factor/-steps`` arm a
seeded service-time-inflation storm — together the replayable overload
experiment the ``loadgen/overload-*`` bench rows gate.

    python -m repro.launch.loadgen --rate 20000 --n 50000 --check
    python -m repro.launch.loadgen --record traces/smoke.json --compact
    python -m repro.launch.loadgen --trace traces/smoke.json \
        --slo-floor 0.9 --hist-out hist.json
    python -m repro.launch.loadgen --sweep 1000 64000
    python -m repro.launch.loadgen --trace traces/overload_50k.json \
        --scale 5 --overload --slowdown-p 0.02 --check
"""

from __future__ import annotations

import argparse
import json
import sys


def _build_specs(args):
    from repro.loadgen import ArrivalSpec, WorkloadSpec

    arrivals = ArrivalSpec(process=args.process, rate_rps=args.rate,
                           n_requests=args.n, seed=args.seed,
                           burst_factor=args.burst_factor,
                           duty=args.duty, period_ms=args.period_ms)
    deadline_choices = (None,) if args.deadline_mix <= 0.0 \
        else (None, args.deadline_ms)
    deadline_weights = (1,) if args.deadline_mix <= 0.0 else (
        max(1, round(100 * (1 - args.deadline_mix))),
        max(1, round(100 * args.deadline_mix)))
    workload = WorkloadSpec(n_inputs=args.inputs,
                            p_intensity=args.p_intensity,
                            t_choices=tuple(args.t_choices),
                            priority_choices=tuple(args.priority_choices),
                            priority_weights=tuple(args.priority_weights),
                            deadline_choices=deadline_choices,
                            deadline_weights=deadline_weights,
                            seed=args.workload_seed)
    return arrivals, workload


def _make_engine(args, workload, mode: str):
    from repro.core.stdp import init_weights
    from repro.engine.plan import SNNEnginePlan
    from repro.loadgen.runner import ServiceModel, make_clock
    from repro.serving.snn import SNNServingEngine, SNNServingPolicy

    plan = SNNEnginePlan(threshold=args.threshold, leak=args.leak,
                         n_syn=workload.n_inputs, encode="kernel",
                         cycle_backend="window",
                         max_batch=args.max_batch, t_chunk=args.t_chunk)
    weights = init_weights(args.neurons, workload.words, density_seed=0)
    policy = SNNServingPolicy(max_queue=args.max_queue,
                              deadline_ms=args.queue_deadline_ms)
    clock = make_clock(mode, ServiceModel(
        base_ms=args.model_base_ms, per_slot_ms=args.model_slot_ms,
        per_cycle_ms=args.model_cycle_ms))
    injector = _make_injector(args)
    overload = None
    if getattr(args, "overload", False):
        from repro.serving.overload import storm_policy

        overload = storm_policy(args.overload_base_rps)
    return SNNServingEngine(weights, plan, policy=policy, clock=clock,
                            on_launch=injector,
                            journal_dir=getattr(args, "journal_dir", None),
                            snapshot_every=getattr(args, "snapshot_every",
                                                   256),
                            overload=overload)


def _make_injector(args):
    """A fault injector when a crash point or a slowdown storm is
    armed, else None — a clean run never consults a hook."""
    point = getattr(args, "crash_point", None)
    crash = bool(point) and point != "none"
    slowdown = getattr(args, "slowdown_p", 0.0) > 0.0
    if not crash and not slowdown:
        return None
    from repro.serving.faults import FaultInjector, FaultSpec

    fields = {}
    if crash:
        fields[{"before_dispatch": "p_crash_before_dispatch",
                "after_serve": "p_crash_after_serve_before_journal",
                "mid_snapshot": "p_crash_mid_snapshot"}[point]] = \
            args.crash_p
    if slowdown:
        fields.update(p_slowdown=args.slowdown_p,
                      slowdown_factor=args.slowdown_factor,
                      slowdown_steps=args.slowdown_steps)
    seed = args.crash_seed if crash else getattr(args, "fault_seed", 0)
    return FaultInjector(FaultSpec(seed=seed, **fields))


def _run_once(args, workload, rows):
    from repro.loadgen.runner import run_rows

    eng = _make_engine(args, workload, args.mode)
    resume = (eng.journal_resume_offset
              if getattr(args, "resume_from_journal", False) else 0)
    if resume:
        print(f"loadgen: resuming from journaled offset {resume} "
              f"({eng.journal_recovered} requests re-queued)")
    rep = run_rows(eng, workload, rows, slo_ms=args.slo_ms,
                   verify_payloads=args.verify_payloads,
                   resume_offset=resume)
    eng.close()
    # cumulative (recovered + this run) engine truth for the chaos
    # harness's cross-restart audit; per-run LoadReport fields only
    # cover the rows offered by this process
    rep.engine_totals = {
        "per_status": eng.per_status(), "submitted": eng.submitted,
        "steps": eng.steps,
        "e2e_ms_p50": round(eng.service_hist.percentile(50), 3),
        "e2e_ms_p99": round(eng.service_hist.percentile(99), 3),
        "e2e_ms_p999": round(eng.service_hist.percentile(99.9), 3),
        "queue_wait_ms_p50": round(eng.queue_wait_hist.percentile(50), 3),
        "queue_wait_ms_p99": round(eng.queue_wait_hist.percentile(99), 3),
    }
    return rep


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="open-loop load generation against the SNN serving "
                    "engine")
    # stream source
    ap.add_argument("--trace", default=None,
                    help="replay this recorded trace (digest-verified)")
    ap.add_argument("--record", default=None,
                    help="write the generated stream as a trace here "
                         "and exit (no run)")
    ap.add_argument("--compact", action="store_true",
                    help="with --record: header-only generative trace")
    # arrival process (used when no --trace)
    ap.add_argument("--process", default="poisson",
                    choices=["poisson", "uniform", "onoff"])
    ap.add_argument("--rate", type=float, default=20000.0,
                    help="offered rate, requests/s (virtual clock)")
    ap.add_argument("--n", type=int, default=50_000,
                    help="number of requests in the stream")
    ap.add_argument("--seed", type=int, default=42,
                    help="arrival-process seed")
    ap.add_argument("--burst-factor", type=float, default=4.0)
    ap.add_argument("--duty", type=float, default=0.2)
    ap.add_argument("--period-ms", type=float, default=100.0)
    # workload mix (used when no --trace)
    ap.add_argument("--inputs", type=int, default=256)
    ap.add_argument("--p-intensity", type=float, default=1.0)
    ap.add_argument("--t-choices", type=int, nargs="+",
                    default=[8, 12, 16])
    ap.add_argument("--deadline-mix", type=float, default=0.25,
                    help="fraction of requests carrying an explicit "
                         "deadline")
    ap.add_argument("--deadline-ms", type=float, default=40.0)
    ap.add_argument("--priority-choices", type=int, nargs="+",
                    default=[0],
                    help="priority levels in the request mix")
    ap.add_argument("--priority-weights", type=int, nargs="+",
                    default=[1],
                    help="integer weights matching --priority-choices")
    ap.add_argument("--workload-seed", type=int, default=9)
    # engine shape
    ap.add_argument("--neurons", type=int, default=64)
    ap.add_argument("--threshold", type=int, default=192)
    ap.add_argument("--leak", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--t-chunk", type=int, default=8)
    ap.add_argument("--max-queue", type=int, default=4096)
    ap.add_argument("--queue-deadline-ms", type=float, default=200.0,
                    help="engine default deadline for requests without "
                         "one")
    # measurement
    ap.add_argument("--mode", default="virtual",
                    choices=["virtual", "wall"])
    ap.add_argument("--model-base-ms", type=float, default=0.25)
    ap.add_argument("--model-slot-ms", type=float, default=0.02)
    ap.add_argument("--model-cycle-ms", type=float, default=0.01)
    ap.add_argument("--slo-ms", type=float, default=50.0)
    ap.add_argument("--slo-floor", type=float, default=None,
                    help="exit nonzero if SLO attainment falls below "
                         "this")
    ap.add_argument("--check", action="store_true",
                    help="run twice; exit nonzero unless per-status "
                         "totals and histogram buckets are "
                         "bit-identical")
    ap.add_argument("--verify-payloads", action="store_true",
                    help="re-hash every payload during materialization")
    ap.add_argument("--sweep", type=float, nargs=2, default=None,
                    metavar=("LO_RPS", "HI_RPS"),
                    help="bisect max sustainable rate in [LO, HI]")
    ap.add_argument("--sweep-iters", type=int, default=7)
    ap.add_argument("--hist-out", default=None,
                    help="write the run's latency histograms (JSON) "
                         "here")
    # crash-consistency journal
    ap.add_argument("--journal-dir", default=None,
                    help="journal request lifecycle + engine snapshots "
                         "here; construction over an existing dir "
                         "recovers the crashed engine state")
    ap.add_argument("--resume-from-journal", action="store_true",
                    help="continue the trace from the last journaled "
                         "offset instead of re-offering from row 0")
    ap.add_argument("--snapshot-every", type=int, default=256,
                    help="serving steps between journal snapshots "
                         "(0 = only the final close() snapshot)")
    ap.add_argument("--crash-point", default="none",
                    choices=["none", "before_dispatch", "after_serve",
                             "mid_snapshot"],
                    help="arm one seeded whole-process crash point "
                         "(the kill-restart chaos harness's knob)")
    ap.add_argument("--crash-p", type=float, default=0.01,
                    help="per-consult crash probability when armed")
    ap.add_argument("--crash-seed", type=int, default=0,
                    help="crash-draw seed (distinct per restart)")
    ap.add_argument("--report-out", default=None,
                    help="write the full run report (incl. cumulative "
                         "engine totals) as JSON here")
    # overload control + storms
    ap.add_argument("--overload", action="store_true",
                    help="attach the adaptive overload controller "
                         "(storm_policy scaled to --overload-base-rps)")
    ap.add_argument("--overload-base-rps", type=float, default=None,
                    help="the ~sustainable 1x rate the controller is "
                         "scaled to (default: the trace's recorded "
                         "rate, else --rate)")
    ap.add_argument("--scale", type=float, default=1.0,
                    help="time-compress the stream: divide every "
                         "arrival timestamp by this factor (5 = the "
                         "same requests at 5x the offered rate)")
    ap.add_argument("--slowdown-p", type=float, default=0.0,
                    help="P[a serving step starts a seeded slowdown "
                         "burst] (service-time inflation storm)")
    ap.add_argument("--slowdown-factor", type=float, default=4.0)
    ap.add_argument("--slowdown-steps", type=int, default=1)
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="fault-injector seed when no crash point is "
                         "armed")
    args = ap.parse_args(argv)

    from repro.loadgen import generate_rows, read_trace, write_trace
    from repro.loadgen.runner import rate_sweep

    if args.trace is not None:
        header, rows = read_trace(args.trace)
        from repro.loadgen import ArrivalSpec, WorkloadSpec
        arrivals = ArrivalSpec.from_dict(header["arrivals"])
        workload = WorkloadSpec.from_dict(header["workload"])
        print(f"loadgen: trace {args.trace} verified "
              f"({header['n_requests']} requests, "
              f"sha {header['stream_sha256'][:12]}…)")
    else:
        arrivals, workload = _build_specs(args)
        rows = None

    if args.record is not None:
        header = write_trace(args.record, arrivals, workload, rows,
                             compact=args.compact)
        print(f"loadgen: recorded {header['n_requests']} requests "
              f"({header['kind']}) -> {args.record} "
              f"sha {header['stream_sha256'][:12]}…")
        return

    if rows is None:
        rows = generate_rows(arrivals, workload)
    if args.scale != 1.0:
        from repro.loadgen import scale_rows

        rows = scale_rows(rows, args.scale)
        print(f"loadgen: stream time-compressed {args.scale}x "
              f"(offered rate scaled accordingly)")
    if args.overload and args.overload_base_rps is None:
        # the controller is scaled to the stream's *recorded* 1x rate,
        # not the post---scale offered rate: a 5x storm must descend
        # toward the sustainable rate, not adopt the storm as baseline
        args.overload_base_rps = float(arrivals.rate_rps)

    if args.sweep is not None:
        if args.trace is not None:
            ap.error("--sweep regenerates streams per rate; it cannot "
                     "be combined with --trace")
        import dataclasses

        floor = args.slo_floor if args.slo_floor is not None else 0.95

        def run_at(rate):
            asp = dataclasses.replace(arrivals, rate_rps=rate)
            return _run_once(args, workload, generate_rows(asp, workload))

        rate, rep = rate_sweep(run_at, args.sweep[0], args.sweep[1],
                               slo_floor=floor, iters=args.sweep_iters)
        print(f"loadgen-sweep: sustainable_rps={rate:.1f} "
              f"(floor={floor}) " + rep.summary())
        if args.hist_out:
            _dump_hists(args.hist_out, rep)
        sys.exit(0 if rate > 0.0 else 1)

    rep = _run_once(args, workload, rows)
    print("loadgen: " + rep.summary())
    status = 0
    if args.report_out:
        with open(args.report_out, "w") as fh:
            json.dump({**rep.to_dict(),
                       "engine_totals": rep.engine_totals}, fh)
    if args.check:
        rep2 = _run_once(args, workload, rows)
        same = (rep.per_status == rep2.per_status
                and rep.service_hist == rep2.service_hist
                and rep.queue_wait_hist == rep2.queue_wait_hist)
        print(f"loadgen-check: replay "
              f"{'bit-identical' if same else 'DIVERGED'}")
        if not same:
            status = 1
    if rep.non_terminal:
        print(f"loadgen: {rep.non_terminal} requests never reached a "
              f"terminal status")
        status = 1
    if args.slo_floor is not None and rep.slo_attainment < args.slo_floor:
        print(f"loadgen: SLO attainment {rep.slo_attainment} below "
              f"floor {args.slo_floor}")
        status = 1
    if args.hist_out:
        _dump_hists(args.hist_out, rep)
    sys.exit(status)


def _dump_hists(path: str, rep) -> None:
    with open(path, "w") as fh:
        json.dump({"service_hist": rep.service_hist,
                   "queue_wait_hist": rep.queue_wait_hist,
                   "slo_attainment": rep.slo_attainment,
                   "offered_rps": rep.offered_rps,
                   "achieved_rps": rep.achieved_rps}, fh)
    print(f"loadgen: histograms -> {path}")


if __name__ == "__main__":
    main()
