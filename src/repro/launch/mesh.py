"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module does not touch jax device state — the dry-run must set XLA_FLAGS
before the first jax call.

Production topology (TPU v5e target):
  single pod:  (data=16, model=16)            = 256 chips
  multi-pod:   (pod=2, data=16, model=16)     = 512 chips
The ``pod`` axis carries only data parallelism (gradient all-reduce over
DCN); ``model`` stays inside the pod's ICI domain.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_local_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    model = min(model, n)
    data = n // model
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
