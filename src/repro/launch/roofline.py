"""Three-term roofline analysis from a compiled dry-run artifact.

    compute   = HLO_FLOPs / (chips x peak_FLOP/s)
    memory    = HLO_bytes / (chips x HBM_bw)
    collective= collective_bytes / (chips x link_bw)

cost_analysis() reports the per-device program (post-SPMD), so FLOPs /
bytes are already per-chip; collective bytes are parsed from the
compiled HLO (operand sizes of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute ops).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (values given in the task brief).
"""

from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12     # bf16 / chip
HBM_BW = 819e9          # bytes / s / chip
LINK_BW = 50e9          # bytes / s / link

_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*(\([^)]*\)|[\w\[\],{}\s]+?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)

_SHAPE_RE = re.compile(r"(bf16|f32|f16|s32|u32|s8|u8|pred|f64|s64|u64|s16|u16)"
                       r"\[([\d,]*)\]")

_DTYPE_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "f64": 8, "s32": 4,
                "u32": 4, "s8": 1, "u8": 1, "pred": 1, "s64": 8,
                "u64": 8, "s16": 2, "u16": 2}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-shape bytes per collective kind (per-device program).

    ``-start``/``-done`` async pairs are counted once (the -start op).
    """
    out: dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        line = hlo_text[m.start():m.end()]
        if "-done" in line:
            continue
        out[kind] = out.get(kind, 0) + _shape_bytes(shape_str)
    return out


@dataclasses.dataclass
class Roofline:
    flops: float
    bytes_hbm: float
    bytes_collective: float
    coll_breakdown: dict
    chips: int

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_hbm / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.bytes_collective / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def summary(self) -> dict:
        return {
            "flops_per_chip": self.flops,
            "hbm_bytes_per_chip": self.bytes_hbm,
            "collective_bytes_per_chip": self.bytes_collective,
            "coll_breakdown": self.coll_breakdown,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
        }


def analyze(compiled, chips: int) -> Roofline:
    """Trip-count-aware analysis of the per-device compiled program.

    Uses repro.launch.hlo_cost (lax.scan bodies x trip count); XLA's own
    cost_analysis() counts while bodies once and is kept only as the
    ``xla_*`` cross-check fields.
    """
    from repro.launch.hlo_cost import analyze_hlo
    res = analyze_hlo(compiled.as_text())
    return Roofline(flops=res["flops"], bytes_hbm=res["bytes"],
                    bytes_collective=res["collective_bytes"],
                    coll_breakdown=res["collectives"],
                    chips=chips)


def estimate_tpu_peak(cfg, shape, chips: int, tp: int, accum: int,
                      arg_bytes: int) -> float:
    """Analytic per-device HBM peak for the TPU target.

    The CPU-backend ``memory_analysis().temp_size_in_bytes`` is inflated
    by layout-change copies of stacked weights that XLA:TPU's
    layout-aware fusion does not materialize (EXPERIMENTS.md §Dry-run
    shows both numbers).  Model:

      peak = args (params/opt/cache, exact, post-donation)
           + grad buffer (train: params_bytes in accum dtype)
           + scan carries (train: L x microbatch residual, seq/TP-sharded)
           + transient working set (~4 x largest layer activation)
           + loss chunk logits (train: 2 x B_loc x chunk x V/tp x 4B)
    """
    dp = chips // tp
    d, L = cfg.d_model, cfg.n_layers + cfg.encoder_layers
    if shape.kind == "train":
        b_micro = max(1, shape.global_batch // accum)
        b_loc = max(1, b_micro // dp)
        t_loc = max(1, shape.seq_len // tp)
        carry = L * b_loc * t_loc * d * 2
        grad_buf = cfg.n_params() * 2 // chips
        act = 4 * b_loc * shape.seq_len * max(d, cfg.d_ff // tp) * 2
        loss = 2 * max(1, shape.global_batch // dp) * 512 \
            * (cfg.vocab_padded // tp) * 4 // max(1, accum)
        return float(arg_bytes + grad_buf + carry + act + loss)
    # inference: args dominate (params + cache); add transients
    b_loc = max(1, shape.global_batch // dp)
    act = 4 * b_loc * min(shape.seq_len, 4096) * d * 2
    return float(arg_bytes + act)


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE), D = tokens.

    For decode steps D = global_batch (one token per sequence); training
    counts fwd+bwd (6ND); inference counts 2ND.
    """
    n = cfg.n_params_active()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token / seq
