"""Serving step builders (decode + prefill) for jit/lowering."""

from __future__ import annotations

from repro.models.transformer import Model


def make_serve_step(model: Model):
    """decode: (params, tokens [B,1], cache, cache_len) ->
    (logits [B, Vp], cache')."""

    def serve_step(params, tokens, cache, cache_len):
        return model.decode_step(params, tokens, cache, cache_len)

    return serve_step


def make_prefill_step(model: Model, max_len: int):
    """prefill: (params, batch) -> (last logits, cache, cache_len)."""

    def prefill_step(params, batch):
        return model.prefill(params, batch, max_len)

    return prefill_step


def _serve_snn(args) -> None:
    """SNN serving demo: intensity-resident digit requests through the
    dynamic-window-batching :class:`SNNServingEngine` (ragged T's to
    exercise the padding path; ``--encode kernel`` draws the spike
    windows in VMEM, so they never exist in HBM)."""
    import dataclasses

    import numpy as np

    from repro.configs.wenquxing_snn import WENQUXING_22A
    from repro.core.encoder import quantize_intensities
    from repro.core.stdp import init_weights
    from repro.data.digits import make_digits
    from repro.engine import plan_from_config
    from repro.serving import SNNRequest, SNNServingEngine

    cfg = dataclasses.replace(WENQUXING_22A, n_steps=24,
                              encode=args.encode)
    plan = dataclasses.replace(plan_from_config(cfg),
                               max_batch=args.slots)
    weights = init_weights(cfg.n_neurons, cfg.words, dense=True)
    neuron_class = np.tile(np.arange(cfg.n_classes), cfg.n_blocks)
    imgs, _ = make_digits(args.requests, seed=0)
    inten = np.asarray(quantize_intensities(imgs))
    reqs = []
    for i in range(args.requests):
        t_i = cfg.n_steps - 4 * (i % 3)     # ragged window lengths
        reqs.append(SNNRequest(rid=i, intensities=inten[i],
                               n_steps=t_i))
    eng = SNNServingEngine(weights, plan, neuron_class=neuron_class)
    eng.run(reqs)
    print(f"wenquxing-snn: {sum(r.done for r in reqs)}/{len(reqs)} done, "
          f"{eng.windows_served} windows in {eng.batches} batches "
          f"(max_batch={plan.max_batch}, encode={plan.encode})")
    if args.bench:
        stats = eng.stats()
        stats["padded_slot_waste"] = round(stats["padded_slot_waste"], 4)
        print("serve-bench: " + " ".join(
            f"{k}={v}" for k, v in sorted(stats.items())))


def main() -> None:
    """CLI launcher: serve any assigned architecture (reduced size on
    CPU) with the continuous-batching engine, or the paper's SNN through
    the window-batching engine.

    python -m repro.launch.serve --arch mixtral-8x22b --requests 6
    python -m repro.launch.serve --arch wenquxing-snn --requests 6
    """
    import argparse

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, list_configs, reduced
    from repro.serving import Request, ServingEngine

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    choices=list_configs() + ["wenquxing-snn"])
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--encode", default="kernel",
                    choices=["host", "kernel"],
                    help="SNN encode placement (wenquxing-snn only)")
    ap.add_argument("--bench", action="store_true",
                    help="print serving stats (padded-slot waste, "
                         "per-step wall-clock) after the run")
    args = ap.parse_args()

    if args.arch == "wenquxing-snn":
        return _serve_snn(args)

    cfg = reduced(get_config(args.arch))
    model = Model(cfg, dtype=jnp.float32, attn_chunk=16)
    params = model.init_params(jax.random.key(0))
    eng = ServingEngine(model, params, n_slots=args.slots, max_len=128)
    reqs = [Request(rid=i, prompt=[1 + i, 2, 3],
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    eng.run(reqs, max_steps=2000)
    print(f"{cfg.name}: {sum(r.done for r in reqs)}/{len(reqs)} done, "
          f"{eng.tokens_out} tokens")


if __name__ == "__main__":
    main()
