"""Serving step builders (decode + prefill) for jit/lowering."""

from __future__ import annotations

from repro.models.transformer import Model


def make_serve_step(model: Model):
    """decode: (params, tokens [B,1], cache, cache_len) ->
    (logits [B, Vp], cache')."""

    def serve_step(params, tokens, cache, cache_len):
        return model.decode_step(params, tokens, cache, cache_len)

    return serve_step


def make_prefill_step(model: Model, max_len: int):
    """prefill: (params, batch) -> (last logits, cache, cache_len)."""

    def prefill_step(params, batch):
        return model.prefill(params, batch, max_len)

    return prefill_step


def _serve_snn(args) -> None:
    """SNN serving demo: intensity-resident digit requests through the
    dynamic-window-batching :class:`SNNServingEngine` (ragged T's to
    exercise the padding path; ``--encode kernel`` draws the spike
    windows in VMEM, so they never exist in HBM).  ``--inject-faults``
    runs the same traffic under a seeded fault storm (launch failures,
    corrupted counts, zero-deadline requests) and proves the robustness
    layer: every request terminates in a terminal status and every
    SERVED count vector stays bit-exact with the host oracle.

    ``--refresh-every N`` turns on versioned train-while-serving: a
    probe-gated STDP refresh every N serving steps, double-buffered
    weight swaps, and (with ``--state-dir``) checkpointed promotions +
    rollback.  The oracle check then runs per served *version*, and a
    version audit exits nonzero if any request was served from a
    version that was never promoted (``version_violations`` > 0 or a
    ``served_version`` outside the store's promotion history).  Clean
    (fault-free) refresh runs additionally require the final probe
    accuracy to beat the frozen seed bank — the measurable gain
    train-while-serving exists to deliver."""
    import dataclasses
    import sys
    from collections import Counter

    import jax.numpy as jnp
    import numpy as np

    from repro.configs.wenquxing_snn import WENQUXING_22A
    from repro.core.encoder import encode_from_counter, quantize_intensities
    from repro.core.stdp import init_weights
    from repro.data.digits import make_digits
    from repro.engine import plan_from_config
    from repro.kernels import ops
    from repro.serving import (FaultInjector, FaultSpec, SNNRefreshPolicy,
                               SNNRequest, SNNServingEngine,
                               SNNServingPolicy, SNNWeightRefresher)

    cfg = dataclasses.replace(WENQUXING_22A, n_steps=24,
                              encode=args.encode)
    plan = dataclasses.replace(plan_from_config(cfg),
                               max_batch=args.slots)
    weights = init_weights(cfg.n_neurons, cfg.words, dense=True)
    neuron_class = np.tile(np.arange(cfg.n_classes), cfg.n_blocks)
    imgs, _ = make_digits(args.requests, seed=0)
    inten = np.asarray(quantize_intensities(imgs))
    policy = SNNServingPolicy(max_retries=2, canary_every=2,
                              reprobe_after=4)
    refresher = None
    if args.refresh_every > 0:
        # labeled refresh stream + held-out probe set, disjoint from
        # the request traffic (different render seeds)
        ref_imgs, ref_labels = make_digits(
            max(args.refresh_samples * 4, args.refresh_samples), seed=1)
        probe_imgs, probe_labels = make_digits(args.probe_size, seed=2)
        refresher = SNNWeightRefresher(
            plan, np.asarray(quantize_intensities(ref_imgs)), ref_labels,
            n_classes=cfg.n_classes,
            probe_intensities=np.asarray(quantize_intensities(probe_imgs)),
            probe_labels=probe_labels, neuron_class=neuron_class,
            n_steps=cfg.n_steps, teach_pos=cfg.teach_pos,
            teach_neg=cfg.teach_neg,
            policy=SNNRefreshPolicy(
                refresh_every=args.refresh_every,
                probe_size=args.probe_size,
                refresh_samples=args.refresh_samples))
    injector = None
    if args.inject_faults:
        refresh_faults = {}
        if refresher is not None:
            refresh_faults = dict(p_refresh_corrupt=0.4,
                                  p_refresh_stall=0.2,
                                  refresh_stall_ms=1.0,
                                  p_save_crash=0.3)
        injector = FaultInjector(FaultSpec(
            p_launch_error=0.4, p_corrupt=0.4,
            error_burst=policy.max_retries + 2, seed=args.fault_seed,
            **refresh_faults))
    reqs = []
    for i in range(args.requests):
        t_i = cfg.n_steps - 4 * (i % 3)     # ragged window lengths
        # under a fault storm, every 5th request carries an already-
        # elapsed deadline so the EXPIRED path is exercised too
        ddl = 0.0 if (args.inject_faults and i % 5 == 4) else None
        reqs.append(SNNRequest(rid=i, intensities=inten[i],
                               n_steps=t_i, deadline_ms=ddl))
    eng = SNNServingEngine(weights, plan, neuron_class=neuron_class,
                           policy=policy, on_launch=injector,
                           refresher=refresher, state_dir=args.state_dir,
                           keep_versions=64)
    eng.run(reqs)
    print(f"wenquxing-snn: {sum(r.done for r in reqs)}/{len(reqs)} done, "
          f"{eng.windows_served} windows in {eng.batches} batches "
          f"(max_batch={plan.max_batch}, encode={plan.encode})")
    by_status = Counter(r.status for r in reqs)
    non_terminal = sum(not r.terminal for r in reqs)
    print("statuses: " + " ".join(f"{k}={v}"
                                  for k, v in sorted(by_status.items()))
          + f" non-terminal={non_terminal}")
    print(f"throughput: offered_rps={eng.offered_rps:.1f} "
          f"achieved_rps={eng.achieved_rps:.1f} "
          f"(submitted={eng.submitted} served={eng.windows_served})")
    served = [r for r in reqs if r.status == "SERVED"]
    mismatches = 0
    for r in served:
        # the oracle must use the weights of the version that served
        # the request — frozen serving pins everything to version 0
        ver = eng.store.get(r.served_version)
        if ver is None:
            mismatches += 1     # unattributable response
            continue
        win = np.asarray(encode_from_counter(
            r.seed, jnp.asarray(r.intensities), r.n_steps))
        win = np.pad(win, ((0, 0), (0, eng.words - win.shape[1])))
        want = np.asarray(ops.infer_window_batch(
            ver.weights, jnp.asarray(win)[None],
            threshold=plan.threshold, leak=plan.leak, backend="ref"))[0]
        mismatches += int(not np.array_equal(r.counts, want))
    print(f"oracle-check: {'ok' if mismatches == 0 else 'MISMATCH'} "
          f"({len(served)} served, {mismatches} diverged)")
    # version audit: every served response attributable to a version
    # promoted at serve time
    stats = eng.stats()
    version_bad = stats["version_violations"] + sum(
        r.served_version not in eng.store.promoted_order for r in served)
    gain_bad = 0
    if refresher is not None:
        acc_seed = refresher.probe(weights)
        acc_final = refresher.probe(eng.weights)
        print(f"refresh-gain: probe_seed={acc_seed:.4f} "
              f"probe_final={acc_final:.4f} "
              f"version={stats['weight_version']} "
              f"promoted={stats['versions_promoted']} "
              f"rejected={stats['versions_rejected']} "
              f"rollbacks={stats['rollbacks']} "
              f"version-audit={'ok' if version_bad == 0 else 'VIOLATION'}")
        if not args.inject_faults:
            gain_bad = int(acc_final <= acc_seed)
    if args.bench:
        stats["padded_slot_waste"] = round(stats["padded_slot_waste"], 4)
        if injector is not None:
            stats.update(injector.stats())
        print("serve-bench: " + " ".join(
            # list-valued stats (breaker states) join without spaces so
            # the k=v line stays whitespace-splittable
            f"{k}={'/'.join(map(str, v)) if isinstance(v, list) else v}"
            for k, v in sorted(stats.items())))
    if non_terminal or mismatches or version_bad or gain_bad:
        sys.exit(1)


def _chaos_snn(args) -> None:
    """Seeded kill–restart chaos harness for the crash-consistent SNN
    serving engine.

    Drives the committed loadgen trace through a journaled engine in a
    *subprocess*, arming one whole-process crash point per restart
    (rotating ``before_dispatch`` → ``after_serve`` → ``mid_snapshot``,
    so every injection site is exercised).  A crashing child dies via
    ``os._exit(73)`` — user-space journal buffers lost, fsync'd records
    kept — and the harness restarts it with ``--resume-from-journal``
    until, after ``--chaos-crashes`` induced crashes, a clean child
    completes the trace.  A crash-free journal-less reference run over
    the same trace (same virtual clock, same seeds) then defines
    ground truth, and the audit asserts:

    * every offered request has exactly one terminal-ledger entry
      (zero lost ADMITs, zero duplicates — rids cover 0..n-1 once);
    * zero duplicate SERVEs by payload content hash;
    * every SERVED entry is attributable to a weight version;
    * the recovered engine's cumulative per-status totals and latency
      histogram percentiles are bit-identical to the crash-free
      replay.  (``steps`` may legitimately exceed the reference by up
      to one re-dispatched batch per crash and is not compared.)

    Exits nonzero on any violation.
    """
    import json
    import os
    import subprocess
    import sys
    import tempfile

    from repro.loadgen import read_trace
    from repro.serving import CRASH_EXIT_CODE, RequestJournal

    trace = args.trace or "benchmarks/traces/smoke_50k.json"
    header, _ = read_trace(trace)
    n = header["n_requests"]
    workdir = args.state_dir or tempfile.mkdtemp(prefix="snn-chaos-")
    jdir = os.path.join(workdir, "journal")
    report = os.path.join(workdir, "report.json")
    ref_report = os.path.join(workdir, "reference.json")
    base = [sys.executable, "-m", "repro.launch.loadgen",
            "--trace", trace, "--mode", "virtual"]
    # per-consult crash probabilities: dispatch/serve points are
    # consulted every step, mid_snapshot only once per snapshot — its
    # p must be much higher to fire before the trace drains
    points = [("before_dispatch", 0.02), ("after_serve", 0.02),
              ("mid_snapshot", 0.5)]
    crashes, restart = 0, 0
    max_restarts = args.chaos_crashes + 10
    while True:
        point, crash_p = (points[restart % len(points)]
                          if crashes < args.chaos_crashes
                          else ("none", 0.0))
        cmd = base + ["--journal-dir", jdir, "--resume-from-journal",
                      "--snapshot-every", "16", "--report-out", report]
        if point != "none":
            cmd += ["--crash-point", point, "--crash-p", str(crash_p),
                    "--crash-seed",
                    str(args.chaos_seed * 1000 + restart)]
        rc = subprocess.run(cmd).returncode
        if rc == CRASH_EXIT_CODE:
            crashes += 1
            restart += 1
            print(f"chaos: induced crash #{crashes} at point "
                  f"'{point}' (restart {restart})")
            if restart > max_restarts:
                print("chaos: FAIL — restart budget exhausted")
                sys.exit(1)
            continue
        if rc != 0:
            print(f"chaos: FAIL — child exited {rc} (not a crash)")
            sys.exit(1)
        break
    print(f"chaos: trace complete after {crashes} induced crashes / "
          f"{restart} restarts")
    subprocess.run(base + ["--report-out", ref_report], check=True,
                   stdout=subprocess.DEVNULL)

    # --- audit ----------------------------------------------------------
    violations = []
    ledger = RequestJournal(jdir).read_ledger()
    rids = [r["rid"] for r in ledger]
    if len(rids) != len(set(rids)):
        violations.append(f"duplicate terminal-ledger entries: "
                          f"{len(rids) - len(set(rids))}")
    if set(rids) != set(range(n)):
        lost = sorted(set(range(n)) - set(rids))[:10]
        extra = sorted(set(rids) - set(range(n)))[:10]
        violations.append(f"ledger does not cover 0..{n - 1} exactly "
                          f"(lost={lost} extra={extra})")
    served = [r for r in ledger if r["st"] == "SERVED"]
    shas = [r["sha"] for r in served if r.get("sha")]
    if len(shas) != len(set(shas)):
        violations.append("duplicate SERVEs by content hash")
    unattributed = sum(r.get("ver") is None for r in served)
    if unattributed:
        violations.append(f"{unattributed} SERVEs not attributable to "
                          f"a weight version")
    ledger_status: dict = {}
    for r in ledger:
        ledger_status[r["st"]] = ledger_status.get(r["st"], 0) + 1
    with open(report) as fh:
        chaos_totals = json.load(fh)["engine_totals"]
    with open(ref_report) as fh:
        ref_totals = json.load(fh)["engine_totals"]

    def _nonzero(d):
        return {k: v for k, v in d.items() if v}

    if ledger_status != _nonzero(ref_totals["per_status"]):
        violations.append(f"ledger per-status {ledger_status} != "
                          f"crash-free {ref_totals['per_status']}")
    for key in ("per_status", "submitted", "e2e_ms_p50", "e2e_ms_p99",
                "e2e_ms_p999", "queue_wait_ms_p50", "queue_wait_ms_p99"):
        if chaos_totals[key] != ref_totals[key]:
            violations.append(f"recovered {key}={chaos_totals[key]} != "
                              f"crash-free {ref_totals[key]}")
    if crashes < args.chaos_crashes:
        violations.append(f"only {crashes} crashes induced "
                          f"(wanted {args.chaos_crashes})")
    print(f"chaos-audit: n={n} ledger={len(ledger)} "
          f"served={len(served)} statuses="
          + " ".join(f"{k}={v}" for k, v in sorted(ledger_status.items())))
    if violations:
        for v in violations:
            print(f"chaos-audit: VIOLATION — {v}")
        sys.exit(1)
    print("chaos-audit: ok — every request terminal exactly once, "
          "zero lost admits, zero duplicate serves, counters match "
          "crash-free replay")


def _overload_storm_snn(args) -> None:
    """Replayable overload-storm smoke for the adaptive overload
    controller.

    Replays the committed priority-mixed trace
    (``benchmarks/traces/overload_50k.json``) three times on the
    virtual clock, every run with :func:`storm_policy` attached and a
    seeded service-time-inflation storm (``--overload-seed``): once at
    the recorded 1x rate (the capacity-sagged goodput anchor) and
    twice time-compressed to ``--overload-scale`` x (the storm, run
    twice to prove bit-identical replay).  Exits nonzero when any of
    the robustness contract fails:

    * any request non-terminal in any run;
    * storm goodput below 80% of the 1x anchor (metastable collapse);
    * high-priority SLO attainment below 0.95 under the storm
      (shedding leaked onto the protected class);
    * the two same-seed storm runs diverge anywhere in the report or
      the overload counters (lost determinism).
    """
    import sys

    from repro.core.stdp import init_weights
    from repro.engine.plan import SNNEnginePlan
    from repro.loadgen import WorkloadSpec, read_trace, scale_rows
    from repro.loadgen.runner import ServiceModel, VirtualClock, run_rows
    from repro.serving import (FaultInjector, FaultSpec, SNNServingEngine,
                               SNNServingPolicy)
    from repro.serving.overload import storm_policy

    trace = args.trace or "benchmarks/traces/overload_50k.json"
    header, rows = read_trace(trace)
    workload = WorkloadSpec.from_dict(header["workload"])
    base_rps = float(header["arrivals"]["rate_rps"])

    def run_once(scale: float):
        plan = SNNEnginePlan(threshold=192, leak=16,
                             n_syn=workload.n_inputs, encode="kernel",
                             cycle_backend="window", max_batch=32,
                             t_chunk=8)
        weights = init_weights(64, workload.words, density_seed=0)
        eng = SNNServingEngine(
            weights, plan,
            policy=SNNServingPolicy(max_queue=4096, deadline_ms=200.0),
            clock=VirtualClock(ServiceModel()),
            on_launch=FaultInjector(FaultSpec(
                p_slowdown=0.02, slowdown_factor=3.0, slowdown_steps=6,
                seed=args.overload_seed)),
            overload=storm_policy(base_rps))
        r = rows if scale == 1.0 else scale_rows(rows, scale)
        rep = run_rows(eng, workload, r, slo_ms=50.0)
        keys = ("shed_admission", "shed_low_priority", "shed_codel",
                "retries_denied", "admit_rate_rps", "codel_entries",
                "aimd_md_events", "aimd_ai_events", "breaker_trips")
        return rep, {k: eng.stats()[k] for k in keys}

    rep1, _ = run_once(1.0)
    rep5a, st5a = run_once(args.overload_scale)
    rep5b, st5b = run_once(args.overload_scale)
    high = rep5a.slo_attainment_by_priority.get("1", 0.0)
    retention = (rep5a.goodput_rps / rep1.goodput_rps
                 if rep1.goodput_rps else 0.0)
    print(f"overload-storm: seed={args.overload_seed} "
          f"scale={args.overload_scale:g}x base={base_rps:.0f}rps")
    print(f"  1x anchor: goodput={rep1.goodput_rps:.0f}rps "
          f"high_slo={rep1.slo_attainment_by_priority.get('1', 0.0)}")
    print(f"  storm:     goodput={rep5a.goodput_rps:.0f}rps "
          f"(retention {retention:.3f}) high_slo={high} "
          f"shed={st5a['shed_admission']}+{st5a['shed_low_priority']}"
          f"+{st5a['shed_codel']}")
    violations = []
    for label, rep in (("1x", rep1), ("storm-a", rep5a),
                       ("storm-b", rep5b)):
        if rep.non_terminal:
            violations.append(f"{label}: {rep.non_terminal} "
                              f"non-terminal requests")
    if retention < 0.8:
        violations.append(f"goodput collapsed: storm retains "
                          f"{retention:.3f} of the 1x anchor (< 0.8)")
    if high < 0.95:
        violations.append(f"high-priority SLO attainment {high} "
                          f"under the storm (< 0.95)")
    if rep5a.to_dict() != rep5b.to_dict() or st5a != st5b:
        violations.append("same-seed storm runs diverged "
                          "(determinism lost)")
    if violations:
        for v in violations:
            print(f"overload-storm: VIOLATION — {v}")
        sys.exit(1)
    print("overload-storm: ok — every request terminal, goodput held, "
          "high-priority SLO protected, replay bit-identical")


def main() -> None:
    """CLI launcher: serve any assigned architecture (reduced size on
    CPU) with the continuous-batching engine, or the paper's SNN through
    the window-batching engine.

    python -m repro.launch.serve --arch mixtral-8x22b --requests 6
    python -m repro.launch.serve --arch wenquxing-snn --requests 6
    """
    import argparse

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, list_configs, reduced
    from repro.serving import Request, ServingEngine

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    choices=list_configs() + ["wenquxing-snn"])
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--encode", default="kernel",
                    choices=["host", "kernel"],
                    help="SNN encode placement (wenquxing-snn only)")
    ap.add_argument("--bench", action="store_true",
                    help="print serving stats (padded-slot waste, "
                         "per-step wall-clock, robustness counters, "
                         "latency p50/p99) after the run")
    ap.add_argument("--inject-faults", action="store_true",
                    help="run the SNN serve under a seeded fault storm "
                         "(launch failures, corrupted counts, expired "
                         "deadlines) to exercise retry/degradation")
    ap.add_argument("--fault-seed", type=int, default=7,
                    help="FaultInjector seed (storms replay exactly)")
    ap.add_argument("--refresh-every", type=int, default=0,
                    help="SNN train-while-serving: run one probe-gated "
                         "STDP refresh every N serving steps (0 = "
                         "frozen weights)")
    ap.add_argument("--probe-size", type=int, default=32,
                    help="held-out probe samples gating each refresh "
                         "promotion")
    ap.add_argument("--refresh-samples", type=int, default=32,
                    help="labeled samples trained per refresh cycle")
    ap.add_argument("--state-dir", default=None,
                    help="persist promoted weight versions here "
                         "(atomic checkpoints; restart restores the "
                         "newest complete version)")
    ap.add_argument("--chaos", action="store_true",
                    help="kill-restart chaos harness: drive --trace "
                         "through a journaled subprocess engine with "
                         "seeded induced crashes, restart-resume it, "
                         "and audit exactly-once terminal accounting "
                         "(wenquxing-snn only)")
    ap.add_argument("--chaos-seed", type=int, default=1,
                    help="seed for the induced-crash draws")
    ap.add_argument("--chaos-crashes", type=int, default=3,
                    help="induced crashes before the clean final run "
                         "(rotates through the 3 injection points)")
    ap.add_argument("--trace", default=None,
                    help="loadgen trace the chaos/overload harnesses "
                         "replay (defaults: smoke_50k.json for chaos, "
                         "overload_50k.json for the overload storm)")
    ap.add_argument("--overload-storm", action="store_true",
                    help="replayable overload smoke: storm_policy + "
                         "seeded service-time inflation over the "
                         "committed trace at 1x and --overload-scale x, "
                         "run twice for bit-identical replay; exits "
                         "nonzero on goodput collapse, high-priority "
                         "SLO loss, non-terminal requests, or "
                         "divergence (wenquxing-snn only)")
    ap.add_argument("--overload-seed", type=int, default=5,
                    help="seed for the overload storm's service-time "
                         "inflation draws")
    ap.add_argument("--overload-scale", type=float, default=5.0,
                    help="time-compression factor for the storm runs")
    args = ap.parse_args()

    if args.arch == "wenquxing-snn":
        if args.overload_storm:
            return _overload_storm_snn(args)
        if args.chaos:
            return _chaos_snn(args)
        return _serve_snn(args)

    cfg = reduced(get_config(args.arch))
    model = Model(cfg, dtype=jnp.float32, attn_chunk=16)
    params = model.init_params(jax.random.key(0))
    eng = ServingEngine(model, params, n_slots=args.slots, max_len=128)
    reqs = [Request(rid=i, prompt=[1 + i, 2, 3],
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    eng.run(reqs, max_steps=2000)
    print(f"{cfg.name}: {sum(r.done for r in reqs)}/{len(reqs)} done, "
          f"{eng.tokens_out} tokens")


if __name__ == "__main__":
    main()
