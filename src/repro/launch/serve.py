"""Serving step builders (decode + prefill) for jit/lowering."""

from __future__ import annotations

from repro.models.transformer import Model


def make_serve_step(model: Model):
    """decode: (params, tokens [B,1], cache, cache_len) ->
    (logits [B, Vp], cache')."""

    def serve_step(params, tokens, cache, cache_len):
        return model.decode_step(params, tokens, cache, cache_len)

    return serve_step


def make_prefill_step(model: Model, max_len: int):
    """prefill: (params, batch) -> (last logits, cache, cache_len)."""

    def prefill_step(params, batch):
        return model.prefill(params, batch, max_len)

    return prefill_step


def main() -> None:
    """CLI launcher: serve any assigned architecture (reduced size on
    CPU) with the continuous-batching engine.

    python -m repro.launch.serve --arch mixtral-8x22b --requests 6
    """
    import argparse

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, list_configs, reduced
    from repro.serving import Request, ServingEngine

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_configs())
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--reduced", action="store_true", default=True)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    model = Model(cfg, dtype=jnp.float32, attn_chunk=16)
    params = model.init_params(jax.random.key(0))
    eng = ServingEngine(model, params, n_slots=args.slots, max_len=128)
    reqs = [Request(rid=i, prompt=[1 + i, 2, 3],
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    eng.run(reqs, max_steps=2000)
    print(f"{cfg.name}: {sum(r.done for r in reqs)}/{len(reqs)} done, "
          f"{eng.tokens_out} tokens")


if __name__ == "__main__":
    main()
