"""Training step builder: microbatch accumulation + optimizer update.

``make_train_step(model, opt, accum_steps)`` returns a pure function
    step(params, opt_state, batch, rng) -> (params', opt_state', metrics)
suitable for jit with in/out shardings (see launch/dryrun.py) and for the
fault-tolerant loop (repro.runtime.train_loop).

Gradient accumulation reshapes the global batch [B, ...] into
[A, B/A, ...] and lax.scan's over microbatches, accumulating grads in
``accum_dtype`` (bf16 halves the grad-buffer footprint for the 100B+
archs; stochastic-rounding AdamW makes that loss of precision safe —
see repro.optim.adamw).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain, current_mesh
from repro.distributed.specs import param_logical_tree
from repro.models.transformer import Model
from repro.optim.adamw import AdamW


def _constrain_like_params(grads, params):
    """Pin gradient shardings to the parameter layout at the point of
    production.  Without this the SPMD partitioner materializes full
    per-layer gradients and all-reduces them replicated (observed:
    12.7 GB x n_layers x n_micro on llama3-405b) instead of
    reduce-scattering into the ZeRO-3 layout."""
    if current_mesh() is None:
        return grads
    logical = param_logical_tree(params)
    return jax.tree.map(lambda g, names: constrain(g, *names),
                        grads, logical)


def make_train_step(model: Model, opt: AdamW, *, accum_steps: int = 1,
                    accum_dtype: Any = jnp.bfloat16):
    def loss_fn(params, micro):
        return model.loss(params, micro)

    def grad_fn(params, micro):
        loss, grads = jax.value_and_grad(loss_fn)(params, micro)
        return loss, _constrain_like_params(grads, params)

    def train_step(params, opt_state, batch, rng):
        if accum_steps == 1:
            loss, grads = grad_fn(params, batch)
        else:
            def split(x):
                a = accum_steps
                return x.reshape((a, x.shape[0] // a) + x.shape[1:])

            micro_batches = jax.tree.map(split, batch)

            def body(acc, micro):
                loss_sum, g_acc = acc
                loss, g = grad_fn(params, micro)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(accum_dtype), g_acc, g)
                return (loss_sum + loss, g_acc), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params)
            (loss_sum, grads), _ = jax.lax.scan(
                body, (jnp.float32(0), g0), micro_batches)
            loss = loss_sum / accum_steps
            # stay in accum_dtype: /accum is exact for power-of-2 steps,
            # and a tree-wide f32 upcast would transiently double the
            # grad footprint
            grads = jax.tree.map(lambda g: g / accum_steps, grads)

        new_params, new_state = opt.apply(grads, opt_state, params,
                                          rng=rng)
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "step": new_state["step"]}
        return new_params, new_state, metrics

    return train_step


def main() -> None:
    """CLI launcher: train any assigned architecture.

    python -m repro.launch.train --arch gemma3-1b --steps 5 --reduced
    (--reduced instantiates the smoke-sized config; without it the full
    config is built — only sensible on real hardware.)
    """
    import argparse

    from repro.configs import get_config, list_configs, reduced
    from repro.data import ShardedLoader, SyntheticTokens
    from repro.optim import AdamWConfig, cosine_schedule
    from repro.runtime import TrainLoop, TrainLoopConfig

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_configs())
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-sized config (CPU-runnable)")
    ap.add_argument("--ckpt-dir", default=None,
                    help="default: /tmp/repro_train_ckpt/<arch>")
    args = ap.parse_args()
    if args.ckpt_dir is None:
        args.ckpt_dir = f"/tmp/repro_train_ckpt/{args.arch}"

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    print(f"{cfg.name}: {cfg.n_params()/1e6:.1f}M params")
    model = Model(cfg, dtype=jnp.float32 if args.reduced else jnp.bfloat16,
                  loss_chunk=min(256, args.seq),
                  attn_chunk=min(512, args.seq))
    opt = AdamW(AdamWConfig(lr=cosine_schedule(
        args.lr, warmup_steps=5, total_steps=args.steps)))
    params = model.init_params(jax.random.key(0))
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(model, opt,
                                      accum_steps=args.accum))

    src = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          batch_size=args.batch, seed=0)
    loader = ShardedLoader(src.batch, prefetch=2)

    def batch_fn(step):
        b = loader.get(step)
        out = {k: jnp.asarray(v) for k, v in b.items()}
        if cfg.is_enc_dec:
            out["frames"] = jnp.zeros(
                (args.batch, cfg.frontend_len, cfg.d_model))
        if cfg.frontend == "vision":
            out["patches"] = jnp.zeros(
                (args.batch, cfg.frontend_len, cfg.d_model))
        return out

    loop = TrainLoop(step_fn, TrainLoopConfig(
        total_steps=args.steps, checkpoint_every=max(5, args.steps // 3)),
        args.ckpt_dir, batch_fn=batch_fn)
    loop.run((params, opt_state))
    if loop.metrics_log:
        print(f"loss {loop.metrics_log[0]['loss']:.3f} -> "
              f"{loop.metrics_log[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
