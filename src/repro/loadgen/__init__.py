"""repro.loadgen — open-loop load generation for the SNN serving stack.

The measurement substrate the serving/training work is judged by:
seeded arrival processes (:mod:`~repro.loadgen.arrivals`),
reproducible request-mix specs (:mod:`~repro.loadgen.workload`),
bit-identically replayable traces (:mod:`~repro.loadgen.trace`),
mergeable log-bucketed latency histograms
(:mod:`~repro.loadgen.histogram`), and a coordinated-omission-correct
virtual-clock driver with SLO attainment and a sustainable-rate sweep
(:mod:`~repro.loadgen.runner`).

``runner`` imports :mod:`repro.serving` (which itself uses
``loadgen.histogram`` for the engine's latency accounting), so its
symbols load lazily here — ``from repro.loadgen import run_rows``
works, but importing :mod:`repro.serving` never recurses back through
it.
"""

from repro.loadgen.arrivals import ArrivalSpec, timestamps, u01, u64
from repro.loadgen.histogram import LatencyHistogram
from repro.loadgen.trace import (TraceError, generate_rows, read_trace,
                                 scale_rows, stream_sha, verify_payloads,
                                 write_trace)
from repro.loadgen.workload import WorkloadSpec, u64_stream

_RUNNER_SYMBOLS = ("LoadReport", "PacedWallClock", "ServiceModel",
                   "VirtualClock", "make_clock", "rate_sweep", "run_rows")

__all__ = [
    "ArrivalSpec", "timestamps", "u01", "u64",
    "LatencyHistogram",
    "TraceError", "generate_rows", "read_trace", "scale_rows",
    "stream_sha", "verify_payloads", "write_trace",
    "WorkloadSpec", "u64_stream",
    *_RUNNER_SYMBOLS,
]


def __getattr__(name: str):
    if name in _RUNNER_SYMBOLS:
        from repro.loadgen import runner
        return getattr(runner, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
