"""Seeded open-loop arrival processes on a virtual clock.

An *open-loop* load generator decides request arrival times from the
offered-rate process alone — never from how fast the server is
draining — which is what makes the measured latencies immune to
coordinated omission: a backed-up server cannot slow the arrival
stream down and thereby hide its own queueing delay.  Every process
here emits a deterministic, seed-reproducible, nondecreasing stream of
**virtual-clock timestamps in milliseconds** (rounded to 1 us so the
stream serializes exactly in traces).

Randomness comes from a stateless splitmix64-style counter hash
(:func:`u64`) rather than a stateful library RNG: any (seed, counter)
pair can be drawn in isolation, the stream is identical on every
platform and library version, and a replayed trace can re-derive any
request's draw without regenerating its predecessors — the same
argument :func:`repro.core.lfsr.counter_hash` makes for the in-kernel
spike draw.

Processes
---------

``uniform``
    Constant inter-arrival gap ``1000 / rate_rps`` ms.
``poisson``
    Exponential i.i.d. gaps with mean ``1000 / rate_rps`` ms (the
    memoryless process heavy-traffic queueing results assume).
``onoff``
    Bursty modulated Poisson: a square wave of period ``period_ms``
    spends ``duty`` of each period in the ON phase at
    ``burst_factor x`` the mean rate and the rest in the OFF phase at
    the complementary rate, so the long-run offered rate is still
    ``rate_rps`` — the arrival pattern tail-latency percentiles are
    most sensitive to.
"""

from __future__ import annotations

import dataclasses
import math

_M64 = (1 << 64) - 1
_P1 = 0x9E3779B97F4A7C15      # golden-ratio increment (splitmix64)
_P2 = 0xBF58476D1CE4E5B9
_P3 = 0x94D049BB133111EB

ARRIVAL_PROCESSES = ("uniform", "poisson", "onoff")


def u64(seed: int, *counters: int) -> int:
    """Stateless 64-bit draw for (seed, counters...): a Weyl-style
    combination of the counters finalized with the splitmix64 mixer.
    Pure integer arithmetic — bit-identical on every platform."""
    z = (seed * _P1) & _M64
    for i, c in enumerate(counters):
        z = (z + (c + 1) * ((_P2 + 2 * i) & _M64)) & _M64
    z ^= z >> 30
    z = (z * _P2) & _M64
    z ^= z >> 27
    z = (z * _P3) & _M64
    return z ^ (z >> 31)


def u01(seed: int, *counters: int) -> float:
    """Uniform in [0, 1) with 53 random bits (never exactly 1.0)."""
    return (u64(seed, *counters) >> 11) / float(1 << 53)


@dataclasses.dataclass(frozen=True)
class ArrivalSpec:
    """One seeded arrival process: ``n_requests`` virtual timestamps."""
    process: str = "poisson"      # uniform | poisson | onoff
    rate_rps: float = 1000.0      # long-run offered rate (requests/s)
    n_requests: int = 1000
    seed: int = 0
    # --- onoff modulation only ------------------------------------
    burst_factor: float = 4.0     # ON-phase rate multiplier (> 1)
    duty: float = 0.2             # fraction of each period spent ON
    period_ms: float = 100.0

    def __post_init__(self):
        if self.process not in ARRIVAL_PROCESSES:
            raise ValueError(f"process must be one of "
                             f"{ARRIVAL_PROCESSES}, got {self.process!r}")
        if self.rate_rps <= 0:
            raise ValueError(f"rate_rps must be > 0, got {self.rate_rps}")
        if self.n_requests < 1:
            raise ValueError(f"n_requests must be >= 1, got "
                             f"{self.n_requests}")
        if self.process == "onoff":
            if not 0.0 < self.duty < 1.0:
                raise ValueError(f"duty must be in (0, 1), got "
                                 f"{self.duty}")
            if self.burst_factor * self.duty >= 1.0:
                raise ValueError(
                    f"burst_factor * duty must be < 1 so the OFF-phase "
                    f"rate stays positive, got "
                    f"{self.burst_factor} * {self.duty}")
            if self.period_ms <= 0:
                raise ValueError(f"period_ms must be > 0, got "
                                 f"{self.period_ms}")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "ArrivalSpec":
        return cls(**d)


def _onoff_rate(spec: ArrivalSpec, t_ms: float) -> float:
    """Instantaneous rate (requests/ms) of the on-off square wave."""
    on = (t_ms % spec.period_ms) < spec.duty * spec.period_ms
    if on:
        return spec.rate_rps * spec.burst_factor / 1e3
    rate_off = (spec.rate_rps * (1.0 - spec.burst_factor * spec.duty)
                / (1.0 - spec.duty))
    return rate_off / 1e3


def timestamps(spec: ArrivalSpec) -> list[float]:
    """The spec's full virtual-clock arrival stream (ms, nondecreasing,
    rounded to 1 us).  Same spec -> bit-identical stream."""
    n = spec.n_requests
    gap_ms = 1e3 / spec.rate_rps
    out: list[float] = []
    if spec.process == "uniform":
        for i in range(n):
            out.append(round(i * gap_ms, 3))
        return out
    t = 0.0
    for i in range(n):
        u = u01(spec.seed, i)
        if spec.process == "poisson":
            t += -gap_ms * math.log(1.0 - u)
        else:   # onoff: exponential gap at the instantaneous phase rate
            t += -math.log(1.0 - u) / _onoff_rate(spec, t)
        out.append(round(t, 3))
    return out
