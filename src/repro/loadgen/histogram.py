"""Mergeable log-bucketed latency histogram (HDR-style).

Serving latency used to be tracked as one unbounded Python list per
metric, with ``np.percentile`` re-sorting the whole run's samples on
every ``stats()`` call — O(requests) memory and O(n log n) per report,
which a millions-of-requests load run cannot afford.
:class:`LatencyHistogram` replaces the lists with a fixed-resolution
log-linear bucket array in the scheme HDR histograms use:

* values are quantized to integer ``unit_ms`` ticks (default 1 us);
* ticks below ``2**sub_bits`` get one bucket each (exact);
* every octave above that is split into ``2**(sub_bits-1)`` linear
  sub-buckets, so relative bucket width — and therefore worst-case
  percentile error — stays below ``2**(1 - sub_bits)`` (~1.6% at the
  default ``sub_bits=7``) at any magnitude.

Bucket indices are pure integer arithmetic on the tick count (no
float ``log``), so two histograms built from the same samples are
bit-identical — the property the loadgen determinism gate asserts.
Two histograms with the same parameters **merge** by adding bucket
counts: merging shard- or run-level histograms is exact, equal to the
histogram of the concatenated samples (tested).  Percentiles are
nearest-rank over bucket midpoints, compatible with the committed
``serve/latency-*`` gate rows up to bucket resolution.

Serialization (:meth:`to_dict` / :meth:`from_dict`) is plain JSON so
per-commit artifacts can be archived alongside ``bench_history`` and
diffed across commits.
"""

from __future__ import annotations

import math


class LatencyHistogram:
    """Fixed-parameter log-bucketed histogram over millisecond values."""

    def __init__(self, unit_ms: float = 1e-3, sub_bits: int = 7):
        if unit_ms <= 0:
            raise ValueError(f"unit_ms must be > 0, got {unit_ms}")
        if not 1 <= sub_bits <= 16:
            raise ValueError(f"sub_bits must be in [1, 16], got "
                             f"{sub_bits}")
        self.unit_ms = float(unit_ms)
        self.sub_bits = int(sub_bits)
        self._sub = 1 << self.sub_bits       # one-per-tick region size
        self._half = self._sub >> 1          # sub-buckets per octave
        self.counts: dict[int, int] = {}
        self.total = 0
        self.min_ms: float | None = None
        self.max_ms: float | None = None

    # --- bucket arithmetic (integers only, so runs are bit-identical) ---

    def _index(self, ticks: int) -> int:
        if ticks < self._sub:
            return ticks
        k = ticks.bit_length() - 1           # octave: ticks in [2^k, 2^k+1)
        off = (ticks - (1 << k)) >> (k - self.sub_bits + 1)
        return self._sub + (k - self.sub_bits) * self._half + off

    def _bounds(self, index: int) -> tuple[float, float]:
        """[lo, hi) of a bucket in ticks."""
        if index < self._sub:
            return float(index), float(index + 1)
        j = index - self._sub
        k = self.sub_bits + j // self._half
        off = j % self._half
        width = 1 << (k - self.sub_bits + 1)
        lo = (1 << k) + off * width
        return float(lo), float(lo + width)

    def _midpoint_ms(self, index: int) -> float:
        lo, hi = self._bounds(index)
        return (lo + hi) / 2.0 * self.unit_ms

    # --- recording ------------------------------------------------------

    def record(self, value_ms: float, count: int = 1) -> None:
        """Record ``count`` observations of ``value_ms`` (clamped >= 0)."""
        if count <= 0:
            return
        v = max(float(value_ms), 0.0)
        idx = self._index(int(v / self.unit_ms))
        self.counts[idx] = self.counts.get(idx, 0) + count
        self.total += count
        self.min_ms = v if self.min_ms is None else min(self.min_ms, v)
        self.max_ms = v if self.max_ms is None else max(self.max_ms, v)

    def record_many(self, values_ms) -> None:
        for v in values_ms:
            self.record(float(v))

    def reset(self) -> None:
        self.counts = {}
        self.total = 0
        self.min_ms = None
        self.max_ms = None

    # --- queries --------------------------------------------------------

    @property
    def count(self) -> int:
        return self.total

    def percentile(self, p: float) -> float:
        """Nearest-rank percentile over bucket midpoints (0.0 when
        empty, matching the list-backed predecessor)."""
        if self.total == 0:
            return 0.0
        rank = min(max(int(math.ceil(p / 100.0 * self.total)), 1),
                   self.total)
        seen = 0
        for idx in sorted(self.counts):
            seen += self.counts[idx]
            if seen >= rank:
                return self._midpoint_ms(idx)
        return self._midpoint_ms(max(self.counts))   # unreachable

    def mean_ms(self) -> float:
        """Approximate mean over bucket midpoints."""
        if self.total == 0:
            return 0.0
        return sum(self._midpoint_ms(i) * c
                   for i, c in self.counts.items()) / self.total

    # --- merge / serialization -----------------------------------------

    def _compatible(self, other: "LatencyHistogram") -> bool:
        return (self.unit_ms == other.unit_ms
                and self.sub_bits == other.sub_bits)

    def merge(self, other: "LatencyHistogram") -> "LatencyHistogram":
        """Add ``other``'s buckets into this histogram (exact: equal to
        the histogram of the concatenated samples)."""
        if not self._compatible(other):
            raise ValueError(
                f"cannot merge histograms with different parameters: "
                f"(unit_ms={self.unit_ms}, sub_bits={self.sub_bits}) vs "
                f"(unit_ms={other.unit_ms}, sub_bits={other.sub_bits})")
        for idx, c in other.counts.items():
            self.counts[idx] = self.counts.get(idx, 0) + c
        self.total += other.total
        for attr, pick in (("min_ms", min), ("max_ms", max)):
            ov = getattr(other, attr)
            if ov is not None:
                sv = getattr(self, attr)
                setattr(self, attr, ov if sv is None else pick(sv, ov))
        return self

    def to_dict(self) -> dict:
        return {
            "unit_ms": self.unit_ms,
            "sub_bits": self.sub_bits,
            "total": self.total,
            "min_ms": self.min_ms,
            "max_ms": self.max_ms,
            "counts": {str(i): self.counts[i]
                       for i in sorted(self.counts)},
        }

    @classmethod
    def from_dict(cls, d: dict) -> "LatencyHistogram":
        h = cls(unit_ms=d["unit_ms"], sub_bits=d["sub_bits"])
        h.counts = {int(i): int(c) for i, c in d["counts"].items()}
        h.total = int(d["total"])
        h.min_ms = d.get("min_ms")
        h.max_ms = d.get("max_ms")
        return h

    def __eq__(self, other) -> bool:
        if not isinstance(other, LatencyHistogram):
            return NotImplemented
        return (self._compatible(other) and self.total == other.total
                and self.counts == other.counts)

    def __repr__(self) -> str:
        return (f"LatencyHistogram(n={self.total}, "
                f"p50={self.percentile(50):.3f}ms, "
                f"p99={self.percentile(99):.3f}ms)")
