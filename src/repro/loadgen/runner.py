"""Virtual-clock load driver: open-loop arrivals through the serving
engine, coordinated-omission-correct latency, SLO attainment, and a
max-sustainable-rate sweep.

**Coordinated omission.**  A closed-loop harness that submits request
``i+1`` only after request ``i`` returns silently re-times the arrival
process to the server's convenience: every stall pushes the remaining
arrivals later, so queueing delay never shows up in the numbers.  This
driver is open-loop: every request carries an *intended* arrival
timestamp drawn by :mod:`repro.loadgen.arrivals` before the run
starts, requests are injected into the admission queue as the clock
passes their timestamp, and every latency (queue-wait, service,
end-to-end) is measured **from the intended arrival time** — a backed
up server accrues the backlog it actually caused.

**Clocks.**  The engine reads time through its pluggable clock, so one
driver serves two measurement modes:

* :class:`VirtualClock` — fully deterministic.  Serving a batch
  advances the clock by a :class:`ServiceModel` cost (pure arithmetic
  in batch size and padded window length); idle gaps skip instantly.
  Two runs of the same trace produce bit-identical per-status totals
  and histogram buckets on any host — the replay/regression mode CI
  gates on.
* :class:`PacedWallClock` — measured.  The virtual timeline advances
  with real ``perf_counter`` time while work is in flight and skips
  idle gaps (no sleeping), so a full wall-clock run of an
  hour-of-traffic trace takes only as long as its busy time.  Latency
  is real, but still charged from intended arrival — the
  throughput-vs-latency mode the ``loadgen/*`` bench rows report.

**SLO.**  A request meets its SLO when it is SERVED and its
end-to-end latency (terminal time minus intended arrival) is within
its own ``deadline_ms`` — or the run-level ``slo_ms`` for requests
without one.  Attainment is the fraction of *offered* requests meeting
the SLO, so rejects, expiries, and failures all count against it.

:func:`rate_sweep` bisects the offered rate for the largest one whose
run still clears the attainment floor — the "maximum sustainable
throughput" number heavy-traffic serving work is judged by.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

from repro.loadgen.workload import WorkloadSpec
from repro.serving.snn import SERVED, SNNServingEngine


@dataclasses.dataclass(frozen=True)
class ServiceModel:
    """Deterministic virtual service cost of one serving step: an
    affine model in batch size and padded window length (the two
    launch-shape terms the real kernels scale with)."""
    base_ms: float = 0.25         # fixed dispatch overhead
    per_slot_ms: float = 0.02     # per admitted request
    per_cycle_ms: float = 0.01    # per padded presentation cycle

    def cost_ms(self, batch_size: int, t_pad: int) -> float:
        return (self.base_ms + self.per_slot_ms * batch_size
                + self.per_cycle_ms * t_pad)


class VirtualClock:
    """Deterministic virtual time (ms): advances only via recorded
    service costs and explicit idle skips."""

    def __init__(self, model: ServiceModel | None = None):
        self.model = model if model is not None else ServiceModel()
        self._now = 0.0

    def now_ms(self) -> float:
        return self._now

    def skip_to(self, ts_ms: float) -> None:
        self._now = max(self._now, ts_ms)

    def advance_service_ms(self, batch_size: int, t_pad: int,
                           inflation: float = 1.0) -> None:
        # `inflation` is the fault injector's slowdown multiplier — an
        # overload storm sags modeled capacity without failing launches
        self._now += self.model.cost_ms(batch_size, t_pad) * inflation

    def advance_ms(self, ms: float) -> None:
        """Charge a non-launch delay (retry backoff) to virtual time."""
        self._now += max(0.0, ms)


class PacedWallClock:
    """Wall-measured time on a skippable virtual axis: ``now_ms`` runs
    with ``perf_counter`` while serving, and idle gaps between the last
    completion and the next arrival are skipped, not slept."""

    def __init__(self):
        self._offset = -time.perf_counter() * 1e3   # start at 0 ms

    def now_ms(self) -> float:
        return self._offset + time.perf_counter() * 1e3

    def skip_to(self, ts_ms: float) -> None:
        gap = ts_ms - self.now_ms()
        if gap > 0:
            self._offset += gap

    def advance_service_ms(self, batch_size: int, t_pad: int,
                           inflation: float = 1.0) -> None:
        pass    # wall time advanced by itself during the launch

    def advance_ms(self, ms: float) -> None:
        """Charge a retry-backoff delay to the virtual axis instead of
        sleeping through it — the backoff shows up in latency without
        stalling the harness."""
        self._offset += max(0.0, ms)


def make_clock(mode: str, model: ServiceModel | None = None):
    if mode == "virtual":
        return VirtualClock(model)
    if mode == "wall":
        return PacedWallClock()
    raise ValueError(f"clock mode must be 'virtual' or 'wall', got "
                     f"{mode!r}")


@dataclasses.dataclass
class LoadReport:
    """One load run, summarized.  ``to_dict()`` is JSON-ready; the
    histograms serialize in full so per-commit artifacts can be merged
    or re-quantiled later."""
    n_offered: int
    per_status: dict
    non_terminal: int
    steps: int
    duration_ms: float            # first arrival -> last completion
    offered_rps: float            # arrival-stream rate
    achieved_rps: float           # served / duration
    slo_ms: float
    slo_attainment: float
    goodput_rps: float            # SLO-meeting serves / duration
    slo_attainment_by_priority: dict  # str(priority) -> attainment
    e2e_ms_p50: float
    e2e_ms_p99: float
    e2e_ms_p999: float
    queue_wait_ms_p50: float
    queue_wait_ms_p99: float
    service_hist: dict
    queue_wait_hist: dict

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def summary(self) -> str:
        scalars = {k: v for k, v in self.to_dict().items()
                   if not isinstance(v, dict)}
        status = " ".join(f"{k}={v}" for k, v in
                          sorted(self.per_status.items()))
        return (" ".join(f"{k}={v}" for k, v in sorted(scalars.items()))
                + " " + status)


def _round3(x: float) -> float:
    return round(float(x), 3)


def run_rows(engine: SNNServingEngine, workload: WorkloadSpec,
             rows: list[dict], *, slo_ms: float = 50.0,
             verify_payloads: bool = False, keep_payloads: bool = False,
             max_steps: int = 50_000_000,
             resume_offset: int = 0) -> LoadReport:
    """Drive one engine through one recorded request stream.

    The engine must have been constructed with a loadgen clock
    (:func:`make_clock`).  Its queue is normally empty, but a
    journal-recovered engine may start with re-queued requests (and a
    restored clock) — the loop drains them before the next arrival.
    ``resume_offset`` skips rows a previous (crashed) run already made
    durable: pass ``engine.journal_resume_offset`` so a restarted
    replay continues from the last journaled offset instead of
    re-offering from row 0.  Rows are injected strictly by intended
    timestamp, each request's ``t_submit_ms`` is pre-stamped to that
    timestamp (the coordinated-omission guarantee), and payloads are
    freed as requests terminate unless ``keep_payloads`` — memory stays
    flat at millions of requests.
    """
    clock = engine.clock
    if resume_offset:
        rows = rows[resume_offset:]
    reqs: list = []
    inflight: list = []     # admitted, not yet freed — stays ~queue-sized
    i, n, steps = 0, len(rows), 0
    first_ts = rows[0]["ts"] if rows else 0.0

    def _free(r) -> None:
        r.window = r.intensities = r.counts = None

    while True:
        now = clock.now_ms()
        while i < n and rows[i]["ts"] <= now:
            req = workload.materialize(rows[i], verify=verify_payloads)
            req.t_submit_ms = rows[i]["ts"]
            engine.submit(req)
            reqs.append(req)
            if not keep_payloads:
                if req.terminal:        # structural reject at submit
                    _free(req)
                else:
                    inflight.append(req)
            i += 1
        if engine.queue:
            if steps >= max_steps:
                break
            engine.step()
            steps += 1
            if not keep_payloads:
                live = []
                for r in inflight:
                    if r.terminal:
                        _free(r)
                    else:
                        live.append(r)
                inflight = live
            continue
        if i >= n:
            break
        clock.skip_to(rows[i]["ts"])
    end_ms = clock.now_ms()

    per_status: dict[str, int] = {}
    non_terminal = 0
    slo_met = 0
    prio_offered: dict[str, int] = {}
    prio_met: dict[str, int] = {}
    for r in reqs:
        per_status[r.status] = per_status.get(r.status, 0) + 1
        if not r.terminal:
            non_terminal += 1
        target = r.deadline_ms if r.deadline_ms is not None else slo_ms
        pk = str(r.priority)
        prio_offered[pk] = prio_offered.get(pk, 0) + 1
        if (r.status == SERVED and r.service_ms is not None
                and r.service_ms <= target):
            slo_met += 1
            prio_met[pk] = prio_met.get(pk, 0) + 1
    span_ms = max((rows[-1]["ts"] - first_ts) if n > 1 else 0.0, 1e-6)
    duration_ms = max(end_ms - first_ts, 1e-6)
    served = per_status.get(SERVED, 0)
    return LoadReport(
        n_offered=n,
        per_status=per_status,
        non_terminal=non_terminal,
        steps=steps,
        duration_ms=_round3(duration_ms),
        offered_rps=_round3(n / span_ms * 1e3),
        achieved_rps=_round3(served / duration_ms * 1e3),
        slo_ms=slo_ms,
        slo_attainment=round(slo_met / max(n, 1), 4),
        goodput_rps=_round3(slo_met / duration_ms * 1e3),
        slo_attainment_by_priority={
            pk: round(prio_met.get(pk, 0) / cnt, 4)
            for pk, cnt in sorted(prio_offered.items())},
        e2e_ms_p50=_round3(engine.service_hist.percentile(50)),
        e2e_ms_p99=_round3(engine.service_hist.percentile(99)),
        e2e_ms_p999=_round3(engine.service_hist.percentile(99.9)),
        queue_wait_ms_p50=_round3(engine.queue_wait_hist.percentile(50)),
        queue_wait_ms_p99=_round3(engine.queue_wait_hist.percentile(99)),
        service_hist=engine.service_hist.to_dict(),
        queue_wait_hist=engine.queue_wait_hist.to_dict(),
    )


def rate_sweep(run_at: Callable[[float], LoadReport],
               lo_rps: float, hi_rps: float, *,
               slo_floor: float = 0.95, iters: int = 7
               ) -> tuple[float, LoadReport]:
    """Bisect the largest offered rate whose run clears ``slo_floor``.

    ``run_at(rate)`` must run a fresh engine over a stream offered at
    ``rate`` and return its report.  If even ``lo_rps`` fails the
    floor, returns ``(0.0, that report)``; if ``hi_rps`` passes,
    returns it (the search range was the binding constraint).  The
    returned report is the one measured at the returned rate."""
    rep_lo = run_at(lo_rps)
    if rep_lo.slo_attainment < slo_floor:
        return 0.0, rep_lo
    rep_hi = run_at(hi_rps)
    if rep_hi.slo_attainment >= slo_floor:
        return hi_rps, rep_hi
    best, best_rep = lo_rps, rep_lo
    lo, hi = lo_rps, hi_rps
    for _ in range(iters):
        mid = (lo + hi) / 2.0
        rep = run_at(mid)
        if rep.slo_attainment >= slo_floor:
            best, best_rep, lo = mid, rep, mid
        else:
            hi = mid
    return best, best_rep
