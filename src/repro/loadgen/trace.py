"""Replayable load traces: record a generated request stream, replay it
bit-identically against any commit.

A trace is a JSONL file.  Line 1 is the **header** — trace version,
the :class:`~repro.loadgen.arrivals.ArrivalSpec` and
:class:`~repro.loadgen.workload.WorkloadSpec` that generated the
stream, the request count, and a SHA-256 **stream digest** over the
canonical serialization of every request row.  Two densities share
that header:

* ``kind="full"`` — one JSON row per request follows (ids, virtual
  timestamps, sampled fields, payload seed + content hash; payload
  *bytes* are never stored — they regenerate from the seed).
* ``kind="compact"`` — no rows follow.  Because sampling is stateless
  and seeded, the stream is fully derivable from the header's specs;
  :func:`read_trace` regenerates it and verifies the stream digest, so
  a multi-megabyte 50k-request stream commits as a few hundred bytes
  while remaining pinned bit-for-bit.  Tampering with the header specs
  or regenerating with drifted sampling code fails the digest check
  (:class:`TraceError`), never silently replays different traffic.

Either way, ``read_trace`` hands back ``(header, rows)`` where the
rows are exactly what the recorder produced: same ids, same seeds,
same timestamps, same payload hashes.
"""

from __future__ import annotations

import hashlib
import json

from repro.loadgen.arrivals import ArrivalSpec, timestamps
from repro.loadgen.workload import WorkloadSpec

TRACE_VERSION = 1


class TraceError(ValueError):
    """A trace failed structural or digest verification."""


def _canon(obj) -> str:
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


def stream_sha(rows: list[dict]) -> str:
    """SHA-256 over the canonical serialization of the row stream."""
    h = hashlib.sha256()
    for row in rows:
        h.update(_canon(row).encode())
        h.update(b"\n")
    return h.hexdigest()


def generate_rows(arrivals: ArrivalSpec,
                  workload: WorkloadSpec) -> list[dict]:
    """Sample the full request stream the two specs define."""
    return [workload.sample_row(rid, ts)
            for rid, ts in enumerate(timestamps(arrivals))]


def make_header(arrivals: ArrivalSpec, workload: WorkloadSpec,
                rows: list[dict], *, kind: str) -> dict:
    return {
        "version": TRACE_VERSION,
        "kind": kind,
        "n_requests": len(rows),
        "stream_sha256": stream_sha(rows),
        "arrivals": arrivals.to_dict(),
        "workload": workload.to_dict(),
    }


def write_trace(path: str, arrivals: ArrivalSpec, workload: WorkloadSpec,
                rows: list[dict] | None = None, *,
                compact: bool = False) -> dict:
    """Record a trace (generating the rows if not given); returns the
    header.  ``compact=True`` writes the header only — the stream stays
    pinned by its digest and regenerates on read."""
    if rows is None:
        rows = generate_rows(arrivals, workload)
    header = make_header(arrivals, workload, rows,
                         kind="compact" if compact else "full")
    with open(path, "w") as fh:
        fh.write(_canon(header) + "\n")
        if not compact:
            for row in rows:
                fh.write(_canon(row) + "\n")
    return header


def read_trace(path: str) -> tuple[dict, list[dict]]:
    """Load (and for compact traces, regenerate) a trace; verifies the
    stream digest either way.  Raises :class:`TraceError` on any
    mismatch — a trace that fails verification must never be served."""
    with open(path) as fh:
        lines = [ln for ln in fh.read().splitlines() if ln.strip()]
    if not lines:
        raise TraceError(f"{path}: empty trace file")
    try:
        header = json.loads(lines[0])
    except json.JSONDecodeError as e:
        raise TraceError(f"{path}: unparseable header: {e}") from e
    if header.get("version") != TRACE_VERSION:
        raise TraceError(f"{path}: unsupported trace version "
                         f"{header.get('version')!r}")
    arrivals = ArrivalSpec.from_dict(header["arrivals"])
    workload = WorkloadSpec.from_dict(header["workload"])
    if header.get("kind") == "compact":
        rows = generate_rows(arrivals, workload)
    else:
        try:
            rows = [json.loads(ln) for ln in lines[1:]]
        except json.JSONDecodeError as e:
            raise TraceError(f"{path}: unparseable row: {e}") from e
    if len(rows) != header["n_requests"]:
        raise TraceError(
            f"{path}: header says {header['n_requests']} requests, "
            f"got {len(rows)} rows")
    digest = stream_sha(rows)
    if digest != header["stream_sha256"]:
        raise TraceError(
            f"{path}: stream digest mismatch — recorded "
            f"{header['stream_sha256'][:12]}…, got {digest[:12]}… "
            f"(tampered rows, or sampling drift vs the recording "
            f"commit)")
    return header, rows


def scale_rows(rows: list[dict], factor: float) -> list[dict]:
    """Time-compress a recorded stream: divide every intended arrival
    by ``factor``, multiplying the offered rate (factor=5 turns a 1x
    trace into the same requests at 5x).  Payload content hashes are
    unaffected — ``payload_sha`` covers the sampled fields and seed,
    not the timestamp — so a scaled stream still verifies per-row."""
    if factor <= 0:
        raise ValueError(f"factor must be > 0, got {factor}")
    return [{**row, "ts": round(row["ts"] / factor, 3)} for row in rows]


def verify_payloads(workload: WorkloadSpec, rows: list[dict]) -> int:
    """Re-derive every row's payload and check its content hash;
    returns the number of rows checked (raises on the first
    mismatch)."""
    for row in rows:
        if workload.payload_sha(row) != row["sha"]:
            raise TraceError(
                f"row {row['rid']}: payload hash mismatch "
                f"(recorded {row['sha']}, regenerated "
                f"{workload.payload_sha(row)})")
    return len(rows)
