"""Reproducible request-mix specifications for the SNN load generator.

A :class:`WorkloadSpec` describes the *shape* of serving traffic — the
pre-packed vs intensity request mix, the window-length (T-bucket)
distribution, and the priority / deadline mix — and samples a concrete
request stream from a seed.  Sampling is per-request stateless (every
field of request ``rid`` is a counter-hash draw keyed on
``(seed, rid)``), so a trace row can be re-materialized in isolation,
in any order, on any platform, bit-identically.

A sampled request is represented twice:

* a **row** — the small JSON-serializable dict that goes into a trace
  (ids, seeds, field choices, and a payload content hash, never the
  payload bytes themselves);
* the **materialized** :class:`repro.serving.snn.SNNRequest`, whose
  payload (uint8 intensities or a packed uint32 spike window) is
  regenerated from the row's ``seed`` by the same counter hash and
  verified against the recorded ``sha`` — so traces stay small while
  replay remains bit-exact.
"""

from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

from repro.loadgen.arrivals import u64

_M64 = (1 << 64) - 1
_P1 = 0x9E3779B97F4A7C15
_P2 = 0xBF58476D1CE4E5B9
_P3 = 0x94D049BB133111EB

KIND_INTENSITY = "I"
KIND_WINDOW = "W"

# field tags for the per-request draws (keyed so adding a field never
# perturbs the existing ones)
_TAG_KIND, _TAG_T, _TAG_PRIO, _TAG_DDL, _TAG_SEED = 11, 12, 13, 14, 15


def u64_stream(seed: int, n: int, tag: int = 0) -> np.ndarray:
    """Vectorized counter-mode stream: element ``i`` equals
    ``arrivals.u64(seed, i, tag)`` (tested) — splitmix64 finalizer over
    a two-counter Weyl combination, wrapping uint64 arithmetic."""
    z0 = np.uint64((seed * _P1) & _M64)
    idx = np.arange(1, n + 1, dtype=np.uint64)
    z = (z0 + idx * np.uint64(_P2)
         + np.uint64(((tag + 1) * ((_P2 + 2) & _M64)) & _M64))
    z ^= z >> np.uint64(30)
    z *= np.uint64(_P2)
    z ^= z >> np.uint64(27)
    z *= np.uint64(_P3)
    return z ^ (z >> np.uint64(31))


def _payload_bytes(seed: int, n_bytes: int, tag: int = 0) -> np.ndarray:
    words = u64_stream(seed, (n_bytes + 7) // 8, tag=tag)
    return words.view(np.uint8)[:n_bytes]


def _choice(options: tuple, weights: tuple, draw: int):
    """Integer-weighted choice from a 64-bit draw."""
    total = sum(weights)
    r = draw % total
    for opt, wgt in zip(options, weights):
        r -= wgt
        if r < 0:
            return opt
    return options[-1]


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """Seeded request-mix distribution over the serving request space.

    ``p_intensity`` picks intensity requests (uint8[n_inputs] + a
    counter seed — the production shape) vs pre-packed uint32[T, w]
    spike windows; ``t_choices``/``t_weights`` give the presentation
    window-length mix (the serving engine compiles one launch per
    T-quantum bucket, so this distribution is what exercises ragged
    batching); ``priority_*`` and ``deadline_*`` draw the admission
    policy inputs (a deadline of ``None`` defers to the engine
    policy's default)."""
    n_inputs: int = 256               # synapse lanes (32 * words)
    p_intensity: float = 1.0
    t_choices: tuple = (8, 12, 16)
    t_weights: tuple = (1, 1, 1)
    priority_choices: tuple = (0,)
    priority_weights: tuple = (1,)
    deadline_choices: tuple = (None,)  # ms | None
    deadline_weights: tuple = (1,)
    seed: int = 0

    def __post_init__(self):
        if self.n_inputs < 32 or self.n_inputs % 32:
            raise ValueError(f"n_inputs must be a positive multiple of "
                             f"32, got {self.n_inputs}")
        if not 0.0 <= self.p_intensity <= 1.0:
            raise ValueError(f"p_intensity must be in [0, 1], got "
                             f"{self.p_intensity}")
        for name in ("t", "priority", "deadline"):
            opts = getattr(self, f"{name}_choices")
            wgts = getattr(self, f"{name}_weights")
            if len(opts) != len(wgts) or not opts:
                raise ValueError(f"{name}_choices/{name}_weights must be "
                                 f"equal-length and nonempty")
            if any(w < 0 for w in wgts) or sum(wgts) <= 0:
                raise ValueError(f"{name}_weights must be nonnegative "
                                 f"with a positive sum")
        if any(t < 1 for t in self.t_choices):
            raise ValueError(f"t_choices must be >= 1, got "
                             f"{self.t_choices}")

    @property
    def words(self) -> int:
        return self.n_inputs // 32

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        for k in ("t_choices", "t_weights", "priority_choices",
                  "priority_weights", "deadline_choices",
                  "deadline_weights"):
            d[k] = list(d[k])
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "WorkloadSpec":
        d = dict(d)
        for k in ("t_choices", "t_weights", "priority_choices",
                  "priority_weights", "deadline_choices",
                  "deadline_weights"):
            if k in d:
                d[k] = tuple(d[k])
        return cls(**d)

    # --- sampling -------------------------------------------------------

    def sample_row(self, rid: int, ts_ms: float) -> dict:
        """The trace row for request ``rid`` arriving at ``ts_ms``."""
        kind = (KIND_INTENSITY
                if (u64(self.seed, rid, _TAG_KIND) >> 11) / float(1 << 53)
                < self.p_intensity else KIND_WINDOW)
        t = _choice(self.t_choices, self.t_weights,
                    u64(self.seed, rid, _TAG_T))
        prio = _choice(self.priority_choices, self.priority_weights,
                       u64(self.seed, rid, _TAG_PRIO))
        ddl = _choice(self.deadline_choices, self.deadline_weights,
                      u64(self.seed, rid, _TAG_DDL))
        seed = int(u64(self.seed, rid, _TAG_SEED) & 0x7FFFFFFF)
        row = {"rid": int(rid), "ts": float(ts_ms), "kind": kind,
               "t": int(t), "prio": int(prio),
               "ddl": None if ddl is None else float(ddl),
               "seed": seed}
        row["sha"] = self.payload_sha(row)
        return row

    def payload(self, row: dict) -> np.ndarray:
        """Regenerate the request payload from its row (bit-exact)."""
        if row["kind"] == KIND_INTENSITY:
            return np.array(
                _payload_bytes(row["seed"], self.n_inputs), np.uint8)
        raw = _payload_bytes(row["seed"], row["t"] * self.words * 4,
                             tag=1)
        return raw.view(np.uint32).reshape(row["t"], self.words).copy()

    def payload_sha(self, row: dict) -> str:
        """Content hash binding the row's fields to its payload bytes."""
        head = (f"{row['rid']}|{row['kind']}|{row['t']}|{row['prio']}|"
                f"{row['ddl']}|{row['seed']}|").encode()
        return hashlib.sha256(
            head + self.payload(row).tobytes()).hexdigest()[:16]

    def materialize(self, row: dict, *, verify: bool = False):
        """Build the :class:`SNNRequest` a trace row describes.  With
        ``verify=True`` the regenerated payload's content hash must
        match the recorded one (raises ``ValueError`` otherwise)."""
        # local import: repro.serving imports loadgen.histogram, so a
        # module-level import here would be circular
        from repro.serving.snn import SNNRequest

        if verify and row.get("sha") != self.payload_sha(row):
            raise ValueError(
                f"trace row {row['rid']}: payload hash mismatch "
                f"(recorded {row.get('sha')}, regenerated "
                f"{self.payload_sha(row)})")
        payload = self.payload(row)
        # the row rides along so a journaled engine can ADMIT-log the
        # tiny descriptor (and re-materialize after a crash) instead of
        # the payload bytes; content_sha is the exactly-once audit key
        if row["kind"] == KIND_INTENSITY:
            return SNNRequest(rid=row["rid"], intensities=payload,
                              n_steps=row["t"], seed=row["seed"],
                              priority=row["prio"],
                              deadline_ms=row["ddl"],
                              trace_row=dict(row),
                              content_sha=row.get("sha"))
        return SNNRequest(rid=row["rid"], window=payload,
                          priority=row["prio"], deadline_ms=row["ddl"],
                          trace_row=dict(row), content_sha=row.get("sha"))
