"""repro.models — the LM model zoo (assigned architectures).

Functional JAX (no framework dependency): params are pytrees, models are
pure functions.  A single config-driven ``transformer.Model`` covers all
10 assigned architectures (dense / GQA / MoE / SSM / hybrid / enc-dec /
stub-frontend VLM+audio); see repro.configs for the exact configs.
"""

from repro.models.transformer import Model

__all__ = ["Model"]
