"""GQA attention: chunked (flash-style) XLA path + decode-with-cache.

Three execution paths, all matching ``repro.kernels.ref.attention_ref``:

* ``chunked_attention`` — lax.scan over kv blocks with online softmax.
  Never materializes the [Tq, Tk] score matrix, so 32k-token prefill fits
  HBM.  This is what the multi-pod dry-run lowers (pure XLA -> SPMD
  partitionable).
* ``repro.kernels.flash_attention`` — the Pallas TPU kernel (same math,
  single-chip deployment path; selected with ``use_pallas=True``).
* ``decode_attention`` — one-token query against a KV cache laid out
  [B, Hkv, S, D].  The cache sequence axis is sharded over the `model`
  mesh axis (flash-decode); XLA inserts the small max/sum all-reduces.

Weights layout: fused qkv projection [d, (Hq + 2*Hkv) * Dh] so one matmul
produces q/k/v (fewer, larger MXU ops).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.models.layers.rope import apply_rope

_NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    window: int | None = None     # sliding-window size (None = full)
    causal: bool = True           # False for encoder self-attention
    use_bias: bool = False
    chunk_k: int = 1024           # kv block for the chunked path
    use_rope: bool = True


def init(key, cfg: AttnConfig, dtype=jnp.bfloat16) -> dict:
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    k1, k2 = jax.random.split(key)
    std = d ** -0.5
    p = {
        "wqkv": (jax.random.normal(k1, (d, (hq + 2 * hkv) * hd)) * std
                 ).astype(dtype),
        "wo": (jax.random.normal(k2, (hq * hd, d)) * (hq * hd) ** -0.5
               ).astype(dtype),
    }
    if cfg.use_bias:
        p["bqkv"] = jnp.zeros(((hq + 2 * hkv) * hd,), dtype)
        p["bo"] = jnp.zeros((d,), dtype)
    return p


def _split_qkv(params, x, cfg: AttnConfig):
    """x: [B, T, d] -> q [B, Hq, T, Dh], k/v [B, Hkv, T, Dh]."""
    b, t, _ = x.shape
    qkv = x @ params["wqkv"]
    if cfg.use_bias:
        qkv = qkv + params["bqkv"]
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q, k, v = jnp.split(qkv, [hq * hd, (hq + hkv) * hd], axis=-1)
    q = q.reshape(b, t, hq, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, t, hkv, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, t, hkv, hd).transpose(0, 2, 1, 3)
    return q, k, v


def chunked_attention(q, k, v, *, causal=True, window=None, chunk_k=1024,
                      q_offset=0):
    """Online-softmax attention, scanning kv chunks.

    q: [B, Hq, Tq, D]; k, v: [B, Hkv, Tk, D].  ``q_offset``: absolute
    position of q[...,0,:] minus that of k[...,0,:] (prefill: Tk - Tq).
    """
    b, hq, tq, d = q.shape
    _, hkv, tk, _ = k.shape
    group = hq // hkv
    scale = d ** -0.5
    chunk_k = min(chunk_k, tk)
    tk_valid = tk
    if tk % chunk_k:
        pad = chunk_k - tk % chunk_k
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        tk = k.shape[2]
    nk = tk // chunk_k

    qf = q.astype(jnp.float32) * scale
    qg = qf.reshape(b, hkv, group, tq, d)
    kc = k.astype(jnp.float32).reshape(b, hkv, nk, chunk_k, d)
    vc = v.astype(jnp.float32).reshape(b, hkv, nk, chunk_k, d)
    kc = jnp.moveaxis(kc, 2, 0)  # [nk, B, Hkv, C, D]
    vc = jnp.moveaxis(vc, 2, 0)

    q_pos = jnp.arange(tq) + q_offset  # absolute positions of queries

    def body(carry, inp):
        acc, m, l = carry
        j, kj, vj = inp
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kj)
        k_pos = j * chunk_k + jnp.arange(chunk_k)
        mask = jnp.broadcast_to(k_pos[None, :] < tk_valid, (tq, chunk_k))
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(mask[None, None, None], s, _NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask[None, None, None], p, 0.0)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum("bhgqk,bhkd->bhgqd", p, vj)
        return (acc, m_new, l), None

    acc0 = jnp.zeros((b, hkv, group, tq, d), jnp.float32)
    m0 = jnp.full((b, hkv, group, tq, 1), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, group, tq, 1), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(
        body, (acc0, m0, l0), (jnp.arange(nk), kc, vc))
    l = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l).reshape(b, hq, tq, d)
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *, window=None):
    """Single-token attention over a cache.

    q: [B, Hq, 1, D]; caches: [B, Hkv, S, D]; cache_len: int32[] OR
    int32[B] (per-sequence — continuous batching) number of valid
    positions (the new token's kv must already be written at position
    cache_len - 1).
    """
    b, hq, _, d = q.shape
    hkv = k_cache.shape[1]
    group = hq // hkv
    s_len = k_cache.shape[2]
    scale = d ** -0.5
    cl = jnp.asarray(cache_len)
    if cl.ndim == 1:
        cl = cl[:, None, None, None]
    qg = (q.astype(jnp.float32) * scale).reshape(b, hkv, group, d)
    kf = k_cache.astype(jnp.float32)
    s = jnp.einsum("bhgd,bhkd->bhgk", qg, kf)
    k_pos = jnp.arange(s_len)
    mask = k_pos[None, None, None, :] < cl
    if window is not None:
        mask &= k_pos[None, None, None, :] > cl - 1 - window
    s = jnp.where(mask, s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bhkd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, hq, 1, d).astype(q.dtype)


# --- full layer forward passes ------------------------------------------------


def forward(params, x, cfg: AttnConfig, *, positions=None, kv_x=None,
            return_kv: bool = False):
    """Training / prefill self- (or cross-) attention.

    x: [B, T, d].  kv_x: encoder output for cross-attention (no rope,
    no causal mask).  Returns [B, T, d], or (y, (k, v)) when
    ``return_kv`` (k/v post-rope, [B, Hkv, T, D] — prefill cache fill).
    """
    b, t, _ = x.shape
    if kv_x is None:
        q, k, v = _split_qkv(params, x, cfg)
        if positions is None:
            positions = jnp.arange(t)
        if cfg.use_rope:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
        out = chunked_attention(q, k, v, causal=cfg.causal,
                                window=cfg.window, chunk_k=cfg.chunk_k,
                                q_offset=0)
    else:
        # cross-attention: q from x, kv from encoder stream
        q, _, _ = _split_qkv(params, x, cfg)
        _, k, v = _split_qkv(params, kv_x, cfg)
        out = chunked_attention(q, k, v, causal=False, window=None,
                                chunk_k=cfg.chunk_k)
    y = out.transpose(0, 2, 1, 3).reshape(b, t, -1) @ params["wo"]
    if cfg.use_bias:
        y = y + params["bo"]
    if return_kv:
        return y, (k, v)
    return y


def init_cache(batch: int, cfg: AttnConfig, max_len: int,
               dtype=jnp.bfloat16) -> dict:
    """Sliding-window layers allocate only ``window`` slots and decode
    with a ring buffer — a 500k-context mixtral cache is bounded by the
    4096-token window instead of the sequence length."""
    alloc = max_len if cfg.window is None else min(max_len, cfg.window)
    shape = (batch, cfg.n_kv_heads, alloc, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_step(params, x, cache, cache_len, cfg: AttnConfig):
    """One decode step.  x: [B, 1, d]; cache_len: int32[] tokens already
    in the cache (the new token sits at index cache_len).

    Returns (y [B, 1, d], new_cache).
    """
    b = x.shape[0]
    s_alloc = cache["k"].shape[2]
    ring = cfg.window is not None and s_alloc == cfg.window
    per_seq = jnp.ndim(cache_len) == 1  # continuous batching
    q, k, v = _split_qkv(params, x, cfg)
    if cfg.use_rope:
        if per_seq:
            from repro.models.layers.rope import apply_rope_per_batch
            q = apply_rope_per_batch(q, cache_len, cfg.rope_theta)
            k = apply_rope_per_batch(k, cache_len, cfg.rope_theta)
        else:
            pos = jnp.full((1,), cache_len, jnp.int32)
            q = apply_rope(q, pos, cfg.rope_theta)
            k = apply_rope(k, pos, cfg.rope_theta)
    slot = cache_len % s_alloc if ring else cache_len
    if per_seq:
        upd = jax.vmap(
            lambda c, kk, s: jax.lax.dynamic_update_slice_in_dim(
                c, kk, s, axis=1))
        k_cache = upd(cache["k"], k.astype(cache["k"].dtype), slot)
        v_cache = upd(cache["v"], v.astype(cache["v"].dtype), slot)
    else:
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), slot, axis=2)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), slot, axis=2)
    if ring:
        # ring holds exactly the window; mask only during warm-up
        valid = jnp.minimum(cache_len + 1, s_alloc)
        out = decode_attention(q, k_cache, v_cache, valid, window=None)
    else:
        out = decode_attention(q, k_cache, v_cache, cache_len + 1,
                               window=cfg.window)
    y = out.transpose(0, 2, 1, 3).reshape(b, 1, -1) @ params["wo"]
    if cfg.use_bias:
        y = y + params["bo"]
    return y, {"k": k_cache, "v": v_cache}
