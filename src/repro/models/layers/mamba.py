"""Mamba (selective SSM) mixer — jamba's recurrent layer.

Faithful selective-SSM dataflow (Gu & Dao 2023 / Jamba): in-projection to
(x, z), short causal depthwise conv, data-dependent (Δ, B, C) from x,
diagonal selective scan over time, gated out-projection.  State is O(1)
in sequence length, which is what qualifies jamba for ``long_500k``.

Train/prefill uses an associative scan over time (O(log T) depth);
decode carries (conv_state, ssm_state) explicitly.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_model: int
    d_inner: int          # expansion (2x d_model in jamba)
    d_state: int = 16
    d_conv: int = 4
    dt_rank: int = 0      # 0 -> ceil(d_model / 16)

    @property
    def rank(self) -> int:
        return self.dt_rank or max(1, self.d_model // 16)


def init(key, cfg: MambaConfig, dtype=jnp.bfloat16) -> dict:
    d, di, ds, r = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.rank
    ks = jax.random.split(key, 6)
    A = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": (jax.random.normal(ks[0], (d, 2 * di)) * d ** -0.5
                    ).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.d_conv, di)) * 0.1
                   ).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": (jax.random.normal(ks[2], (di, r + 2 * ds)) * di ** -0.5
                   ).astype(dtype),
        "dt_proj": (jax.random.normal(ks[3], (r, di)) * r ** -0.5
                    ).astype(dtype),
        "dt_bias": jnp.full((di,), -4.6, jnp.float32),  # softplus^-1(0.01)
        "A_log": jnp.log(A),                             # [di, ds] f32
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": (jax.random.normal(ks[4], (di, d)) * di ** -0.5
                     ).astype(dtype),
    }


def _ssm_params(params, xc, cfg: MambaConfig):
    """xc: [..., T, di] conv output -> (dt, B, C) data-dependent."""
    r, ds = cfg.rank, cfg.d_state
    proj = xc @ params["x_proj"]                       # [..., T, r+2ds]
    dt_r, Bm, Cm = jnp.split(proj, [r, r + ds], axis=-1)
    dt = jax.nn.softplus(
        (dt_r @ params["dt_proj"]).astype(jnp.float32)
        + params["dt_bias"])                           # [..., T, di]
    return dt, Bm.astype(jnp.float32), Cm.astype(jnp.float32)


def forward(params: dict, x: jnp.ndarray, cfg: MambaConfig,
            return_state: bool = False):
    """x: [B, T, d] -> [B, T, d] (train / prefill path).

    return_state=True additionally returns the decode cache (conv tail +
    final ssm state)."""
    b, t, _ = x.shape
    di, ds, dc = cfg.d_inner, cfg.d_state, cfg.d_conv
    xz = x @ params["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)                  # [B, T, di] each

    # causal depthwise conv (kernel dc)
    xpad = jnp.pad(xi, ((0, 0), (dc - 1, 0), (0, 0)))
    xc = sum(xpad[:, i:i + t, :] * params["conv_w"][i]
             for i in range(dc)) + params["conv_b"]
    xc = jax.nn.silu(xc)

    dt, Bm, Cm = _ssm_params(params, xc, cfg)
    A = -jnp.exp(params["A_log"])                      # [di, ds]
    # discretize: a_t = exp(dt * A), b_t = dt * B_t * x_t
    a = jnp.exp(dt[..., None] * A)                     # [B, T, di, ds]
    bx = (dt * xc.astype(jnp.float32))[..., None] * Bm[..., None, :]

    # associative scan over T: s_t = a_t * s_{t-1} + bx_t
    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    a_s = jnp.moveaxis(a, 1, 0)
    b_s = jnp.moveaxis(bx, 1, 0)
    _, s = jax.lax.associative_scan(combine, (a_s, b_s), axis=0)
    s = jnp.moveaxis(s, 0, 1)                          # [B, T, di, ds]

    y = jnp.einsum("btds,bts->btd", s, Cm)             # [B, T, di]
    y = y + params["D"] * xc.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = y @ params["out_proj"]
    if return_state:
        cache = {"conv": xi[:, t - (dc - 1):, :].astype(x.dtype),
                 "ssm": s[:, -1]}
        return out, cache
    return out


def init_cache(batch: int, cfg: MambaConfig, dtype=jnp.bfloat16) -> dict:
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), dtype),
        "ssm": jnp.zeros((batch, cfg.d_inner, cfg.d_state), jnp.float32),
    }


def decode_step(params: dict, x: jnp.ndarray, cache: dict,
                cfg: MambaConfig) -> tuple[jnp.ndarray, dict]:
    """x: [B, 1, d] -> (y [B, 1, d], cache')."""
    b = x.shape[0]
    di, ds, dc = cfg.d_inner, cfg.d_state, cfg.d_conv
    xz = x @ params["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)                  # [B, 1, di]

    hist = jnp.concatenate([cache["conv"], xi.astype(cache["conv"].dtype)],
                           axis=1)                     # [B, dc, di]
    xc = jnp.einsum("bcd,cd->bd", hist, params["conv_w"]) + params["conv_b"]
    xc = jax.nn.silu(xc)[:, None, :]                   # [B, 1, di]

    dt, Bm, Cm = _ssm_params(params, xc, cfg)
    A = -jnp.exp(params["A_log"])
    a = jnp.exp(dt[:, 0, :, None] * A)                 # [B, di, ds]
    bx = (dt[:, 0] * xc[:, 0].astype(jnp.float32))[..., None] \
        * Bm[:, 0, None, :]
    s = cache["ssm"] * a + bx                          # [B, di, ds]

    y = jnp.einsum("bds,bs->bd", s, Cm[:, 0])
    y = y + params["D"] * xc[:, 0].astype(jnp.float32)
    y = y[:, None, :].astype(x.dtype) * jax.nn.silu(z)
    return y @ params["out_proj"], {"conv": hist[:, 1:], "ssm": s}
