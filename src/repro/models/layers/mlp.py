"""Feed-forward blocks: SwiGLU (modern LMs) and GELU (whisper)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def swiglu_init(key, d: int, ff: int, dtype=jnp.bfloat16) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": (jax.random.normal(k1, (d, ff)) * d ** -0.5).astype(dtype),
        "wg": (jax.random.normal(k2, (d, ff)) * d ** -0.5).astype(dtype),
        "wo": (jax.random.normal(k3, (ff, d)) * ff ** -0.5).astype(dtype),
    }


def swiglu(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.silu(x @ params["wg"]) * (x @ params["wi"])
    return h @ params["wo"]


def gelu_mlp_init(key, d: int, ff: int, dtype=jnp.bfloat16) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "wi": (jax.random.normal(k1, (d, ff)) * d ** -0.5).astype(dtype),
        "bi": jnp.zeros((ff,), dtype),
        "wo": (jax.random.normal(k2, (ff, d)) * ff ** -0.5).astype(dtype),
        "bo": jnp.zeros((d,), dtype),
    }


def gelu_mlp(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.gelu(x @ params["wi"] + params["bi"])
    return h @ params["wo"] + params["bo"]
