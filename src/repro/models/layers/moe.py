"""Mixture-of-Experts (top-k routing, capacity-bounded scatter dispatch).

Dispatch strategy (see DESIGN.md §5): tokens are scatter-packed into an
[E, C, d] buffer (C = capacity per expert), experts run as one batched
einsum over E with the expert FFN dim sharded over the `model` mesh axis
(tensor parallelism inside every expert — no all-to-all in the baseline;
expert-parallel all-to-all is evaluated separately in §Perf).  Tokens
over capacity are dropped (standard capacity-factor semantics); the
router uses softmax-then-top-k with gate renormalization as in Mixtral.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int
    n_experts: int
    top_k: int = 2
    capacity_factor: float = 1.25


def init(key, cfg: MoEConfig, dtype=jnp.bfloat16) -> dict:
    kr, k1, k2, k3 = jax.random.split(key, 4)
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": (jax.random.normal(kr, (d, e)) * d ** -0.5
                   ).astype(jnp.float32),
        "wi": (jax.random.normal(k1, (e, d, ff)) * d ** -0.5).astype(dtype),
        "wg": (jax.random.normal(k2, (e, d, ff)) * d ** -0.5).astype(dtype),
        "wo": (jax.random.normal(k3, (e, ff, d)) * ff ** -0.5).astype(dtype),
    }


def capacity(n_tokens: int, cfg: MoEConfig) -> int:
    c = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, (c + 7) // 8 * 8)  # sublane-aligned


def forward(params: dict, x: jnp.ndarray, cfg: MoEConfig
            ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, T, d] -> (y [B, T, d], aux_loss scalar).

    aux_loss is the standard load-balancing loss (mean_prob * mean_assign
    * E), used by the training step.
    """
    b, t, d = x.shape
    n = b * t
    e, k = cfg.n_experts, cfg.top_k
    cap = capacity(n, cfg)
    xf = x.reshape(n, d)

    logits = (xf.astype(jnp.float32) @ params["router"])          # [N, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                           # [N, k]
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)           # renorm

    # position of each (token, slot) within its expert's capacity buffer
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)              # [N, k, E]
    flat = onehot.reshape(n * k, e)
    pos_flat = jnp.cumsum(flat, axis=0) - 1                       # [N*k, E]
    pos = jnp.sum(pos_flat.reshape(n, k, e) * onehot, axis=-1)    # [N, k]
    keep = pos < cap                                              # [N, k]

    # scatter tokens into [E, C, d]
    e_idx = jnp.where(keep, idx, e)        # overflow -> dropped row
    c_idx = jnp.where(keep, pos, cap)
    buf = jnp.zeros((e + 1, cap + 1, d), x.dtype)
    xk = jnp.broadcast_to(xf[:, None, :], (n, k, d))
    buf = buf.at[e_idx.reshape(-1), c_idx.reshape(-1)].add(
        xk.reshape(n * k, d))
    buf = buf[:e, :cap]                                           # [E, C, d]

    # batched expert FFN (SwiGLU), E leading dim
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["wg"]))
    h = h * jnp.einsum("ecd,edf->ecf", buf, params["wi"])
    y_e = jnp.einsum("ecf,efd->ecd", h, params["wo"])             # [E, C, d]

    # gather back + weighted combine
    y_tok = y_e[jnp.minimum(e_idx, e - 1), jnp.minimum(c_idx, cap - 1)]
    y_tok = jnp.where(keep[..., None], y_tok, 0.0)                # [N, k, d]
    y = jnp.sum(y_tok * gate[..., None].astype(y_tok.dtype), axis=1)

    # load-balancing auxiliary loss
    me = jnp.mean(probs, axis=0)                                   # [E]
    ce = jnp.mean(jnp.sum(onehot, axis=1).astype(jnp.float32), axis=0)
    aux = jnp.sum(me * ce) * e

    return y.reshape(b, t, d), aux
