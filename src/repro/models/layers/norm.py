"""Normalization layers (RMSNorm for modern LMs, LayerNorm for whisper)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm_init(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * params["scale"]).astype(dt)


def layernorm_init(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(params: dict, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * (var + eps) ** -0.5
    return (out * params["scale"] + params["bias"]).astype(dt)
