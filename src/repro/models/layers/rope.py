"""Rotary position embeddings (RoPE)."""

from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    """Inverse frequencies, float32[head_dim // 2]."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10000.0) -> jnp.ndarray:
    """x: [..., T, D] (D even); positions: int32[T] absolute positions."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                       # [D/2]
    ang = positions.astype(jnp.float32)[:, None] * inv[None, :]  # [T, D/2]
    cos = jnp.cos(ang)
    sin = jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)


def apply_rope_per_batch(x: jnp.ndarray, positions: jnp.ndarray,
                         theta: float = 10000.0) -> jnp.ndarray:
    """Decode variant: x [B, H, 1, D], positions int32[B] (per-sequence
    cache lengths — continuous batching)."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                       # [D/2]
    ang = (positions.astype(jnp.float32)[:, None, None, None]
           * inv[None, None, None, :])               # [B,1,1,D/2]
    cos = jnp.cos(ang)
    sin = jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)
