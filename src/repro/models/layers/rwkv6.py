"""RWKV-6 "Finch" mixer: attention-free, data-dependent per-channel decay.

Time-mixing recurrence (per head, head size N):
    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
with data-dependent decay w_t = exp(-exp(wf_t)) produced by a low-rank
MLP from the token-shifted input (the "Finch" change vs RWKV-5's static
decay), and bonus u for the current token.

State is O(1) in T (heads x N x N per layer), which qualifies rwkv6-7b
for ``long_500k``.  Train/prefill uses lax.scan over time; decode carries
(shift, state).  Token-shift interpolation and the r/k/v/g projections
follow the published architecture; fine low-rank sizes are reduced-rank
faithful approximations (documented in DESIGN.md §7).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class RWKV6Config:
    d_model: int
    head_size: int = 64
    decay_rank: int = 64      # low-rank bottleneck for the decay MLP

    @property
    def n_heads(self) -> int:
        return self.d_model // self.head_size


def init(key, cfg: RWKV6Config, dtype=jnp.bfloat16) -> dict:
    d, hs = cfg.d_model, cfg.head_size
    ks = jax.random.split(key, 8)
    std = d ** -0.5
    return {
        # token-shift interpolation weights (per-channel, for r/k/v/g/w)
        "mu": (0.5 * jnp.ones((5, d))).astype(jnp.float32),
        "wr": (jax.random.normal(ks[0], (d, d)) * std).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, d)) * std).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, d)) * std).astype(dtype),
        "wg": (jax.random.normal(ks[3], (d, d)) * std).astype(dtype),
        # data-dependent decay: low-rank MLP d -> rank -> d
        "wd1": (jax.random.normal(ks[4], (d, cfg.decay_rank)) * std
                ).astype(dtype),
        "wd2": (jax.random.normal(ks[5], (cfg.decay_rank, d))
                * cfg.decay_rank ** -0.5).astype(dtype),
        "decay_base": jnp.full((d,), -6.0, jnp.float32),
        "bonus": (jax.random.normal(ks[6], (cfg.n_heads, hs)) * 0.1
                  ).astype(jnp.float32),
        "wo": (jax.random.normal(ks[7], (d, d)) * std).astype(dtype),
        "ln_scale": jnp.ones((d,), jnp.float32),  # per-head group norm
    }


def _mix(x, x_prev, mu):
    """Token shift: lerp(current, previous, mu)."""
    return x + (x_prev - x) * mu.astype(x.dtype)


def _projections(params, x, x_prev, cfg: RWKV6Config):
    """x, x_prev: [..., d] -> r, k, v, g [..., H, N], w decay [..., H, N]."""
    h, n = cfg.n_heads, cfg.head_size
    mu = params["mu"]
    r = _mix(x, x_prev, mu[0]) @ params["wr"]
    k = _mix(x, x_prev, mu[1]) @ params["wk"]
    v = _mix(x, x_prev, mu[2]) @ params["wv"]
    g = _mix(x, x_prev, mu[3]) @ params["wg"]
    xw = _mix(x, x_prev, mu[4])
    wf = jnp.tanh(xw @ params["wd1"]) @ params["wd2"]
    w = jnp.exp(-jnp.exp(wf.astype(jnp.float32) + params["decay_base"]))
    shp = x.shape[:-1]
    return (r.reshape(*shp, h, n), k.reshape(*shp, h, n),
            v.reshape(*shp, h, n), g.reshape(*shp, h, n),
            w.reshape(*shp, h, n))


def _group_norm(params, o, cfg: RWKV6Config):
    """Per-head RMS normalization of the output."""
    var = jnp.mean(o * o, axis=-1, keepdims=True)
    o = o * jax.lax.rsqrt(var + 1e-6)
    return o.reshape(*o.shape[:-2], cfg.d_model) * params["ln_scale"]


def forward(params: dict, x: jnp.ndarray, cfg: RWKV6Config,
            return_state: bool = False):
    """x: [B, T, d] -> [B, T, d] (train / prefill).

    return_state=True additionally returns the decode cache."""
    b, t, d = x.shape
    h, n = cfg.n_heads, cfg.head_size
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :t]
    r, k, v, g, w = _projections(params, x, x_prev, cfg)
    rf = r.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    u = params["bonus"]                                 # [H, N]

    def body(state, inp):
        rt, kt, vt, wt = inp                            # [B, H, N]
        kv = kt[..., :, None] * vt[..., None, :]        # [B, H, N, N]
        out = jnp.einsum("bhn,bhnm->bhm", rt, state + u[..., None] * kv)
        state = wt[..., None] * state + kv
        return state, out

    s0 = jnp.zeros((b, h, n, n), jnp.float32)
    seq = (jnp.moveaxis(rf, 1, 0), jnp.moveaxis(kf, 1, 0),
           jnp.moveaxis(vf, 1, 0), jnp.moveaxis(w, 1, 0))
    s_fin, o = jax.lax.scan(body, s0, seq)              # [T, B, H, N]
    o = jnp.moveaxis(o, 0, 1)                           # [B, T, H, N]
    o = _group_norm(params, o, cfg).astype(x.dtype)
    out = (o * jax.nn.silu(g.reshape(b, t, d))) @ params["wo"]
    if return_state:
        return out, {"shift": x[:, -1], "state": s_fin}
    return out


def forward_chunked(params: dict, x: jnp.ndarray, cfg: RWKV6Config,
                    chunk: int = 32, return_state: bool = False):
    """Chunked (blocked) RWKV6 recurrence — §Perf hillclimb A.

    The per-timestep scan round-trips the O(H x N x N) state through
    HBM every step (T x per layer); this formulation carries the state
    only ACROSS chunks and handles within-chunk interactions with a
    masked decay-weighted attention matrix (the flash-linear-attention
    chunk form).  State traffic drops by the chunk length (~32x) and
    the inner work becomes batched einsums.

    Numerical safety: all decay exponentials are differences
    L_a - L_b with a >= b along time, hence <= 0 -> exp() in (0, 1].

    Identity with ``forward`` is asserted in tests/test_rwkv_chunked.py.
    """
    b, t, d = x.shape
    h, n = cfg.n_heads, cfg.head_size
    assert t % chunk == 0, (t, chunk)
    nc = t // chunk
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :t]
    r, k, v, g, w = _projections(params, x, x_prev, cfg)
    u = params["bonus"]                                  # [H, N]

    def resh(a):  # [B, T, H, N] -> [nc, B, C, H, N]
        return jnp.moveaxis(
            a.reshape(b, nc, chunk, h, n), 1, 0)

    rf, kf, vf = (resh(a.astype(jnp.float32)) for a in (r, k, v))
    logw = jnp.log(jnp.maximum(resh(w), 1e-38))          # w in (0,1)

    tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)  # s < t

    def chunk_body(S, inp):
        rc, kc, vc, lw = inp                  # [B, C, H, N] each
        L = jnp.cumsum(lw, axis=1)            # L_t = sum_{s<=t} log w_s
        Lprev = L - lw                        # L_{t-1}
        # cross-chunk: o_t += (r_t * exp(L_{t-1})) @ S
        r_dec = rc * jnp.exp(Lprev)
        o_cross = jnp.einsum("bthn,bhnm->bthm", r_dec, S)
        # intra-chunk (s < t): D[t,s,n] = exp(L_{t-1,n} - L_{s,n}) <= 1
        diff = Lprev[:, :, None] - L[:, None]           # [B,C,C,H,N]
        D = jnp.exp(jnp.minimum(diff, 0.0))
        att = jnp.einsum("bthn,bshn,btshn->btsh", rc, kc, D)
        att = att * tri[None, :, :, None]
        o_intra = jnp.einsum("btsh,bshn->bthn", att, vc)
        # bonus (current token): (r_t . u k_t) v_t
        o_bonus = jnp.sum(rc * u * kc, axis=-1,
                          keepdims=True) * vc
        # state to end of chunk: S' = diag(exp L_C) S + sum_t k'_t (x) v_t
        k_dec = kc * jnp.exp(L[:, -1:] - L)   # <= k (exponent <= 0)
        S = (jnp.exp(L[:, -1])[..., None] * S
             + jnp.einsum("bthn,bthm->bhnm", k_dec, vc))
        return S, o_cross + o_intra + o_bonus

    s0 = jnp.zeros((b, h, n, n), jnp.float32)
    s_fin, o = jax.lax.scan(chunk_body, s0, (rf, kf, vf, logw))
    o = jnp.moveaxis(o, 0, 1).reshape(b, t, h, n)        # [B, T, H, N]
    o = _group_norm(params, o, cfg).astype(x.dtype)
    out = (o * jax.nn.silu(g.reshape(b, t, d))) @ params["wo"]
    if return_state:
        return out, {"shift": x[:, -1], "state": s_fin}
    return out


def init_cache(batch: int, cfg: RWKV6Config, dtype=jnp.bfloat16) -> dict:
    return {
        "shift": jnp.zeros((batch, cfg.d_model), dtype),
        "state": jnp.zeros((batch, cfg.n_heads, cfg.head_size,
                            cfg.head_size), jnp.float32),
    }


def decode_step(params: dict, x: jnp.ndarray, cache: dict,
                cfg: RWKV6Config) -> tuple[jnp.ndarray, dict]:
    """x: [B, 1, d] -> (y [B, 1, d], cache')."""
    b, _, d = x.shape
    xt = x[:, 0]
    r, k, v, g, w = _projections(params, xt,
                                 cache["shift"].astype(xt.dtype), cfg)
    u = params["bonus"]
    kv = k.astype(jnp.float32)[..., :, None] \
        * v.astype(jnp.float32)[..., None, :]
    out = jnp.einsum("bhn,bhnm->bhm", r.astype(jnp.float32),
                     cache["state"] + u[..., None] * kv)
    state = w[..., None] * cache["state"] + kv
    o = _group_norm(params, out, cfg).astype(x.dtype)
    y = (o * jax.nn.silu(g.reshape(b, d))) @ params["wo"]
    return y[:, None, :], {"shift": xt.astype(cache["shift"].dtype),
                           "state": state}
