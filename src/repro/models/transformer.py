"""Config-driven transformer covering all assigned architectures.

One ``Model`` class handles dense / GQA / SWA / MoE / Mamba-hybrid /
RWKV / encoder-decoder / stub-frontend (audio, vision) variants, driven
entirely by :class:`repro.configs.ArchConfig`.

Key structural choices (rationale in DESIGN.md §5):

* **scan-over-layers**: the layer pattern is factored into its smallest
  repeating super-block (``configs.scan_grouping``); params are stacked
  per sub-layer position and the stack is ``lax.scan``'d.  126-layer
  llama3 lowers one super-block, not 126 copies — compile time and HLO
  size stay bounded.
* **chunked attention** (no [T, T] scores) and **chunked cross-entropy**
  (no [B, T, V] logits) keep 32k-token prefill and 262k-vocab losses
  inside v5e HBM.
* **logical-axis sharding constraints** (repro.distributed.sharding) at
  layer boundaries; the same code runs unsharded in tests.
* decode paths carry explicit caches (KV / conv+ssm / rwkv state).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayerKind, layer_kinds, scan_grouping
from repro.distributed.sharding import constrain
from repro.models.layers import attention as attn
from repro.models.layers import mamba as mamba_l
from repro.models.layers import mlp as mlp_l
from repro.models.layers import moe as moe_l
from repro.models.layers import norm as norm_l
from repro.models.layers import rwkv6 as rwkv_l


def cache_out(dec_cache, enc_out=None) -> dict:
    cache: dict = {"decoder": dec_cache}
    if enc_out is not None:
        cache["enc_out"] = enc_out
    return cache


def _norm_init(cfg: ArchConfig, d: int) -> dict:
    return (norm_l.layernorm_init(d) if cfg.norm == "ln"
            else norm_l.rmsnorm_init(d))


def _norm_apply(cfg: ArchConfig, p: dict, x):
    return (norm_l.layernorm(p, x) if cfg.norm == "ln"
            else norm_l.rmsnorm(p, x))


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    dtype: Any = jnp.bfloat16
    remat: bool = True
    loss_chunk: int = 512
    attn_chunk: int = 1024
    rwkv_chunk: int = 0   # >0: blocked RWKV6 recurrence (§Perf A)

    # --- config plumbing ---------------------------------------------------

    def attn_cfg(self, kind: LayerKind, causal=True) -> attn.AttnConfig:
        c = self.cfg
        return attn.AttnConfig(
            d_model=c.d_model, n_heads=c.n_heads, n_kv_heads=c.n_kv_heads,
            head_dim=c.hd, rope_theta=c.rope_theta,
            window=(c.window if kind.mixer == "attn_window" else None),
            causal=causal, use_bias=c.use_bias, chunk_k=self.attn_chunk,
            use_rope=c.use_rope)

    def mamba_cfg(self) -> mamba_l.MambaConfig:
        c = self.cfg
        return mamba_l.MambaConfig(d_model=c.d_model,
                                   d_inner=2 * c.d_model,
                                   d_state=c.d_state)

    def rwkv_cfg(self) -> rwkv_l.RWKV6Config:
        c = self.cfg
        return rwkv_l.RWKV6Config(d_model=c.d_model,
                                  head_size=c.rwkv_head_size)

    def moe_cfg(self) -> moe_l.MoEConfig:
        c = self.cfg
        return moe_l.MoEConfig(d_model=c.d_model, d_ff=c.d_ff,
                               n_experts=c.n_experts, top_k=c.top_k,
                               capacity_factor=c.capacity_factor)

    @property
    def pos_emb(self) -> str:
        c = self.cfg
        if c.use_rope:
            return "rope"
        return "learned" if c.is_enc_dec else "none"

    # --- init ----------------------------------------------------------------

    def _init_sublayer(self, key, kind: LayerKind, causal=True) -> dict:
        c = self.cfg
        ks = jax.random.split(key, 6)
        p: dict = {"ln1": _norm_init(c, c.d_model)}
        if kind.mixer.startswith("attn"):
            p["mixer"] = attn.init(ks[0], self.attn_cfg(kind, causal),
                                   self.dtype)
        elif kind.mixer == "mamba":
            p["mixer"] = mamba_l.init(ks[0], self.mamba_cfg(), self.dtype)
        elif kind.mixer == "rwkv":
            p["mixer"] = rwkv_l.init(ks[0], self.rwkv_cfg(), self.dtype)
        if kind.cross_attn:
            p["ln_cross"] = _norm_init(c, c.d_model)
            p["cross"] = attn.init(ks[1], self.attn_cfg(kind, causal=False),
                                   self.dtype)
        p["ln2"] = _norm_init(c, c.d_model)
        if kind.ffn == "moe":
            p["ffn"] = moe_l.init(ks[2], self.moe_cfg(), self.dtype)
        else:
            p["ffn"] = (mlp_l.gelu_mlp_init(ks[2], c.d_model, c.d_ff,
                                            self.dtype)
                        if c.act == "gelu" else
                        mlp_l.swiglu_init(ks[2], c.d_model, c.d_ff,
                                          self.dtype))
        return p

    def _init_stack(self, key, kinds: list[LayerKind], causal=True) -> dict:
        period, reps, rem = scan_grouping(kinds)
        keys = jax.random.split(key, period * reps + rem)

        scan_params = []
        for s in range(period):
            # stack the params of sub-position s across all repeats
            per_rep = [self._init_sublayer(keys[r * period + s], kinds[s],
                                           causal)
                       for r in range(reps)]
            scan_params.append(
                jax.tree.map(lambda *a: jnp.stack(a), *per_rep)
                if reps > 1 else
                jax.tree.map(lambda a: a[None], per_rep[0]))
        rem_params = [
            self._init_sublayer(keys[period * reps + i],
                                kinds[reps * period + i], causal)
            for i in range(rem)]
        return {"scan": scan_params, "rem": rem_params}

    def init_params(self, key) -> dict:
        c = self.cfg
        ks = jax.random.split(key, 8)
        vp = c.vocab_padded
        params: dict = {
            "embed": (jax.random.normal(ks[0], (vp, c.d_model))
                      * c.d_model ** -0.5).astype(self.dtype),
            "final_norm": _norm_init(c, c.d_model),
            "decoder": self._init_stack(ks[1], layer_kinds(c)),
        }
        if not c.tie_embeddings:
            params["lm_head"] = (
                jax.random.normal(ks[2], (c.d_model, vp))
                * c.d_model ** -0.5).astype(self.dtype)
        if self.pos_emb == "learned":
            params["pos_embed"] = (
                jax.random.normal(ks[3], (c.max_seq_len, c.d_model))
                * 0.02).astype(self.dtype)
        if c.is_enc_dec:
            enc_kinds = layer_kinds(c, c.encoder_layers, decoder=False)
            params["encoder"] = self._init_stack(ks[4], enc_kinds,
                                                 causal=False)
            params["enc_final_norm"] = _norm_init(c, c.d_model)
            params["enc_pos"] = (
                jax.random.normal(ks[5], (c.frontend_len, c.d_model))
                * 0.02).astype(self.dtype)
        return params

    # --- forward sub-layer -----------------------------------------------------

    def _apply_sublayer(self, p: dict, x, kind: LayerKind, *, causal=True,
                        positions=None, enc_out=None, cache_max_len=None):
        """One pre-norm sub-layer.  cache_max_len != None -> prefill mode
        (returns the decode cache alongside)."""
        c = self.cfg
        collect = cache_max_len is not None
        cache: dict = {}
        h = _norm_apply(c, p["ln1"], x)
        h = constrain(h, "batch", "mix_seq", "embed")
        if kind.mixer.startswith("attn"):
            out = attn.forward(p["mixer"], h, self.attn_cfg(kind, causal),
                               positions=positions, return_kv=collect)
            if collect:
                h, (k, v) = out
                acfg = self.attn_cfg(kind, causal)
                alloc = (cache_max_len if acfg.window is None
                         else min(cache_max_len, acfg.window))
                t = k.shape[2]
                if t <= alloc:
                    pad = alloc - t
                    cache["kv"] = {
                        "k": jnp.pad(k, ((0, 0), (0, 0), (0, pad),
                                         (0, 0))),
                        "v": jnp.pad(v, ((0, 0), (0, 0), (0, pad),
                                         (0, 0)))}
                else:
                    # ring buffer: last `alloc` tokens at slot pos%alloc
                    dest = (jnp.arange(alloc) + (t - alloc)) % alloc
                    cache["kv"] = {
                        "k": jnp.zeros_like(k[:, :, :alloc]
                                            ).at[:, :, dest].set(
                                                k[:, :, -alloc:]),
                        "v": jnp.zeros_like(v[:, :, :alloc]
                                            ).at[:, :, dest].set(
                                                v[:, :, -alloc:])}
            else:
                h = out
        elif kind.mixer == "mamba":
            out = mamba_l.forward(p["mixer"], h, self.mamba_cfg(),
                                  return_state=collect)
            if collect:
                h, cache["mamba"] = out
            else:
                h = out
        elif kind.mixer == "rwkv":
            ck = self.rwkv_chunk
            if ck and h.shape[1] % ck == 0 and h.shape[1] > ck:
                out = rwkv_l.forward_chunked(p["mixer"], h,
                                             self.rwkv_cfg(), chunk=ck,
                                             return_state=collect)
            else:
                out = rwkv_l.forward(p["mixer"], h, self.rwkv_cfg(),
                                     return_state=collect)
            if collect:
                h, cache["rwkv"] = out
            else:
                h = out
        x = x + h
        x = constrain(x, "batch", "res_seq", "embed")
        if kind.cross_attn and enc_out is not None:
            h = _norm_apply(c, p["ln_cross"], x)
            out = attn.forward(p["cross"], h, self.attn_cfg(kind, False),
                               kv_x=enc_out, return_kv=collect)
            if collect:
                h, (ck, cv) = out
                cache["cross"] = {"k": ck, "v": cv}
            else:
                h = out
            x = x + h
        h = _norm_apply(c, p["ln2"], x)
        h = constrain(h, "batch", "mix_seq", "embed")
        aux = jnp.float32(0)
        if kind.ffn == "moe":
            h, aux = moe_l.forward(p["ffn"], h, self.moe_cfg())
        elif c.act == "gelu":
            h = mlp_l.gelu_mlp(p["ffn"], h)
        else:
            h = mlp_l.swiglu(p["ffn"], h)
        x = x + h
        x = constrain(x, "batch", "res_seq", "embed")
        if collect:
            return x, aux, cache
        return x, aux

    def _apply_stack(self, stack: dict, x, kinds: list[LayerKind], *,
                     causal=True, positions=None, enc_out=None,
                     cache_max_len=None):
        period, reps, rem = scan_grouping(kinds)
        collect = cache_max_len is not None

        def superblock(x, slice_params):
            aux = jnp.float32(0)
            caches = []
            for s in range(period):
                out = self._apply_sublayer(
                    slice_params[s], x, kinds[s], causal=causal,
                    positions=positions, enc_out=enc_out,
                    cache_max_len=cache_max_len)
                if collect:
                    x, a, cc = out
                    caches.append(cc)
                else:
                    x, a = out
                aux = aux + a
            return x, aux, caches

        body = superblock
        if self.remat and not collect:
            def body(x, sp):  # noqa: F811
                f = jax.checkpoint(
                    lambda xx, pp: superblock(xx, pp)[:2],
                    policy=jax.checkpoint_policies.nothing_saveable)
                y, a = f(x, sp)
                return y, a, []

        def scan_fn(carry, slice_params):
            x, aux = carry
            x, a, caches = body(x, slice_params)
            return (x, aux + a), (caches if collect else None)

        (x, aux), scan_caches = jax.lax.scan(
            scan_fn, (x, jnp.float32(0)), stack["scan"])
        rem_caches = []
        for i in range(rem):
            out = self._apply_sublayer(
                stack["rem"][i], x, kinds[period * reps + i],
                causal=causal, positions=positions, enc_out=enc_out,
                cache_max_len=cache_max_len)
            if collect:
                x, a, cc = out
                rem_caches.append(cc)
            else:
                x, a = out
            aux = aux + a
        if collect:
            return x, aux, {"scan": scan_caches, "rem": rem_caches}
        return x, aux

    # --- embedding / heads -----------------------------------------------------

    def _embed(self, params, tokens, offset: int = 0):
        x = jnp.take(params["embed"], tokens, axis=0).astype(self.dtype)
        if self.pos_emb == "learned":
            t = tokens.shape[1]
            pos = jax.lax.dynamic_slice_in_dim(params["pos_embed"], offset,
                                               t, axis=0)
            x = x + pos
        return constrain(x, "batch", "res_seq", "embed")

    def _encode(self, params, frames):
        """Encoder pass over stub frontend embeddings [B, F, d]."""
        c = self.cfg
        x = frames.astype(self.dtype) + params["enc_pos"][None]
        kinds = layer_kinds(c, c.encoder_layers, decoder=False)
        x, _ = self._apply_stack(params["encoder"], x, kinds, causal=False)
        return _norm_apply(c, params["enc_final_norm"], x)

    def _head_matrix(self, params):
        if self.cfg.tie_embeddings:
            # the embedding table is sharded (vocab->data, d->model) for
            # the lookup; its head use wants the transpose-compatible
            # (d->data, vocab->model).  Reshard ONCE here (hoisted out
            # of the loss-chunk scan) — without this the partitioner
            # replicates full-vocab logits per chunk (~8.6 GB each).
            return constrain(params["embed"].T, "p_in", "vocab")
        return params["lm_head"]

    def _logits(self, params, h):
        """h: [B, T, d] -> logits [B, T, Vp] (small T only: decode)."""
        w = self._head_matrix(params)
        logits = (h @ w).astype(jnp.float32)
        vp, v = self.cfg.vocab_padded, self.cfg.vocab_size
        if vp != v:
            neg = jnp.full((vp - v,), -1e30, jnp.float32)
            logits = logits.at[..., v:].set(neg)
        # vocab gets the model axis here even under sequence-parallel
        # rules (the chunk seq dim is short; sharding it wastes the mesh)
        return constrain(logits, "batch", None, "vocab")

    def _chunked_loss(self, params, h, labels, mask=None):
        """Cross-entropy without materializing [B, T, V] logits."""
        # under sequence-parallel rules, gather seq here: the loss wants
        # (batch->data, vocab->model); leaving seq on the model axis
        # forces an involuntary full rematerialization in the backward
        h = constrain(h, "batch", None, "embed")
        b, t, d = h.shape
        chunk = min(self.loss_chunk, t)
        assert t % chunk == 0, (t, chunk)
        n = t // chunk
        w = self._head_matrix(params)
        v = self.cfg.vocab_size

        def one(h_c, y_c, m_c):
            logits = (h_c @ w).astype(jnp.float32)
            logits = constrain(logits, "batch", None, "vocab")
            if self.cfg.vocab_padded != v:
                logits = logits.at[..., v:].set(-1e30)
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, y_c[..., None],
                                       axis=-1)[..., 0]
            return jnp.sum((lse - gold) * m_c), jnp.sum(m_c)

        one = jax.checkpoint(one)

        def body(carry, xs):
            h_c, y_c, m_c = xs
            s, cnt = one(h_c, y_c, m_c)
            return (carry[0] + s, carry[1] + cnt), None

        hs = jnp.moveaxis(h.reshape(b, n, chunk, d), 1, 0)
        ys = jnp.moveaxis(labels.reshape(b, n, chunk), 1, 0)
        if mask is None:
            mask = jnp.ones_like(labels, jnp.float32)
        ms = jnp.moveaxis(mask.reshape(b, n, chunk), 1, 0)
        (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)),
                                     (hs, ys, ms))
        return tot / jnp.maximum(cnt, 1.0)

    # --- public entry points -----------------------------------------------------

    def forward_hidden(self, params, batch: dict):
        """Run the decoder stack -> hidden states [B, T, d] (+ aux loss)."""
        c = self.cfg
        enc_out = None
        prefix = None
        if c.is_enc_dec:
            enc_out = self._encode(params, batch["frames"])
        if c.frontend == "vision":
            prefix = batch["patches"].astype(self.dtype)
        x = self._embed(params, batch["tokens"])
        if prefix is not None:
            x = jnp.concatenate([prefix, x], axis=1)
            x = constrain(x, "batch", "res_seq", "embed")
        positions = jnp.arange(x.shape[1])
        x, aux = self._apply_stack(params["decoder"], x,
                                   layer_kinds(c), causal=True,
                                   positions=positions, enc_out=enc_out)
        x = _norm_apply(c, params["final_norm"], x)
        if prefix is not None:
            x = x[:, prefix.shape[1]:]
        return x, aux

    def loss(self, params, batch: dict):
        """Mean next-token cross-entropy (+ MoE aux)."""
        h, aux = self.forward_hidden(params, batch)
        ce = self._chunked_loss(params, h, batch["labels"],
                                batch.get("loss_mask"))
        return ce + 0.01 * aux

    # --- decode ------------------------------------------------------------------

    def _init_layer_cache(self, kind: LayerKind, batch: int, max_len: int):
        c = self.cfg
        cache: dict = {}
        if kind.mixer.startswith("attn"):
            cache["kv"] = attn.init_cache(batch, self.attn_cfg(kind),
                                          max_len, self.dtype)
        elif kind.mixer == "mamba":
            cache["mamba"] = mamba_l.init_cache(batch, self.mamba_cfg(),
                                                self.dtype)
        elif kind.mixer == "rwkv":
            cache["rwkv"] = rwkv_l.init_cache(batch, self.rwkv_cfg(),
                                              self.dtype)
        if kind.cross_attn:
            cache["cross"] = attn.init_cache(batch, self.attn_cfg(kind),
                                             c.frontend_len, self.dtype)
        return cache

    def init_cache(self, batch: int, max_len: int) -> dict:
        kinds = layer_kinds(self.cfg)
        period, reps, rem = scan_grouping(kinds)
        scan_caches = []
        for s in range(period):
            one = self._init_layer_cache(kinds[s], batch, max_len)
            scan_caches.append(jax.tree.map(
                lambda a: jnp.broadcast_to(a[None],
                                           (reps,) + a.shape).copy()
                if reps > 1 else a[None], one))
        rem_caches = [self._init_layer_cache(kinds[period * reps + i],
                                             batch, max_len)
                      for i in range(rem)]
        cache = {"decoder": {"scan": scan_caches, "rem": rem_caches}}
        if self.cfg.is_enc_dec:
            cache["enc_out"] = jnp.zeros(
                (batch, self.cfg.frontend_len, self.cfg.d_model),
                self.dtype)
        return cache

    def _decode_sublayer(self, p, x, kind: LayerKind, cache, cache_len,
                         enc_out):
        c = self.cfg
        h = _norm_apply(c, p["ln1"], x)
        new_cache = dict(cache)
        if kind.mixer.startswith("attn"):
            h, kv = attn.decode_step(p["mixer"], h, cache["kv"], cache_len,
                                     self.attn_cfg(kind))
            new_cache["kv"] = kv
        elif kind.mixer == "mamba":
            h, mc = mamba_l.decode_step(p["mixer"], h, cache["mamba"],
                                        self.mamba_cfg())
            new_cache["mamba"] = mc
        elif kind.mixer == "rwkv":
            h, rc = rwkv_l.decode_step(p["mixer"], h, cache["rwkv"],
                                       self.rwkv_cfg())
            new_cache["rwkv"] = rc
        x = x + h
        if kind.cross_attn:
            h = _norm_apply(c, p["ln_cross"], x)
            acfg = self.attn_cfg(kind, causal=False)
            q, _, _ = attn._split_qkv(p["cross"], h, acfg)
            out = attn.decode_attention(q, cache["cross"]["k"],
                                        cache["cross"]["v"],
                                        jnp.int32(c.frontend_len))
            b = x.shape[0]
            h = out.transpose(0, 2, 1, 3).reshape(b, 1, -1) @ \
                p["cross"]["wo"]
            if acfg.use_bias:
                h = h + p["cross"]["bo"]
            x = x + h
        h = _norm_apply(c, p["ln2"], x)
        if kind.ffn == "moe":
            h, _ = moe_l.forward(p["ffn"], h, self.moe_cfg())
        elif c.act == "gelu":
            h = mlp_l.gelu_mlp(p["ffn"], h)
        else:
            h = mlp_l.swiglu(p["ffn"], h)
        x = x + h
        return x, new_cache

    def decode_step(self, params, tokens, cache, cache_len):
        """One serving step.  tokens: int32[B, 1]; cache_len: int32[].

        Returns (logits f32[B, Vp], new_cache).
        """
        c = self.cfg
        kinds = layer_kinds(c)
        period, reps, rem = scan_grouping(kinds)
        x = jnp.take(params["embed"], tokens, axis=0).astype(self.dtype)
        if self.pos_emb == "learned":
            if jnp.ndim(cache_len) == 1:  # per-sequence lengths
                pos = jnp.take(params["pos_embed"], cache_len,
                               axis=0)[:, None, :]
            else:
                pos = jax.lax.dynamic_slice_in_dim(
                    params["pos_embed"], cache_len, 1, axis=0)
            x = x + pos
        enc_out = cache.get("enc_out")

        def scan_fn(carry, xs):
            x = carry
            slice_params, slice_cache = xs
            aux_caches = []
            for s in range(period):
                x, nc = self._decode_sublayer(
                    slice_params[s], x, kinds[s], slice_cache[s],
                    cache_len, enc_out)
                aux_caches.append(nc)
            return x, aux_caches

        x, new_scan_cache = jax.lax.scan(
            scan_fn, x, (params["decoder"]["scan"],
                         cache["decoder"]["scan"]))
        rem_caches = []
        for i in range(rem):
            x, nc = self._decode_sublayer(
                params["decoder"]["rem"][i], x, kinds[period * reps + i],
                cache["decoder"]["rem"][i], cache_len, enc_out)
            rem_caches.append(nc)
        x = _norm_apply(c, params["final_norm"], x)
        logits = self._logits(params, x)[:, 0]
        new_cache = dict(cache)
        new_cache["decoder"] = {"scan": new_scan_cache, "rem": rem_caches}
        return logits, new_cache

    def prefill(self, params, batch: dict, max_len: int, lengths=None):
        """Process a prompt, build the decode cache.

        batch: {"tokens": [B, T], + frontend inputs}.  ``lengths``
        (int32[B], optional) = true prompt lengths when T is a padded
        bucket; last-token logits are gathered per sequence.  Returns
        (logits f32[B, Vp] for the last valid position, cache,
        cache_len).
        """
        c = self.cfg
        enc_out = None
        prefix = None
        if c.is_enc_dec:
            enc_out = self._encode(params, batch["frames"])
        if c.frontend == "vision":
            prefix = batch["patches"].astype(self.dtype)
        x = self._embed(params, batch["tokens"])
        if prefix is not None:
            x = jnp.concatenate([prefix, x], axis=1)
            x = constrain(x, "batch", "res_seq", "embed")
        t_total = x.shape[1]
        positions = jnp.arange(t_total)
        x, _, dec_cache = self._apply_stack(
            params["decoder"], x, layer_kinds(c), causal=True,
            positions=positions, enc_out=enc_out, cache_max_len=max_len)
        x = _norm_apply(c, params["final_norm"], x)
        if lengths is not None:
            last = jnp.take_along_axis(
                x, (lengths - 1)[:, None, None].astype(jnp.int32)
                .clip(0), axis=1)
            logits = self._logits(params, last)[:, 0]
            return logits, cache_out(dec_cache, enc_out), lengths
        logits = self._logits(params, x[:, -1:])[:, 0]
        return logits, cache_out(dec_cache, enc_out), jnp.int32(t_total)
