"""repro.optim — optimizers, schedules, gradient compression."""

from repro.optim.adamw import AdamW, AdamWConfig
from repro.optim.compression import onebit_compress, onebit_decompress
from repro.optim.schedule import cosine_schedule

__all__ = ["AdamW", "AdamWConfig", "cosine_schedule", "onebit_compress",
           "onebit_decompress"]
