"""AdamW with low-precision optimizer states and stochastic rounding.

The paper's binary *stochastic* STDP (clear/set a 1-bit weight with an
LFSR-driven probability) generalizes to **stochastic rounding under a
precision budget**: an update too small to represent still lands with
the right probability.  We apply that insight framework-wide:

* ``state_dtype=bfloat16`` keeps Adam's m/v in bf16 (2+2 bytes/param),
* ``param_dtype=bfloat16`` + ``stochastic_rounding=True`` drops the fp32
  master copy entirely — updates are stochastically rounded onto the
  bf16 grid, so tiny LR x grad increments are not systematically lost.

Under full ZeRO-3 sharding this is 8 bytes/param total (param + grad +
m + v, all bf16), which is what fits llama3-405b training on a single
256-chip v5e pod (see EXPERIMENTS.md §Dry-run).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: Callable[[jnp.ndarray], jnp.ndarray] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: Any = jnp.float32        # m/v storage dtype
    stochastic_rounding: bool = False      # bf16 params w/o master copy


def _stochastic_round_bf16(x: jnp.ndarray, key) -> jnp.ndarray:
    """f32 -> bf16 with probability proportional to the residual."""
    bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    noise = jax.random.bits(key, x.shape, dtype=jnp.uint32) & jnp.uint32(0xFFFF)
    rounded = (bits + noise) & jnp.uint32(0xFFFF0000)
    return jax.lax.bitcast_convert_type(rounded, jnp.float32
                                        ).astype(jnp.bfloat16)


@dataclasses.dataclass(frozen=True)
class AdamW:
    cfg: AdamWConfig = AdamWConfig()

    def init(self, params) -> dict:
        dt = self.cfg.state_dtype
        zeros = lambda p: jnp.zeros(p.shape, dt)  # noqa: E731
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def _lr(self, step):
        lr = self.cfg.lr
        return lr(step) if callable(lr) else jnp.float32(lr)

    # leaves above this many elements update via lax.scan over axis 0
    # (layer-stacked tensors), bounding f32 temporaries to one slice —
    # a tree-wide elementwise update would materialize f32 copies of
    # every stacked leaf simultaneously (~10 GB/leaf on llama3-405b).
    _SCAN_THRESHOLD = 1 << 24

    def apply(self, grads, state, params, *, rng=None):
        """Returns (new_params, new_state).  ``rng`` required when
        stochastic_rounding is on."""
        c = self.cfg
        step = state["step"] + 1
        lr = self._lr(step)

        # global-norm clip; square fuses into the reduction (no f32 copy)
        gsq = sum(jnp.sum(jnp.square(g), dtype=jnp.float32)
                  for g in jax.tree.leaves(grads))
        gnorm = jnp.sqrt(gsq)
        scale = jnp.minimum(1.0, c.grad_clip / (gnorm + 1e-9))

        bc1 = 1 - c.b1 ** step.astype(jnp.float32)
        bc2 = 1 - c.b2 ** step.astype(jnp.float32)

        flat_params, treedef = jax.tree.flatten(params)
        flat_grads = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])

        if c.stochastic_rounding:
            if rng is None:
                raise ValueError("stochastic_rounding requires rng")
            keys = list(jax.random.split(rng, len(flat_params)))
        else:
            keys = [None] * len(flat_params)

        def update_slice(p, g, m, v, k, decay: bool):
            gf = g.astype(jnp.float32) * scale
            mf = c.b1 * m.astype(jnp.float32) + (1 - c.b1) * gf
            vf = c.b2 * v.astype(jnp.float32) + (1 - c.b2) * gf * gf
            upd = (mf / bc1) / (jnp.sqrt(vf / bc2) + c.eps)
            pf = p.astype(jnp.float32)
            if decay:
                upd = upd + c.weight_decay * pf
            pf = pf - lr * upd
            if c.stochastic_rounding and p.dtype == jnp.bfloat16:
                p_new = _stochastic_round_bf16(pf, k)
            else:
                p_new = pf.astype(p.dtype)
            return p_new, mf.astype(c.state_dtype), vf.astype(c.state_dtype)

        new_p, new_m, new_v = [], [], []
        for p, g, m, v, k in zip(flat_params, flat_grads, flat_m, flat_v,
                                 keys):
            decay = p.ndim >= 2  # decay matrices only (standard)
            if p.size >= self._SCAN_THRESHOLD and p.ndim >= 3:
                ks = (jax.random.split(k, p.shape[0]) if k is not None
                      else jnp.zeros((p.shape[0],), jnp.uint32))

                def body(_, xs, decay=decay, use_key=k is not None):
                    pi, gi, mi, vi, ki = xs
                    out = update_slice(pi, gi, mi, vi,
                                       ki if use_key else None, decay)
                    return 0, out

                _, (pn, mn, vn) = jax.lax.scan(
                    body, 0, (p, g, m, v, ks))
            else:
                pn, mn, vn = update_slice(p, g, m, v, k, decay)
            new_p.append(pn)
            new_m.append(mn)
            new_v.append(vn)

        return (jax.tree.unflatten(treedef, new_p), {
            "m": jax.tree.unflatten(treedef, new_m),
            "v": jax.tree.unflatten(treedef, new_v),
            "step": step,
        })
