"""1-bit gradient compression with error feedback.

Direct generalization of the paper's binary stochastic STDP to gradient
tensors: a gradient tensor is reduced to sign bits x one scale (the LTP/
LTD "set/clear" decision), and the quantization residual is fed back
into the next step (the role the stochastic LTD probability plays for
synapses — no systematic bias accumulates).

Wire format reuses the SNN bit-packing (repro.core.bitpack): 32 signs
per uint32 word + one f32 scale per tensor, a 32x reduction of DP
gradient traffic.  ``compressed_psum`` shows the shard_map usage.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.bitpack import n_words, pack, unpack


def onebit_compress(g: jnp.ndarray, err: jnp.ndarray
                    ) -> tuple[dict, jnp.ndarray]:
    """(grad, error_state) -> (compressed {bits, scale, shape}, new_err)."""
    s = g.astype(jnp.float32) + err
    scale = jnp.mean(jnp.abs(s))
    q = jnp.where(s >= 0, scale, -scale)
    bits = pack((s >= 0).reshape(-1).astype(jnp.uint32))
    new_err = s - q
    return {"bits": bits, "scale": scale}, new_err


def onebit_decompress(comp: dict, shape: tuple, n: int) -> jnp.ndarray:
    signs = unpack(comp["bits"], n).astype(jnp.float32) * 2.0 - 1.0
    return (signs * comp["scale"]).reshape(shape)


def init_error(params) -> dict:
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_tree(grads, err_tree):
    """Compress every leaf; returns (comp_tree, new_err_tree)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err_tree)
    comps, errs = [], []
    for g, e in zip(flat_g, flat_e):
        c, ne = onebit_compress(g, e)
        comps.append(c)
        errs.append(ne)
    return (jax.tree.unflatten(treedef, comps),
            jax.tree.unflatten(treedef, errs))


def decompress_tree(comp_tree, like):
    flat_l, treedef = jax.tree.flatten(like)
    flat_c = treedef.flatten_up_to(comp_tree)
    outs = [onebit_decompress(c, l.shape, l.size)
            for c, l in zip(flat_c, flat_l)]
    return jax.tree.unflatten(treedef, outs)


def compressed_psum(grads, err_tree, axis_name: str):
    """DP gradient sync at 1 bit/element (use inside shard_map).

    Each rank compresses locally (error feedback keeps the bias bounded),
    the *decompressed* +-scale tensors are psum'd — the wire cost of the
    sign tensor is 1 bit/element + one scalar; the psum itself runs on
    the reconstructed values so the result stays an unbiased-ish mean.
    Returns (synced_grads, new_err_tree).
    """
    comp, new_err = compress_tree(grads, err_tree)
    recon = decompress_tree(comp, grads)
    synced = jax.tree.map(
        lambda g: jax.lax.pmean(g, axis_name), recon)
    return synced, new_err
