"""repro.runtime — fault-tolerant training loop, straggler watchdog."""

from repro.runtime.train_loop import SimulatedFailure, TrainLoop, TrainLoopConfig

__all__ = ["SimulatedFailure", "TrainLoop", "TrainLoopConfig"]
