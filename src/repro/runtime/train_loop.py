"""Fault-tolerant training loop.

Mechanisms (each exercised by tests/test_runtime.py):

* **checkpoint/restart** — periodic async checkpoints; on failure the
  loop restores the latest complete step and replays.  The data
  pipeline is stateless (step -> batch), so restart resumes the exact
  token stream: training after a crash is bit-identical to an
  uninterrupted run (tested).
* **failure injection** — any exception from the step function (or the
  ``SimulatedFailure`` raised by the test hook) triggers restore;
  ``max_restarts`` bounds flapping.
* **straggler watchdog** — per-step wall time EWMA; a step slower than
  ``straggler_factor x`` EWMA is recorded and a callback fires (at
  scale: re-dispatch / drain the slow host; here: structured log +
  counter, the decision logic is what's being validated).
* **elastic scaling** — ``TrainLoop.restore_onto`` re-lays-out the
  latest checkpoint onto a new mesh/sharding (chips added/removed), via
  CheckpointManager's sharding-tree restore.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax

from repro.checkpoint import CheckpointManager


class SimulatedFailure(RuntimeError):
    """Raised by the failure-injection hook to emulate a node loss."""


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 20
    keep_checkpoints: int = 3
    straggler_factor: float = 3.0
    straggler_warmup: int = 5
    max_restarts: int = 5
    log_every: int = 10


class TrainLoop:
    def __init__(self, step_fn: Callable, cfg: TrainLoopConfig,
                 ckpt_dir: str, *, batch_fn: Callable[[int], Any],
                 rng_fn: Callable[[int], Any] | None = None,
                 on_straggler: Callable[[int, float, float], None] | None
                 = None,
                 failure_hook: Callable[[int], None] | None = None):
        self.step_fn = step_fn
        self.cfg = cfg
        self.ckpt = CheckpointManager(ckpt_dir,
                                      keep=cfg.keep_checkpoints)
        self.batch_fn = batch_fn
        self.rng_fn = rng_fn or (lambda s: jax.random.fold_in(
            jax.random.key(0), s))
        self.on_straggler = on_straggler
        self.failure_hook = failure_hook
        self.metrics_log: list[dict] = []
        self.straggler_events: list[dict] = []
        self.restarts = 0

    # --- elastic entry point ----------------------------------------------

    def restore_onto(self, like_state, sharding_tree):
        """Restore the latest checkpoint onto a (possibly different)
        mesh — the elastic-scaling path."""
        return self.ckpt.restore(None, like_state, sharding_tree)

    # --- main loop -----------------------------------------------------------

    def run(self, state) -> Any:
        """state: (params, opt_state).  Returns final state."""
        cfg = self.cfg
        start = 0
        if self.ckpt.latest_step() is not None:
            state, start = self.ckpt.restore(None, state)
            start += 1
        else:
            # anchor checkpoint: "state after step start-1", so a crash
            # before the first periodic save still restores cleanly
            self.ckpt.save(start - 1, state)
            self.ckpt.wait()
        step = start
        ewma = None
        while step < cfg.total_steps:
            try:
                # the watchdog times the WHOLE iteration — input stalls
                # are a straggler cause too
                t0 = time.monotonic()
                if self.failure_hook is not None:
                    self.failure_hook(step)
                batch = self.batch_fn(step)
                params, opt_state, metrics = self.step_fn(
                    state[0], state[1], batch, self.rng_fn(step))
                jax.block_until_ready(metrics["loss"])
                dt = time.monotonic() - t0
                state = (params, opt_state)

                # straggler watchdog
                if ewma is not None and step - start >= cfg.straggler_warmup \
                        and dt > cfg.straggler_factor * ewma:
                    ev = {"step": step, "dt": dt, "ewma": ewma}
                    self.straggler_events.append(ev)
                    if self.on_straggler:
                        self.on_straggler(step, dt, ewma)
                ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt

                self.metrics_log.append(
                    {"step": step, "loss": float(metrics["loss"]),
                     "dt": dt})
                if step % cfg.checkpoint_every == 0 and step > start:
                    self.ckpt.save(step, state)
                step += 1
            except SimulatedFailure:
                self.restarts += 1
                if self.restarts > cfg.max_restarts:
                    raise
                state, latest = self.ckpt.restore(None, state)
                step = latest + 1
        self.ckpt.wait()
        return state
