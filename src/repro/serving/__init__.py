"""repro.serving — batched serving engines.

Transformer path: continuous-batching :class:`ServingEngine` over KV
cache slots.  SNN path: :class:`SNNServingEngine`, dynamic window
batching over the unified SNN engine.
"""

from repro.serving.engine import Request, ServingEngine
from repro.serving.snn import SNNRequest, SNNServingEngine

__all__ = ["Request", "ServingEngine", "SNNRequest", "SNNServingEngine"]
