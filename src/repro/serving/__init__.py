"""repro.serving — batched serving engines.

Transformer path: continuous-batching :class:`ServingEngine` over KV
cache slots.  SNN path: :class:`SNNServingEngine`, dynamic window
batching over the unified SNN engine with a fault-tolerant request
lifecycle (:class:`SNNServingPolicy`), versioned train-while-serving
weights (:mod:`repro.serving.weights` — double-buffered swap,
probe-gated promotion, checkpointed rollback), a deterministic
fault injection harness (:mod:`repro.serving.faults`), and a
crash-consistency layer (:mod:`repro.serving.journal` — fsync'd
CRC-framed request WAL, engine-state snapshots, exactly-once terminal
ledger, snapshot+tail recovery on construction), and adaptive overload
control (:mod:`repro.serving.overload` — CoDel sojourn management,
AIMD admission, priority-aware shedding, a global retry budget, and
per-rung circuit breakers over the degradation ladder).
"""

from repro.serving.engine import Request, ServingEngine
from repro.serving.faults import (CRASH_EXIT_CODE, FaultInjectedError,
                                  FaultInjector, FaultSpec)
from repro.serving.journal import (JournalError, RequestJournal, RingLog,
                                   replay)
from repro.serving.overload import (LadderBreakers, OverloadController,
                                    OverloadPolicy, storm_policy)
from repro.serving.snn import (SNNRequest, SNNServingEngine,
                               SNNServingPolicy, TERMINAL_STATUSES,
                               degradation_ladder)
from repro.serving.weights import (SNNRefreshPolicy, SNNWeightRefresher,
                                   VersionedWeightStore, WeightVersion,
                                   weight_fingerprint)

__all__ = [
    "Request", "ServingEngine",
    "SNNRequest", "SNNServingEngine", "SNNServingPolicy",
    "TERMINAL_STATUSES", "degradation_ladder",
    "CRASH_EXIT_CODE", "FaultInjectedError", "FaultInjector", "FaultSpec",
    "JournalError", "RequestJournal", "RingLog", "replay",
    "LadderBreakers", "OverloadController", "OverloadPolicy",
    "storm_policy",
    "SNNRefreshPolicy", "SNNWeightRefresher", "VersionedWeightStore",
    "WeightVersion", "weight_fingerprint",
]
