"""repro.serving — batched serving engine with continuous batching."""

from repro.serving.engine import Request, ServingEngine

__all__ = ["Request", "ServingEngine"]
