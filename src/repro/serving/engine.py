"""Continuous-batching serving engine.

vLLM-style slot scheduler over a single batched KV cache:

* fixed ``n_slots`` decode batch; every engine step decodes ONE token
  for every active slot (per-slot cache lengths — new requests join
  mid-flight without stalling running ones);
* prompt admission runs a B=1 prefill (exact length — recurrent archs'
  states must not see pad tokens) and splices the resulting cache into
  the slot via batch-axis scatter (batch axes derived from the cache's
  logical spec tree);
* slots free on EOS / max_tokens and are immediately reusable.

Decoder-only archs (dense / MoE / SSM / hybrid / VLM-with-prefix); the
whisper enc-dec path is exercised by its own example instead.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.specs import cache_logical_tree
from repro.models.transformer import Model
from repro.serving import sampler as smp


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int = 32
    eos_id: int = -1                  # -1: never stops early
    output: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    slot: int = -1


class ServingEngine:
    def __init__(self, model: Model, params, *, n_slots: int = 4,
                 max_len: int = 512, temperature: float = 0.0,
                 seed: int = 0):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.temperature = temperature
        self.cache = model.init_cache(n_slots, max_len)
        logical = cache_logical_tree(
            jax.eval_shape(lambda: model.init_cache(n_slots, max_len)))
        self._batch_axis = jax.tree.map(
            lambda names: names.index("batch") if "batch" in names else 0,
            logical, is_leaf=lambda x: isinstance(x, tuple))
        self.cache_len = np.zeros((n_slots,), np.int32)
        self.last_token = np.zeros((n_slots,), np.int32)
        self.slot_req: list[Optional[Request]] = [None] * n_slots
        self.queue: deque[Request] = deque()
        self.key = jax.random.key(seed)
        self._decode = jax.jit(model.decode_step)
        self._prefills: dict[int, callable] = {}
        self.steps = 0
        self.tokens_out = 0

    # --- admission -----------------------------------------------------------

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _prefill_fn(self, length: int):
        if length not in self._prefills:
            self._prefills[length] = jax.jit(
                lambda p, b: self.model.prefill(p, b, self.max_len))
        return self._prefills[length]

    def _splice(self, slot: int, one_cache) -> None:
        """Write a B=1 cache into batch position ``slot``."""
        def put(big, small, axis):
            idx = [slice(None)] * big.ndim
            idx[axis] = slice(slot, slot + 1)
            return big.at[tuple(idx)].set(small.astype(big.dtype))

        self.cache = jax.tree.map(put, self.cache, one_cache,
                                  self._batch_axis)

    def _admit(self) -> None:
        free = [i for i, r in enumerate(self.slot_req) if r is None]
        while free and self.queue:
            slot = free.pop(0)
            req = self.queue.popleft()
            toks = jnp.asarray([req.prompt], jnp.int32)
            logits, cache1, clen = self._prefill_fn(len(req.prompt))(
                self.params, {"tokens": toks})
            tok = self._sample(logits)[0]
            self._splice(slot, cache1)
            self.cache_len[slot] = int(clen)
            self.last_token[slot] = int(tok)
            req.slot = slot
            req.output.append(int(tok))
            self.slot_req[slot] = req
            self.tokens_out += 1
            self._finish_if_done(req)

    # --- decode --------------------------------------------------------------

    def _sample(self, logits) -> np.ndarray:
        if self.temperature <= 0.0:
            return np.asarray(smp.greedy(logits))
        self.key, k = jax.random.split(self.key)
        return np.asarray(smp.temperature(k, logits, self.temperature))

    def _finish_if_done(self, req: Request) -> None:
        if req.done or req.slot < 0:
            return
        if (len(req.output) >= req.max_new_tokens
                or req.output[-1] == req.eos_id
                or self.cache_len[req.slot] >= self.max_len - 1):
            req.done = True
            self.slot_req[req.slot] = None
            req.slot = -1

    def step(self) -> int:
        """One engine iteration: admit + batched decode.  Returns the
        number of tokens produced."""
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return 0
        tokens = jnp.asarray(self.last_token[:, None], jnp.int32)
        clen = jnp.asarray(self.cache_len, jnp.int32)
        logits, self.cache = self._decode(self.params, tokens, self.cache,
                                          clen)
        toks = self._sample(logits)
        produced = 0
        for i in active:
            req = self.slot_req[i]
            self.cache_len[i] += 1
            self.last_token[i] = int(toks[i])
            req.output.append(int(toks[i]))
            produced += 1
            self._finish_if_done(req)
        self.steps += 1
        self.tokens_out += produced
        return produced

    def run(self, requests: list[Request], max_steps: int = 10_000
            ) -> list[Request]:
        for r in requests:
            self.submit(r)
        steps = 0
        while (any(not r.done for r in requests)
               and steps < max_steps):
            self.step()
            steps += 1
        return requests
