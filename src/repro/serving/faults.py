"""Deterministic fault injection for the SNN serving robustness layer.

:class:`FaultInjector` is the test substrate behind
:class:`~repro.serving.snn.SNNServingEngine`'s optional ``on_launch``
hook: when no hook is installed the production serve path runs exactly
as before (the hook is never consulted), and when one is, every serve /
canary launch first passes through the injector, which — from one
seeded ``numpy`` generator, so storms replay bit-identically — may

* raise :class:`FaultInjectedError` (a failed kernel launch; an
  ``error_burst`` of consecutive failures per trigger lets a single
  draw push the engine past its retry budget and down the degradation
  ladder),
* sleep ``stall_ms`` (an injected stall, visible in the latency
  percentiles), or
* return a corruption callable the engine applies to the launch's
  count matrix.  Corruptions are always *detectable*: they drive a
  slot negative or past its ``t_total`` cycle budget, which the
  engine's output integrity guard (``0 <= counts <= t_total``) is
  specified to catch — in-range corruption is the canary's job, not
  the guard's.

The engine never hooks its ``kind="fallback"`` oracle re-serves, so an
injector can never corrupt the path that repairs its own damage.

Overload storms add a *slowdown* channel, consulted through the
separate :meth:`FaultInjector.service_inflation` method once per
serving step: with ``p_slowdown`` a seeded burst of ``slowdown_steps``
consecutive steps each cost ``slowdown_factor``x modeled service time
(the virtual clock multiplies its step charge), sagging capacity
without any launch failing — the load shape the adaptive admission
controller exists to absorb.  The method draws from the same generator
but only when ``p_slowdown > 0``, so legacy storm recipes replay
bit-identically.

Versioned train-while-serving adds two hooked call kinds with their own
fault families (drawn from the same generator, but only when those
calls happen — a storm with no refresher replays bit-identically with
older injectors):

* ``kind="refresh"`` — consulted once per refresh cycle, before the
  candidate is trained.  May stall (``p_refresh_stall`` ×
  ``refresh_stall_ms`` — trips the refresher's stalled-refresh
  timeout) or return a *weight*-corruption callable
  (``p_refresh_corrupt``) the engine applies to the candidate bank
  after its content fingerprint was taken — exactly a torn/corrupted
  candidate, which the store's fingerprint verification at the probe
  gate is specified to catch deterministically.
* ``kind="save"`` — consulted by the store right before persisting a
  promoted version.  With ``p_save_crash`` it raises, modeling a
  process crash mid-checkpoint: the store leaves a torn ``step_N.tmp``
  dropping and aborts the promotion, exactly what a restarted process
  would find on disk.

Crash-consistent serving (PR 9) adds *whole-process* crash points,
consulted by the engine only when a :class:`~repro.serving.journal.
RequestJournal` is attached (a journal-less engine never sends these
kinds, so legacy storms replay bit-identically):

* ``kind="crash_before_dispatch"`` — after the batch's ADMIT+DISPATCH
  records are fsync'd, before the serve launch.
* ``kind="crash_after_serve"`` — after counts are computed, before any
  TERMINAL record is journaled (``p_crash_after_serve_before_journal``).
* ``kind="crash_mid_snapshot"`` — after ``snapshot_N.json.tmp`` is
  written, before the atomic rename.

A firing crash point calls ``crash_hook(kind)`` — by default
``os._exit(73)``, the real ``kill -9`` model: user-space journal
buffers die, fsync'd records survive, and the kill–restart harness
recognizes exit code 73 as an induced crash.  Tests substitute a hook
that raises, then ``journal.abandon()`` to drop the buffers the dead
process would have lost.  Crash draws happen only when the matching
probability is nonzero, so a chaos child running "clean" (all crash
probabilities 0) is bit-identical to a journal-less run.
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable

import numpy as np

CRASH_EXIT_CODE = 73   # the kill–restart harness's "induced crash" code


class FaultInjectedError(RuntimeError):
    """An injected kernel-launch failure (never raised in production)."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Seeded storm recipe: per-launch fault probabilities."""
    p_launch_error: float = 0.0   # P[launch raises] per hooked launch
    p_corrupt: float = 0.0        # P[count matrix corrupted]
    p_stall: float = 0.0          # P[injected stall before the launch]
    stall_ms: float = 0.0         # stall duration when one fires
    error_burst: int = 1          # consecutive failures per error trigger
    seed: int = 0                 # numpy generator seed (replayable)
    # --- refresh-path faults (kind="refresh" / kind="save" calls) -------
    p_refresh_corrupt: float = 0.0  # P[candidate weights corrupted]
    p_refresh_stall: float = 0.0    # P[refresh stalls before training]
    refresh_stall_ms: float = 0.0   # refresh stall duration
    p_save_crash: float = 0.0       # P[crash mid-checkpoint-save]
    # --- whole-process crash points (journaled engines only) ------------
    p_crash_before_dispatch: float = 0.0        # post-WAL-sync, pre-launch
    p_crash_after_serve_before_journal: float = 0.0  # pre-TERMINAL write
    p_crash_mid_snapshot: float = 0.0           # tmp written, pre-rename
    # --- service-time inflation (overload storms) -----------------------
    p_slowdown: float = 0.0       # P[a serving step starts a slow burst]
    slowdown_factor: float = 4.0  # modeled service-cost multiplier
    slowdown_steps: int = 1       # consecutive inflated steps per burst

    def __post_init__(self):
        for name in ("p_launch_error", "p_corrupt", "p_stall",
                     "p_refresh_corrupt", "p_refresh_stall",
                     "p_save_crash", "p_crash_before_dispatch",
                     "p_crash_after_serve_before_journal",
                     "p_crash_mid_snapshot", "p_slowdown"):
            p = getattr(self, name)
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {p}")
        if self.error_burst < 1:
            raise ValueError(f"error_burst must be >= 1, got "
                             f"{self.error_burst}")
        if self.stall_ms < 0:
            raise ValueError(f"stall_ms must be >= 0, got {self.stall_ms}")
        if self.refresh_stall_ms < 0:
            raise ValueError(f"refresh_stall_ms must be >= 0, got "
                             f"{self.refresh_stall_ms}")
        if self.slowdown_factor < 1.0:
            raise ValueError(f"slowdown_factor must be >= 1, got "
                             f"{self.slowdown_factor}")
        if self.slowdown_steps < 1:
            raise ValueError(f"slowdown_steps must be >= 1, got "
                             f"{self.slowdown_steps}")


class FaultInjector:
    """Callable ``on_launch`` hook: ctx dict in, corruption fn (or
    None) out, :class:`FaultInjectedError` raised for launch failures.

    ``ctx`` carries ``step`` / ``attempt`` / ``level`` / ``kind`` /
    ``batch_size`` / ``t_lens`` from the engine; all randomness comes
    from one ``default_rng(spec.seed)``, so a storm is a pure function
    of (spec, launch sequence).
    """

    def __init__(self, spec: FaultSpec | None = None,
                 crash_hook: Callable[[str], None] | None = None,
                 **kwargs):
        self.spec = spec if spec is not None else FaultSpec(**kwargs)
        self.rng = np.random.default_rng(self.spec.seed)
        self.crash_hook = (crash_hook if crash_hook is not None
                           else lambda kind: os._exit(CRASH_EXIT_CODE))
        self.launches = 0
        self.errors = 0
        self.corruptions = 0
        self.stalls = 0
        self.refresh_corruptions = 0
        self.refresh_stalls = 0
        self.save_crashes = 0
        self.crashes = 0
        self._burst_left = 0
        self.slowdowns = 0
        self._slow_left = 0

    _CRASH_P = {
        "crash_before_dispatch": "p_crash_before_dispatch",
        "crash_after_serve": "p_crash_after_serve_before_journal",
        "crash_mid_snapshot": "p_crash_mid_snapshot",
    }

    def __call__(self, ctx: dict):
        self.launches += 1
        sp = self.spec
        kind = ctx.get("kind", "serve")
        if kind in self._CRASH_P:
            # draw only when armed, so a clean chaos child replays
            # bit-identically with a journal-less storm
            p = getattr(sp, self._CRASH_P[kind])
            if p > 0.0 and self.rng.random() < p:
                self.crashes += 1
                self.crash_hook(kind)   # default: os._exit(73), no return
            return None
        if kind == "refresh":
            draw = self.rng.random(2)
            if draw[0] < sp.p_refresh_stall and sp.refresh_stall_ms > 0:
                self.refresh_stalls += 1
                time.sleep(sp.refresh_stall_ms / 1e3)
            if draw[1] < sp.p_refresh_corrupt:
                self.refresh_corruptions += 1

                def corrupt_weights(w):
                    out = np.array(w)        # torn-buffer bit rot
                    out ^= np.uint32(0xA5A5A5A5)
                    return out

                return corrupt_weights
            return None
        if kind == "save":
            if self.rng.random() < sp.p_save_crash:
                self.save_crashes += 1
                raise FaultInjectedError(
                    f"injected crash during checkpoint save "
                    f"(version={ctx.get('version')})")
            return None
        draw = self.rng.random(3)
        if self._burst_left > 0 or draw[0] < sp.p_launch_error:
            if self._burst_left == 0:
                self._burst_left = sp.error_burst
            self._burst_left -= 1
            self.errors += 1
            raise FaultInjectedError(
                f"injected launch failure (step={ctx.get('step')}, "
                f"level={ctx.get('level')}, kind={ctx.get('kind')})")
        if draw[1] < sp.p_stall and sp.stall_ms > 0:
            self.stalls += 1
            time.sleep(sp.stall_ms / 1e3)
        if draw[2] < sp.p_corrupt and ctx.get("batch_size", 0) > 0:
            slot = int(self.rng.integers(ctx["batch_size"]))
            t_len = int(ctx["t_lens"][slot])
            mode = int(self.rng.integers(2))
            self.corruptions += 1

            def corrupt(counts, slot=slot, t_len=t_len, mode=mode):
                out = np.array(counts)
                if mode == 0:
                    out[slot, 0] = -1            # violates counts >= 0
                else:
                    out[slot, :] = t_len + 1     # violates counts <= t_total
                return out

            return corrupt
        return None

    def service_inflation(self, ctx: dict) -> float:
        """Service-time multiplier for one serving step (the overload
        storm's slowdown channel): with ``p_slowdown`` a burst of
        ``slowdown_steps`` consecutive steps each cost
        ``slowdown_factor``x modeled time — capacity sags without any
        launch failing, exactly the overload the admission controller
        must absorb.  Draws only when armed (``p_slowdown > 0``), so
        legacy storms replay bit-identically."""
        sp = self.spec
        if sp.p_slowdown <= 0.0:
            return 1.0
        if self._slow_left > 0:
            self._slow_left -= 1
            return sp.slowdown_factor
        if self.rng.random() < sp.p_slowdown:
            self.slowdowns += 1
            self._slow_left = sp.slowdown_steps - 1
            return sp.slowdown_factor
        return 1.0

    def stats(self) -> dict:
        """Injection counters (for bench reports and storm tests)."""
        return {"fault_launches": self.launches,
                "fault_errors": self.errors,
                "fault_corruptions": self.corruptions,
                "fault_stalls": self.stalls,
                "fault_refresh_corruptions": self.refresh_corruptions,
                "fault_refresh_stalls": self.refresh_stalls,
                "fault_save_crashes": self.save_crashes,
                "fault_crashes": self.crashes,
                "fault_slowdowns": self.slowdowns}
