"""Deterministic fault injection for the SNN serving robustness layer.

:class:`FaultInjector` is the test substrate behind
:class:`~repro.serving.snn.SNNServingEngine`'s optional ``on_launch``
hook: when no hook is installed the production serve path runs exactly
as before (the hook is never consulted), and when one is, every serve /
canary launch first passes through the injector, which — from one
seeded ``numpy`` generator, so storms replay bit-identically — may

* raise :class:`FaultInjectedError` (a failed kernel launch; an
  ``error_burst`` of consecutive failures per trigger lets a single
  draw push the engine past its retry budget and down the degradation
  ladder),
* sleep ``stall_ms`` (an injected stall, visible in the latency
  percentiles), or
* return a corruption callable the engine applies to the launch's
  count matrix.  Corruptions are always *detectable*: they drive a
  slot negative or past its ``t_total`` cycle budget, which the
  engine's output integrity guard (``0 <= counts <= t_total``) is
  specified to catch — in-range corruption is the canary's job, not
  the guard's.

The engine never hooks its ``kind="fallback"`` oracle re-serves, so an
injector can never corrupt the path that repairs its own damage.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np


class FaultInjectedError(RuntimeError):
    """An injected kernel-launch failure (never raised in production)."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Seeded storm recipe: per-launch fault probabilities."""
    p_launch_error: float = 0.0   # P[launch raises] per hooked launch
    p_corrupt: float = 0.0        # P[count matrix corrupted]
    p_stall: float = 0.0          # P[injected stall before the launch]
    stall_ms: float = 0.0         # stall duration when one fires
    error_burst: int = 1          # consecutive failures per error trigger
    seed: int = 0                 # numpy generator seed (replayable)

    def __post_init__(self):
        for name in ("p_launch_error", "p_corrupt", "p_stall"):
            p = getattr(self, name)
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {p}")
        if self.error_burst < 1:
            raise ValueError(f"error_burst must be >= 1, got "
                             f"{self.error_burst}")
        if self.stall_ms < 0:
            raise ValueError(f"stall_ms must be >= 0, got {self.stall_ms}")


class FaultInjector:
    """Callable ``on_launch`` hook: ctx dict in, corruption fn (or
    None) out, :class:`FaultInjectedError` raised for launch failures.

    ``ctx`` carries ``step`` / ``attempt`` / ``level`` / ``kind`` /
    ``batch_size`` / ``t_lens`` from the engine; all randomness comes
    from one ``default_rng(spec.seed)``, so a storm is a pure function
    of (spec, launch sequence).
    """

    def __init__(self, spec: FaultSpec | None = None, **kwargs):
        self.spec = spec if spec is not None else FaultSpec(**kwargs)
        self.rng = np.random.default_rng(self.spec.seed)
        self.launches = 0
        self.errors = 0
        self.corruptions = 0
        self.stalls = 0
        self._burst_left = 0

    def __call__(self, ctx: dict):
        self.launches += 1
        sp = self.spec
        draw = self.rng.random(3)
        if self._burst_left > 0 or draw[0] < sp.p_launch_error:
            if self._burst_left == 0:
                self._burst_left = sp.error_burst
            self._burst_left -= 1
            self.errors += 1
            raise FaultInjectedError(
                f"injected launch failure (step={ctx.get('step')}, "
                f"level={ctx.get('level')}, kind={ctx.get('kind')})")
        if draw[1] < sp.p_stall and sp.stall_ms > 0:
            self.stalls += 1
            time.sleep(sp.stall_ms / 1e3)
        if draw[2] < sp.p_corrupt and ctx.get("batch_size", 0) > 0:
            slot = int(self.rng.integers(ctx["batch_size"]))
            t_len = int(ctx["t_lens"][slot])
            mode = int(self.rng.integers(2))
            self.corruptions += 1

            def corrupt(counts, slot=slot, t_len=t_len, mode=mode):
                out = np.array(counts)
                if mode == 0:
                    out[slot, 0] = -1            # violates counts >= 0
                else:
                    out[slot, :] = t_len + 1     # violates counts <= t_total
                return out

            return corrupt
        return None

    def stats(self) -> dict:
        """Injection counters (for bench reports and storm tests)."""
        return {"fault_launches": self.launches,
                "fault_errors": self.errors,
                "fault_corruptions": self.corruptions,
                "fault_stalls": self.stalls}
