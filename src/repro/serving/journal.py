"""Crash-consistent request journal for the SNN serving engine.

:class:`RequestJournal` is the durability substrate under
:class:`~repro.serving.snn.SNNServingEngine`: an append-only, fsync'd,
CRC-framed write-ahead log of request lifecycle events plus periodic
engine-state snapshots that truncate the log.  A process can die at any
instant — ``kill -9``, power loss, an injected ``os._exit`` from
:mod:`repro.serving.faults` — and a restarted engine recovers every
admitted request and every counter the dead process had made durable.

Journal layout (one directory per engine)::

    snapshot_<seq>.json      # engine state at the start of segment <seq>
    snapshot_<seq>.json.tmp  # torn snapshot (crash mid-write) — ignored
    wal_<seq>.log            # CRC-framed events appended after snapshot <seq>
    ledger.log               # append-only terminal ledger (never truncated)

**WAL framing.**  Each record is ``<u32 len><u32 crc32>`` followed by
``len`` bytes of canonical JSON.  Appends are buffered; :meth:`sync`
flushes and ``fsync``\\ s, so the engine chooses its durability points
(group commit at batch dispatch and at step end).  On recovery a
*partial final* record — fewer bytes on disk than its header promises,
or a final record whose CRC fails (page tearing) — is truncated away:
it was never acknowledged durable.  A CRC mismatch on a *mid-log*
record means bit rot of acknowledged state and raises
:class:`JournalError` loudly; silently dropping acknowledged events
could re-serve or lose requests.

**Event records.**  Three event kinds, written by the engine:

* ``A`` (ADMIT) — rid, intended-arrival timestamp, priority, deadline,
  the payload *descriptor* (a :class:`repro.loadgen.workload` trace
  row when the request came from a trace — payload bytes regenerate
  from its seed — or the inline payload otherwise) and the payload
  content hash.
* ``D`` (DISPATCH) — the rids of one formed batch, the pinned weight
  version, and the batch's pad waste.  Purely attributive: recovery
  treats dispatched-but-unterminated exactly like admitted.
* ``T`` (TERMINAL) — rid, terminal status, served weight version,
  queue-wait / service latency, completion time, content hash.

**Snapshots.**  :meth:`snapshot` writes the engine's full state (queue
contents as ADMIT records, robustness counters, latency histograms via
their JSON round-trip, degradation rung, live weight version, clock
time) to ``snapshot_<seq+1>.json.tmp``, fsyncs, renames, then rotates
the WAL: a new empty ``wal_<seq+1>.log`` is opened and the previous
segment is deleted.  A crash mid-snapshot leaves only the ``.tmp``
(ignored on recovery — the previous snapshot + full log win); a crash
after the rename but before the new segment opens leaves a stale
``wal_<seq>.log`` whose events are already folded into the snapshot —
recovery reads only the segment matching the newest complete snapshot,
so stale segments are dead weight, deleted on the next rotation.

**Recovery** (:meth:`recover` + :func:`replay`) folds the newest
complete snapshot and its WAL tail into a :class:`RecoveredState`:
counters and histograms advance by the tail's TERMINAL events, ADMITs
without a TERMINAL become the re-queue set (in admission order), and
``resume_offset`` is one past the highest rid ever journaled — the
trace offset a resumed load run continues from.

**Terminal ledger.**  ``ledger.log`` is the exactly-once audit trail:
one CRC-framed record per terminal request, appended *after* the WAL
terminal record is fsync'd and never truncated by snapshots.  Because
a rid is re-queued only when its WAL terminal is missing, and a ledger
entry exists only when that WAL terminal was durable, a rid can never
acquire two ledger entries — the property the kill–restart chaos
harness audits (zero lost admits, zero duplicate serves by content
hash).
"""

from __future__ import annotations

import collections.abc
import dataclasses
import json
import os
import struct
import zlib
from pathlib import Path
from typing import Callable

_FRAME_HDR = struct.Struct("<II")     # (payload length, crc32)
_MAX_RECORD = 64 << 20                # sanity bound on one record


class JournalError(RuntimeError):
    """Acknowledged journal state failed verification (mid-log CRC
    mismatch, unparseable snapshot/record).  Never raised for a torn
    *tail* — that is truncated silently, it was never durable."""


class RingLog(collections.abc.Sequence):
    """Fixed-capacity append-only event log: keeps the most recent
    ``cap`` entries and counts the rest in ``dropped``, so week-long
    serving runs carry bounded telemetry instead of an unbounded list.
    Supports the list operations the telemetry consumers use (len,
    indexing incl. negative, iteration, ``append``)."""

    def __init__(self, cap: int = 256, items=None):
        if cap < 1:
            raise ValueError(f"cap must be >= 1, got {cap}")
        self.cap = int(cap)
        self.dropped = 0
        self._items: list = []
        for it in (items or []):
            self.append(it)

    def append(self, item) -> None:
        self._items.append(item)
        if len(self._items) > self.cap:
            del self._items[0]
            self.dropped += 1

    def __len__(self) -> int:
        return len(self._items)

    def __getitem__(self, i):
        return self._items[i]

    def __iter__(self):
        return iter(self._items)

    def to_list(self) -> list:
        return list(self._items)

    def __repr__(self) -> str:
        return (f"RingLog(cap={self.cap}, kept={len(self._items)}, "
                f"dropped={self.dropped})")


def _canon(obj) -> bytes:
    return json.dumps(obj, sort_keys=True,
                      separators=(",", ":")).encode()


def _frame(payload: bytes) -> bytes:
    return _FRAME_HDR.pack(len(payload), zlib.crc32(payload)) + payload


def read_frames(data: bytes, *, where: str = "log"
                ) -> tuple[list[dict], int]:
    """Decode a CRC-framed byte stream.  Returns ``(records,
    valid_len)`` where ``valid_len`` is the byte length of the intact
    prefix — shorter than ``len(data)`` exactly when the final record
    is torn (partial header, partial payload, or CRC-failed tail).  A
    CRC mismatch on any record *before* the last raises
    :class:`JournalError`: that record was acknowledged durable."""
    records: list[dict] = []
    off = 0
    n = len(data)
    while off < n:
        if n - off < _FRAME_HDR.size:
            return records, off                      # torn header
        length, crc = _FRAME_HDR.unpack_from(data, off)
        if length > _MAX_RECORD or n - off - _FRAME_HDR.size < length:
            return records, off                      # torn payload
        payload = data[off + _FRAME_HDR.size:
                       off + _FRAME_HDR.size + length]
        end = off + _FRAME_HDR.size + length
        if zlib.crc32(payload) != crc:
            if end >= n:
                return records, off                  # torn final record
            raise JournalError(
                f"{where}: CRC mismatch on record {len(records)} at "
                f"byte {off} (mid-log corruption of acknowledged "
                f"state)")
        try:
            records.append(json.loads(payload))
        except json.JSONDecodeError as e:
            raise JournalError(
                f"{where}: unparseable record {len(records)} at byte "
                f"{off}: {e}") from e
        off = end
    return records, off


def _fsync_dir(path: Path) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


@dataclasses.dataclass
class RecoveredState:
    """One journal's replayed state: what a restarted engine adopts."""
    counters: dict                 # scalar engine counters
    qw_hist: dict | None           # LatencyHistogram.to_dict() or None
    sv_hist: dict | None
    pending: list[dict]            # ADMIT records lacking a TERMINAL
    last_rid: int                  # highest rid ever journaled (-1 none)
    weight_version: int | None     # live version at last durable point
    clock_ms: float                # engine clock high-water mark
    t_first_ms: float | None
    t_last_ms: float | None
    deg_events: list[dict]
    deg_dropped: int
    level: int                     # degradation rung at snapshot time
    snapshotted: bool              # a complete snapshot existed
    overload: dict | None = None   # OverloadController.state_dict()
    breakers: list | None = None   # per-rung circuit-breaker states

    @property
    def resume_offset(self) -> int:
        """One past the highest journaled rid: the trace offset a
        resumed load run continues from."""
        return self.last_rid + 1


_COUNTER_KEYS = (
    "steps", "batches", "windows_served", "slots_offered",
    "slots_padded", "submitted", "rejected", "expired", "failed",
    "retried", "degraded", "integrity_failures", "canary_checks",
    "canary_failures", "healthy_steps", "refresh_runs",
    "refresh_rejected", "refresh_corrupt", "refresh_timeouts",
    "refresh_failed", "version_violations",
    "shed_admission", "shed_low_priority", "shed_codel",
    "retries_denied",
)

_STATUS_COUNTER = {"SERVED": "windows_served", "REJECTED": "rejected",
                   "EXPIRED": "expired", "FAILED": "failed"}

# TERMINAL records tag overload sheds so tail replay re-attributes the
# shed counters exactly; tags from a future schema are ignored (the
# status counter above still advances)
_SHED_COUNTER = {"adm": "shed_admission", "lowprio": "shed_low_priority",
                 "codel": "shed_codel"}


def replay(snapshot: dict | None, tail: list[dict]) -> RecoveredState:
    """Fold a snapshot and its WAL tail into the recovered state.

    Pure function of the journal contents, shared by the engine's
    restart path and the chaos harness's audit.  TERMINAL events
    advance counters and histograms; ADMITs without a TERMINAL stay
    pending in admission order (re-queue set); duplicate terminal
    serves are impossible by construction (a rid re-queues only when
    its terminal record was never durable), so replay does not need to
    deduplicate — it asserts instead.
    """
    from repro.loadgen.histogram import LatencyHistogram

    counters = {k: 0 for k in _COUNTER_KEYS}
    qw = LatencyHistogram()
    sv = LatencyHistogram()
    pending: dict[int, dict] = {}
    last_rid = -1
    weight_version: int | None = None
    clock_ms = 0.0
    t_first: float | None = None
    t_last: float | None = None
    deg_events: list[dict] = []
    deg_dropped = 0
    level = 0
    if snapshot is not None:
        # adopt every snapshot counter, known or not: unknown keys come
        # from a different schema generation (an older engine reading a
        # newer snapshot, or vice versa) and are preserved-and-ignored
        # rather than breaking replay
        for k, v in snapshot["counters"].items():
            counters[k] = int(v)
        if snapshot.get("qw_hist"):
            qw = LatencyHistogram.from_dict(snapshot["qw_hist"])
        if snapshot.get("sv_hist"):
            sv = LatencyHistogram.from_dict(snapshot["sv_hist"])
        for rec in snapshot.get("queue", []):
            pending[int(rec["rid"])] = rec
        last_rid = int(snapshot.get("last_rid", -1))
        weight_version = snapshot.get("weight_version")
        clock_ms = float(snapshot.get("clock_ms", 0.0))
        t_first = snapshot.get("t_first_ms")
        t_last = snapshot.get("t_last_ms")
        deg_events = list(snapshot.get("deg_events", []))
        deg_dropped = int(snapshot.get("deg_dropped", 0))
        level = int(snapshot.get("level", 0))
    terminal_seen: set[int] = set()
    for ev in tail:
        kind = ev.get("ev")
        if kind == "A":
            rid = int(ev["rid"])
            pending[rid] = ev
            counters["submitted"] += 1
            last_rid = max(last_rid, rid)
            ts = float(ev["ts"])
            t_first = ts if t_first is None else min(t_first, ts)
        elif kind == "T":
            rid = int(ev["rid"])
            if rid in terminal_seen:
                raise JournalError(
                    f"duplicate TERMINAL for rid {rid} in one journal "
                    f"segment (exactly-once broken)")
            terminal_seen.add(rid)
            pending.pop(rid, None)
            status = ev["st"]
            key = _STATUS_COUNTER.get(status)
            if key is None:
                raise JournalError(
                    f"rid {rid}: unknown terminal status {status!r}")
            counters[key] += 1
            shed_key = _SHED_COUNTER.get(ev.get("shed"))
            if shed_key is not None:
                counters[shed_key] += 1
            if status == "SERVED":
                if ev.get("qw") is not None:
                    qw.record(float(ev["qw"]))
                if ev.get("sv") is not None:
                    sv.record(float(ev["sv"]))
                if ev.get("ver") is not None:
                    weight_version = int(ev["ver"])
            # a reject at submit time never had an ADMIT; count the
            # offer so resume never re-offers the row
            counters["submitted"] += int(ev.get("noadmit", 0))
            last_rid = max(last_rid, rid)
            at = ev.get("at")
            if at is not None:
                clock_ms = max(clock_ms, float(at))
                t_last = (float(at) if t_last is None
                          else max(t_last, float(at)))
        elif kind == "D":
            counters["steps"] = max(counters["steps"],
                                    int(ev["step"]) + 1)
            counters["batches"] = counters["steps"]
            counters["slots_offered"] += int(ev["n"]) + int(ev["pad"])
            counters["slots_padded"] += int(ev["pad"])
            if ev.get("ver") is not None:
                weight_version = int(ev["ver"])
            at = ev.get("at")
            if at is not None:
                clock_ms = max(clock_ms, float(at))
        else:
            raise JournalError(f"unknown event kind {kind!r}")
    ordered = sorted(pending.values(), key=lambda r: int(r["rid"]))
    return RecoveredState(
        counters=counters, qw_hist=qw.to_dict(), sv_hist=sv.to_dict(),
        pending=ordered, last_rid=last_rid,
        weight_version=weight_version, clock_ms=clock_ms,
        t_first_ms=t_first, t_last_ms=t_last, deg_events=deg_events,
        deg_dropped=deg_dropped, level=level,
        snapshotted=snapshot is not None,
        overload=(snapshot or {}).get("overload"),
        breakers=(snapshot or {}).get("breakers"))


class RequestJournal:
    """Append-only, fsync'd, CRC-framed WAL + snapshot pair for one
    serving engine (see the module docstring for the protocol).

    Appends land in an explicit user-space buffer; :meth:`sync` writes
    the buffer to the file descriptor and ``fsync``\\ s it.  Process
    death (``kill -9``, ``os._exit``) loses exactly the buffered,
    un-synced suffix — :meth:`abandon` simulates that in-process for
    tests by dropping the buffers and closing the raw fds."""

    LEDGER = "ledger.log"

    def __init__(self, directory: str | Path):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.seq = 0
        self.snapshots_taken = 0
        self.records_appended = 0
        self.syncs = 0
        self.torn_tail_truncated = 0
        self._wal_fd: int | None = None
        self._wal_buf = bytearray()
        self._ledger_fd: int | None = None
        self._ledger_buf = bytearray()

    # --- paths ---------------------------------------------------------

    def _snap_path(self, seq: int) -> Path:
        return self.dir / f"snapshot_{seq}.json"

    def _wal_path(self, seq: int) -> Path:
        return self.dir / f"wal_{seq}.log"

    def _complete_snapshots(self) -> list[int]:
        out = []
        for p in self.dir.glob("snapshot_*.json"):
            try:
                out.append(int(p.stem.split("_")[1]))
            except (IndexError, ValueError):
                continue
        return sorted(out)

    # --- recovery ------------------------------------------------------

    def recover(self, *, truncate: bool = True
                ) -> tuple[dict | None, list[dict]]:
        """Read the newest complete snapshot and its WAL tail.

        ``.tmp`` snapshot droppings are ignored (a crash mid-snapshot
        recovers from the previous snapshot + full log).  A torn final
        WAL record is truncated away (physically, when ``truncate`` —
        the engine's restart path; read-only for audits).  Positions
        the journal at the recovered segment so subsequent appends
        continue it.
        """
        snaps = self._complete_snapshots()
        snapshot = None
        if snaps:
            self.seq = snaps[-1]
            raw = self._snap_path(self.seq).read_text()
            try:
                snapshot = json.loads(raw)
            except json.JSONDecodeError as e:
                raise JournalError(
                    f"{self._snap_path(self.seq)}: unparseable "
                    f"snapshot: {e}") from e
        tail: list[dict] = []
        wal = self._wal_path(self.seq)
        if wal.exists():
            data = wal.read_bytes()
            tail, valid = read_frames(data, where=str(wal))
            if valid < len(data):
                self.torn_tail_truncated += 1
                if truncate:
                    with open(wal, "r+b") as fh:
                        fh.truncate(valid)
                        fh.flush()
                        os.fsync(fh.fileno())
        return snapshot, tail

    # --- appends -------------------------------------------------------

    _OPEN_FLAGS = os.O_WRONLY | os.O_CREAT | os.O_APPEND

    def _wal_open(self) -> int:
        if self._wal_fd is None:
            self._wal_fd = os.open(self._wal_path(self.seq),
                                   self._OPEN_FLAGS, 0o644)
        return self._wal_fd

    def append(self, record: dict) -> None:
        """Buffered append; durable only after the next :meth:`sync`."""
        self._wal_buf += _frame(_canon(record))
        self.records_appended += 1

    def sync(self) -> None:
        if self._wal_buf:
            fd = self._wal_open()
            os.write(fd, bytes(self._wal_buf))
            self._wal_buf.clear()
            os.fsync(fd)
            self.syncs += 1

    def ledger_append(self, record: dict) -> None:
        """Buffer a record for the never-truncated terminal ledger.
        Call only after the matching WAL terminal is durable
        (:meth:`sync`), so the ledger can never run ahead of the WAL —
        the exactly-once argument depends on that order."""
        self._ledger_buf += _frame(_canon(record))

    def ledger_sync(self) -> None:
        if self._ledger_buf:
            if self._ledger_fd is None:
                self._ledger_fd = os.open(self.dir / self.LEDGER,
                                          self._OPEN_FLAGS, 0o644)
            os.write(self._ledger_fd, bytes(self._ledger_buf))
            self._ledger_buf.clear()
            os.fsync(self._ledger_fd)

    # --- snapshots -----------------------------------------------------

    def snapshot(self, state: dict, *,
                 crash_point: Callable[[], None] | None = None) -> int:
        """Write a snapshot and rotate the WAL; returns the new seq.

        Protocol: write ``snapshot_<seq+1>.json.tmp`` + fsync, consult
        ``crash_point`` (the ``p_crash_mid_snapshot`` injection site —
        a crash here leaves only the ``.tmp``, which recovery ignores),
        rename to ``snapshot_<seq+1>.json``, fsync the directory, open
        the new WAL segment, then delete the superseded snapshot and
        segment.
        """
        self.sync()          # events up to here fold into the snapshot
        new = self.seq + 1
        tmp = self.dir / f"snapshot_{new}.json.tmp"
        with open(tmp, "w") as fh:
            fh.write(json.dumps(state, sort_keys=True,
                                separators=(",", ":")))
            fh.flush()
            os.fsync(fh.fileno())
        if crash_point is not None:
            crash_point()
        tmp.rename(self._snap_path(new))
        _fsync_dir(self.dir)
        if self._wal_fd is not None:
            os.close(self._wal_fd)
            self._wal_fd = None
        old = self.seq
        self.seq = new
        self._wal_open()
        for p in (self._snap_path(old), self._wal_path(old),
                  self.dir / f"snapshot_{old}.json.tmp"):
            try:
                p.unlink()
            except FileNotFoundError:
                pass
        self.snapshots_taken += 1
        return new

    def _close_fds(self) -> None:
        for fd in (self._wal_fd, self._ledger_fd):
            if fd is not None:
                os.close(fd)
        self._wal_fd = self._ledger_fd = None

    def close(self) -> None:
        self.sync()
        self.ledger_sync()
        self._close_fds()

    def abandon(self) -> None:
        """Simulated process death: drop every un-synced buffer and
        close the fds without writing — on-disk state is exactly what a
        ``kill -9`` at this instant would leave."""
        self._wal_buf.clear()
        self._ledger_buf.clear()
        self._close_fds()

    # --- audit ---------------------------------------------------------

    def read_ledger(self) -> list[dict]:
        """All terminal-ledger records (torn tail truncated in-read)."""
        path = self.dir / self.LEDGER
        if not path.exists():
            return []
        records, _ = read_frames(path.read_bytes(), where=str(path))
        return records

    def load_state(self) -> RecoveredState:
        """Read-only snapshot+tail replay (the audit entry point)."""
        snapshot, tail = self.recover(truncate=False)
        return replay(snapshot, tail)
