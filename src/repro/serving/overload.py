"""Adaptive overload control for the SNN serving engine.

Static defenses (a bounded queue, per-request deadlines, a per-request
retry budget) keep a server *correct* under overload but not
*productive*: once sustained offered load exceeds capacity the queue
pins at ``max_queue``, every admitted request ages toward its deadline
while being served, and goodput collapses into expiry/retry churn —
the metastable failure mode.  :class:`OverloadController` is the
adaptive layer that keeps the pipeline productive through sustained
overload, built from four cooperating mechanisms plus an explicit
circuit-breaker view of the degradation ladder:

**CoDel-style sojourn control (drop-at-dequeue).**  At every batch
formation the controller observes the *standing-queue sojourn* — the
age of the oldest queued request, the requests a priority queue lets
linger — and, CoDel-style, reacts only to its minimum over a sliding
``interval_ms`` window (a transient burst that drains within the
interval resets the state).  When the sojourn stays above
``target_sojourn_ms`` for a full interval the controller enters the
*dropping* state:
batch formation sheds queued requests instead of serving them into
certain SLO misses, at a rate that ramps with the classic
``interval / sqrt(drop_count)`` control law, and — while dropping —
any request already older than the sojourn ceiling
(``max_sojourn_ms``, default ``0.8 * slo_ms``) is shed outright:
serving it would burn capacity on a response that can no longer meet
its SLO.  The state exits as soon as a dequeue minimum falls back
under target.

**AIMD admission (front-door rate limit).**  ``submit()`` consults a
token bucket refilled at ``admit_rate`` requests/s.  Every
``interval_ms`` the rate adapts: multiplicative decrease
(``md_factor``) when the interval saw congestion (CoDel dropping, or
a served request breaching ``slo_ms``), additive increase
(``additive_rps``) otherwise.  Bucket exhaustion alone is *not*
congestion — that is exactly how AIMD probes upward until the latency
signal pushes back, converging on the sustainable rate.  Rejecting at
the front door is the cheap place to say no: the request never
occupies queue memory or a batch slot.

**Priority-aware shedding.**  The rate limiter governs the *low*
class.  High-priority requests (``priority >= high_priority``) bypass
the bucket — they are protected by strict-priority dequeue, CoDel
exemption, and the low class's shedding, and bounded only by the
engine's ``max_queue`` backpressure (plus their own deadlines under a
pure high-priority storm).  They still consume a token when one is
available, and a low-priority admit must leave ``high_reserve``
tokens behind, so the low class yields admission capacity to the high
class first.  Low-priority requests additionally shed
probabilistically at the front door as the queue fills (a RED-style
ramp from ``low_shed_start`` to ``low_shed_full`` occupancy).  Under
5x overload the shed mass concentrates on the low class, which is
what holds high-priority SLO attainment.

**Global retry-token budget.**  Per-request retry budgets multiply
under correlated fault bursts: every batch retries independently and
the retry traffic itself becomes the overload (a retry storm).
:meth:`grant_retry` draws from one global bucket (``retry_budget``
tokens, refilled at ``retry_refill_per_s``) so the *aggregate* retry
rate is bounded no matter how many batches are failing concurrently.

**Determinism.**  The controller owns no clock and no stateful RNG:
every method takes ``now_ms`` from the engine's pluggable clock, and
the only probabilistic decision (the RED shed) hashes a decision
counter through the stateless splitmix64 draw
(:func:`repro.loadgen.arrivals.u01`).  A virtual-clock overload run is
therefore a pure function of (trace, specs, seeds) and replays
bit-identically — the property the ``loadgen/overload-*`` gate rows
and the ``serve --overload-storm`` CI smoke assert.

:class:`LadderBreakers` formalizes the PR 6 degradation ladder as one
circuit breaker per rung: ``closed`` (serving normally), ``open``
(tripped by retry exhaustion / integrity violation at that rung), and
``half_open`` (the deterministic reprobe after ``reprobe_after``
healthy steps readmits trial traffic; the first healthy step closes
the trial, a fault re-opens it).  The states are pure observability
over the engine's existing level/healthy-step mechanics — bit-compatible
with pre-breaker replays — surfaced in ``stats()`` and persisted in
journal snapshots.
"""

from __future__ import annotations

import dataclasses
import math

from repro.loadgen.arrivals import u01

# breaker states (one per degradation rung)
CLOSED = "closed"
OPEN = "open"
HALF_OPEN = "half_open"

# shed-attribution tags (journaled on TERMINAL records, so recovery
# re-derives the shed counters exactly)
SHED_ADMISSION = "adm"       # AIMD token bucket said no at submit()
SHED_LOW_PRIORITY = "lowprio"  # RED occupancy ramp shed a low-prio submit
SHED_CODEL = "codel"         # dropped at dequeue by sojourn control


@dataclasses.dataclass(frozen=True)
class OverloadPolicy:
    """One engine's overload-control law.  Frozen, like the serving
    policy: the controller's runtime state lives in
    :class:`OverloadController`."""
    slo_ms: float = 50.0            # latency target the AIMD loop tracks
    # --- CoDel sojourn control -----------------------------------------
    target_sojourn_ms: float = 5.0  # acceptable standing-queue sojourn
    interval_ms: float = 100.0      # sliding window / AIMD epoch
    max_sojourn_ms: float | None = None  # dequeue age ceiling while
    #                                 dropping (None = 0.8 * slo_ms)
    # --- AIMD admission-rate limiter ------------------------------------
    admit_rps_min: float = 50.0
    admit_rps_max: float = 1e6
    admit_rps_init: float | None = None   # None = start at admit_rps_max
    additive_rps: float = 500.0     # +per clean interval
    md_factor: float = 0.7          # x per congested interval
    burst: float = 64.0             # token-bucket depth
    # --- priority-aware shedding ----------------------------------------
    high_priority: int = 1          # priority >= this is the high class
    high_reserve: float = 8.0       # tokens a low-prio admit must leave
    low_shed_start: float = 0.5     # RED ramp start (queue occupancy)
    low_shed_full: float = 0.9      # occupancy where low class sheds 100%
    # --- global retry budget --------------------------------------------
    retry_budget: float = 32.0      # bucket depth (tokens)
    retry_refill_per_s: float = 8.0
    seed: int = 0xC0DE1             # RED-shed counter-hash seed

    def __post_init__(self):
        for name in ("slo_ms", "target_sojourn_ms", "interval_ms",
                     "admit_rps_min", "admit_rps_max", "additive_rps",
                     "burst", "retry_budget"):
            if getattr(self, name) <= 0:
                raise ValueError(f"{name} must be > 0, got "
                                 f"{getattr(self, name)}")
        if self.max_sojourn_ms is not None and self.max_sojourn_ms <= 0:
            raise ValueError(f"max_sojourn_ms must be > 0 or None, got "
                             f"{self.max_sojourn_ms}")
        if not 0.0 < self.md_factor < 1.0:
            raise ValueError(f"md_factor must be in (0, 1), got "
                             f"{self.md_factor}")
        if self.admit_rps_min > self.admit_rps_max:
            raise ValueError("admit_rps_min must be <= admit_rps_max")
        if self.admit_rps_init is not None and not (
                self.admit_rps_min <= self.admit_rps_init
                <= self.admit_rps_max):
            raise ValueError("admit_rps_init must lie in "
                             "[admit_rps_min, admit_rps_max]")
        if not 0.0 <= self.low_shed_start < self.low_shed_full <= 1.0:
            raise ValueError("need 0 <= low_shed_start < low_shed_full "
                             "<= 1")
        if self.high_reserve < 0 or self.retry_refill_per_s < 0:
            raise ValueError("high_reserve and retry_refill_per_s must "
                             "be >= 0")

    @property
    def sojourn_limit_ms(self) -> float:
        """The dequeue age ceiling enforced while dropping."""
        return (self.max_sojourn_ms if self.max_sojourn_ms is not None
                else 0.8 * self.slo_ms)


def storm_policy(base_rps: float) -> OverloadPolicy:
    """The overload-bench / CI-storm policy, scaled to a known
    ~sustainable rate (the committed trace's recorded 1x rate).  Tuned
    for the virtual-clock service model: a ~20 ms control interval is
    ~15-20 serving steps, the limiter starts at 2x base (so the 5x
    storm exercises a real AIMD descent), and the sojourn ceiling sits
    under the 50 ms run SLO so CoDel sheds zombies instead of serving
    them.  Shared by :mod:`benchmarks.loadgen_bench` and
    ``serve --overload-storm`` so the gate rows and the CI smoke run
    the identical control law."""
    return OverloadPolicy(
        slo_ms=50.0, target_sojourn_ms=8.0, interval_ms=20.0,
        max_sojourn_ms=30.0, admit_rps_min=base_rps / 4.0,
        admit_rps_max=base_rps * 8.0, admit_rps_init=base_rps * 2.0,
        additive_rps=base_rps / 8.0, md_factor=0.7, burst=64.0,
        high_priority=1, high_reserve=8.0, low_shed_start=0.1,
        low_shed_full=0.5, retry_budget=32.0, retry_refill_per_s=8.0)


class OverloadController:
    """Runtime state of one engine's overload control (see the module
    docstring).  Every method takes ``now_ms`` explicitly — the
    controller never reads a clock — and all state serializes through
    :meth:`state_dict` for journal snapshots."""

    def __init__(self, policy: OverloadPolicy | None = None):
        self.policy = p = (policy if policy is not None
                           else OverloadPolicy())
        self.admit_rate = (p.admit_rps_init if p.admit_rps_init
                           is not None else p.admit_rps_max)
        self._tokens = p.burst
        self._t_tokens_ms: float | None = None
        self._interval_start_ms: float | None = None
        self._congested = False
        # CoDel state
        self._first_above_ms: float | None = None
        self.dropping = False
        self._drop_next_ms = 0.0
        self._drop_count = 0
        # retry budget
        self.retry_tokens = p.retry_budget
        self._t_retry_ms: float | None = None
        # counters (decisions doubles as the stateless RED-draw counter)
        self.decisions = 0
        self.md_events = 0
        self.ai_events = 0
        self.codel_entries = 0

    # --- AIMD epoch ------------------------------------------------------

    def _tick(self, now_ms: float) -> None:
        """Roll the AIMD interval if it elapsed: one rate adjustment per
        epoch, congestion-flag reset."""
        if self._interval_start_ms is None:
            self._interval_start_ms = now_ms
            return
        if now_ms - self._interval_start_ms < self.policy.interval_ms:
            return
        if self._congested or self.dropping:
            self.admit_rate = max(self.policy.admit_rps_min,
                                  self.admit_rate * self.policy.md_factor)
            self.md_events += 1
        else:
            self.admit_rate = min(self.policy.admit_rps_max,
                                  self.admit_rate
                                  + self.policy.additive_rps)
            self.ai_events += 1
        self._congested = False
        self._interval_start_ms = now_ms

    def _refill(self, now_ms: float) -> None:
        if self._t_tokens_ms is None:
            self._t_tokens_ms = now_ms
        dt = max(0.0, now_ms - self._t_tokens_ms)
        self._tokens = min(self.policy.burst,
                           self._tokens + dt * self.admit_rate / 1e3)
        self._t_tokens_ms = now_ms

    # --- front door ------------------------------------------------------

    def admit(self, priority: int, queue_len: int,
              max_queue: int | None, now_ms: float
              ) -> tuple[bool, str | None]:
        """One admission decision.  Returns ``(admitted, shed_tag)`` —
        the tag (:data:`SHED_ADMISSION` / :data:`SHED_LOW_PRIORITY`)
        attributes a rejection for counters and the journal.  The high
        class bypasses the limiter (consuming a token when one exists,
        so the low class yields first); the low class pays the RED
        occupancy ramp and must leave ``high_reserve`` tokens."""
        p = self.policy
        self._tick(now_ms)
        self._refill(now_ms)
        self.decisions += 1
        if priority >= p.high_priority:
            self._tokens = max(0.0, self._tokens - 1.0)
            return True, None
        if max_queue:
            occ = queue_len / max_queue
            if occ >= p.low_shed_start:
                frac = ((occ - p.low_shed_start)
                        / (p.low_shed_full - p.low_shed_start))
                if u01(p.seed, 1, self.decisions) < min(1.0, frac):
                    return False, SHED_LOW_PRIORITY
        if self._tokens < 1.0 + p.high_reserve:
            # NOT a congestion signal: the limiter binding is how AIMD
            # probes upward; only latency pushes the rate back down
            return False, SHED_ADMISSION
        self._tokens -= 1.0
        return True, None

    # --- dequeue (CoDel) -------------------------------------------------

    def on_dequeue(self, sojourn_ms: float, now_ms: float,
                   backlog: int) -> int:
        """Observe one batch formation's standing-queue sojourn (age of
        the oldest queued request); returns how many requests the sqrt
        control law says to shed now (the engine additionally sheds
        anything older than ``sojourn_limit_ms`` while
        :attr:`dropping`).  The CoDel interval filter is internal: a
        single below-target observation resets the state, so only a
        sojourn persistently above target — the interval *minimum* —
        triggers dropping."""
        p = self.policy
        self._tick(now_ms)
        if sojourn_ms < p.target_sojourn_ms:
            self._first_above_ms = None
            self.dropping = False
            self._drop_count = 0
            return 0
        if self._first_above_ms is None:
            self._first_above_ms = now_ms + p.interval_ms
            return 0
        if not self.dropping:
            if now_ms < self._first_above_ms:
                return 0
            self.dropping = True
            self.codel_entries += 1
            self._congested = True
            self._drop_count = 0
            self._drop_next_ms = now_ms
        self._congested = True
        n = 0
        while self._drop_next_ms <= now_ms and n < backlog:
            n += 1
            self._drop_count += 1
            self._drop_next_ms += (p.interval_ms
                                   / math.sqrt(self._drop_count))
        return n

    # --- serve feedback --------------------------------------------------

    def note_served(self, service_ms: float) -> None:
        """A served request's end-to-end latency: breaching the SLO
        marks the current AIMD interval congested."""
        if service_ms > self.policy.slo_ms:
            self._congested = True

    # --- global retry budget ---------------------------------------------

    def grant_retry(self, now_ms: float) -> bool:
        """Spend one global retry token (refilled at
        ``retry_refill_per_s``); False = the retry storm budget is
        exhausted and the caller must fail fast instead."""
        p = self.policy
        if self._t_retry_ms is None:
            self._t_retry_ms = now_ms
        dt = max(0.0, now_ms - self._t_retry_ms)
        self.retry_tokens = min(p.retry_budget,
                                self.retry_tokens
                                + dt * p.retry_refill_per_s / 1e3)
        self._t_retry_ms = now_ms
        if self.retry_tokens >= 1.0:
            self.retry_tokens -= 1.0
            return True
        return False

    # --- persistence -----------------------------------------------------

    def state_dict(self) -> dict:
        """JSON-ready controller state for journal snapshots."""
        return {"admit_rate": self.admit_rate, "tokens": self._tokens,
                "t_tokens_ms": self._t_tokens_ms,
                "interval_start_ms": self._interval_start_ms,
                "congested": self._congested,
                "first_above_ms": self._first_above_ms,
                "dropping": self.dropping,
                "drop_next_ms": self._drop_next_ms,
                "drop_count": self._drop_count,
                "retry_tokens": self.retry_tokens,
                "t_retry_ms": self._t_retry_ms,
                "decisions": self.decisions,
                "md_events": self.md_events,
                "ai_events": self.ai_events,
                "codel_entries": self.codel_entries}

    def load_state(self, d: dict) -> None:
        """Adopt a snapshot's controller state (tolerant: unknown keys
        ignored, missing keys keep their fresh-construction values —
        an old snapshot restores a younger controller, never fails)."""
        for attr, key in (("admit_rate", "admit_rate"),
                          ("_tokens", "tokens"),
                          ("_t_tokens_ms", "t_tokens_ms"),
                          ("_interval_start_ms", "interval_start_ms"),
                          ("_congested", "congested"),
                          ("_first_above_ms", "first_above_ms"),
                          ("dropping", "dropping"),
                          ("_drop_next_ms", "drop_next_ms"),
                          ("_drop_count", "drop_count"),
                          ("retry_tokens", "retry_tokens"),
                          ("_t_retry_ms", "t_retry_ms"),
                          ("decisions", "decisions"),
                          ("md_events", "md_events"),
                          ("ai_events", "ai_events"),
                          ("codel_entries", "codel_entries")):
            if key in d:
                setattr(self, attr, d[key])


class LadderBreakers:
    """Explicit closed/open/half-open circuit-breaker state, one per
    degradation-ladder rung.  Pure observability over the engine's
    level / healthy-step mechanics (which stay the source of truth, so
    pre-breaker replays are bit-identical): retry exhaustion or an
    integrity violation at rung R *opens* R, the deterministic reprobe
    (``policy.reprobe_after`` healthy steps) *half-opens* every open
    rung while the engine trials rung 0, and the next healthy step
    *closes* the trial; a fault during the trial re-opens its rung."""

    def __init__(self, n_rungs: int, states: list[str] | None = None):
        if n_rungs < 1:
            raise ValueError(f"n_rungs must be >= 1, got {n_rungs}")
        self.n_rungs = n_rungs
        self._states = [CLOSED] * n_rungs
        self.trips = 0
        self.reprobes = 0
        if states:
            for i, s in enumerate(states[:n_rungs]):
                if s in (CLOSED, OPEN, HALF_OPEN):
                    self._states[i] = s

    def open_rung(self, rung: int) -> None:
        """The ladder stepped down off ``rung``: trip its breaker."""
        if 0 <= rung < self.n_rungs and self._states[rung] != OPEN:
            self._states[rung] = OPEN
            self.trips += 1

    def half_open_all(self) -> None:
        """Deterministic reprobe: every tripped rung admits trial
        traffic (the engine resets to rung 0)."""
        changed = False
        for i, s in enumerate(self._states):
            if s == OPEN:
                self._states[i] = HALF_OPEN
                changed = True
        if changed:
            self.reprobes += 1

    def close_trials(self) -> None:
        """A healthy step landed: the half-open trials passed."""
        for i, s in enumerate(self._states):
            if s == HALF_OPEN:
                self._states[i] = CLOSED

    def states(self) -> list[str]:
        return list(self._states)

    def __repr__(self) -> str:
        return (f"LadderBreakers({'/'.join(self._states)}, "
                f"trips={self.trips})")
