"""Token samplers for the serving engine."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits: jnp.ndarray) -> jnp.ndarray:
    """logits [B, V] -> tokens int32[B]."""
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def temperature(key, logits: jnp.ndarray, temp: float = 1.0,
                top_k: int = 0) -> jnp.ndarray:
    """Temperature (+ optional top-k) sampling.  logits [B, V] -> [B]."""
    l = logits / max(temp, 1e-6)
    if top_k > 0:
        vals, _ = jax.lax.top_k(l, top_k)
        cutoff = vals[:, -1:]
        l = jnp.where(l < cutoff, -jnp.inf, l)
    return jax.random.categorical(key, l, axis=-1).astype(jnp.int32)
