"""SNN request serving: queue + dynamic window batching over the engine.

The transformer path batches decode steps over KV-cache slots
(:mod:`repro.serving.engine`); the SNN path batches whole presentation
windows.  :class:`SNNServingEngine` keeps a request queue and, per
engine step, admits up to ``plan.max_batch`` requests, pads their
(possibly ragged) windows into one uint32[B, T, w] batch, and serves
them with a single :meth:`SNNEngine.infer` launch — sharded over the
plan's neuron mesh when one is present, so population-sharded serving
and request batching compose.

Ragged batching is bit-exact by construction: windows are zero-padded on
the time axis, and a zero spike row adds no input counts while the
membrane only leaks — with ``threshold >= 1`` a neuron that did not fire
in the true window cannot fire in a padded cycle (after any cycle
``v < threshold``), so padded cycles contribute no spikes.  The batch
axis is likewise padded with all-zero windows (their counts are
discarded), which pins the launch shape to ``(max_batch, T_q, w)`` with
``T_q`` rounded up to the time quantum — one compile per window-length
bucket instead of one per ragged batch shape.
"""

from __future__ import annotations

import dataclasses
from collections import deque

import jax.numpy as jnp
import numpy as np

from repro.engine import SNNEngine, SNNEnginePlan

_T_QUANTUM = 8   # window lengths bucket to multiples of this (or t_chunk)


@dataclasses.dataclass
class SNNRequest:
    """One classification request: a packed spike window in, counts out."""
    rid: int
    window: np.ndarray               # uint32[T, w] packed spike window
    counts: np.ndarray | None = None  # int32[n] spike counts (result)
    pred: int | None = None           # argmax class (if classes known)
    done: bool = False


class SNNServingEngine:
    """Dynamic window batching over :meth:`SNNEngine.infer`.

    weights: uint32[n, w] frozen population weights; ``neuron_class``
    (int[n], optional) maps the maximally-firing neuron to a class label
    for ``req.pred``.  Admission, padding and launch shape come from the
    plan (``max_batch``, ``t_chunk``, placement).
    """

    def __init__(self, weights, plan: SNNEnginePlan, *,
                 neuron_class=None):
        if plan.threshold < 1:
            raise ValueError("SNN serving requires threshold >= 1 "
                             "(zero-padded cycles must stay silent)")
        self.engine = SNNEngine(plan)
        self.weights = jnp.asarray(weights, jnp.uint32)
        self.neuron_class = (None if neuron_class is None
                             else np.asarray(neuron_class))
        self.words = int(self.weights.shape[1])
        self.queue: deque[SNNRequest] = deque()
        self.steps = 0
        self.batches = 0
        self.windows_served = 0

    # --- admission -----------------------------------------------------

    def submit(self, req: SNNRequest) -> None:
        window = np.asarray(req.window, np.uint32)
        if window.ndim != 2 or window.shape[1] != self.words:
            raise ValueError(f"request {req.rid}: window must be "
                             f"uint32[T, {self.words}], got "
                             f"{window.shape}")
        req.window = window
        self.queue.append(req)

    def _t_quantum(self) -> int:
        tc = self.engine.plan.t_chunk
        return tc if tc is not None else _T_QUANTUM

    # --- serve ---------------------------------------------------------

    def step(self) -> int:
        """Admit + serve one batch.  Returns requests completed."""
        plan = self.engine.plan
        batch: list[SNNRequest] = []
        while self.queue and len(batch) < plan.max_batch:
            batch.append(self.queue.popleft())
        if not batch:
            return 0
        q = self._t_quantum()
        t_max = max(r.window.shape[0] for r in batch)
        t_pad = -(-t_max // q) * q
        stacked = np.zeros((plan.max_batch, t_pad, self.words),
                           np.uint32)
        for i, r in enumerate(batch):
            stacked[i, :r.window.shape[0]] = r.window
        counts = np.asarray(
            self.engine.infer(self.weights, jnp.asarray(stacked)))
        for i, r in enumerate(batch):
            r.counts = counts[i]
            if self.neuron_class is not None:
                r.pred = int(self.neuron_class[int(np.argmax(counts[i]))])
            r.done = True
        self.steps += 1
        self.batches += 1
        self.windows_served += len(batch)
        return len(batch)

    def run(self, requests: list[SNNRequest], max_steps: int = 10_000
            ) -> list[SNNRequest]:
        for r in requests:
            self.submit(r)
        steps = 0
        while any(not r.done for r in requests) and steps < max_steps:
            if self.step() == 0:
                break
            steps += 1
        return requests
