"""SNN request serving: queue + dynamic window batching over the engine.

The transformer path batches decode steps over KV-cache slots
(:mod:`repro.serving.engine`); the SNN path batches whole presentation
windows.  :class:`SNNServingEngine` keeps a request queue and, per
engine step, admits up to ``plan.max_batch`` requests, pads their
(possibly ragged) windows into one batch, and serves them with a single
:meth:`SNNEngine.infer` launch — sharded over the plan's neuron mesh
when one is present, so population-sharded serving and request batching
compose.

Requests come in two shapes:

* **pre-packed**: a ``uint32[T, w]`` spike window (the original form);
* **intensity**: ``uint8[n_in]`` pixel intensities + ``n_steps`` (+ an
  optional counter ``seed``, default derived from the request id).  The
  queue then holds ``n_in`` bytes instead of ``T*w*4`` (~T/8x smaller),
  and when the plan says ``encode="kernel"`` the spike window *never*
  exists — the serve launch draws it in VMEM from the counter hash.
  Both placements are bit-exact with ``encoder.encode_from_counter``,
  so mixed batches (host-encoded on admission) return identical counts.

Ragged batching is bit-exact by construction: windows are zero-padded on
the time axis, and a zero spike row adds no input counts while the
membrane only leaks — with ``threshold >= 1`` a neuron that did not fire
in the true window cannot fire in a padded cycle (after any cycle
``v < threshold``), so padded cycles contribute no spikes.  The batch
axis is likewise padded (zero windows / zero intensities — silent by the
same argument), which pins the launch shape to ``(max_batch, T_q, ...)``
with ``T_q`` rounded up to the time quantum — one compile per
window-length bucket instead of one per ragged batch shape.  The
intensity path additionally carries each sample's true length as a
traced SMEM operand, so raggedness itself never retraces.

Failure semantics
-----------------

Serving is fault-tolerant end to end: no exception escapes ``step()``
or ``run()``, and every submitted request terminates in exactly one
terminal status.

**Status machine.**  A fresh request is ``NEW``; ``submit()`` moves it
to ``QUEUED`` or — structurally, without raising — ``REJECTED``
(malformed request, or backpressure when the queue is at
``policy.max_queue``).  Batch formation drops queued requests whose
``deadline_ms`` has elapsed as ``EXPIRED`` and pulls the survivors
highest-priority-first (FIFO within a priority).  A serve launch then
ends each batched request as ``SERVED`` (counts attached) or, when
every retry and degradation rung is exhausted, ``FAILED`` with the
last error recorded.  ``SERVED | REJECTED | EXPIRED | FAILED`` are
terminal.

**Degradation ladder.**  Every kernel path has a bit-exact host/ref
oracle, which makes graceful degradation free of result drift: on
repeated launch failure the engine steps down
``plan → encode="host" → kernel_backend="ref"`` (deduplicated; each
rung re-runs the full retry budget).  Rung changes are recorded in
``degradation_events``; after ``policy.reprobe_after`` consecutive
healthy steps the engine re-probes the fast path from rung 0.

**Integrity guard.**  A served count vector must satisfy
``0 <= counts <= t_total`` per slot (a neuron cannot spike more than
once per cycle).  Violating slots are re-served on the most-degraded
oracle rung with the ``on_launch`` hook bypassed, so injected
corruption can never propagate into a ``SERVED`` result.  A periodic
known-answer canary (every ``policy.canary_every`` steps) re-serves a
fixed window through the *current* rung and compares against golden
ref-path counts, catching in-range corruption the guard cannot.

**Version lifecycle (train-while-serving).**  With a
:class:`repro.serving.weights.SNNWeightRefresher` attached, weights
live in a :class:`repro.serving.weights.VersionedWeightStore` and move
through ``candidate -> probed -> promoted -> (rolled-back)``:

* **candidate** — every ``refresh_every`` serving steps the refresher
  trains a new bank from the serving weights (STDP over the next
  refresh-stream slice, epoch-keyed counter seeds) and *stages* it
  under a fresh monotonic version number.  Staged versions are never
  visible to traffic.
* **probed** — the candidate must first re-verify the content
  fingerprint taken at production time (a corrupted or torn candidate
  is rejected deterministically, before any accuracy math), then beat
  the serving bank on the fixed held-out probe set within the policy's
  ``max_regression``.  Rejections only increment counters.
* **promoted** — a passing candidate is persisted through the atomic
  :class:`repro.checkpoint.CheckpointManager` (tmp-dir + rename; a
  crash mid-save leaves a ``.tmp`` dropping and aborts the promotion)
  and *queued* for swap.  The swap itself happens only between serving
  steps: each ``step()`` pins the serving version before forming its
  batch, so in-flight windows always finish on the bank they launched
  with — a half-written or mid-swap bank is unobservable by
  construction.  Every ``SERVED`` request records ``served_version``.
* **rolled-back** — if the probe later shows the *serving* bank
  regressed, or the known-answer canary fails right after a refresh
  promotion, the store demotes it and re-reads the previous promoted
  version from disk (bit-exact with its checkpoint).  Demoted versions
  are never served again; a process restart restores the newest
  *complete* on-disk version instead of the seed weights.

Load model
----------

The engine is **closed-loop-agnostic**: it serves whatever its queue
holds, and the *submitter* defines the load model.  The legacy
``run()`` loop is closed-loop — it feeds the queue as fast as
``step()`` drains it, so it can say nothing about behavior at a given
offered rate.  :mod:`repro.loadgen` drives the same engine
**open-loop**: request arrival times come from a seeded arrival
process fixed before the run, independent of how fast the server
drains — the regime in which offered-load vs latency curves and
maximum-sustainable-throughput numbers are meaningful.

**Coordinated omission.**  All latency is measured from the request's
*intended* arrival time, not from when the submitter got around to
calling ``submit()``: ``submit()`` honors a pre-stamped
``t_submit_ms`` (the loadgen runner sets it to the arrival-process
timestamp), so a backed-up server accrues the queueing delay it
caused instead of silently re-timing the arrival stream.  Time itself
is read through the engine's pluggable ``clock``
(:class:`ServingClock` — wall by default; loadgen substitutes a
deterministic virtual clock whose serving steps cost a modeled
duration, making per-status totals and histogram buckets bit-identical
across replays of the same trace).

**SLO.**  A request meets its SLO when it ends ``SERVED`` within its
own ``deadline_ms`` (or the run-level SLO target for requests
without one), end-to-end from intended arrival.  Attainment is
reported over *offered* requests: rejects, expiries and failures all
count against it.

**Latency accounting.**  Queue-wait (submit → batch formation) and
service (submit → terminal) latencies live in fixed-size mergeable
log-bucketed histograms (:class:`repro.loadgen.histogram.LatencyHistogram`,
~1.6% worst-case bucket error), not per-request lists — memory stays
flat at millions of requests and ``stats()`` percentiles are O(buckets),
while staying nearest-rank-compatible with the committed
``serve/latency-*`` gate rows.

Overload model
--------------

The static defenses above (bounded queue, deadlines, per-request
retries) keep overload *correct* but not *productive*: sustained
offered load past capacity pins the queue at ``max_queue`` and every
admitted request ages toward its deadline while being served — goodput
collapses into expiry churn (the metastable failure mode).  Passing
``overload=OverloadPolicy(...)`` attaches an
:class:`repro.serving.overload.OverloadController` that keeps the
pipeline productive through sustained overload.  Its state machine and
shedding order, in pipeline position:

1. **AIMD admission** (``submit()``): a token bucket refilled at an
   adaptive ``admit_rate`` sheds excess arrivals at the front door
   (status ``REJECTED``, tagged ``shed="adm"``) — the cheapest place
   to say no.  Every ``interval_ms`` the rate multiplicatively
   decreases if the interval saw congestion (CoDel dropping or a
   served latency over ``slo_ms``) and additively increases otherwise;
   bucket exhaustion alone never counts as congestion, which is how
   the rate probes up to capacity.
2. **Priority-aware shed** (``submit()``): low-priority requests
   (``priority < high_priority``) additionally shed probabilistically
   as queue occupancy rises (RED-style ramp, tagged ``"lowprio"``, a
   stateless counter-hash draw) and must leave ``high_reserve``
   admission tokens for the high class.  Under overload the shed mass
   concentrates on the low class, holding high-priority SLO
   attainment.
3. **CoDel drop-at-dequeue** (``_form_batch``): the controller tracks
   the *standing-queue* sojourn — the age of the oldest queued
   request, which in a priority queue is the lingering low-priority
   tail; when it stays above ``target_sojourn_ms`` for a full
   ``interval_ms`` the controller
   enters its *dropping* state and batch formation sheds queued
   low-priority requests (status ``EXPIRED``, tagged ``"codel"``) —
   the ``interval/sqrt(n)`` control law plus everything older than the
   sojourn ceiling — instead of serving requests into certain SLO
   misses.  High-priority requests are never CoDel-shed.
4. **Global retry budget** (``_launch_with_recovery``): retries draw
   from one bucket (``retry_budget`` tokens at ``retry_refill_per_s``)
   so correlated fault bursts cannot amplify into retry storms;
   denials count ``retries_denied`` and fail the batch fast.

The degradation ladder doubles as explicit **circuit breakers**
(:class:`repro.serving.overload.LadderBreakers`): rung R's breaker
*opens* when the engine degrades off R, every open breaker goes
*half-open* at the deterministic reprobe (trial traffic at rung 0),
and the next fault-free step *closes* the trials.  Breaker states ride
in ``stats()`` and journal snapshots; the level/healthy-step counters
remain the behavioral source of truth, so pre-breaker replays are
bit-identical.

All controller decisions read the engine clock and a stateless
splitmix64 counter hash — no wall time, no stateful RNG — so
virtual-clock overload runs replay bit-identically (asserted by the
``loadgen/overload-*`` bench rows and ``serve --overload-storm``).

Crash consistency
-----------------

With ``journal_dir`` set, the engine writes every request lifecycle
transition through a :class:`repro.serving.journal.RequestJournal` —
an append-only, CRC-framed write-ahead log with periodic engine-state
snapshots — so process death (kill -9, power loss, an injected
``os._exit``) never loses admitted work or breaks the "every request
reaches a terminal, attributable status" invariant.

**Journal format.**  Three WAL event kinds: ``ADMIT`` (rid, intended
arrival, priority, effective deadline, payload content hash, and the
payload *descriptor* — the loadgen trace row when one rides on the
request, else the inline payload), ``DISPATCH`` (one batch's rids +
pinned weight version + pad waste), ``TERMINAL`` (status,
served_version, queue-wait/service latency, content hash).  Snapshots
capture the full engine state — queue contents as ADMIT records,
robustness counters, latency histograms via their JSON round-trip, the
degradation rung, and the live weight version — then rotate the WAL
(old segment deleted), bounding recovery work.  A separate append-only
``ledger.log`` records one entry per terminal request and is never
truncated: it is the cross-restart exactly-once audit substrate.

**Durability points (group commit).**  ADMIT records buffer at
``submit()`` and are fsync'd together with the DISPATCH record before
the serve launch; TERMINAL records are fsync'd at step end.  A ledger
entry is appended only *after* its WAL terminal is durable, so the
ledger never runs ahead of the WAL.

**Recovery invariants.**  Constructing an engine over an existing
``journal_dir`` replays snapshot + WAL tail: a torn *final* record is
physically truncated (it was never acknowledged), a CRC-corrupt
*mid-log* record fails loudly (acknowledged state rotted), and a
``snapshot_N.json.tmp`` dropping from a crash mid-snapshot is ignored
(the previous snapshot + full log win).  Counters and histograms
resume from the replayed state; every ADMIT without a TERMINAL is
re-queued idempotently — trace-backed payloads re-materialize from the
row's seeds and are verified against the recorded content hash — and
the virtual clock resumes from the journal's time high-water mark.
The live weight version is reconciled against
:class:`~repro.serving.weights.VersionedWeightStore`'s own restart
path (newest complete checkpoint wins; a disagreement only counts
``version_reconciliations``).  ``journal_resume_offset`` (one past the
highest journaled rid) lets a replayed trace run continue where the
dead process stopped instead of re-offering from row 0.

**Exactly-once argument.**  A rid is re-queued only when its WAL
TERMINAL is missing; a ledger entry exists only when that WAL terminal
was durable first.  Therefore a crashed-then-recovered request can
never acquire two ledger entries: either its terminal was durable (it
is *not* re-queued) or it was not (no ledger entry exists, and the
re-serve writes the only one).  Replayed requests keep their original
rids and content hashes, so the kill–restart chaos harness
(``serve --chaos``) can audit zero lost ADMITs and zero duplicate
SERVEs by content hash across any number of crashes.

**Observability.**  ``stats()`` reports rejected / expired / failed /
retried / degraded / integrity-failure / canary counters plus
per-request queue-wait and service latency p50/p99 — surfaced by
``repro.launch.serve --arch wenquxing-snn --bench``.  Versioned
serving adds the store counters (weight_version, versions promoted /
rejected, rollbacks, save_crashes) and refresh-path counters
(refresh_runs / refresh_rejected / refresh_corrupt / refresh_timeouts
/ refresh_failed, probe_accuracy, version_violations — the latter must
stay 0: every served response is attributable to a version that was
promoted and live at serve time).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax.numpy as jnp
import numpy as np

from repro.core.encoder import encode_from_counter
from repro.engine import SNNEngine, SNNEnginePlan
from repro.kernels import ops
from repro.loadgen.histogram import LatencyHistogram
from repro.serving.journal import (_COUNTER_KEYS, RequestJournal, RingLog,
                                   replay)
from repro.serving.overload import (SHED_CODEL, LadderBreakers,
                                    OverloadController, OverloadPolicy)
from repro.serving.weights import SNNWeightRefresher, VersionedWeightStore

_T_QUANTUM = 8   # window lengths bucket to multiples of this (or t_chunk)
_ERR_MAX = 256   # per-request error strings are capped at this length
_EVENT_RING = 256  # degradation/refresh telemetry kept in memory

# --- request lifecycle -------------------------------------------------------

QUEUED = "QUEUED"
SERVED = "SERVED"
REJECTED = "REJECTED"
EXPIRED = "EXPIRED"
FAILED = "FAILED"
TERMINAL_STATUSES = frozenset({SERVED, REJECTED, EXPIRED, FAILED})

_CANARY_SEED = 0xC0FFEE


def _now_ms() -> float:
    return time.perf_counter() * 1e3


def _cap_error(error: str | None) -> str | None:
    """Bound per-request error strings (millions of FAILED requests
    must not grow memory — or the journal — unboundedly)."""
    if error is not None and len(error) > _ERR_MAX:
        return error[:_ERR_MAX] + "...[truncated]"
    return error


class ServingClock:
    """The engine's time source (milliseconds).  The default is the
    wall clock; :mod:`repro.loadgen.runner` substitutes virtual clocks
    that skip idle gaps and (in deterministic mode) charge serving
    steps a modeled cost via :meth:`advance_service_ms` — a no-op here
    because wall time advances by itself during the launch."""

    def now_ms(self) -> float:
        return _now_ms()

    def advance_service_ms(self, batch_size: int, t_pad: int,
                           inflation: float = 1.0) -> None:
        pass

    def advance_ms(self, ms: float) -> None:
        """Charge a non-launch delay (retry backoff).  A no-op on the
        wall clock on purpose: stalling the serving loop in a sleep is
        exactly the pathology the pluggable clock removes — virtual
        clocks charge the delay to modeled time instead."""


@dataclasses.dataclass
class SNNRequest:
    """One classification request: spikes (or intensities) in, counts out."""
    rid: int
    window: np.ndarray | None = None   # uint32[T, w] packed spike window
    intensities: np.ndarray | None = None  # uint8[n_in] (with n_steps)
    n_steps: int | None = None         # presentation length (intensity form)
    seed: int | None = None            # counter seed (default: from rid)
    priority: int = 0                  # higher pulled into batches first
    deadline_ms: float | None = None   # queue-relative deadline (None = policy's)
    # --- lifecycle (written by the serving engine) ----------------------
    status: str = "NEW"                # NEW -> QUEUED -> terminal
    error: str | None = None           # rejection / failure detail
    retries: int = 0                   # launch re-attempts this request rode
    counts: np.ndarray | None = None   # int32[n] spike counts (result)
    pred: int | None = None            # argmax class (if classes known)
    done: bool = False                 # terminal-status flag
    queue_wait_ms: float | None = None  # submit -> batch formation
    service_ms: float | None = None     # submit -> terminal
    t_submit_ms: float | None = None    # perf_counter stamp at admission
    served_version: int | None = None   # weight version the counts came from
    trace_row: dict | None = None       # loadgen row (journal descriptor)
    content_sha: str | None = None      # payload content hash (audit key)
    shed: str | None = None             # overload shed tag (adm/lowprio/codel)

    @property
    def terminal(self) -> bool:
        return self.status in TERMINAL_STATUSES


@dataclasses.dataclass(frozen=True)
class SNNServingPolicy:
    """Admission + recovery policy consulted at submit, batch-formation
    and launch time.  Frozen, like the plan: one policy per engine."""
    max_queue: int | None = None       # backpressure bound (None = unbounded)
    deadline_ms: float | None = None   # default deadline for requests without one
    max_retries: int = 2               # re-launches per degradation rung
    retry_backoff_ms: float = 0.0      # base sleep between retries (doubles)
    degrade_on_failure: bool = True    # step down the ladder on retry exhaustion
    degrade_on_integrity: bool = True  # ... and on guard / canary violations
    reprobe_after: int | None = None   # healthy steps before re-probing rung 0
    canary_every: int = 0              # steps between known-answer checks (0 = off)
    canary_steps: int = 8              # canary window length

    def __post_init__(self):
        if self.max_queue is not None and self.max_queue < 1:
            raise ValueError(f"max_queue must be >= 1 or None, got "
                             f"{self.max_queue}")
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got "
                             f"{self.max_retries}")
        if self.retry_backoff_ms < 0:
            raise ValueError(f"retry_backoff_ms must be >= 0, got "
                             f"{self.retry_backoff_ms}")
        if self.reprobe_after is not None and self.reprobe_after < 1:
            raise ValueError(f"reprobe_after must be >= 1 or None, got "
                             f"{self.reprobe_after}")
        if self.canary_every < 0:
            raise ValueError(f"canary_every must be >= 0, got "
                             f"{self.canary_every}")
        if self.canary_steps < 1:
            raise ValueError(f"canary_steps must be >= 1, got "
                             f"{self.canary_steps}")


def degradation_ladder(plan: SNNEnginePlan) -> list[SNNEnginePlan]:
    """The graceful-degradation rungs for a plan, fastest first: the
    plan itself, then host encode, then the ref (host oracle) backend —
    each provably bit-exact with the previous, adjacent duplicates
    removed (a host+ref plan has nowhere to degrade to)."""
    ladder = [plan]
    host = dataclasses.replace(plan, encode="host")
    if host != ladder[-1]:
        ladder.append(host)
    ref = dataclasses.replace(ladder[-1], kernel_backend="ref")
    if ref != ladder[-1]:
        ladder.append(ref)
    return ladder


class SNNServingEngine:
    """Dynamic window batching over :meth:`SNNEngine.infer`.

    weights: uint32[n, w] frozen population weights; ``neuron_class``
    (int[n], optional) maps the maximally-firing neuron to a class label
    for ``req.pred``.  Admission, padding, encode placement and launch
    shape come from the plan (``max_batch``, ``t_chunk``, ``encode``,
    placement); failure handling comes from the ``policy`` (see the
    module docstring's failure-semantics section).  ``on_launch``, when
    given, is consulted before every serve/canary launch (the fault
    injection hook — :mod:`repro.serving.faults`); the production path
    is untouched when it is None.

    ``refresher`` (optional) turns on train-while-serving: every
    ``refresher.policy.refresh_every`` steps the engine runs one
    probe-gated refresh cycle between batches (see the module
    docstring's version-lifecycle section).  ``state_dir`` (optional,
    independent of the refresher) persists promoted versions through
    the atomic checkpoint manager; constructing an engine over an
    existing ``state_dir`` restores the newest complete version
    instead of ``weights``.
    """

    def __init__(self, weights, plan: SNNEnginePlan, *,
                 neuron_class=None, policy: SNNServingPolicy | None = None,
                 on_launch: Callable[[dict], object] | None = None,
                 refresher: SNNWeightRefresher | None = None,
                 state_dir=None, keep_versions: int = 4,
                 clock: ServingClock | None = None,
                 journal_dir=None, snapshot_every: int = 256,
                 overload: OverloadPolicy | None = None):
        if plan.threshold < 1:
            raise ValueError("SNN serving requires threshold >= 1 "
                             "(zero-padded cycles must stay silent)")
        self.plan = plan
        self.policy = policy if policy is not None else SNNServingPolicy()
        self.on_launch = on_launch
        self.refresher = refresher
        self.clock = clock if clock is not None else ServingClock()
        self._plans = degradation_ladder(plan)
        self._engines: dict[int, SNNEngine] = {0: SNNEngine(plan)}
        self.engine = self._engines[0]
        self._store = VersionedWeightStore(weights, state_dir=state_dir,
                                           keep=keep_versions)
        self._pinned = self._store.serving
        self.words = int(self.weights.shape[1])
        self.n_inputs = self.words * 32
        if neuron_class is None:
            self.neuron_class = None
        else:
            nc = np.asarray(neuron_class)
            n = int(self.weights.shape[0])
            if nc.ndim != 1 or nc.shape[0] != n:
                raise ValueError(f"neuron_class must be a 1-D array of "
                                 f"length n={n} (one label per neuron), "
                                 f"got shape {nc.shape}")
            self.neuron_class = nc
        self.queue: list[SNNRequest] = []
        # --- throughput counters ---------------------------------------
        self.steps = 0
        self.batches = 0
        self.windows_served = 0
        self.slots_offered = 0      # max_batch per launch
        self.slots_padded = 0       # offered - admitted (batch-pad waste)
        self.step_seconds = 0.0     # total serve wall-clock
        self.last_step_seconds = 0.0
        # --- robustness counters ---------------------------------------
        self.rejected = 0
        self.expired = 0
        self.failed = 0
        self.retried = 0            # launch re-attempts (all rungs)
        self.degraded = 0           # ladder steps taken
        self.integrity_failures = 0
        self.canary_checks = 0
        self.canary_failures = 0
        self.level = 0              # current degradation rung
        self.healthy_steps = 0      # fault-free steps at this rung
        self.degradation_events = RingLog(cap=_EVENT_RING)
        # --- overload control (None = static defenses only) -------------
        self.overload = (OverloadController(overload)
                         if overload is not None else None)
        self.breakers = LadderBreakers(len(self._plans))
        self.shed_admission = 0     # AIMD front-door sheds
        self.shed_low_priority = 0  # RED occupancy-ramp sheds
        self.shed_codel = 0         # sojourn-control dequeue drops
        self.retries_denied = 0     # global retry-budget denials
        self._foreign_counters: dict[str, int] = {}  # future-schema keys
        self.queue_wait_hist = LatencyHistogram()
        self.service_hist = LatencyHistogram()
        self.submitted = 0          # every submit() call, admitted or not
        self._t_first_ms: float | None = None   # first submit, clock time
        self._t_last_ms: float | None = None    # last completed step
        self._step_faults = 0
        self._last_error: str | None = None
        self._canary_window: np.ndarray | None = None
        self._canary_golden: np.ndarray | None = None
        self._canary_version: int | None = None
        # --- versioned-refresh counters --------------------------------
        self.refresh_runs = 0
        self.refresh_rejected = 0     # probe-gate accuracy rejections
        self.refresh_corrupt = 0      # fingerprint-mismatch rejections
        self.refresh_timeouts = 0     # stalled refreshes aborted
        self.refresh_failed = 0       # candidate production / probe died
        self.version_violations = 0   # served from a non-live version
        self.last_probe_accuracy: float | None = None
        self.refresh_events = RingLog(cap=_EVENT_RING)
        self._last_refresh_step = 0
        # --- crash-consistency journal ---------------------------------
        self.journal: RequestJournal | None = None
        self.snapshot_every = int(snapshot_every)
        self.journal_recovered = 0      # requests re-queued at recovery
        self.journal_resume_offset = 0  # trace offset a resumed run uses
        self.version_reconciliations = 0
        self._journal_last_rid = -1
        self._admit_records: dict[int, dict] = {}
        self._pending_ledger: list[dict] = []
        if journal_dir is not None:
            self._recover_from_journal(journal_dir)

    @property
    def weights(self):
        """The serving weight bank (the store's promoted version)."""
        return self._store.serving.weights

    @property
    def store(self) -> VersionedWeightStore:
        return self._store

    # --- admission -----------------------------------------------------

    def _validate(self, req: SNNRequest) -> str | None:
        """Normalize the request's payload in place; return the
        rejection reason (None = admissible)."""
        if (req.window is None) == (req.intensities is None):
            return (f"request {req.rid}: provide exactly one of "
                    "window / intensities")
        if req.window is not None:
            window = np.asarray(req.window, np.uint32)
            if window.ndim != 2 or window.shape[1] != self.words:
                return (f"request {req.rid}: window must be "
                        f"uint32[T, {self.words}], got {window.shape}")
            req.window = window
            return None
        inten = np.asarray(req.intensities, np.uint8)
        if inten.ndim != 1 or inten.shape[0] > self.n_inputs:
            return (f"request {req.rid}: intensities must be "
                    f"uint8[<= {self.n_inputs}], got {inten.shape}")
        if req.n_steps is None or req.n_steps < 1:
            return (f"request {req.rid}: intensity requests need "
                    "n_steps >= 1")
        req.intensities = inten
        if req.seed is None:
            req.seed = self.plan.encode_seed + req.rid
        return None

    def submit(self, req: SNNRequest) -> bool:
        """Admit a request, or reject it *structurally*: a malformed or
        backpressured request ends as ``REJECTED`` with ``error`` set —
        nothing raises, so one bad request can never strand the queue.
        Returns whether the request was admitted."""
        self.submitted += 1
        if self._t_first_ms is None:
            self._t_first_ms = (req.t_submit_ms
                                if req.t_submit_ms is not None
                                else self.clock.now_ms())
        error = self._validate(req)
        if error is None and self.overload is not None:
            ok, tag = self.overload.admit(req.priority, len(self.queue),
                                          self.policy.max_queue,
                                          self.clock.now_ms())
            if not ok:
                req.shed = tag
                if tag == "lowprio":
                    self.shed_low_priority += 1
                    error = (f"request {req.rid}: low-priority shed at "
                             "queue occupancy (overload)")
                else:
                    self.shed_admission += 1
                    error = (f"request {req.rid}: admission rate limit "
                             f"({self.overload.admit_rate:.0f} rps), "
                             "overload shed")
        if error is None and self.policy.max_queue is not None \
                and len(self.queue) >= self.policy.max_queue:
            error = (f"request {req.rid}: queue full "
                     f"(max_queue={self.policy.max_queue}), "
                     "backpressure reject")
        if error is not None:
            req.status, req.error, req.done = REJECTED, _cap_error(error), \
                True
            self.rejected += 1
            if self.journal is not None:
                self._journal_terminal(req, noadmit=True)
            return False
        if req.deadline_ms is None:
            req.deadline_ms = self.policy.deadline_ms
        if req.t_submit_ms is None:    # loadgen pre-stamps intended arrival
            req.t_submit_ms = self.clock.now_ms()
        req.status = QUEUED
        self.queue.append(req)
        if self.journal is not None:
            self._journal_admit(req)
        return True

    def _t_quantum(self) -> int:
        tc = self.plan.t_chunk
        return tc if tc is not None else _T_QUANTUM

    @staticmethod
    def _t_len(req: SNNRequest) -> int:
        return (req.window.shape[0] if req.window is not None
                else req.n_steps)

    def _form_batch(self) -> tuple[list[SNNRequest], int]:
        """Expire overdue queued requests, consult the overload
        controller's sojourn law (drop-at-dequeue), then pull up to
        ``max_batch`` highest-priority-first (stable, so FIFO within a
        priority).  Returns (batch, n_finished_here)."""
        now = self.clock.now_ms()
        live: list[SNNRequest] = []
        n_expired = 0
        for r in self.queue:
            if (r.deadline_ms is not None
                    and now - r.t_submit_ms > r.deadline_ms):
                r.service_ms = now - r.t_submit_ms
                self._finish(r, EXPIRED,
                             f"request {r.rid}: deadline "
                             f"{r.deadline_ms}ms exceeded in queue")
                n_expired += 1
            else:
                live.append(r)
        live.sort(key=lambda r: -r.priority)
        ov = self.overload
        if ov is not None and live:
            sojourn = max(now - r.t_submit_ms for r in live)
            n_drop = ov.on_dequeue(sojourn, now, len(live))
            if ov.dropping:
                # shed low-priority only: everything past the sojourn
                # ceiling (serving it cannot meet the SLO), oldest
                # first, plus what the sqrt control law asks for
                limit = ov.policy.sojourn_limit_ms
                low = sorted((r for r in live
                              if r.priority < ov.policy.high_priority),
                             key=lambda r: r.t_submit_ms)
                aged = [r for r in low if now - r.t_submit_ms > limit]
                fresh = [r for r in low if now - r.t_submit_ms <= limit]
                for r in aged + fresh[:max(0, n_drop - len(aged))]:
                    r.service_ms = now - r.t_submit_ms
                    r.shed = SHED_CODEL
                    self.shed_codel += 1
                    self._finish(r, EXPIRED,
                                 f"request {r.rid}: shed at dequeue by "
                                 "sojourn control (overload)")
                    n_expired += 1
                live = [r for r in live if not r.done]
        batch, self.queue = live[:self.plan.max_batch], \
            live[self.plan.max_batch:]
        return batch, n_expired

    def _finish(self, req: SNNRequest, status: str,
                error: str | None = None) -> None:
        req.status, req.error, req.done = status, _cap_error(error), True
        if status == EXPIRED:
            self.expired += 1
        elif status == FAILED:
            self.failed += 1
        if self.journal is not None:
            self._journal_terminal(req)

    # --- crash-consistency journal -------------------------------------

    def _journal_admit(self, req: SNNRequest) -> None:
        """Buffered ADMIT record (durable at the next dispatch sync).
        Trace-backed requests journal the tiny row descriptor — the
        payload re-materializes from its seeds on recovery — while ad
        hoc requests journal the payload inline."""
        rec = {"ev": "A", "rid": req.rid, "ts": req.t_submit_ms,
               "prio": req.priority, "ddl": req.deadline_ms}
        if req.content_sha is not None:
            rec["sha"] = req.content_sha
        if req.trace_row is not None:
            rec["row"] = req.trace_row
        elif req.intensities is not None:
            rec["payload"] = {"kind": "I",
                              "inten": req.intensities.tolist(),
                              "n_steps": int(req.n_steps),
                              "seed": req.seed}
        else:
            rec["payload"] = {"kind": "W", "t": int(req.window.shape[0]),
                              "win": req.window.reshape(-1).tolist()}
        self.journal.append(rec)
        self._admit_records[req.rid] = rec
        self._journal_last_rid = max(self._journal_last_rid, req.rid)

    def _journal_terminal(self, req: SNNRequest, *,
                          noadmit: bool = False) -> None:
        """Buffered TERMINAL record + (post-sync) ledger entry.
        ``noadmit`` marks a structural reject at submit time — the rid
        never had an ADMIT, but it was offered, so replay still counts
        it toward ``submitted`` and the resume offset."""
        rec = {"ev": "T", "rid": req.rid, "st": req.status,
               "at": self.clock.now_ms()}
        if noadmit:
            rec["noadmit"] = 1
        if req.served_version is not None:
            rec["ver"] = req.served_version
        if req.queue_wait_ms is not None:
            rec["qw"] = req.queue_wait_ms
        if req.service_ms is not None:
            rec["sv"] = req.service_ms
        if req.content_sha is not None:
            rec["sha"] = req.content_sha
        if req.shed is not None:
            rec["shed"] = req.shed
        if req.error:
            rec["err"] = req.error
        self.journal.append(rec)
        self._admit_records.pop(req.rid, None)
        self._journal_last_rid = max(self._journal_last_rid, req.rid)
        self._pending_ledger.append(
            {"rid": req.rid, "st": req.status, "sha": req.content_sha,
             "ver": req.served_version})

    def _journal_sync(self) -> None:
        """Group commit: make buffered WAL records durable, THEN flush
        the terminal-ledger entries they cover (ledger ⊆ durable WAL —
        the exactly-once ordering)."""
        self.journal.sync()
        if self._pending_ledger:
            for rec in self._pending_ledger:
                self.journal.ledger_append(rec)
            self._pending_ledger.clear()
            self.journal.ledger_sync()

    def _consult_crash(self, kind: str) -> None:
        """Injected whole-process crash point (journaled engines only;
        the default hook calls ``os._exit`` and never returns)."""
        if self.on_launch is not None:
            self.on_launch({"kind": kind, "step": self.steps,
                            "level": self.level, "batch_size": 0,
                            "t_lens": []})

    def _snapshot_state(self) -> dict:
        state = {
            "counters": {**{k: int(getattr(self, k))
                            for k in _COUNTER_KEYS},
                         # keys from a newer schema ride along untouched
                         **self._foreign_counters},
            "qw_hist": self.queue_wait_hist.to_dict(),
            "sv_hist": self.service_hist.to_dict(),
            "queue": [self._admit_records[r.rid] for r in self.queue
                      if r.rid in self._admit_records],
            "last_rid": self._journal_last_rid,
            "weight_version": self._store.serving.version,
            "clock_ms": self.clock.now_ms(),
            "t_first_ms": self._t_first_ms,
            "t_last_ms": self._t_last_ms,
            "deg_events": self.degradation_events.to_list(),
            "deg_dropped": self.degradation_events.dropped,
            "level": self.level,
            "breakers": self.breakers.states(),
            "breaker_trips": self.breakers.trips,
        }
        if self.overload is not None:
            state["overload"] = self.overload.state_dict()
        return state

    def _requeue_record(self, rec: dict) -> None:
        """Re-materialize one recovered ADMIT record into the queue,
        bypassing ``submit()`` (its counters were already replayed).
        Trace rows regenerate their payload from the row's seeds and
        are verified against the recorded content hash — a mismatch
        fails loudly rather than serving the wrong bytes."""
        row = rec.get("row")
        if row is not None:
            # local import: repro.loadgen.__init__ imports the runner,
            # which imports this module
            from repro.loadgen.workload import WorkloadSpec

            req = WorkloadSpec(n_inputs=self.n_inputs).materialize(
                row, verify=True)
        else:
            p = rec["payload"]
            if p["kind"] == "I":
                req = SNNRequest(rid=rec["rid"],
                                 intensities=np.array(p["inten"],
                                                      np.uint8),
                                 n_steps=p["n_steps"], seed=p.get("seed"))
            else:
                req = SNNRequest(rid=rec["rid"],
                                 window=np.array(p["win"], np.uint32)
                                 .reshape(p["t"], self.words))
        req.priority = rec.get("prio", 0)
        req.deadline_ms = rec.get("ddl")
        req.t_submit_ms = rec["ts"]
        req.content_sha = rec.get("sha")
        req.status = QUEUED
        self.queue.append(req)
        self._admit_records[req.rid] = rec

    def _recover_from_journal(self, journal_dir) -> None:
        """Adopt the journal's replayed state: counters, histograms,
        degradation rung, clock high-water mark, and the re-queue set
        (see the module docstring's crash-consistency section)."""
        if self.snapshot_every < 0:
            raise ValueError(f"snapshot_every must be >= 0, got "
                             f"{self.snapshot_every}")
        self.journal = j = RequestJournal(journal_dir)
        snapshot, tail = j.recover()
        rec = replay(snapshot, tail)
        if rec.last_rid < 0 and not rec.snapshotted:
            return      # fresh journal directory: nothing to adopt
        for k, v in rec.counters.items():
            if k in _COUNTER_KEYS:
                setattr(self, k, v)
            else:
                # a newer engine's counter: preserve through our own
                # snapshots rather than break (forward compatibility)
                self._foreign_counters[k] = v
        if rec.overload is not None and self.overload is not None:
            self.overload.load_state(rec.overload)
        if rec.breakers is not None:
            self.breakers = LadderBreakers(len(self._plans),
                                           states=rec.breakers)
        if rec.qw_hist:
            self.queue_wait_hist = LatencyHistogram.from_dict(rec.qw_hist)
        if rec.sv_hist:
            self.service_hist = LatencyHistogram.from_dict(rec.sv_hist)
        self.level = min(rec.level, len(self._plans) - 1)
        self.degradation_events = RingLog(cap=_EVENT_RING,
                                          items=rec.deg_events)
        self.degradation_events.dropped += rec.deg_dropped
        self._t_first_ms = rec.t_first_ms
        self._t_last_ms = rec.t_last_ms
        self._journal_last_rid = rec.last_rid
        self.journal_resume_offset = rec.resume_offset
        skip = getattr(self.clock, "skip_to", None)
        if skip is not None:
            skip(rec.clock_ms)
        for adm in rec.pending:
            self._requeue_record(adm)
        self.journal_recovered = len(rec.pending)
        # the store's restart path (newest complete checkpoint) is the
        # source of truth for weights; a journal/store disagreement is
        # counted, never fought
        if (rec.weight_version is not None
                and rec.weight_version != self._store.serving.version):
            self.version_reconciliations += 1
            self._store.events.append({
                "event": "journal_version_reconciled",
                "journal": rec.weight_version,
                "store": self._store.serving.version})
        # ledger reconciliation: a crash between the WAL terminal sync
        # and the ledger flush leaves durable terminals the ledger
        # missed — append them now, before the compacting snapshot
        # folds the tail away
        ledger_rids = {r["rid"] for r in j.read_ledger()}
        missing = [ev for ev in tail if ev.get("ev") == "T"
                   and int(ev["rid"]) not in ledger_rids]
        for ev in missing:
            j.ledger_append({"rid": int(ev["rid"]), "st": ev["st"],
                             "sha": ev.get("sha"), "ver": ev.get("ver")})
        if missing:
            j.ledger_sync()
        j.snapshot(self._snapshot_state())   # compact: tail -> snapshot

    def close(self) -> None:
        """Flush and close the journal (final compacting snapshot).
        A crash *instead of* close loses nothing durable — this only
        tightens the next recovery."""
        if self.journal is not None:
            self._journal_sync()
            self.journal.snapshot(self._snapshot_state())
            self.journal.close()

    # --- serve ---------------------------------------------------------

    def _engine_for(self, level: int) -> SNNEngine:
        if level not in self._engines:
            self._engines[level] = SNNEngine(self._plans[level])
        return self._engines[level]

    def _serve_intensities(self, eng: SNNEngine, batch,
                           t_pad: int) -> np.ndarray:
        """One in-kernel-encode launch: uint8 intensities + ragged
        lengths in, counts out; the batch tail pads with zero intensity
        (silent) and t_total=0."""
        plan = eng.plan
        inten = np.zeros((plan.max_batch, self.n_inputs), np.uint8)
        seeds = np.zeros((plan.max_batch,), np.int32)
        t_total = np.zeros((plan.max_batch,), np.int32)
        for i, r in enumerate(batch):
            inten[i, :r.intensities.shape[0]] = r.intensities
            seeds[i] = r.seed
            t_total[i] = r.n_steps
        return np.asarray(eng.infer(
            self._pinned.weights, intensities=jnp.asarray(inten),
            seeds=jnp.asarray(seeds), n_steps=t_pad,
            t_total=jnp.asarray(t_total)))

    def _serve_windows(self, eng: SNNEngine, batch,
                       t_pad: int) -> np.ndarray:
        """One pre-packed launch; intensity requests in a mixed batch
        are host-encoded here (bit-exact with the kernel draw)."""
        plan = eng.plan
        stacked = np.zeros((plan.max_batch, t_pad, self.words),
                           np.uint32)
        for i, r in enumerate(batch):
            win = r.window
            if win is None:
                win = np.asarray(encode_from_counter(
                    r.seed, jnp.asarray(r.intensities), r.n_steps))
            stacked[i, :win.shape[0], :win.shape[1]] = win
        return np.asarray(
            eng.infer(self._pinned.weights, jnp.asarray(stacked)))

    def _launch_counts(self, batch, t_pad: int, level: int, *,
                       hooked: bool = True, attempt: int = 0,
                       kind: str = "serve") -> np.ndarray:
        """One serve launch at one degradation rung.  The ``on_launch``
        hook runs first (fault injection: may raise, stall, or return a
        count-corruption callable) — except on ``kind="fallback"``
        oracle re-serves, which are never hooked."""
        eng = self._engine_for(level)
        corrupt = None
        if hooked and self.on_launch is not None:
            corrupt = self.on_launch({
                "step": self.steps, "attempt": attempt, "level": level,
                "kind": kind, "batch_size": len(batch), "t_pad": t_pad,
                "t_lens": [self._t_len(r) for r in batch]})
        plan = eng.plan
        intensity_only = all(r.window is None for r in batch)
        if (intensity_only and plan.encode == "kernel"
                and plan.cycle_backend == "window"):
            counts = self._serve_intensities(eng, batch, t_pad)
        else:
            counts = self._serve_windows(eng, batch, t_pad)
        if corrupt is not None:
            counts = np.asarray(corrupt(counts))
        return counts

    def _degrade(self, reason: str) -> None:
        frm = self.level
        self.level += 1
        self.degraded += 1
        self.healthy_steps = 0
        self.breakers.open_rung(frm)
        plan = self._plans[self.level]
        self.degradation_events.append({
            "step": self.steps, "from": frm, "to": self.level,
            "encode": plan.encode, "kernel_backend": plan.kernel_backend,
            "reason": reason})

    def _launch_with_recovery(self, batch, t_pad: int
                              ) -> np.ndarray | None:
        """Bounded-retry launch with graceful degradation: re-attempt at
        the current rung up to ``max_retries`` times, then step down the
        ladder and re-run the budget; None once every rung is spent
        (the batch fails)."""
        pol = self.policy
        max_level = len(self._plans) - 1
        while True:
            attempts = 0
            while True:
                try:
                    return self._launch_counts(batch, t_pad, self.level,
                                               attempt=attempts)
                except Exception as e:  # noqa: BLE001 — contain faults
                    self._step_faults += 1
                    self._last_error = f"{type(e).__name__}: {e}"
                    if attempts >= pol.max_retries:
                        break
                    if (self.overload is not None
                            and not self.overload.grant_retry(
                                self.clock.now_ms())):
                        # global retry budget spent: fail fast instead
                        # of amplifying a correlated fault burst into a
                        # retry storm
                        self.retries_denied += 1
                        break
                    attempts += 1
                    self.retried += 1
                    for r in batch:
                        r.retries += 1
                    if pol.retry_backoff_ms:
                        self.clock.advance_ms(pol.retry_backoff_ms
                                              * 2 ** (attempts - 1))
            if pol.degrade_on_failure and self.level < max_level:
                self._degrade(f"launch failed after {attempts + 1} "
                              f"attempts: {self._last_error}")
                continue
            return None

    def _integrity_guard(self, batch, counts: np.ndarray, t_pad: int
                         ) -> tuple[np.ndarray, set[int]]:
        """Enforce ``0 <= counts <= t_total`` per slot; violating slots
        are re-served on the most-degraded oracle rung with the launch
        hook bypassed.  Returns (repaired counts, slots that could not
        be repaired)."""
        bad = [i for i, r in enumerate(batch)
               if (counts[i] < 0).any()
               or (counts[i] > self._t_len(r)).any()]
        if not bad:
            return counts, set()
        self.integrity_failures += len(bad)
        self._step_faults += len(bad)
        counts = np.array(counts)
        unrepaired: set[int] = set()
        try:
            good = self._launch_counts([batch[i] for i in bad], t_pad,
                                       len(self._plans) - 1,
                                       hooked=False, kind="fallback")
            for j, i in enumerate(bad):
                counts[i] = good[j]
        except Exception as e:  # noqa: BLE001 — oracle re-serve failed
            self._last_error = f"{type(e).__name__}: {e}"
            unrepaired = set(bad)
        if (self.policy.degrade_on_integrity
                and self.level < len(self._plans) - 1):
            self._degrade(f"integrity violation in {len(bad)} slot(s)")
        return counts, unrepaired

    def _canary_check(self) -> None:
        """Known-answer probe: serve a fixed window through the current
        rung (hook included) and compare with golden ref-path counts —
        catches in-range corruption the range guard cannot.  Golden
        counts are a function of the weights, so they are re-derived
        whenever the pinned version changes; a mismatch while serving a
        freshly *refreshed* version is treated as post-promotion
        regression and rolls the store back (path corruption on a
        seed/rollback bank only degrades, as before)."""
        plan = self.plan
        pinned = self._pinned
        if self._canary_window is None:
            inten = jnp.full((self.n_inputs,), 128, jnp.uint8)
            self._canary_window = np.asarray(encode_from_counter(
                _CANARY_SEED, inten, self.policy.canary_steps),
                dtype=np.uint32)
        if self._canary_version != pinned.version:
            self._canary_golden = np.asarray(ops.infer_window_batch(
                pinned.weights, jnp.asarray(self._canary_window)[None],
                threshold=plan.threshold, leak=plan.leak,
                backend="ref"))[0]
            self._canary_version = pinned.version
        req = SNNRequest(rid=-1, window=self._canary_window)
        q = self._t_quantum()
        t_pad = -(-self.policy.canary_steps // q) * q
        self.canary_checks += 1
        try:
            got = self._launch_counts([req], t_pad, self.level,
                                      kind="canary")[0]
            ok = bool(np.array_equal(got, self._canary_golden))
        except Exception as e:  # noqa: BLE001 — canary launch died
            self._last_error = f"{type(e).__name__}: {e}"
            ok = False
        if not ok:
            self.canary_failures += 1
            self._step_faults += 1
            if (self.policy.degrade_on_integrity
                    and self.level < len(self._plans) - 1):
                self._degrade("canary mismatch vs golden counts")
            if pinned.origin == "refresh" and self._store.can_rollback():
                tgt = self._store.rollback(
                    reason=f"canary mismatch on refreshed version "
                           f"{pinned.version}")
                self.refresh_events.append({
                    "event": "rollback", "step": self.steps,
                    "from": pinned.version, "to": tgt.version,
                    "reason": "canary mismatch"})

    # --- versioned refresh ----------------------------------------------

    def _refresh_event(self, event: str, **fields) -> None:
        self.refresh_events.append({"event": event, "step": self.steps,
                                    **fields})

    def _maybe_refresh(self) -> None:
        rf = self.refresher
        if rf is None or rf.policy.refresh_every <= 0 or self.steps == 0:
            return
        if self.steps - self._last_refresh_step < rf.policy.refresh_every:
            return
        self._last_refresh_step = self.steps
        self._refresh_cycle()

    def _refresh_cycle(self) -> None:
        """One probe-gated refresh, run BETWEEN serving steps (the
        double-buffered swap point).  Train a candidate from the serving
        bank, verify its content fingerprint, probe it on the held-out
        set, then promote / reject / roll back.  Never raises; every
        outcome lands in a counter and ``refresh_events``."""
        rf = self.refresher
        pol = rf.policy
        serving = self._store.serving
        self.refresh_runs += 1
        t0 = time.perf_counter()
        corrupt = None
        try:
            if self.on_launch is not None:
                # refresh-path fault hook: may stall, raise, or return a
                # weight-corruption callable (applied post-fingerprint,
                # exactly the torn-candidate failure mode)
                corrupt = self.on_launch({
                    "kind": "refresh", "step": self.steps,
                    "epoch": rf.epochs_run, "level": self.level,
                    "batch_size": 0, "t_lens": []})
            cand_w, epoch = rf.next_candidate(serving.weights)
        except Exception as e:  # noqa: BLE001 — contain refresh faults
            self._last_error = f"{type(e).__name__}: {e}"
            self.refresh_failed += 1
            self._refresh_event("refresh_failed", error=self._last_error)
            return
        cand = self._store.stage(cand_w, origin="refresh")
        if corrupt is not None:
            cand = dataclasses.replace(cand, weights=jnp.asarray(
                np.asarray(corrupt(np.asarray(cand.weights))),
                jnp.uint32))
        elapsed_ms = (time.perf_counter() - t0) * 1e3
        if (pol.refresh_timeout_ms is not None
                and elapsed_ms > pol.refresh_timeout_ms):
            self.refresh_timeouts += 1
            self._store.reject(cand, f"stalled refresh: "
                               f"{elapsed_ms:.1f}ms > "
                               f"{pol.refresh_timeout_ms}ms")
            self._refresh_event("refresh_stalled", version=cand.version,
                                elapsed_ms=round(elapsed_ms, 1))
            return
        if not cand.verify():
            self.refresh_corrupt += 1
            self._store.reject(cand, "candidate fingerprint mismatch "
                               "(corrupt weights)")
            self._refresh_event("refresh_corrupt", version=cand.version)
            return
        try:
            acc_cand = rf.probe(cand.weights)
            acc_cur = rf.probe(serving.weights)
        except Exception as e:  # noqa: BLE001 — probe died
            self._last_error = f"{type(e).__name__}: {e}"
            self.refresh_failed += 1
            self._store.reject(cand, f"probe failed: {self._last_error}")
            self._refresh_event("refresh_failed", version=cand.version,
                                error=self._last_error)
            return
        self.last_probe_accuracy = acc_cur
        if (serving.probe_accuracy is not None
                and acc_cur < serving.probe_accuracy - pol.max_regression
                and self._store.can_rollback()):
            # the SERVING bank itself regressed vs its promotion-time
            # probe — post-promotion rollback, candidate dropped too
            self._store.reject(cand, "serving bank regressed; "
                               "rolling back first")
            tgt = self._store.rollback(
                reason=f"probe regression: {acc_cur:.3f} < promoted "
                       f"{serving.probe_accuracy:.3f}")
            self._refresh_event("rollback", **{
                "from": serving.version, "to": tgt.version,
                "probe_accuracy": acc_cur})
            return
        if acc_cand < acc_cur - pol.max_regression:
            self.refresh_rejected += 1
            self._store.reject(cand, f"probe gate: candidate "
                               f"{acc_cand:.3f} < serving "
                               f"{acc_cur:.3f} - {pol.max_regression}")
            self._refresh_event("refresh_rejected", version=cand.version,
                                candidate=acc_cand, serving=acc_cur)
            return
        cand = dataclasses.replace(cand, probe_accuracy=acc_cand)
        if self._store.promote(cand, on_save=self.on_launch):
            self.last_probe_accuracy = acc_cand
            self._refresh_event("promoted", version=cand.version,
                                probe_accuracy=acc_cand, epoch=epoch)
        else:
            self._refresh_event("save_crash", version=cand.version)

    def step(self) -> int:
        """Admit + serve one batch.  Returns the number of requests
        reaching a terminal status this step; never raises — launch
        faults retry, degrade, and at worst end the batch ``FAILED``.

        Step top is the version boundary: run a due refresh cycle,
        apply any queued promotion/rollback swap, then *pin* the
        serving version — every launch this step (serve, retry, oracle
        re-serve, canary) reads the pinned bank, so a swap can never
        tear a batch."""
        pol = self.policy
        self._maybe_refresh()
        self._store.swap_if_pending()
        self._pinned = self._store.serving
        batch, finished = self._form_batch()
        if not batch:
            if self.journal is not None:
                self._journal_sync()     # expiries found this step
            return finished
        t0 = time.perf_counter()
        t_start_ms = self.clock.now_ms()
        self._step_faults = 0
        q = self._t_quantum()
        t_pad = -(-max(self._t_len(r) for r in batch) // q) * q
        if self.journal is not None:
            # group commit: buffered ADMITs + this DISPATCH become
            # durable together, before the launch can observe them
            self.journal.append({
                "ev": "D", "step": self.steps, "n": len(batch),
                "pad": self.plan.max_batch - len(batch),
                "ver": self._pinned.version,
                "rids": [r.rid for r in batch], "at": t_start_ms})
            self._journal_sync()
            self._consult_crash("crash_before_dispatch")
        counts = self._launch_with_recovery(batch, t_pad)
        unrepaired: set[int] = set()
        if counts is not None:
            counts, unrepaired = self._integrity_guard(batch, counts,
                                                       t_pad)
        if self.journal is not None:
            self._consult_crash("crash_after_serve")
        infl_fn = getattr(self.on_launch, "service_inflation", None)
        infl = 1.0 if infl_fn is None else infl_fn(
            {"step": self.steps, "batch_size": len(batch),
             "t_pad": t_pad})
        self.clock.advance_service_ms(len(batch), t_pad, inflation=infl)
        now_ms = self.clock.now_ms()
        self._t_last_ms = now_ms
        for i, r in enumerate(batch):
            r.queue_wait_ms = t_start_ms - r.t_submit_ms
            r.service_ms = now_ms - r.t_submit_ms
            if counts is None or i in unrepaired:
                self._finish(r, FAILED, f"request {r.rid}: "
                             f"{self._last_error}")
                continue
            r.counts = counts[i]
            r.served_version = self._pinned.version
            if not self._store.is_live(self._pinned.version):
                self.version_violations += 1
            if self.neuron_class is not None:
                r.pred = int(self.neuron_class[int(np.argmax(counts[i]))])
            self.queue_wait_hist.record(r.queue_wait_ms)
            self.service_hist.record(r.service_ms)
            self._finish(r, SERVED)
            self.windows_served += 1
            if self.overload is not None:
                self.overload.note_served(r.service_ms)
        finished += len(batch)
        self.steps += 1
        self.batches += 1
        self.slots_offered += self.plan.max_batch
        self.slots_padded += self.plan.max_batch - len(batch)
        if pol.canary_every and self.steps % pol.canary_every == 0:
            self._canary_check()
        if self._step_faults == 0:
            self.healthy_steps += 1
            if (self.level > 0 and pol.reprobe_after is not None
                    and self.healthy_steps >= pol.reprobe_after):
                self.degradation_events.append({
                    "step": self.steps, "from": self.level, "to": 0,
                    "encode": self.plan.encode,
                    "kernel_backend": self.plan.kernel_backend,
                    "reason": f"re-probe after {self.healthy_steps} "
                              "healthy steps"})
                self.breakers.half_open_all()   # trial traffic admitted
                self.level = 0
                self.healthy_steps = 0
            else:
                self.breakers.close_trials()    # half-open trial passed
        else:
            self.healthy_steps = 0
        if self.journal is not None:
            self._journal_sync()         # TERMINALs durable at step end
            if self.snapshot_every and \
                    self.steps % self.snapshot_every == 0:
                self.journal.snapshot(
                    self._snapshot_state(),
                    crash_point=lambda: self._consult_crash(
                        "crash_mid_snapshot"))
        dt = time.perf_counter() - t0
        self.step_seconds += dt
        self.last_step_seconds = dt
        return finished

    def run(self, requests: list[SNNRequest], max_steps: int = 10_000
            ) -> list[SNNRequest]:
        """Submit everything through the structured-rejection path, then
        step until every request is terminal (a rejected request never
        strands the rest)."""
        for r in requests:
            if r.status == "NEW":
                self.submit(r)
        steps = 0
        while any(not r.terminal for r in requests) and steps < max_steps:
            if self.step() == 0 and not self.queue:
                break
            steps += 1
        return requests

    # --- stats ---------------------------------------------------------

    @property
    def padded_slot_waste(self) -> float:
        """Fraction of offered batch slots burned on zero padding."""
        if self.slots_offered == 0:
            return 0.0
        return self.slots_padded / self.slots_offered

    @property
    def offered_rps(self) -> float:
        """Submitted requests per second of clock time spent serving."""
        return self._rate(self.submitted)

    @property
    def achieved_rps(self) -> float:
        """SERVED requests per second of clock time spent serving."""
        return self._rate(self.windows_served)

    def _rate(self, count: int) -> float:
        if self._t_first_ms is None or self._t_last_ms is None:
            return 0.0
        span_ms = self._t_last_ms - self._t_first_ms
        return count / span_ms * 1e3 if span_ms > 0 else 0.0

    def per_status(self) -> dict:
        """Terminal-status totals (the loadgen replay invariant)."""
        return {SERVED: self.windows_served, REJECTED: self.rejected,
                EXPIRED: self.expired, FAILED: self.failed}

    def stats(self) -> dict:
        """Serving counters for the ``--bench`` report."""
        return {
            "submitted": self.submitted,
            "windows_served": self.windows_served,
            "offered_rps": round(self.offered_rps, 3),
            "achieved_rps": round(self.achieved_rps, 3),
            "batches": self.batches,
            "padded_slot_waste": self.padded_slot_waste,
            "mean_step_ms": round(
                1e3 * self.step_seconds / max(self.batches, 1), 3),
            "last_step_ms": round(1e3 * self.last_step_seconds, 3),
            # --- robustness ------------------------------------------
            "rejected": self.rejected,
            "expired": self.expired,
            "failed": self.failed,
            "retried": self.retried,
            "degraded": self.degraded,
            "integrity_failures": self.integrity_failures,
            "canary_checks": self.canary_checks,
            "canary_failures": self.canary_failures,
            "level": self.level,
            # --- overload control ------------------------------------
            "breaker_states": self.breakers.states(),
            "breaker_trips": self.breakers.trips,
            **({"admit_rate_rps": round(self.overload.admit_rate, 1),
                "shed_admission": self.shed_admission,
                "shed_low_priority": self.shed_low_priority,
                "shed_codel": self.shed_codel,
                "retries_denied": self.retries_denied,
                "codel_dropping": self.overload.dropping,
                "codel_entries": self.overload.codel_entries,
                "aimd_md_events": self.overload.md_events,
                "aimd_ai_events": self.overload.ai_events,
                "retry_tokens": round(self.overload.retry_tokens, 2)}
               if self.overload is not None else {}),
            # --- versioned refresh -----------------------------------
            **self._store.stats(),
            "refresh_runs": self.refresh_runs,
            "refresh_rejected": self.refresh_rejected,
            "refresh_corrupt": self.refresh_corrupt,
            "refresh_timeouts": self.refresh_timeouts,
            "refresh_failed": self.refresh_failed,
            "version_violations": self.version_violations,
            "probe_accuracy": (None if self.last_probe_accuracy is None
                               else round(self.last_probe_accuracy, 4)),
            # --- crash-consistency journal ---------------------------
            **({"journal_records": self.journal.records_appended,
                "journal_syncs": self.journal.syncs,
                "journal_snapshots": self.journal.snapshots_taken,
                "journal_recovered": self.journal_recovered,
                "journal_resume_offset": self.journal_resume_offset,
                "version_reconciliations": self.version_reconciliations,
                "telemetry_dropped": self.degradation_events.dropped
                + self.refresh_events.dropped}
               if self.journal is not None else {}),
            "queue_wait_ms_p50": round(
                self.queue_wait_hist.percentile(50), 3),
            "queue_wait_ms_p99": round(
                self.queue_wait_hist.percentile(99), 3),
            "service_ms_p50": round(self.service_hist.percentile(50), 3),
            "service_ms_p99": round(self.service_hist.percentile(99), 3),
        }
