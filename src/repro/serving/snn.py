"""SNN request serving: queue + dynamic window batching over the engine.

The transformer path batches decode steps over KV-cache slots
(:mod:`repro.serving.engine`); the SNN path batches whole presentation
windows.  :class:`SNNServingEngine` keeps a request queue and, per
engine step, admits up to ``plan.max_batch`` requests, pads their
(possibly ragged) windows into one batch, and serves them with a single
:meth:`SNNEngine.infer` launch — sharded over the plan's neuron mesh
when one is present, so population-sharded serving and request batching
compose.

Requests come in two shapes:

* **pre-packed**: a ``uint32[T, w]`` spike window (the original form);
* **intensity**: ``uint8[n_in]`` pixel intensities + ``n_steps`` (+ an
  optional counter ``seed``, default derived from the request id).  The
  queue then holds ``n_in`` bytes instead of ``T*w*4`` (~T/8x smaller),
  and when the plan says ``encode="kernel"`` the spike window *never*
  exists — the serve launch draws it in VMEM from the counter hash.
  Both placements are bit-exact with ``encoder.encode_from_counter``,
  so mixed batches (host-encoded on admission) return identical counts.

Ragged batching is bit-exact by construction: windows are zero-padded on
the time axis, and a zero spike row adds no input counts while the
membrane only leaks — with ``threshold >= 1`` a neuron that did not fire
in the true window cannot fire in a padded cycle (after any cycle
``v < threshold``), so padded cycles contribute no spikes.  The batch
axis is likewise padded (zero windows / zero intensities — silent by the
same argument), which pins the launch shape to ``(max_batch, T_q, ...)``
with ``T_q`` rounded up to the time quantum — one compile per
window-length bucket instead of one per ragged batch shape.  The
intensity path additionally carries each sample's true length as a
traced SMEM operand, so raggedness itself never retraces.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque

import jax.numpy as jnp
import numpy as np

from repro.core.encoder import encode_from_counter
from repro.engine import SNNEngine, SNNEnginePlan

_T_QUANTUM = 8   # window lengths bucket to multiples of this (or t_chunk)


@dataclasses.dataclass
class SNNRequest:
    """One classification request: spikes (or intensities) in, counts out."""
    rid: int
    window: np.ndarray | None = None   # uint32[T, w] packed spike window
    intensities: np.ndarray | None = None  # uint8[n_in] (with n_steps)
    n_steps: int | None = None         # presentation length (intensity form)
    seed: int | None = None            # counter seed (default: from rid)
    counts: np.ndarray | None = None   # int32[n] spike counts (result)
    pred: int | None = None            # argmax class (if classes known)
    done: bool = False


class SNNServingEngine:
    """Dynamic window batching over :meth:`SNNEngine.infer`.

    weights: uint32[n, w] frozen population weights; ``neuron_class``
    (int[n], optional) maps the maximally-firing neuron to a class label
    for ``req.pred``.  Admission, padding, encode placement and launch
    shape come from the plan (``max_batch``, ``t_chunk``, ``encode``,
    placement).
    """

    def __init__(self, weights, plan: SNNEnginePlan, *,
                 neuron_class=None):
        if plan.threshold < 1:
            raise ValueError("SNN serving requires threshold >= 1 "
                             "(zero-padded cycles must stay silent)")
        self.engine = SNNEngine(plan)
        self.weights = jnp.asarray(weights, jnp.uint32)
        self.neuron_class = (None if neuron_class is None
                             else np.asarray(neuron_class))
        self.words = int(self.weights.shape[1])
        self.n_inputs = self.words * 32
        self.queue: deque[SNNRequest] = deque()
        self.steps = 0
        self.batches = 0
        self.windows_served = 0
        self.slots_offered = 0      # max_batch per launch
        self.slots_padded = 0       # offered - admitted (batch-pad waste)
        self.step_seconds = 0.0     # total serve wall-clock
        self.last_step_seconds = 0.0

    # --- admission -----------------------------------------------------

    def submit(self, req: SNNRequest) -> None:
        if (req.window is None) == (req.intensities is None):
            raise ValueError(f"request {req.rid}: provide exactly one "
                             "of window / intensities")
        if req.window is not None:
            window = np.asarray(req.window, np.uint32)
            if window.ndim != 2 or window.shape[1] != self.words:
                raise ValueError(f"request {req.rid}: window must be "
                                 f"uint32[T, {self.words}], got "
                                 f"{window.shape}")
            req.window = window
        else:
            inten = np.asarray(req.intensities, np.uint8)
            if inten.ndim != 1 or inten.shape[0] > self.n_inputs:
                raise ValueError(f"request {req.rid}: intensities must "
                                 f"be uint8[<= {self.n_inputs}], got "
                                 f"{inten.shape}")
            if req.n_steps is None or req.n_steps < 1:
                raise ValueError(f"request {req.rid}: intensity "
                                 "requests need n_steps >= 1")
            req.intensities = inten
            if req.seed is None:
                req.seed = self.engine.plan.encode_seed + req.rid
        self.queue.append(req)

    def _t_quantum(self) -> int:
        tc = self.engine.plan.t_chunk
        return tc if tc is not None else _T_QUANTUM

    @staticmethod
    def _t_len(req: SNNRequest) -> int:
        return (req.window.shape[0] if req.window is not None
                else req.n_steps)

    # --- serve ---------------------------------------------------------

    def _serve_intensities(self, batch, t_pad: int) -> np.ndarray:
        """One in-kernel-encode launch: uint8 intensities + ragged
        lengths in, counts out; the batch tail pads with zero intensity
        (silent) and t_total=0."""
        plan = self.engine.plan
        inten = np.zeros((plan.max_batch, self.n_inputs), np.uint8)
        seeds = np.zeros((plan.max_batch,), np.int32)
        t_total = np.zeros((plan.max_batch,), np.int32)
        for i, r in enumerate(batch):
            inten[i, :r.intensities.shape[0]] = r.intensities
            seeds[i] = r.seed
            t_total[i] = r.n_steps
        return np.asarray(self.engine.infer(
            self.weights, intensities=jnp.asarray(inten),
            seeds=jnp.asarray(seeds), n_steps=t_pad,
            t_total=jnp.asarray(t_total)))

    def _serve_windows(self, batch, t_pad: int) -> np.ndarray:
        """One pre-packed launch; intensity requests in a mixed batch
        are host-encoded here (bit-exact with the kernel draw)."""
        plan = self.engine.plan
        stacked = np.zeros((plan.max_batch, t_pad, self.words),
                           np.uint32)
        for i, r in enumerate(batch):
            win = r.window
            if win is None:
                win = np.asarray(encode_from_counter(
                    r.seed, jnp.asarray(r.intensities), r.n_steps))
            stacked[i, :win.shape[0], :win.shape[1]] = win
        return np.asarray(
            self.engine.infer(self.weights, jnp.asarray(stacked)))

    def step(self) -> int:
        """Admit + serve one batch.  Returns requests completed."""
        plan = self.engine.plan
        batch: list[SNNRequest] = []
        while self.queue and len(batch) < plan.max_batch:
            batch.append(self.queue.popleft())
        if not batch:
            return 0
        t0 = time.perf_counter()
        q = self._t_quantum()
        t_pad = -(-max(self._t_len(r) for r in batch) // q) * q
        intensity_only = all(r.window is None for r in batch)
        if (intensity_only and plan.encode == "kernel"
                and plan.cycle_backend == "window"):
            counts = self._serve_intensities(batch, t_pad)
        else:
            counts = self._serve_windows(batch, t_pad)
        for i, r in enumerate(batch):
            r.counts = counts[i]
            if self.neuron_class is not None:
                r.pred = int(self.neuron_class[int(np.argmax(counts[i]))])
            r.done = True
        dt = time.perf_counter() - t0
        self.steps += 1
        self.batches += 1
        self.windows_served += len(batch)
        self.slots_offered += plan.max_batch
        self.slots_padded += plan.max_batch - len(batch)
        self.step_seconds += dt
        self.last_step_seconds = dt
        return len(batch)

    def run(self, requests: list[SNNRequest], max_steps: int = 10_000
            ) -> list[SNNRequest]:
        for r in requests:
            self.submit(r)
        steps = 0
        while any(not r.done for r in requests) and steps < max_steps:
            if self.step() == 0:
                break
            steps += 1
        return requests

    # --- stats ---------------------------------------------------------

    @property
    def padded_slot_waste(self) -> float:
        """Fraction of offered batch slots burned on zero padding."""
        if self.slots_offered == 0:
            return 0.0
        return self.slots_padded / self.slots_offered

    def stats(self) -> dict:
        """Serving counters for the ``--bench`` report."""
        return {
            "windows_served": self.windows_served,
            "batches": self.batches,
            "padded_slot_waste": self.padded_slot_waste,
            "mean_step_ms": round(
                1e3 * self.step_seconds / max(self.batches, 1), 3),
            "last_step_ms": round(1e3 * self.last_step_seconds, 3),
        }
