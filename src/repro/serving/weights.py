"""Versioned weight bank for train-while-serving SNN deployments.

The paper's pitch against ODIN is *cheap online learning in the CPU
pipeline*: 1-bit binary stochastic STDP keeps learning while the
processor classifies.  Serving the same trick safely needs three
guarantees the frozen-weights engine could not give:

1. **No torn reads.**  :class:`VersionedWeightStore` is an immutable,
   monotonically numbered weight bank with double-buffered swap
   semantics: the *serving* version is the only one traffic can see,
   candidates are staged under fresh version numbers that are never
   visible, and a promotion only queues a swap —
   :meth:`VersionedWeightStore.swap_if_pending` applies it at the
   caller's step boundary, so every batch launch pins the version it
   started with and in-flight windows always finish on the old bank.

2. **No bad promotions.**  :class:`SNNWeightRefresher` builds candidate
   banks by pushing labeled samples through the engine's data-parallel
   :func:`repro.engine.refresh_weights` verb (epoch-keyed counter
   seeds — fresh Poisson draws per refresh at zero memory cost) and
   probes them on a fixed held-out set.  A candidate is promoted only
   if (a) its content fingerprint still matches the one taken at
   production time (a corrupted/torn candidate is caught *at the probe
   gate*, before any accuracy math) and (b) its probe accuracy is
   within ``max_regression`` of the serving bank's.  Rejected
   candidates increment counters and are garbage — never serveable.

3. **Recoverability.**  Every promoted version is persisted through the
   atomic :class:`repro.checkpoint.CheckpointManager` (tmp-dir +
   rename, keep-k), which yields two behaviors for free:
   :meth:`VersionedWeightStore.rollback` demotes the serving version
   and re-reads the previous promoted version from disk (bit-exact with
   the persisted checkpoint), and constructing a store over an existing
   ``state_dir`` restores the newest *complete* version instead of the
   seed weights — a leftover ``step_N.tmp/`` from a crash mid-save is
   purged and ignored.
"""

from __future__ import annotations

import dataclasses
import hashlib
import shutil
import threading

import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core.encoder import sample_seeds, sample_seeds_at
from repro.engine import SNNEngine, SNNEnginePlan, refresh_weights
from repro.serving.journal import RingLog


def weight_fingerprint(weights) -> str:
    """Content hash (shape + bytes) of a packed uint32 weight bank."""
    arr = np.ascontiguousarray(np.asarray(weights, np.uint32))
    h = hashlib.sha256()
    h.update(repr(arr.shape).encode())
    h.update(arr.tobytes())
    return h.hexdigest()


@dataclasses.dataclass(frozen=True)
class WeightVersion:
    """One immutable numbered weight bank.

    ``origin`` records how the version came to be: ``seed`` (the
    constructor bank), ``refresh`` (a trained candidate), ``restore``
    (read back from disk at startup), ``rollback`` (re-read from disk
    after a demotion).  ``fingerprint`` is taken when the bank is
    produced; :meth:`verify` recomputes it, so corruption anywhere
    between production and promotion is detectable.
    """
    version: int
    weights: object                    # jnp.ndarray uint32[n, w]
    fingerprint: str
    origin: str = "seed"               # seed|refresh|restore|rollback
    probe_accuracy: float | None = None

    def verify(self) -> bool:
        return weight_fingerprint(self.weights) == self.fingerprint


class VersionedWeightStore:
    """Immutable, monotonically numbered weight bank with
    double-buffered swap semantics and atomic persistence.

    The store never mutates a bank in place: ``serving`` is replaced
    only by :meth:`swap_if_pending` (the between-steps swap point) and
    every promoted version is written through the atomic checkpoint
    manager before it becomes swappable.  With no ``state_dir`` the
    store is memory-only (rollback falls back to the in-memory history
    of promoted versions).
    """

    def __init__(self, seed_weights, *, state_dir=None, keep: int = 4):
        self._lock = threading.Lock()
        self.keep = keep
        self.ckpt = (CheckpointManager(state_dir, keep=keep,
                                       async_save=False)
                     if state_dir is not None else None)
        # --- counters / audit trail ------------------------------------
        self.staged = 0
        self.promotions = 0            # refresh promotions (not seed)
        self.rejected = 0
        self.rollbacks = 0
        self.rollback_load_failures = 0  # missing/torn rollback targets
        self.save_crashes = 0
        self.events = RingLog(cap=256)   # bounded audit trail
        self.promoted_order: list[int] = []   # every live-able version
        self.demoted: set[int] = set()        # rolled-back versions
        self._history: dict[int, WeightVersion] = {}
        self._pending: WeightVersion | None = None

        seed_w = jnp.asarray(seed_weights, jnp.uint32)
        restored = None
        if self.ckpt is not None:
            purged = self.ckpt.purge_tmp()
            if purged:
                self.events.append({"event": "purged_torn_saves",
                                    "dirs": purged})
            step = self.ckpt.latest_step()
            if step is not None:
                restored = self._load(step, seed_w.shape,
                                      origin="restore")
        if restored is not None:
            self._serving = restored
            self.events.append({"event": "restored",
                                "version": restored.version})
        else:
            self._serving = WeightVersion(0, seed_w,
                                          weight_fingerprint(seed_w),
                                          origin="seed")
            if self.ckpt is not None:
                self._persist(self._serving)
        self.promoted_order.append(self._serving.version)
        self._history[self._serving.version] = self._serving
        self._next = self._serving.version + 1

    # --- persistence ---------------------------------------------------

    def _persist(self, ver: WeightVersion) -> None:
        acc = (float("nan") if ver.probe_accuracy is None
               else float(ver.probe_accuracy))
        self.ckpt.save(ver.version, {
            "weights": np.asarray(ver.weights, np.uint32),
            "probe_accuracy": np.float64(acc)})

    def _load(self, version: int, shape, *, origin: str
              ) -> WeightVersion:
        like = {"weights": np.zeros(shape, np.uint32),
                "probe_accuracy": np.float64(0)}
        tree, got = self.ckpt.restore(version, like)
        acc = float(tree["probe_accuracy"])
        w = jnp.asarray(tree["weights"], jnp.uint32)
        return WeightVersion(got, w, weight_fingerprint(w),
                             origin=origin,
                             probe_accuracy=None if np.isnan(acc)
                             else acc)

    def _write_torn(self, version: int) -> None:
        """Leave exactly what a crash mid-save leaves: a ``.tmp``
        directory with partial contents and no manifest."""
        tmp = self.ckpt.dir / f"step_{version}.tmp"
        tmp.mkdir(parents=True, exist_ok=True)
        (tmp / "weights.proc0.npy").write_bytes(b"\x93NUMPY torn")

    # --- lifecycle -----------------------------------------------------

    @property
    def serving(self) -> WeightVersion:
        """The promoted version traffic sees (pin it per batch step)."""
        return self._serving

    def stage(self, weights, *, origin: str = "refresh"
              ) -> WeightVersion:
        """Number a candidate bank.  Staged versions are invisible to
        traffic until promoted; the fingerprint is taken here, so any
        later mutation of the bank is detectable by ``verify()``."""
        with self._lock:
            v = self._next
            self._next += 1
            self.staged += 1
        return WeightVersion(v, jnp.asarray(weights, jnp.uint32),
                             weight_fingerprint(weights), origin=origin)

    def reject(self, cand: WeightVersion, reason: str) -> None:
        """Drop a candidate (never visible to traffic)."""
        with self._lock:
            self.rejected += 1
            self.events.append({"event": "rejected",
                                "version": cand.version,
                                "reason": reason})

    def promote(self, cand: WeightVersion, *, on_save=None) -> bool:
        """Persist a candidate and queue it for the next between-steps
        swap.  ``on_save`` (the fault hook) is consulted before the
        write with ``{"kind": "save", ...}``; if it raises, the store
        simulates the crash it models — a torn ``.tmp`` directory is
        left on disk, the promotion is aborted, and False is returned
        (the serving bank is untouched, exactly as a restarted process
        would observe).  Candidates must verify their fingerprint."""
        if not cand.verify():
            raise ValueError(f"refusing to promote version "
                             f"{cand.version}: fingerprint mismatch "
                             "(corrupt candidate)")
        with self._lock:
            if self.ckpt is not None:
                if on_save is not None:
                    try:
                        on_save({"kind": "save",
                                 "version": cand.version})
                    except Exception as e:  # noqa: BLE001 — crash sim
                        self.save_crashes += 1
                        self._write_torn(cand.version)
                        self.events.append({
                            "event": "save_crash",
                            "version": cand.version,
                            "error": f"{type(e).__name__}: {e}"})
                        return False
                self._persist(cand)
            self._history[cand.version] = cand
            self.promoted_order.append(cand.version)
            self.promotions += 1
            self._pending = cand
            self.events.append({"event": "promoted",
                                "version": cand.version,
                                "probe_accuracy": cand.probe_accuracy})
            # trim the in-memory history like the on-disk keep-k
            for v in sorted(self._history)[:-max(self.keep, 1)]:
                if v != self._serving.version:
                    del self._history[v]
        return True

    def swap_if_pending(self) -> bool:
        """Apply a queued promotion/rollback.  This is the ONLY place
        ``serving`` changes — call it between serving steps, never
        while a batch is in flight."""
        with self._lock:
            if self._pending is None:
                return False
            self._serving = self._pending
            self._pending = None
            return True

    # --- rollback ------------------------------------------------------

    def _rollback_target(self) -> int | None:
        cur = (self._pending or self._serving).version
        for v in reversed(self.promoted_order):
            if v != cur and v not in self.demoted:
                return v
        return None

    def can_rollback(self) -> bool:
        return self._rollback_target() is not None

    def is_live(self, version: int) -> bool:
        """Whether a version is currently serveable: promoted at some
        point and never rolled back."""
        return (version in self.promoted_order
                and version not in self.demoted)

    def get(self, version: int) -> WeightVersion | None:
        """A promoted version still in the in-memory history (keep-k
        trimmed), e.g. for per-version oracle audits."""
        return self._history.get(version)

    def _load_rollback_target(self, tgt_v: int, shape
                              ) -> WeightVersion | None:
        """One rollback target's weights, or None when they are
        unrecoverable (checkpoint missing or torn AND trimmed from the
        in-memory keep-k history).  Never raises: a torn target is
        counted, its droppings are purged through the same
        ``purge_tmp`` path a restart uses, and the caller degrades to
        the next-older target."""
        from_disk = (self.ckpt is not None
                     and tgt_v in self.ckpt.all_steps())
        if from_disk:
            try:
                return self._load(tgt_v, shape, origin="rollback")
            except Exception as e:  # noqa: BLE001 — torn checkpoint
                self.rollback_load_failures += 1
                self.ckpt.purge_tmp()
                shutil.rmtree(self.ckpt.dir / f"step_{tgt_v}",
                              ignore_errors=True)
                self.events.append({
                    "event": "rollback_target_torn", "version": tgt_v,
                    "error": f"{type(e).__name__}: {e}"})
        if tgt_v in self._history:
            return dataclasses.replace(self._history[tgt_v],
                                       origin="rollback")
        if not from_disk:
            self.rollback_load_failures += 1
            self.events.append({"event": "rollback_target_missing",
                                "version": tgt_v})
        return None

    def rollback(self, reason: str = "") -> WeightVersion | None:
        """Demote the serving version and queue the previous promoted
        version for the next between-steps swap.  The target's weights
        are re-read from disk when a ``state_dir`` is present —
        bit-exact with the persisted checkpoint — else from the
        in-memory promotion history.  A missing or torn target is
        *counted and degraded past* (``rollback_load_failures``), never
        raised: the store walks to the next-older promoted version, and
        returns None only when every candidate target is gone — the
        serving bank then stays live, which beats crashing the serve
        loop over history bookkeeping.  The demoted version's
        checkpoint is deleted, so a process restart converges with
        post-rollback serving (the newest *complete* version on disk is
        the rollback target, never a demoted bank).  Returns the queued
        version (None when there is nothing usable to roll back to)."""
        with self._lock:
            cur = self._pending or self._serving
            shape = np.asarray(cur.weights).shape
            while True:
                tgt_v = self._rollback_target()
                if tgt_v is None:
                    return None
                tgt = self._load_rollback_target(tgt_v, shape)
                if tgt is not None:
                    break
                # unrecoverable target: demote it and keep walking
                self.demoted.add(tgt_v)
            self.demoted.add(cur.version)
            if self.ckpt is not None:
                shutil.rmtree(self.ckpt.dir / f"step_{cur.version}",
                              ignore_errors=True)
            self._pending = tgt
            self.rollbacks += 1
            self.events.append({"event": "rollback",
                                "from": cur.version, "to": tgt.version,
                                "reason": reason})
            return tgt

    # --- stats ---------------------------------------------------------

    def stats(self) -> dict:
        s = self._serving
        return {
            "weight_version": s.version,
            "weight_origin": s.origin,
            "versions_staged": self.staged,
            "versions_promoted": self.promotions,
            "versions_rejected": self.rejected,
            "rollbacks": self.rollbacks,
            "rollback_load_failures": self.rollback_load_failures,
            "save_crashes": self.save_crashes,
        }


@dataclasses.dataclass(frozen=True)
class SNNRefreshPolicy:
    """Knobs of the probe-gated online refresh path.  Frozen, like the
    serving policy: one refresh contract per engine."""
    refresh_every: int = 8           # serving steps between refreshes
    probe_size: int = 32             # held-out probe samples
    max_regression: float = 0.0      # allowed probe-accuracy drop
    refresh_samples: int = 32        # training samples per refresh
    refresh_timeout_ms: float | None = None  # stalled-refresh abort

    def __post_init__(self):
        if self.refresh_every < 0:
            raise ValueError(f"refresh_every must be >= 0, got "
                             f"{self.refresh_every}")
        if self.probe_size < 1:
            raise ValueError(f"probe_size must be >= 1, got "
                             f"{self.probe_size}")
        if self.max_regression < 0:
            raise ValueError(f"max_regression must be >= 0, got "
                             f"{self.max_regression}")
        if self.refresh_samples < 1:
            raise ValueError(f"refresh_samples must be >= 1, got "
                             f"{self.refresh_samples}")
        if (self.refresh_timeout_ms is not None
                and self.refresh_timeout_ms <= 0):
            raise ValueError(f"refresh_timeout_ms must be > 0 or None, "
                             f"got {self.refresh_timeout_ms}")


class SNNWeightRefresher:
    """Builds and probes candidate weight versions for a serving engine.

    ``plan`` must be a *learning* plan (``w_exp`` set); training runs
    through :func:`repro.engine.refresh_weights` on the plan's mesh
    placement.  ``intensities``/``labels`` are the labeled refresh
    stream (uint8[N, n_in] / int[N]); each refresh cycle takes the next
    ``policy.refresh_samples``-sized slice (cyclic) with **epoch-keyed
    counter seeds**, so every cycle re-presents data with fresh Poisson
    draws.  ``probe_intensities``/``probe_labels`` are the fixed
    held-out probe set (truncated to ``policy.probe_size``), encoded
    with fixed seeds so probe accuracy is a pure function of the
    weights — the regression gate compares candidates and the serving
    bank on identical inputs.
    """

    _PROBE_SEED_SALT = 0x5EED

    def __init__(self, plan: SNNEnginePlan, intensities, labels, *,
                 n_classes: int, probe_intensities, probe_labels,
                 neuron_class, n_steps: int,
                 policy: SNNRefreshPolicy | None = None,
                 teach_pos: int = 64, teach_neg: int = -1024,
                 ltp_prob=None):
        if not plan.learn:
            raise ValueError("SNNWeightRefresher needs a learning plan "
                             "(w_exp is None)")
        self.plan = plan
        self.policy = policy if policy is not None else SNNRefreshPolicy()
        self.n_classes = int(n_classes)
        self.n_steps = int(n_steps)
        self.teach_pos, self.teach_neg = teach_pos, teach_neg
        self.ltp_prob = ltp_prob
        self.intensities = np.asarray(intensities, np.uint8)
        self.labels = np.asarray(labels, np.int64)
        if self.intensities.shape[0] != self.labels.shape[0]:
            raise ValueError("intensities and labels disagree on N")
        self.neuron_class = np.asarray(neuron_class)
        k = self.policy.probe_size
        self._probe_inten = jnp.asarray(
            np.asarray(probe_intensities, np.uint8)[:k])
        self._probe_labels = np.asarray(probe_labels)[:k]
        self._probe_seeds = sample_seeds(
            plan.encode_seed + self._PROBE_SEED_SALT,
            int(self._probe_inten.shape[0]))
        self._train_eng = SNNEngine(plan)
        self._probe_eng = SNNEngine(
            dataclasses.replace(plan, w_exp=None))
        self.epochs_run = 0

    def next_candidate(self, weights) -> tuple[jnp.ndarray, int]:
        """Train one candidate bank from ``weights`` on the next cyclic
        refresh slice; returns (candidate weights, refresh epoch).  The
        epoch keys both the sample seeds (fresh windows) and the
        per-block LFSR chains (fresh stochastic-STDP draws)."""
        epoch = self.epochs_run
        self.epochs_run += 1
        n = self.labels.shape[0]
        k = min(self.policy.refresh_samples, n)
        idx = (np.arange(k) + epoch * k) % n
        seeds = sample_seeds_at(self.plan.encode_seed,
                                jnp.asarray(idx, jnp.uint32), epoch)
        b = int(np.asarray(weights).shape[0]) // self.n_classes
        lfsr_seeds = [((0x22A + 0x9E37 * i) ^ (0x2545 * epoch)) & 0xFFFF
                      or 0xACE1 for i in range(b)]
        cand = refresh_weights(
            self._train_eng, weights, labels=self.labels[idx],
            n_classes=self.n_classes, teach_pos=self.teach_pos,
            teach_neg=self.teach_neg,
            intensities=jnp.asarray(self.intensities[idx]),
            seeds=seeds, n_steps=self.n_steps, lfsr_seeds=lfsr_seeds,
            ltp_prob=self.ltp_prob)
        return cand, epoch

    def probe(self, weights) -> float:
        """Held-out accuracy of a bank on the fixed probe set — a pure
        function of the weights (fixed samples, fixed seeds)."""
        counts = np.asarray(self._probe_eng.infer(
            jnp.asarray(weights, jnp.uint32),
            intensities=self._probe_inten, seeds=self._probe_seeds,
            n_steps=self.n_steps))
        pred = self.neuron_class[np.argmax(counts, axis=1)]
        return float(np.mean(pred == self._probe_labels))
