"""Crash-mid-write recovery for SNN regfile pytrees.

The atomic tmp-dir + rename protocol means a writer dying at ANY point
before the rename leaves only a ``step_N.tmp/`` dropping; restore must
ignore it and pick the newest *complete* step, and ``purge_tmp`` must
clear the droppings.  Exercised with the NamedTuple SnnRegFile pytree
the versioned serving path actually persists (uint32/int32 leaves),
not just dict-of-float trees.
"""

import json

import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core.rvsnn import SnnRegFile, snn_regfile


def _regfile(seed=0x22A, n=6, w=3):
    rng = np.random.default_rng(seed)
    weights = jnp.asarray(rng.integers(0, 2**32, (n, w),
                                       dtype=np.uint32))
    return snn_regfile(weights, seed=seed)


def _assert_regfile_equal(a: SnnRegFile, b: SnnRegFile):
    for name in SnnRegFile._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, name)), np.asarray(getattr(b, name)),
            err_msg=f"leaf {name} diverged")


def test_regfile_roundtrip_preserves_dtypes(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3, async_save=False)
    rf = _regfile()
    mgr.save(1, rf)
    got, step = mgr.restore(None, rf)
    assert step == 1
    _assert_regfile_equal(got, rf)
    assert np.asarray(got.weights).dtype == np.uint32
    assert np.asarray(got.v).dtype == np.int32


def _torn_save(directory, step, rf, *, with_manifest=False):
    """Reproduce a writer crash: partial leaf files in ``step_N.tmp``,
    the rename never happened."""
    tmp = directory / f"step_{step}.tmp"
    tmp.mkdir()
    (tmp / "weights.proc0.npy").write_bytes(
        np.asarray(rf.weights).tobytes()[:7])   # truncated mid-leaf
    if with_manifest:
        (tmp / "manifest.json").write_text(json.dumps({"step": step}))


def test_crash_mid_write_restores_newest_complete(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=4, async_save=False)
    rf_old, rf_new = _regfile(1), _regfile(2)
    mgr.save(1, rf_old)
    mgr.save(2, rf_new)
    # a later save died mid-write: torn tmp only, never renamed
    _torn_save(tmp_path, 3, _regfile(3))
    assert mgr.all_steps() == [1, 2]            # tmp never listed
    got, step = mgr.restore(None, rf_new)
    assert step == 2
    _assert_regfile_equal(got, rf_new)


def test_torn_tmp_with_manifest_still_ignored(tmp_path):
    """Even a tmp dir that got as far as writing manifest.json is not a
    checkpoint — only the atomic rename publishes a step."""
    mgr = CheckpointManager(tmp_path, keep=4, async_save=False)
    rf = _regfile(1)
    mgr.save(7, rf)
    _torn_save(tmp_path, 9, _regfile(9), with_manifest=True)
    assert mgr.all_steps() == [7]
    got, step = mgr.restore(None, rf)
    assert step == 7
    _assert_regfile_equal(got, rf)


def test_purge_tmp_clears_droppings_and_keeps_steps(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=4, async_save=False)
    rf = _regfile(1)
    mgr.save(1, rf)
    _torn_save(tmp_path, 2, _regfile(2))
    _torn_save(tmp_path, 5, _regfile(5))
    purged = mgr.purge_tmp()
    assert sorted(purged) == ["step_2.tmp", "step_5.tmp"]
    assert not list(tmp_path.glob("*.tmp"))
    assert mgr.all_steps() == [1]
    _assert_regfile_equal(mgr.restore(None, rf)[0], rf)
    assert mgr.purge_tmp() == []                # idempotent


def test_interrupted_rewrite_of_same_step(tmp_path):
    """A crash while REWRITING an existing step must not damage the
    published copy: the torn tmp sits next to the complete step dir."""
    mgr = CheckpointManager(tmp_path, keep=4, async_save=False)
    rf = _regfile(4)
    mgr.save(4, rf)
    _torn_save(tmp_path, 4, _regfile(40))
    got, step = mgr.restore(None, rf)
    assert step == 4
    _assert_regfile_equal(got, rf)
    mgr.purge_tmp()
    # and a fresh save of the same step still goes through cleanly
    rf2 = _regfile(41)
    mgr.save(4, rf2)
    _assert_regfile_equal(mgr.restore(4, rf2)[0], rf2)
