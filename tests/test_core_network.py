"""Integration tests: encoder, preprocess, network execution, trainer."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import network
from repro.core.bitpack import pack, unpack
from repro.core.encoder import poisson_encode, poisson_encode_batch
from repro.core.lif import lif_params
from repro.core.preprocess import deskew, preprocess, soft_threshold
from repro.core.rvsnn import snn_regfile
from repro.core.stdp import init_weights, stdp_params
from repro.core.trainer import SNNTrainConfig, accuracy, train
from repro.data.digits import make_digits


def test_poisson_rate_matches_intensity():
    x = jnp.array([0.0, 0.25, 0.5, 1.0] * 50)
    packed = poisson_encode(jax.random.key(0), x, 400)
    rates = unpack(packed, x.shape[0]).astype(np.float32).mean(axis=0)
    np.testing.assert_allclose(np.asarray(rates), np.asarray(x), atol=0.08)


def test_poisson_zero_and_one_are_deterministic():
    x = jnp.array([0.0, 1.0])
    packed = poisson_encode(jax.random.key(1), x, 64)
    bits = np.asarray(unpack(packed, 2))
    assert (bits[:, 0] == 0).all()
    assert (bits[:, 1] == 1).all()


def test_deskew_identity_on_symmetric():
    img = jnp.zeros((28, 28)).at[:, 13:15].set(1.0)
    out = np.asarray(deskew(img))
    np.testing.assert_allclose(out, np.asarray(img), atol=1e-3)


def test_deskew_straightens_shear():
    # Build a sheared vertical bar and check deskew concentrates columns.
    img = np.zeros((28, 28), np.float32)
    for y in range(28):
        x = int(13 + 0.4 * (y - 14))
        img[y, x] = 1.0
    out = np.asarray(deskew(jnp.asarray(img)))
    width = lambda im: (im.sum(axis=0) > 0.2).sum()
    assert width(out) < width(img)


def test_soft_threshold_zeroes_noise():
    img = jnp.array([[0.05, 0.2, 1.0]])
    out = np.asarray(soft_threshold(img, 0.1))
    assert out[0, 0] == 0.0
    assert 0.1 < out[0, 1] < 0.2
    assert abs(out[0, 2] - 1.0) < 1e-6


def test_inference_counts_bounded_and_deterministic():
    n, n_in, T = 8, 64, 32
    w = init_weights(n, 2, dense=True)
    key = jax.random.key(3)
    trains = poisson_encode_batch(
        key, jax.random.uniform(key, (4, n_in)), T)
    lif = lif_params(threshold=16, leak=1)
    c1 = network.infer_batch(w, trains, lif)
    c2 = network.infer_batch(w, trains, lif)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    assert (np.asarray(c1) >= 0).all() and (np.asarray(c1) <= T).all()


def test_training_changes_weights_only_for_fired_rows():
    n, words, T = 4, 2, 16
    w0 = init_weights(n, words, dense=True)
    rf = snn_regfile(w0)
    trains = poisson_encode_batch(
        jax.random.key(5), jnp.full((2, 60), 0.8), T)
    # teacher: drive neuron 0, inhibit the rest hard
    teach = jnp.tile(jnp.array([[100, -10000, -10000, -10000]], jnp.int32),
                     (2, 1))
    lif = lif_params(threshold=8, leak=0)
    stdp = stdp_params(60, w_exp=16)
    rf2, counts = network.train_stream(rf, trains, teach, lif, stdp)
    w2 = np.asarray(rf2.weights)
    assert (w2[0] != np.asarray(w0)[0]).any()          # learned
    np.testing.assert_array_equal(w2[1:], np.asarray(w0)[1:])  # inhibited
    assert (np.asarray(counts)[:, 1:] == 0).all()


def test_homeostasis_prunes_to_budget():
    """After training, ON-counts sit near w_exp (paper §3.3)."""
    imgs, labels = make_digits(300, seed=11)
    cfg = SNNTrainConfig(n_neurons=10, w_exp=128, epochs=1, n_steps=48)
    model = train(cfg, imgs, labels)
    on = unpack(model.weights, 784).sum(axis=1)
    assert (np.asarray(on) < 128 * 2).all()
    assert (np.asarray(on) > 128 // 3).all()


@pytest.mark.slow
def test_end_to_end_learning_beats_chance():
    imgs, labels = make_digits(800, seed=21)
    timgs, tlabels = make_digits(200, seed=22)
    cfg = SNNTrainConfig(n_neurons=10, epochs=1)
    model = train(cfg, imgs, labels)
    st = poisson_encode_batch(jax.random.key(9), jnp.asarray(timgs),
                              cfg.n_steps)
    acc = accuracy(model, st, jnp.asarray(tlabels))
    assert acc > 0.35  # chance is 0.10


def test_window_and_step_paths_bit_exact():
    """cycle_backend="window" == the per-cycle scan, full regfile."""
    n, words, T, B = 12, 3, 20, 4
    w0 = init_weights(n, words, dense=False)
    lif = lif_params(40, 3)
    stdp = stdp_params(words * 32, w_exp=30, gain=4, ltp_prob=500)
    key = jax.random.key(31)
    trains = poisson_encode_batch(
        key, jax.random.uniform(key, (B, words * 32)), T)
    teach = jnp.asarray(
        np.random.default_rng(2).integers(-50, 50, (B, n), dtype=np.int32))
    rf = snn_regfile(w0)
    rf_w, c_w = network.train_stream(rf, trains, teach, lif, stdp,
                                     cycle_backend="window")
    rf_s, c_s = network.train_stream(rf, trains, teach, lif, stdp,
                                     cycle_backend="step")
    for a, b in [(rf_w.weights, rf_s.weights), (rf_w.v, rf_s.v),
                 (rf_w.lfsr, rf_s.lfsr), (rf_w.spike, rf_s.spike),
                 (c_w, c_s)]:
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    i_w = network.infer_batch(rf_w.weights, trains, lif,
                              cycle_backend="window")
    i_s = network.infer_batch(rf_w.weights, trains, lif,
                              cycle_backend="step")
    np.testing.assert_array_equal(np.asarray(i_w), np.asarray(i_s))


def test_window_path_falls_back_under_traced_params():
    """jit with LIFParams as runtime args must still work (step path)."""
    n, words, T = 8, 2, 10
    w0 = init_weights(n, words, dense=True)
    lif = lif_params(16, 1)
    trains = poisson_encode_batch(
        jax.random.key(3), jax.random.uniform(jax.random.key(4),
                                              (2, words * 32)), T)
    jitted = jax.jit(network.infer_batch)
    got = jitted(w0, trains, lif)
    want = network.infer_batch(w0, trains, lif)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_reset_between_samples_clears_state():
    w = init_weights(3, 2)
    rf = snn_regfile(w)
    rf = rf._replace(v=jnp.array([5, 3, 1], jnp.int32),
                     spike=jnp.array([7, 7], jnp.uint32))
    rf2 = network.reset_between_samples(rf)
    assert (np.asarray(rf2.v) == 0).all()
    assert (np.asarray(rf2.spike) == 0).all()
    np.testing.assert_array_equal(np.asarray(rf2.weights), np.asarray(w))
