"""Unit tests: LFSR, bitpack, LIF, STDP primitives."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitpack, lfsr
from repro.core.lif import lif_params, lif_step
from repro.core.stdp import (init_weights, ltd_prob_from_wexp, stdp_params,
                             stdp_update)


# --- LFSR -------------------------------------------------------------------

def _lfsr_py(state: int) -> int:
    """Scalar python oracle for the 16-bit Fibonacci LFSR (taps 16,14,13,11)."""
    fb = (state ^ (state >> 2) ^ (state >> 3) ^ (state >> 5)) & 1
    return ((state >> 1) | (fb << 15)) & 0xFFFF


def test_lfsr_bit_exact_vs_python():
    states = np.array([0xACE1, 0x0001, 0xFFFF, 0x1234, 0xBEEF], np.uint32)
    s = jnp.asarray(states)
    for _ in range(100):
        expected = np.array([_lfsr_py(int(x)) for x in np.asarray(s)],
                            np.uint32)
        s = lfsr.step(s)
        np.testing.assert_array_equal(np.asarray(s), expected)


def test_lfsr_period_is_maximal():
    s0 = jnp.asarray(np.array([0xACE1], np.uint32))

    def body(i, s):
        return lfsr.step(s)

    # After 65535 steps a maximal-length 16-bit LFSR returns to the seed.
    s = jax.lax.fori_loop(0, lfsr.LFSR_PERIOD, body, s0)
    np.testing.assert_array_equal(np.asarray(s), np.asarray(s0))
    # ... and never hits it earlier over a decent prefix.
    seen = set()
    s = s0
    for _ in range(5000):
        s = lfsr.step(s)
        v = int(np.asarray(s)[0])
        assert v != 0
        assert v not in seen
        seen.add(v)


def test_lfsr_seed_nonzero_distinct():
    s = np.asarray(lfsr.seed(0, 4096))
    assert (s != 0).all()
    assert len(np.unique(s)) > 4000  # Weyl increment decorrelates lanes


def test_draw10_range():
    s = lfsr.seed(7, 1024)
    for _ in range(10):
        s, x = lfsr.draw10(s)
        assert (np.asarray(x) <= 1023).all()


# --- bitpack ----------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 31, 32, 33, 784, 1000])
def test_pack_unpack_roundtrip(n):
    rng = np.random.default_rng(n)
    bits = rng.integers(0, 2, size=(3, n)).astype(np.int32)
    packed = bitpack.pack(jnp.asarray(bits))
    assert packed.shape == (3, bitpack.n_words(n))
    out = bitpack.unpack(packed, n)
    np.testing.assert_array_equal(np.asarray(out), bits)


def test_popcount_matches_numpy():
    rng = np.random.default_rng(0)
    words = rng.integers(0, 2**32, size=(5, 25), dtype=np.uint32)
    got = np.asarray(bitpack.popcount(jnp.asarray(words)))
    want = np.array([[bin(int(w)).count("1") for w in row] for row in words]
                    ).sum(axis=1)
    np.testing.assert_array_equal(got, want)


def test_tail_mask():
    m = np.asarray(bitpack.tail_mask(70))
    assert m[0] == 0xFFFFFFFF and m[1] == 0xFFFFFFFF
    assert m[2] == (1 << 6) - 1


# --- streamlined LIF --------------------------------------------------------

def test_lif_integrate_and_fire():
    p = lif_params(threshold=10, leak=1)
    v = jnp.array([0, 5, 9, 12], jnp.int32)
    cnt = jnp.array([3, 5, 0, 0], jnp.int32)
    v2, fired = lif_step(v, cnt, p)
    np.testing.assert_array_equal(np.asarray(fired), [False, True, False, True])
    # non-fired: V+count-leak floored at 0; fired: reset to 0
    np.testing.assert_array_equal(np.asarray(v2), [2, 0, 8, 0])


def test_lif_leak_floor_at_zero():
    p = lif_params(threshold=100, leak=5)
    v = jnp.array([2], jnp.int32)
    v2, fired = lif_step(v, jnp.array([0], jnp.int32), p)
    assert int(v2[0]) == 0 and not bool(fired[0])


def test_lif_teacher_inhibition_blocks_firing():
    p = lif_params(threshold=4, leak=0)
    v = jnp.array([3, 3], jnp.int32)
    teach = jnp.array([2, -8], jnp.int32)
    v2, fired = lif_step(v, teach, p)
    assert bool(fired[0]) and not bool(fired[1])
    assert int(v2[1]) == 0  # inhibition cannot push V below 0


# --- binary stochastic STDP --------------------------------------------------

def test_ltp_sets_coincident_bits():
    n, w = 4, 2
    weights = jnp.zeros((n, w), jnp.uint32)
    pre = jnp.array([0b1010, 0b1], jnp.uint32)
    fired = jnp.array([True, False, True, False])
    st = lfsr.seed(1, n * w).reshape(n, w)
    p = stdp_params(64, w_exp=512)
    w2, _ = stdp_update(weights, pre, fired, st, p)
    w2 = np.asarray(w2)
    # fired rows gained exactly the pre bits (LTD can only clear
    # non-coincident bits, and there are none set besides pre)
    np.testing.assert_array_equal(w2[0], np.asarray(pre))
    np.testing.assert_array_equal(w2[2], np.asarray(pre))
    # non-fired rows untouched
    np.testing.assert_array_equal(w2[1], 0)
    np.testing.assert_array_equal(w2[3], 0)


def test_ltd_only_clears_noncoincident():
    n, w = 8, 4
    weights = jnp.full((n, w), 0xFFFFFFFF, jnp.uint32)
    pre = jnp.asarray(np.array([0xF0F0F0F0] * w, np.uint32))
    fired = jnp.ones((n,), bool)
    st = lfsr.seed(3, n * w).reshape(n, w)
    p = stdp_params(128, w_exp=32)  # row popcount 128 >> budget 32 -> p=1
    w2, st2 = stdp_update(weights, pre, fired, st, p)
    w2 = np.asarray(w2)
    # coincident bits always survive
    assert ((w2 & np.asarray(pre)[None]) == np.asarray(pre)[None]).all()
    # excess over the budget saturates p_ltd -> words got depressed
    assert (w2 != 0xFFFFFFFF).any()
    # LFSR advanced for fired rows
    assert (np.asarray(st2) != np.asarray(st)).any()


def test_stdp_lfsr_freezes_when_not_fired():
    n, w = 4, 2
    weights = init_weights(n, w)
    pre = jnp.zeros((w,), jnp.uint32)
    fired = jnp.zeros((n,), bool)
    st = lfsr.seed(9, n * w).reshape(n, w)
    _, st2 = stdp_update(weights, pre, fired, st, stdp_params(64, 256))
    np.testing.assert_array_equal(np.asarray(st2), np.asarray(st))


def test_wexp_monotone_ltd_prob():
    # At a fixed ON-count, a larger budget w_exp => lower LTD pressure.
    probs = [ltd_prob_from_wexp(784, w, popcount=600, gain=1)
             for w in (128, 256, 512)]
    assert probs[0] > probs[1] > probs[2]
    assert all(0 <= p <= 1023 for p in probs)
    # At/below the budget the rule is quiescent (homeostasis).
    assert ltd_prob_from_wexp(784, 256, popcount=256) == 0
    assert ltd_prob_from_wexp(784, 256, popcount=100) == 0
