"""Dry-run machinery on a small (8-device) mesh, in a subprocess.

Validates the full lowering path the production dry-run uses —
param/batch/cache spec trees -> NamedShardings -> jit lower + compile —
without the 512-device compile cost.
"""

import subprocess
import sys
import textwrap


def _run(code: str) -> str:
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       cwd="/root/repo", capture_output=True, text=True,
                       timeout=560)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    return r.stdout


def test_train_step_lowers_on_small_mesh():
    out = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp
        from repro.configs import get_config, reduced
        from repro.distributed import sharding as shd
        from repro.distributed.specs import param_logical_tree, to_shardings
        from repro.launch.train import make_train_step
        from repro.models.transformer import Model
        from repro.optim import AdamW, AdamWConfig

        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        cfg = reduced(get_config("mixtral-8x22b"))  # MoE path
        model = Model(cfg, dtype=jnp.bfloat16, attn_chunk=16,
                      loss_chunk=16)
        opt = AdamW(AdamWConfig())
        rules = shd.use_rules()
        with shd.use_mesh(mesh, rules):
            ps = jax.eval_shape(lambda: model.init_params(jax.random.key(0)))
            p_sh = to_shardings(mesh, rules, param_logical_tree(ps), ps)
            os_ = jax.eval_shape(opt.init, ps)
            none = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
            o_sh = {"m": p_sh, "v": p_sh, "step": none}
            b = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
            b_sh = {"tokens": jax.sharding.NamedSharding(
                        mesh, jax.sharding.PartitionSpec("data", None)),
                    "labels": jax.sharding.NamedSharding(
                        mesh, jax.sharding.PartitionSpec("data", None))}
            step = make_train_step(model, opt, accum_steps=2)
            rng = jax.eval_shape(lambda: jax.random.key(0))
            co = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh, none),
                         donate_argnums=(0, 1)).lower(ps, os_, b, rng
                                                      ).compile()
            mem = co.memory_analysis()
            assert mem.argument_size_in_bytes > 0
            print("LOWER_OK", mem.argument_size_in_bytes)
    """)
    assert "LOWER_OK" in out


def test_train_step_executes_sharded():
    """Not just lowering: a real sharded execution converges."""
    out = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config, reduced
        from repro.distributed import sharding as shd
        from repro.distributed.specs import param_logical_tree, to_shardings
        from repro.launch.train import make_train_step
        from repro.models.transformer import Model
        from repro.optim import AdamW, AdamWConfig
        from repro.data import SyntheticTokens

        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        cfg = reduced(get_config("starcoder2-3b"))
        model = Model(cfg, dtype=jnp.float32, attn_chunk=16, loss_chunk=16)
        opt = AdamW(AdamWConfig(lr=1e-3))
        rules = shd.use_rules(**shd.SEQPAR_RULES_OVERRIDES)
        src = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=32,
                              batch_size=8, seed=0)
        with shd.use_mesh(mesh, rules):
            params = model.init_params(jax.random.key(0))
            p_log = param_logical_tree(params)
            p_sh = to_shardings(mesh, rules, p_log, params)
            params = jax.device_put(params, p_sh)
            state = opt.init(params)
            step = jax.jit(make_train_step(model, opt))
            losses = []
            for i in range(8):
                b = {k: jnp.asarray(v) for k, v in src.batch(i).items()}
                params, state, m = step(params, state, b,
                                        jax.random.key(i))
                losses.append(float(m["loss"]))
        assert losses[-1] < losses[0], losses
        print("TRAIN_OK", losses[0], "->", losses[-1])
    """)
    assert "TRAIN_OK" in out


def test_compressed_dp_step_shard_map():
    """1-bit gradient sync (paper C3 -> DP) under shard_map trains."""
    out = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.optim.compression import compressed_psum, init_error

        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        w_true = np.linspace(-1, 1, 16).astype(np.float32)

        def local_grad(w, x, y):
            def loss(w):
                return jnp.mean((x @ w - y) ** 2)
            return jax.grad(loss)(w)

        def dp_step(w, err, x, y):
            g = local_grad(w, x, y)
            g_sync, err = compressed_psum({"w": g}, err, "data")
            return w - 0.05 * g_sync["w"], err

        step = jax.jit(jax.shard_map(
            dp_step, mesh=mesh,
            in_specs=(P(), {"w": P()}, P("data"), P("data")),
            out_specs=(P(), {"w": P()})))

        rng = np.random.default_rng(0)
        w = jnp.zeros((16,))
        err = init_error({"w": w})
        for i in range(150):
            x = rng.normal(size=(64, 16)).astype(np.float32)
            y = x @ w_true
            w, err = step(w, err, jnp.asarray(x), jnp.asarray(y))
        final = float(jnp.mean((w - w_true) ** 2))
        assert final < 0.05, final
        print("DP1BIT_OK", final)
    """)
    assert "DP1BIT_OK" in out
