"""In-kernel Poisson encode: the VMEM counter draw must be BIT-EXACT
with the ``encoder.encode_from_counter`` host oracle across every
dispatch path — ref/interp x {infer, train, train_batch} x
{unchunked, chunked, sharded} — and silent for zero intensity (the
property serving's batch padding rests on)."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lfsr
from repro.core.bitpack import unpack
from repro.core.encoder import (encode_from_counter,
                                encode_from_counter_batch,
                                quantize_intensities, spike_rate)
from repro.core.rvsnn import snn_regfile, snn_regfile_batch
from repro.distributed import snn_mesh
from repro.engine import SNNEngine, SNNEnginePlan
from repro.kernels import ops

N, W, T, B = 33, 7, 9, 3
N_IN = 200                      # < W * 32 = 224: exercises tail padding
KW = dict(threshold=60, leak=4, w_exp=64, gain=4, n_syn=N_IN,
          ltp_prob=200)


def _operands(seed=0):
    rng = np.random.default_rng(seed)
    weights = jnp.asarray(rng.integers(0, 2**32, (N, W), dtype=np.uint32))
    inten = jnp.asarray(rng.integers(0, 256, (B, N_IN), dtype=np.uint8))
    v = jnp.asarray(rng.integers(0, 200, (N,), dtype=np.int32))
    teach = jnp.asarray(rng.integers(-100, 100, (N,), dtype=np.int32))
    st = lfsr.seed(5, N * W).reshape(N, W)
    return weights, inten, v, teach, st


def _host_window(seed, inten, t_steps):
    win = encode_from_counter(seed, inten, t_steps)
    return jnp.pad(win, ((0, 0), (0, W - win.shape[1])))


# --- host oracle properties --------------------------------------------------


def test_counter_encode_rate_matches_intensity():
    inten = jnp.asarray([0, 64, 128, 255] * 50, jnp.uint8)
    bits = unpack(encode_from_counter(3, inten, 2048), inten.shape[0])
    rates = np.asarray(bits, np.float32).mean(axis=0).reshape(-1, 4)
    np.testing.assert_allclose(rates.mean(axis=0),
                               np.array([0, 64, 128, 255]) / 256,
                               atol=0.03)


def test_counter_encode_zero_intensity_is_silent():
    inten = jnp.zeros((96,), jnp.uint8)
    assert not np.asarray(encode_from_counter(11, inten, 64)).any()


def test_counter_encode_deterministic_and_seed_sensitive():
    inten = jnp.full((64,), 128, jnp.uint8)
    a = np.asarray(encode_from_counter(7, inten, 16))
    b = np.asarray(encode_from_counter(7, inten, 16))
    c = np.asarray(encode_from_counter(8, inten, 16))
    np.testing.assert_array_equal(a, b)
    assert (a != c).any()


def test_counter_encode_t0_slices_the_same_stream():
    """Any cycle range regenerates in isolation (the chunking and
    spike-register arguments rest on this)."""
    inten = jnp.asarray(np.random.default_rng(1).integers(
        0, 256, (70,), dtype=np.uint8))
    full = np.asarray(encode_from_counter(5, inten, 12))
    tail = np.asarray(encode_from_counter(5, inten, 3, t0=9))
    np.testing.assert_array_equal(full[9:], tail)


def test_quantize_intensities_round_trip_extremes():
    q = np.asarray(quantize_intensities(jnp.asarray([0.0, 0.5, 1.0])))
    np.testing.assert_array_equal(q, [0, 128, 255])


def test_spike_rate_popcount_per_time_slice():
    from repro.core.bitpack import pack
    rng = np.random.default_rng(2)
    n = 80
    bits = rng.integers(0, 2, (5, n))
    packed = pack(jnp.asarray(bits))
    np.testing.assert_allclose(np.asarray(spike_rate(packed, n)),
                               bits.mean(axis=1))


# --- op-level bit-exactness vs the host oracle -------------------------------


@pytest.mark.parametrize("backend", ["ref", "interp"])
@pytest.mark.parametrize("train", [True, False])
@pytest.mark.parametrize("t_chunk", [None, 4, 2])
def test_fused_window_encode_matches_host_oracle(backend, train, t_chunk):
    weights, inten, v, teach, st = _operands(3)
    got = ops.fused_snn_window_encode(
        weights, inten[0], 7, v, st, teach, n_steps=T, train=train,
        t_chunk=t_chunk, backend=backend, **KW)
    want = ops.fused_snn_window(
        weights, _host_window(7, inten[0], T), v, st, teach, train=train,
        t_chunk=t_chunk, backend=backend, **KW)
    for g, r in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(r))


@pytest.mark.parametrize("backend", ["ref", "interp"])
@pytest.mark.parametrize("t_chunk", [None, 4])
def test_train_batch_encode_matches_host_oracle(backend, t_chunk):
    weights, inten, _, _, _ = _operands(4)
    rng = np.random.default_rng(4)
    wts = jnp.asarray(rng.integers(0, 2**32, (B, N, W), dtype=np.uint32))
    vb = jnp.asarray(rng.integers(0, 200, (B, N), dtype=np.int32))
    tb = jnp.asarray(rng.integers(-100, 100, (B, N), dtype=np.int32))
    stb = jnp.stack([lfsr.seed(11 + i, N * W).reshape(N, W)
                     for i in range(B)])
    seeds = jnp.asarray([3, 9, 27], jnp.int32)
    lp = jnp.asarray([16, 500, 1023], jnp.int32)
    kw = {k: v for k, v in KW.items() if k != "ltp_prob"}
    got = ops.train_window_batch_encode(
        wts, inten, seeds, vb, stb, tb, n_steps=T, ltp_prob=lp,
        t_chunk=t_chunk, backend=backend, **kw)
    wins = encode_from_counter_batch(seeds, inten, T)
    wins = jnp.pad(wins, ((0, 0), (0, 0), (0, W - wins.shape[2])))
    want = ops.train_window_batch(
        wts, wins, vb, stb, tb, ltp_prob=lp, t_chunk=t_chunk,
        backend=backend, **kw)
    for g, r in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(r))


@pytest.mark.parametrize("backend", ["ref", "interp"])
@pytest.mark.parametrize("t_chunk", [None, 4])
def test_infer_batch_encode_ragged_matches_host_oracle(backend, t_chunk):
    """Per-sample t_total (SMEM-masked in kernel, zero-masked on host)
    returns the counts of serving each sample at its true length."""
    weights, inten, _, _, _ = _operands(5)
    seeds = jnp.asarray([1, 2, 3], jnp.int32)
    tt = [T, 5, 2]
    got = ops.infer_window_batch_encode(
        weights, inten, seeds, n_steps=T, threshold=60, leak=4,
        t_total=jnp.asarray(tt), t_chunk=t_chunk, backend=backend)
    for i, t_i in enumerate(tt):
        want = ops.infer_window_batch(
            weights, _host_window(seeds[i], inten[i], t_i)[None],
            threshold=60, leak=4, backend=backend)[0]
        np.testing.assert_array_equal(np.asarray(got[i]),
                                      np.asarray(want))


def test_encode_sharded_matches_unsharded_local_mesh():
    mesh = snn_mesh.snn_mesh()
    weights, inten, v, teach, st = _operands(6)
    seeds = jnp.asarray([4, 5, 6], jnp.int32)
    got = snn_mesh.sharded_infer_window_batch_encode(
        weights, inten, seeds, n_steps=T, threshold=60, leak=4,
        mesh=mesh)
    want = ops.infer_window_batch_encode(
        weights, inten, seeds, n_steps=T, threshold=60, leak=4)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    for train in (True, False):
        got = snn_mesh.sharded_fused_snn_window_encode(
            weights, inten[0], 7, v, st, teach, n_steps=T, train=train,
            mesh=mesh, **KW)
        want = ops.fused_snn_window_encode(
            weights, inten[0], 7, v, st, teach, n_steps=T, train=train,
            **KW)
        for g, r in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(r))


# --- engine verbs: encode placement is invisible to results ------------------


def _plans(**over):
    base = dict(KW, encode_seed=42, **over)
    return (SNNEnginePlan(**base, encode="host"),
            SNNEnginePlan(**base, encode="kernel"))


@pytest.mark.parametrize("kb,t_chunk", [("ref", None), ("interp", 5)])
def test_engine_verbs_host_vs_kernel_encode(kb, t_chunk):
    weights, inten, _, teach, _ = _operands(7)
    rng = np.random.default_rng(7)
    teach_b = jnp.asarray(rng.integers(-50, 50, (B, N), dtype=np.int32))
    ph, pk = _plans(kernel_backend=kb, t_chunk=t_chunk)
    eh, ek = SNNEngine(ph), SNNEngine(pk)

    tt = jnp.asarray([T, 7, 3])
    np.testing.assert_array_equal(
        np.asarray(eh.infer(weights, intensities=inten, n_steps=T,
                            t_total=tt)),
        np.asarray(ek.infer(weights, intensities=inten, n_steps=T,
                            t_total=tt)))

    rf = snn_regfile(weights, seed=9)
    oa = eh.train(rf, intensities=inten[0], teach=teach, n_steps=T)
    ob = ek.train(rf, intensities=inten[0], teach=teach, n_steps=T)
    for x, y in zip(oa.regfile, ob.regfile):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    np.testing.assert_array_equal(np.asarray(oa.fired),
                                  np.asarray(ob.fired))

    rfs = snn_regfile_batch(
        jnp.asarray(rng.integers(0, 2**32, (B, N, W), dtype=np.uint32)),
        [1, 2, 3])
    ra, ca, fa = eh.train_batch(rfs, intensities=inten, teach=teach_b,
                                n_steps=T)
    rb, cb, fb = ek.train_batch(rfs, intensities=inten, teach=teach_b,
                                n_steps=T)
    for x, y in zip(ra, rb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    np.testing.assert_array_equal(np.asarray(ca), np.asarray(cb))
    np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))


def test_train_batch_accepts_omitted_teach():
    """teach=None (now that the signature allows it) means zero teacher
    current on every path, same as train()."""
    weights, inten, _, _, _ = _operands(9)
    rng = np.random.default_rng(9)
    wts = jnp.asarray(rng.integers(0, 2**32, (B, N, W), dtype=np.uint32))
    rfs = snn_regfile_batch(wts, [4, 5, 6])
    ph, pk = _plans()
    for eng in (SNNEngine(ph), SNNEngine(pk)):
        rfs2, counts, _ = eng.train_batch(rfs, intensities=inten,
                                          n_steps=T)
        want = eng.train_batch(rfs, intensities=inten, n_steps=T,
                               teach=jnp.zeros((B, N), jnp.int32))
        np.testing.assert_array_equal(np.asarray(counts),
                                      np.asarray(want[1]))


def test_engine_rejects_ambiguous_inputs():
    weights, inten, _, _, _ = _operands(8)
    eng = SNNEngine(SNNEnginePlan(**KW))
    with pytest.raises(ValueError):
        eng.infer(weights)                       # neither form
    with pytest.raises(ValueError):
        eng.infer(weights, intensities=inten)    # missing n_steps
    with pytest.raises(ValueError):
        eng.infer(weights, jnp.zeros((B, T, W), jnp.uint32),
                  intensities=inten, n_steps=T)  # both forms


def test_plan_encode_validation():
    with pytest.raises(ValueError):
        SNNEnginePlan(encode="vmem")
    with pytest.raises(ValueError):
        SNNEnginePlan(encode="kernel", cycle_backend="step")
    assert SNNEnginePlan(encode="kernel").encode_seed == 0
    cfg_plan = dataclasses.replace(SNNEnginePlan(), encode_seed=7)
    assert cfg_plan.encode == "host"
