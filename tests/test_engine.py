"""Engine-vs-legacy bit-exactness parity across all four dispatch paths
(step / window / batch / sharded), plan validation, and the per-stream
``ltp_prob`` schedule."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lfsr, network
from repro.core.lif import lif_params
from repro.core.rvsnn import snn_regfile, snn_regfile_batch
from repro.core.stdp import stdp_params
from repro.core.trainer import SNNTrainConfig, train
from repro.data.digits import make_digits
from repro.distributed import snn_mesh
from repro.engine import (SNNEngine, SNNEnginePlan, plan_from_config,
                          train_stream, train_stream_batch)
from repro.kernels import ops

N, W, T, B = 24, 5, 12, 3
KW = dict(threshold=40, leak=3, w_exp=30, gain=4, n_syn=W * 32,
          ltp_prob=500)


def _plan(**over):
    return SNNEnginePlan(**{**KW, **over})


def _operands(seed=0):
    rng = np.random.default_rng(seed)
    weights = jnp.asarray(rng.integers(0, 2**32, (N, W), dtype=np.uint32))
    windows = jnp.asarray(
        rng.integers(0, 2**32, (B, T, W), dtype=np.uint32))
    teach = jnp.asarray(rng.integers(-50, 50, (N,), dtype=np.int32))
    return weights, windows, teach


def _assert_rf_equal(a, b):
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# --- plan validation ---------------------------------------------------------

def test_plan_validation():
    with pytest.raises(ValueError):
        _plan(cycle_backend="windw")
    with pytest.raises(ValueError):
        _plan(kernel_backend="cuda")
    with pytest.raises(ValueError):
        _plan(t_chunk=0)
    with pytest.raises(ValueError):
        _plan(max_batch=0)
    with pytest.raises(ValueError):
        _plan(cycle_backend="step", mesh=snn_mesh.snn_mesh())
    assert not _plan(w_exp=None).learn
    assert _plan().learn


def test_plan_from_config_active_schedule():
    cfg = SNNTrainConfig(ltp_prob=16, ltp_prob_active=1023)
    assert plan_from_config(cfg).ltp_prob == 16
    assert plan_from_config(cfg, block_idx=1).ltp_prob == 1023
    assert plan_from_config(cfg).n_syn == cfg.n_inputs


# --- infer: window / step / interp / legacy ---------------------------------

def test_infer_parity_all_paths():
    weights, windows, _ = _operands()
    lif = lif_params(KW["threshold"], KW["leak"])
    want = network.infer_batch(weights, windows, lif,
                               cycle_backend="step")
    for plan in (_plan(), _plan(cycle_backend="step"),
                 _plan(kernel_backend="interp", t_chunk=5)):
        got = SNNEngine(plan).infer(weights, windows)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # legacy window entrypoint agrees too
    np.testing.assert_array_equal(
        np.asarray(network.infer_batch(weights, windows, lif)),
        np.asarray(want))


# --- train: window / step / SU-idle / legacy --------------------------------

def test_train_parity_window_vs_step_vs_legacy():
    weights, windows, teach = _operands(1)
    lif = lif_params(KW["threshold"], KW["leak"])
    stdp = stdp_params(KW["n_syn"], KW["w_exp"], KW["gain"],
                       KW["ltp_prob"])
    rf = snn_regfile(weights, seed=9)
    out_w = SNNEngine(_plan()).train(rf, windows[0], teach)
    out_s = SNNEngine(_plan(cycle_backend="step")).train(
        rf, windows[0], teach)
    leg_w = network.run_sample(rf, windows[0], lif, stdp, teach)
    leg_s = network.run_sample(rf, windows[0], lif, stdp, teach,
                               cycle_backend="step")
    for other in (out_s, leg_w, leg_s):
        _assert_rf_equal(out_w.regfile, other.regfile)
        np.testing.assert_array_equal(np.asarray(out_w.spike_counts),
                                      np.asarray(other.spike_counts))
        np.testing.assert_array_equal(np.asarray(out_w.fired),
                                      np.asarray(other.fired))


def test_train_su_idle_matches_legacy_inference():
    weights, windows, _ = _operands(2)
    lif = lif_params(KW["threshold"], KW["leak"])
    rf = snn_regfile(weights, seed=4)
    got = SNNEngine(_plan(w_exp=None)).train(rf, windows[0])
    want = network.run_sample(rf, windows[0], lif, None)
    _assert_rf_equal(got.regfile, want.regfile)
    np.testing.assert_array_equal(np.asarray(got.fired),
                                  np.asarray(want.fired))
    # SU idle: weights and LFSR untouched
    np.testing.assert_array_equal(np.asarray(got.regfile.weights),
                                  np.asarray(weights))


# --- train_batch: batched grid vs sequential / step / legacy ----------------

def test_train_batch_parity_sequential_and_step():
    weights, windows, _ = _operands(3)
    rng = np.random.default_rng(7)
    wts_b = jnp.asarray(rng.integers(0, 2**32, (B, N, W),
                                     dtype=np.uint32))
    teach_b = jnp.asarray(rng.integers(-50, 50, (B, N), dtype=np.int32))
    seeds = [11, 22, 33]
    rfs = snn_regfile_batch(wts_b, seeds)
    eng = SNNEngine(_plan())
    rfs2, counts, fired = eng.train_batch(rfs, windows, teach_b)
    # stream b == one engine.train on regfile b
    for i in range(B):
        rf_i = snn_regfile(wts_b[i], seed=seeds[i])
        out = eng.train(rf_i, windows[i], teach_b[i])
        np.testing.assert_array_equal(np.asarray(rfs2.weights[i]),
                                      np.asarray(out.regfile.weights))
        np.testing.assert_array_equal(np.asarray(rfs2.lfsr[i]),
                                      np.asarray(out.regfile.lfsr))
        np.testing.assert_array_equal(np.asarray(counts[i]),
                                      np.asarray(out.spike_counts))
        np.testing.assert_array_equal(np.asarray(fired[i]),
                                      np.asarray(out.fired))
    # step fallback is bit-exact with the batched window grid
    rfs3, counts3, fired3 = SNNEngine(
        _plan(cycle_backend="step")).train_batch(rfs, windows, teach_b)
    _assert_rf_equal(rfs2, rfs3)
    np.testing.assert_array_equal(np.asarray(counts), np.asarray(counts3))
    np.testing.assert_array_equal(np.asarray(fired), np.asarray(fired3))


def test_train_batch_rejects_inference_plan():
    weights, windows, _ = _operands()
    rfs = snn_regfile_batch(
        jnp.broadcast_to(weights, (B, N, W)), [1, 2, 3])
    teach = jnp.zeros((B, N), jnp.int32)
    with pytest.raises(ValueError):
        SNNEngine(_plan(w_exp=None)).train_batch(rfs, windows, teach)


def test_stream_helpers_match_legacy_network():
    """engine.train_stream / train_stream_batch == network legacy
    entrypoints (same params threaded the old way)."""
    weights, windows, _ = _operands(5)
    lif = lif_params(KW["threshold"], KW["leak"])
    stdp = stdp_params(KW["n_syn"], KW["w_exp"], KW["gain"],
                       KW["ltp_prob"])
    n_samples = 3
    rng = np.random.default_rng(13)
    trains = jnp.asarray(rng.integers(0, 2**32, (n_samples, T, W),
                                      dtype=np.uint32))
    teach = jnp.asarray(rng.integers(-50, 50, (n_samples, N),
                                     dtype=np.int32))
    eng = SNNEngine(_plan())
    rf = snn_regfile(weights, seed=21)
    got_rf, got_c = train_stream(eng, rf, trains, teach)
    want_rf, want_c = network.train_stream(rf, trains, teach, lif, stdp)
    _assert_rf_equal(got_rf, want_rf)
    np.testing.assert_array_equal(np.asarray(got_c), np.asarray(want_c))

    wts_b = jnp.broadcast_to(weights, (B, N, W))
    rfs = snn_regfile_batch(wts_b, [5, 6, 7])
    trains_b = jnp.broadcast_to(trains, (B,) + trains.shape)
    teach_b = jnp.broadcast_to(teach, (B,) + teach.shape)
    got_rfs, got_cb = train_stream_batch(eng, rfs, trains_b, teach_b)
    want_rfs, want_cb = network.train_stream_batch(rfs, trains_b,
                                                   teach_b, lif, stdp)
    _assert_rf_equal(got_rfs, want_rfs)
    np.testing.assert_array_equal(np.asarray(got_cb),
                                  np.asarray(want_cb))


# --- sharded dispatch (plan placement) ---------------------------------------

def test_sharded_plan_parity_all_verbs():
    """Verbs under a neuron mesh == unsharded verbs == legacy snn_mesh
    entrypoints (whatever mesh this process has)."""
    mesh = snn_mesh.snn_mesh()
    weights, windows, teach = _operands(6)
    plan_m = _plan(mesh=mesh)
    plan_1 = _plan()
    eng_m, eng_1 = SNNEngine(plan_m), SNNEngine(plan_1)

    got = eng_m.infer(weights, windows)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(eng_1.infer(weights, windows)))
    np.testing.assert_array_equal(
        np.asarray(got),
        np.asarray(snn_mesh.sharded_infer_window_batch(
            weights, windows, threshold=KW["threshold"],
            leak=KW["leak"], mesh=mesh)))

    rf = snn_regfile(weights, seed=31)
    out_m = eng_m.train(rf, windows[0], teach)
    out_1 = eng_1.train(rf, windows[0], teach)
    _assert_rf_equal(out_m.regfile, out_1.regfile)
    np.testing.assert_array_equal(np.asarray(out_m.fired),
                                  np.asarray(out_1.fired))

    rng = np.random.default_rng(17)
    wts_b = jnp.asarray(rng.integers(0, 2**32, (B, N, W),
                                     dtype=np.uint32))
    teach_b = jnp.asarray(rng.integers(-50, 50, (B, N), dtype=np.int32))
    rfs = snn_regfile_batch(wts_b, [41, 42, 43])
    lp = jnp.asarray([100, 500, 900], jnp.int32)
    got_m = eng_m.train_batch(rfs, windows, teach_b, ltp_prob=lp)
    got_1 = eng_1.train_batch(rfs, windows, teach_b, ltp_prob=lp)
    _assert_rf_equal(got_m[0], got_1[0])
    np.testing.assert_array_equal(np.asarray(got_m[1]),
                                  np.asarray(got_1[1]))
    np.testing.assert_array_equal(np.asarray(got_m[2]),
                                  np.asarray(got_1[2]))


def test_sharded_train_batch_non_divisible_rows():
    """Stream rows not divisible by the mesh pad + slice transparently."""
    mesh = snn_mesh.snn_mesh()
    d = mesh.shape["neuron"]
    n = d * 2 + 1
    rng = np.random.default_rng(23)
    wts = jnp.asarray(rng.integers(0, 2**32, (2, n, W), dtype=np.uint32))
    spk = jnp.asarray(rng.integers(0, 2**32, (2, T, W), dtype=np.uint32))
    v = jnp.zeros((2, n), jnp.int32)
    teach = jnp.asarray(rng.integers(-50, 50, (2, n), dtype=np.int32))
    st = jnp.stack([lfsr.seed(3 + i, n * W).reshape(n, W)
                    for i in range(2)])
    kw = {k: v2 for k, v2 in KW.items() if k != "ltp_prob"}
    got = snn_mesh.sharded_train_window_batch(
        wts, spk, v, st, teach, ltp_prob=200, mesh=mesh, **kw)
    want = ops.train_window_batch(wts, spk, v, st, teach, ltp_prob=200,
                                  **kw)
    for g, r in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(r))


# --- per-stream ltp_prob (SMEM scalar operand) -------------------------------

@pytest.mark.parametrize("backend,t_chunk", [("ref", None),
                                             ("interp", 4)])
def test_per_stream_ltp_prob_matches_per_plan_runs(backend, t_chunk):
    """train_batch with an i32[B] schedule == per-stream train calls,
    each under a plan pinned to that stream's ltp_prob."""
    weights, windows, _ = _operands(8)
    rng = np.random.default_rng(29)
    wts_b = jnp.asarray(rng.integers(0, 2**32, (B, N, W),
                                     dtype=np.uint32))
    teach_b = jnp.asarray(rng.integers(-50, 50, (B, N), dtype=np.int32))
    seeds = [51, 52, 53]
    rfs = snn_regfile_batch(wts_b, seeds)
    lp = jnp.asarray([16, 500, 1023], jnp.int32)
    eng = SNNEngine(_plan(kernel_backend=backend, t_chunk=t_chunk))
    rfs2, counts, _ = eng.train_batch(rfs, windows, teach_b, ltp_prob=lp)
    for i in range(B):
        plan_i = _plan(kernel_backend=backend, t_chunk=t_chunk,
                       ltp_prob=int(lp[i]))
        out = SNNEngine(plan_i).train(
            snn_regfile(wts_b[i], seed=seeds[i]), windows[i], teach_b[i])
        np.testing.assert_array_equal(np.asarray(rfs2.weights[i]),
                                      np.asarray(out.regfile.weights))
        np.testing.assert_array_equal(np.asarray(rfs2.lfsr[i]),
                                      np.asarray(out.regfile.lfsr))
        np.testing.assert_array_equal(np.asarray(counts[i]),
                                      np.asarray(out.spike_counts))


def test_trainer_parallel_mode_keeps_active_schedule():
    """Parallel training now honors ltp_prob_active for blocks >= 1:
    changing it changes only the later blocks' weights."""
    imgs, labels = make_digits(60, seed=3)
    base = SNNTrainConfig(n_neurons=20, epochs=1, n_steps=16,
                          train_mode="parallel", ltp_prob=16,
                          ltp_prob_active=1023)
    other = dataclasses.replace(base, ltp_prob_active=16)
    m_a = train(base, imgs, labels)
    m_b = train(other, imgs, labels)
    wa, wb = np.asarray(m_a.weights), np.asarray(m_b.weights)
    # block 0 trains at the base ltp_prob in both configs
    np.testing.assert_array_equal(wa[:10], wb[:10])
    # block 1 sees ltp_prob_active 1023 vs 16 -> different weights
    assert (wa[10:] != wb[10:]).any()


# --- 2-D placement: mesh_shape in the plan -----------------------------------

def test_plan_mesh_shape_validation_and_roundtrip():
    cfg = SNNTrainConfig(mesh_shape=(2, 4))
    assert plan_from_config(cfg).mesh_shape == (2, 4)
    # an explicit mesh overrides the config's declarative shape
    m = snn_mesh.snn_mesh()
    p = plan_from_config(cfg, mesh=m)
    assert p.mesh is m and p.mesh_shape is None
    # lists normalize to tuples so the frozen plan stays hashable
    assert _plan(mesh_shape=[1, 1]).mesh_shape == (1, 1)
    for bad in ((0, 2), (2,), (1, 2, 3), ("2", "4")):
        with pytest.raises(ValueError):
            _plan(mesh_shape=bad)
    with pytest.raises(ValueError):
        _plan(mesh_shape=(1, 1), cycle_backend="step")
    with pytest.raises(ValueError):
        _plan(mesh_shape=(1, 1), mesh=snn_mesh.snn_mesh())


def test_plan_placement_resolution():
    assert _plan().placement() is None
    m = snn_mesh.snn_mesh()
    assert _plan(mesh=m).placement() is m
    built = _plan(mesh_shape=(1, 1)).placement()
    assert built.shape == {"data": 1, "neuron": 1}


def test_mesh_shape_verbs_match_local_plan():
    """All three verbs through a (1, 1) grid == the unplaced plan,
    bit-exactly (real factorizations run in test_snn_mesh's subprocess
    test; dispatch is identical, only the device grid differs)."""
    weights, windows, teach = _operands(31)
    rng = np.random.default_rng(33)
    local, grid = _plan(), _plan(mesh_shape=(1, 1))

    got = SNNEngine(grid).infer(weights, windows)
    want = SNNEngine(local).infer(weights, windows)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    out_g = SNNEngine(grid).train(snn_regfile(weights, seed=5),
                                  windows[0], teach)
    out_l = SNNEngine(local).train(snn_regfile(weights, seed=5),
                                   windows[0], teach)
    _assert_rf_equal(out_g.regfile, out_l.regfile)
    np.testing.assert_array_equal(np.asarray(out_g.spike_counts),
                                  np.asarray(out_l.spike_counts))

    wts_b = jnp.asarray(rng.integers(0, 2**32, (B, N, W),
                                     dtype=np.uint32))
    teach_b = jnp.asarray(rng.integers(-50, 50, (B, N), dtype=np.int32))
    inten = jnp.asarray(rng.integers(0, 256, (B, W * 32),
                                     dtype=np.uint8))
    seeds = jnp.arange(1, B + 1, dtype=jnp.int32)
    for plan_kw in (dict(), dict(encode="kernel")):
        rfs_g = snn_regfile_batch(wts_b, [7, 8, 9])
        rfs_l = snn_regfile_batch(wts_b, [7, 8, 9])
        eng_g = SNNEngine(_plan(mesh_shape=(1, 1), **plan_kw))
        eng_l = SNNEngine(_plan(**plan_kw))
        kw = (dict(intensities=inten, seeds=seeds, n_steps=T)
              if plan_kw else dict(windows=windows))
        rfs_g2, counts_g, _ = eng_g.train_batch(rfs_g, teach=teach_b,
                                                **kw)
        rfs_l2, counts_l, _ = eng_l.train_batch(rfs_l, teach=teach_b,
                                                **kw)
        _assert_rf_equal(rfs_g2, rfs_l2)
        np.testing.assert_array_equal(np.asarray(counts_g),
                                      np.asarray(counts_l))
