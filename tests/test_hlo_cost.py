"""Validate the trip-count-aware HLO cost model on known programs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import analyze_hlo


def _compile(fn, *specs):
    return jax.jit(fn).lower(*specs).compile()


def test_plain_matmul_flops():
    n = 512
    co = _compile(lambda a, b: a @ b,
                  jax.ShapeDtypeStruct((n, n), jnp.float32),
                  jax.ShapeDtypeStruct((n, n), jnp.float32))
    res = analyze_hlo(co.as_text())
    want = 2 * n ** 3
    assert abs(res["flops"] - want) / want < 0.05


def test_scan_multiplies_by_trip_count():
    n, reps = 256, 8

    def f(x, ws):
        def body(c, w):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    co = _compile(f, jax.ShapeDtypeStruct((n, n), jnp.float32),
                  jax.ShapeDtypeStruct((reps, n, n), jnp.float32))
    res = analyze_hlo(co.as_text())
    want = 2 * n ** 3 * reps
    assert abs(res["flops"] - want) / want < 0.10, res["flops"] / want


def test_nested_scan():
    n, outer, inner = 128, 4, 3

    def f(x, ws):
        def outer_body(c, w):
            def inner_body(c2, _):
                return c2 @ w, None
            c2, _ = jax.lax.scan(inner_body, c, None, length=inner)
            return c2, None
        y, _ = jax.lax.scan(outer_body, x, ws)
        return y

    co = _compile(f, jax.ShapeDtypeStruct((n, n), jnp.float32),
                  jax.ShapeDtypeStruct((outer, n, n), jnp.float32))
    res = analyze_hlo(co.as_text())
    want = 2 * n ** 3 * outer * inner
    assert abs(res["flops"] - want) / want < 0.15


def test_batched_dot_with_batch_dims():
    b, m, k, n = 4, 64, 128, 32
    co = _compile(lambda a, c: jnp.einsum("bmk,bkn->bmn", a, c),
                  jax.ShapeDtypeStruct((b, m, k), jnp.float32),
                  jax.ShapeDtypeStruct((b, k, n), jnp.float32))
    res = analyze_hlo(co.as_text())
    want = 2 * b * m * k * n
    assert abs(res["flops"] - want) / want < 0.10


def test_bytes_scale_with_trip_count():
    n = 256

    def f(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    rs = []
    for reps in (2, 8):
        co = _compile(f, jax.ShapeDtypeStruct((n, n), jnp.float32),
                      jax.ShapeDtypeStruct((reps, n, n), jnp.float32))
        rs.append(analyze_hlo(co.as_text())["bytes"])
    # 4x trip count -> ~4x loop-body bytes (constant overhead allowed)
    assert 2.5 < rs[1] / rs[0] < 5.0


def test_collective_detection_with_mesh():
    import subprocess, sys, textwrap
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        import sys
        sys.path.insert(0, "src")
        from repro.launch.hlo_cost import analyze_hlo
        mesh = jax.make_mesh((8,), ("model",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        sh_a = NamedSharding(mesh, P(None, "model"))
        sh_b = NamedSharding(mesh, P("model", None))
        f = jax.jit(lambda a, b: a @ b, in_shardings=(sh_a, sh_b),
                    out_shardings=NamedSharding(mesh, P()))
        co = f.lower(jax.ShapeDtypeStruct((256, 256), jnp.float32),
                     jax.ShapeDtypeStruct((256, 256), jnp.float32)).compile()
        res = analyze_hlo(co.as_text())
        assert res["collective_bytes"] > 0, res
        print("OK", res["collective_bytes"])
    """)
    out = subprocess.run([sys.executable, "-c", code], cwd="/root/repo",
                         capture_output=True, text=True, timeout=240)
    assert "OK" in out.stdout, out.stdout + out.stderr
