"""Crash-consistency suite: the request journal, engine recovery, and
the kill–restart exactly-once guarantees.

Covers the WAL framing invariants (torn *tail* records truncate
silently, CRC-corrupt *mid-log* records fail loudly), snapshot
rotation (a ``snapshot_N.json.tmp`` dropping from a crash mid-snapshot
is ignored), engine recovery (counters, histograms, queue contents,
resume offset), the three injected whole-process crash points, the
terminal-ledger exactly-once argument, the bounded telemetry rings,
and the rollback count-and-degrade path for missing/torn checkpoints.

Process death is simulated in-process: the crash hook raises, then
``journal.abandon()`` drops the un-synced user-space buffers — exactly
what ``kill -9`` at that instant would leave on disk.
"""

import dataclasses
import json
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.encoder import encode_from_counter
from repro.engine import SNNEnginePlan
from repro.kernels import ops
from repro.loadgen.runner import make_clock, run_rows
from repro.loadgen.workload import WorkloadSpec
from repro.serving import (FaultInjector, FaultSpec, JournalError,
                           RequestJournal, RingLog, SNNRequest,
                           SNNServingEngine, VersionedWeightStore)
from repro.serving.journal import read_frames, replay

N, W = 20, 4
PLAN = SNNEnginePlan(threshold=40, leak=3, w_exp=None, max_batch=3)


def _weights(seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, 2**32, (N, W), dtype=np.uint32))


def _request(rid, t_steps=8, **kw):
    rng = np.random.default_rng(300 + rid)
    return SNNRequest(rid=rid, intensities=rng.integers(
        0, 256, (70,), dtype=np.uint8), n_steps=t_steps, **kw)


def _engine(journal_dir, **kw):
    kw.setdefault("clock", make_clock("virtual"))
    return SNNServingEngine(_weights(), PLAN, journal_dir=journal_dir,
                            **kw)


def _oracle(weights, r):
    win = np.asarray(encode_from_counter(
        r.seed, jnp.asarray(r.intensities), r.n_steps))
    win = np.pad(win, ((0, 0), (0, W - win.shape[1])))
    return np.asarray(ops.infer_window_batch(
        weights, jnp.asarray(win)[None], threshold=PLAN.threshold,
        leak=PLAN.leak, backend="ref"))[0]


class SimCrash(Exception):
    """Stands in for process death in in-process crash tests."""


def _crash_injector(**spec_kw):
    def hook(kind):
        raise SimCrash(kind)
    return FaultInjector(FaultSpec(**spec_kw), crash_hook=hook)


# --- RingLog ----------------------------------------------------------------

def test_ringlog_bounds_and_dropped():
    r = RingLog(cap=4)
    for i in range(10):
        r.append(i)
    assert len(r) == 4 and r.dropped == 6
    assert r[0] == 6 and r[-1] == 9
    assert list(r) == [6, 7, 8, 9]
    assert r.to_list() == [6, 7, 8, 9]
    with pytest.raises(ValueError):
        RingLog(cap=0)


def test_engine_telemetry_is_ring_buffered(tmp_path):
    eng = _engine(str(tmp_path / "j"))
    assert isinstance(eng.degradation_events, RingLog)
    assert isinstance(eng.refresh_events, RingLog)
    for i in range(eng.degradation_events.cap + 50):
        eng.degradation_events.append({"i": i})
    assert len(eng.degradation_events) == eng.degradation_events.cap
    assert eng.degradation_events.dropped == 50


def test_error_strings_capped():
    from repro.serving.snn import _ERR_MAX, _cap_error

    assert _cap_error(None) is None
    assert _cap_error("short") == "short"
    capped = _cap_error("x" * 10_000)
    assert len(capped) <= _ERR_MAX + len("...[truncated]")
    assert capped.endswith("...[truncated]")


# --- WAL framing ------------------------------------------------------------

def _framed(*records):
    j = RequestJournal.__new__(RequestJournal)  # only want the framing
    import struct
    import zlib
    out = b""
    for rec in records:
        payload = json.dumps(rec, sort_keys=True,
                             separators=(",", ":")).encode()
        out += struct.pack("<II", len(payload),
                           zlib.crc32(payload)) + payload
    return out


def test_read_frames_torn_tail_variants():
    data = _framed({"a": 1}, {"b": 2})
    # intact
    recs, valid = read_frames(data)
    assert recs == [{"a": 1}, {"b": 2}] and valid == len(data)
    # partial header / partial payload: every strict prefix of the
    # final record truncates back to the first record's end
    first_len = len(_framed({"a": 1}))
    for cut in range(first_len + 1, len(data)):
        recs, valid = read_frames(data[:cut])
        assert recs == [{"a": 1}] and valid == first_len
    # CRC-failed FINAL record is a torn tail, not corruption
    broken = bytearray(data)
    broken[-1] ^= 0xFF
    recs, valid = read_frames(bytes(broken))
    assert recs == [{"a": 1}] and valid == first_len


def test_read_frames_midlog_corruption_raises():
    data = bytearray(_framed({"a": 1}, {"b": 2}, {"c": 3}))
    data[10] ^= 0xFF                      # inside record 0's payload
    with pytest.raises(JournalError, match="CRC mismatch"):
        read_frames(bytes(data))


def test_journal_recover_truncates_torn_tail(tmp_path):
    j = RequestJournal(tmp_path / "j")
    j.append({"ev": "A", "rid": 0, "ts": 0.0})
    j.append({"ev": "A", "rid": 1, "ts": 1.0})
    j.sync()
    j.close()
    wal = tmp_path / "j" / "wal_0.log"
    data = wal.read_bytes()
    wal.write_bytes(data[:-3])            # tear the final record
    j2 = RequestJournal(tmp_path / "j")
    snapshot, tail = j2.recover()
    assert snapshot is None
    assert [e["rid"] for e in tail] == [0]
    assert j2.torn_tail_truncated == 1
    assert len(wal.read_bytes()) < len(data)   # physically truncated
    # appends continue cleanly after the truncation point
    j2.append({"ev": "A", "rid": 2, "ts": 2.0})
    j2.sync()
    j2.close()
    _, tail = RequestJournal(tmp_path / "j").recover()
    assert [e["rid"] for e in tail] == [0, 2]


def test_snapshot_rotation_and_tmp_ignored(tmp_path):
    j = RequestJournal(tmp_path / "j")
    j.append({"ev": "A", "rid": 0, "ts": 0.0})
    j.snapshot({"counters": {}, "queue": [], "last_rid": 0})
    assert (tmp_path / "j" / "snapshot_1.json").exists()
    assert not (tmp_path / "j" / "wal_0.log").exists()   # old seg gone
    j.append({"ev": "T", "rid": 0, "st": "SERVED", "at": 1.0})
    j.sync()
    j.close()
    # a crash mid-snapshot leaves only the .tmp — recovery must ignore
    # it and use snapshot_1 + its wal tail
    (tmp_path / "j" / "snapshot_2.json.tmp").write_text("{garbage")
    snapshot, tail = RequestJournal(tmp_path / "j").recover()
    assert snapshot["last_rid"] == 0
    assert [e["ev"] for e in tail] == ["T"]


def test_replay_folds_snapshot_and_tail():
    rec = replay(None, [
        {"ev": "A", "rid": 0, "ts": 1.0},
        {"ev": "A", "rid": 1, "ts": 2.0},
        {"ev": "D", "step": 0, "n": 2, "pad": 1, "ver": 0, "at": 3.0},
        {"ev": "T", "rid": 0, "st": "SERVED", "ver": 0, "qw": 1.0,
         "sv": 2.0, "at": 3.5},
    ])
    assert [r["rid"] for r in rec.pending] == [1]
    assert rec.counters["windows_served"] == 1
    assert rec.counters["submitted"] == 2
    assert rec.counters["slots_offered"] == 3
    assert rec.last_rid == 1 and rec.resume_offset == 2
    assert rec.weight_version == 0
    assert rec.clock_ms == 3.5


def test_replay_rejects_duplicate_terminal():
    tail = [{"ev": "T", "rid": 0, "st": "SERVED", "at": 1.0},
            {"ev": "T", "rid": 0, "st": "SERVED", "at": 2.0}]
    with pytest.raises(JournalError, match="duplicate TERMINAL"):
        replay(None, tail)


# --- engine recovery --------------------------------------------------------

def test_engine_recovers_counters_queue_and_resume_offset(tmp_path):
    jdir = str(tmp_path / "j")
    eng = _engine(jdir, snapshot_every=2)
    for i in range(7):
        eng.submit(_request(i))
    eng.step()
    eng.step()                       # 6 served, 1 queued, 1 snapshot
    served, queued = eng.windows_served, len(eng.queue)
    assert served == 6 and queued == 1
    eng.journal.abandon()            # kill -9

    eng2 = _engine(jdir, snapshot_every=2)
    assert eng2.windows_served == served
    assert eng2.submitted == 7
    assert len(eng2.queue) == queued
    assert eng2.journal_recovered == queued
    assert eng2.journal_resume_offset == 7
    assert eng2.queue[0].rid == 6    # original id survives recovery
    # recovered histograms carry the pre-crash samples
    assert eng2.service_hist.to_dict() == eng.service_hist.to_dict()
    while eng2.queue:
        eng2.step()
    eng2.close()
    ledger = RequestJournal(jdir).read_ledger()
    assert sorted(r["rid"] for r in ledger) == list(range(7))
    assert all(r["st"] == "SERVED" for r in ledger)


def test_recovered_request_serves_bit_exact(tmp_path):
    jdir = str(tmp_path / "j")
    eng = _engine(jdir)
    reqs = [_request(i) for i in range(4)]   # max_batch=3: one left over
    for r in reqs:
        eng.submit(r)
    eng.step()
    eng.journal.abandon()
    eng2 = _engine(jdir)
    assert len(eng2.queue) == 1
    recovered = eng2.queue[0]
    eng2.step()
    assert np.array_equal(recovered.counts, _oracle(_weights(), reqs[3]))
    eng2.close()
    ledger = RequestJournal(jdir).read_ledger()
    assert sorted(x["rid"] for x in ledger) == [0, 1, 2, 3]


def test_inline_window_payload_roundtrip(tmp_path):
    jdir = str(tmp_path / "j")
    eng = _engine(jdir)
    rng = np.random.default_rng(5)
    win = rng.integers(0, 2**32, (8, W), dtype=np.uint32)
    eng.submit(SNNRequest(rid=0, window=win))
    eng.submit(SNNRequest(rid=1, window=win.copy()))
    eng.submit(SNNRequest(rid=2, window=win.copy()))
    eng.submit(SNNRequest(rid=3, window=win.copy()))  # stays queued
    eng.step()
    eng.journal.abandon()
    eng2 = _engine(jdir)
    assert len(eng2.queue) == 1 and eng2.queue[0].rid == 3
    assert np.array_equal(eng2.queue[0].window, win)


def test_midlog_corruption_fails_loudly_at_construction(tmp_path):
    jdir = str(tmp_path / "j")
    eng = _engine(jdir)
    for i in range(6):
        eng.submit(_request(i))
    eng.step()
    eng.journal.abandon()            # keep records in the live segment
    wal = next((tmp_path / "j").glob("wal_*.log"))
    data = bytearray(wal.read_bytes())
    data[12] ^= 0xFF                 # inside the first record
    wal.write_bytes(bytes(data))
    with pytest.raises(JournalError):
        _engine(jdir)


# --- injected whole-process crash points ------------------------------------

def _run_to_crash(eng, reqs):
    for r in reqs:
        eng.submit(r)
    with pytest.raises(SimCrash):
        while eng.queue:
            eng.step()
    eng.journal.abandon()


def test_crash_before_dispatch_requeues_batch(tmp_path):
    jdir = str(tmp_path / "j")
    eng = _engine(jdir,
                  on_launch=_crash_injector(p_crash_before_dispatch=1.0))
    _run_to_crash(eng, [_request(i) for i in range(3)])
    assert eng.windows_served == 0
    eng2 = _engine(jdir)             # no injector: clean restart
    # ADMITs + DISPATCH were durable before the crash point fired
    assert len(eng2.queue) == 3
    while eng2.queue:
        eng2.step()
    eng2.close()
    ledger = RequestJournal(jdir).read_ledger()
    assert sorted(r["rid"] for r in ledger) == [0, 1, 2]
    assert all(r["st"] == "SERVED" for r in ledger)


def test_crash_after_serve_reserves_without_duplicates(tmp_path):
    jdir = str(tmp_path / "j")
    eng = _engine(jdir, on_launch=_crash_injector(
        p_crash_after_serve_before_journal=1.0))
    reqs = [_request(i) for i in range(3)]
    _run_to_crash(eng, reqs)
    # counts were computed but no TERMINAL was durable: the serve is
    # invisible, so recovery re-queues and re-serves — exactly once
    eng2 = _engine(jdir)
    assert len(eng2.queue) == 3 and eng2.windows_served == 0
    while eng2.queue:
        eng2.step()
    eng2.close()
    ledger = RequestJournal(jdir).read_ledger()
    rids = [r["rid"] for r in ledger]
    assert sorted(rids) == [0, 1, 2] and len(rids) == len(set(rids))


def test_crash_mid_snapshot_recovers_from_log(tmp_path):
    jdir = str(tmp_path / "j")
    eng = _engine(jdir, snapshot_every=1,
                  on_launch=_crash_injector(p_crash_mid_snapshot=1.0))
    _run_to_crash(eng, [_request(i) for i in range(3)])
    assert eng.windows_served == 3   # the serve itself completed
    # only the .tmp dropping exists; the WAL holds everything
    assert list((tmp_path / "j").glob("snapshot_*.json")) == []
    assert list((tmp_path / "j").glob("snapshot_*.json.tmp")) != []
    eng2 = _engine(jdir)
    assert eng2.windows_served == 3 and len(eng2.queue) == 0
    eng2.close()
    ledger = RequestJournal(jdir).read_ledger()
    assert sorted(r["rid"] for r in ledger) == [0, 1, 2]


# --- trace-backed recovery + runner resume ----------------------------------

def test_trace_rows_rematerialize_and_resume(tmp_path):
    spec = WorkloadSpec(n_inputs=W * 32, seed=3)
    rows = [spec.sample_row(i, i * 0.5) for i in range(30)]
    jdir = str(tmp_path / "j")
    eng = _engine(jdir)
    run_rows(eng, spec, rows[:10], verify_payloads=True)
    eng.journal.abandon()            # close() skipped: simulated kill
    eng2 = _engine(jdir)
    assert eng2.journal_resume_offset == 10
    run_rows(eng2, spec, rows,
             resume_offset=eng2.journal_resume_offset)
    eng2.close()
    ledger = RequestJournal(jdir).read_ledger()
    assert sorted(r["rid"] for r in ledger) == list(range(30))
    shas = [r["sha"] for r in ledger if r["st"] == "SERVED"]
    assert len(shas) == len(set(shas))       # no duplicate serves
    assert all(sha is not None for sha in shas)


def test_recovered_trace_payload_hash_mismatch_fails(tmp_path):
    spec = WorkloadSpec(n_inputs=W * 32, seed=3)
    rows = [spec.sample_row(i, float(i)) for i in range(4)]
    jdir = str(tmp_path / "j")
    eng = _engine(jdir)
    for row in rows:
        req = spec.materialize(row)
        req.t_submit_ms = row["ts"]
        eng.submit(req)
    eng.step()                       # 3 served, 1 queued (durable A)
    eng.journal.abandon()
    # corrupt the queued row's recorded hash inside the WAL is not
    # possible without breaking CRC — instead corrupt via a snapshot
    wal = next((tmp_path / "j").glob("wal_*.log"))
    recs, _ = read_frames(wal.read_bytes())
    bad = [dict(r) for r in recs]
    for r in bad:
        if r.get("ev") == "A" and "row" in r and r["rid"] == 3:
            r["row"]["seed"] ^= 1    # payload no longer matches sha
    j = RequestJournal(jdir)
    wal.unlink()
    for r in bad:
        j.append(r)
    j.sync()
    j.close()
    with pytest.raises(ValueError, match="hash mismatch"):
        _engine(jdir)


# --- rollback count-and-degrade (satellite) ---------------------------------

def _promote(st, weights, version_src=1):
    cand = st.stage(jnp.asarray(weights, jnp.uint32))
    assert st.promote(cand)
    st.swap_if_pending()
    return cand


def test_rollback_degrades_on_torn_checkpoint(tmp_path):
    st = VersionedWeightStore(_weights(0), state_dir=tmp_path / "w")
    _promote(st, _weights(1))
    _promote(st, _weights(2))        # serving v2, rollback target v1
    # tear v1's checkpoint on disk: every file becomes garbage
    for p in (tmp_path / "w" / "step_1").iterdir():
        p.write_bytes(b"torn")
    tgt = st.rollback(reason="test")
    # disk load failed but the in-memory history still has v1
    assert tgt is not None and tgt.version == 1
    assert st.rollback_load_failures == 1
    assert any(e["event"] == "rollback_target_torn" for e in st.events)


def test_rollback_walks_past_missing_targets():
    # memory-only store with keep=1: old promoted versions are trimmed
    # from the in-memory history — the pre-fix code raised KeyError
    st = VersionedWeightStore(_weights(0), keep=1)
    for s in (1, 2, 3):
        _promote(st, _weights(s))
    assert st.rollback(reason="a") is not None    # v2 still in history
    st.swap_if_pending()
    # next targets (v1, v0) were trimmed: count-and-degrade, never raise
    assert st.rollback(reason="b") is None
    assert st.rollback_load_failures >= 1
    assert any(e["event"] == "rollback_target_missing"
               for e in st.events)


def test_journaled_stats_keys(tmp_path):
    eng = _engine(str(tmp_path / "j"))
    eng.submit(_request(0))
    eng.step()
    s = eng.stats()
    for key in ("journal_records", "journal_snapshots",
                "journal_recovered", "journal_resume_offset",
                "version_reconciliations", "telemetry_dropped"):
        assert key in s
    eng.close()


# --- snapshot schema compatibility (overload-era counters) ------------------

def _newest_snapshot(jdir):
    snaps = sorted(Path(jdir).glob("snapshot_*.json"),
                   key=lambda p: int(p.stem.split("_")[1]))
    assert snaps, "no complete snapshot written"
    return snaps[-1]


def _rewrite_snapshot(jdir, mutate):
    path = _newest_snapshot(jdir)
    state = json.loads(path.read_text())
    mutate(state)
    path.write_text(json.dumps(state))


def test_pre_overload_schema_snapshot_round_trips(tmp_path):
    """A snapshot written before the overload-control schema (no shed
    counters, no breaker states, no controller state) must restore a
    new engine cleanly: new counters default to zero, everything the
    old schema did record survives."""
    jdir = str(tmp_path / "j")
    eng = _engine(jdir)
    for i in range(4):
        eng.submit(_request(i))
    while eng.queue:
        eng.step()
    eng.close()

    def strip_new_schema(state):
        for k in ("shed_admission", "shed_low_priority", "shed_codel",
                  "retries_denied"):
            state["counters"].pop(k, None)
        state.pop("breakers", None)
        state.pop("breaker_trips", None)
        state.pop("overload", None)

    _rewrite_snapshot(jdir, strip_new_schema)
    eng2 = _engine(jdir)
    assert eng2.windows_served == 4
    assert eng2.submitted == 4
    assert (eng2.shed_admission, eng2.shed_low_priority,
            eng2.shed_codel, eng2.retries_denied) == (0, 0, 0, 0)
    assert eng2.breakers.states() == ["closed"] * len(eng2._plans)
    eng2.close()


def test_unknown_snapshot_counters_preserved_through_recovery(tmp_path):
    """Forward compatibility: counter keys from a *newer* engine ride
    through an old engine's recover -> snapshot cycle untouched instead
    of being dropped (so a rollback never erases a newer schema's
    accounting)."""
    jdir = str(tmp_path / "j")
    eng = _engine(jdir)
    eng.submit(_request(0))
    eng.step()
    eng.close()

    _rewrite_snapshot(jdir, lambda s: s["counters"].update(zz_future=7))
    eng2 = _engine(jdir)             # construction compacts to a new
    eng2.close()                     # snapshot; close compacts again
    state = json.loads(_newest_snapshot(jdir).read_text())
    assert state["counters"]["zz_future"] == 7
    assert state["counters"]["windows_served"] == 1
    # the foreign key never leaks into engine attributes or stats
    assert not hasattr(eng2, "zz_future")
    assert "zz_future" not in eng2.stats()
