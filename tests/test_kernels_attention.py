"""Flash-attention Pallas kernel vs dense reference (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import attention_ref


def _qkv(key, b, hq, hkv, tq, tk, d, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (b, hq, tq, d), dtype)
    k = jax.random.normal(k2, (b, hkv, tk, d), dtype)
    v = jax.random.normal(k3, (b, hkv, tk, d), dtype)
    return q, k, v


@pytest.mark.parametrize("b,hq,hkv,t,d", [
    (1, 2, 2, 128, 64),
    (2, 4, 2, 256, 64),   # GQA group 2
    (1, 8, 1, 128, 128),  # MQA
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_prefill_matches_ref(b, hq, hkv, t, d, causal):
    q, k, v = _qkv(jax.random.key(0), b, hq, hkv, t, t, d)
    got = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                          interpret=True)
    want = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("window", [64, 128])
def test_flash_sliding_window_matches_ref(window):
    q, k, v = _qkv(jax.random.key(1), 1, 4, 2, 256, 256, 64)
    got = flash_attention(q, k, v, causal=True, window=window,
                          block_q=64, block_k=64, interpret=True)
    want = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_bf16_close_to_f32_ref():
    q, k, v = _qkv(jax.random.key(2), 1, 2, 2, 128, 128, 64, jnp.bfloat16)
    got = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                          interpret=True)
    want = attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                         v.astype(jnp.float32), causal=True)
    np.testing.assert_allclose(np.asarray(got, dtype=np.float32),
                               np.asarray(want), atol=3e-2, rtol=3e-2)


def test_flash_block_shape_independence():
    """Result must not depend on the tiling."""
    q, k, v = _qkv(jax.random.key(3), 1, 2, 2, 256, 256, 64)
    a = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    b = flash_attention(q, k, v, block_q=128, block_k=64, interpret=True)
    c = flash_attention(q, k, v, block_q=64, block_k=128, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=2e-5)
