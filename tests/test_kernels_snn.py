"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle.

Sweeps shapes; integer kernels must be BIT-EXACT with ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lfsr
from repro.kernels import ops, ref

SHAPES = [(8, 1), (10, 25), (40, 25), (128, 32), (256, 130), (33, 7)]


def _rand_words(rng, shape):
    return jnp.asarray(rng.integers(0, 2**32, shape, dtype=np.uint32))


@pytest.mark.parametrize("n,w", SHAPES)
def test_spike_process_bit_exact(n, w):
    rng = np.random.default_rng(n * 100 + w)
    spikes = _rand_words(rng, (w,))
    weights = _rand_words(rng, (n, w))
    got = ops.spike_process(spikes, weights, backend="interp")
    want = ref.spike_process_ref(spikes, weights)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n", [8, 40, 100, 256])
@pytest.mark.parametrize("threshold,leak", [(10, 1), (192, 16), (1, 0)])
def test_lif_step_bit_exact(n, threshold, leak):
    rng = np.random.default_rng(n)
    v = jnp.asarray(rng.integers(0, 300, (n,), dtype=np.int32))
    c = jnp.asarray(rng.integers(-50, 120, (n,), dtype=np.int32))
    v2, f = ops.lif_step(v, c, threshold, leak, backend="interp")
    rv, rf = ref.lif_step_ref(v, c, threshold, leak)
    np.testing.assert_array_equal(np.asarray(v2), np.asarray(rv))
    np.testing.assert_array_equal(np.asarray(f), np.asarray(rf))


@pytest.mark.parametrize("n,w", SHAPES)
@pytest.mark.parametrize("wexp,ltp", [(128, 1023), (128, 16), (512, 64)])
def test_stdp_update_bit_exact(n, w, wexp, ltp):
    rng = np.random.default_rng(n * 7 + w)
    weights = _rand_words(rng, (n, w))
    pre = _rand_words(rng, (w,))
    fired = jnp.asarray(rng.integers(0, 2, (n,)).astype(bool))
    st = lfsr.seed(n + w, n * w).reshape(n, w)
    n_syn = w * 32
    got_w, got_s = ops.stdp_update(
        weights, pre, fired, st, w_exp=wexp, gain=4, n_syn=n_syn,
        ltp_prob=ltp, backend="interp")
    want_w, want_s = ref.stdp_update_ref(
        weights, pre, fired, st, wexp, 4, n_syn, ltp)
    np.testing.assert_array_equal(np.asarray(got_w), np.asarray(want_w))
    np.testing.assert_array_equal(np.asarray(got_s), np.asarray(want_s))


@pytest.mark.parametrize("n,w", [(10, 25), (128, 32), (40, 25)])
@pytest.mark.parametrize("train", [True, False])
def test_fused_snn_step_bit_exact(n, w, train):
    rng = np.random.default_rng(n + w)
    weights = _rand_words(rng, (n, w))
    pre = _rand_words(rng, (w,))
    v = jnp.asarray(rng.integers(0, 200, (n,), dtype=np.int32))
    teach = jnp.asarray(rng.integers(-100, 100, (n,), dtype=np.int32))
    st = lfsr.seed(5, n * w).reshape(n, w)
    kw = dict(threshold=192, leak=16, w_exp=128, gain=4, n_syn=w * 32,
              ltp_prob=16)
    got = ops.fused_snn_step(weights, pre, v, st, teach, train=train,
                             backend="interp", **kw)
    if train:
        want = ref.fused_snn_step_ref(weights, pre, v, st, teach, **kw)
    else:
        counts = ref.spike_process_ref(pre, weights) + teach
        v2, f = ref.lif_step_ref(v, counts, 192, 16)
        want = (weights, v2, f, st)
    for g, r in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(r))


def test_fused_equals_unfused_composition():
    """The fused kernel must equal SPU -> NU -> SU composition exactly."""
    rng = np.random.default_rng(0)
    n, w = 40, 25
    weights = _rand_words(rng, (n, w))
    pre = _rand_words(rng, (w,))
    v = jnp.zeros((n,), jnp.int32)
    teach = jnp.zeros((n,), jnp.int32)
    st = lfsr.seed(1, n * w).reshape(n, w)
    kw = dict(w_exp=128, gain=4, n_syn=800, ltp_prob=1023)
    counts = ops.spike_process(pre, weights, backend="interp")
    v2, f = ops.lif_step(v, counts, 50, 4, backend="interp")
    w2, s2 = ops.stdp_update(weights, pre, f, st, backend="interp", **kw)
    fw, fv, ff, fs = ops.fused_snn_step(
        weights, pre, v, st, teach, threshold=50, leak=4,
        backend="interp", **kw)
    np.testing.assert_array_equal(np.asarray(fw), np.asarray(w2))
    np.testing.assert_array_equal(np.asarray(fv), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(ff), np.asarray(f))
    np.testing.assert_array_equal(np.asarray(fs), np.asarray(s2))
