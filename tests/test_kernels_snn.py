"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle.

Sweeps shapes; integer kernels must be BIT-EXACT with ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lfsr
from repro.kernels import ops, ref

SHAPES = [(8, 1), (10, 25), (40, 25), (128, 32), (256, 130), (33, 7)]


def _rand_words(rng, shape):
    return jnp.asarray(rng.integers(0, 2**32, shape, dtype=np.uint32))


@pytest.mark.parametrize("n,w", SHAPES)
def test_spike_process_bit_exact(n, w):
    rng = np.random.default_rng(n * 100 + w)
    spikes = _rand_words(rng, (w,))
    weights = _rand_words(rng, (n, w))
    got = ops.spike_process(spikes, weights, backend="interp")
    want = ref.spike_process_ref(spikes, weights)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n", [8, 40, 100, 256])
@pytest.mark.parametrize("threshold,leak", [(10, 1), (192, 16), (1, 0)])
def test_lif_step_bit_exact(n, threshold, leak):
    rng = np.random.default_rng(n)
    v = jnp.asarray(rng.integers(0, 300, (n,), dtype=np.int32))
    c = jnp.asarray(rng.integers(-50, 120, (n,), dtype=np.int32))
    v2, f = ops.lif_step(v, c, threshold, leak, backend="interp")
    rv, rf = ref.lif_step_ref(v, c, threshold, leak)
    np.testing.assert_array_equal(np.asarray(v2), np.asarray(rv))
    np.testing.assert_array_equal(np.asarray(f), np.asarray(rf))


@pytest.mark.parametrize("n,w", SHAPES)
@pytest.mark.parametrize("wexp,ltp", [(128, 1023), (128, 16), (512, 64)])
def test_stdp_update_bit_exact(n, w, wexp, ltp):
    rng = np.random.default_rng(n * 7 + w)
    weights = _rand_words(rng, (n, w))
    pre = _rand_words(rng, (w,))
    fired = jnp.asarray(rng.integers(0, 2, (n,)).astype(bool))
    st = lfsr.seed(n + w, n * w).reshape(n, w)
    n_syn = w * 32
    got_w, got_s = ops.stdp_update(
        weights, pre, fired, st, w_exp=wexp, gain=4, n_syn=n_syn,
        ltp_prob=ltp, backend="interp")
    want_w, want_s = ref.stdp_update_ref(
        weights, pre, fired, st, wexp, 4, n_syn, ltp)
    np.testing.assert_array_equal(np.asarray(got_w), np.asarray(want_w))
    np.testing.assert_array_equal(np.asarray(got_s), np.asarray(want_s))


@pytest.mark.parametrize("n,w", [(10, 25), (128, 32), (40, 25)])
@pytest.mark.parametrize("train", [True, False])
def test_fused_snn_step_bit_exact(n, w, train):
    rng = np.random.default_rng(n + w)
    weights = _rand_words(rng, (n, w))
    pre = _rand_words(rng, (w,))
    v = jnp.asarray(rng.integers(0, 200, (n,), dtype=np.int32))
    teach = jnp.asarray(rng.integers(-100, 100, (n,), dtype=np.int32))
    st = lfsr.seed(5, n * w).reshape(n, w)
    kw = dict(threshold=192, leak=16, w_exp=128, gain=4, n_syn=w * 32,
              ltp_prob=16)
    got = ops.fused_snn_step(weights, pre, v, st, teach, train=train,
                             backend="interp", **kw)
    if train:
        want = ref.fused_snn_step_ref(weights, pre, v, st, teach, **kw)
    else:
        counts = ref.spike_process_ref(pre, weights) + teach
        v2, f = ref.lif_step_ref(v, counts, 192, 16)
        want = (weights, v2, f, st)
    for g, r in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(r))


def _window_operands(n, w, t_steps, seed=0):
    rng = np.random.default_rng(seed)
    weights = _rand_words(rng, (n, w))
    spk = _rand_words(rng, (t_steps, w))
    v = jnp.asarray(rng.integers(0, 200, (n,), dtype=np.int32))
    teach = jnp.asarray(rng.integers(-100, 100, (n,), dtype=np.int32))
    st = lfsr.seed(n + w + t_steps, n * w).reshape(n, w)
    return weights, spk, v, teach, st


@pytest.mark.parametrize("n,w", [(8, 1), (10, 25), (33, 7), (128, 32)])
@pytest.mark.parametrize("train", [True, False])
def test_fused_window_equals_sequential_steps(n, w, train):
    """Window kernel == T sequential fused steps, bit-exact incl. LFSR."""
    t_steps = 9
    weights, spk, v, teach, st = _window_operands(n, w, t_steps)
    kw = dict(threshold=60, leak=4, w_exp=64, gain=4, n_syn=w * 32,
              ltp_prob=200)
    got = ops.fused_snn_window(weights, spk, v, st, teach, train=train,
                               backend="interp", **kw)
    wq, vq, sq = weights, v, st
    raster = []
    for t in range(t_steps):
        wq, vq, f, sq = ops.fused_snn_step(
            wq, spk[t], vq, sq, teach, train=train, backend="ref", **kw)
        raster.append(np.asarray(f))
    want = (wq, vq, np.stack(raster), sq)
    for g, r in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(r))


def test_fused_window_zero_teach_matches_no_teach_ref():
    """teach=0 through the kernel == teach-free sequential reference."""
    n, w, t_steps = 10, 3, 7
    weights, spk, v, _, st = _window_operands(n, w, t_steps, seed=2)
    kw = dict(threshold=30, leak=2, w_exp=32, gain=4, n_syn=w * 32,
              ltp_prob=1023)
    got = ops.fused_snn_window(weights, spk, v, st,
                               jnp.zeros((n,), jnp.int32),
                               backend="interp", **kw)
    want = ref.fused_snn_window_ref(weights, spk, v, st, None,
                                    kw["threshold"], kw["leak"],
                                    kw["w_exp"], kw["gain"], kw["n_syn"],
                                    kw["ltp_prob"])
    for g, r in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(r))


def test_fused_window_ref_matches_interp():
    """ops dispatch: ref and interp backends agree on the window op."""
    n, w, t_steps = 40, 25, 12
    weights, spk, v, teach, st = _window_operands(n, w, t_steps, seed=4)
    kw = dict(threshold=50, leak=4, w_exp=128, gain=4, n_syn=w * 32,
              ltp_prob=16)
    a = ops.fused_snn_window(weights, spk, v, st, teach,
                             backend="ref", **kw)
    b = ops.fused_snn_window(weights, spk, v, st, teach,
                             backend="interp", **kw)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("n,w,b", [(8, 1, 2), (33, 7, 3), (128, 32, 4)])
def test_infer_window_batch_bit_exact(n, w, b):
    """Batched serving kernel == per-sample inference oracle."""
    rng = np.random.default_rng(n * 3 + w + b)
    weights = _rand_words(rng, (n, w))
    trains = _rand_words(rng, (b, 11, w))
    got = ops.infer_window_batch(weights, trains, threshold=40, leak=3,
                                 backend="interp")
    want = ref.infer_window_batch_ref(weights, trains, 40, 3)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # and each batch row == the single-sample window op (inference mode)
    for i in range(b):
        _, _, fired, _ = ops.fused_snn_window(
            weights, trains[i], jnp.zeros((n,), jnp.int32),
            jnp.ones((n, w), jnp.uint32), jnp.zeros((n,), jnp.int32),
            threshold=40, leak=3, w_exp=0, gain=0, n_syn=1, ltp_prob=0,
            train=False, backend="interp")
        np.testing.assert_array_equal(
            np.asarray(got[i]),
            np.asarray(jnp.sum(fired.astype(jnp.int32), axis=0)))


@pytest.mark.parametrize("t_chunk", [1, 2, 4, 5, 9, 16])
@pytest.mark.parametrize("train", [True, False])
def test_chunked_window_equals_unchunked(t_chunk, train):
    """t_chunk-slab streaming == whole-window launch, bit-exact.

    Covers dividing chunks (1, 9), ragged tails (2, 4, 5) and
    t_chunk > T (16) at T=9.
    """
    n, w, t_steps = 33, 7, 9
    weights, spk, v, teach, st = _window_operands(n, w, t_steps, seed=6)
    kw = dict(threshold=60, leak=4, w_exp=64, gain=4, n_syn=w * 32,
              ltp_prob=200)
    want = ops.fused_snn_window(weights, spk, v, st, teach, train=train,
                                backend="interp", **kw)
    got = ops.fused_snn_window(weights, spk, v, st, teach, train=train,
                               t_chunk=t_chunk, backend="interp", **kw)
    for g, r in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(r))


@pytest.mark.parametrize("t_chunk", [1, 3, 4, 11, 20])
def test_chunked_infer_batch_equals_unchunked(t_chunk):
    n, w, b, t_steps = 33, 7, 3, 11
    rng = np.random.default_rng(9)
    weights = _rand_words(rng, (n, w))
    trains = _rand_words(rng, (b, t_steps, w))
    want = ref.infer_window_batch_ref(weights, trains, 40, 3)
    got = ops.infer_window_batch(weights, trains, threshold=40, leak=3,
                                 t_chunk=t_chunk, backend="interp")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def _batch_operands(b, n, w, t_steps, seed=0):
    rng = np.random.default_rng(seed)
    weights = _rand_words(rng, (b, n, w))
    spk = _rand_words(rng, (b, t_steps, w))
    v = jnp.asarray(rng.integers(0, 200, (b, n), dtype=np.int32))
    teach = jnp.asarray(rng.integers(-100, 100, (b, n), dtype=np.int32))
    st = jnp.stack([lfsr.seed(11 + 13 * i, n * w).reshape(n, w)
                    for i in range(b)])
    return weights, spk, v, teach, st


@pytest.mark.parametrize("n,w,b", [(8, 1, 2), (10, 25, 3), (33, 7, 2)])
@pytest.mark.parametrize("backend", ["ref", "interp"])
def test_train_window_batch_equals_sequential_streams(n, w, b, backend):
    """Batched training grid == B sequential windows, incl. each
    stream's LFSR sequence."""
    t_steps = 7
    weights, spk, v, teach, st = _batch_operands(b, n, w, t_steps, seed=3)
    kw = dict(threshold=60, leak=4, w_exp=64, gain=4, n_syn=w * 32,
              ltp_prob=200)
    got = ops.train_window_batch(weights, spk, v, st, teach,
                                 backend=backend, **kw)
    for i in range(b):
        want = ops.fused_snn_window(weights[i], spk[i], v[i], st[i],
                                    teach[i], backend="ref", **kw)
        for g, r in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g[i]),
                                          np.asarray(r))


@pytest.mark.parametrize("t_chunk", [2, 3, 7, 10])
def test_train_window_batch_chunked(t_chunk):
    """Batch grid + time chunking together stay bit-exact (ragged incl.)."""
    b, n, w, t_steps = 2, 10, 3, 7
    weights, spk, v, teach, st = _batch_operands(b, n, w, t_steps, seed=5)
    kw = dict(threshold=30, leak=2, w_exp=32, gain=4, n_syn=w * 32,
              ltp_prob=500)
    want = ops.train_window_batch(weights, spk, v, st, teach,
                                  backend="ref", **kw)
    got = ops.train_window_batch(weights, spk, v, st, teach,
                                 t_chunk=t_chunk, backend="interp", **kw)
    for g, r in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(r))


def test_fused_equals_unfused_composition():
    """The fused kernel must equal SPU -> NU -> SU composition exactly."""
    rng = np.random.default_rng(0)
    n, w = 40, 25
    weights = _rand_words(rng, (n, w))
    pre = _rand_words(rng, (w,))
    v = jnp.zeros((n,), jnp.int32)
    teach = jnp.zeros((n,), jnp.int32)
    st = lfsr.seed(1, n * w).reshape(n, w)
    kw = dict(w_exp=128, gain=4, n_syn=800, ltp_prob=1023)
    counts = ops.spike_process(pre, weights, backend="interp")
    v2, f = ops.lif_step(v, counts, 50, 4, backend="interp")
    w2, s2 = ops.stdp_update(weights, pre, f, st, backend="interp", **kw)
    fw, fv, ff, fs = ops.fused_snn_step(
        weights, pre, v, st, teach, threshold=50, leak=4,
        backend="interp", **kw)
    np.testing.assert_array_equal(np.asarray(fw), np.asarray(w2))
    np.testing.assert_array_equal(np.asarray(fv), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(ff), np.asarray(f))
    np.testing.assert_array_equal(np.asarray(fs), np.asarray(s2))
