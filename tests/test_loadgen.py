"""Determinism and correctness tests for the open-loop load generator.

The replay invariant the subsystem exists for: same specs + same seed
=> bit-identical arrival timestamps, trace rows, payload bytes, and —
driven through the engine on the virtual clock — identical per-status
totals and histogram buckets.  Plus the histogram algebra (merge ==
concat), the coordinated-omission stamping, and the trace round-trip
(full and compact, with tamper detection).
"""

import json
import math

import numpy as np
import pytest

from repro.loadgen import (ArrivalSpec, LatencyHistogram, TraceError,
                           WorkloadSpec, generate_rows, read_trace,
                           stream_sha, timestamps, u64, u64_stream,
                           verify_payloads, write_trace)
from repro.loadgen.runner import (PacedWallClock, ServiceModel,
                                  VirtualClock, make_clock, rate_sweep,
                                  run_rows)


# --- counter hash ------------------------------------------------------

def test_u64_stream_matches_scalar():
    s = u64_stream(123, 32, tag=5)
    assert [int(x) for x in s] == [u64(123, i, 5) for i in range(32)]


def test_u64_counters_independent():
    # changing any counter or the seed changes the draw
    base = u64(1, 2, 3)
    assert base != u64(2, 2, 3)
    assert base != u64(1, 3, 3)
    assert base != u64(1, 2, 4)


# --- arrivals ----------------------------------------------------------

@pytest.mark.parametrize("process", ["poisson", "uniform", "onoff"])
def test_arrivals_reproducible_and_monotone(process):
    spec = ArrivalSpec(process=process, rate_rps=1000.0,
                       n_requests=500, seed=7)
    ts1, ts2 = timestamps(spec), timestamps(spec)
    assert ts1 == ts2
    assert len(ts1) == 500
    assert all(b >= a for a, b in zip(ts1, ts1[1:]))
    # a different seed gives a different stream (uniform is seedless
    # by construction — equal gaps — so skip it)
    if process != "uniform":
        assert timestamps(ArrivalSpec(process=process, rate_rps=1000.0,
                                      n_requests=500, seed=8)) != ts1


def test_poisson_rate_roughly_honored():
    spec = ArrivalSpec(process="poisson", rate_rps=2000.0,
                       n_requests=4000, seed=3)
    ts = timestamps(spec)
    achieved = (len(ts) - 1) / (ts[-1] - ts[0]) * 1e3
    assert 0.9 * 2000 < achieved < 1.1 * 2000


def test_onoff_burstiness():
    # on/off arrivals concentrate mass into the duty window: the
    # in-burst instantaneous rate is burst_factor / duty x the mean
    spec = ArrivalSpec(process="onoff", rate_rps=1000.0,
                       n_requests=2000, seed=5, burst_factor=3.0,
                       duty=0.25)
    ts = timestamps(spec)
    in_burst = sum(1 for t in ts if (t % spec.period_ms)
                   < spec.duty * spec.period_ms)
    assert in_burst / len(ts) > 0.5     # >> duty=0.25 if bursty


def test_arrival_spec_round_trip():
    spec = ArrivalSpec(process="onoff", rate_rps=123.0, n_requests=10,
                       seed=9, burst_factor=2.0, duty=0.3,
                       period_ms=50.0)
    assert ArrivalSpec.from_dict(
        json.loads(json.dumps(spec.to_dict()))) == spec


# --- workload ----------------------------------------------------------

def _specs(n=200, rate=4000.0):
    return (ArrivalSpec(process="poisson", rate_rps=rate, n_requests=n,
                        seed=42),
            WorkloadSpec(n_inputs=256, p_intensity=0.75,
                         t_choices=(8, 12, 16),
                         deadline_choices=(None, 40.0),
                         deadline_weights=(3, 1), seed=9))


def test_rows_reproducible_and_isolated():
    asp, wl = _specs()
    rows = generate_rows(asp, wl)
    # any row re-samples identically in isolation (stateless hash)
    ts = timestamps(asp)
    for rid in (0, 57, 199):
        assert wl.sample_row(rid, ts[rid]) == rows[rid]
    kinds = {r["kind"] for r in rows}
    assert kinds == {"I", "W"}          # mixed traffic at p=0.75


def test_payload_regeneration_bit_exact():
    asp, wl = _specs(n=50)
    rows = generate_rows(asp, wl)
    for row in rows:
        a, b = wl.payload(row), wl.payload(row)
        assert np.array_equal(a, b)
        assert wl.payload_sha(row) == row["sha"]
    assert verify_payloads(wl, rows) == 50


def test_materialize_verifies_sha():
    asp, wl = _specs(n=5)
    row = generate_rows(asp, wl)[0]
    req = wl.materialize(row, verify=True)
    assert req.rid == row["rid"]
    bad = dict(row, sha="0" * 16)
    with pytest.raises(ValueError, match="hash mismatch"):
        wl.materialize(bad, verify=True)


# --- histogram ---------------------------------------------------------

def test_histogram_merge_equals_concat():
    rng = np.random.default_rng(11)
    a = np.abs(rng.normal(5, 3, 3000))
    b = np.abs(rng.lognormal(1, 1, 2000))
    ha, hb, hc = (LatencyHistogram() for _ in range(3))
    for v in a:
        ha.record(v)
    for v in b:
        hb.record(v)
    for v in np.concatenate([a, b]):
        hc.record(v)
    ha.merge(hb)
    assert ha == hc
    assert ha.count == 5000
    for p in (50, 90, 99, 99.9):
        assert ha.percentile(p) == hc.percentile(p)


def test_histogram_bounded_relative_error():
    h = LatencyHistogram()
    for v in (0.01, 0.5, 1.0, 7.3, 42.0, 999.0, 12345.6):
        h.reset()
        h.record(v)
        est = h.percentile(50)
        # relative error bounded by the log-bucket width, absolute by
        # the 1 us tick resolution near zero
        assert abs(est - v) <= max(0.02 * v, 2 * h.unit_ms), (v, est)


def test_histogram_serialization_round_trip():
    h = LatencyHistogram()
    for v in (0.1, 1.0, 10.0, 100.0, 100.0):
        h.record(v)
    h2 = LatencyHistogram.from_dict(json.loads(json.dumps(h.to_dict())))
    assert h2 == h
    assert h2.percentile(99) == h.percentile(99)


def test_histogram_memory_bounded():
    h = LatencyHistogram()
    for i in range(100_000):
        h.record((i % 977) * 0.13)
    assert len(h.to_dict()["counts"]) < 2000   # sparse, not per-value
    assert h.count == 100_000


# --- trace -------------------------------------------------------------

def test_trace_round_trip_full_and_compact(tmp_path):
    asp, wl = _specs(n=100)
    rows = generate_rows(asp, wl)
    for compact in (False, True):
        p = tmp_path / f"t_{compact}.jsonl"
        header = write_trace(str(p), asp, wl, compact=compact)
        h2, rows2 = read_trace(str(p))
        assert rows2 == rows
        assert h2["stream_sha256"] == header["stream_sha256"]
        assert h2["stream_sha256"] == stream_sha(rows)
    # compact trace is tiny regardless of n_requests
    assert (tmp_path / "t_True.jsonl").stat().st_size < 1000


def test_trace_detects_tampering(tmp_path):
    asp, wl = _specs(n=20)
    p = tmp_path / "t.jsonl"
    write_trace(str(p), asp, wl)
    lines = p.read_text().splitlines()
    row = json.loads(lines[5])
    row["t"] = 999
    lines[5] = json.dumps(row, sort_keys=True, separators=(",", ":"))
    p.write_text("\n".join(lines) + "\n")
    with pytest.raises(TraceError, match="digest mismatch"):
        read_trace(str(p))


def test_compact_trace_detects_spec_tampering(tmp_path):
    asp, wl = _specs(n=20)
    p = tmp_path / "t.jsonl"
    write_trace(str(p), asp, wl, compact=True)
    header = json.loads(p.read_text())
    header["workload"]["seed"] += 1     # regenerates different traffic
    p.write_text(json.dumps(header, sort_keys=True,
                            separators=(",", ":")) + "\n")
    with pytest.raises(TraceError, match="digest mismatch"):
        read_trace(str(p))


# --- clocks ------------------------------------------------------------

def test_virtual_clock_deterministic():
    c = VirtualClock(ServiceModel(base_ms=1.0, per_slot_ms=0.5,
                                  per_cycle_ms=0.25))
    c.skip_to(10.0)
    c.advance_service_ms(4, 8)
    assert c.now_ms() == 10.0 + 1.0 + 2.0 + 2.0
    c.skip_to(5.0)                      # never goes backwards
    assert c.now_ms() == 15.0


def test_paced_wall_clock_skips_idle():
    c = PacedWallClock()
    t0 = c.now_ms()
    c.skip_to(t0 + 5000.0)              # instant, no sleep
    assert c.now_ms() >= t0 + 5000.0
    assert c.now_ms() < t0 + 5100.0
    with pytest.raises(ValueError):
        make_clock("nonsense")


# --- end-to-end replay -------------------------------------------------

def _engine(wl, clock):
    from repro.core.stdp import init_weights
    from repro.engine.plan import SNNEnginePlan
    from repro.serving.snn import SNNServingEngine, SNNServingPolicy

    plan = SNNEnginePlan(threshold=192, leak=16, n_syn=wl.n_inputs,
                         encode="kernel", cycle_backend="window",
                         max_batch=16, t_chunk=8)
    return SNNServingEngine(
        init_weights(32, wl.words, density_seed=0), plan,
        policy=SNNServingPolicy(max_queue=1024, deadline_ms=200.0),
        clock=clock)


def test_replay_bit_identical():
    asp, wl = _specs(n=400, rate=8000.0)
    rows = generate_rows(asp, wl)

    def once():
        return run_rows(_engine(wl, make_clock("virtual")), wl, rows,
                        slo_ms=50.0)

    r1, r2 = once(), once()
    assert r1.per_status == r2.per_status
    assert r1.non_terminal == 0
    assert r1.service_hist == r2.service_hist
    assert r1.queue_wait_hist == r2.queue_wait_hist
    assert json.dumps(r1.to_dict(), sort_keys=True) == \
        json.dumps(r2.to_dict(), sort_keys=True)


def test_coordinated_omission_latency_from_intended_arrival():
    # one slow engine step must charge queueing delay to every request
    # that arrived during it: with a service model far slower than the
    # arrival gaps, open-loop p99 >> service cost of a single batch
    asp = ArrivalSpec(process="uniform", rate_rps=10000.0,
                      n_requests=300, seed=1)
    wl = WorkloadSpec(n_inputs=256, seed=2)
    rows = generate_rows(asp, wl)
    model = ServiceModel(base_ms=5.0, per_slot_ms=0.0, per_cycle_ms=0.0)
    eng = _engine(wl, VirtualClock(model))
    rep = run_rows(eng, wl, rows, slo_ms=50.0)
    # arrivals outpace service 5x+: the backlog grows, so tail e2e
    # reflects accumulated queueing, not the 5 ms service floor
    assert rep.e2e_ms_p99 > 5 * rep.e2e_ms_p50 or rep.e2e_ms_p99 > 25.0
    assert rep.queue_wait_ms_p99 > model.base_ms


def test_slo_attainment_counts_non_served_against():
    asp = ArrivalSpec(process="uniform", rate_rps=50000.0,
                      n_requests=200, seed=1)
    wl = WorkloadSpec(n_inputs=256, seed=2,
                      deadline_choices=(1.0,))   # 1 ms: most expire
    rows = generate_rows(asp, wl)
    model = ServiceModel(base_ms=10.0, per_slot_ms=0.0,
                         per_cycle_ms=0.0)
    rep = run_rows(_engine(wl, VirtualClock(model)), wl, rows,
                   slo_ms=50.0)
    assert rep.per_status.get("EXPIRED", 0) > 0
    assert rep.slo_attainment < 0.5
    assert math.isclose(
        sum(rep.per_status.values()), rep.n_offered)


def test_rate_sweep_bisects():
    # synthetic run_at: attainment flips at 1000 rps
    calls = []

    def run_at(rate):
        calls.append(rate)
        class R:
            slo_attainment = 1.0 if rate <= 1000.0 else 0.0
        return R()

    rate, rep = rate_sweep(run_at, 100.0, 2000.0, slo_floor=0.95,
                           iters=8)
    assert 950.0 < rate <= 1000.0
    assert rep.slo_attainment == 1.0
    # degenerate ends
    rate, _ = rate_sweep(run_at, 2000.0, 4000.0)
    assert rate == 0.0
    rate, _ = rate_sweep(run_at, 100.0, 900.0)
    assert rate == 900.0


def test_engine_stats_offered_vs_achieved():
    asp, wl = _specs(n=100, rate=4000.0)
    rows = generate_rows(asp, wl)
    eng = _engine(wl, make_clock("virtual"))
    run_rows(eng, wl, rows, slo_ms=50.0)
    st = eng.stats()
    assert st["submitted"] == 100
    assert st["offered_rps"] >= st["achieved_rps"] > 0
    assert eng.per_status()["SERVED"] == st["windows_served"]
    assert sum(eng.per_status().values()) == 100
