"""Per-architecture smoke tests: reduced same-family config, one
forward/train step + one decode step on CPU; asserts shapes + finite."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_configs, reduced
from repro.models.transformer import Model

ARCHS = [
    "whisper-small", "mixtral-8x22b", "grok-1-314b", "rwkv6-7b",
    "starcoder2-3b", "command-r-35b", "gemma3-1b", "llama3-405b",
    "jamba-1.5-large-398b", "internvl2-26b",
]

B, T = 2, 32


def _batch(cfg, key):
    ks = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, T), 0, cfg.vocab_size,
                                     dtype=jnp.int32),
        "labels": jax.random.randint(ks[1], (B, T), 0, cfg.vocab_size,
                                     dtype=jnp.int32),
    }
    if cfg.is_enc_dec:
        batch["frames"] = jax.random.normal(
            ks[2], (B, cfg.frontend_len, cfg.d_model), jnp.float32)
    if cfg.frontend == "vision":
        batch["patches"] = jax.random.normal(
            ks[2], (B, cfg.frontend_len, cfg.d_model), jnp.float32)
    return batch


def test_all_archs_registered():
    names = list_configs()
    for a in ARCHS:
        assert a in names, f"{a} missing from registry"


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_loss(arch):
    cfg = reduced(get_config(arch))
    model = Model(cfg, dtype=jnp.float32, loss_chunk=16, attn_chunk=16)
    params = model.init_params(jax.random.key(0))
    batch = _batch(cfg, jax.random.key(1))
    h, aux = jax.jit(model.forward_hidden)(params, batch)
    assert h.shape == (B, T, cfg.d_model)
    assert np.isfinite(np.asarray(h, np.float32)).all()
    loss = jax.jit(model.loss)(params, batch)
    lv = float(loss)
    assert np.isfinite(lv)
    # untrained loss should be near ln(V)
    assert 0.2 * np.log(cfg.vocab_size) < lv < 3.0 * np.log(cfg.vocab_size)


@pytest.mark.parametrize("arch", ARCHS)
def test_train_grad_step(arch):
    cfg = reduced(get_config(arch))
    model = Model(cfg, dtype=jnp.float32, loss_chunk=16, attn_chunk=16)
    params = model.init_params(jax.random.key(0))
    batch = _batch(cfg, jax.random.key(1))
    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in flat)
    # at least the embedding must receive gradient
    gnorm = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
                for g in flat)
    assert gnorm > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch):
    cfg = reduced(get_config(arch))
    model = Model(cfg, dtype=jnp.float32, attn_chunk=16)
    params = model.init_params(jax.random.key(0))
    max_len = 64
    cache = model.init_cache(B, max_len)
    if cfg.is_enc_dec:
        enc = jax.random.normal(jax.random.key(3),
                                (B, cfg.frontend_len, cfg.d_model))
        cache["enc_out"] = enc.astype(model.dtype)
        # fill cross caches from the encoder output
    tok = jnp.zeros((B, 1), jnp.int32)
    step = jax.jit(model.decode_step)
    logits, cache = step(params, tok, cache, jnp.int32(0))
    logits2, cache = step(params, tok + 1, cache, jnp.int32(1))
    assert logits.shape == (B, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


@pytest.mark.parametrize("arch", ["starcoder2-3b", "rwkv6-7b",
                                  "jamba-1.5-large-398b", "gemma3-1b",
                                  "mixtral-8x22b"])
def test_prefill_then_decode_consistency(arch):
    """Greedy decode after prefill == teacher-forced forward argmax."""
    import dataclasses
    cfg = reduced(get_config(arch))
    if cfg.n_experts:
        # capacity drops depend on batch length; disable for parity check
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    model = Model(cfg, dtype=jnp.float32, attn_chunk=16)
    params = model.init_params(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(2), (B, 16), 0,
                              cfg.vocab_size, dtype=jnp.int32)
    # full forward logits at the last position
    h, _ = model.forward_hidden(params, {"tokens": toks})
    full_logits = model._logits(params, h[:, -1:])[:, 0]
    # prefill on the first 15 tokens, then decode token 15
    pre_logits, cache, clen = model.prefill(
        params, {"tokens": toks[:, :15]}, max_len=32)
    logits, _ = model.decode_step(params, toks[:, 15:16], cache, clen)
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(full_logits),
                               atol=2e-3, rtol=2e-3)
