"""Optimizer: AdamW semantics, low-precision states, stochastic
rounding, 1-bit compression with error feedback."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import AdamW, AdamWConfig, cosine_schedule
from repro.optim.adamw import _stochastic_round_bf16
from repro.optim.compression import (compress_tree, decompress_tree,
                                     init_error, onebit_compress,
                                     onebit_decompress)


def _quadratic_params():
    return {"w": jnp.array([2.0, -3.0, 5.0]), "b": jnp.array([1.0])}


def test_adamw_converges_on_quadratic():
    opt = AdamW(AdamWConfig(lr=0.1, weight_decay=0.0))
    params = _quadratic_params()
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state = opt.apply(g, state, params)
    assert float(loss(params)) < 1e-2


def test_adamw_bias_correction_first_step():
    opt = AdamW(AdamWConfig(lr=1e-1, grad_clip=1e9, weight_decay=0.0))
    params = {"w": jnp.array([0.0])}
    state = opt.init(params)
    g = {"w": jnp.array([0.5])}
    params, state = opt.apply(g, state, params)
    # with bias correction, the first update is ~ -lr * sign(g)
    np.testing.assert_allclose(float(params["w"][0]), -0.1, rtol=1e-3)


def test_grad_clip_limits_update_norm():
    opt = AdamW(AdamWConfig(lr=1.0, grad_clip=1e-3, weight_decay=0.0))
    params = {"w": jnp.ones((4,))}
    state = opt.init(params)
    g = {"w": jnp.full((4,), 1e6)}
    p2, _ = opt.apply(g, state, params)
    assert np.isfinite(np.asarray(p2["w"])).all()


def test_scanned_update_matches_flat():
    """Large stacked leaves (scan path) == small-leaf math."""
    opt = AdamW(AdamWConfig(lr=0.01, weight_decay=0.0))
    big = {"w": jnp.arange(4 * 64 * 64, dtype=jnp.float32
                           ).reshape(4, 64, 64) / 1e4}
    g = {"w": jnp.ones_like(big["w"]) * 0.1}
    s = opt.init(big)
    # force the scan path by lowering the threshold
    orig = AdamW._SCAN_THRESHOLD
    try:
        AdamW._SCAN_THRESHOLD = 1
        p_scan, s_scan = opt.apply(g, s, big)
    finally:
        AdamW._SCAN_THRESHOLD = orig
    p_flat, s_flat = opt.apply(g, opt.init(big), big)
    np.testing.assert_allclose(np.asarray(p_scan["w"]),
                               np.asarray(p_flat["w"]),
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(s_scan["m"]["w"]),
                               np.asarray(s_flat["m"]["w"]),
                               rtol=1e-5, atol=1e-7)


def test_stochastic_rounding_unbiased():
    x = jnp.full((20000,), 1.0 + 1e-3, jnp.float32)  # between bf16 grid pts
    keys = jax.random.key(0)
    r = _stochastic_round_bf16(x, keys)
    vals = np.asarray(r, np.float32)
    grid = np.unique(vals)
    assert len(grid) == 2  # rounds to the two neighbours only
    mean = vals.mean()
    np.testing.assert_allclose(mean, 1.0 + 1e-3, atol=2e-4)


def test_stochastic_rounding_training_progresses_in_bf16():
    """bf16 params + tiny LR: deterministic rounding loses every update;
    stochastic rounding makes progress (the paper's C3 insight)."""
    lr = 2e-4
    steps = 300
    w0 = jnp.float32(1.0)

    def run(stochastic):
        opt = AdamW(AdamWConfig(lr=lr, weight_decay=0.0,
                                state_dtype=jnp.bfloat16,
                                stochastic_rounding=stochastic))
        params = {"w": w0.astype(jnp.bfloat16)}
        state = opt.init(params)
        key = jax.random.key(1)
        for i in range(steps):
            g = {"w": params["w"].astype(jnp.float32) * 2.0}  # d/dw w^2
            key, k = jax.random.split(key)
            params, state = opt.apply(
                g, state, params, rng=k if stochastic else None)
        return float(params["w"].astype(jnp.float32))

    w_stoch = run(True)
    w_det = run(False)
    # deterministic bf16 rounding loses sub-ULP updates (w stuck at 1.0);
    # stochastic rounding keeps their expected value
    assert w_det > 0.995, w_det
    assert w_stoch < w_det - 0.01, (w_stoch, w_det)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup_steps=10, total_steps=100)
    assert float(lr(0)) == 0.0
    np.testing.assert_allclose(float(lr(10)), 1.0, rtol=1e-5)
    assert float(lr(100)) < 0.11
    assert float(lr(50)) < float(lr(20))


# --- 1-bit compression ---------------------------------------------------------

def test_onebit_roundtrip_preserves_sign_and_scale():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(257,)).astype(np.float32))
    err = jnp.zeros_like(g)
    comp, new_err = onebit_compress(g, err)
    out = onebit_decompress(comp, g.shape, g.size)
    go = np.asarray(g)
    oo = np.asarray(out)
    nz = go != 0
    assert np.all(np.sign(oo[nz]) == np.sign(go[nz]))
    np.testing.assert_allclose(float(comp["scale"]),
                               np.abs(np.asarray(g)).mean(), rtol=1e-5)


def test_error_feedback_bounds_accumulated_bias():
    """Compressing a constant gradient with error feedback recovers the
    true mean over time (residual stays bounded)."""
    g_true = jnp.asarray(np.linspace(-1, 1, 64).astype(np.float32))
    err = jnp.zeros_like(g_true)
    total = np.zeros(64, np.float32)
    n = 200
    for _ in range(n):
        comp, err = onebit_compress(g_true, err)
        total += np.asarray(onebit_decompress(comp, g_true.shape, 64))
    # time-average converges to the true gradient (sign compression is
    # unbiased WITH feedback; naive sign-only would stick at +-scale)
    np.testing.assert_allclose(total / n, np.asarray(g_true), atol=0.1)
    # residual stays bounded (grows ~linearly only until the scale
    # adapts; see compression.py docstring)
    assert float(jnp.max(jnp.abs(err))) < 20.0


def test_compress_tree_structure():
    grads = {"a": jnp.ones((10,)), "b": {"c": -jnp.ones((5,))}}
    err = init_error(grads)
    comp, err2 = compress_tree(grads, err)
    out = decompress_tree(comp, grads)
    assert out["a"].shape == (10,)
    assert out["b"]["c"].shape == (5,)
    assert (np.asarray(out["a"]) > 0).all()
    assert (np.asarray(out["b"]["c"]) < 0).all()
