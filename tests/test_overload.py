"""Overload-control tests: AIMD admission, CoDel sojourn management,
priority-aware shedding, the global retry budget, ladder circuit
breakers, and the engine integration (bit-identical replay, goodput
retention vs a naive engine, AIMD convergence on the virtual clock)."""

import dataclasses
import json
import math

import numpy as np
import pytest

from repro.core.stdp import init_weights
from repro.engine.plan import SNNEnginePlan
from repro.loadgen import (ArrivalSpec, WorkloadSpec, generate_rows,
                           scale_rows, u01)
from repro.loadgen.runner import (ServiceModel, VirtualClock, make_clock,
                                  rate_sweep, run_rows)
from repro.serving import (FaultInjector, FaultSpec, LadderBreakers,
                           OverloadController, OverloadPolicy, SNNRequest,
                           SNNServingEngine, SNNServingPolicy,
                           storm_policy)
from repro.serving.overload import (CLOSED, HALF_OPEN, OPEN,
                                    SHED_ADMISSION, SHED_CODEL,
                                    SHED_LOW_PRIORITY)


# --- policy validation -------------------------------------------------

@pytest.mark.parametrize("bad", [
    dict(slo_ms=0.0),
    dict(interval_ms=-1.0),
    dict(md_factor=1.0),
    dict(md_factor=0.0),
    dict(admit_rps_min=200.0, admit_rps_max=100.0),
    dict(admit_rps_init=10.0, admit_rps_min=50.0),
    dict(low_shed_start=0.9, low_shed_full=0.5),
    dict(low_shed_full=1.5),
    dict(high_reserve=-1.0),
    dict(max_sojourn_ms=0.0),
])
def test_policy_validation(bad):
    with pytest.raises(ValueError):
        OverloadPolicy(**bad)


def test_sojourn_limit_defaults_to_fraction_of_slo():
    assert OverloadPolicy(slo_ms=100.0).sojourn_limit_ms == 80.0
    assert OverloadPolicy(max_sojourn_ms=7.0).sojourn_limit_ms == 7.0


def test_storm_policy_scales_to_base_rate():
    p = storm_policy(10000.0)
    assert p.admit_rps_init == 20000.0
    assert p.admit_rps_min == 2500.0
    assert OverloadController(p).admit_rate == 20000.0


# --- AIMD token bucket -------------------------------------------------

def test_bucket_exhaustion_sheds_low_but_never_high():
    p = OverloadPolicy(burst=4.0, high_reserve=2.0, admit_rps_min=50.0,
                       admit_rps_max=50.0, low_shed_start=0.98,
                       low_shed_full=0.99)
    c = OverloadController(p)
    # burst 4, low needs 1 + reserve 2 = 3 tokens: two low admits fit
    # (4 -> 3 -> 2), the third finds the reserve breached
    assert c.admit(0, 0, 1024, now_ms=0.0) == (True, None)
    assert c.admit(0, 0, 1024, now_ms=0.0) == (True, None)
    ok, tag = c.admit(0, 0, 1024, now_ms=0.0)
    assert not ok and tag == SHED_ADMISSION
    # the high class bypasses the limiter even with the bucket drained
    for _ in range(10):
        ok, tag = c.admit(1, 0, 1024, now_ms=0.0)
        assert ok and tag is None
    assert c._tokens == 0.0          # high still drains what exists


def test_bucket_refills_at_admit_rate():
    p = OverloadPolicy(burst=8.0, high_reserve=0.0, admit_rps_min=1000.0,
                       admit_rps_max=1000.0, low_shed_start=0.98,
                       low_shed_full=0.99)
    c = OverloadController(p)
    for _ in range(8):
        assert c.admit(0, 0, 1024, now_ms=0.0)[0]
    assert not c.admit(0, 0, 1024, now_ms=0.0)[0]
    # 1000 rps = 1 token/ms: 5 ms restores 5 admits
    admits = sum(c.admit(0, 0, 1024, now_ms=5.0)[0] for _ in range(8))
    assert admits == 5


def test_aimd_decreases_on_congestion_increases_when_clean():
    p = OverloadPolicy(interval_ms=10.0, additive_rps=100.0,
                       md_factor=0.5, admit_rps_init=1000.0,
                       admit_rps_min=50.0, admit_rps_max=2000.0)
    c = OverloadController(p)
    c.admit(0, 0, 1024, now_ms=0.0)          # opens the interval
    c.note_served(p.slo_ms + 1.0)            # SLO breach -> congested
    c.admit(0, 0, 1024, now_ms=11.0)         # interval rolls: MD
    assert c.admit_rate == 500.0 and c.md_events == 1
    c.admit(0, 0, 1024, now_ms=22.0)         # clean interval: AI
    assert c.admit_rate == 600.0 and c.ai_events == 1
    # bucket exhaustion alone must NOT trigger MD
    c._tokens = 0.0
    assert not c.admit(0, 0, 1024, now_ms=23.0)[0]
    c.admit(0, 0, 1024, now_ms=33.0)
    assert c.admit_rate == 700.0             # still additive increase


# --- RED low-priority shed ---------------------------------------------

def test_red_shed_ramp_is_deterministic_and_monotone():
    p = OverloadPolicy(low_shed_start=0.25, low_shed_full=0.75,
                       admit_rps_min=1e6, admit_rps_max=1e6, burst=1e6)

    def shed_rate(occ):
        c = OverloadController(p)
        n = 200
        sheds = sum(c.admit(0, int(occ * 1000), 1000, now_ms=0.0)[1]
                    == SHED_LOW_PRIORITY for _ in range(n))
        return sheds / n

    assert shed_rate(0.2) == 0.0             # below the ramp
    mid, near_full = shed_rate(0.5), shed_rate(0.7)
    assert 0.2 < mid < 0.8 < near_full < 1.0
    assert shed_rate(0.75) == 1.0            # at/after full: always shed
    assert shed_rate(0.5) == mid             # same seed+counters: exact
    # the draw is the documented stateless counter hash
    c = OverloadController(p)
    ok, tag = c.admit(0, 500, 1000, now_ms=0.0)
    want_shed = u01(p.seed, 1, 1) < 0.5    # frac = (0.5-0.25)/(0.75-0.25)
    assert (tag == SHED_LOW_PRIORITY) == want_shed


def test_high_priority_skips_red_shed():
    p = OverloadPolicy(low_shed_start=0.1, low_shed_full=0.2)
    c = OverloadController(p)
    for _ in range(50):
        ok, tag = c.admit(1, 999, 1000, now_ms=0.0)
        assert ok and tag is None


# --- CoDel state machine -----------------------------------------------

def test_codel_arms_drops_and_exits():
    p = OverloadPolicy(target_sojourn_ms=5.0, interval_ms=100.0)
    c = OverloadController(p)
    assert c.on_dequeue(20.0, 0.0, 100) == 0      # arms first_above
    assert not c.dropping
    assert c.on_dequeue(20.0, 50.0, 100) == 0     # inside the interval
    n = c.on_dequeue(20.0, 101.0, 100)            # interval elapsed
    assert c.dropping and n >= 1 and c.codel_entries == 1
    # sqrt law: the first drop schedules the next interval/sqrt(1) out
    assert c._drop_next_ms == pytest.approx(101.0 + 100.0 / math.sqrt(1))
    # still dropping at t=350: drops 2..4 land interval/sqrt(k) apart
    # (201 + 100/sqrt(2) + 100/sqrt(3) ~ 329.4 <= 350 < +100/sqrt(4))
    n2 = c.on_dequeue(20.0, 350.0, 100)
    assert n2 == 3
    assert c._drop_next_ms == pytest.approx(
        201.0 + 100.0 / math.sqrt(2) + 100.0 / math.sqrt(3)
        + 100.0 / math.sqrt(4))
    # a single below-target observation resets everything
    assert c.on_dequeue(1.0, 150.0, 100) == 0
    assert not c.dropping and c._first_above_ms is None


def test_codel_drop_count_bounded_by_backlog():
    c = OverloadController(OverloadPolicy(target_sojourn_ms=1.0,
                                          interval_ms=10.0))
    c.on_dequeue(50.0, 0.0, 3)
    n = c.on_dequeue(50.0, 1000.0, 3)             # far past drop_next
    assert n <= 3                                 # never more than queued


# --- global retry budget -----------------------------------------------

def test_retry_budget_drains_and_refills():
    p = OverloadPolicy(retry_budget=2.0, retry_refill_per_s=1000.0)
    c = OverloadController(p)
    assert c.grant_retry(0.0) and c.grant_retry(0.0)
    assert not c.grant_retry(0.0)                 # exhausted
    assert c.grant_retry(1.5)                     # 1000/s: 1.5 tokens back
    assert not c.grant_retry(1.5)


# --- ladder breakers ---------------------------------------------------

def test_breaker_lifecycle():
    b = LadderBreakers(3)
    assert b.states() == [CLOSED] * 3
    b.open_rung(0)
    b.open_rung(0)                                # idempotent trip
    assert b.states() == [OPEN, CLOSED, CLOSED] and b.trips == 1
    b.open_rung(1)
    b.half_open_all()
    assert b.states() == [HALF_OPEN, HALF_OPEN, CLOSED]
    assert b.reprobes == 1
    b.close_trials()
    assert b.states() == [CLOSED] * 3
    b.open_rung(99)                               # out of range: ignored
    assert b.trips == 2
    # state round-trip (the journal snapshot path)
    b2 = LadderBreakers(3, states=[OPEN, HALF_OPEN, "bogus"])
    assert b2.states() == [OPEN, HALF_OPEN, CLOSED]


def test_controller_state_round_trip():
    c = OverloadController(OverloadPolicy(admit_rps_init=5000.0))
    c.admit(0, 10, 100, now_ms=3.0)
    c.on_dequeue(50.0, 4.0, 10)
    c.grant_retry(5.0)
    d = json.loads(json.dumps(c.state_dict()))    # JSON-safe
    c2 = OverloadController(c.policy)
    c2.load_state(d)
    assert c2.state_dict() == c.state_dict()
    c2.load_state({"unknown_future_key": 1})      # tolerated
    assert c2.state_dict() == c.state_dict()


# --- engine integration ------------------------------------------------

N_NEURONS = 32
N_INPUTS = 256


def _plan(max_batch=16):
    return SNNEnginePlan(threshold=192, leak=16, n_syn=N_INPUTS,
                         encode="kernel", cycle_backend="window",
                         max_batch=max_batch, t_chunk=8)


def _engine(overload=None, injector=None, max_queue=512,
            deadline_ms=200.0):
    return SNNServingEngine(
        init_weights(N_NEURONS, N_INPUTS // 32, density_seed=0), _plan(),
        policy=SNNServingPolicy(max_queue=max_queue,
                                deadline_ms=deadline_ms),
        clock=VirtualClock(ServiceModel()), on_launch=injector,
        overload=overload)


def _specs(n, rate, high_frac=0.1):
    asp = ArrivalSpec(process="poisson", rate_rps=rate, n_requests=n,
                      seed=9)
    wl = WorkloadSpec(n_inputs=N_INPUTS, seed=4,
                      priority_choices=(0, 1),
                      priority_weights=(round(10 * (1 - high_frac)),
                                        round(10 * high_frac)))
    return asp, wl


def test_stats_keys_under_zero_traffic():
    """stats() must be fully populated before any request arrives."""
    eng = _engine(overload=OverloadPolicy(admit_rps_init=1234.0))
    st = eng.stats()
    assert st["admit_rate_rps"] == 1234.0
    for k in ("shed_admission", "shed_low_priority", "shed_codel",
              "retries_denied", "codel_entries", "aimd_md_events",
              "aimd_ai_events"):
        assert st[k] == 0
    assert st["codel_dropping"] is False
    assert st["retry_tokens"] == OverloadPolicy().retry_budget
    assert st["breaker_states"] == [CLOSED] * len(eng._plans)
    assert st["breaker_trips"] == 0
    # without a controller the overload keys are absent, the breaker
    # keys still present (pure observability, always on)
    bare = _engine().stats()
    assert "admit_rate_rps" not in bare
    assert bare["breaker_states"] == [CLOSED] * len(eng._plans)


def test_form_batch_expired_high_before_live_low():
    """A high-priority request whose deadline already elapsed must
    resolve EXPIRED at batch formation while a live low-priority
    request in the same queue still gets served."""
    eng = _engine()
    rng = np.random.default_rng(0)
    inten = rng.integers(0, 256, (N_INPUTS,), dtype=np.uint8)
    dead = SNNRequest(rid=0, intensities=inten, n_steps=8, priority=1,
                      deadline_ms=0.0)
    live = SNNRequest(rid=1, intensities=inten, n_steps=8, priority=0)
    eng.submit(dead)
    eng.submit(live)
    eng.clock.skip_to(eng.clock.now_ms() + 1.0)   # the deadline passes
    eng.step()
    assert dead.status == "EXPIRED"
    assert live.status == "SERVED"
    assert dead.shed is None                      # deadline, not a shed


def test_overload_none_engine_unchanged():
    """overload=None must leave the legacy pipeline bit-identical:
    no admission gate, no CoDel, no new stats keys."""
    asp, wl = _specs(n=300, rate=8000.0)
    rows = generate_rows(asp, wl)
    r1 = run_rows(_engine(), wl, rows, slo_ms=50.0)
    r2 = run_rows(_engine(), wl, rows, slo_ms=50.0)
    assert json.dumps(r1.to_dict(), sort_keys=True) == \
        json.dumps(r2.to_dict(), sort_keys=True)
    assert r1.per_status.get("REJECTED", 0) == 0


def test_overload_replay_bit_identical():
    asp, wl = _specs(n=600, rate=60000.0)         # well past capacity
    rows = generate_rows(asp, wl)

    def once():
        eng = _engine(overload=storm_policy(15000.0),
                      injector=FaultInjector(FaultSpec(
                          p_slowdown=0.05, slowdown_factor=3.0,
                          slowdown_steps=4, seed=3)))
        rep = run_rows(eng, wl, rows, slo_ms=50.0)
        return rep, eng.stats()

    (r1, s1), (r2, s2) = once(), once()
    assert json.dumps(r1.to_dict(), sort_keys=True) == \
        json.dumps(r2.to_dict(), sort_keys=True)
    assert {k: v for k, v in s1.items() if "ms" not in k} == \
        {k: v for k, v in s2.items() if "ms" not in k}
    assert r1.non_terminal == 0
    # overload shed mass exists and concentrates on the low class
    assert s1["shed_admission"] + s1["shed_low_priority"] \
        + s1["shed_codel"] > 0
    assert r1.slo_attainment_by_priority["1"] >= \
        r1.slo_attainment_by_priority["0"]


def test_controller_beats_naive_on_high_priority_under_overload():
    """Same 4x-overload stream: the controlled engine must keep the
    high class's SLO attainment where the naive engine loses it."""
    asp, wl = _specs(n=1200, rate=15000.0)
    rows = scale_rows(generate_rows(asp, wl), 4.0)  # ~60k rps offered
    naive = run_rows(_engine(), wl, rows, slo_ms=50.0)
    ctrl = run_rows(_engine(overload=storm_policy(15000.0)), wl, rows,
                    slo_ms=50.0)
    assert ctrl.non_terminal == naive.non_terminal == 0
    assert ctrl.slo_attainment_by_priority["1"] >= 0.95
    assert ctrl.slo_attainment_by_priority["1"] > \
        naive.slo_attainment_by_priority["1"]
    # every terminal is attributed exactly once across statuses
    assert sum(ctrl.per_status.values()) == len(rows)


def test_retry_denial_under_fault_burst():
    """A correlated launch-fault burst must hit the global retry budget
    and fail fast (retries_denied > 0) instead of retry-storming."""
    pol = OverloadPolicy(retry_budget=1.0, retry_refill_per_s=0.0)
    eng = _engine(overload=pol,
                  injector=FaultInjector(FaultSpec(
                      p_launch_error=0.9, error_burst=64, seed=11)))
    rng = np.random.default_rng(1)
    reqs = [SNNRequest(rid=i,
                       intensities=rng.integers(0, 256, (N_INPUTS,),
                                                dtype=np.uint8),
                       n_steps=8) for i in range(24)]
    eng.run(reqs)
    assert all(r.terminal for r in reqs)
    assert eng.retries_denied > 0
    # budget 1, no refill: at most one granted retry ever
    assert eng.retried <= 1


def test_aimd_converges_toward_sustainable_rate():
    """Property: under sustained overload on the virtual clock, the
    AIMD admission rate must end within the oscillation band of the
    independently-bisected sustainable rate — the limiter finds the
    capacity, it is not pinned at either rail."""
    asp, wl = _specs(n=3000, rate=1000.0, high_frac=0.0)

    def run_at(rate):
        rows = generate_rows(dataclasses.replace(asp, rate_rps=rate),
                             wl)
        return run_rows(_engine(), wl, rows, slo_ms=50.0)

    sustainable, _ = rate_sweep(run_at, 2000.0, 32000.0,
                                slo_floor=0.95, iters=5)
    assert 0.0 < sustainable < 32000.0
    rows = generate_rows(
        dataclasses.replace(asp, rate_rps=3.0 * sustainable,
                            n_requests=6000), wl)
    eng = _engine(overload=storm_policy(sustainable))
    run_rows(eng, wl, rows, slo_ms=50.0)
    rate = eng.stats()["admit_rate_rps"]
    p = eng.overload.policy
    assert p.admit_rps_min < rate < p.admit_rps_max   # off both rails
    # within the AIMD sawtooth band around capacity
    assert 0.3 * sustainable < rate < 1.7 * sustainable
    assert eng.stats()["aimd_md_events"] > 0
    assert eng.stats()["aimd_ai_events"] > 0


# --- rate_sweep degenerate edges ---------------------------------------

def test_rate_sweep_floor_unmet_at_lo_returns_zero_with_report():
    reports = {}

    def run_at(rate):
        class R:
            slo_attainment = 0.2
        reports[rate] = R()
        return reports[rate]

    rate, rep = rate_sweep(run_at, 500.0, 8000.0, slo_floor=0.95)
    assert rate == 0.0
    assert rep is reports[500.0]          # the lo report, not a dummy
    assert list(reports) == [500.0]       # no wasted probes past lo


def test_rate_sweep_floor_met_at_hi_returns_hi_with_report():
    calls = []

    def run_at(rate):
        calls.append(rate)
        class R:
            slo_attainment = 1.0
        return R()

    rate, rep = rate_sweep(run_at, 500.0, 8000.0, slo_floor=0.95)
    assert rate == 8000.0
    assert rep.slo_attainment == 1.0
    assert calls == [500.0, 8000.0]       # range was the binding limit
