"""CI perf gate: regression detection over BENCH_kernels.json rows."""

import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
if str(REPO) not in sys.path:
    sys.path.insert(0, str(REPO))

from benchmarks.run import (GATE_LATENCY_FLOOR_MS,  # noqa: E402
                            GATE_LATENCY_RATIO, GATE_SLO_DROP,
                            GATE_THRESHOLD, GATE_TIME_BASE_MIN,
                            GATE_TIME_FLOOR, check_regressions,
                            load_baseline)


def test_detects_lost_structural_speedup():
    base = {"k/window": {"time_ratio": 8.0, "bytes_ratio": 30.0}}
    rows = {"k/window": {"time_ratio": 1.0, "bytes_ratio": 30.0}}
    msgs = check_regressions(base, rows)
    assert len(msgs) == 1 and "time_ratio" in msgs[0]


def test_noisy_but_still_structural_time_ratio_passes():
    """Wall-clock swings above the floor never gate (the committed
    baseline's time_ratios vary several-x run to run)."""
    base = {"k/window": {"time_ratio": 8.0}}
    rows = {"k/window": {"time_ratio": GATE_TIME_FLOOR + 0.1}}
    assert check_regressions(base, rows) == []


def test_bytes_ratio_always_gates():
    base = {"k/fused": {"time_ratio": 1.1, "bytes_ratio": 1.29}}
    rows = {"k/fused": {"time_ratio": 1.1, "bytes_ratio": 0.5}}
    msgs = check_regressions(base, rows)
    assert len(msgs) == 1 and "bytes_ratio" in msgs[0]


def test_noise_band_time_rows_never_gate():
    """Rows whose baseline ratio is not clearly structural (< base min)
    are exempt from time gating — their noise band straddles any
    threshold (observed 1.1 <-> 1.55 on identical code)."""
    base = {"k/fused": {"time_ratio": GATE_TIME_BASE_MIN - 0.5}}
    rows = {"k/fused": {"time_ratio": 0.4}}
    assert check_regressions(base, rows) == []


def test_within_threshold_passes():
    base = {"k/w": {"bytes_ratio": 8.0}}
    rows = {"k/w": {"bytes_ratio": 8.0 * (1.0 - GATE_THRESHOLD + 0.01)}}
    assert check_regressions(base, rows) == []


def test_new_removed_and_ratio_free_rows_ignored():
    base = {"gone": {"time_ratio": 9.0}, "interp": {"us_per_call": 3.0}}
    rows = {"new": {"time_ratio": 9.0}, "interp": {"us_per_call": 9.0}}
    assert check_regressions(base, rows) == []


def test_latency_gates_on_increase():
    """Serving latency percentiles gate the INCREASE direction: a
    percentile past ratio x baseline AND above the absolute floor
    fails (a serving step that started recompiling/blocking)."""
    base = {"serve/latency-a": {"service_ms_p99": 2.0,
                               "queue_wait_ms_p50": 1.0}}
    bad = 2.0 * GATE_LATENCY_RATIO + GATE_LATENCY_FLOOR_MS
    rows = {"serve/latency-a": {"service_ms_p99": bad,
                               "queue_wait_ms_p50": 1.0}}
    msgs = check_regressions(base, rows)
    assert len(msgs) == 1 and "service_ms_p99" in msgs[0]


def test_latency_noise_below_floor_never_gates():
    """A huge relative jump that stays under the absolute floor is
    host-speed noise on a sub-ms path, not a regression."""
    base = {"serve/latency-a": {"service_ms_p99": 0.5}}
    rows = {"serve/latency-a": {
        "service_ms_p99": GATE_LATENCY_FLOOR_MS - 1.0}}
    assert check_regressions(base, rows) == []


def test_latency_slow_but_proportional_never_gates():
    """Above the floor but within ratio x baseline passes — a uniformly
    slower CI host shifts every percentile without tripping the gate."""
    base = {"serve/latency-a": {"service_ms_p99": 20.0}}
    rows = {"serve/latency-a": {
        "service_ms_p99": 20.0 * (GATE_LATENCY_RATIO - 1.0)}}
    assert check_regressions(base, rows) == []


def test_latency_decrease_never_gates():
    base = {"serve/latency-a": {"service_ms_p99": 200.0}}
    rows = {"serve/latency-a": {"service_ms_p99": 1.0}}
    assert check_regressions(base, rows) == []


def test_p999_latency_suffix_gates():
    """The loadgen rows' _ms_p999 tail percentile is gated like the
    p50/p99 suffixes."""
    base = {"loadgen/virtual-a": {"e2e_ms_p999": 2.0}}
    bad = 2.0 * GATE_LATENCY_RATIO + GATE_LATENCY_FLOOR_MS
    rows = {"loadgen/virtual-a": {"e2e_ms_p999": bad}}
    msgs = check_regressions(base, rows)
    assert len(msgs) == 1 and "e2e_ms_p999" in msgs[0]


def test_slo_attainment_gates_on_absolute_drop():
    base = {"loadgen/virtual-a": {"slo_attainment": 0.99}}
    rows = {"loadgen/virtual-a": {
        "slo_attainment": 0.99 - GATE_SLO_DROP - 0.01}}
    msgs = check_regressions(base, rows)
    assert len(msgs) == 1 and "slo_attainment" in msgs[0]
    # within the allowance (and any increase) passes
    ok = {"loadgen/virtual-a": {
        "slo_attainment": 0.99 - GATE_SLO_DROP + 0.01}}
    assert check_regressions(base, ok) == []
    assert check_regressions(
        base, {"loadgen/virtual-a": {"slo_attainment": 1.0}}) == []


def test_sustainable_rps_gates_on_collapse():
    base = {"loadgen/sweep-5k": {"sustainable_rps": 40000.0}}
    rows = {"loadgen/sweep-5k": {
        "sustainable_rps": 40000.0 * (1.0 - GATE_THRESHOLD) * 0.9}}
    msgs = check_regressions(base, rows)
    assert len(msgs) == 1 and "sustainable_rps" in msgs[0]
    ok = {"loadgen/sweep-5k": {
        "sustainable_rps": 40000.0 * (1.0 - GATE_THRESHOLD + 0.01)}}
    assert check_regressions(base, ok) == []


def test_goodput_rps_gates_on_collapse():
    base = {"loadgen/overload-5x": {"goodput_rps": 22000.0}}
    rows = {"loadgen/overload-5x": {
        "goodput_rps": 22000.0 * (1.0 - GATE_THRESHOLD) * 0.9}}
    msgs = check_regressions(base, rows)
    assert len(msgs) == 1 and "goodput_rps" in msgs[0]
    ok = {"loadgen/overload-5x": {
        "goodput_rps": 22000.0 * (1.0 - GATE_THRESHOLD + 0.01)}}
    assert check_regressions(base, ok) == []


def test_high_slo_attainment_gates_on_absolute_drop():
    base = {"loadgen/overload-5x": {"high_slo_attainment": 1.0}}
    rows = {"loadgen/overload-5x": {
        "high_slo_attainment": 1.0 - GATE_SLO_DROP - 0.01}}
    msgs = check_regressions(base, rows)
    assert len(msgs) == 1 and "high_slo_attainment" in msgs[0]
    ok = {"loadgen/overload-5x": {
        "high_slo_attainment": 1.0 - GATE_SLO_DROP + 0.01}}
    assert check_regressions(base, ok) == []


def test_committed_baseline_has_loadgen_rows():
    """The gated loadgen rows (deterministic virtual replay + sweep)
    are committed with coordinated-omission-correct latency metrics."""
    baseline = load_baseline(str(REPO / "BENCH_kernels.json"))
    virtual = [row for name, row in baseline.items()
               if name.startswith("loadgen/virtual-")]
    assert virtual and all(
        k in virtual[0] for k in ("slo_attainment", "offered_rps",
                                  "achieved_rps", "e2e_ms_p50",
                                  "e2e_ms_p99", "e2e_ms_p999"))
    assert any("sustainable_rps" in row for row in baseline.values())


def test_committed_baseline_has_latency_rows():
    """The serve/latency-* percentiles are committed so the increase
    gate has a baseline to compare against."""
    baseline = load_baseline(str(REPO / "BENCH_kernels.json"))
    lat = [row for name, row in baseline.items()
           if name.startswith("serve/latency-")]
    assert lat and all(
        k in lat[0] for k in ("queue_wait_ms_p50", "queue_wait_ms_p99",
                              "service_ms_p50", "service_ms_p99"))


def test_committed_baseline_loads_and_has_gated_rows():
    baseline = load_baseline(str(REPO / "BENCH_kernels.json"))
    assert baseline is not None
    assert any("bytes_ratio" in row for row in baseline.values())
    assert any(row.get("time_ratio", 0) >= GATE_TIME_BASE_MIN
               for row in baseline.values())


def test_missing_baseline_returns_none():
    assert load_baseline(str(REPO / "no_such_baseline.json")) is None


def test_gate_without_json_is_an_error():
    """--gate must never be a silent no-op."""
    import subprocess
    proc = subprocess.run(
        [sys.executable, "benchmarks/run.py", "no_such_module",
         "--gate"],
        cwd=REPO, capture_output=True, text=True, timeout=300,
        env={**__import__("os").environ,
             "PYTHONPATH": f"{REPO / 'src'}:{REPO}"})
    assert proc.returncode == 2, proc.stdout + proc.stderr
    assert "--gate requires --json" in proc.stdout
