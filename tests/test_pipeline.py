"""Pipeline-parallel schedule == sequential stage application.

Runs in a subprocess with 4 forced host devices (the main test process
must keep the default single-device config)."""

import subprocess
import sys
import textwrap


def test_pipeline_matches_sequential():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys
        sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline import pipelined_apply

        S, M, B, D = 4, 6, 2, 16
        mesh = jax.make_mesh((S,), ("stage",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        key = jax.random.key(0)
        ws = jax.random.normal(key, (S, D, D)) * 0.3

        def stage_fn(w, x):
            return jnp.tanh(x @ w)

        micro_x = jax.random.normal(jax.random.key(1), (M, B, D))

        # sequential reference
        ref = micro_x
        for s in range(S):
            ref = jax.vmap(lambda x: stage_fn(ws[s], x))(ref)

        out = pipelined_apply(mesh, stage_fn, ws, micro_x,
                              axis_name="stage")
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)
        print("PIPELINE_OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], cwd="/root/repo",
                       capture_output=True, text=True, timeout=300)
    assert "PIPELINE_OK" in r.stdout, r.stdout + r.stderr
