"""Property-based tests (hypothesis) on system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import bitpack, lfsr
from repro.core.lif import lif_params, lif_step
from repro.core.stdp import stdp_params, stdp_update
from repro.core.energy import EnergyConstants, count_events, energy
from repro.optim.compression import onebit_compress, onebit_decompress

SET = settings(max_examples=30, deadline=None)


@SET
@given(st.lists(st.integers(0, 1), min_size=1, max_size=200),
       st.integers(0, 3))
def test_pack_unpack_roundtrip_property(bits, rows):
    arr = np.asarray(bits, np.int32)
    if rows:
        arr = np.tile(arr, (rows + 1, 1))
    packed = bitpack.pack(jnp.asarray(arr))
    out = np.asarray(bitpack.unpack(packed, arr.shape[-1]))
    np.testing.assert_array_equal(out, arr)


@SET
@given(st.lists(st.integers(0, 1), min_size=1, max_size=200))
def test_popcount_equals_bit_sum(bits):
    arr = np.asarray([bits], np.int32)
    packed = bitpack.pack(jnp.asarray(arr))
    got = int(bitpack.popcount(packed)[0])
    assert got == arr.sum()


@SET
@given(st.integers(0, 0xFFFF), st.integers(1, 64))
def test_lfsr_stays_nonzero_16bit(seed_val, steps):
    s = lfsr.seed(seed_val, 8)
    for _ in range(steps):
        s = lfsr.step(s)
        v = np.asarray(s)
        assert (v != 0).all()
        assert (v <= 0xFFFF).all()


@SET
@given(st.lists(st.integers(-200, 400), min_size=1, max_size=64),
       st.lists(st.integers(-100, 300), min_size=1, max_size=64),
       st.integers(1, 300), st.integers(0, 50))
def test_lif_invariants(vs, counts, threshold, leak):
    n = min(len(vs), len(counts))
    v = jnp.asarray(np.maximum(np.asarray(vs[:n], np.int32), 0))
    c = jnp.asarray(np.asarray(counts[:n], np.int32))
    p = lif_params(threshold, leak)
    v2, fired = lif_step(v, c, p)
    v2n = np.asarray(v2)
    fn = np.asarray(fired)
    assert (v2n >= 0).all()                       # floor at 0
    assert (v2n[fn] == 0).all()                   # reset on fire
    assert (v2n <= np.maximum(np.asarray(v) + np.asarray(c), 0)).all()
    # monotonicity: +1 input spike can only help firing
    v3, fired3 = lif_step(v, c + 1, p)
    assert (np.asarray(fired3) | ~fn).all()


@SET
@given(st.integers(0, 2**32 - 1), st.integers(0, 2**32 - 1),
       st.integers(1, 1023), st.integers(8, 512))
def test_stdp_invariants(wbits, prebits, ltp_prob, wexp):
    n, w = 4, 2
    rng = np.random.default_rng(wbits & 0xFFFF)
    weights = jnp.asarray(rng.integers(0, 2**32, (n, w), dtype=np.uint32))
    pre = jnp.asarray(
        np.array([prebits, wbits], np.uint32))
    fired = jnp.asarray(np.array([True, False, True, True]))
    state = lfsr.seed(prebits & 0xFFFF, n * w).reshape(n, w)
    p = stdp_params(64, wexp, ltp_prob=ltp_prob)
    w2, s2 = stdp_update(weights, pre, fired, state, p)
    w0 = np.asarray(weights)
    w2n = np.asarray(w2)
    pren = np.asarray(pre)
    # non-fired rows untouched
    np.testing.assert_array_equal(w2n[1], w0[1])
    # coincident synapses never cleared (LTD only strips non-coincident)
    for i in (0, 2, 3):
        coincident_before = w0[i] & pren
        assert ((w2n[i] & coincident_before) == coincident_before).all()
        # bits outside pre can only be cleared, never set
        assert ((w2n[i] & ~pren) & ~w0[i]).sum() == 0


@SET
@given(st.floats(0.05, 1.0), st.integers(0, 10_000),
       st.integers(16, 1024), st.integers(8, 64))
def test_energy_fused_never_exceeds_decoupled(activity, post, n_in, n_n):
    """Holds for input activity >= 5% (the paper's Poisson-MNIST regime
    is 15-20%).  Below that, the event-driven accelerator's idle-cycle
    skipping wins over the fused pipeline's per-cycle row streaming —
    a real crossover hypothesis found at near-zero activity, now
    documented here and in core/energy.py."""
    k = EnergyConstants()
    steps = 100
    in_spikes = int(activity * steps * n_in)
    ef = energy(count_events(n_n, n_in, steps, in_spikes, post, "fused"),
                k, "fused")
    ed = energy(count_events(n_n, n_in, steps, in_spikes, post,
                             "decoupled"), k, "decoupled")
    assert ef["total_J"] <= ed["total_J"]


@SET
@given(st.lists(st.floats(-10, 10, allow_nan=False), min_size=2,
                max_size=100))
def test_compression_identity(vals):
    g = jnp.asarray(np.asarray(vals, np.float32))
    err = jnp.zeros_like(g)
    comp, new_err = onebit_compress(g, err)
    out = onebit_decompress(comp, g.shape, g.size)
    # exact algebraic identity: g + err_in = q + err_out
    np.testing.assert_allclose(np.asarray(g), np.asarray(out)
                               + np.asarray(new_err), atol=1e-4)


@SET
@given(st.integers(1, 6), st.integers(1, 4), st.integers(16, 64))
def test_chunked_attention_matches_ref_property(nh, group_pow, t):
    from repro.kernels.ref import attention_ref
    from repro.models.layers.attention import chunked_attention
    hkv = nh
    hq = nh * min(group_pow, 2)
    key = jax.random.key(t * 7 + nh)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (1, hq, t, 16))
    k = jax.random.normal(k2, (1, hkv, t, 16))
    v = jax.random.normal(k3, (1, hkv, t, 16))
    got = chunked_attention(q, k, v, causal=True, chunk_k=16)
    want = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-5, rtol=3e-5)
