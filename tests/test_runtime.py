"""Fault tolerance: checkpoint/restart, failure injection, exact-resume,
straggler watchdog, elastic restore."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.optim import AdamW, AdamWConfig
from repro.runtime import SimulatedFailure, TrainLoop, TrainLoopConfig


def _toy_setup():
    """Tiny linear-regression training step with AdamW."""
    opt = AdamW(AdamWConfig(lr=0.05, weight_decay=0.0))
    w_true = np.linspace(-1, 1, 8).astype(np.float32)

    def batch_fn(step):
        rng = np.random.default_rng(step)  # stateless: step -> batch
        x = rng.normal(size=(16, 8)).astype(np.float32)
        y = x @ w_true
        return {"x": jnp.asarray(x), "y": jnp.asarray(y)}

    @jax.jit
    def step_fn(params, opt_state, batch, rng):
        def loss(p):
            pred = batch["x"] @ p["w"]
            return jnp.mean((pred - batch["y"]) ** 2)

        lval, g = jax.value_and_grad(loss)(params)
        params, opt_state = opt.apply(g, opt_state, params)
        return params, opt_state, {"loss": lval,
                                   "step": opt_state["step"]}

    params = {"w": jnp.zeros((8,))}
    return step_fn, batch_fn, params, opt.init(params)


def test_checkpoint_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_save=False)
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    mgr.save(5, tree)
    out, step = mgr.restore(None, tree)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(out["b"]["c"]),
                                  np.asarray(tree["b"]["c"]))


def test_checkpoint_keep_k_rotation(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_save=False)
    tree = {"a": jnp.zeros((2,))}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.all_steps() == [3, 4]


def test_checkpoint_async_is_consistent(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3, async_save=True)
    tree = {"a": jnp.arange(1000.0)}
    mgr.save(1, tree)
    mgr.wait()
    out, _ = mgr.restore(1, tree)
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(tree["a"]))


def test_train_loop_runs_and_logs(tmp_path):
    step_fn, batch_fn, params, opt_state = _toy_setup()
    loop = TrainLoop(step_fn, TrainLoopConfig(total_steps=30,
                                              checkpoint_every=10),
                     str(tmp_path), batch_fn=batch_fn)
    (params, _) = loop.run((params, opt_state))
    assert len(loop.metrics_log) == 30
    assert loop.metrics_log[-1]["loss"] < loop.metrics_log[0]["loss"]


def test_failure_recovery_bit_identical(tmp_path):
    """Crash at step 17 -> restore -> final params identical to an
    uninterrupted run (stateless data pipeline + checkpointed state)."""
    step_fn, batch_fn, params0, opt0 = _toy_setup()

    # uninterrupted reference
    ref_loop = TrainLoop(step_fn, TrainLoopConfig(total_steps=25,
                                                  checkpoint_every=5),
                         str(tmp_path / "ref"), batch_fn=batch_fn)
    ref_params, _ = ref_loop.run((params0, opt0))

    # crashing run
    crashed = {"done": False}

    def failure_hook(step):
        if step == 17 and not crashed["done"]:
            crashed["done"] = True
            raise SimulatedFailure("node lost")

    loop = TrainLoop(step_fn, TrainLoopConfig(total_steps=25,
                                              checkpoint_every=5),
                     str(tmp_path / "crash"), batch_fn=batch_fn,
                     failure_hook=failure_hook)
    params, _ = loop.run((params0, opt0))
    assert loop.restarts == 1
    np.testing.assert_array_equal(np.asarray(params["w"]),
                                  np.asarray(ref_params["w"]))


def test_resume_after_stop(tmp_path):
    """Stopping at 10 and relaunching equals one 20-step run."""
    step_fn, batch_fn, params0, opt0 = _toy_setup()
    l1 = TrainLoop(step_fn, TrainLoopConfig(total_steps=10,
                                            checkpoint_every=3),
                   str(tmp_path / "c"), batch_fn=batch_fn)
    state = l1.run((params0, opt0))
    # checkpoint may lag the last step; relaunch resumes from latest ckpt
    l2 = TrainLoop(step_fn, TrainLoopConfig(total_steps=20,
                                            checkpoint_every=3),
                   str(tmp_path / "c"), batch_fn=batch_fn)
    params, _ = l2.run(state)

    ref = TrainLoop(step_fn, TrainLoopConfig(total_steps=20,
                                             checkpoint_every=3),
                    str(tmp_path / "ref"), batch_fn=batch_fn)
    ref_params, _ = ref.run((params0, opt0))
    np.testing.assert_allclose(np.asarray(params["w"]),
                               np.asarray(ref_params["w"]), rtol=1e-6)


def test_straggler_watchdog_fires(tmp_path):
    step_fn, batch_fn, params, opt_state = _toy_setup()
    slow = {"hit": []}

    def slow_hook(step):
        if step == 20:
            time.sleep(0.5)

    loop = TrainLoop(step_fn, TrainLoopConfig(total_steps=25,
                                              checkpoint_every=100,
                                              straggler_factor=3.0),
                     str(tmp_path), batch_fn=batch_fn,
                     failure_hook=slow_hook,
                     on_straggler=lambda s, dt, ew: slow["hit"].append(s))
    loop.run((params, opt_state))
    assert 20 in slow["hit"]
    assert loop.straggler_events


def test_elastic_restore_changes_sharding(tmp_path):
    """Restore a checkpoint onto a different sharding layout."""
    mgr = CheckpointManager(tmp_path, async_save=False)
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    mgr.save(1, tree)
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    sh = {"w": jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("data", None))}
    out, _ = mgr.restore(None, tree, sh)
    assert out["w"].sharding.is_equivalent_to(sh["w"], 2)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))
