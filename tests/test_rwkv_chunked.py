"""Chunked RWKV6 == sequential recurrence (hillclimb A correctness)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import rwkv6


@pytest.mark.parametrize("b,t,d,chunk", [
    (2, 64, 128, 32),
    (1, 96, 64, 16),
    (3, 32, 128, 32),   # single chunk
])
def test_chunked_matches_sequential(b, t, d, chunk):
    cfg = rwkv6.RWKV6Config(d_model=d, head_size=32)
    params = rwkv6.init(jax.random.key(0), cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.key(1), (b, t, d), jnp.float32)
    ref = rwkv6.forward(params, x, cfg)
    got = rwkv6.forward_chunked(params, x, cfg, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=2e-4, rtol=2e-4)


def test_chunked_state_matches_sequential():
    cfg = rwkv6.RWKV6Config(d_model=64, head_size=32)
    params = rwkv6.init(jax.random.key(2), cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.key(3), (2, 64, 64), jnp.float32)
    _, c_ref = rwkv6.forward(params, x, cfg, return_state=True)
    _, c_chk = rwkv6.forward_chunked(params, x, cfg, chunk=16,
                                     return_state=True)
    np.testing.assert_allclose(np.asarray(c_chk["state"]),
                               np.asarray(c_ref["state"]),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_array_equal(np.asarray(c_chk["shift"]),
                                  np.asarray(c_ref["shift"]))


def test_chunked_then_decode_consistent():
    """Prefill with the chunked form, continue decoding with the
    sequential step — outputs must line up with a full sequential run."""
    cfg = rwkv6.RWKV6Config(d_model=64, head_size=32)
    params = rwkv6.init(jax.random.key(4), cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.key(5), (1, 33, 64), jnp.float32)
    # reference: sequential over all 33 tokens
    ref = rwkv6.forward(params, x, cfg)
    # chunked prefill over 32, then one decode step
    _, cache = rwkv6.forward_chunked(params, x[:, :32], cfg, chunk=16,
                                     return_state=True)
    y, _ = rwkv6.decode_step(params, x[:, 32:33], cache, cfg)
    np.testing.assert_allclose(np.asarray(y[:, 0]),
                               np.asarray(ref[:, 32]),
                               atol=2e-4, rtol=2e-4)
