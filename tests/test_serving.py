"""Serving engine: continuous batching, slot reuse, decode parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models.transformer import Model
from repro.serving import Request, ServingEngine


def _engine(arch="starcoder2-3b", n_slots=3, max_len=64):
    cfg = reduced(get_config(arch))
    model = Model(cfg, dtype=jnp.float32, attn_chunk=16)
    params = model.init_params(jax.random.key(0))
    eng = ServingEngine(model, params, n_slots=n_slots, max_len=max_len)
    return cfg, model, params, eng


def test_engine_serves_batch_of_requests():
    cfg, model, params, eng = _engine()
    reqs = [Request(rid=i, prompt=[1 + i, 2, 3], max_new_tokens=5)
            for i in range(5)]  # more requests than slots
    done = eng.run(reqs, max_steps=200)
    assert all(r.done for r in done)
    for r in done:
        assert len(r.output) == 5
        assert all(0 <= t < cfg.vocab_padded for t in r.output)


def test_engine_matches_sequential_greedy():
    """Continuous-batched greedy decode == one-at-a-time greedy decode."""
    cfg, model, params, eng = _engine(n_slots=2)
    prompts = [[5, 6, 7], [9, 8, 7, 6]]
    reqs = [Request(rid=i, prompt=p, max_new_tokens=4)
            for i, p in enumerate(prompts)]
    eng.run(reqs, max_steps=100)

    # sequential reference: prefill + per-token decode, B=1
    for req, prompt in zip(reqs, prompts):
        logits, cache, clen = model.prefill(
            params, {"tokens": jnp.asarray([prompt], jnp.int32)},
            max_len=64)
        out = [int(jnp.argmax(logits[0]))]
        for _ in range(3):
            tok = jnp.asarray([[out[-1]]], jnp.int32)
            logits, cache = model.decode_step(params, tok, cache, clen)
            clen = clen + 1
            out.append(int(jnp.argmax(logits[0])))
        assert req.output == out, (req.output, out)


def test_engine_slot_reuse():
    cfg, model, params, eng = _engine(n_slots=1)
    reqs = [Request(rid=i, prompt=[i + 1, i + 2], max_new_tokens=3)
            for i in range(3)]
    eng.run(reqs, max_steps=200)
    assert all(r.done for r in reqs)


def test_engine_eos_stops_early():
    cfg, model, params, eng = _engine()
    # find the greedy first token, then use it as "eos"
    logits, _, _ = model.prefill(
        params, {"tokens": jnp.asarray([[1, 2, 3]], jnp.int32)},
        max_len=64)
    eos = int(jnp.argmax(logits[0]))
    req = Request(rid=0, prompt=[1, 2, 3], max_new_tokens=50, eos_id=eos)
    eng.run([req], max_steps=100)
    assert req.done and len(req.output) == 1  # stopped on first token


@pytest.mark.parametrize("arch", ["rwkv6-7b", "jamba-1.5-large-398b"])
def test_engine_recurrent_archs(arch):
    cfg, model, params, eng = _engine(arch=arch, n_slots=2, max_len=32)
    reqs = [Request(rid=i, prompt=[4, 5, 6], max_new_tokens=4)
            for i in range(2)]
    eng.run(reqs, max_steps=100)
    assert all(r.done and len(r.output) == 4 for r in reqs)
