"""Fault-injection suite for the SNN serving robustness layer.

Covers the request lifecycle (structured rejection, backpressure,
deadlines, priorities), the bounded-retry + graceful-degradation
ladder, the output integrity guard + canary, and the seeded
FaultInjector storm acceptance criterion: every request terminates in
a terminal status, no exception escapes step()/run(), and every SERVED
count vector stays bit-exact with the host oracle.
"""

import dataclasses
import os
import subprocess
import sys
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.encoder import encode_from_counter
from repro.engine import SNNEnginePlan
from repro.kernels import ops
from repro.serving import (FaultInjectedError, FaultInjector, FaultSpec,
                           SNNRequest, SNNServingEngine, SNNServingPolicy,
                           degradation_ladder)

REPO = Path(__file__).resolve().parents[1]

N, W = 20, 4
PLAN = SNNEnginePlan(threshold=40, leak=3, w_exp=None, max_batch=3)
KPLAN = dataclasses.replace(PLAN, encode="kernel")


def _weights(seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, 2**32, (N, W), dtype=np.uint32))


def _request(rid, t_steps, seed=None, **kw):
    rng = np.random.default_rng(100 + rid if seed is None else seed)
    return SNNRequest(rid=rid, window=rng.integers(
        0, 2**32, (t_steps, W), dtype=np.uint32), **kw)


def _intensity_request(rid, t_steps, n_in=70, **kw):
    rng = np.random.default_rng(300 + rid)
    return SNNRequest(rid=rid, intensities=rng.integers(
        0, 256, (n_in,), dtype=np.uint8), n_steps=t_steps, **kw)


def _oracle(weights, r, plan):
    """Host-oracle counts for one request at its true window length."""
    if r.window is not None:
        win = np.asarray(r.window)
    else:
        win = np.asarray(encode_from_counter(
            r.seed, jnp.asarray(r.intensities), r.n_steps))
        win = np.pad(win, ((0, 0), (0, W - win.shape[1])))
    return np.asarray(ops.infer_window_batch(
        weights, jnp.asarray(win)[None], threshold=plan.threshold,
        leak=plan.leak, backend="ref"))[0]


class FailFirstN:
    """Deterministic hook: the first ``n`` hooked launches raise."""

    def __init__(self, n):
        self.n = n
        self.calls = 0

    def __call__(self, ctx):
        self.calls += 1
        if self.calls <= self.n:
            raise FaultInjectedError(f"boom #{self.calls}")
        return None


# --- degradation ladder -----------------------------------------------------

def test_degradation_ladder_rungs():
    # host + ref already: nothing to degrade to
    assert degradation_ladder(PLAN) == [PLAN]
    # kernel encode + ref backend: one host-encode rung below
    lad = degradation_ladder(KPLAN)
    assert [p.encode for p in lad] == ["kernel", "host"]
    # kernel encode + interp backend: full 3-rung ladder
    lad = degradation_ladder(
        dataclasses.replace(KPLAN, kernel_backend="interp"))
    assert [(p.encode, p.kernel_backend) for p in lad] == [
        ("kernel", "interp"), ("host", "interp"), ("host", "ref")]


# --- fault injector ---------------------------------------------------------

def test_fault_spec_validates():
    with pytest.raises(ValueError):
        FaultSpec(p_launch_error=1.5)
    with pytest.raises(ValueError):
        FaultSpec(error_burst=0)
    with pytest.raises(ValueError):
        FaultSpec(stall_ms=-1)


def test_fault_injector_is_deterministic():
    spec = FaultSpec(p_launch_error=0.3, p_corrupt=0.4, seed=5)
    ctx = {"step": 0, "level": 0, "kind": "serve", "batch_size": 3,
           "t_lens": [8, 8, 8]}

    def drive(inj, n=40):
        out = []
        for _ in range(n):
            try:
                out.append("corrupt" if inj(ctx) else "ok")
            except FaultInjectedError:
                out.append("error")
        return out

    a, b = drive(FaultInjector(spec)), drive(FaultInjector(spec))
    assert a == b
    assert "error" in a and "corrupt" in a     # storm actually storms


# --- retry / degradation ----------------------------------------------------

def test_launch_failure_retries_then_serves_bit_exact():
    weights = _weights(1)
    hook = FailFirstN(1)
    eng = SNNServingEngine(weights, PLAN,
                           policy=SNNServingPolicy(max_retries=2),
                           on_launch=hook)
    reqs = [_request(0, 10), _request(1, 7)]
    eng.run(reqs)
    assert [r.status for r in reqs] == ["SERVED", "SERVED"]
    assert eng.retried == 1 and eng.level == 0
    assert all(r.retries == 1 for r in reqs)
    for r in reqs:
        np.testing.assert_array_equal(r.counts, _oracle(weights, r, PLAN))


def test_retry_exhaustion_degrades_kernel_encode_to_host():
    weights = _weights(2)
    hook = FailFirstN(3)                 # rung 0's whole budget fails
    eng = SNNServingEngine(weights, KPLAN,
                           policy=SNNServingPolicy(max_retries=2),
                           on_launch=hook)
    reqs = [_intensity_request(i, 9) for i in range(3)]
    eng.run(reqs)
    assert all(r.status == "SERVED" for r in reqs)
    assert eng.level == 1 and eng.degraded == 1 and eng.retried == 2
    ev = eng.degradation_events[0]
    assert ev["encode"] == "host" and "launch failed" in ev["reason"]
    for r in reqs:                       # degraded path is bit-exact
        np.testing.assert_array_equal(r.counts,
                                      _oracle(weights, r, KPLAN))
    assert eng.stats()["degraded"] == 1


def test_failure_on_last_rung_marks_batch_failed_without_raising():
    weights = _weights(3)
    hook = FailFirstN(10**9)             # every launch dies
    eng = SNNServingEngine(weights, PLAN,   # 1-rung ladder
                           policy=SNNServingPolicy(max_retries=1),
                           on_launch=hook)
    reqs = [_request(0, 8), _request(1, 8)]
    eng.run(reqs)                        # must not raise
    assert [r.status for r in reqs] == ["FAILED", "FAILED"]
    assert all("boom" in r.error for r in reqs)
    assert all(r.counts is None for r in reqs)
    assert eng.stats()["failed"] == 2


# --- integrity guard / canary ----------------------------------------------

def test_corrupted_counts_repaired_by_oracle_fallback():
    weights = _weights(4)

    class CorruptFirst:
        calls = 0

        def __call__(self, ctx):
            self.calls += 1
            if self.calls == 1:
                return lambda c: np.where(
                    np.arange(len(c))[:, None] == 0, 10_000, np.array(c))
            return None

    eng = SNNServingEngine(weights, KPLAN, on_launch=CorruptFirst())
    reqs = [_intensity_request(i, 9) for i in range(3)]
    eng.run(reqs)
    assert all(r.status == "SERVED" for r in reqs)
    assert eng.integrity_failures == 1
    assert eng.level == 1                # corruption degrades the rung
    for r in reqs:
        np.testing.assert_array_equal(r.counts,
                                      _oracle(weights, r, KPLAN))


def test_canary_catches_in_range_corruption_and_degrades():
    weights = _weights(5)

    def hook(ctx):
        if ctx["kind"] == "canary":
            return lambda c: np.zeros_like(np.array(c))   # in-range, wrong
        return None

    pol = SNNServingPolicy(canary_every=1)
    eng = SNNServingEngine(weights, KPLAN, policy=pol, on_launch=hook)
    # the canary must be a non-trivial known answer for this check to
    # mean anything
    reqs = [_request(0, 8)]
    eng.run(reqs)
    assert reqs[0].status == "SERVED"
    assert (eng._canary_golden > 0).any()
    assert eng.canary_checks == 1 and eng.canary_failures == 1
    assert eng.level == 1                # in-range corruption caught
    assert eng.stats()["canary_failures"] == 1


def test_reprobe_returns_to_fast_path_after_healthy_steps():
    weights = _weights(6)
    hook = FailFirstN(1)
    pol = SNNServingPolicy(max_retries=0, reprobe_after=1)
    eng = SNNServingEngine(weights, KPLAN, policy=pol, on_launch=hook)
    eng.run([_intensity_request(0, 9)])
    assert eng.level == 1                # degraded on the first step
    eng.run([_intensity_request(1, 9)])  # healthy step at rung 1
    assert eng.level == 0                # re-probed the fast path
    assert eng.degradation_events[-1]["reason"].startswith("re-probe")


# --- admission: deadlines, backpressure, priorities -------------------------

def test_expired_deadline_drops_request_as_expired():
    eng = SNNServingEngine(_weights(7), PLAN)
    late = _request(0, 8, deadline_ms=0.0)
    fresh = _request(1, 8)
    eng.run([late, fresh])
    assert late.status == "EXPIRED" and "deadline" in late.error
    assert late.counts is None
    assert fresh.status == "SERVED"
    assert eng.stats()["expired"] == 1


def test_policy_default_deadline_applies_to_requests_without_one():
    pol = SNNServingPolicy(deadline_ms=0.0)
    eng = SNNServingEngine(_weights(8), PLAN, policy=pol)
    req = _request(0, 8)
    eng.run([req])
    assert req.status == "EXPIRED"


def test_backpressure_rejects_beyond_max_queue():
    pol = SNNServingPolicy(max_queue=2)
    eng = SNNServingEngine(_weights(9), PLAN, policy=pol)
    reqs = [_request(i, 8) for i in range(5)]
    admitted = [eng.submit(r) for r in reqs]
    assert admitted == [True, True, False, False, False]
    assert all(r.status == "REJECTED" and "backpressure" in r.error
               for r in reqs[2:])
    assert eng.stats()["rejected"] == 3
    eng.run(reqs)                        # queued two still complete
    assert [r.status for r in reqs[:2]] == ["SERVED", "SERVED"]


def test_priority_pulls_high_priority_requests_first():
    plan = dataclasses.replace(PLAN, max_batch=2)
    eng = SNNServingEngine(_weights(10), plan)
    r0, r1 = _request(0, 8), _request(1, 8)
    hi = _request(2, 8, priority=5)
    for r in (r0, r1, hi):
        eng.submit(r)
    eng.step()
    # first batch: the priority-5 request plus the oldest prio-0 one
    assert hi.status == "SERVED" and r0.status == "SERVED"
    assert r1.status == "QUEUED"
    eng.step()
    assert r1.status == "SERVED"


def test_latency_percentiles_recorded():
    eng = SNNServingEngine(_weights(11), PLAN)
    eng.run([_request(i, 8) for i in range(7)])
    st = eng.stats()
    assert st["service_ms_p99"] >= st["service_ms_p50"] > 0
    assert st["queue_wait_ms_p99"] >= st["queue_wait_ms_p50"] >= 0
    assert eng.service_hist.count == 7


# --- the storm acceptance criterion -----------------------------------------

def test_fault_storm_terminal_statuses_and_bit_exact_serves():
    """Seeded FaultInjector storm (launch failures + corrupted counts +
    expired deadlines): every request terminal, nothing raises, every
    SERVED vector bit-exact with the oracle, recovery counters nonzero."""
    weights = _weights(40)
    plan = dataclasses.replace(KPLAN, max_batch=4)
    pol = SNNServingPolicy(max_retries=1, canary_every=3,
                           reprobe_after=2)
    inj = FaultInjector(FaultSpec(p_launch_error=0.35, p_corrupt=0.5,
                                  error_burst=3, seed=11))
    eng = SNNServingEngine(weights, plan, policy=pol, on_launch=inj)
    reqs = []
    for i in range(24):
        if i % 6 == 5:                   # already-dead deadline
            reqs.append(_intensity_request(i, 9, deadline_ms=0.0))
        elif i % 2:
            reqs.append(_intensity_request(i, 9 - (i % 3)))
        else:
            reqs.append(_request(i, 10 - (i % 4), priority=i % 3))
    eng.run(reqs)

    assert all(r.terminal for r in reqs)
    assert sum(r.status == "EXPIRED" for r in reqs) == 4
    for r in reqs:
        if r.status == "SERVED":
            np.testing.assert_array_equal(r.counts,
                                          _oracle(weights, r, plan))
    st = eng.stats()
    assert st["retried"] > 0
    assert st["degraded"] > 0
    assert st["expired"] == 4
    assert st["integrity_failures"] > 0
    assert st["service_ms_p99"] >= st["service_ms_p50"] > 0
    assert inj.errors > 0 and inj.corruptions > 0


def test_launch_serve_snn_cli_fault_storm_smoke():
    """CI acceptance: serve --inject-faults terminates every request in
    a terminal status and degraded results stay bit-exact with the
    oracle (the CLI exits nonzero otherwise)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = (str(REPO / "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch",
         "wenquxing-snn", "--requests", "12", "--bench",
         "--inject-faults", "--fault-seed", "7"],
        env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "non-terminal=0" in proc.stdout
    assert "oracle-check: ok" in proc.stdout
    assert "EXPIRED=2" in proc.stdout    # rids 4 and 9 carry deadline 0
    bench = dict(kv.split("=") for kv in
                 proc.stdout.split("serve-bench: ")[1].split())
    assert int(bench["retried"]) > 0
    assert int(bench["degraded"]) > 0
    assert int(bench["expired"]) == 2
    assert float(bench["service_ms_p99"]) > 0
