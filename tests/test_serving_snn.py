"""SNNServingEngine unit tests: admission, ragged batch padding,
request completion counts, and the launch CLI integration."""

import os
import subprocess
import sys
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import snn_mesh
from repro.engine import SNNEnginePlan
from repro.kernels import ops
from repro.serving import SNNRequest, SNNServingEngine

REPO = Path(__file__).resolve().parents[1]

N, W = 20, 4
PLAN = SNNEnginePlan(threshold=40, leak=3, w_exp=None, max_batch=3)


def _weights(seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, 2**32, (N, W), dtype=np.uint32))


def _request(rid, t_steps, seed=None):
    rng = np.random.default_rng(100 + rid if seed is None else seed)
    return SNNRequest(rid=rid, window=rng.integers(
        0, 2**32, (t_steps, W), dtype=np.uint32))


def test_admission_respects_max_batch():
    eng = SNNServingEngine(_weights(), PLAN)
    reqs = [_request(i, 10) for i in range(7)]
    out = eng.run(reqs)
    assert all(r.done for r in out)
    assert eng.windows_served == 7
    assert eng.batches == 3          # 3 + 3 + 1 at max_batch=3
    assert all(r.counts is not None and r.counts.shape == (N,)
               for r in out)


def test_ragged_batch_bit_exact_with_individual_serving():
    """One ragged batch (T = 5/9/12, padded to one launch) returns the
    same counts as serving each window alone at its true length."""
    weights = _weights(1)
    eng = SNNServingEngine(weights, PLAN)
    reqs = [_request(0, 5), _request(1, 9), _request(2, 12)]
    eng.run(reqs)
    assert eng.batches == 1
    for r in reqs:
        want = ops.infer_window_batch(
            weights, jnp.asarray(r.window)[None],
            threshold=PLAN.threshold, leak=PLAN.leak)[0]
        np.testing.assert_array_equal(r.counts, np.asarray(want))


def test_batch_padding_rows_do_not_leak_into_results():
    """A lone request (batch padded up to max_batch with zero windows)
    matches a full-batch serve of the same window."""
    weights = _weights(2)
    alone = _request(0, 8, seed=200)
    full = [_request(i, 8, seed=200) for i in range(3)]
    e1 = SNNServingEngine(weights, PLAN)
    e1.run([alone])
    e2 = SNNServingEngine(weights, PLAN)
    e2.run(full)
    np.testing.assert_array_equal(alone.counts, full[0].counts)


def test_pred_uses_neuron_class():
    weights = _weights(3)
    classes = np.arange(N) % 10
    eng = SNNServingEngine(weights, PLAN, neuron_class=classes)
    req = _request(0, 10)
    eng.run([req])
    assert req.pred == int(classes[int(np.argmax(req.counts))])


def test_sharded_serving_matches_unsharded():
    """Plan placement composes with request batching: a mesh-carrying
    plan serves identical counts."""
    weights = _weights(4)
    import dataclasses
    plan_m = dataclasses.replace(PLAN, mesh=snn_mesh.snn_mesh())
    reqs_a = [_request(i, 10) for i in range(4)]
    reqs_b = [_request(i, 10) for i in range(4)]
    SNNServingEngine(weights, PLAN).run(reqs_a)
    SNNServingEngine(weights, plan_m).run(reqs_b)
    for a, b in zip(reqs_a, reqs_b):
        np.testing.assert_array_equal(a.counts, b.counts)


def test_submit_rejects_bad_window_shape_structurally():
    """Invalid requests no longer raise out of submit(): they end
    REJECTED with the reason recorded."""
    eng = SNNServingEngine(_weights(), PLAN)
    req = SNNRequest(rid=0, window=np.zeros((10, W + 1), np.uint32))
    assert eng.submit(req) is False
    assert req.status == "REJECTED" and req.done
    assert "window" in req.error
    assert eng.stats()["rejected"] == 1


def test_serving_requires_positive_threshold():
    with pytest.raises(ValueError):
        SNNServingEngine(_weights(),
                         SNNEnginePlan(threshold=0, w_exp=None))


def _intensity_request(rid, t_steps, n_in=70, seed=None):
    rng = np.random.default_rng(300 + rid)
    return SNNRequest(rid=rid, intensities=rng.integers(
        0, 256, (n_in,), dtype=np.uint8), n_steps=t_steps, seed=seed)


@pytest.mark.parametrize("encode", ["host", "kernel"])
def test_intensity_requests_match_prepacked_oracle_windows(encode):
    """An intensity request returns exactly the counts of a pre-packed
    request carrying its encode_from_counter window — for both encode
    placements (the in-kernel draw is bit-exact with the host oracle)."""
    import dataclasses

    from repro.core.encoder import encode_from_counter

    weights = _weights(5)
    plan = dataclasses.replace(PLAN, encode=encode)
    reqs_i = [_intensity_request(i, 10 - 3 * (i % 3)) for i in range(5)]
    reqs_w = []
    for r in reqs_i:
        win = np.asarray(encode_from_counter(
            plan.encode_seed + r.rid, jnp.asarray(r.intensities),
            r.n_steps))
        win = np.pad(win, ((0, 0), (0, W - win.shape[1])))
        reqs_w.append(SNNRequest(rid=r.rid, window=win))
    SNNServingEngine(weights, plan).run(reqs_i)
    SNNServingEngine(weights, PLAN).run(reqs_w)
    for a, b in zip(reqs_i, reqs_w):
        np.testing.assert_array_equal(a.counts, b.counts)


def test_mixed_batch_serves_both_request_kinds():
    """Pre-packed and intensity requests in ONE batch agree with
    serving each kind alone (mixed batches host-encode, bit-exactly)."""
    import dataclasses

    weights = _weights(6)
    plan = dataclasses.replace(PLAN, encode="kernel")
    mixed = [_request(0, 9), _intensity_request(1, 9), _request(2, 9)]
    alone = [_request(0, 9), _intensity_request(1, 9), _request(2, 9)]
    eng = SNNServingEngine(weights, plan)
    eng.run(mixed)
    assert eng.batches == 1
    for r in alone:
        SNNServingEngine(weights, plan).run([r])
    for a, b in zip(mixed, alone):
        np.testing.assert_array_equal(a.counts, b.counts)


def test_sharded_intensity_serving_matches_unsharded():
    import dataclasses

    weights = _weights(7)
    plan_k = dataclasses.replace(PLAN, encode="kernel")
    plan_m = dataclasses.replace(plan_k, mesh=snn_mesh.snn_mesh())
    reqs_a = [_intensity_request(i, 10) for i in range(4)]
    reqs_b = [_intensity_request(i, 10) for i in range(4)]
    SNNServingEngine(weights, plan_k).run(reqs_a)
    SNNServingEngine(weights, plan_m).run(reqs_b)
    for a, b in zip(reqs_a, reqs_b):
        np.testing.assert_array_equal(a.counts, b.counts)


def test_submit_rejects_invalid_intensity_requests_structurally():
    eng = SNNServingEngine(_weights(), PLAN)
    bad = [
        SNNRequest(rid=0, window=np.zeros((4, W), np.uint32),
                   intensities=np.zeros(8, np.uint8),
                   n_steps=4),                                # both forms
        SNNRequest(rid=1),                                    # neither
        SNNRequest(rid=2, intensities=np.zeros(8, np.uint8)),  # no n_steps
        SNNRequest(rid=3, n_steps=4,
                   intensities=np.zeros(W * 32 + 1, np.uint8)),  # too big
    ]
    for req in bad:
        assert eng.submit(req) is False
        assert req.status == "REJECTED" and req.error
    assert eng.stats()["rejected"] == len(bad)
    assert not eng.queue


def test_one_bad_request_cannot_strand_the_rest():
    """run() pushes every request through the structured-rejection
    path: the invalid one ends REJECTED, the rest are SERVED."""
    eng = SNNServingEngine(_weights(), PLAN)
    good1, bad, good2 = _request(0, 10), SNNRequest(rid=1), _request(2, 8)
    eng.run([good1, bad, good2])
    assert good1.status == "SERVED" and good2.status == "SERVED"
    assert bad.status == "REJECTED" and bad.counts is None
    assert good1.counts is not None and good2.counts is not None


def test_neuron_class_length_validated_at_init():
    with pytest.raises(ValueError):
        SNNServingEngine(_weights(), PLAN,
                         neuron_class=np.arange(N - 1))  # too short
    with pytest.raises(ValueError):
        SNNServingEngine(_weights(), PLAN,
                         neuron_class=np.zeros((N, 2), np.int32))  # 2-D


def test_serving_stats_track_waste_and_step_time():
    eng = SNNServingEngine(_weights(8), PLAN)
    eng.run([_request(i, 10) for i in range(4)])   # batches of 3 + 1
    stats = eng.stats()
    assert stats["batches"] == 2
    assert stats["windows_served"] == 4
    # second batch padded 2 of 3 slots -> 2/6 waste
    assert stats["padded_slot_waste"] == pytest.approx(2 / 6)
    assert stats["mean_step_ms"] > 0
    assert stats["last_step_ms"] >= 0
    assert eng.padded_slot_waste == pytest.approx(2 / 6)


@pytest.mark.parametrize("encode", ["host", "kernel"])
def test_one_jit_trace_per_window_length_bucket(encode):
    """Ragged batches retrace ONLY per window-length bucket (the jax
    trace counter of the dispatched op), for both admission kinds."""
    import dataclasses

    weights = _weights(9)
    plan = dataclasses.replace(PLAN, encode=encode)

    def deltas(serve):
        pp0 = ops.infer_window_batch._cache_size()
        enc0 = ops.infer_window_batch_encode._cache_size()
        serve()
        return (ops.infer_window_batch._cache_size() - pp0,
                ops.infer_window_batch_encode._cache_size() - enc0)

    # pre-packed admission: T in {5..9} buckets to 8, {11, 12} to 16 —
    # at most one trace per bucket, then ZERO retraces for new ragged
    # lengths inside already-seen buckets
    eng = SNNServingEngine(weights, plan)
    pp, enc = deltas(lambda: [eng.run([_request(100 + t, t)])
                              for t in (5, 7, 12)])
    assert pp <= 2 and enc == 0
    pp, enc = deltas(lambda: [eng.run([_request(120 + t, t)])
                              for t in (6, 8, 3, 11)])
    assert (pp, enc) == (0, 0)

    # intensity admission: kernel encode dispatches the encode op (the
    # ragged t_total is a traced SMEM operand, so raggedness inside a
    # bucket never retraces); host encode feeds the pre-packed op whose
    # buckets are warm from above
    eng2 = SNNServingEngine(weights, plan)
    pp, enc = deltas(lambda: [eng2.run([_intensity_request(200 + t, t)])
                              for t in (5, 7, 12)])
    if encode == "kernel":
        assert pp == 0 and enc <= 2
    else:
        assert (pp, enc) == (0, 0)
    pp, enc = deltas(lambda: [eng2.run([_intensity_request(220 + t, t)])
                              for t in (6, 8, 3, 11)])
    assert (pp, enc) == (0, 0)


@pytest.mark.parametrize("threshold", [1, 2])
def test_ragged_padding_silent_at_threshold_boundary(threshold):
    """The zero-pad silence invariant at its tightest boundary
    (threshold == 1): after any true cycle v < threshold, and a zero
    row only leaks, so padded cycles never fire.  Ragged batch counts
    must equal each window served alone at its true length."""
    import dataclasses

    plan = dataclasses.replace(PLAN, threshold=threshold, leak=1)
    weights = _weights(20 + threshold)
    reqs = [_request(0, 5), _request(1, 9), _request(2, 12)]
    eng = SNNServingEngine(weights, plan)
    eng.run(reqs)
    assert eng.batches == 1               # one padded launch
    for r in reqs:
        want = ops.infer_window_batch(
            weights, jnp.asarray(r.window)[None],
            threshold=threshold, leak=1)[0]
        np.testing.assert_array_equal(r.counts, np.asarray(want))


def test_threshold_one_intensity_t_total_mask_bit_exact():
    """Same boundary for the intensity form, where raggedness is the
    kernels' t_total SMEM mask rather than host zero-padding."""
    import dataclasses

    plan = dataclasses.replace(PLAN, threshold=1, leak=1,
                               encode="kernel")
    weights = _weights(23)
    ragged = [_intensity_request(i, 10 - 3 * (i % 3)) for i in range(3)]
    alone = [_intensity_request(i, 10 - 3 * (i % 3)) for i in range(3)]
    eng = SNNServingEngine(weights, plan)
    eng.run(ragged)
    assert eng.batches == 1
    for r in alone:
        SNNServingEngine(weights, plan).run([r])
    for a, b in zip(ragged, alone):
        np.testing.assert_array_equal(a.counts, b.counts)


def test_t_quantum_buckets_share_one_trace():
    """_t_quantum buckets ragged T's to t_chunk multiples (default 8):
    all lengths inside one bucket pad to the same launch shape, so they
    share a single compiled trace."""
    import dataclasses

    plan = dataclasses.replace(PLAN, t_chunk=6)
    eng = SNNServingEngine(_weights(24), plan)
    assert eng._t_quantum() == 6

    pp0 = ops.infer_window_batch._cache_size()
    for t in (4, 5, 6):                   # all pad to T=6: one bucket
        eng.run([_request(300 + t, t)])
    assert ops.infer_window_batch._cache_size() - pp0 == 1
    for t in (7, 11, 12):                 # all pad to T=12: one more
        eng.run([_request(320 + t, t)])
    assert ops.infer_window_batch._cache_size() - pp0 == 2

    # default quantum (no t_chunk) buckets to multiples of 8
    eng8 = SNNServingEngine(_weights(24), PLAN)
    assert eng8._t_quantum() == 8


def test_launch_serve_snn_cli_completes_requests():
    """Acceptance: repro.launch.serve --arch wenquxing-snn --requests 6
    completes every request through SNNServingEngine."""
    env = dict(os.environ)
    env["PYTHONPATH"] = (str(REPO / "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch",
         "wenquxing-snn", "--requests", "6", "--bench"],
        env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "wenquxing-snn: 6/6 done" in proc.stdout
    assert "SERVED=6" in proc.stdout
    assert "non-terminal=0" in proc.stdout
    assert "oracle-check: ok" in proc.stdout
    assert "serve-bench:" in proc.stdout
    assert "padded_slot_waste=" in proc.stdout
    assert "mean_step_ms=" in proc.stdout
    assert "service_ms_p99=" in proc.stdout
