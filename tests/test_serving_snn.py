"""SNNServingEngine unit tests: admission, ragged batch padding,
request completion counts, and the launch CLI integration."""

import os
import subprocess
import sys
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import snn_mesh
from repro.engine import SNNEnginePlan
from repro.kernels import ops
from repro.serving import SNNRequest, SNNServingEngine

REPO = Path(__file__).resolve().parents[1]

N, W = 20, 4
PLAN = SNNEnginePlan(threshold=40, leak=3, w_exp=None, max_batch=3)


def _weights(seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, 2**32, (N, W), dtype=np.uint32))


def _request(rid, t_steps, seed=None):
    rng = np.random.default_rng(100 + rid if seed is None else seed)
    return SNNRequest(rid=rid, window=rng.integers(
        0, 2**32, (t_steps, W), dtype=np.uint32))


def test_admission_respects_max_batch():
    eng = SNNServingEngine(_weights(), PLAN)
    reqs = [_request(i, 10) for i in range(7)]
    out = eng.run(reqs)
    assert all(r.done for r in out)
    assert eng.windows_served == 7
    assert eng.batches == 3          # 3 + 3 + 1 at max_batch=3
    assert all(r.counts is not None and r.counts.shape == (N,)
               for r in out)


def test_ragged_batch_bit_exact_with_individual_serving():
    """One ragged batch (T = 5/9/12, padded to one launch) returns the
    same counts as serving each window alone at its true length."""
    weights = _weights(1)
    eng = SNNServingEngine(weights, PLAN)
    reqs = [_request(0, 5), _request(1, 9), _request(2, 12)]
    eng.run(reqs)
    assert eng.batches == 1
    for r in reqs:
        want = ops.infer_window_batch(
            weights, jnp.asarray(r.window)[None],
            threshold=PLAN.threshold, leak=PLAN.leak)[0]
        np.testing.assert_array_equal(r.counts, np.asarray(want))


def test_batch_padding_rows_do_not_leak_into_results():
    """A lone request (batch padded up to max_batch with zero windows)
    matches a full-batch serve of the same window."""
    weights = _weights(2)
    alone = _request(0, 8, seed=200)
    full = [_request(i, 8, seed=200) for i in range(3)]
    e1 = SNNServingEngine(weights, PLAN)
    e1.run([alone])
    e2 = SNNServingEngine(weights, PLAN)
    e2.run(full)
    np.testing.assert_array_equal(alone.counts, full[0].counts)


def test_pred_uses_neuron_class():
    weights = _weights(3)
    classes = np.arange(N) % 10
    eng = SNNServingEngine(weights, PLAN, neuron_class=classes)
    req = _request(0, 10)
    eng.run([req])
    assert req.pred == int(classes[int(np.argmax(req.counts))])


def test_sharded_serving_matches_unsharded():
    """Plan placement composes with request batching: a mesh-carrying
    plan serves identical counts."""
    weights = _weights(4)
    import dataclasses
    plan_m = dataclasses.replace(PLAN, mesh=snn_mesh.snn_mesh())
    reqs_a = [_request(i, 10) for i in range(4)]
    reqs_b = [_request(i, 10) for i in range(4)]
    SNNServingEngine(weights, PLAN).run(reqs_a)
    SNNServingEngine(weights, plan_m).run(reqs_b)
    for a, b in zip(reqs_a, reqs_b):
        np.testing.assert_array_equal(a.counts, b.counts)


def test_submit_validates_window_shape():
    eng = SNNServingEngine(_weights(), PLAN)
    with pytest.raises(ValueError):
        eng.submit(SNNRequest(rid=0, window=np.zeros((10, W + 1),
                                                     np.uint32)))


def test_serving_requires_positive_threshold():
    with pytest.raises(ValueError):
        SNNServingEngine(_weights(),
                         SNNEnginePlan(threshold=0, w_exp=None))


def test_launch_serve_snn_cli_completes_requests():
    """Acceptance: repro.launch.serve --arch wenquxing-snn --requests 6
    completes every request through SNNServingEngine."""
    env = dict(os.environ)
    env["PYTHONPATH"] = (str(REPO / "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch",
         "wenquxing-snn", "--requests", "6"],
        env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "wenquxing-snn: 6/6 done" in proc.stdout
