"""Versioned train-while-serving suite.

Covers the VersionedWeightStore lifecycle (stage / promote / swap /
rollback / restore), the probe-gated refresh path (corrupt candidates
caught at the fingerprint gate, regressions at the accuracy gate,
stalls at the timeout), crash-during-save recovery, and the refresh
storm acceptance criteria: every request terminal, every served
response attributable to a version promoted and live at serve time,
rollback/restart bit-exact with the last promoted checkpoint, and a
measurable probe-accuracy gain over frozen-weight serving.
"""

import dataclasses
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.encoder import encode_from_counter
from repro.engine import SNNEnginePlan
from repro.kernels import ops
from repro.serving import (FaultInjector, FaultSpec, SNNRefreshPolicy,
                           SNNRequest, SNNServingEngine, SNNServingPolicy,
                           SNNWeightRefresher, VersionedWeightStore,
                           weight_fingerprint)

N_CLASSES, BLOCKS, N_IN, W = 4, 2, 64, 2
N = N_CLASSES * BLOCKS
PLAN = SNNEnginePlan(threshold=24, leak=2, w_exp=128, n_syn=N_IN,
                     encode="kernel", cycle_backend="window",
                     max_batch=4, t_chunk=8)
T = 16
NEURON_CLASS = np.tile(np.arange(N_CLASSES), BLOCKS)


def _weights(seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, 2**32, (N, W), dtype=np.uint32))


def _quadrant_data(n):
    """Linearly separable toy stream: class c lights up quadrant c."""
    labels = np.arange(n) % N_CLASSES
    inten = np.zeros((n, N_IN), np.uint8)
    for i, c in enumerate(labels):
        inten[i, c * 16:(c + 1) * 16] = 200
    return inten, labels


def _refresher(policy=None, **kw):
    inten, labels = _quadrant_data(64)
    return SNNWeightRefresher(
        PLAN, inten, labels, n_classes=N_CLASSES,
        probe_intensities=inten[:16], probe_labels=labels[:16],
        neuron_class=NEURON_CLASS, n_steps=T,
        policy=policy or SNNRefreshPolicy(refresh_every=2, probe_size=16,
                                          refresh_samples=16), **kw)


def _requests(n):
    inten, _ = _quadrant_data(64)
    return [SNNRequest(rid=i, intensities=inten[i % 64], n_steps=T)
            for i in range(n)]


def _oracle(weights, r):
    win = np.asarray(encode_from_counter(
        r.seed, jnp.asarray(r.intensities), r.n_steps))
    win = np.pad(win, ((0, 0), (0, W - win.shape[1])))
    return np.asarray(ops.infer_window_batch(
        weights, jnp.asarray(win)[None], threshold=PLAN.threshold,
        leak=PLAN.leak, backend="ref"))[0]


# --- store lifecycle --------------------------------------------------------


def test_store_seed_is_version_zero_and_live():
    st = VersionedWeightStore(_weights())
    assert st.serving.version == 0
    assert st.serving.origin == "seed"
    assert st.is_live(0)
    assert st.serving.verify()


def test_store_stage_is_monotonic_and_invisible():
    st = VersionedWeightStore(_weights())
    c1 = st.stage(_weights(1))
    c2 = st.stage(_weights(2))
    assert (c1.version, c2.version) == (1, 2)
    assert st.serving.version == 0          # staging never swaps
    assert not st.is_live(1) and not st.is_live(2)


def test_store_promote_swaps_only_at_swap_point():
    st = VersionedWeightStore(_weights())
    cand = st.stage(_weights(1))
    assert st.promote(cand)
    # promotion queues the swap; traffic still sees the old version
    assert st.serving.version == 0
    assert st.swap_if_pending()
    assert st.serving.version == 1
    assert not st.swap_if_pending()         # idempotent once applied
    assert st.is_live(1)


def test_store_promote_refuses_corrupt_candidate():
    st = VersionedWeightStore(_weights())
    cand = st.stage(_weights(1))
    bad = dataclasses.replace(
        cand, weights=jnp.asarray(np.asarray(cand.weights) ^ 1,
                                  jnp.uint32))
    assert not bad.verify()
    with pytest.raises(ValueError, match="fingerprint"):
        st.promote(bad)


def test_store_rollback_in_memory():
    st = VersionedWeightStore(_weights())
    st.promote(st.stage(_weights(1)))
    st.swap_if_pending()
    tgt = st.rollback(reason="test")
    assert tgt.version == 0 and tgt.origin == "rollback"
    st.swap_if_pending()
    assert st.serving.version == 0
    assert not st.is_live(1)                # demoted, never serveable
    np.testing.assert_array_equal(np.asarray(st.serving.weights),
                                  np.asarray(_weights()))
    assert st.rollback() is None            # nothing left to fall to


def test_store_rollback_reads_checkpoint_bit_exact(tmp_path):
    st = VersionedWeightStore(_weights(), state_dir=tmp_path)
    w1 = _weights(1)
    st.promote(dataclasses.replace(st.stage(w1), probe_accuracy=0.75))
    st.swap_if_pending()
    w2 = _weights(2)
    st.promote(st.stage(w2))
    st.swap_if_pending()
    tgt = st.rollback(reason="post-promotion regression")
    st.swap_if_pending()
    assert st.serving.version == 1
    assert st.serving.probe_accuracy == 0.75     # round-tripped
    np.testing.assert_array_equal(np.asarray(st.serving.weights),
                                  np.asarray(w1))
    assert tgt.fingerprint == weight_fingerprint(w1)
    # the demoted version's checkpoint is gone: restart converges with
    # post-rollback serving, never the rolled-back bank
    assert not (tmp_path / "step_2").exists()
    st2 = VersionedWeightStore(_weights(), state_dir=tmp_path)
    assert st2.serving.version == 1
    np.testing.assert_array_equal(np.asarray(st2.serving.weights),
                                  np.asarray(w1))


def test_store_restart_restores_newest_complete(tmp_path):
    st = VersionedWeightStore(_weights(), state_dir=tmp_path)
    w3 = _weights(3)
    st.promote(st.stage(w3))
    # a crashed writer's dropping must be ignored AND purged
    torn = tmp_path / "step_9.tmp"
    torn.mkdir()
    (torn / "weights.proc0.npy").write_bytes(b"torn")
    st2 = VersionedWeightStore(_weights(7), state_dir=tmp_path)
    assert st2.serving.version == 1
    assert st2.serving.origin == "restore"
    np.testing.assert_array_equal(np.asarray(st2.serving.weights),
                                  np.asarray(w3))
    assert not torn.exists()


def test_store_save_crash_aborts_promotion(tmp_path):
    st = VersionedWeightStore(_weights(), state_dir=tmp_path)

    def crash(ctx):
        assert ctx["kind"] == "save"
        raise RuntimeError("power loss")

    assert not st.promote(st.stage(_weights(1)), on_save=crash)
    assert st.serving.version == 0
    assert not st.swap_if_pending()         # nothing became swappable
    assert st.save_crashes == 1
    assert (tmp_path / "step_1.tmp").exists()
    assert not (tmp_path / "step_1").exists()
    # a restarted process sees only the complete seed checkpoint
    st2 = VersionedWeightStore(_weights(9), state_dir=tmp_path)
    assert st2.serving.version == 0
    np.testing.assert_array_equal(np.asarray(st2.serving.weights),
                                  np.asarray(_weights()))


# --- refresher --------------------------------------------------------------


def test_refresher_probe_is_pure_function_of_weights():
    rf = _refresher()
    w = _weights()
    assert rf.probe(w) == rf.probe(w)


def test_refresher_epochs_key_fresh_draws():
    rf = _refresher(policy=SNNRefreshPolicy(refresh_every=1,
                                            probe_size=16,
                                            refresh_samples=64))
    w = _weights()
    c1, e1 = rf.next_candidate(w)
    c2, e2 = rf.next_candidate(w)
    assert (e1, e2) == (0, 1)
    # full cyclic pass each time -> same samples, different epochs ->
    # different windows/LFSR chains -> different candidates
    assert not np.array_equal(np.asarray(c1), np.asarray(c2))


def test_refresher_requires_learning_plan():
    inten, labels = _quadrant_data(8)
    with pytest.raises(ValueError, match="learning plan"):
        SNNWeightRefresher(
            dataclasses.replace(PLAN, w_exp=None), inten, labels,
            n_classes=N_CLASSES, probe_intensities=inten,
            probe_labels=labels, neuron_class=NEURON_CLASS, n_steps=T)


# --- engine integration -----------------------------------------------------


def test_refresh_serving_improves_probe_accuracy():
    rf = _refresher()
    eng = SNNServingEngine(_weights(), PLAN, neuron_class=NEURON_CLASS,
                           refresher=rf)
    out = eng.run(_requests(40))
    st = eng.stats()
    assert all(r.terminal for r in out)
    assert st["versions_promoted"] >= 1
    assert st["version_violations"] == 0
    assert rf.probe(eng.weights) > rf.probe(_weights())
    # served versions advance monotonically with rid (promotions only
    # land between steps) and all come from the promotion history
    served = [r for r in out if r.status == "SERVED"]
    vs = [r.served_version for r in sorted(served, key=lambda r: r.rid)]
    assert vs == sorted(vs)
    assert all(v in eng.store.promoted_order for v in vs)


def test_served_counts_bit_exact_with_served_version_oracle():
    eng = SNNServingEngine(_weights(), PLAN, neuron_class=NEURON_CLASS,
                           refresher=_refresher(), keep_versions=64)
    out = eng.run(_requests(32))
    served = [r for r in out if r.status == "SERVED"]
    assert len({r.served_version for r in served}) > 1   # swaps happened
    for r in served:
        ver = eng.store.get(r.served_version)
        np.testing.assert_array_equal(
            r.counts, _oracle(ver.weights, r),
            err_msg=f"rid={r.rid} version={r.served_version}")


def test_corrupt_candidates_always_caught_at_probe_gate():
    inj = FaultInjector(FaultSpec(seed=3, p_refresh_corrupt=1.0))
    eng = SNNServingEngine(_weights(), PLAN, neuron_class=NEURON_CLASS,
                           refresher=_refresher(), on_launch=inj)
    out = eng.run(_requests(32))
    st = eng.stats()
    assert all(r.terminal for r in out)
    assert st["refresh_runs"] >= 3
    # every corrupted candidate was staged, then rejected at the
    # fingerprint gate — none promoted, traffic never saw one
    assert st["refresh_corrupt"] == st["refresh_runs"] \
        == inj.refresh_corruptions
    assert st["versions_promoted"] == 0
    assert st["weight_version"] == 0
    assert {r.served_version for r in out if r.status == "SERVED"} == {0}
    np.testing.assert_array_equal(np.asarray(eng.weights),
                                  np.asarray(_weights()))


def test_stalled_refresh_hits_timeout_and_never_promotes():
    inj = FaultInjector(FaultSpec(seed=3, p_refresh_stall=1.0,
                                  refresh_stall_ms=30.0))
    pol = SNNRefreshPolicy(refresh_every=2, probe_size=16,
                           refresh_samples=16, refresh_timeout_ms=1e-3)
    eng = SNNServingEngine(_weights(), PLAN, neuron_class=NEURON_CLASS,
                           refresher=_refresher(policy=pol),
                           on_launch=inj)
    eng.run(_requests(16))
    st = eng.stats()
    assert st["refresh_timeouts"] == st["refresh_runs"] >= 1
    assert st["versions_promoted"] == 0
    assert inj.refresh_stalls == st["refresh_runs"]


def test_save_crash_leaves_serving_on_old_version(tmp_path):
    inj = FaultInjector(FaultSpec(seed=3, p_save_crash=1.0))
    eng = SNNServingEngine(_weights(), PLAN, neuron_class=NEURON_CLASS,
                           refresher=_refresher(), on_launch=inj,
                           state_dir=tmp_path)
    out = eng.run(_requests(24))
    st = eng.stats()
    assert st["save_crashes"] == inj.save_crashes >= 1
    assert st["versions_promoted"] == 0
    assert {r.served_version for r in out if r.status == "SERVED"} == {0}
    assert any(p.suffix == ".tmp" for p in tmp_path.iterdir())
    # restart: torn tmp purged, seed checkpoint restored bit-exact
    eng2 = SNNServingEngine(_weights(5), PLAN, state_dir=tmp_path)
    assert eng2.store.serving.version == 0
    np.testing.assert_array_equal(np.asarray(eng2.weights),
                                  np.asarray(_weights()))
    assert not any(p.suffix == ".tmp" for p in tmp_path.iterdir())


class CorruptCanaryAfterPromotion:
    """Deterministic hook: once a refreshed version is serving, corrupt
    canary counts in-range (the range guard cannot see it) to force the
    post-promotion rollback path."""

    def __init__(self, engine_ref):
        self.engine_ref = engine_ref

    def __call__(self, ctx):
        if (ctx.get("kind") == "canary"
                and self.engine_ref[0]._pinned.origin == "refresh"):
            def corrupt(counts):
                out = np.array(counts)
                out[:, 0] += 1      # in-range drift: canary's job
                return out
            return corrupt
        return None


def test_canary_mismatch_on_refreshed_version_rolls_back(tmp_path):
    ref = []
    hook = CorruptCanaryAfterPromotion(ref)
    eng = SNNServingEngine(
        _weights(), PLAN, neuron_class=NEURON_CLASS,
        refresher=_refresher(), on_launch=hook, state_dir=tmp_path,
        policy=SNNServingPolicy(canary_every=1))
    ref.append(eng)
    eng.run(_requests(32))
    st = eng.stats()
    assert st["rollbacks"] >= 1
    assert st["canary_failures"] >= 1
    # the rolled-back version is demoted and its checkpoint deleted;
    # serving and a restarted process agree bit-exactly
    assert any(e["event"] == "rollback" for e in eng.refresh_events)
    assert eng.store.is_live(eng.store.serving.version)
    eng2 = SNNServingEngine(_weights(5), PLAN, state_dir=tmp_path)
    np.testing.assert_array_equal(np.asarray(eng2.weights),
                                  np.asarray(eng.weights))


def test_refresh_without_state_dir_is_memory_only():
    eng = SNNServingEngine(_weights(), PLAN, neuron_class=NEURON_CLASS,
                           refresher=_refresher())
    eng.run(_requests(16))
    assert eng.stats()["versions_promoted"] >= 1
    assert eng.store.ckpt is None           # nothing persisted anywhere


def test_frozen_serving_is_unchanged_without_refresher():
    """No refresher, no state_dir: the engine serves version 0 forever
    and the legacy counters/semantics are untouched."""
    eng = SNNServingEngine(_weights(), PLAN, neuron_class=NEURON_CLASS)
    out = eng.run(_requests(12))
    st = eng.stats()
    assert st["refresh_runs"] == 0
    assert st["weight_version"] == 0 and st["weight_origin"] == "seed"
    assert {r.served_version for r in out if r.status == "SERVED"} == {0}


# --- storm acceptance -------------------------------------------------------


def test_refresh_storm_acceptance(tmp_path):
    """The ISSUE's acceptance storm: launch faults + count corruption +
    candidate corruption + stalls + save crashes, all seeded.  Every
    request must reach a terminal status, every served response must be
    attributable to a version promoted and live at serve time, corrupt
    candidates must all die at the probe gate, and a post-storm restart
    must converge bit-exactly with the surviving serving bank."""
    inj = FaultInjector(FaultSpec(
        p_launch_error=0.15, p_corrupt=0.15, seed=11,
        p_refresh_corrupt=0.5, p_refresh_stall=0.25,
        refresh_stall_ms=1.0, p_save_crash=0.25))
    eng = SNNServingEngine(
        _weights(), PLAN, neuron_class=NEURON_CLASS,
        refresher=_refresher(), on_launch=inj, state_dir=tmp_path,
        keep_versions=64,
        policy=SNNServingPolicy(canary_every=3, reprobe_after=4))
    out = eng.run(_requests(48))
    st = eng.stats()
    assert all(r.terminal for r in out)
    assert st["version_violations"] == 0
    assert st["refresh_corrupt"] == inj.refresh_corruptions
    served = [r for r in out if r.status == "SERVED"]
    assert served
    for r in served:
        assert r.served_version in eng.store.promoted_order
        ver = eng.store.get(r.served_version)
        if ver is not None:
            np.testing.assert_array_equal(r.counts,
                                          _oracle(ver.weights, r))
    # storms replay bit-identically: same spec + traffic => identical
    # deterministic counters (timing/latency keys excluded)
    inj2 = FaultInjector(dataclasses.replace(inj.spec))
    eng2 = SNNServingEngine(
        _weights(), PLAN, neuron_class=NEURON_CLASS,
        refresher=_refresher(), on_launch=inj2,
        state_dir=tmp_path / "replay", keep_versions=64,
        policy=SNNServingPolicy(canary_every=3, reprobe_after=4))
    eng2.run(_requests(48))
    timing = {k for k in st if k.endswith(("_ms", "_rps"))
              or "_ms_" in k}
    st2 = eng2.stats()
    assert {k: v for k, v in st2.items() if k not in timing} \
        == {k: v for k, v in st.items() if k not in timing}
    # restart converges with the storm survivor
    eng3 = SNNServingEngine(_weights(5), PLAN, state_dir=tmp_path)
    np.testing.assert_array_equal(np.asarray(eng3.weights),
                                  np.asarray(eng.weights))
    assert eng3.store.serving.version == eng.store.serving.version


def test_fault_spec_validates_refresh_fields():
    with pytest.raises(ValueError, match="p_save_crash"):
        FaultSpec(p_save_crash=1.5)
    with pytest.raises(ValueError, match="refresh_stall_ms"):
        FaultSpec(refresh_stall_ms=-1.0)
