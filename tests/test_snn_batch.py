"""Batched training grid at the network/trainer level, and the keyed
trainer randomness fix."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import network
from repro.core.encoder import poisson_encode_batch
from repro.core.lif import lif_params
from repro.core.rvsnn import snn_regfile, snn_regfile_batch
from repro.core.stdp import init_weights, stdp_params
from repro.core.trainer import SNNTrainConfig, _train_block, train
from repro.data.digits import make_digits


def _stream_operands(b, n, words, n_samples, t_steps):
    lif = lif_params(40, 3)
    stdp = stdp_params(words * 32, w_exp=30, gain=4, ltp_prob=500)
    w0 = init_weights(n, words, dense=False)
    trains = jnp.stack([
        poisson_encode_batch(
            jax.random.key(40 + i),
            jax.random.uniform(jax.random.key(50 + i),
                               (n_samples, words * 32)), t_steps)
        for i in range(b)])
    teach = jnp.asarray(np.random.default_rng(2).integers(
        -50, 50, (b, n_samples, n), dtype=np.int32))
    return lif, stdp, w0, trains, teach


def test_train_stream_batch_matches_sequential_streams():
    """Each batched stream == a sequential train_stream run (weights,
    membrane, LFSR sequence and spike counts)."""
    b, n, words, n_samples, t_steps = 3, 12, 3, 4, 20
    lif, stdp, w0, trains, teach = _stream_operands(
        b, n, words, n_samples, t_steps)
    seeds = [101, 202, 303]
    rfs = snn_regfile_batch(jnp.broadcast_to(w0, (b, n, words)), seeds)
    rfs2, counts = network.train_stream_batch(rfs, trains, teach, lif,
                                              stdp)
    for i in range(b):
        rf2, c2 = network.train_stream(snn_regfile(w0, seed=seeds[i]),
                                       trains[i], teach[i], lif, stdp)
        np.testing.assert_array_equal(np.asarray(rfs2.weights[i]),
                                      np.asarray(rf2.weights))
        np.testing.assert_array_equal(np.asarray(rfs2.lfsr[i]),
                                      np.asarray(rf2.lfsr))
        np.testing.assert_array_equal(np.asarray(rfs2.v[i]),
                                      np.asarray(rf2.v))
        np.testing.assert_array_equal(np.asarray(counts[i]),
                                      np.asarray(c2))


def test_train_stream_batch_step_fallback_matches_window():
    b, n, words, n_samples, t_steps = 2, 10, 2, 3, 12
    lif, stdp, w0, trains, teach = _stream_operands(
        b, n, words, n_samples, t_steps)
    rfs = snn_regfile_batch(jnp.broadcast_to(w0, (b, n, words)), [7, 9])
    rw, cw = network.train_stream_batch(rfs, trains, teach, lif, stdp)
    rs, cs = network.train_stream_batch(rfs, trains, teach, lif, stdp,
                                        cycle_backend="step")
    for a, bb in [(rw.weights, rs.weights), (rw.v, rs.v),
                  (rw.lfsr, rs.lfsr), (cw, cs)]:
        np.testing.assert_array_equal(np.asarray(a), np.asarray(bb))


def test_train_stream_batch_interp_kernel_matches_ref():
    b, n, words, n_samples, t_steps = 2, 10, 2, 2, 9
    lif, stdp, w0, trains, teach = _stream_operands(
        b, n, words, n_samples, t_steps)
    rfs = snn_regfile_batch(jnp.broadcast_to(w0, (b, n, words)), [3, 5])
    rr, cr = network.train_stream_batch(rfs, trains, teach, lif, stdp)
    ri, ci = network.train_stream_batch(rfs, trains, teach, lif, stdp,
                                        kernel_backend="interp",
                                        window_chunk=4)
    for a, bb in [(rr.weights, ri.weights), (rr.v, ri.v),
                  (rr.lfsr, ri.lfsr), (cr, ci)]:
        np.testing.assert_array_equal(np.asarray(a), np.asarray(bb))


def test_parallel_train_mode_deterministic_and_shaped():
    imgs, labels = make_digits(80, seed=13)
    cfg = SNNTrainConfig(n_neurons=20, epochs=1, n_steps=16,
                         train_mode="parallel")
    m1 = train(cfg, imgs, labels)
    m2 = train(cfg, imgs, labels)
    assert m1.weights.shape == (20, cfg.words)
    np.testing.assert_array_equal(np.asarray(m1.neuron_class),
                                  np.tile(np.arange(10), 2))
    np.testing.assert_array_equal(np.asarray(m1.weights),
                                  np.asarray(m2.weights))


def test_parallel_blocks_decorrelated_by_keyed_seeds():
    """Parallel blocks share data + params; only keyed LFSR seeds differ,
    so their learned rows must differ."""
    imgs, labels = make_digits(80, seed=17)
    cfg = SNNTrainConfig(n_neurons=20, epochs=1, n_steps=16,
                         train_mode="parallel")
    m = train(cfg, imgs, labels)
    w = np.asarray(m.weights)
    assert (w[:10] != w[10:]).any()


def test_train_block_key_is_used_and_reproducible():
    """_train_block must thread its PRNG key into the regfile seeding:
    same key -> identical weights, different key -> different weights."""
    imgs, labels = make_digits(60, seed=19)
    cfg = SNNTrainConfig(n_neurons=10, epochs=1, n_steps=16)
    sp = poisson_encode_batch(jax.random.key(0),
                              jnp.asarray(imgs, jnp.float32), cfg.n_steps)
    labels_j = jnp.asarray(labels, jnp.int32)
    wa = _train_block(cfg, jax.random.key(1), labels_j, 0,
                      spike_trains=sp)
    wb = _train_block(cfg, jax.random.key(1), labels_j, 0,
                      spike_trains=sp)
    wc = _train_block(cfg, jax.random.key(2), labels_j, 0,
                      spike_trains=sp)
    np.testing.assert_array_equal(np.asarray(wa), np.asarray(wb))
    assert (np.asarray(wa) != np.asarray(wc)).any()
