"""Neuron-axis mesh sharding of the window engine.

The multi-device case needs ``--xla_force_host_platform_device_count``
set before jax initializes, so it runs in a subprocess; the in-process
test exercises the same shard_map path on whatever mesh this process
has (1 CPU device under plain pytest).
"""

import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lfsr
from repro.distributed import snn_mesh
from repro.kernels import ops

REPO = Path(__file__).resolve().parents[1]


def test_sharded_ops_match_unsharded_on_local_mesh():
    mesh = snn_mesh.snn_mesh()
    rng = np.random.default_rng(4)
    n, w, t, b = 24, 5, 9, 3
    weights = jnp.asarray(rng.integers(0, 2**32, (n, w), dtype=np.uint32))
    trains = jnp.asarray(
        rng.integers(0, 2**32, (b, t, w), dtype=np.uint32))
    v = jnp.zeros((n,), jnp.int32)
    teach = jnp.asarray(rng.integers(-50, 50, (n,), dtype=np.int32))
    st = lfsr.seed(7, n * w).reshape(n, w)
    kw = dict(threshold=60, leak=4, w_exp=64, gain=4, n_syn=w * 32,
              ltp_prob=200)

    got = snn_mesh.sharded_infer_window_batch(
        weights, trains, threshold=60, leak=4, mesh=mesh)
    want = ops.infer_window_batch(weights, trains, threshold=60, leak=4)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    for train in (True, False):
        got = snn_mesh.sharded_fused_snn_window(
            weights, trains[0], v, st, teach, train=train, mesh=mesh,
            **kw)
        want = ops.fused_snn_window(weights, trains[0], v, st, teach,
                                    train=train, **kw)
        for g, r in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(r))


def test_sharded_handles_non_divisible_neuron_axis():
    """n not a multiple of the mesh size pads + slices transparently."""
    mesh = snn_mesh.snn_mesh()
    d = mesh.shape["neuron"]
    n, w = d * 4 + 3, 3
    rng = np.random.default_rng(8)
    weights = jnp.asarray(rng.integers(0, 2**32, (n, w), dtype=np.uint32))
    trains = jnp.asarray(rng.integers(0, 2**32, (2, 6, w),
                                      dtype=np.uint32))
    got = snn_mesh.sharded_infer_window_batch(
        weights, trains, threshold=20, leak=2, mesh=mesh)
    want = ops.infer_window_batch(weights, trains, threshold=20, leak=2)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.slow
def test_multi_device_host_mesh_subprocess():
    """Sharded == unsharded on a real 8-device CPU mesh (fresh jax)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8"
                        ).strip()
    env["PYTHONPATH"] = (str(REPO / "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.distributed.snn_mesh", "--check",
         "--devices", "8", "--neurons", "64", "--words", "5",
         "--steps", "8", "--batch", "2"],
        env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "sharded(8 devices) == single-device" in proc.stdout
