"""Neuron-axis mesh sharding of the window engine.

The multi-device case needs ``--xla_force_host_platform_device_count``
set before jax initializes, so it runs in a subprocess; the in-process
test exercises the same shard_map path on whatever mesh this process
has (1 CPU device under plain pytest).
"""

import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lfsr
from repro.distributed import snn_mesh
from repro.kernels import ops

REPO = Path(__file__).resolve().parents[1]


def test_sharded_ops_match_unsharded_on_local_mesh():
    mesh = snn_mesh.snn_mesh()
    rng = np.random.default_rng(4)
    n, w, t, b = 24, 5, 9, 3
    weights = jnp.asarray(rng.integers(0, 2**32, (n, w), dtype=np.uint32))
    trains = jnp.asarray(
        rng.integers(0, 2**32, (b, t, w), dtype=np.uint32))
    v = jnp.zeros((n,), jnp.int32)
    teach = jnp.asarray(rng.integers(-50, 50, (n,), dtype=np.int32))
    st = lfsr.seed(7, n * w).reshape(n, w)
    kw = dict(threshold=60, leak=4, w_exp=64, gain=4, n_syn=w * 32,
              ltp_prob=200)

    got = snn_mesh.sharded_infer_window_batch(
        weights, trains, threshold=60, leak=4, mesh=mesh)
    want = ops.infer_window_batch(weights, trains, threshold=60, leak=4)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    for train in (True, False):
        got = snn_mesh.sharded_fused_snn_window(
            weights, trains[0], v, st, teach, train=train, mesh=mesh,
            **kw)
        want = ops.fused_snn_window(weights, trains[0], v, st, teach,
                                    train=train, **kw)
        for g, r in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(r))


def test_sharded_handles_non_divisible_neuron_axis():
    """n not a multiple of the mesh size pads + slices transparently."""
    mesh = snn_mesh.snn_mesh()
    d = mesh.shape["neuron"]
    n, w = d * 4 + 3, 3
    rng = np.random.default_rng(8)
    weights = jnp.asarray(rng.integers(0, 2**32, (n, w), dtype=np.uint32))
    trains = jnp.asarray(rng.integers(0, 2**32, (2, 6, w),
                                      dtype=np.uint32))
    got = snn_mesh.sharded_infer_window_batch(
        weights, trains, threshold=20, leak=2, mesh=mesh)
    want = ops.infer_window_batch(weights, trains, threshold=20, leak=2)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_sharded_2d_ops_match_unsharded_on_local_mesh():
    """The 2-D wrappers run the (1, 1) degenerate grid in-process:
    batch axes spec'd over "data", state over "neurons", bit-exact with
    the unsharded ops (the real factorizations run in the subprocess
    test below)."""
    mesh = snn_mesh.snn_mesh2d(1, 1)
    rng = np.random.default_rng(11)
    n, w, t, b = 24, 5, 9, 3
    kw = dict(threshold=60, leak=4, w_exp=64, gain=4, n_syn=w * 32,
              ltp_prob=200)
    trains = jnp.asarray(
        rng.integers(0, 2**32, (b, t, w), dtype=np.uint32))
    wts_b = jnp.asarray(
        rng.integers(0, 2**32, (b, n, w), dtype=np.uint32))
    vb = jnp.zeros((b, n), jnp.int32)
    tb = jnp.asarray(rng.integers(-50, 50, (b, n), dtype=np.int32))
    stb = jnp.stack([lfsr.seed(3 + i, n * w).reshape(n, w)
                     for i in range(b)])
    inten = jnp.asarray(rng.integers(0, 256, (b, w * 32),
                                     dtype=np.uint8))
    seeds = jnp.arange(1, b + 1, dtype=jnp.int32)

    got = snn_mesh.sharded_train_window_batch(
        wts_b, trains, vb, stb, tb, mesh=mesh, **kw)
    want = ops.train_window_batch(wts_b, trains, vb, stb, tb, **kw)
    for g, r in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(r))

    got = snn_mesh.sharded_train_window_batch_encode(
        wts_b, inten, seeds, vb, stb, tb, n_steps=t, mesh=mesh, **kw)
    want = ops.train_window_batch_encode(
        wts_b, inten, seeds, vb, stb, tb, n_steps=t, **kw)
    for g, r in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(r))

    got = snn_mesh.sharded_infer_window_batch_encode(
        wts_b[0], inten, seeds, n_steps=t, threshold=60, leak=4,
        mesh=mesh)
    want = ops.infer_window_batch_encode(
        wts_b[0], inten, seeds, n_steps=t, threshold=60, leak=4)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.slow
def test_multi_device_host_mesh_subprocess():
    """Sharded == unsharded on a real 8-device CPU mesh (fresh jax)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8"
                        ).strip()
    env["PYTHONPATH"] = (str(REPO / "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.distributed.snn_mesh", "--check",
         "--devices", "8", "--neurons", "64", "--words", "5",
         "--steps", "8", "--batch", "2"],
        env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "sharded(8 devices) == single-device" in proc.stdout


@pytest.mark.slow
def test_2d_factorizations_subprocess():
    """(2,4), (4,2) and (8,1) grids of the same 8 host devices are all
    bit-exact with the unsharded oracle — pre-packed AND encode-fused,
    infer AND train_batch — in one fresh-jax subprocess (batch 5 and 26
    neurons don't divide any factorization, so padding is exercised
    everywhere)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8"
                        ).strip()
    env["PYTHONPATH"] = (str(REPO / "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.distributed.snn_mesh", "--check",
         "--mesh-shape", "2,4", "--mesh-shape", "4,2",
         "--mesh-shape", "8,1", "--neurons", "26", "--words", "5",
         "--steps", "8", "--batch", "5"],
        env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    for lbl in ("2x4", "4x2", "8x1"):
        for op in ("infer_window_batch", "train_window_batch",
                   "infer_window_batch_encode",
                   "train_window_batch_encode"):
            assert (f"{op}: sharded({lbl} mesh) == single-device"
                    in proc.stdout), (lbl, op, proc.stdout)
